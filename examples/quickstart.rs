//! Quickstart: track a model with Git-Theta, make a sparse update,
//! inspect the parameter-group diff, and time-travel.
//!
//! ```bash
//! cargo run --release --example quickstart
//! ```

use git_theta::checkpoint::{Checkpoint, CheckpointFormat, SafetensorsFormat};
use git_theta::gitcore::repo::Repository;
use git_theta::tensor::Tensor;
use git_theta::util::rng::Pcg64;
use git_theta::util::tmp::TempDir;

fn main() -> anyhow::Result<()> {
    git_theta::init();
    let td = TempDir::new("quickstart")?;
    let repo = Repository::init(td.path())?;
    println!("repo: {}", td.path().display());

    // 1. Track the checkpoint with Git-Theta (writes .thetaattributes).
    git_theta::theta::track(&repo, "model.safetensors")?;

    // 2. Write and commit a small "pre-trained" model.
    let mut rng = Pcg64::new(7);
    let mut ck = Checkpoint::new();
    for (name, m, n) in [("encoder/wq", 64, 64), ("encoder/wv", 64, 64), ("head/w", 64, 8)] {
        let vals: Vec<f32> = (0..m * n).map(|_| rng.next_gaussian() as f32 * 0.02).collect();
        ck.insert(name, Tensor::from_f32(vec![m, n], vals)?);
    }
    SafetensorsFormat.save_file(&ck, &td.join("model.safetensors"))?;
    repo.add(&["model.safetensors", ".thetaattributes"])?;
    let v1 = repo.commit("add pre-trained model", "you <you@example.com>")?;
    println!("committed v1 {}", v1.short());

    // 3. Make a sparse update (3 parameters of one group) and commit.
    let mut vals = ck.get("encoder/wq").unwrap().to_f32_vec()?;
    vals[0] += 0.5;
    vals[100] -= 0.25;
    vals[4000] = 1.0;
    ck.insert("encoder/wq", Tensor::from_f32(vec![64, 64], vals)?);
    SafetensorsFormat.save_file(&ck, &td.join("model.safetensors"))?;
    repo.add(&["model.safetensors"])?;
    let v2 = repo.commit("tune 3 parameters", "you <you@example.com>")?;
    println!("committed v2 {}", v2.short());

    // 4. Parameter-group diff (the theta diff driver).
    println!("\n$ git-theta diff v1 v2");
    print!("{}", repo.diff(Some(v1), Some(v2))?);

    // 5. Storage: only the sparse delta was stored for v2.
    let store = git_theta::lfs::LfsStore::open(repo.theta_dir());
    println!(
        "\nLFS store: {} objects, {}",
        store.list()?.len(),
        git_theta::util::humansize::bytes(store.disk_usage()?)
    );

    // 6. Time-travel: checkout v1 and verify the original values.
    repo.checkout(&v1.to_hex())?;
    let old = SafetensorsFormat.load_file(&td.join("model.safetensors"))?;
    assert_eq!(old.get("encoder/wq").unwrap().to_f32_vec()?[0], {
        let mut r = Pcg64::new(7);
        r.next_gaussian() as f32 * 0.02
    });
    println!("checked out v1: original parameters restored exactly");
    Ok(())
}
