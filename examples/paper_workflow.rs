//! End-to-end driver: the paper's full §4 evaluation on a real (small)
//! workload, proving all three layers compose.
//!
//! 1. **Figure 3** — real training: the L2 transformer is trained from
//!    Rust via the AOT `train_step`/`train_step_lora`/`eval_step` HLO
//!    artifacts (Pallas attention kernel inside), each stage committed
//!    through Git-Theta, the branches merged by the native merge driver
//!    with parameter averaging, and every task evaluated at every
//!    commit.
//! 2. **Table 1 / Figure 2** — the six-commit storage/timing comparison
//!    against the Git LFS baseline.
//!
//! ```bash
//! make artifacts && cargo run --release --example paper_workflow
//! # larger Table 1 model: THETA_BENCH_PARAMS=120 cargo run ...
//! ```

use git_theta::benchkit::{figure3, workflow};

fn main() -> anyhow::Result<()> {
    git_theta::init();

    println!("=== Figure 3: performance across commit history (real training) ===");
    let steps: usize = std::env::var("THETA_FIG3_STEPS")
        .ok()
        .and_then(|v| v.parse().ok())
        .unwrap_or(600);
    match figure3::run_figure3(steps, 0.1)? {
        Some(result) => print!("{}", figure3::render_figure3(&result)),
        None => println!("skipped: run `make artifacts` first"),
    }

    println!("\n=== Table 1: Git LFS vs Git-Theta over the 6-commit workflow ===");
    let cfg = workflow::ModelConfig::from_env();
    println!(
        "model: d={} layers={} vocab={}+{} = {:.1}M params",
        cfg.d_model,
        cfg.layers,
        cfg.vocab,
        cfg.sentinels,
        cfg.param_count() as f64 / 1e6
    );
    let models = workflow::build_models(&cfg, 42);
    let lfs = workflow::run_lfs_workflow(&models)?;
    let theta = workflow::run_theta_workflow(&models)?;
    print!("{}", workflow::render_table1(&lfs, &theta));

    println!("\n=== Figure 2: relative space savings ===");
    print!(
        "{}",
        workflow::render_figure2(&workflow::figure2_series(&lfs, &theta))
    );
    Ok(())
}
