//! Distributed collaboration: two contributors, one remote.
//!
//! Alice publishes a base model; Bob clones it (downloading only the
//! metadata + the parameters he checks out), fine-tunes one group, and
//! pushes back — transferring only the sparse delta. Alice pulls and
//! merges Bob's branch with her own concurrent change using parameter
//! averaging. This is the paper's "bazaar" workflow end to end.
//!
//! ```bash
//! cargo run --release --example collaboration
//! ```

use git_theta::baseline::ThetaRepo;
use git_theta::checkpoint::{Checkpoint, CheckpointFormat, SafetensorsFormat};
use git_theta::gitcore::repo::Repository;
use git_theta::lfs::LfsStore;
use git_theta::tensor::Tensor;
use git_theta::util::humansize;
use git_theta::util::rng::Pcg64;
use git_theta::util::tmp::TempDir;

fn main() -> anyhow::Result<()> {
    git_theta::init();
    let remote = TempDir::new("remote")?;
    let alice_dir = TempDir::new("alice")?;
    let bob_dir = TempDir::new("bob")?;

    // Alice publishes the base model.
    let alice = ThetaRepo::init(alice_dir.path(), "model.safetensors")?;
    let mut rng = Pcg64::new(3);
    let mut ck = Checkpoint::new();
    for l in 0..4 {
        let vals: Vec<f32> = (0..256 * 256).map(|_| rng.next_gaussian() as f32 * 0.02).collect();
        ck.insert(format!("layer_{l}/w"), Tensor::from_f32(vec![256, 256], vals)?);
    }
    alice.write_model(&ck)?;
    alice.repo.add(&["model.safetensors", ".thetaattributes"])?;
    alice.commit("base model")?;
    let report = alice.repo.push(remote.path(), "main")?;
    println!(
        "alice pushed base: {} objects, {}",
        report.objects_sent,
        humansize::bytes(report.bytes_sent)
    );

    // Bob clones (init + config remote + pull) and fine-tunes layer_0.
    let bob_repo = Repository::init(bob_dir.path())?;
    bob_repo.config_set("remote", remote.path().to_str().unwrap())?;
    bob_repo.pull(remote.path(), "main")?;
    println!(
        "bob cloned; local LFS cache holds {}",
        humansize::bytes(LfsStore::open(bob_repo.theta_dir()).disk_usage()?)
    );

    let mut bob_ck = SafetensorsFormat.load_file(&bob_dir.join("model.safetensors"))?;
    let mut vals = bob_ck.get("layer_0/w").unwrap().to_f32_vec()?;
    for v in vals.iter_mut().take(500) {
        *v += 0.01; // Bob's sparse-ish tune
    }
    bob_ck.insert("layer_0/w", Tensor::from_f32(vec![256, 256], vals)?);
    SafetensorsFormat.save_file(&bob_ck, &bob_dir.join("model.safetensors"))?;
    bob_repo.add(&["model.safetensors"])?;
    bob_repo.commit("bob: tune layer_0", "bob <bob@example.com>")?;
    let report = bob_repo.push(remote.path(), "main")?;
    println!(
        "bob pushed update: {} objects, {} (only the delta moved)",
        report.objects_sent,
        humansize::bytes(report.bytes_sent)
    );
    assert!(report.bytes_sent < 200_000, "delta should be small");

    // Alice concurrently tuned layer_3 on a branch, then pulls Bob's
    // main and merges — non-overlapping groups merge automatically.
    alice.repo.create_branch("alice-tune")?;
    alice.checkout("alice-tune")?;
    let mut alice_ck = alice.read_model()?;
    let mut vals = alice_ck.get("layer_3/w").unwrap().to_f32_vec()?;
    for v in vals.iter_mut().take(500) {
        *v -= 0.01;
    }
    alice_ck.insert("layer_3/w", Tensor::from_f32(vec![256, 256], vals)?);
    alice.write_model(&alice_ck)?;
    alice.repo.add(&["model.safetensors"])?;
    alice.commit("alice: tune layer_3")?;

    alice.checkout("main")?;
    alice.repo.pull(remote.path(), "main")?;
    let report = alice.repo.merge(
        "alice-tune",
        &git_theta::gitcore::drivers::MergeOptions::default(),
        "alice <alice@example.com>",
    )?;
    println!(
        "alice merged her branch with bob's main (driver resolved {} groups)",
        report.driver_resolved.len()
    );

    // Both tunes are present in the final model.
    let merged = alice.read_model()?;
    assert!(merged.get("layer_0/w").unwrap().to_f32_vec()?[0] > 0.0 + ck.get("layer_0/w").unwrap().to_f32_vec()?[0]);
    assert!(merged.get("layer_3/w").unwrap().to_f32_vec()?[0] < ck.get("layer_3/w").unwrap().to_f32_vec()?[0]);
    println!("final model contains both contributors' updates ✓");
    Ok(())
}
