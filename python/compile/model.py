"""Layer 2: the JAX transformer classifier used for Figure 3.

A small pre-LN transformer with learned positions, causal attention
(through the L1 Pallas kernel), mean pooling, and a linear head. Three
compiled entry points, all lowered to HLO text by aot.py:

* ``train_step``       — full fine-tune (SGD), returns (params, loss)
* ``train_step_lora``  — LoRA adapters on q/v only, base frozen,
                         returns (lora, loss)
* ``eval_step``        — returns (correct_count, loss)

Parameters are flat dicts keyed by names that match the Rust side
(``block_0/attn/q`` etc.); JAX flattens dicts in sorted-key order,
which is the order recorded in ``artifacts/manifest.json``.
"""

import jax
import jax.numpy as jnp

from .kernels.attention import attention as attention_kernel


class ModelConfig:
    def __init__(
        self,
        vocab=256,
        seq_len=32,
        d_model=128,
        layers=2,
        heads=4,
        classes=2,
        batch=32,
        lora_rank=8,
    ):
        self.vocab = vocab
        self.seq_len = seq_len
        self.d_model = d_model
        self.layers = layers
        self.heads = heads
        self.classes = classes
        self.batch = batch
        self.lora_rank = lora_rank

    def to_dict(self):
        return {
            "vocab": self.vocab,
            "seq_len": self.seq_len,
            "d_model": self.d_model,
            "layers": self.layers,
            "heads": self.heads,
            "classes": self.classes,
            "batch": self.batch,
            "lora_rank": self.lora_rank,
        }


def init_params(cfg, key):
    """Initialize base parameters (the 'pre-trained' stand-in)."""
    params = {}
    k = iter(jax.random.split(key, 64))
    d = cfg.d_model

    def dense(shape, scale):
        return (jax.random.normal(next(k), shape) * scale).astype(jnp.float32)

    params["embed/weight"] = dense((cfg.vocab, d), 0.02)
    params["pos/weight"] = dense((cfg.seq_len, d), 0.02)
    for l in range(cfg.layers):
        p = f"block_{l}"
        for name in ("q", "k", "v", "o"):
            params[f"{p}/attn/{name}"] = dense((d, d), d**-0.5)
        params[f"{p}/mlp/wi"] = dense((d, 4 * d), d**-0.5)
        params[f"{p}/mlp/wo"] = dense((4 * d, d), (4 * d) ** -0.5)
        params[f"{p}/ln1/scale"] = jnp.ones((d,), jnp.float32)
        params[f"{p}/ln2/scale"] = jnp.ones((d,), jnp.float32)
    params["ln_f/scale"] = jnp.ones((d,), jnp.float32)
    params["head/weight"] = dense((d, cfg.classes), d**-0.5)
    return params


def init_lora(cfg, key):
    """Zero-init LoRA adapters for every q/v projection (B side zero,
    so the adapted model starts identical to the base)."""
    lora = {}
    k = iter(jax.random.split(key, 32))
    d = cfg.d_model
    r = cfg.lora_rank
    for l in range(cfg.layers):
        for name in ("q", "v"):
            target = f"block_{l}/attn/{name}"
            lora[f"{target}.lora_a"] = (
                jax.random.normal(next(k), (d, r)) * 0.01
            ).astype(jnp.float32)
            lora[f"{target}.lora_b"] = jnp.zeros((r, d), jnp.float32)
    return lora


def _layer_norm(x, scale):
    mean = jnp.mean(x, axis=-1, keepdims=True)
    var = jnp.var(x, axis=-1, keepdims=True)
    return (x - mean) / jnp.sqrt(var + 1e-6) * scale


def _proj(h, params, lora, name):
    w = params[name]
    y = h @ w
    if lora is not None and f"{name}.lora_a" in lora:
        # scale 1.0 (alpha == r by convention; rust merges with alpha=r).
        y = y + (h @ lora[f"{name}.lora_a"]) @ lora[f"{name}.lora_b"]
    return y


def forward(params, lora, tokens, cfg):
    """tokens: (B, S) int32 -> logits (B, classes)."""
    b, s = tokens.shape
    d = cfg.d_model
    h_count = cfg.heads
    dh = d // h_count

    x = params["embed/weight"][tokens] + params["pos/weight"][None, :s, :]
    for l in range(cfg.layers):
        p = f"block_{l}"
        h = _layer_norm(x, params[f"{p}/ln1/scale"])
        q = _proj(h, params, lora, f"{p}/attn/q")
        k = _proj(h, params, None, f"{p}/attn/k")
        v = _proj(h, params, lora, f"{p}/attn/v")

        def split(t):
            return t.reshape(b, s, h_count, dh).transpose(0, 2, 1, 3).reshape(b * h_count, s, dh)

        attn = attention_kernel(split(q), split(k), split(v))
        attn = attn.reshape(b, h_count, s, dh).transpose(0, 2, 1, 3).reshape(b, s, d)
        x = x + attn @ params[f"{p}/attn/o"]

        h2 = _layer_norm(x, params[f"{p}/ln2/scale"])
        x = x + jax.nn.relu(h2 @ params[f"{p}/mlp/wi"]) @ params[f"{p}/mlp/wo"]

    x = _layer_norm(x, params["ln_f/scale"])
    pooled = jnp.mean(x, axis=1)
    return pooled @ params["head/weight"]


def loss_fn(params, lora, tokens, labels, cfg):
    logits = forward(params, lora, tokens, cfg)
    logp = jax.nn.log_softmax(logits, axis=-1)
    nll = -jnp.take_along_axis(logp, labels[:, None], axis=-1).mean()
    return nll


def make_train_step(cfg):
    def train_step(params, tokens, labels, lr):
        loss, grads = jax.value_and_grad(loss_fn)(params, None, tokens, labels, cfg)
        new_params = jax.tree_util.tree_map(lambda p, g: p - lr * g, params, grads)
        return new_params, loss

    return train_step


def make_train_step_lora(cfg):
    def train_step_lora(params, lora, tokens, labels, lr):
        def lora_loss(lora_params):
            return loss_fn(params, lora_params, tokens, labels, cfg)

        loss, grads = jax.value_and_grad(lora_loss)(lora)
        new_lora = jax.tree_util.tree_map(lambda p, g: p - lr * g, lora, grads)
        return new_lora, loss

    return train_step_lora


def make_eval_step(cfg):
    def eval_step(params, tokens, labels):
        logits = forward(params, None, tokens, cfg)
        correct = jnp.sum((jnp.argmax(logits, axis=-1) == labels).astype(jnp.float32))
        logp = jax.nn.log_softmax(logits, axis=-1)
        nll = -jnp.take_along_axis(logp, labels[:, None], axis=-1).mean()
        return correct, nll

    return eval_step
