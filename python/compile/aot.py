"""AOT lowering: JAX/Pallas (L2/L1) → HLO text artifacts for the Rust
runtime.

Run once via ``make artifacts``. Emits into ``artifacts/``:

* ``train_step.hlo.txt``, ``train_step_lora.hlo.txt``,
  ``eval_step.hlo.txt`` — the Figure 3 model entry points;
* ``lsh_project.hlo.txt``, ``param_average.hlo.txt``,
  ``lora_apply_{m}x{n}x{r}.hlo.txt`` — standalone kernels used by the
  Rust mlops layer;
* ``init_params.safetensors`` / ``init_lora.safetensors`` — initial
  parameters (hand-rolled safetensors writer; interoperates with the
  Rust reader);
* ``manifest.json`` — model dims + flattened parameter ordering.

HLO **text** is the interchange format: jax >= 0.5 serialized protos
carry 64-bit instruction ids that xla_extension 0.5.1 rejects; the
text parser reassigns ids (see /opt/xla-example/README.md).
"""

import argparse
import json
import os
import struct

import jax
import jax.numpy as jnp
from jax._src.lib import xla_client as xc

from . import model as model_lib
from .kernels import average as average_kernel
from .kernels import lora as lora_kernel
from .kernels import lsh as lsh_kernel

SEED = 20230717


def to_hlo_text(lowered) -> str:
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    return comp.as_hlo_text()


def save_safetensors(path, tensors):
    """Minimal safetensors writer (f32 only), compatible with the Rust
    reader in rust/src/checkpoint/safetensors.rs."""
    header = {}
    offset = 0
    names = sorted(tensors)
    for name in names:
        t = tensors[name]
        nbytes = t.size * 4
        header[name] = {
            "dtype": "F32",
            "shape": list(t.shape),
            "data_offsets": [offset, offset + nbytes],
        }
        offset += nbytes
    header_text = json.dumps(header, separators=(",", ":"))
    while (8 + len(header_text)) % 8 != 0:
        header_text += " "
    with open(path, "wb") as f:
        f.write(struct.pack("<Q", len(header_text)))
        f.write(header_text.encode())
        import numpy as np

        for name in names:
            f.write(np.asarray(tensors[name], dtype="<f4").tobytes())


def write(out_dir, name, lowered):
    text = to_hlo_text(lowered)
    path = os.path.join(out_dir, f"{name}.hlo.txt")
    with open(path, "w") as f:
        f.write(text)
    print(f"  wrote {path} ({len(text)} chars)")


def lower_model(cfg, out_dir):
    key = jax.random.PRNGKey(SEED)
    params = model_lib.init_params(cfg, key)
    lora = model_lib.init_lora(cfg, jax.random.fold_in(key, 1))

    tok_spec = jax.ShapeDtypeStruct((cfg.batch, cfg.seq_len), jnp.int32)
    lab_spec = jax.ShapeDtypeStruct((cfg.batch,), jnp.int32)
    lr_spec = jax.ShapeDtypeStruct((), jnp.float32)
    p_spec = jax.tree_util.tree_map(
        lambda t: jax.ShapeDtypeStruct(t.shape, t.dtype), params
    )
    l_spec = jax.tree_util.tree_map(
        lambda t: jax.ShapeDtypeStruct(t.shape, t.dtype), lora
    )

    write(
        out_dir,
        "train_step",
        jax.jit(model_lib.make_train_step(cfg)).lower(p_spec, tok_spec, lab_spec, lr_spec),
    )
    write(
        out_dir,
        "train_step_lora",
        jax.jit(model_lib.make_train_step_lora(cfg)).lower(
            p_spec, l_spec, tok_spec, lab_spec, lr_spec
        ),
    )
    write(
        out_dir,
        "eval_step",
        jax.jit(model_lib.make_eval_step(cfg)).lower(p_spec, tok_spec, lab_spec),
    )

    save_safetensors(os.path.join(out_dir, "init_params.safetensors"), params)
    save_safetensors(os.path.join(out_dir, "init_lora.safetensors"), lora)

    manifest = {
        "model": {
            **cfg.to_dict(),
            "param_names": sorted(params),
            "lora_param_names": sorted(lora),
        },
    }
    with open(os.path.join(out_dir, "manifest.json"), "w") as f:
        json.dump(manifest, f, indent=2)
    print("  wrote manifest.json + init params")


def lower_kernels(cfg, out_dir):
    # LSH projection block.
    x_spec = jax.ShapeDtypeStruct(
        (lsh_kernel.BLOCK_ROWS, lsh_kernel.POOL_SIZE), jnp.float32
    )
    pool_spec = jax.ShapeDtypeStruct(
        (lsh_kernel.POOL_SIZE, lsh_kernel.NUM_HASHES), jnp.float32
    )
    write(out_dir, "lsh_project", jax.jit(lsh_kernel.lsh_project).lower(x_spec, pool_spec))

    # Parameter averaging block.
    n = 1 << 20
    v_spec = jax.ShapeDtypeStruct((n,), jnp.float32)
    write(out_dir, "param_average", jax.jit(average_kernel.param_average).lower(v_spec, v_spec))

    # LoRA application for the model's attention shape and a larger
    # benchmark shape.
    d = cfg.d_model
    for (m, nn, r) in [(d, d, cfg.lora_rank), (512, 512, 16)]:
        w_spec = jax.ShapeDtypeStruct((m, nn), jnp.float32)
        a_spec = jax.ShapeDtypeStruct((m, r), jnp.float32)
        b_spec = jax.ShapeDtypeStruct((r, nn), jnp.float32)
        alpha_spec = jax.ShapeDtypeStruct((1,), jnp.float32)
        write(
            out_dir,
            f"lora_apply_{m}x{nn}x{r}",
            jax.jit(lora_kernel.lora_apply).lower(w_spec, a_spec, b_spec, alpha_spec),
        )


def main():
    parser = argparse.ArgumentParser()
    parser.add_argument("--out", default="../artifacts")
    parser.add_argument("--d-model", type=int, default=128)
    parser.add_argument("--layers", type=int, default=2)
    parser.add_argument("--vocab", type=int, default=256)
    args = parser.parse_args()
    os.makedirs(args.out, exist_ok=True)
    cfg = model_lib.ModelConfig(
        vocab=args.vocab, d_model=args.d_model, layers=args.layers
    )
    print(f"lowering model {cfg.to_dict()}")
    lower_model(cfg, args.out)
    lower_kernels(cfg, args.out)
    print("done")


if __name__ == "__main__":
    main()
