"""Pure-jnp oracles for the Pallas kernels.

Every kernel in this package has a reference implementation here;
pytest (python/tests/) sweeps shapes and dtypes with hypothesis and
asserts allclose between kernel and oracle.
"""

import jax
import jax.numpy as jnp


def lsh_project(x, pool):
    """Pooled LSH projection.

    x:    (rows, POOL) f32 — parameter values folded into pool-width rows
          (zero-padded).
    pool: (POOL, K) f32 — fixed Gaussian pool matrix.
    returns (K,) f32 — projections y_j = sum_i x_i * pool[i mod POOL, j].
    """
    return jnp.sum(x @ pool, axis=0)


def lora_apply(w, a, b, alpha):
    """W + (alpha / r) * A @ B."""
    r = a.shape[1]
    scale = alpha / r if r > 0 else 0.0
    return w + scale * (a @ b)


def param_average(x, y):
    """Elementwise mean of two parameter vectors."""
    return (x + y) * 0.5


def attention(q, k, v):
    """Causal single-head attention over (BH, S, Dh) tensors."""
    s = q.shape[-2]
    scale = 1.0 / jnp.sqrt(jnp.asarray(q.shape[-1], dtype=q.dtype))
    scores = jnp.einsum("bsd,btd->bst", q, k) * scale
    mask = jnp.tril(jnp.ones((s, s), dtype=bool))
    scores = jnp.where(mask[None, :, :], scores, jnp.finfo(scores.dtype).min)
    probs = jax.nn.softmax(scores, axis=-1)
    return jnp.einsum("bst,btd->bsd", probs, v)
