"""Pallas kernel: parameter averaging (Layer 1).

The merge driver's hot loop: elementwise mean of two flattened
parameter blocks. Pure VPU work, tiled so each grid step streams one
chunk of both inputs through VMEM.
"""

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

CHUNK = 65536


def _kernel(x_ref, y_ref, o_ref):
    o_ref[...] = (x_ref[...] + y_ref[...]) * 0.5


def param_average(x, y):
    """x, y: (N,) f32 with N % CHUNK == 0 -> (N,) f32."""
    n = x.shape[0]
    chunk = min(CHUNK, n)
    grid = (n // chunk,)
    return pl.pallas_call(
        _kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((chunk,), lambda i: (i,)),
            pl.BlockSpec((chunk,), lambda i: (i,)),
        ],
        out_specs=pl.BlockSpec((chunk,), lambda i: (i,)),
        out_shape=jax.ShapeDtypeStruct((n,), jnp.float32),
        interpret=True,
    )(x, y)
