"""Pallas kernel: LoRA application W' = W + (alpha/r) * A @ B (Layer 1).

Tiled for VMEM: each grid step holds one (BM, BN) tile of W plus the
matching (BM, r) rows of A and (r, BN) columns of B; the factor matmul
runs on the MXU and the add is fused, saving a second round trip of W
through HBM versus materializing A@B first.
"""

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

BM = 64
BN = 64


def _kernel(w_ref, a_ref, b_ref, alpha_ref, o_ref, *, rank):
    scale = alpha_ref[0] / rank if rank > 0 else 0.0
    delta = jnp.dot(a_ref[...], b_ref[...], preferred_element_type=jnp.float32)
    o_ref[...] = w_ref[...] + scale * delta


def lora_apply(w, a, b, alpha):
    """w: (m, n), a: (m, r), b: (r, n), alpha: (1,) -> (m, n)."""
    m, n = w.shape
    r = a.shape[1]
    bm = min(BM, m)
    bn = min(BN, n)
    grid = (m // bm, n // bn)
    import functools

    return pl.pallas_call(
        functools.partial(_kernel, rank=r),
        grid=grid,
        in_specs=[
            pl.BlockSpec((bm, bn), lambda i, j: (i, j)),
            pl.BlockSpec((bm, r), lambda i, j: (i, 0)),
            pl.BlockSpec((r, bn), lambda i, j: (0, j)),
            pl.BlockSpec((1,), lambda i, j: (0,)),
        ],
        out_specs=pl.BlockSpec((bm, bn), lambda i, j: (i, j)),
        out_shape=jax.ShapeDtypeStruct((m, n), jnp.float32),
        interpret=True,
    )(w, a, b, alpha)
