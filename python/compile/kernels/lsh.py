"""Pallas kernel: pooled LSH projection (Layer 1).

The Van Durme & Lall random-pool LSH is re-expressed as a pooled
projection matmul (DESIGN.md §Hardware-Adaptation): the parameter
vector is folded into rows of POOL_SIZE, streamed HBM→VMEM one
row-block at a time, and multiplied against the resident (POOL, K)
Gaussian pool matrix on the MXU, accumulating K partial sums on-chip.

The pool matrix is an *argument* (generated once by the Rust side), so
both implementations project against identical Gaussians.

interpret=True everywhere: the CPU PJRT plugin cannot run Mosaic
custom-calls; real-TPU performance is estimated statically in
EXPERIMENTS.md §Perf.
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

# Shapes the AOT artifact is lowered for (mirrored by rust mlops).
BLOCK_ROWS = 64
POOL_SIZE = 16384
NUM_HASHES = 16

# Rows per grid step: the VMEM working set per step is
# ROW_TILE*POOL*4B (x tile) + POOL*K*4B (pool, resident) + K*4B (acc).
ROW_TILE = 8


def _kernel(x_ref, pool_ref, o_ref):
    step = pl.program_id(0)
    partial = jnp.sum(
        jnp.dot(x_ref[...], pool_ref[...], preferred_element_type=jnp.float32),
        axis=0,
    )

    @pl.when(step == 0)
    def _init():
        o_ref[...] = partial

    @pl.when(step != 0)
    def _acc():
        o_ref[...] += partial


@functools.partial(jax.jit, static_argnames=())
def lsh_project(x, pool):
    """x: (BLOCK_ROWS, POOL_SIZE) f32, pool: (POOL_SIZE, K) f32 -> (K,) f32."""
    rows, pool_size = x.shape
    k = pool.shape[1]
    grid = (rows // ROW_TILE,)
    return pl.pallas_call(
        _kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((ROW_TILE, pool_size), lambda i: (i, 0)),
            pl.BlockSpec((pool_size, k), lambda i: (0, 0)),
        ],
        out_specs=pl.BlockSpec((k,), lambda i: (0,)),
        out_shape=jax.ShapeDtypeStruct((k,), jnp.float32),
        interpret=True,
    )(x, pool)
