"""Pallas kernel: fused causal attention (Layer 1).

Used inside the L2 transformer (Figure 3 model). One grid step per
(batch*head): the full (S, Dh) Q/K/V tiles fit VMEM at this model
scale, so scores, causal mask, softmax, and the value matmul are fused
in one kernel — the flash-style row-blocked schedule is unnecessary at
S=32 but the same BlockSpec structure extends to it.
"""

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _kernel(q_ref, k_ref, v_ref, o_ref):
    q = q_ref[0]  # (S, Dh)
    k = k_ref[0]
    v = v_ref[0]
    s, dh = q.shape
    scale = 1.0 / jnp.sqrt(jnp.asarray(dh, dtype=q.dtype))
    scores = jnp.dot(q, k.T, preferred_element_type=jnp.float32) * scale
    mask = jnp.tril(jnp.ones((s, s), dtype=bool))
    scores = jnp.where(mask, scores, jnp.finfo(scores.dtype).min)
    scores = scores - jnp.max(scores, axis=-1, keepdims=True)
    probs = jnp.exp(scores)
    probs = probs / jnp.sum(probs, axis=-1, keepdims=True)
    o_ref[0] = jnp.dot(probs, v, preferred_element_type=jnp.float32)


def _attention_fwd_kernel(q, k, v):
    bh, s, dh = q.shape
    spec = pl.BlockSpec((1, s, dh), lambda i: (i, 0, 0))
    return pl.pallas_call(
        _kernel,
        grid=(bh,),
        in_specs=[spec, spec, spec],
        out_specs=spec,
        out_shape=jax.ShapeDtypeStruct((bh, s, dh), jnp.float32),
        interpret=True,
    )(q, k, v)


def _ref_attention(q, k, v):
    # Reference math used for the backward pass (standard fused-attention
    # practice: the kernel carries a custom VJP whose bwd re-derives
    # gradients from the mathematically-equivalent graph).
    s = q.shape[-2]
    scale = 1.0 / jnp.sqrt(jnp.asarray(q.shape[-1], dtype=q.dtype))
    scores = jnp.einsum("bsd,btd->bst", q, k) * scale
    mask = jnp.tril(jnp.ones((s, s), dtype=bool))
    scores = jnp.where(mask[None, :, :], scores, jnp.finfo(scores.dtype).min)
    probs = jax.nn.softmax(scores, axis=-1)
    return jnp.einsum("bst,btd->bsd", probs, v)


@jax.custom_vjp
def attention(q, k, v):
    """q, k, v: (BH, S, Dh) f32 -> (BH, S, Dh) f32, causal."""
    return _attention_fwd_kernel(q, k, v)


def _attention_fwd(q, k, v):
    return _attention_fwd_kernel(q, k, v), (q, k, v)


def _attention_bwd(res, g):
    q, k, v = res
    _, vjp = jax.vjp(_ref_attention, q, k, v)
    return vjp(g)


attention.defvjp(_attention_fwd, _attention_bwd)
