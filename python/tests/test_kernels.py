"""Pallas kernels vs pure-jnp oracles (hypothesis shape sweeps)."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from compile.kernels import attention as attention_kernel
from compile.kernels import average as average_kernel
from compile.kernels import lora as lora_kernel
from compile.kernels import lsh as lsh_kernel
from compile.kernels import ref


def rand(key, shape, scale=1.0):
    return (jax.random.normal(jax.random.PRNGKey(key), shape) * scale).astype(
        jnp.float32
    )


# ----------------------------------------------------------------------
# lsh_project
# ----------------------------------------------------------------------


def test_lsh_project_matches_ref():
    x = rand(0, (lsh_kernel.BLOCK_ROWS, lsh_kernel.POOL_SIZE), 0.1)
    pool = rand(1, (lsh_kernel.POOL_SIZE, lsh_kernel.NUM_HASHES))
    got = lsh_kernel.lsh_project(x, pool)
    want = ref.lsh_project(x, pool)
    np.testing.assert_allclose(got, want, rtol=2e-4, atol=2e-3)


def test_lsh_project_zero_padding_invariant():
    # Zero rows contribute nothing (rust pads partial blocks with zeros).
    pool = rand(2, (lsh_kernel.POOL_SIZE, lsh_kernel.NUM_HASHES))
    x = jnp.zeros((lsh_kernel.BLOCK_ROWS, lsh_kernel.POOL_SIZE), jnp.float32)
    x = x.at[0, :100].set(rand(3, (100,), 0.1))
    got = lsh_kernel.lsh_project(x, pool)
    want = ref.lsh_project(x, pool)
    np.testing.assert_allclose(got, want, rtol=2e-4, atol=1e-4)
    assert float(jnp.abs(got).max()) > 0


# ----------------------------------------------------------------------
# lora_apply
# ----------------------------------------------------------------------


@settings(max_examples=12, deadline=None)
@given(
    m=st.sampled_from([64, 128, 256]),
    n=st.sampled_from([64, 128]),
    r=st.sampled_from([1, 4, 8, 16]),
    alpha=st.floats(min_value=0.25, max_value=32.0),
)
def test_lora_apply_matches_ref(m, n, r, alpha):
    w = rand(m * 31 + n, (m, n), 0.1)
    a = rand(m, (m, r), 0.1)
    b = rand(n, (r, n), 0.1)
    alpha_arr = jnp.asarray([alpha], jnp.float32)
    got = lora_kernel.lora_apply(w, a, b, alpha_arr)
    want = ref.lora_apply(w, a, b, alpha)
    np.testing.assert_allclose(got, want, rtol=1e-5, atol=1e-5)


def test_lora_apply_zero_b_is_identity():
    w = rand(7, (64, 64), 0.1)
    a = rand(8, (64, 8), 0.1)
    b = jnp.zeros((8, 64), jnp.float32)
    got = lora_kernel.lora_apply(w, a, b, jnp.asarray([8.0], jnp.float32))
    np.testing.assert_allclose(got, w, rtol=0, atol=0)


# ----------------------------------------------------------------------
# param_average
# ----------------------------------------------------------------------


@settings(max_examples=8, deadline=None)
@given(n=st.sampled_from([65536, 131072, 1 << 20]))
def test_param_average_matches_ref(n, ):
    x = rand(n % 97, (n,), 1.0)
    y = rand(n % 89 + 1, (n,), 1.0)
    got = average_kernel.param_average(x, y)
    want = ref.param_average(x, y)
    np.testing.assert_allclose(got, want, rtol=1e-6, atol=1e-6)


def test_param_average_commutes():
    x = rand(10, (65536,))
    y = rand(11, (65536,))
    np.testing.assert_array_equal(
        average_kernel.param_average(x, y), average_kernel.param_average(y, x)
    )


# ----------------------------------------------------------------------
# attention
# ----------------------------------------------------------------------


@settings(max_examples=8, deadline=None)
@given(
    bh=st.sampled_from([1, 4, 8]),
    s=st.sampled_from([8, 16, 32]),
    dh=st.sampled_from([16, 32]),
)
def test_attention_matches_ref(bh, s, dh):
    q = rand(bh, (bh, s, dh), 0.5)
    k = rand(s, (bh, s, dh), 0.5)
    v = rand(dh, (bh, s, dh), 0.5)
    got = attention_kernel.attention(q, k, v)
    want = ref.attention(q, k, v)
    np.testing.assert_allclose(got, want, rtol=2e-4, atol=2e-5)


def test_attention_is_causal():
    # Changing a future token must not change earlier outputs.
    q = rand(1, (1, 8, 16), 0.5)
    k = rand(2, (1, 8, 16), 0.5)
    v = rand(3, (1, 8, 16), 0.5)
    out1 = attention_kernel.attention(q, k, v)
    k2 = k.at[0, -1].add(10.0)
    v2 = v.at[0, -1].add(10.0)
    out2 = attention_kernel.attention(q, k2, v2)
    np.testing.assert_allclose(out1[0, :-1], out2[0, :-1], rtol=1e-6, atol=1e-6)
    assert not np.allclose(out1[0, -1], out2[0, -1])


def test_attention_softmax_rows_bounded():
    q = rand(4, (2, 16, 16), 2.0)
    k = rand(5, (2, 16, 16), 2.0)
    v = jnp.ones((2, 16, 16), jnp.float32)
    out = attention_kernel.attention(q, k, v)
    # With constant V, any convex combination returns exactly V.
    np.testing.assert_allclose(out, jnp.ones_like(out), rtol=1e-5, atol=1e-5)
