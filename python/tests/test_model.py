"""L2 model sanity: shapes, training signal, LoRA freezing semantics."""

import jax
import jax.numpy as jnp
import numpy as np

from compile import model as model_lib


def tiny_cfg():
    return model_lib.ModelConfig(
        vocab=64, seq_len=16, d_model=32, layers=2, heads=2, classes=2, batch=8, lora_rank=4
    )


def synthetic_batch(cfg, seed):
    key = jax.random.PRNGKey(seed)
    tokens = jax.random.randint(key, (cfg.batch, cfg.seq_len), 0, cfg.vocab)
    # Labels from a fixed token-weight rule (learnable from embeddings).
    weights = jax.random.normal(jax.random.PRNGKey(999), (cfg.vocab,))
    score = weights[tokens].sum(axis=1)
    labels = (score > 0).astype(jnp.int32)
    return tokens.astype(jnp.int32), labels


def test_forward_shapes():
    cfg = tiny_cfg()
    params = model_lib.init_params(cfg, jax.random.PRNGKey(0))
    tokens, _ = synthetic_batch(cfg, 1)
    logits = model_lib.forward(params, None, tokens, cfg)
    assert logits.shape == (cfg.batch, cfg.classes)
    assert jnp.isfinite(logits).all()


def test_lora_zero_b_matches_base():
    cfg = tiny_cfg()
    params = model_lib.init_params(cfg, jax.random.PRNGKey(0))
    lora = model_lib.init_lora(cfg, jax.random.PRNGKey(1))
    tokens, _ = synthetic_batch(cfg, 2)
    base = model_lib.forward(params, None, tokens, cfg)
    adapted = model_lib.forward(params, lora, tokens, cfg)
    np.testing.assert_allclose(base, adapted, rtol=0, atol=0)


def test_train_step_reduces_loss():
    cfg = tiny_cfg()
    params = model_lib.init_params(cfg, jax.random.PRNGKey(0))
    step = jax.jit(model_lib.make_train_step(cfg))
    tokens, labels = synthetic_batch(cfg, 3)
    losses = []
    for _ in range(60):
        params, loss = step(params, tokens, labels, jnp.float32(0.5))
        losses.append(float(loss))
    assert losses[-1] < losses[0] * 0.7, losses[::10]


def test_train_step_lora_only_touches_adapters():
    cfg = tiny_cfg()
    params = model_lib.init_params(cfg, jax.random.PRNGKey(0))
    lora = model_lib.init_lora(cfg, jax.random.PRNGKey(1))
    step = jax.jit(model_lib.make_train_step_lora(cfg))
    tokens, labels = synthetic_batch(cfg, 4)
    new_lora, loss = step(params, lora, tokens, labels, jnp.float32(0.5))
    assert jnp.isfinite(loss)
    # Adapters moved...
    moved = any(
        not np.allclose(new_lora[k], lora[k]) for k in lora
    )
    assert moved
    # ...and LoRA training converges too.
    for _ in range(60):
        lora, loss = step(params, lora, tokens, labels, jnp.float32(0.5))
    assert float(loss) < 0.6


def test_eval_step_counts():
    cfg = tiny_cfg()
    params = model_lib.init_params(cfg, jax.random.PRNGKey(0))
    es = jax.jit(model_lib.make_eval_step(cfg))
    tokens, labels = synthetic_batch(cfg, 5)
    correct, loss = es(params, tokens, labels)
    assert 0 <= float(correct) <= cfg.batch
    assert jnp.isfinite(loss)
