//! Figure 2: relative space saving of Git-Theta over Git LFS per commit.

use git_theta::benchkit::workflow;

fn main() -> anyhow::Result<()> {
    let cfg = workflow::ModelConfig::from_env();
    let models = workflow::build_models(&cfg, 42);
    let lfs = workflow::run_lfs_workflow(&models)?;
    let theta = workflow::run_theta_workflow(&models)?;
    let series = workflow::figure2_series(&lfs, &theta);
    println!("{}", workflow::render_figure2(&series));
    Ok(())
}
