//! Table 1: add/checkout wall-clock and storage per commit,
//! Git LFS vs Git-Theta, over the paper's six-commit workflow.
//!
//! Scale with `THETA_BENCH_PARAMS=<millions>` (default 15). The paper's
//! absolute numbers come from an 11.4 GB T0-3B checkpoint; the *shape*
//! (theta slower but far smaller on LoRA/trim commits, smaller overall)
//! is what this regenerates.

use git_theta::benchkit::workflow;

fn main() -> anyhow::Result<()> {
    let cfg = workflow::ModelConfig::from_env();
    eprintln!(
        "[table1] model: d={} layers={} vocab={}+{} = {:.1}M params ({:.0} MB f32)",
        cfg.d_model,
        cfg.layers,
        cfg.vocab,
        cfg.sentinels,
        cfg.param_count() as f64 / 1e6,
        cfg.param_count() as f64 * 4.0 / 1e6,
    );
    let models = workflow::build_models(&cfg, 42);
    let lfs = workflow::run_lfs_workflow(&models)?;
    let theta = workflow::run_theta_workflow(&models)?;
    println!("{}", workflow::render_table1(&lfs, &theta));

    // Shape assertions mirroring the paper's qualitative claims.
    let lora_saving = 1.0 - theta.commits[1].size_bytes as f64 / lfs.commits[1].size_bytes as f64;
    let trim_saving = 1.0 - theta.commits[5].size_bytes as f64 / lfs.commits[5].size_bytes as f64;
    let total_saving = 1.0 - theta.total_bytes as f64 / lfs.total_bytes as f64;
    println!(
        "savings: LoRA commit {:.1}%, trim commit {:.2}%, total {:.1}%",
        lora_saving * 100.0,
        trim_saving * 100.0,
        total_saving * 100.0
    );
    Ok(())
}
