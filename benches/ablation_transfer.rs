//! Ablation A4: per-object vs packed vs http LFS transfer.
//!
//! Moves a synthetic 100-group model (bf16-valued f32 payloads)
//! through the transfer engines in both directions and reports round
//! trips, wire bytes, and wall-clock — the cost model behind the
//! batched pack engine in `lfs/batch.rs` / `lfs/pack.rs` and the
//! transport abstraction in `lfs/transport.rs`. The `+resume` sample
//! cuts the pack stream mid-flight with the fault proxy and measures
//! how much of the retry byte-range resume saves. Scale with
//! `THETA_BENCH_GROUPS` / `THETA_BENCH_ELEMS`.

use git_theta::benchkit::transfer::{
    render_resume, render_runs, render_stream, run_compare, run_resume_sample, run_stream_sample,
};

// Heap high-water-mark tracking so the `+stream` sample can report the
// real peak allocation of a pack round trip.
#[global_allocator]
static ALLOC: git_theta::util::alloc::TrackingAlloc = git_theta::util::alloc::TrackingAlloc;

fn env_usize(key: &str, default: usize) -> usize {
    std::env::var(key)
        .ok()
        .and_then(|v| v.parse().ok())
        .unwrap_or(default)
}

fn main() -> anyhow::Result<()> {
    let groups = env_usize("THETA_BENCH_GROUPS", 100);
    let elems = env_usize("THETA_BENCH_ELEMS", 4096);
    let runs = run_compare(groups, elems)?;
    print!("{}", render_runs(groups, elems, &runs));
    let resume = run_resume_sample(groups, elems)?;
    print!("{}", render_resume(&resume));
    let stream = run_stream_sample(1024, 8192)?;
    print!("{}", render_stream(&stream));

    let per = &runs[0];
    let packed = &runs[1];
    let http = &runs[2];
    println!(
        "\npacked vs per-object: {}x fewer round trips, {:.2}x wire bytes, {:.2}x upload time",
        per.up.round_trips().max(1) / packed.up.round_trips().max(1),
        packed.up.packed_bytes as f64 / per.up.packed_bytes.max(1) as f64,
        packed.upload_secs / per.upload_secs.max(1e-9),
    );
    println!(
        "http vs packed-dir: same {} round trips; resume retry re-sent {:.0}% of the pack",
        http.up.round_trips(),
        100.0 * resume.retry_fraction(),
    );
    Ok(())
}
