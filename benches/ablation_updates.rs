//! Ablation A3: update-type storage cost vs sparsity / rank.
//!
//! Sweeps LoRA rank and sparse-update density on a 1024x1024 group and
//! reports stored bytes per update type chosen by `infer_best`, versus
//! the dense baseline — the core of the paper's "smallest amount of
//! information needed to describe how the parameter group was modified".

use git_theta::benchkit::render_table;
use git_theta::tensor::Tensor;
use git_theta::theta::updates::infer_best;
use git_theta::util::humansize;
use git_theta::util::rng::Pcg64;

fn random(seed: u64, m: usize, n: usize) -> Tensor {
    let mut rng = Pcg64::new(seed);
    let vals: Vec<f32> = (0..m * n).map(|_| (rng.next_f32() - 0.5) * 0.2).collect();
    Tensor::from_f32(vec![m, n], vals).unwrap()
}

fn main() -> anyhow::Result<()> {
    let (m, n) = (1024usize, 1024usize);
    let prev = random(1, m, n);
    let dense_bytes = prev.nbytes();
    let mut rows = Vec::new();

    // LoRA rank sweep.
    for rank in [1usize, 4, 16, 64] {
        let mut rng = Pcg64::new(100 + rank as u64);
        let a: Vec<f64> = (0..m * rank).map(|_| rng.next_gaussian() * 0.01).collect();
        let b: Vec<f64> = (0..rank * n).map(|_| rng.next_gaussian() * 0.01).collect();
        let pv = prev.to_f32_vec()?;
        let mut nv = vec![0f32; m * n];
        for i in 0..m {
            for j in 0..n {
                let mut acc = 0f64;
                for k in 0..rank {
                    acc += a[i * rank + k] * b[k * n + j];
                }
                nv[i * n + j] = (pv[i * n + j] as f64 + acc) as f32;
            }
        }
        let new = Tensor::from_f32(vec![m, n], nv)?;
        let p = infer_best(Some(&prev), &new, None)?;
        rows.push(vec![
            format!("LoRA rank {rank}"),
            p.kind.clone(),
            humansize::bytes(p.raw_bytes() as u64),
            format!("{:.1}x", dense_bytes as f64 / p.raw_bytes() as f64),
        ]);
    }

    // Sparse density sweep.
    for density in [0.001f64, 0.01, 0.1, 0.3] {
        let mut rng = Pcg64::new(200 + (density * 1000.0) as u64);
        let mut nv = prev.to_f32_vec()?;
        let nnz = (nv.len() as f64 * density) as usize;
        for idx in rng.choose_indices(nv.len(), nnz) {
            nv[idx] += 1.0;
        }
        let new = Tensor::from_f32(vec![m, n], nv)?;
        let p = infer_best(Some(&prev), &new, None)?;
        rows.push(vec![
            format!("sparse density {density}"),
            p.kind.clone(),
            humansize::bytes(p.raw_bytes() as u64),
            format!("{:.1}x", dense_bytes as f64 / p.raw_bytes() as f64),
        ]);
    }

    // IA3 and trim.
    {
        let pv = prev.to_f32_vec()?;
        let nv: Vec<f32> = pv
            .iter()
            .enumerate()
            .map(|(i, &v)| v * (1.0 + (i % n) as f32 * 1e-4))
            .collect();
        let new = Tensor::from_f32(vec![m, n], nv)?;
        let p = infer_best(Some(&prev), &new, None)?;
        rows.push(vec![
            "IA3 column rescale".into(),
            p.kind.clone(),
            humansize::bytes(p.raw_bytes() as u64),
            format!("{:.1}x", dense_bytes as f64 / p.raw_bytes() as f64),
        ]);
        let trimmed = prev.take_rows(m - 100)?;
        let p = infer_best(Some(&prev), &trimmed, None)?;
        rows.push(vec![
            "trim 100 rows".into(),
            p.kind.clone(),
            humansize::bytes(p.raw_bytes() as u64),
            format!("{:.0}x", dense_bytes as f64 / p.raw_bytes() as f64),
        ]);
    }

    // Dense fallback.
    {
        let new = random(2, m, n);
        let p = infer_best(Some(&prev), &new, None)?;
        rows.push(vec![
            "full fine-tune".into(),
            p.kind.clone(),
            humansize::bytes(p.raw_bytes() as u64),
            "1.0x".into(),
        ]);
    }

    println!(
        "{}",
        render_table(
            &["update", "inferred type", "stored (pre-compression)", "saving vs dense"],
            &rows
        )
    );
    Ok(())
}
