//! Ablation A2: serializer design — raw vs zstd vs byte-shuffle+zstd —
//! on bf16-valued f32 checkpoints (the Table 1 compression effect:
//! "TensorStore's compression is particularly valuable in the first
//! commit since T0 3B was trained using bfloat16 precision but is
//! distributed as a float32 checkpoint").

use git_theta::benchkit::render_table;
use git_theta::tensor::{bf16_to_f32, f32_to_bf16, Tensor};
use git_theta::theta::serialize::{Serializer, TensorStoreSerializer};
use git_theta::util::humansize;
use git_theta::util::rng::Pcg64;
use std::time::Instant;

fn make(n: usize, bf16_valued: bool, seed: u64) -> Tensor {
    let mut rng = Pcg64::new(seed);
    let vals: Vec<f32> = (0..n)
        .map(|_| {
            let v = rng.next_gaussian() as f32 * 0.02;
            if bf16_valued {
                bf16_to_f32(f32_to_bf16(v))
            } else {
                v
            }
        })
        .collect();
    Tensor::from_f32(vec![n], vals).unwrap()
}

fn main() -> anyhow::Result<()> {
    let n = 4_000_000; // 16 MB
    let mut rows = Vec::new();
    for (label, t) in [
        ("bf16-valued f32 (T0-like)", make(n, true, 1)),
        ("full-precision f32", make(n, false, 2)),
    ] {
        for (cfg_label, ser) in [
            (
                "zstd only",
                TensorStoreSerializer {
                    shuffle: false,
                    ..Default::default()
                },
            ),
            ("shuffle+zstd (default)", TensorStoreSerializer::default()),
            (
                "shuffle+zstd level 9",
                TensorStoreSerializer {
                    level: 9,
                    ..Default::default()
                },
            ),
        ] {
            let t0 = Instant::now();
            let bytes = ser.serialize(&t)?;
            let enc = t0.elapsed().as_secs_f64();
            let t1 = Instant::now();
            let back = ser.deserialize(&bytes)?;
            let dec = t1.elapsed().as_secs_f64();
            assert_eq!(back, t);
            rows.push(vec![
                label.to_string(),
                cfg_label.to_string(),
                humansize::bytes(bytes.len() as u64),
                format!("{:.2}x", t.nbytes() as f64 / bytes.len() as f64),
                format!("{:.0} MB/s", t.nbytes() as f64 / enc / 1e6),
                format!("{:.0} MB/s", t.nbytes() as f64 / dec / 1e6),
            ]);
        }
        rows.push(vec![
            label.to_string(),
            "raw".into(),
            humansize::bytes(t.nbytes() as u64),
            "1.00x".into(),
            "-".into(),
            "-".into(),
        ]);
    }
    println!(
        "{}",
        render_table(
            &["data", "serializer", "size", "ratio", "enc", "dec"],
            &rows
        )
    );
    Ok(())
}
