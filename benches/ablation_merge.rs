//! Ablation A6: the merge engine — shared reconstruction cache,
//! parallel conflict resolution, batched prefetch, and change-skipping
//! via chain keys / LSH signatures.
//!
//! Merges a synthetic three-way fixture (deep ancestor chains on an
//! LFS remote, conflicted / one-sided / value-equal group quarters)
//! with each engine lever toggled and reports merge wall-clock, peak
//! transient heap, transfer round trips, and speedup vs the serial
//! baseline — the cost model behind `theta/merge.rs`. Merged-output
//! parity against the serial path is asserted on every sample. Scale
//! with `THETA_BENCH_DEPTH` / `THETA_BENCH_GROUPS` /
//! `THETA_BENCH_ELEMS`.

use git_theta::benchkit::merge::{build_fixture, render_runs, run_ablation, runs_to_json};
use git_theta::benchkit::write_bench_json;
use git_theta::util::alloc::TrackingAlloc;

// Install the heap high-water-mark tracker so the peak-alloc column is
// real numbers instead of n/a.
#[global_allocator]
static ALLOC: TrackingAlloc = TrackingAlloc;

fn env_usize(key: &str, default: usize) -> usize {
    std::env::var(key)
        .ok()
        .and_then(|v| v.parse().ok())
        .unwrap_or(default)
}

fn main() -> anyhow::Result<()> {
    git_theta::init();
    let depth = env_usize("THETA_BENCH_DEPTH", 8);
    let groups = env_usize("THETA_BENCH_GROUPS", 64);
    let elems = env_usize("THETA_BENCH_ELEMS", 16_384);

    let fixture = build_fixture(depth, groups, elems)?;
    println!("merged-output parity asserted against the serial path on every sample");
    let runs = run_ablation(&fixture)?;
    print!("{}", render_runs(&fixture, &runs));
    let path = write_bench_json("merge", runs_to_json(&fixture, &runs))?;
    println!("wrote {}", path.display());

    let serial = &runs[0];
    let all_on = runs.last().unwrap();
    println!(
        "\nall-on vs serial on {} conflicted group(s): {:.2}x merge speedup, \
         {} -> {} round trips",
        serial.resolved,
        serial.merge_secs / all_on.merge_secs.max(1e-12),
        serial.round_trips,
        all_on.round_trips
    );
    Ok(())
}
