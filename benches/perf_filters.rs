//! P1: clean/smudge throughput scaling — threads x checkpoint size.
//!
//! The paper attributes Git-Theta's speed to "the embarrassingly
//! parallel nature of parameter processing"; this bench measures the
//! clean and smudge filter throughput (MB/s) across thread counts and
//! drives the §Perf optimization loop in EXPERIMENTS.md.

use git_theta::benchkit::workflow::{base_model, ModelConfig};
use git_theta::benchkit::{render_table, time_n};
use git_theta::lfs::LfsStore;
use git_theta::theta::filter::{clean_checkpoint, smudge_metadata, ObjectAccess};
use git_theta::util::tmp::TempDir;

fn main() -> anyhow::Result<()> {
    let cfg = ModelConfig::from_env();
    let ck = base_model(&cfg, 7);
    let mb = ck.total_bytes() as f64 / 1e6;
    eprintln!(
        "[perf_filters] checkpoint: {} groups, {:.0} MB",
        ck.len(),
        mb
    );

    let mut rows = Vec::new();
    for threads in [1usize, 2, 4, 8, 16] {
        let td = TempDir::new("perf")?;
        let acc = ObjectAccess {
            store: LfsStore::open(td.path()),
            remote: None,
        };
        // clean (first version: all dense).
        let stats = time_n(1, 3, || {
            let td2 = TempDir::new("perf-clean")?;
            let acc2 = ObjectAccess {
                store: LfsStore::open(td2.path()),
                remote: None,
            };
            clean_checkpoint(&acc2, &ck, "safetensors", None, None, threads)?;
            Ok(())
        })?;
        let clean_mbs = mb / stats.min();

        // smudge.
        let meta = clean_checkpoint(&acc, &ck, "safetensors", None, None, threads)?;
        let stats = time_n(1, 3, || {
            smudge_metadata(&acc, &meta, threads)?;
            Ok(())
        })?;
        let smudge_mbs = mb / stats.min();

        rows.push(vec![
            threads.to_string(),
            format!("{clean_mbs:.0} MB/s"),
            format!("{smudge_mbs:.0} MB/s"),
        ]);
    }
    println!(
        "{}",
        render_table(&["threads", "clean throughput", "smudge throughput"], &rows)
    );
    Ok(())
}
