//! Ablation A5: the checkout engine — chain snapshotting, memoized
//! reconstruction, and in-place chunk decode.
//!
//! Smudges a synthetic continually-trained model (dense base + sparse
//! update commits) with each optimization toggled and reports smudge
//! wall-clock, peak transient heap, and speedup vs the all-off
//! baseline — the cost model behind `theta/checkout.rs` and the
//! in-place decoder in `theta/serialize.rs`. Scale with
//! `THETA_BENCH_DEPTH` / `THETA_BENCH_GROUPS` / `THETA_BENCH_ELEMS`.

use git_theta::benchkit::checkout::{build_fixture, render_runs, run_ablation, runs_to_json};
use git_theta::benchkit::write_bench_json;
use git_theta::util::alloc::TrackingAlloc;

// Install the heap high-water-mark tracker so the peak-alloc column is
// real numbers instead of n/a.
#[global_allocator]
static ALLOC: TrackingAlloc = TrackingAlloc;

fn env_usize(key: &str, default: usize) -> usize {
    std::env::var(key)
        .ok()
        .and_then(|v| v.parse().ok())
        .unwrap_or(default)
}

fn main() -> anyhow::Result<()> {
    let depth = env_usize("THETA_BENCH_DEPTH", 32);
    let groups = env_usize("THETA_BENCH_GROUPS", 4);
    let elems = env_usize("THETA_BENCH_ELEMS", 262_144);

    let fixture = build_fixture(groups, elems, depth)?;
    println!("clean -> smudge identity verified at every depth 1..={depth} (both histories)");
    let runs = run_ablation(&fixture)?;
    print!("{}", render_runs(groups, elems, &runs));
    let path = write_bench_json("checkout", runs_to_json(depth, groups, elems, &runs))?;
    println!("wrote {}", path.display());

    let all_off = &runs[0];
    let all_on = &runs[4];
    let fresh_copying = &runs[5];
    let fresh_in_place = &runs[6];
    println!(
        "\nall-on vs all-off at depth {}: {:.2}x smudge speedup; \
         fresh dense in-place vs copying: {:.2}x",
        all_off.chain_depth,
        all_off.smudge_secs / all_on.smudge_secs.max(1e-12),
        fresh_copying.smudge_secs / fresh_in_place.smudge_secs.max(1e-12),
    );
    Ok(())
}
