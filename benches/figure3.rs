//! Figure 3: model performance at each point in commit history, with
//! real training through the AOT train/eval artifacts and a native
//! merge through the Git-Theta merge driver.
//!
//! Requires `make artifacts`. Steps via THETA_FIG3_STEPS (default 600).

use git_theta::benchkit::figure3;

fn main() -> anyhow::Result<()> {
    let steps: usize = std::env::var("THETA_FIG3_STEPS")
        .ok()
        .and_then(|v| v.parse().ok())
        .unwrap_or(600);
    match figure3::run_figure3(steps, 0.1)? {
        Some(result) => {
            println!("{}", figure3::render_figure3(&result));
        }
        None => {
            eprintln!("[figure3] skipped: artifacts not built (run `make artifacts`)");
        }
    }
    Ok(())
}
