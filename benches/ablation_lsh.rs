//! Ablation A1: LSH change detection vs bitwise hashing under
//! floating-point noise (the paper's §3.3 motivation for the LSH).
//!
//! Sweeps perturbation magnitudes; reports how often each detector
//! flags a "change". Bitwise hashing flags everything; the calibrated
//! LSH ignores noise below 1e-8 and flags real updates.

use git_theta::benchkit::render_table;
use git_theta::theta::lsh::{LshSignature, LshVerdict};
use git_theta::util::rng::Pcg64;
use sha2::{Digest, Sha256};

fn main() {
    let n = 100_000;
    let trials = 30;
    let mut rows = Vec::new();
    for &dist in &[0.0f64, 1e-10, 1e-9, 1e-8, 1e-7, 1e-6, 1e-5, 1e-3] {
        let mut lsh_changed = 0;
        let mut lsh_exact_check = 0;
        let mut bit_changed = 0;
        for t in 0..trials {
            let mut rng = Pcg64::new(1000 + t);
            let base: Vec<f32> = (0..n).map(|_| (rng.next_f32() - 0.5) * 0.2).collect();
            let mut pert = base.clone();
            if dist > 0.0 {
                let per = (dist / (n as f64).sqrt()) as f32;
                for v in pert.iter_mut() {
                    *v += per;
                }
            }
            // Bitwise.
            let h = |v: &[f32]| {
                let mut hasher = Sha256::new();
                for x in v {
                    hasher.update(x.to_le_bytes());
                }
                hasher.finalize()
            };
            if h(&base) != h(&pert) {
                bit_changed += 1;
            }
            // LSH.
            let a = LshSignature::of_values(&base);
            let b = LshSignature::of_values(&pert);
            match b.compare(&a) {
                LshVerdict::Changed => lsh_changed += 1,
                LshVerdict::NeedsExactCheck => lsh_exact_check += 1,
                LshVerdict::Unchanged => {}
            }
        }
        rows.push(vec![
            format!("{dist:.0e}"),
            format!("{}/{}", bit_changed, trials),
            format!("{}/{}", lsh_changed, trials),
            format!("{}/{}", lsh_exact_check, trials),
        ]);
    }
    println!(
        "{}",
        render_table(
            &["L2 distance", "bitwise flags", "LSH flags changed", "LSH -> allclose band"],
            &rows
        )
    );
    println!("(paper claim: noise <= 1e-8 must not flag; real updates ~1e-3+ always flag)");
}
