//! Transport parity: `DirRemote` and `HttpRemote` must be
//! observationally identical — arbitrary have/want sets produce the
//! same store states, the same negotiation/pack/byte counters, and the
//! same fast paths, whichever channel carries the packs.

mod support;

use git_theta::gitcore::object::Oid;
use git_theta::gitcore::remote::RemoteSpec;
use git_theta::gitcore::repo::Repository;
use git_theta::lfs::{
    batch, classify, BatchResponse, ChainAdvert, ChainEntryAdvert, FailureClass, LfsRemote,
    LfsStore, PackStats, Prefetcher, RemoteTransport, ReplicatedRemote, RetryPolicy, WireReport,
};
use git_theta::util::prop::{self, gens};
use git_theta::util::rng::Pcg64;
use git_theta::util::tmp::TempDir;

/// One randomized have/want scenario.
#[derive(Debug)]
struct Scenario {
    /// Number of real objects in the source store.
    objects: usize,
    /// How many of them the receiving side already has.
    have: usize,
    /// Extra wanted oids nobody holds.
    ghosts: usize,
    /// Payload seed.
    seed: u64,
}

fn gen_scenario(rng: &mut Pcg64) -> Scenario {
    let objects = gens::usize_in(rng, 1, 10);
    Scenario {
        objects,
        have: gens::usize_in(rng, 0, objects),
        ghosts: gens::usize_in(rng, 0, 3),
        seed: rng.next_u64(),
    }
}

fn ghost_oids(n: usize, seed: u64) -> Vec<Oid> {
    (0..n)
        .map(|i| Oid::of_bytes(format!("ghost-{seed}-{i}").as_bytes()))
        .collect()
}

#[test]
fn push_parity_across_transports() {
    prop::check("push-parity", gen_scenario, |sc| {
        let td_local = TempDir::new("parity-local").map_err(|e| e.to_string())?;
        let local = LfsStore::open(td_local.path());
        let oids = support::seed_store(&local, sc.objects, 900, sc.seed);
        let mut want = oids.clone();
        want.extend(ghost_oids(sc.ghosts, sc.seed));

        // Directory remote, pre-seeded with the `have` subset.
        let td_dir = TempDir::new("parity-dir").map_err(|e| e.to_string())?;
        let dir = LfsRemote::open(td_dir.path());
        for oid in &oids[..sc.have] {
            dir.store().put(&local.get(oid).unwrap()).unwrap();
        }

        // HTTP remote over a live server, identically pre-seeded.
        let fx = support::HttpFixture::new();
        let server_store = fx.server_store();
        for oid in &oids[..sc.have] {
            server_store.put(&local.get(oid).unwrap()).unwrap();
        }
        let td_staging = TempDir::new("parity-staging").map_err(|e| e.to_string())?;
        let http = fx.direct_remote(td_staging.path());

        batch::reset_stats();
        let sum_dir = batch::push_pack(&local, &dir, &want).map_err(|e| format!("{e:#}"))?;
        let stats_dir = batch::stats();

        batch::reset_stats();
        let sum_http = batch::push_pack(&local, &http, &want).map_err(|e| format!("{e:#}"))?;
        let stats_http = batch::stats();

        if sum_dir != sum_http {
            return Err(format!("summaries diverge:\n dir {sum_dir:?}\n http {sum_http:?}"));
        }
        if stats_dir != stats_http {
            return Err(format!("counters diverge:\n dir {stats_dir:?}\n http {stats_http:?}"));
        }
        if sum_dir.unavailable != sc.ghosts {
            return Err(format!(
                "{} ghosts wanted but {} reported unavailable",
                sc.ghosts, sum_dir.unavailable
            ));
        }
        support::assert_stores_equal(dir.store(), &server_store);
        Ok(())
    });
}

#[test]
fn fetch_parity_across_transports() {
    prop::check("fetch-parity", gen_scenario, |sc| {
        // Both remotes hold the full object set.
        let td_dir = TempDir::new("parity-dir").map_err(|e| e.to_string())?;
        let dir = LfsRemote::open(td_dir.path());
        let oids = support::seed_store(dir.store(), sc.objects, 900, sc.seed);
        let fx = support::HttpFixture::new();
        let server_store = fx.server_store();
        for oid in &oids {
            server_store.put(&dir.store().get(oid).unwrap()).unwrap();
        }
        let mut want = oids.clone();
        want.extend(ghost_oids(sc.ghosts, sc.seed));

        // Two receivers, each pre-seeded with the same `have` subset.
        let td_a = TempDir::new("parity-recv-dir").map_err(|e| e.to_string())?;
        let td_b = TempDir::new("parity-recv-http").map_err(|e| e.to_string())?;
        let recv_dir = LfsStore::open(td_a.path());
        let recv_http = LfsStore::open(td_b.path());
        for oid in &oids[..sc.have] {
            let bytes = dir.store().get(oid).unwrap();
            recv_dir.put(&bytes).unwrap();
            recv_http.put(&bytes).unwrap();
        }
        let http = fx.direct_remote(td_b.path());

        batch::reset_stats();
        let sum_dir = batch::fetch_pack(&dir, &recv_dir, &want).map_err(|e| format!("{e:#}"))?;
        let stats_dir = batch::stats();

        batch::reset_stats();
        let sum_http = batch::fetch_pack(&http, &recv_http, &want);
        let sum_http = sum_http.map_err(|e| format!("{e:#}"))?;
        let stats_http = batch::stats();

        if sum_dir != sum_http {
            return Err(format!("summaries diverge:\n dir {sum_dir:?}\n http {sum_http:?}"));
        }
        if stats_dir != stats_http {
            return Err(format!("counters diverge:\n dir {stats_dir:?}\n http {stats_http:?}"));
        }
        support::assert_stores_equal(&recv_dir, &recv_http);
        Ok(())
    });
}

/// A replica set of one must be invisible: pushes and fetches through
/// [`ReplicatedRemote`] over a single mirror produce byte-identical
/// stores and identical `TransferSummary`/`TransferStats` to the bare
/// transport — no extra negotiations, no failover or quorum counters.
#[test]
fn single_mirror_replica_is_transparent() {
    prop::check("replica-of-one-parity", gen_scenario, |sc| {
        let td_local = TempDir::new("rep1-local").map_err(|e| e.to_string())?;
        let local = LfsStore::open(td_local.path());
        let oids = support::seed_store(&local, sc.objects, 900, sc.seed);
        let mut want = oids.clone();
        want.extend(ghost_oids(sc.ghosts, sc.seed));

        // Two identically pre-seeded dir remotes: one bare, one
        // wrapped in a replica set of one.
        let td_bare = TempDir::new("rep1-bare").map_err(|e| e.to_string())?;
        let td_wrapped = TempDir::new("rep1-wrapped").map_err(|e| e.to_string())?;
        let bare = LfsRemote::open(td_bare.path());
        let wrapped = LfsRemote::open(td_wrapped.path());
        for oid in &oids[..sc.have] {
            let bytes = local.get(oid).unwrap();
            bare.store().put(&bytes).unwrap();
            wrapped.store().put(&bytes).unwrap();
        }
        let replica =
            ReplicatedRemote::new(vec![Box::new(LfsRemote::open(td_wrapped.path()))], None);

        // Push parity.
        batch::reset_stats();
        let sum_bare = batch::push_pack(&local, &bare, &want).map_err(|e| format!("{e:#}"))?;
        let stats_bare = batch::stats();
        batch::reset_stats();
        let sum_rep = batch::push_pack(&local, &replica, &want).map_err(|e| format!("{e:#}"))?;
        let stats_rep = batch::stats();
        if sum_bare != sum_rep {
            return Err(format!(
                "push summaries diverge:\n bare {sum_bare:?}\n replica {sum_rep:?}"
            ));
        }
        if stats_bare != stats_rep {
            return Err(format!(
                "push counters diverge:\n bare {stats_bare:?}\n replica {stats_rep:?}"
            ));
        }
        if stats_rep.mirror_failovers != 0 || stats_rep.quorum_shortfalls != 0 {
            return Err("a healthy replica of one recorded failovers or shortfalls".into());
        }
        support::assert_stores_equal(bare.store(), wrapped.store());

        // Fetch parity, back into two fresh receivers.
        let td_ra = TempDir::new("rep1-recv-bare").map_err(|e| e.to_string())?;
        let td_rb = TempDir::new("rep1-recv-rep").map_err(|e| e.to_string())?;
        let recv_bare = LfsStore::open(td_ra.path());
        let recv_rep = LfsStore::open(td_rb.path());
        batch::reset_stats();
        let fsum_bare =
            batch::fetch_pack(&bare, &recv_bare, &want).map_err(|e| format!("{e:#}"))?;
        let fstats_bare = batch::stats();
        batch::reset_stats();
        let fsum_rep =
            batch::fetch_pack(&replica, &recv_rep, &want).map_err(|e| format!("{e:#}"))?;
        let fstats_rep = batch::stats();
        if fsum_bare != fsum_rep {
            return Err(format!(
                "fetch summaries diverge:\n bare {fsum_bare:?}\n replica {fsum_rep:?}"
            ));
        }
        if fstats_bare != fstats_rep {
            return Err(format!(
                "fetch counters diverge:\n bare {fstats_bare:?}\n replica {fstats_rep:?}"
            ));
        }
        support::assert_stores_equal(&recv_bare, &recv_rep);
        Ok(())
    });
}

/// The empty-want and already-synced fast paths cost zero round trips
/// on both transports.
#[test]
fn fast_paths_cost_nothing_on_both_transports() {
    let td_local = TempDir::new("parity-fast-local").unwrap();
    let local = LfsStore::open(td_local.path());
    let oids = support::seed_store(&local, 5, 600, 0xFA57);

    let td_dir = TempDir::new("parity-fast-dir").unwrap();
    let dir = LfsRemote::open(td_dir.path());
    let fx = support::HttpFixture::new();
    let td_staging = TempDir::new("parity-fast-staging").unwrap();
    let http = fx.direct_remote(td_staging.path());

    let transports: [&dyn RemoteTransport; 2] = [&dir, &http];
    for remote in transports {
        // Empty want: no negotiation at all.
        batch::reset_stats();
        let s = batch::push_pack(&local, remote, &[]).unwrap();
        assert_eq!(s, git_theta::lfs::TransferSummary::default());
        assert_eq!(batch::stats(), git_theta::lfs::TransferStats::default());

        batch::reset_stats();
        let s = batch::fetch_pack(remote, &local, &[]).unwrap();
        assert_eq!(s, git_theta::lfs::TransferSummary::default());
        assert_eq!(batch::stats(), git_theta::lfs::TransferStats::default());

        // First sync moves the pack; re-sync negotiates once and moves
        // nothing; a fetch of fully local objects costs zero round trips.
        batch::push_pack(&local, remote, &oids).unwrap();
        batch::reset_stats();
        let s = batch::push_pack(&local, remote, &oids).unwrap();
        assert_eq!((s.objects, s.packed_bytes), (0, 0));
        assert_eq!(batch::stats().round_trips(), 1); // the negotiation only

        batch::reset_stats();
        let s = batch::fetch_pack(remote, &local, &oids).unwrap();
        assert_eq!(s.objects, 0);
        assert_eq!(batch::stats().round_trips(), 0);
    }
}

/// One randomized chain-prefix push scenario.
#[derive(Debug)]
struct ChainScenario {
    /// Chain length (entries, base → tip).
    depth: usize,
    /// Prefix depth the receiving side already holds.
    have: usize,
    /// Standalone wanted objects outside any chain.
    extra: usize,
    /// Payload seed.
    seed: u64,
}

fn gen_chain_scenario(rng: &mut Pcg64) -> ChainScenario {
    let depth = gens::usize_in(rng, 2, 5);
    ChainScenario {
        depth,
        have: gens::usize_in(rng, 0, depth),
        extra: gens::usize_in(rng, 0, 2),
        seed: rng.next_u64(),
    }
}

/// `depth` chain payloads: a random base plus successors that share its
/// first three quarters (a fine-tune touching the same region), so
/// suffix entries genuinely delta against any held prefix entry.
fn chain_payloads(depth: usize, len: usize, seed: u64) -> Vec<Vec<u8>> {
    let mut rng = Pcg64::new(seed);
    let base: Vec<u8> = (0..len).map(|_| rng.next_u64() as u8).collect();
    let mut out = vec![base.clone()];
    for _ in 1..depth {
        let mut next = base.clone();
        for b in &mut next[len - len / 4..] {
            *b = rng.next_u64() as u8;
        }
        out.push(next);
    }
    out
}

/// A chain-oblivious peer for the version-skew fallback path: it
/// delegates the wire to a real [`LfsRemote`] but implements only the
/// trait's *required* methods, so the default (flat)
/// `negotiate_chains`/`send_pack_with_bases` bodies run — exactly what
/// a binary predating the chain protocol looks like on the other end.
struct ObliviousRemote(LfsRemote);

impl RemoteTransport for ObliviousRemote {
    fn describe(&self) -> String {
        self.0.describe()
    }

    fn batch(&self, want: &[Oid]) -> anyhow::Result<BatchResponse> {
        Ok(self.0.batch(want))
    }

    fn fetch_pack_into(
        &self,
        oids: &[Oid],
        dest: &LfsStore,
        threads: usize,
    ) -> anyhow::Result<(PackStats, WireReport)> {
        self.0.fetch_pack_into(oids, dest, threads)
    }

    fn send_pack_from(
        &self,
        src: &LfsStore,
        oids: &[Oid],
        threads: usize,
    ) -> anyhow::Result<(PackStats, WireReport)> {
        self.0.send_pack_from(src, oids, threads)
    }

    fn get_object(&self, oid: &Oid) -> anyhow::Result<Vec<u8>> {
        self.0.get_object(oid)
    }

    fn put_object(&self, bytes: &[u8]) -> anyhow::Result<()> {
        self.0.put_object(bytes)
    }
}

/// Chain-prefix pushes: Dir and Http remotes must negotiate identical
/// suffix sets (same `have_depths`, same flat split) and end up with
/// byte-identical stores; a chain-oblivious peer must still converge to
/// the same store over the flat fallback, with zero deltas on the wire.
#[test]
fn chain_negotiation_parity_across_transports() {
    prop::check("chain-parity", gen_chain_scenario, |sc| {
        let td_local = TempDir::new("chain-local").map_err(|e| e.to_string())?;
        let local = LfsStore::open(td_local.path());
        let payloads = chain_payloads(sc.depth, 8192, sc.seed);
        let chain_oids: Vec<Oid> = payloads.iter().map(|p| local.put(p).unwrap().0).collect();
        let extras = support::seed_store(&local, sc.extra, 700, sc.seed ^ 0xE77A);

        let entries: Vec<ChainEntryAdvert> = chain_oids
            .iter()
            .enumerate()
            .map(|(i, oid)| ChainEntryAdvert {
                key: Oid::of_bytes(format!("chain-key-{}-{i}", sc.seed).as_bytes()),
                oids: vec![*oid],
            })
            .collect();
        let mut want = chain_oids.clone();
        want.extend(extras.iter().copied());
        let adv = ChainAdvert {
            chains: vec![entries],
            want,
        };

        // Three receivers, identically pre-seeded to prefix depth `have`.
        let td_dir = TempDir::new("chain-dir").map_err(|e| e.to_string())?;
        let dir = LfsRemote::open(td_dir.path());
        let fx = support::HttpFixture::new();
        let server_store = fx.server_store();
        let td_flat = TempDir::new("chain-flat").map_err(|e| e.to_string())?;
        let flat = ObliviousRemote(LfsRemote::open(td_flat.path()));
        for p in &payloads[..sc.have] {
            dir.store().put(p).unwrap();
            server_store.put(p).unwrap();
            flat.0.store().put(p).unwrap();
        }
        let td_staging = TempDir::new("chain-staging").map_err(|e| e.to_string())?;
        let http = fx.direct_remote(td_staging.path());

        // Negotiation parity: same depths, same flat split, one round trip.
        let neg_dir = dir.negotiate_chains(&adv).map_err(|e| format!("{e:#}"))?;
        let neg_http = http.negotiate_chains(&adv).map_err(|e| format!("{e:#}"))?;
        if !neg_dir.chain_aware || !neg_http.chain_aware {
            return Err("a chain-aware transport answered chain-oblivious".into());
        }
        if neg_dir.have_depths != vec![sc.have] || neg_http.have_depths != vec![sc.have] {
            return Err(format!(
                "held prefix depth {} but dir negotiated {:?}, http {:?}",
                sc.have, neg_dir.have_depths, neg_http.have_depths
            ));
        }
        if neg_dir.batch != neg_http.batch {
            return Err(format!(
                "flat splits diverge:\n dir {:?}\n http {:?}",
                neg_dir.batch, neg_http.batch
            ));
        }

        // Version skew: the oblivious peer negotiates the same flat
        // split but earns no depths.
        let neg_flat = flat.negotiate_chains(&adv).map_err(|e| format!("{e:#}"))?;
        if neg_flat.chain_aware || neg_flat.have_depths != vec![0] {
            return Err(format!(
                "oblivious peer claimed chain awareness: {:?}",
                neg_flat.have_depths
            ));
        }
        if neg_flat.batch != neg_dir.batch {
            return Err("flat fallback negotiated a different want split".into());
        }

        // Push parity: identical summaries, counters, and store bytes.
        batch::reset_stats();
        let sum_dir = Prefetcher::default()
            .push_with_chains(&local, &dir, &adv)
            .map_err(|e| format!("{e:#}"))?;
        let stats_dir = batch::stats();
        batch::reset_stats();
        let sum_http = Prefetcher::default()
            .push_with_chains(&local, &http, &adv)
            .map_err(|e| format!("{e:#}"))?;
        let stats_http = batch::stats();
        if sum_dir != sum_http {
            return Err(format!("summaries diverge:\n dir {sum_dir:?}\n http {sum_http:?}"));
        }
        if stats_dir != stats_http {
            return Err(format!("counters diverge:\n dir {stats_dir:?}\n http {stats_http:?}"));
        }
        // Suffix entries ride as deltas whenever a base exists for them
        // (a held prefix entry, or the chain's own base in the pack).
        if sc.depth - sc.have >= 1 && sc.depth >= 2 && stats_dir.delta_objects == 0 {
            return Err(format!(
                "suffix of {} object(s) shipped without a single delta",
                sc.depth - sc.have
            ));
        }

        // Flat fallback: the same objects land, all of them whole.
        batch::reset_stats();
        let sum_flat = Prefetcher::default()
            .push_with_chains(&local, &flat, &adv)
            .map_err(|e| format!("{e:#}"))?;
        let stats_flat = batch::stats();
        if sum_flat.objects != sum_dir.objects || sum_flat.unavailable != sum_dir.unavailable {
            return Err(format!(
                "fallback moved a different object set: {sum_flat:?} vs {sum_dir:?}"
            ));
        }
        if stats_flat.delta_objects != 0 {
            return Err("a delta record was sent to a chain-oblivious peer".into());
        }

        support::assert_stores_equal(dir.store(), &server_store);
        support::assert_stores_equal(dir.store(), flat.0.store());
        Ok(())
    });
}

/// Fetch-direction chain parity: a clone holding a chain prefix pulls
/// the suffix from Dir and Http remotes — identical negotiations,
/// identical delta counters, byte-identical clone stores — and a
/// chain-oblivious *responder* (version skew on the server side)
/// converges the same clone over the flat v1 pack with zero deltas.
#[test]
fn fetch_chain_parity_across_transports() {
    prop::check("fetch-chain-parity", gen_chain_scenario, |sc| {
        let payloads = chain_payloads(sc.depth, 8192, sc.seed);

        // All three remotes hold the full chain plus the extras.
        let td_dir = TempDir::new("fchain-dir").map_err(|e| e.to_string())?;
        let dir = LfsRemote::open(td_dir.path());
        let chain_oids: Vec<Oid> = payloads
            .iter()
            .map(|p| dir.store().put(p).unwrap().0)
            .collect();
        let extras = support::seed_store(dir.store(), sc.extra, 700, sc.seed ^ 0xFE7C);
        let fx = support::HttpFixture::new();
        let server_store = fx.server_store();
        let td_flat = TempDir::new("fchain-flat").map_err(|e| e.to_string())?;
        let flat = ObliviousRemote(LfsRemote::open(td_flat.path()));
        for oid in chain_oids.iter().chain(&extras) {
            let bytes = dir.store().get(oid).unwrap();
            server_store.put(&bytes).unwrap();
            flat.0.store().put(&bytes).unwrap();
        }

        let entries: Vec<ChainEntryAdvert> = chain_oids
            .iter()
            .enumerate()
            .map(|(i, oid)| ChainEntryAdvert {
                key: Oid::of_bytes(format!("fchain-key-{}-{i}", sc.seed).as_bytes()),
                oids: vec![*oid],
            })
            .collect();
        let mut want = chain_oids.clone();
        want.extend(extras.iter().copied());
        let adv = ChainAdvert {
            chains: vec![entries],
            want,
        };

        // Three clones, identically pre-seeded to prefix depth `have`.
        let td_a = TempDir::new("fchain-recv-dir").map_err(|e| e.to_string())?;
        let td_b = TempDir::new("fchain-recv-http").map_err(|e| e.to_string())?;
        let td_c = TempDir::new("fchain-recv-flat").map_err(|e| e.to_string())?;
        let recv_dir = LfsStore::open(td_a.path());
        let recv_http = LfsStore::open(td_b.path());
        let recv_flat = LfsStore::open(td_c.path());
        for p in &payloads[..sc.have] {
            recv_dir.put(p).unwrap();
            recv_http.put(p).unwrap();
            recv_flat.put(p).unwrap();
        }
        let td_staging = TempDir::new("fchain-staging").map_err(|e| e.to_string())?;
        let http = fx.direct_remote(td_staging.path());

        // Negotiation parity for the advert the engine would send (want
        // trimmed to what the clone lacks): identical depths — both
        // servers hold the whole chain — and identical flat splits.
        let trimmed = ChainAdvert {
            chains: adv.chains.clone(),
            want: adv
                .want
                .iter()
                .filter(|o| !recv_dir.contains(o))
                .copied()
                .collect(),
        };
        let neg_dir = dir.negotiate_chains(&trimmed).map_err(|e| format!("{e:#}"))?;
        let neg_http = http.negotiate_chains(&trimmed).map_err(|e| format!("{e:#}"))?;
        if !neg_dir.chain_aware || !neg_http.chain_aware {
            return Err("a chain-aware transport answered chain-oblivious".into());
        }
        if neg_dir.have_depths != neg_http.have_depths {
            return Err(format!(
                "negotiated depths diverge: dir {:?}, http {:?}",
                neg_dir.have_depths, neg_http.have_depths
            ));
        }
        if neg_dir.batch != neg_http.batch {
            return Err(format!(
                "flat splits diverge:\n dir {:?}\n http {:?}",
                neg_dir.batch, neg_http.batch
            ));
        }

        // Fetch parity: identical summaries, counters, clone bytes.
        batch::reset_stats();
        let sum_dir = Prefetcher::default()
            .fetch_with_chains(&dir, &recv_dir, &adv)
            .map_err(|e| format!("{e:#}"))?;
        let stats_dir = batch::stats();
        batch::reset_stats();
        let sum_http = Prefetcher::default()
            .fetch_with_chains(&http, &recv_http, &adv)
            .map_err(|e| format!("{e:#}"))?;
        let stats_http = batch::stats();
        if sum_dir != sum_http {
            return Err(format!("summaries diverge:\n dir {sum_dir:?}\n http {sum_http:?}"));
        }
        if stats_dir != stats_http {
            return Err(format!("counters diverge:\n dir {stats_dir:?}\n http {stats_http:?}"));
        }
        // The wanted suffix arrives as deltas whenever a base exists
        // for it: a prefix entry held by the clone, or the chain's own
        // base riding in the same pack.
        if sc.depth - sc.have >= 1 && stats_dir.delta_objects == 0 {
            return Err(format!(
                "suffix of {} object(s) arrived without a single delta",
                sc.depth - sc.have
            ));
        }

        // Version skew: a chain-oblivious responder serves the same
        // objects whole and the clone still converges byte-identically.
        batch::reset_stats();
        let sum_flat = Prefetcher::default()
            .fetch_with_chains(&flat, &recv_flat, &adv)
            .map_err(|e| format!("{e:#}"))?;
        if sum_flat.objects != sum_dir.objects || sum_flat.unavailable != sum_dir.unavailable {
            return Err(format!(
                "fallback moved a different object set: {sum_flat:?} vs {sum_dir:?}"
            ));
        }
        if batch::stats().delta_objects != 0 {
            return Err("a delta record arrived from a chain-oblivious responder".into());
        }

        support::assert_stores_equal(&recv_dir, &recv_http);
        support::assert_stores_equal(&recv_dir, &recv_flat);
        Ok(())
    });
}

/// Failure-classification parity: the *kind* of failure a caller sees
/// must not depend on the transport. A missing object is fatal on both
/// `DirRemote` and `HttpRemote` — so a backoff policy spends exactly
/// one attempt on it on either channel, and no retry counters move.
#[test]
fn failure_classification_is_transport_agnostic() {
    let td_dir = TempDir::new("classify-dir").unwrap();
    let dir = LfsRemote::open(td_dir.path());
    let fx = support::HttpFixture::new();
    let td_staging = TempDir::new("classify-staging").unwrap();
    let http = fx.direct_remote(td_staging.path());
    let ghost = ghost_oids(1, 0xC1A5)[0];

    let transports: [&dyn RemoteTransport; 2] = [&dir, &http];
    for remote in transports {
        let err = remote
            .get_object(&ghost)
            .expect_err("a ghost object cannot be served");
        assert_eq!(
            classify(&err),
            FailureClass::Fatal,
            "{}: a missing object must classify fatal, got {err:#}",
            remote.describe()
        );

        // A fatal failure surfaces immediately: one attempt, no backoff.
        batch::reset_stats();
        let mut attempts = 0u32;
        let run = RetryPolicy::default().run(|| {
            attempts += 1;
            remote.get_object(&ghost)
        });
        assert!(run.is_err());
        assert_eq!(attempts, 1, "{}: fatal failures must not be retried", remote.describe());
        assert_eq!(batch::stats().backoff_retries, 0);
        assert_eq!(batch::stats().sheds, 0);
    }
}

/// Commit/ref sync parity: the same history pushed to a directory and
/// an HTTP remote, then cloned back, yields identical working trees.
#[test]
fn repo_sync_parity_dir_vs_http() {
    git_theta::init();
    let td = TempDir::new("parity-repo").unwrap();
    let repo = Repository::init(td.path()).unwrap();
    std::fs::write(td.join("notes.txt"), "v1").unwrap();
    repo.add(&["notes.txt"]).unwrap();
    repo.commit("v1", "t").unwrap();
    std::fs::write(td.join("notes.txt"), "v2").unwrap();
    repo.add(&["notes.txt"]).unwrap();
    repo.commit("v2", "t").unwrap();

    let td_dir = TempDir::new("parity-repo-dir").unwrap();
    let fx = support::HttpFixture::new();
    let dir_spec = RemoteSpec::Dir(td_dir.path().to_path_buf());
    let http_spec = RemoteSpec::parse(&fx.server.url()).unwrap();

    let report_dir = repo.push_spec(&dir_spec, "main").unwrap();
    let report_http = repo.push_spec(&http_spec, "main").unwrap();
    assert_eq!(report_dir.commits, report_http.commits);
    assert_eq!(report_dir.objects_sent, report_http.objects_sent);
    assert_eq!(report_dir.bytes_sent, report_http.bytes_sent);

    // Idempotent re-push is a no-op on both.
    assert_eq!(repo.push_spec(&dir_spec, "main").unwrap().objects_sent, 0);
    assert_eq!(repo.push_spec(&http_spec, "main").unwrap().objects_sent, 0);

    let td_a = TempDir::new("parity-clone-dir").unwrap();
    let td_b = TempDir::new("parity-clone-http").unwrap();
    let clone_dir = Repository::init(td_a.path()).unwrap();
    clone_dir.pull_spec(&dir_spec, "main").unwrap();
    let clone_http = Repository::init(td_b.path()).unwrap();
    clone_http.pull_spec(&http_spec, "main").unwrap();
    assert_eq!(
        std::fs::read(td_a.join("notes.txt")).unwrap(),
        std::fs::read(td_b.join("notes.txt")).unwrap()
    );
    assert_eq!(
        clone_dir.head_commit().unwrap(),
        clone_http.head_commit().unwrap()
    );
    assert_eq!(std::fs::read_to_string(td_b.join("notes.txt")).unwrap(), "v2");
}
