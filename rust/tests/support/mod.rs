//! Shared harness for the transport integration tests: an HTTP remote
//! ([`LfsServer`]) fronted by a fault-injection proxy
//! ([`FaultProxy`]), plus seeded-store helpers. Each test binary
//! compiles its own copy (`mod support;`), so the pieces it doesn't
//! use are dead code there.
#![allow(dead_code)]

use git_theta::gitcore::object::Oid;
use git_theta::lfs::faults::FaultProxy;
use git_theta::lfs::{HttpRemote, LfsServer, LfsStore};
use git_theta::util::rng::Pcg64;
use git_theta::util::tmp::TempDir;
use std::path::Path;

/// A live HTTP remote with a fault proxy in front of it.
pub struct HttpFixture {
    /// Root directory the server serves (odb + refs + lfs store).
    pub root: TempDir,
    /// The running server.
    pub server: LfsServer,
    /// A proxy between clients and the server; arm it to inject
    /// exactly one fault into the next pack stream.
    pub proxy: FaultProxy,
}

impl HttpFixture {
    /// Spawn a fresh server + proxy pair over a temp root.
    pub fn new() -> HttpFixture {
        let root = TempDir::new("http-fixture").unwrap();
        let server = LfsServer::spawn(root.path()).unwrap();
        let proxy = FaultProxy::spawn(&server.url()).unwrap();
        HttpFixture { root, server, proxy }
    }

    /// A client that bypasses the proxy (no faults ever).
    pub fn direct_remote(&self, staging: &Path) -> HttpRemote {
        HttpRemote::open(&self.server.url(), Some(staging)).unwrap()
    }

    /// A client whose traffic crosses the fault proxy.
    pub fn proxied_remote(&self, staging: &Path) -> HttpRemote {
        HttpRemote::open(&self.proxy.url(), Some(staging)).unwrap()
    }

    /// Direct handle on the server's LFS store (seeding/asserting).
    pub fn server_store(&self) -> LfsStore {
        LfsStore::at(&self.root.path().join("lfs/objects"))
    }
}

/// Fill a store with `n` pseudo-random payloads of roughly
/// `bytes_per` bytes (deterministic per seed). Returns their oids in
/// insertion order.
pub fn seed_store(store: &LfsStore, n: usize, bytes_per: usize, seed: u64) -> Vec<Oid> {
    let mut rng = Pcg64::new(seed);
    (0..n)
        .map(|_| {
            let len = bytes_per / 2 + (rng.below(bytes_per.max(2) as u64) as usize);
            let payload: Vec<u8> = (0..len.max(1)).map(|_| rng.next_u64() as u8).collect();
            let (oid, _) = store.put(&payload).unwrap();
            oid
        })
        .collect()
}

/// Assert two stores hold exactly the same objects with equal bytes.
pub fn assert_stores_equal(a: &LfsStore, b: &LfsStore) {
    let mut oids_a = a.list().unwrap();
    let mut oids_b = b.list().unwrap();
    oids_a.sort();
    oids_b.sort();
    assert_eq!(oids_a, oids_b, "stores hold different object sets");
    for oid in &oids_a {
        assert_eq!(a.get(oid).unwrap(), b.get(oid).unwrap(), "object {oid} differs");
    }
}
