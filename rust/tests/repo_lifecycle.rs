//! Integration: full Git-Theta lifecycles through the Repository API —
//! track → add → commit → branch → merge → checkout → push/pull/clone.

use git_theta::baseline::ThetaRepo;
use git_theta::checkpoint::{Checkpoint, CheckpointFormat, SafetensorsFormat};
use git_theta::gitcore::drivers::MergeOptions;
use git_theta::gitcore::repo::Repository;
use git_theta::lfs::LfsStore;
use git_theta::tensor::Tensor;
use git_theta::theta::metadata::ModelMetadata;
use git_theta::util::rng::Pcg64;
use git_theta::util::tmp::TempDir;

fn random_ck(seed: u64, groups: usize, elems: usize) -> Checkpoint {
    let mut rng = Pcg64::new(seed);
    let mut ck = Checkpoint::new();
    for g in 0..groups {
        let vals: Vec<f32> = (0..elems).map(|_| rng.next_gaussian() as f32 * 0.02).collect();
        ck.insert(format!("g{g}/w"), Tensor::from_f32(vec![elems], vals).unwrap());
    }
    ck
}

#[test]
fn tracked_checkpoint_roundtrips_through_history() {
    let td = TempDir::new("life").unwrap();
    let repo = ThetaRepo::init(td.path(), "m.safetensors").unwrap();
    let ck1 = random_ck(1, 5, 1000);
    repo.write_model(&ck1).unwrap();
    repo.add().unwrap();
    let c1 = repo.commit("v1").unwrap();

    // Sparse change to one group.
    let mut ck2 = ck1.clone();
    let mut v = ck2.get("g0/w").unwrap().to_f32_vec().unwrap();
    v[7] = 3.5;
    ck2.insert("g0/w", Tensor::from_f32(vec![1000], v).unwrap());
    repo.write_model(&ck2).unwrap();
    repo.add().unwrap();
    let c2 = repo.commit("v2").unwrap();

    // The staged blob is a metadata file, not the checkpoint.
    let staged = repo.repo.read_path_at(c2, "m.safetensors").unwrap().unwrap();
    assert!(ModelMetadata::is_metadata(&staged));
    let meta = ModelMetadata::from_bytes(&staged).unwrap();
    assert_eq!(meta.groups["g0/w"].update.kind, "sparse");

    // Round-trip both versions bit-exactly.
    repo.checkout(&c1.to_hex()).unwrap();
    assert_eq!(repo.read_model().unwrap(), ck1);
    repo.checkout(&c2.to_hex()).unwrap();
    assert_eq!(repo.read_model().unwrap(), ck2);
}

#[test]
fn theta_merge_average_through_repository() {
    let td = TempDir::new("merge").unwrap();
    let repo = ThetaRepo::init(td.path(), "m.safetensors").unwrap();
    let base = random_ck(2, 3, 500);
    repo.write_model(&base).unwrap();
    repo.add().unwrap();
    repo.commit("base").unwrap();

    repo.repo.create_branch("side").unwrap();
    repo.checkout("side").unwrap();
    let mut side = base.clone();
    let v: Vec<f32> = side
        .get("g1/w")
        .unwrap()
        .to_f32_vec()
        .unwrap()
        .iter()
        .map(|x| x + 2.0)
        .collect();
    side.insert("g1/w", Tensor::from_f32(vec![500], v).unwrap());
    repo.write_model(&side).unwrap();
    repo.add().unwrap();
    repo.commit("side +2").unwrap();

    repo.checkout("main").unwrap();
    let mut main = base.clone();
    let v: Vec<f32> = main
        .get("g1/w")
        .unwrap()
        .to_f32_vec()
        .unwrap()
        .iter()
        .map(|x| x + 4.0)
        .collect();
    main.insert("g1/w", Tensor::from_f32(vec![500], v).unwrap());
    repo.write_model(&main).unwrap();
    repo.add().unwrap();
    repo.commit("main +4").unwrap();

    repo.merge_with_strategy("side", "average").unwrap();
    let merged = repo.read_model().unwrap();
    let base_v = base.get("g1/w").unwrap().to_f32_vec().unwrap();
    let merged_v = merged.get("g1/w").unwrap().to_f32_vec().unwrap();
    for (b, m) in base_v.iter().zip(&merged_v) {
        assert!((m - (b + 3.0)).abs() < 1e-5); // avg(+2, +4) = +3
    }
    // Untouched groups identical to base.
    assert_eq!(merged.get("g0/w"), base.get("g0/w"));
}

#[test]
fn clone_fetches_lazily_and_push_dedups() {
    let td_a = TempDir::new("origin").unwrap();
    let td_r = TempDir::new("remote").unwrap();
    let td_b = TempDir::new("clone").unwrap();

    let a = ThetaRepo::init(td_a.path(), "m.safetensors").unwrap();
    let ck = random_ck(3, 8, 4000);
    a.write_model(&ck).unwrap();
    a.repo.add(&["m.safetensors", ".thetaattributes"]).unwrap();
    a.commit("v1").unwrap();
    a.repo.push(td_r.path(), "main").unwrap();

    // Remote LFS store has the objects.
    let remote_store = LfsStore::at(&td_r.path().join("lfs/objects"));
    let n_objects = remote_store.list().unwrap().len();
    assert!(n_objects >= 8);

    // Clone: pull metadata; smudge lazily downloads parameters.
    let b = Repository::init(td_b.path()).unwrap();
    b.config_set("remote", td_r.path().to_str().unwrap()).unwrap();
    b.pull(td_r.path(), "main").unwrap();
    let cloned = SafetensorsFormat.load_file(&td_b.join("m.safetensors")).unwrap();
    assert_eq!(cloned, ck);

    // Sparse change from the clone side pushes only the delta.
    let mut ck2 = cloned;
    let mut v = ck2.get("g0/w").unwrap().to_f32_vec().unwrap();
    v[0] = 9.0;
    ck2.insert("g0/w", Tensor::from_f32(vec![4000], v).unwrap());
    SafetensorsFormat.save_file(&ck2, &td_b.join("m.safetensors")).unwrap();
    b.add(&["m.safetensors"]).unwrap();
    b.commit("tweak", "bob").unwrap();
    let before = remote_store.disk_usage().unwrap();
    b.push(td_r.path(), "main").unwrap();
    let growth = remote_store.disk_usage().unwrap() - before;
    assert!(growth < 2000, "push transferred {growth} bytes for a 1-element change");

    // Origin pulls and sees the change.
    a.repo.pull(td_r.path(), "main").unwrap();
    assert_eq!(a.read_model().unwrap(), ck2);
}

#[test]
fn fresh_clone_smudges_all_groups_via_one_pack() {
    let td_a = TempDir::new("pack-origin").unwrap();
    let td_r = TempDir::new("pack-remote").unwrap();
    let td_b = TempDir::new("pack-clone").unwrap();

    let a = ThetaRepo::init(td_a.path(), "m.safetensors").unwrap();
    let ck = random_ck(9, 12, 2000);
    a.write_model(&ck).unwrap();
    a.repo.add(&["m.safetensors", ".thetaattributes"]).unwrap();
    a.commit("v1").unwrap();
    a.repo.push(td_r.path(), "main").unwrap();

    // Fresh clone: the smudge of a model with 12 missing groups must
    // perform exactly one remote negotiation and one pack transfer
    // (counters are thread-local, so concurrent tests don't interfere).
    let b = Repository::init(td_b.path()).unwrap();
    b.config_set("remote", td_r.path().to_str().unwrap()).unwrap();
    git_theta::lfs::batch::reset_stats();
    b.pull(td_r.path(), "main").unwrap();
    let stats = git_theta::lfs::batch::stats();
    assert_eq!(stats.negotiations, 1, "smudge must negotiate once, not per group");
    assert_eq!(stats.packs, 1, "all missing groups must arrive in one pack");
    assert_eq!(stats.objects, 12);
    let cloned = SafetensorsFormat.load_file(&td_b.join("m.safetensors")).unwrap();
    assert_eq!(cloned, ck);

    // Every referenced object is now local: a re-checkout is offline.
    git_theta::lfs::batch::reset_stats();
    b.checkout("main").unwrap();
    assert_eq!(git_theta::lfs::batch::stats().negotiations, 0);
}

#[test]
fn diff_driver_reports_group_changes() {
    let td = TempDir::new("diff").unwrap();
    let repo = ThetaRepo::init(td.path(), "m.safetensors").unwrap();
    let ck = random_ck(4, 3, 200);
    repo.write_model(&ck).unwrap();
    repo.add().unwrap();
    let c1 = repo.commit("v1").unwrap();

    let mut ck2 = ck.clone();
    ck2.remove("g2/w");
    let mut v = ck2.get("g0/w").unwrap().to_f32_vec().unwrap();
    v[0] += 1.0;
    ck2.insert("g0/w", Tensor::from_f32(vec![200], v).unwrap());
    ck2.insert("new/emb", Tensor::from_f32(vec![4], vec![0.0; 4]).unwrap());
    repo.write_model(&ck2).unwrap();
    repo.add().unwrap();
    let c2 = repo.commit("v2").unwrap();

    let diff = repo.repo.diff(Some(c1), Some(c2)).unwrap();
    assert!(diff.contains("~ modified g0/w"), "{diff}");
    assert!(diff.contains("- removed  g2/w"), "{diff}");
    assert!(diff.contains("+ added    new/emb"), "{diff}");
    assert!(diff.contains("unchanged"), "{diff}");
}

#[test]
fn mixed_repo_code_and_model_coexist() {
    // Code files and the model live in one repository (the paper's
    // motivation: track code and parameters together).
    let td = TempDir::new("mixed").unwrap();
    let repo = ThetaRepo::init(td.path(), "model.safetensors").unwrap();
    std::fs::write(td.join("train.py"), "print('step')\n").unwrap();
    repo.write_model(&random_ck(5, 2, 100)).unwrap();
    repo.repo
        .add(&["train.py", "model.safetensors", ".thetaattributes"])
        .unwrap();
    let c1 = repo.commit("code + model").unwrap();
    std::fs::write(td.join("train.py"), "print('v2')\n").unwrap();
    repo.repo.add(&["train.py"]).unwrap();
    let c2 = repo.commit("code only").unwrap();

    // The model blob oid is shared between both commits (no re-store).
    let t1 = repo.repo.read_path_at(c1, "model.safetensors").unwrap().unwrap();
    let t2 = repo.repo.read_path_at(c2, "model.safetensors").unwrap().unwrap();
    assert_eq!(t1, t2);
    repo.checkout(&c1.to_hex()).unwrap();
    assert_eq!(std::fs::read_to_string(td.join("train.py")).unwrap(), "print('step')\n");
}

#[test]
fn per_group_merge_strategies_through_repo() {
    let td = TempDir::new("pgm").unwrap();
    let repo = ThetaRepo::init(td.path(), "m.safetensors").unwrap();
    let base = random_ck(6, 2, 100);
    repo.write_model(&base).unwrap();
    repo.add().unwrap();
    repo.commit("base").unwrap();

    repo.repo.create_branch("side").unwrap();
    repo.checkout("side").unwrap();
    let mut side = base.clone();
    for g in ["g0/w", "g1/w"] {
        let v: Vec<f32> = side
            .get(g)
            .unwrap()
            .to_f32_vec()
            .unwrap()
            .iter()
            .map(|x| x + 2.0)
            .collect();
        side.insert(g, Tensor::from_f32(vec![100], v).unwrap());
    }
    repo.write_model(&side).unwrap();
    repo.add().unwrap();
    repo.commit("side").unwrap();

    repo.checkout("main").unwrap();
    let mut main = base.clone();
    for g in ["g0/w", "g1/w"] {
        let v: Vec<f32> = main
            .get(g)
            .unwrap()
            .to_f32_vec()
            .unwrap()
            .iter()
            .map(|x| x + 4.0)
            .collect();
        main.insert(g, Tensor::from_f32(vec![100], v).unwrap());
    }
    repo.write_model(&main).unwrap();
    repo.add().unwrap();
    repo.commit("main").unwrap();

    let opts = MergeOptions {
        strategy: Some("average".into()),
        per_group: vec![("g1/w".into(), "us".into())],
        ..Default::default()
    };
    repo.repo.merge("side", &opts, "t").unwrap();
    let merged = repo.read_model().unwrap();
    let b0 = base.get("g0/w").unwrap().to_f32_vec().unwrap();
    let m0 = merged.get("g0/w").unwrap().to_f32_vec().unwrap();
    let m1 = merged.get("g1/w").unwrap().to_f32_vec().unwrap();
    let b1 = base.get("g1/w").unwrap().to_f32_vec().unwrap();
    assert!((m0[0] - (b0[0] + 3.0)).abs() < 1e-5); // averaged
    assert!((m1[0] - (b1[0] + 4.0)).abs() < 1e-5); // ours (main)
}
