//! Failure injection: the system must detect — not silently propagate —
//! corrupted or missing objects, malformed metadata, and bad inputs.

use git_theta::baseline::ThetaRepo;
use git_theta::checkpoint::Checkpoint;
use git_theta::gitcore::repo::Repository;
use git_theta::lfs::LfsStore;
use git_theta::tensor::Tensor;
use git_theta::theta::filter::{clean_checkpoint, smudge_metadata, ObjectAccess};
use git_theta::theta::metadata::ModelMetadata;
use git_theta::util::rng::Pcg64;
use git_theta::util::tmp::TempDir;

fn random_ck(seed: u64) -> Checkpoint {
    let mut rng = Pcg64::new(seed);
    let mut ck = Checkpoint::new();
    for g in 0..3 {
        let vals: Vec<f32> = (0..500).map(|_| rng.next_gaussian() as f32 * 0.02).collect();
        ck.insert(format!("g{g}"), Tensor::from_f32(vec![500], vals).unwrap());
    }
    ck
}

#[test]
fn smudge_fails_loudly_on_missing_lfs_object() {
    let td = TempDir::new("fi").unwrap();
    let acc = ObjectAccess {
        store: LfsStore::open(td.path()),
        remote: None,
    };
    let ck = random_ck(1);
    let meta = clean_checkpoint(&acc, &ck, "safetensors", None, None, 1).unwrap();

    // Delete one object from the store.
    let oid = meta.all_oids()[0];
    let hex = oid.to_hex();
    std::fs::remove_file(td.path().join("lfs/objects").join(&hex[..2]).join(&hex[2..])).unwrap();

    let err = smudge_metadata(&acc, &meta, 1).unwrap_err();
    let msg = format!("{err:#}");
    assert!(msg.contains("not found"), "{msg}");
    assert!(msg.contains("reconstructing parameter group"), "{msg}");
}

#[test]
fn smudge_fails_loudly_on_corrupt_lfs_object() {
    let td = TempDir::new("fi").unwrap();
    let acc = ObjectAccess {
        store: LfsStore::open(td.path()),
        remote: None,
    };
    let ck = random_ck(2);
    let meta = clean_checkpoint(&acc, &ck, "safetensors", None, None, 1).unwrap();
    let oid = meta.all_oids()[0];
    let hex = oid.to_hex();
    let path = td.path().join("lfs/objects").join(&hex[..2]).join(&hex[2..]);
    let mut bytes = std::fs::read(&path).unwrap();
    bytes[10] ^= 0xff;
    std::fs::write(&path, bytes).unwrap();

    let err = smudge_metadata(&acc, &meta, 1).unwrap_err();
    assert!(format!("{err:#}").contains("corrupt"), "{err:#}");
}

#[test]
fn malformed_metadata_is_rejected() {
    assert!(ModelMetadata::from_bytes(b"{\"git-theta\": 1}").is_err()); // missing format
    assert!(
        ModelMetadata::from_bytes(b"{\"git-theta\": 99, \"format\": \"safetensors\"}").is_err()
    );
    assert!(ModelMetadata::from_bytes(b"\x00\x01\x02").is_err());
    // Truncated group entry.
    let bad = br#"{"git-theta":1,"format":"safetensors","groups":{"w":{"tensor":{}}}}"#;
    assert!(ModelMetadata::from_bytes(bad).is_err());
}

#[test]
fn add_of_unparseable_checkpoint_fails_cleanly() {
    git_theta::init();
    let td = TempDir::new("fi").unwrap();
    let repo = ThetaRepo::init(td.path(), "m.safetensors").unwrap();
    // Write garbage where a checkpoint should be.
    std::fs::write(td.join("m.safetensors"), b"garbage bytes").unwrap();
    let err = repo.repo.add(&["m.safetensors"]).unwrap_err();
    let msg = format!("{err:#}");
    assert!(msg.contains("safetensors") || msg.contains("format"), "{msg}");
    // Repository state is untouched: nothing staged.
    assert!(repo.repo.status().unwrap().of("m.safetensors").is_some());
}

#[test]
fn checkout_of_unknown_revision_fails() {
    git_theta::init();
    let td = TempDir::new("fi").unwrap();
    let repo = Repository::init(td.path()).unwrap();
    assert!(repo.checkout("no-such-branch").is_err());
    assert!(repo.resolve("deadbeef00").is_err());
}

#[test]
fn tampered_odb_object_detected_by_fsck_path() {
    git_theta::init();
    let td = TempDir::new("fi").unwrap();
    let repo = Repository::init(td.path()).unwrap();
    std::fs::write(td.join("f.txt"), "content").unwrap();
    repo.add(&["f.txt"]).unwrap();
    repo.commit("c", "t").unwrap();
    // Corrupt every object file; reads must fail with hash mismatch.
    let mut corrupted = 0;
    for oid in repo.odb().list().unwrap() {
        let hex = oid.to_hex();
        let path = td
            .path()
            .join(".theta/objects")
            .join(&hex[..2])
            .join(&hex[2..]);
        let bytes = std::fs::read(&path).unwrap();
        if bytes.len() > 12 {
            let mut b = bytes.clone();
            let at = b.len() - 2;
            b[at] ^= 0x55;
            std::fs::write(&path, b).unwrap();
            if repo.odb().read(&oid).is_err() {
                corrupted += 1;
            }
        }
    }
    assert!(corrupted > 0, "no corruption detected");
}

#[test]
fn push_to_remote_with_foreign_history_rejected() {
    git_theta::init();
    let td_a = TempDir::new("fiA").unwrap();
    let td_b = TempDir::new("fiB").unwrap();
    let td_r = TempDir::new("fiR").unwrap();
    let a = Repository::init(td_a.path()).unwrap();
    std::fs::write(td_a.join("x"), "a").unwrap();
    a.add(&["x"]).unwrap();
    a.commit("a", "a").unwrap();
    a.push(td_r.path(), "main").unwrap();

    // Unrelated repo pushes to the same branch: rejected (non-FF).
    let b = Repository::init(td_b.path()).unwrap();
    std::fs::write(td_b.join("y"), "b").unwrap();
    b.add(&["y"]).unwrap();
    b.commit("b", "b").unwrap();
    assert!(b.push(td_r.path(), "main").is_err());
}
