//! Failure injection: the system must detect — not silently propagate —
//! corrupted or missing objects, malformed metadata, and bad inputs;
//! and the HTTP transport must *survive* real-network failure modes —
//! a pack stream truncated at any byte offset, delayed, or duplicated
//! mid-flight — resuming interrupted transfers byte-for-byte while
//! re-sending only what was lost.

mod support;

use git_theta::baseline::ThetaRepo;
use git_theta::checkpoint::Checkpoint;
use git_theta::gitcore::attributes::Attributes;
use git_theta::gitcore::remote::RemoteSpec;
use git_theta::gitcore::repo::Repository;
use git_theta::lfs::faults::{Direction, FaultSpec};
use git_theta::lfs::{batch, LfsStore, ReplicatedRemote};
use git_theta::tensor::Tensor;
use git_theta::theta::filter::{clean_checkpoint, smudge_metadata, ObjectAccess};
use git_theta::theta::metadata::ModelMetadata;
use git_theta::util::prop::{self, gens};
use git_theta::util::rng::Pcg64;
use git_theta::util::tmp::TempDir;

fn random_ck(seed: u64) -> Checkpoint {
    let mut rng = Pcg64::new(seed);
    let mut ck = Checkpoint::new();
    for g in 0..3 {
        let vals: Vec<f32> = (0..500).map(|_| rng.next_gaussian() as f32 * 0.02).collect();
        ck.insert(format!("g{g}"), Tensor::from_f32(vec![500], vals).unwrap());
    }
    ck
}

#[test]
fn smudge_fails_loudly_on_missing_lfs_object() {
    let td = TempDir::new("fi").unwrap();
    let acc = ObjectAccess {
        store: LfsStore::open(td.path()),
        remote: None,
    };
    let ck = random_ck(1);
    let meta = clean_checkpoint(&acc, &ck, "safetensors", None, None, 1).unwrap();

    // Delete one object from the store.
    let oid = meta.all_oids()[0];
    let hex = oid.to_hex();
    std::fs::remove_file(td.path().join("lfs/objects").join(&hex[..2]).join(&hex[2..])).unwrap();

    let err = smudge_metadata(&acc, &meta, 1).unwrap_err();
    let msg = format!("{err:#}");
    assert!(msg.contains("not found"), "{msg}");
    assert!(msg.contains("reconstructing parameter group"), "{msg}");
}

#[test]
fn smudge_fails_loudly_on_corrupt_lfs_object() {
    let td = TempDir::new("fi").unwrap();
    let acc = ObjectAccess {
        store: LfsStore::open(td.path()),
        remote: None,
    };
    let ck = random_ck(2);
    let meta = clean_checkpoint(&acc, &ck, "safetensors", None, None, 1).unwrap();
    let oid = meta.all_oids()[0];
    let hex = oid.to_hex();
    let path = td.path().join("lfs/objects").join(&hex[..2]).join(&hex[2..]);
    let mut bytes = std::fs::read(&path).unwrap();
    bytes[10] ^= 0xff;
    std::fs::write(&path, bytes).unwrap();

    let err = smudge_metadata(&acc, &meta, 1).unwrap_err();
    assert!(format!("{err:#}").contains("corrupt"), "{err:#}");
}

#[test]
fn malformed_metadata_is_rejected() {
    assert!(ModelMetadata::from_bytes(b"{\"git-theta\": 1}").is_err()); // missing format
    assert!(
        ModelMetadata::from_bytes(b"{\"git-theta\": 99, \"format\": \"safetensors\"}").is_err()
    );
    assert!(ModelMetadata::from_bytes(b"\x00\x01\x02").is_err());
    // Truncated group entry.
    let bad = br#"{"git-theta":1,"format":"safetensors","groups":{"w":{"tensor":{}}}}"#;
    assert!(ModelMetadata::from_bytes(bad).is_err());
}

#[test]
fn add_of_unparseable_checkpoint_fails_cleanly() {
    git_theta::init();
    let td = TempDir::new("fi").unwrap();
    let repo = ThetaRepo::init(td.path(), "m.safetensors").unwrap();
    // Write garbage where a checkpoint should be.
    std::fs::write(td.join("m.safetensors"), b"garbage bytes").unwrap();
    let err = repo.repo.add(&["m.safetensors"]).unwrap_err();
    let msg = format!("{err:#}");
    assert!(msg.contains("safetensors") || msg.contains("format"), "{msg}");
    // Repository state is untouched: nothing staged.
    assert!(repo.repo.status().unwrap().of("m.safetensors").is_some());
}

#[test]
fn checkout_of_unknown_revision_fails() {
    git_theta::init();
    let td = TempDir::new("fi").unwrap();
    let repo = Repository::init(td.path()).unwrap();
    assert!(repo.checkout("no-such-branch").is_err());
    assert!(repo.resolve("deadbeef00").is_err());
}

#[test]
fn tampered_odb_object_detected_by_fsck_path() {
    git_theta::init();
    let td = TempDir::new("fi").unwrap();
    let repo = Repository::init(td.path()).unwrap();
    std::fs::write(td.join("f.txt"), "content").unwrap();
    repo.add(&["f.txt"]).unwrap();
    repo.commit("c", "t").unwrap();
    // Corrupt every object file; reads must fail with hash mismatch.
    let mut corrupted = 0;
    for oid in repo.odb().list().unwrap() {
        let hex = oid.to_hex();
        let path = td
            .path()
            .join(".theta/objects")
            .join(&hex[..2])
            .join(&hex[2..]);
        let bytes = std::fs::read(&path).unwrap();
        if bytes.len() > 12 {
            let mut b = bytes.clone();
            let at = b.len() - 2;
            b[at] ^= 0x55;
            std::fs::write(&path, b).unwrap();
            if repo.odb().read(&oid).is_err() {
                corrupted += 1;
            }
        }
    }
    assert!(corrupted > 0, "no corruption detected");
}

// ---------------------------------------------------------------------
// Transport failure injection: truncation, duplication, delay.
// ---------------------------------------------------------------------

/// Kill a *download* after k bytes for k swept across the pack: the
/// first attempt must fail, the retry must complete byte-for-byte and
/// re-send only the bytes after the truncation point (asserted via the
/// `TransferSummary` wire/resume counters — objects whose records lie
/// entirely before byte k never cross the wire again).
#[test]
fn fetch_kill_sweep_resumes_at_every_offset() {
    let fx = support::HttpFixture::new();
    let server_store = fx.server_store();
    let oids = support::seed_store(&server_store, 14, 1500, 0xFE7C);

    // Learn the pack size with an unfaulted fetch into a scratch store.
    let td_scratch = TempDir::new("fi-scratch").unwrap();
    let direct = fx.direct_remote(td_scratch.path());
    let scratch = LfsStore::open(td_scratch.path());
    let baseline = batch::fetch_pack(&direct, &scratch, &oids).unwrap();
    let pack_bytes = baseline.packed_bytes;
    assert!(pack_bytes > 2, "fixture pack too small to sweep");
    support::assert_stores_equal(&server_store, &scratch);

    prop::check(
        "fetch-resume-at-k",
        |rng| gens::usize_in(rng, 1, (pack_bytes - 1) as usize) as u64,
        |&k| {
            let td = TempDir::new("fi-sweep").map_err(|e| e.to_string())?;
            let local = LfsStore::open(td.path());
            let remote = fx.proxied_remote(td.path());

            fx.proxy.arm(FaultSpec::kill(Direction::Download, k));
            let fired_before = fx.proxy.fired();
            let first = batch::fetch_pack(&remote, &local, &oids);
            if first.is_ok() {
                return Err(format!("kill at byte {k} did not interrupt the fetch"));
            }
            if fx.proxy.fired() != fired_before + 1 {
                return Err("fault never fired".into());
            }

            batch::reset_stats();
            let retry = batch::fetch_pack(&remote, &local, &oids)
                .map_err(|e| format!("resume after kill at {k} failed: {e:#}"))?;
            if retry.resumed_bytes != k {
                return Err(format!(
                    "expected resume to skip exactly {k} bytes, skipped {}",
                    retry.resumed_bytes
                ));
            }
            if retry.wire_bytes != pack_bytes - k {
                return Err(format!(
                    "retry re-sent {} bytes; only the {}-byte tail after the cut may move",
                    retry.wire_bytes,
                    pack_bytes - k
                ));
            }
            for oid in &oids {
                let got = local.get(oid).map_err(|e| format!("{e:#}"))?;
                let want = server_store.get(oid).map_err(|e| format!("{e:#}"))?;
                if got != want {
                    return Err(format!("object {oid} corrupt after resume"));
                }
            }
            Ok(())
        },
    );
}

/// Kill one mirror of a replica set at byte k for k swept across the
/// pack: a SINGLE `fetch_pack` call must complete by failing over to
/// the second mirror, resuming from the dead mirror's k-byte partial
/// (the mirrors share the client's staging dir and packs are
/// content-addressed), so exactly `pack − k` bytes cross the wire on
/// the survivor and every object lands byte-for-byte.
#[test]
fn replicated_fetch_fails_over_mid_pack_and_resumes() {
    // Two mirrors seeded identically (same seed ⇒ same payloads ⇒
    // byte-identical packs for the same want set).
    let fx_a = support::HttpFixture::new();
    let fx_b = support::HttpFixture::new();
    let store_a = fx_a.server_store();
    let store_b = fx_b.server_store();
    let oids = support::seed_store(&store_a, 12, 1500, 0x41FE);
    let oids_b = support::seed_store(&store_b, 12, 1500, 0x41FE);
    assert_eq!(oids, oids_b, "mirrors must hold identical object sets");

    // Learn the pack size with an unfaulted fetch into a scratch store.
    let td_scratch = TempDir::new("fi-rep-scratch").unwrap();
    let scratch = LfsStore::open(td_scratch.path());
    let pack_bytes = batch::fetch_pack(&fx_b.direct_remote(td_scratch.path()), &scratch, &oids)
        .unwrap()
        .packed_bytes;
    assert!(pack_bytes > 2, "fixture pack too small to sweep");

    prop::check(
        "replicated-failover-at-k",
        |rng| gens::usize_in(rng, 1, (pack_bytes - 1) as usize) as u64,
        |&k| {
            let td = TempDir::new("fi-rep").map_err(|e| e.to_string())?;
            let local = LfsStore::open(td.path());
            // Mirror A (proxied, about to die) is tried first: both
            // breakers start closed and ties break by index.
            let replica = ReplicatedRemote::new(
                vec![
                    Box::new(fx_a.proxied_remote(td.path())),
                    Box::new(fx_b.direct_remote(td.path())),
                ],
                None,
            );
            fx_a.proxy.arm(FaultSpec::kill(Direction::Download, k));
            let fired_before = fx_a.proxy.fired();

            batch::reset_stats();
            let summary = batch::fetch_pack(&replica, &local, &oids)
                .map_err(|e| format!("failover after kill at {k} failed: {e:#}"))?;
            let stats = batch::stats();
            if fx_a.proxy.fired() != fired_before + 1 {
                return Err("fault never fired".into());
            }
            if stats.mirror_failovers != 1 {
                return Err(format!(
                    "kill at byte {k}: expected exactly one failover, saw {}",
                    stats.mirror_failovers
                ));
            }
            if summary.resumed_bytes != k {
                return Err(format!(
                    "failover resumed {} bytes; the dead mirror delivered exactly {k}",
                    summary.resumed_bytes
                ));
            }
            if summary.wire_bytes != pack_bytes - k {
                return Err(format!(
                    "survivor sent {} bytes; only the {}-byte tail after the cut may move",
                    summary.wire_bytes,
                    pack_bytes - k
                ));
            }
            for oid in &oids {
                let got = local.get(oid).map_err(|e| format!("{e:#}"))?;
                let want = store_b.get(oid).map_err(|e| format!("{e:#}"))?;
                if got != want {
                    return Err(format!("object {oid} corrupt after failover resume"));
                }
            }
            Ok(())
        },
    );
}

/// Kill an *upload* after k bytes: the server persists the received
/// prefix, and the retry HEAD-probes it and sends only the tail.
#[test]
fn interrupted_push_resumes_from_server_side_partial() {
    let td_local = TempDir::new("fi-up-local").unwrap();
    let local = LfsStore::open(td_local.path());
    let oids = support::seed_store(&local, 12, 1500, 0xBEEF);

    // Learn the pack size from an unfaulted push to a throwaway server.
    let probe = support::HttpFixture::new();
    let td_probe = TempDir::new("fi-up-probe").unwrap();
    let pack_bytes = batch::push_pack(&local, &probe.direct_remote(td_probe.path()), &oids)
        .unwrap()
        .packed_bytes;
    assert!(pack_bytes > 4, "fixture pack too small to sweep");

    for k in [1, pack_bytes / 4, pack_bytes / 2, pack_bytes - 1] {
        // A fresh server per offset: the want set must be entirely
        // missing remotely so the full pack is rebuilt and re-cut.
        let fx = support::HttpFixture::new();
        let server_store = fx.server_store();
        let td_staging = TempDir::new("fi-up-staging").unwrap();
        let remote = fx.proxied_remote(td_staging.path());

        fx.proxy.arm(FaultSpec::kill(Direction::Upload, k));
        let first = batch::push_pack(&local, &remote, &oids);
        assert!(first.is_err(), "kill at byte {k} did not interrupt the push");
        assert_eq!(fx.proxy.fired(), 1);

        batch::reset_stats();
        let retry = batch::push_pack(&local, &remote, &oids).unwrap();
        assert_eq!(
            retry.resumed_bytes, k,
            "server-side partial must hold exactly the {k} bytes that arrived"
        );
        assert_eq!(retry.packed_bytes, pack_bytes);
        assert_eq!(retry.wire_bytes, pack_bytes - k);
        for oid in &oids {
            assert_eq!(server_store.get(oid).unwrap(), local.get(oid).unwrap());
        }
    }
}

/// A duplicated slice mid-stream preserves Content-Length, so only the
/// pack checksum can catch it — in both directions the corruption is
/// detected, nothing poisons a store, and a clean retry succeeds.
#[test]
fn duplicated_pack_bytes_are_detected_never_admitted() {
    let fx = support::HttpFixture::new();
    let server_store = fx.server_store();
    let oids = support::seed_store(&server_store, 10, 1200, 0xD0D0);

    // Download direction.
    let td = TempDir::new("fi-dup-dl").unwrap();
    let local = LfsStore::open(td.path());
    let remote = fx.proxied_remote(td.path());
    fx.proxy.arm(FaultSpec::duplicate(Direction::Download, 4000, 512));
    let err = batch::fetch_pack(&remote, &local, &oids).unwrap_err();
    assert!(
        format!("{err:#}").contains("integrity"),
        "duplication must surface as an integrity failure: {err:#}"
    );
    assert!(local.list().unwrap().is_empty(), "corrupt pack must admit nothing");
    batch::fetch_pack(&remote, &local, &oids).unwrap();
    support::assert_stores_equal(&server_store, &local);

    // Upload direction.
    let td_up = TempDir::new("fi-dup-up").unwrap();
    let up_local = LfsStore::open(td_up.path());
    let up_oids = support::seed_store(&up_local, 10, 1200, 0xD1D1);
    let up_remote = fx.proxied_remote(td_up.path());
    fx.proxy.arm(FaultSpec::duplicate(Direction::Upload, 4000, 512));
    let err = batch::push_pack(&up_local, &up_remote, &up_oids).unwrap_err();
    assert!(format!("{err:#}").contains("rejected pack"), "{err:#}");
    for oid in &up_oids {
        assert!(!server_store.contains(oid), "corrupt upload must admit nothing");
    }
    batch::push_pack(&up_local, &up_remote, &up_oids).unwrap();
    for oid in &up_oids {
        assert_eq!(server_store.get(oid).unwrap(), up_local.get(oid).unwrap());
    }
}

/// A downloaded partial that fails pack verification must be deleted,
/// not left to poison the next byte-range resume: a duplicated slice
/// preserves Content-Length, so the client ends up with a
/// complete-*looking* but corrupt partial — if it survived, the retry
/// would "resume" past it, skip the re-download, and fail forever on
/// the same bytes.
#[test]
fn poisoned_partial_is_deleted_and_retry_restarts_clean() {
    let fx = support::HttpFixture::new();
    let server_store = fx.server_store();
    let oids = support::seed_store(&server_store, 10, 1500, 0xBADD);

    let td = TempDir::new("fi-poison").unwrap();
    let local = LfsStore::open(td.path());
    let remote = fx.proxied_remote(td.path());

    fx.proxy.arm(FaultSpec::duplicate(Direction::Download, 2000, 256));
    let err = batch::fetch_pack(&remote, &local, &oids).unwrap_err();
    assert!(format!("{err:#}").contains("integrity"), "{err:#}");

    // The poisoned partial must be gone from the staging area...
    let incoming = td.path().join("lfs/incoming");
    let leftovers: Vec<String> = match std::fs::read_dir(&incoming) {
        Ok(rd) => rd
            .filter_map(|e| e.ok())
            .map(|e| e.file_name().to_string_lossy().into_owned())
            .collect(),
        Err(_) => Vec::new(), // staging dir never created: equally clean
    };
    assert!(
        leftovers.is_empty(),
        "verify failure left poisoned partial(s) behind: {leftovers:?}"
    );

    // ...so the retry restarts from byte zero instead of resuming
    // corrupt bytes, and converges byte-identically.
    batch::reset_stats();
    let retry = batch::fetch_pack(&remote, &local, &oids).unwrap();
    assert_eq!(retry.resumed_bytes, 0, "a clean retry must not resume poisoned bytes");
    assert_eq!(retry.wire_bytes, retry.packed_bytes);
    support::assert_stores_equal(&server_store, &local);
}

/// A stalled pack stream completes once the delay passes (no spurious
/// timeouts at test scale).
#[test]
fn delayed_pack_stream_still_completes() {
    let fx = support::HttpFixture::new();
    let server_store = fx.server_store();
    let oids = support::seed_store(&server_store, 6, 800, 0x51EE);
    let td = TempDir::new("fi-delay").unwrap();
    let local = LfsStore::open(td.path());
    let remote = fx.proxied_remote(td.path());

    fx.proxy.arm(FaultSpec::delay(Direction::Download, 250));
    let t0 = std::time::Instant::now();
    let summary = batch::fetch_pack(&remote, &local, &oids).unwrap();
    assert!(t0.elapsed().as_millis() >= 250, "delay fault did not stall the stream");
    assert_eq!(fx.proxy.fired(), 1);
    assert_eq!(summary.unavailable, 0);
    support::assert_stores_equal(&server_store, &local);
}

/// End-to-end acceptance: an interrupted `git-theta push` over the
/// HTTP remote resumes — the retry moves strictly fewer bytes than a
/// from-scratch transfer — and a fresh clone round-trips the bytes.
#[test]
fn interrupted_repo_push_over_http_resumes() {
    git_theta::init();
    let fx = support::HttpFixture::new();
    let td = TempDir::new("fi-http-repo").unwrap();
    let repo = Repository::init(td.path()).unwrap();
    Attributes::add_line(repo.worktree(), "*.bin filter=lfs").unwrap();
    // Incompressible payload so the pack is comfortably larger than
    // the truncation point.
    let mut rng = Pcg64::new(7);
    let payload: Vec<u8> = (0..60_000).map(|_| rng.next_u64() as u8).collect();
    std::fs::write(td.join("w.bin"), &payload).unwrap();
    repo.add(&["w.bin", ".thetaattributes"]).unwrap();
    repo.commit("v1", "t").unwrap();

    let spec = RemoteSpec::parse(&fx.proxy.url()).unwrap();
    fx.proxy.arm(FaultSpec::kill(Direction::Upload, 1000));
    assert!(repo.push_spec(&spec, "main").is_err());
    assert_eq!(fx.proxy.fired(), 1);

    batch::reset_stats();
    repo.push_spec(&spec, "main").unwrap();
    let stats = batch::stats();
    assert_eq!(stats.resumed_bytes, 1000, "retry must resume from the server partial");
    assert!(stats.wire_bytes < stats.packed_bytes);

    // A fresh clone (direct, no proxy) reproduces the exact bytes.
    let td_clone = TempDir::new("fi-http-clone").unwrap();
    let clone = Repository::init(td_clone.path()).unwrap();
    let direct = RemoteSpec::parse(&fx.server.url()).unwrap();
    clone.config_set("remote", &direct.to_string()).unwrap();
    clone.pull_spec(&direct, "main").unwrap();
    assert_eq!(std::fs::read(td_clone.join("w.bin")).unwrap(), payload);
}

#[test]
fn push_to_remote_with_foreign_history_rejected() {
    git_theta::init();
    let td_a = TempDir::new("fiA").unwrap();
    let td_b = TempDir::new("fiB").unwrap();
    let td_r = TempDir::new("fiR").unwrap();
    let a = Repository::init(td_a.path()).unwrap();
    std::fs::write(td_a.join("x"), "a").unwrap();
    a.add(&["x"]).unwrap();
    a.commit("a", "a").unwrap();
    a.push(td_r.path(), "main").unwrap();

    // Unrelated repo pushes to the same branch: rejected (non-FF).
    let b = Repository::init(td_b.path()).unwrap();
    std::fs::write(td_b.join("y"), "b").unwrap();
    b.add(&["y"]).unwrap();
    b.commit("b", "b").unwrap();
    assert!(b.push(td_r.path(), "main").is_err());
}
