//! Collaboration-at-scale scenario harness (tier-1): N concurrent
//! collaborator clones drive a seeded weighted op mix against one
//! served hub, mid-pack fetch kills are injected through the fault
//! proxy, and after quiesce the harness *proves* convergence — every
//! clone's checked-out parameter groups byte-identical, a fresh clone
//! reproducing them, and the hub store re-hashing clean. On divergence
//! the harness prints the replay seed and dumps the per-actor op trace.
//!
//! These tests are the acceptance gate from the scenario issue:
//! ≥ 8 actors × ≥ 200 ops with an injected fault converging across
//! ≥ 3 distinct seeds, plus replayability of the op schedule from the
//! printed seed alone.

use git_theta::benchkit::scenario::{run_scenario, ScenarioConfig};
use git_theta::gitcore::object::Oid;
use git_theta::theta::{plan_garbage, prune_plan};

/// The headline scenario: eight concurrent collaborators, 208 total
/// ops, one injected mid-pack fetch kill — and it must converge for
/// every seed, not just a lucky one.
#[test]
fn eight_actors_converge_across_seeds() {
    for seed in [1u64, 2, 3] {
        let out = run_scenario(&ScenarioConfig {
            actors: 8,
            ops: 208,
            seed,
            faults: 1,
        })
        .unwrap();
        assert!(out.converged, "seed {seed} diverged — replay trace dumped");
        assert_eq!(out.ops_applied, 208, "seed {seed} dropped ops");
        assert_eq!(out.faults_fired, 1, "seed {seed}: fault never fired");
        assert!(out.store_objects_verified > 0, "seed {seed}: empty hub store");
    }
}

/// The op schedule is a pure function of the seed: two runs with the
/// same config must attempt the identical per-actor op sequences
/// (counters like push retries may differ — that is contention, not
/// schedule — but the trace may not).
#[test]
fn scenario_is_replayable_from_its_seed() {
    let cfg = ScenarioConfig {
        actors: 4,
        ops: 48,
        seed: 42,
        faults: 1,
    };
    let a = run_scenario(&cfg).unwrap();
    let b = run_scenario(&cfg).unwrap();
    assert!(a.converged && b.converged);
    assert_eq!(a.traces, b.traces, "same seed produced a different op schedule");
}

/// Satellite: the pull+merge path under injected failure. Two fetches
/// are killed mid-pack; each must error, retry, resume from the
/// partial, and the fleet must still converge.
#[test]
fn mid_fetch_kill_retries_and_converges() {
    let out = run_scenario(&ScenarioConfig {
        actors: 4,
        ops: 40,
        seed: 7,
        faults: 2,
    })
    .unwrap();
    assert!(out.converged);
    assert_eq!(out.faults_fired, 2);
    assert_eq!(out.fetch_retries, 2);
}

/// Satellite regression, via the public API: a put that lands between
/// gc's plan and its prune must spare the object (the store-level race
/// the scenario's concurrent gc ops exercise non-deterministically,
/// pinned down deterministically here).
#[test]
fn concurrent_put_vs_prune_never_drops_a_live_oid() {
    use git_theta::checkpoint::{Checkpoint, CheckpointFormat, SafetensorsFormat};
    use git_theta::gitcore::attributes::Attributes;
    use git_theta::gitcore::repo::Repository;
    use git_theta::lfs::LfsStore;
    use git_theta::tensor::Tensor;
    use git_theta::util::tmp::TempDir;

    git_theta::init();
    let td = TempDir::new("scenario-gc-race").unwrap();
    let repo = Repository::init(td.path()).unwrap();
    Attributes::add_line(
        repo.worktree(),
        "*.safetensors filter=theta diff=theta merge=theta",
    )
    .unwrap();
    let mut ck = Checkpoint::new();
    ck.insert("w", Tensor::from_f32(vec![32], vec![1.0; 32]).unwrap());
    SafetensorsFormat
        .save_file(&ck, &td.join("model.safetensors"))
        .unwrap();
    repo.add(&["model.safetensors", ".thetaattributes"]).unwrap();
    repo.commit("v1", "t").unwrap();

    let store = LfsStore::open(repo.theta_dir());
    let payload = b"merge resolution re-stored mid-gc";
    let (orphan, _) = store.put(payload).unwrap();
    // Age the object so only the racing put's mtime freshen saves it.
    let hex = orphan.to_hex();
    let path = td
        .path()
        .join(".theta/lfs/objects")
        .join(format!("{}/{}", &hex[..2], &hex[2..]));
    let f = std::fs::OpenOptions::new().write(true).open(&path).unwrap();
    f.set_modified(std::time::SystemTime::now() - std::time::Duration::from_secs(3600))
        .unwrap();
    drop(f);

    let (mut report, started) = plan_garbage(&repo).unwrap();
    assert_eq!(report.orphaned, vec![orphan]);
    store.put(payload).unwrap(); // the race
    prune_plan(&store, &mut report, started).unwrap();

    assert!(store.contains(&orphan), "prune dropped a live oid");
    assert_eq!(report.spared, 1);
    let bytes = store.get(&orphan).unwrap();
    assert_eq!(Oid::of_bytes(&bytes), orphan);
}
