//! Integration: AOT-compiled Pallas/JAX artifacts vs pure-Rust paths.
//!
//! These run only when `make artifacts` has produced `artifacts/`; each
//! test skips (passes trivially with a note) otherwise so `cargo test`
//! stays green in a fresh checkout.

use git_theta::mlops;
use git_theta::runtime::Runtime;
use git_theta::tensor::Tensor;
use git_theta::theta::lsh;
use git_theta::train::{SyntheticTask, TaskKind, Trainer};
use git_theta::util::rng::Pcg64;

fn artifacts_ready(names: &[&str]) -> bool {
    match Runtime::global() {
        Ok(rt) => names.iter().all(|n| rt.available(n)),
        Err(_) => false,
    }
}

#[test]
fn lsh_kernel_matches_rust_projection() {
    if !artifacts_ready(&["lsh_project"]) {
        eprintln!("skipped: artifacts not built");
        return;
    }
    let mut rng = Pcg64::new(11);
    for n in [100usize, 16_384, 100_000, 2_000_000] {
        let vals: Vec<f32> = (0..n).map(|_| (rng.next_f32() - 0.5) * 0.2).collect();
        let kernel = mlops::lsh_project_kernel(&vals).unwrap();
        let rust = lsh::project(&vals);
        for j in 0..lsh::NUM_HASHES {
            let tol = 1e-3 * rust[j].abs().max(1.0);
            assert!(
                (kernel[j] - rust[j]).abs() < tol,
                "n={n} j={j}: kernel {} vs rust {}",
                kernel[j],
                rust[j]
            );
        }
    }
}

#[test]
fn param_average_kernel_matches_rust() {
    if !artifacts_ready(&["param_average"]) {
        eprintln!("skipped: artifacts not built");
        return;
    }
    let mut rng = Pcg64::new(12);
    let n = 1_500_000; // forces multi-block + padding path
    let a: Vec<f32> = (0..n).map(|_| rng.next_f32()).collect();
    let b: Vec<f32> = (0..n).map(|_| rng.next_f32()).collect();
    let ta = Tensor::from_f32(vec![n], a.clone()).unwrap();
    let tb = Tensor::from_f32(vec![n], b.clone()).unwrap();
    let avg = mlops::average_pair(&ta, &tb).unwrap();
    let got = avg.to_f32_vec().unwrap();
    for i in (0..n).step_by(97_713) {
        assert!((got[i] - (a[i] + b[i]) / 2.0).abs() < 1e-6);
    }
}

#[test]
fn lora_kernel_matches_rust() {
    if !artifacts_ready(&["lora_apply_512x512x16"]) {
        eprintln!("skipped: artifacts not built");
        return;
    }
    let mut rng = Pcg64::new(13);
    let (m, n, r) = (512usize, 512usize, 16usize);
    let w = Tensor::from_f32(vec![m, n], (0..m * n).map(|_| rng.next_f32()).collect()).unwrap();
    let a = Tensor::from_f32(vec![m, r], (0..m * r).map(|_| rng.next_f32() * 0.1).collect())
        .unwrap();
    let b = Tensor::from_f32(vec![r, n], (0..r * n).map(|_| rng.next_f32() * 0.1).collect())
        .unwrap();
    let kernel = mlops::lora_apply(&w, &a, &b, 16.0).unwrap();
    let rust = mlops::lora_apply_rust(&w, &a, &b, 16.0, m, n, r).unwrap();
    let kv = kernel.to_f32_vec().unwrap();
    let rv = rust.to_f32_vec().unwrap();
    for i in (0..m * n).step_by(9973) {
        assert!((kv[i] - rv[i]).abs() < 1e-4, "i={i}: {} vs {}", kv[i], rv[i]);
    }
}

#[test]
fn train_step_learns_and_lora_freezes_base() {
    let trainer = match Trainer::try_new().unwrap() {
        Some(t) => t,
        None => {
            eprintln!("skipped: artifacts not built");
            return;
        }
    };
    let mut params = trainer.init_params().unwrap();
    let mut task = SyntheticTask::new(TaskKind::Cb, trainer.cfg.vocab, trainer.cfg.seq_len, 5);

    let (acc0, _) = trainer.eval(&params, &task, 4).unwrap();
    let losses = trainer.train(&mut params, &mut task, 120, 0.1).unwrap();
    let (acc1, _) = trainer.eval(&params, &task, 4).unwrap();
    let head = losses[..20].iter().sum::<f32>() / 20.0;
    let tail = losses[losses.len() - 20..].iter().sum::<f32>() / 20.0;
    assert!(tail < head, "loss did not decrease: {head} -> {tail}");
    assert!(acc1 >= acc0, "accuracy regressed: {acc0} -> {acc1}");

    // LoRA: base unchanged, adapters move, merged model differs.
    let before = params.clone();
    let mut lora = trainer.init_lora().unwrap();
    trainer.train_lora(&params, &mut lora, &mut task, 30, 0.1).unwrap();
    for ((_, a), (_, b)) in params.tensors.iter().zip(&before.tensors) {
        assert_eq!(a, b, "base weights moved during LoRA training");
    }
    let merged = trainer
        .merge_lora(&params, &lora, trainer.cfg.lora_rank as f32)
        .unwrap();
    let changed = merged
        .tensors
        .iter()
        .zip(&params.tensors)
        .any(|((_, m), (_, p))| m != p);
    assert!(changed, "merged model identical to base");
}

#[test]
fn eval_step_agrees_with_training_signal() {
    let trainer = match Trainer::try_new().unwrap() {
        Some(t) => t,
        None => {
            eprintln!("skipped: artifacts not built");
            return;
        }
    };
    let params = trainer.init_params().unwrap();
    let task = SyntheticTask::new(TaskKind::Rte, trainer.cfg.vocab, trainer.cfg.seq_len, 6);
    let (acc, loss) = trainer.eval(&params, &task, 4).unwrap();
    assert!((0.0..=1.0).contains(&acc));
    assert!(loss.is_finite() && loss > 0.0);
    // Deterministic across calls.
    let (acc2, loss2) = trainer.eval(&params, &task, 4).unwrap();
    assert_eq!(acc, acc2);
    assert_eq!(loss, loss2);
}
