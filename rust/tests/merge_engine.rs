//! Merge/diff-engine integration tests: byte-for-byte parity of the
//! parallel+cached+prefetching engine against the serial path across
//! every strategy and conflict kind, proof that non-conflicted groups
//! are never reconstructed, and the `git-theta gc` command.

use git_theta::checkpoint::{Checkpoint, CheckpointFormat, SafetensorsFormat};
use git_theta::cli::dispatch;
use git_theta::gitcore::drivers::MergeOptions;
use git_theta::gitcore::object::Oid;
use git_theta::lfs::LfsStore;
use git_theta::tensor::Tensor;
use git_theta::theta::filter::{clean_checkpoint_opts, CleanOptions, ObjectAccess};
use git_theta::theta::merge::{merge_metadata_opts, ConflictKind, EngineOptions};
use git_theta::util::prop::check;
use git_theta::util::rng::Pcg64;
use git_theta::util::tmp::TempDir;
use std::path::Path;
use std::sync::Mutex;

// The gc tests chdir; serialize them (and anything else order-sensitive).
static TEST_LOCK: Mutex<()> = Mutex::new(());

fn lock() -> std::sync::MutexGuard<'static, ()> {
    TEST_LOCK.lock().unwrap_or_else(|e| e.into_inner())
}

fn access(td: &TempDir) -> ObjectAccess {
    ObjectAccess {
        store: LfsStore::open(td.path()),
        remote: None,
    }
}

fn deep_opts() -> CleanOptions {
    CleanOptions {
        snapshot_depth: None,
        threads: 2,
        ..Default::default()
    }
}

fn opts(strategy: &str) -> MergeOptions {
    MergeOptions {
        strategy: Some(strategy.to_string()),
        ..Default::default()
    }
}

// ----------------------------------------------------------------------
// parity: parallel + cached + prefetch + skip == serial, byte for byte
// ----------------------------------------------------------------------

#[test]
fn prop_engine_parity_across_strategies_and_conflict_kinds() {
    git_theta::init(); // registers weighted + fisher
    const STRATEGIES: [&str; 6] = ["us", "them", "ancestor", "average", "weighted", "fisher"];
    check(
        "merge engine parity: full levers == serial across strategies/kinds",
        |rng| rng.below(u64::MAX),
        |&seed| {
            let e = |err: anyhow::Error| format!("{err:#}");
            let mut rng = Pcg64::new(seed);
            let strategy = STRATEGIES[rng.below(STRATEGIES.len() as u64) as usize];
            let elems = 32 + rng.below(65) as usize;
            let depth = 1 + rng.below(4) as usize;

            let td = TempDir::new("merge-prop").map_err(|err| err.to_string())?;
            let acc = access(&td);
            let mut ck = Checkpoint::new();
            for g in 0..3 {
                let vals: Vec<f32> = (0..elems).map(|_| (rng.next_f32() - 0.5) * 2.0).collect();
                ck.insert(format!("g{g}"), Tensor::from_f32(vec![elems], vals).unwrap());
            }
            let mut anc =
                clean_checkpoint_opts(&acc, &ck, "native", None, &deep_opts()).map_err(e)?;
            for v in 1..depth {
                for g in 0..3 {
                    let n = format!("g{g}");
                    let mut vals = ck.get(&n).unwrap().to_f32_vec().unwrap();
                    // Guaranteed-magnitude bumps: sub-threshold noise
                    // would be (correctly) ignored by clean and break
                    // the comparison for the wrong reason.
                    vals[(v * 7 + g) % elems] += 0.5 + rng.next_f32();
                    ck.insert(n, Tensor::from_f32(vec![elems], vals).unwrap());
                }
                anc = clean_checkpoint_opts(&acc, &ck, "native", Some(&anc), &deep_opts())
                    .map_err(e)?;
            }

            // Conflict layout per strategy applicability:
            //   g0 — BothModified (every strategy resolves it)
            //   g1 — DeleteModify for us/them/ancestor, else one-sided
            //   g2 — changed on theirs only (always trivial)
            //   new — BothAdded for us/them/average/weighted
            let strat = git_theta::theta::merge::merge_strategy(strategy)
                .ok_or_else(|| format!("strategy '{strategy}' not registered"))?;
            let mut ours_ck = ck.clone();
            let mut theirs_ck = ck.clone();
            let bump = |c: &mut Checkpoint, name: &str, at: usize, delta: f32| {
                let mut vals = c.get(name).unwrap().to_f32_vec().unwrap();
                vals[at % vals.len()] += delta;
                c.insert(name.to_string(), Tensor::from_f32(vec![vals.len()], vals).unwrap());
            };
            bump(&mut ours_ck, "g0", 0, 1.5);
            bump(&mut theirs_ck, "g0", 1, -2.5);
            if strat.applicable(ConflictKind::DeleteModify) {
                ours_ck.remove("g1");
                bump(&mut theirs_ck, "g1", 2, 3.0);
            } else {
                bump(&mut ours_ck, "g1", 2, 3.0); // ours-only: trivial
            }
            bump(&mut theirs_ck, "g2", 3, 0.75);
            if strat.applicable(ConflictKind::BothAdded) {
                ours_ck.insert("new", Tensor::from_f32(vec![8], vec![1.0; 8]).unwrap());
                theirs_ck.insert("new", Tensor::from_f32(vec![8], vec![4.0; 8]).unwrap());
            }
            let ours = clean_checkpoint_opts(&acc, &ours_ck, "native", Some(&anc), &deep_opts())
                .map_err(e)?;
            let theirs = clean_checkpoint_opts(&acc, &theirs_ck, "native", Some(&anc), &deep_opts())
                .map_err(e)?;

            let (serial, s_stats) = merge_metadata_opts(
                &acc,
                Some(&anc),
                &ours,
                &theirs,
                &opts(strategy),
                &EngineOptions::serial(),
            )
            .map_err(e)?;
            let (full, f_stats) = merge_metadata_opts(
                &acc,
                Some(&anc),
                &ours,
                &theirs,
                &opts(strategy),
                &EngineOptions {
                    threads: 4,
                    ..Default::default()
                },
            )
            .map_err(e)?;
            if serial.to_bytes() != full.to_bytes() {
                return Err(format!(
                    "strategy '{strategy}' depth {depth}: engine output diverged from serial"
                ));
            }
            if s_stats.resolved != f_stats.resolved {
                return Err(format!(
                    "resolved lists diverged: {:?} vs {:?}",
                    s_stats.resolved, f_stats.resolved
                ));
            }
            if s_stats.resolved.is_empty() {
                return Err("fixture produced no conflicts".to_string());
            }
            Ok(())
        },
    );
}

// ----------------------------------------------------------------------
// symmetry: ours/theirs order must not matter for commutative strategies
// ----------------------------------------------------------------------

/// For the commutative strategies (average, fisher) a merge is an
/// unordered combination of the two sides: swapping ours and theirs
/// must produce byte-identical metadata. An asymmetry here would mean
/// two collaborators merging each other's work get different models
/// depending on who ran the merge — exactly the divergence the
/// scenario harness exists to rule out.
#[test]
fn prop_commutative_strategies_ignore_ours_theirs_order() {
    git_theta::init(); // registers fisher
    check(
        "merge symmetry: average/fisher are ours/theirs-order independent",
        |rng| rng.below(u64::MAX),
        |&seed| {
            let e = |err: anyhow::Error| format!("{err:#}");
            let mut rng = Pcg64::new(seed);
            let elems = 24 + rng.below(41) as usize;

            let td = TempDir::new("merge-sym").map_err(|err| err.to_string())?;
            let acc = access(&td);
            let mut ck = Checkpoint::new();
            for g in 0..3 {
                let vals: Vec<f32> = (0..elems).map(|_| (rng.next_f32() - 0.5) * 2.0).collect();
                ck.insert(format!("g{g}"), Tensor::from_f32(vec![elems], vals).unwrap());
            }
            let anc = clean_checkpoint_opts(&acc, &ck, "native", None, &deep_opts()).map_err(e)?;

            // g0 — BothModified (the strategy actually combines);
            // g1 — changed on one side only (trivial carry-forward);
            // g2 — untouched (ancestor carries).
            let bump = |c: &mut Checkpoint, name: &str, at: usize, delta: f32| {
                let mut vals = c.get(name).unwrap().to_f32_vec().unwrap();
                vals[at % vals.len()] += delta;
                c.insert(name.to_string(), Tensor::from_f32(vec![vals.len()], vals).unwrap());
            };
            let mut ours_ck = ck.clone();
            let mut theirs_ck = ck.clone();
            bump(&mut ours_ck, "g0", 0, 1.0 + rng.next_f32());
            bump(&mut theirs_ck, "g0", 1, -(2.0 + rng.next_f32()));
            bump(&mut theirs_ck, "g1", 2, 0.5 + rng.next_f32());
            let ours = clean_checkpoint_opts(&acc, &ours_ck, "native", Some(&anc), &deep_opts())
                .map_err(e)?;
            let theirs = clean_checkpoint_opts(&acc, &theirs_ck, "native", Some(&anc), &deep_opts())
                .map_err(e)?;

            for strategy in ["average", "fisher"] {
                let (ab, ab_stats) = merge_metadata_opts(
                    &acc,
                    Some(&anc),
                    &ours,
                    &theirs,
                    &opts(strategy),
                    &EngineOptions::default(),
                )
                .map_err(e)?;
                let (ba, _) = merge_metadata_opts(
                    &acc,
                    Some(&anc),
                    &theirs,
                    &ours,
                    &opts(strategy),
                    &EngineOptions::default(),
                )
                .map_err(e)?;
                if ab.to_bytes() != ba.to_bytes() {
                    return Err(format!(
                        "strategy '{strategy}' seed {seed}: merge(ours, theirs) != \
                         merge(theirs, ours)"
                    ));
                }
                if ab_stats.resolved.is_empty() {
                    return Err(format!(
                        "strategy '{strategy}' seed {seed}: fixture produced no conflict"
                    ));
                }
            }
            Ok(())
        },
    );
}

// ----------------------------------------------------------------------
// change-skipping: unconflicted groups are never reconstructed
// ----------------------------------------------------------------------

#[test]
fn merge_never_reconstructs_unconflicted_groups() {
    let td = TempDir::new("merge-skip-fetch").unwrap();
    let acc = access(&td);
    let mut ck = Checkpoint::new();
    for g in 0..3 {
        ck.insert(
            format!("g{g}"),
            Tensor::from_f32(vec![32], vec![g as f32; 32]).unwrap(),
        );
    }
    let anc = clean_checkpoint_opts(&acc, &ck, "native", None, &deep_opts()).unwrap();
    let mut ours_ck = ck.clone();
    let mut theirs_ck = ck.clone();
    // g0 conflicts; g1 changes only on theirs; g2 untouched.
    ours_ck.insert("g0", Tensor::from_f32(vec![32], vec![10.0; 32]).unwrap());
    theirs_ck.insert("g0", Tensor::from_f32(vec![32], vec![20.0; 32]).unwrap());
    theirs_ck.insert("g1", Tensor::from_f32(vec![32], vec![30.0; 32]).unwrap());
    let ours = clean_checkpoint_opts(&acc, &ours_ck, "native", Some(&anc), &deep_opts()).unwrap();
    let theirs =
        clean_checkpoint_opts(&acc, &theirs_ck, "native", Some(&anc), &deep_opts()).unwrap();

    // Delete every object that is not part of g0's three sides. If the
    // engine reconstructed (or prefetched) anything else, the merge
    // would fail on a missing object.
    let mut keep: Vec<Oid> = Vec::new();
    for meta in [&anc, &ours, &theirs] {
        meta.groups["g0"].all_oids(&mut keep);
    }
    for oid in acc.store.list().unwrap() {
        if !keep.contains(&oid) {
            assert!(acc.store.delete(&oid).unwrap());
        }
    }

    let (merged, stats) = merge_metadata_opts(
        &acc,
        Some(&anc),
        &ours,
        &theirs,
        &opts("average"),
        &EngineOptions::default(),
    )
    .unwrap();
    assert_eq!(stats.resolved, vec!["g0 (average)".to_string()]);
    assert_eq!(stats.trivial, 2);
    // Trivially merged entries carried forward untouched.
    assert_eq!(merged.groups["g1"], theirs.groups["g1"]);
    assert_eq!(merged.groups["g2"], anc.groups["g2"]);
}

// ----------------------------------------------------------------------
// `git-theta gc`
// ----------------------------------------------------------------------

fn in_dir<F: FnOnce() -> anyhow::Result<()>>(dir: &Path, f: F) {
    let _guard = lock();
    let old = std::env::current_dir().unwrap();
    std::env::set_current_dir(dir).unwrap();
    let result = f();
    std::env::set_current_dir(old).unwrap();
    result.unwrap();
}

fn sv(args: &[&str]) -> Vec<String> {
    args.iter().map(|s| s.to_string()).collect()
}

#[test]
fn gc_command_prunes_orphans_and_preserves_history() {
    let td = TempDir::new("cli-gc").unwrap();
    in_dir(td.path(), || {
        git_theta::init();
        dispatch(&sv(&["init"]))?;
        dispatch(&sv(&["track", "model.safetensors"]))?;
        let fmt = SafetensorsFormat;
        let mut ck = Checkpoint::new();
        ck.insert("w", Tensor::from_f32(vec![64], vec![0.5; 64]).unwrap());
        std::fs::write("model.safetensors", fmt.save_bytes(&ck)?)?;
        dispatch(&sv(&["add", "model.safetensors", ".thetaattributes"]))?;
        dispatch(&sv(&["commit", "-m", "v1"]))?;
        ck.insert("w", Tensor::from_f32(vec![64], vec![1.5; 64]).unwrap());
        std::fs::write("model.safetensors", fmt.save_bytes(&ck)?)?;
        dispatch(&sv(&["add", "model.safetensors"]))?;
        dispatch(&sv(&["commit", "-m", "v2"]))?;

        let store = LfsStore::open(&td.path().join(".theta"));
        let live = store.list()?.len();
        let (junk, _) = store.put(b"orphaned by an abandoned run")?;

        // Dry run deletes nothing.
        dispatch(&sv(&["gc"]))?;
        assert!(store.contains(&junk));
        // Unknown flags are rejected.
        assert!(dispatch(&sv(&["gc", "--now"])).is_err());
        // Prune removes exactly the orphan.
        dispatch(&sv(&["gc", "--prune"]))?;
        assert!(!store.contains(&junk));
        assert_eq!(store.list()?.len(), live);

        // Both committed versions still reconstruct.
        dispatch(&sv(&["checkout", "main"]))?;
        assert_eq!(std::fs::read("model.safetensors")?, fmt.save_bytes(&ck)?);
        Ok(())
    });
}
