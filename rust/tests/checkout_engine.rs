//! Checkout-engine integration tests: decode allocation bounds, deep
//! mixed-op chains under snapshotting/caching, and the `git-theta
//! snapshot` command.
//!
//! This binary installs [`TrackingAlloc`] so peak-transient-heap
//! assertions measure the real allocator traffic of the decode path.

use git_theta::checkpoint::{Checkpoint, CheckpointFormat, SafetensorsFormat};
use git_theta::cli::dispatch;
use git_theta::gitcore::repo::Repository;
use git_theta::lfs::LfsStore;
use git_theta::tensor::Tensor;
use git_theta::theta::filter::{
    clean_checkpoint_opts, smudge_metadata, smudge_metadata_opts, CleanOptions, ObjectAccess,
};
use git_theta::theta::metadata::ModelMetadata;
use git_theta::theta::serialize::{set_legacy_decode, Serializer, TensorStoreSerializer};
use git_theta::theta::DEFAULT_SNAPSHOT_DEPTH;
use git_theta::util::alloc::{self, TrackingAlloc};
use git_theta::util::prop::check;
use git_theta::util::rng::Pcg64;
use git_theta::util::tmp::TempDir;
use std::path::Path;
use std::sync::Mutex;

#[global_allocator]
static ALLOC: TrackingAlloc = TrackingAlloc;

// One big lock: the allocation test needs exclusive heap traffic, and
// the CLI tests chdir. Ignore poisoning so one failure doesn't cascade.
static TEST_LOCK: Mutex<()> = Mutex::new(());

fn lock() -> std::sync::MutexGuard<'static, ()> {
    TEST_LOCK.lock().unwrap_or_else(|e| e.into_inner())
}

fn access(td: &TempDir) -> ObjectAccess {
    ObjectAccess {
        store: LfsStore::open(td.path()),
        remote: None,
    }
}

// ----------------------------------------------------------------------
// decode allocation bounds (the `total.max(1)`-per-chunk fix)
// ----------------------------------------------------------------------

#[test]
fn in_place_decode_peak_allocation_is_bounded() {
    // Allocation counters are process-global: keep other tests of this
    // binary from allocating during the measured region.
    let _guard = lock();
    // 64 chunks of 4 KiB: the layout where the old decoder allocated a
    // whole-tensor-capacity Vec *per chunk* (64x over-allocation).
    for shuffle in [true, false] {
        let ser = TensorStoreSerializer {
            chunk_bytes: 4096,
            level: 1,
            shuffle,
        };
        let mut rng = Pcg64::new(9);
        let vals: Vec<f32> = (0..65_536).map(|_| rng.next_f32()).collect();
        let t = Tensor::from_f32(vec![65_536], vals).unwrap();
        let blob = ser.serialize(&t).unwrap();

        // Warm thread-local scratch and lazies outside the measurement.
        assert_eq!(ser.deserialize(&blob).unwrap(), t);

        let base = alloc::reset_peak();
        let out = ser.deserialize(&blob).unwrap();
        let transient = alloc::peak_bytes().saturating_sub(base);
        assert!(
            transient < 2 * t.nbytes(),
            "shuffle={shuffle}: in-place decode peaked at {transient} B \
             for a {} B tensor",
            t.nbytes()
        );
        assert_eq!(out, t);

        // The legacy copying path demonstrates the bug this guards
        // against: it breaks the same bound on the same input.
        set_legacy_decode(true);
        let base = alloc::reset_peak();
        let out = ser.deserialize(&blob);
        let transient = alloc::peak_bytes().saturating_sub(base);
        set_legacy_decode(false);
        assert_eq!(out.unwrap(), t);
        assert!(
            transient >= 2 * t.nbytes(),
            "shuffle={shuffle}: expected the copying path to over-allocate, \
             peaked at {transient} B"
        );
    }
}

// ----------------------------------------------------------------------
// deep mixed-op chains: snapshot/cache equivalence
// ----------------------------------------------------------------------

/// One synthesized training history: the op applied at each version.
#[derive(Debug, Clone, Copy, PartialEq)]
enum Op {
    Sparse,
    Trim,
    Dense,
}

fn apply_op(ck: &mut Checkpoint, rng: &mut Pcg64, op: Op) {
    let names: Vec<String> = ck.names().cloned().collect();
    for name in names {
        let t = ck.get(&name).unwrap().clone();
        let (rows, cols) = (t.shape()[0], t.shape()[1]);
        let next = match op {
            Op::Sparse => {
                let mut vals = t.to_f32_vec().unwrap();
                for _ in 0..3 {
                    let at = rng.below((rows * cols) as u64) as usize;
                    // Guaranteed-magnitude delta: a change below the
                    // LSH/allclose noise floor is *supposed* to be
                    // ignored by clean, which would break this test's
                    // bit-exact comparison for the wrong reason.
                    vals[at] += 0.25 + rng.next_f32();
                }
                Tensor::from_f32(vec![rows, cols], vals).unwrap()
            }
            Op::Trim if rows > 6 => t.take_rows(rows - 1).unwrap(),
            Op::Trim => t, // floor reached: keep as-is (unchanged group)
            Op::Dense => {
                let vals: Vec<f32> = (0..rows * cols)
                    .map(|_| (rng.next_f32() - 0.5) * 2.0)
                    .collect();
                Tensor::from_f32(vec![rows, cols], vals).unwrap()
            }
        };
        ck.insert(name, next);
    }
}

#[test]
fn prop_deep_mixed_chains_reconstruct_identically() {
    let _guard = lock();
    check(
        "depth-32 mixed chains: snapshot/cache do not change smudge output",
        |rng| rng.below(u64::MAX),
        |&seed| {
            let td = TempDir::new("deep-prop").map_err(|e| e.to_string())?;
            let acc = access(&td);
            let mut rng = Pcg64::new(seed);
            let mut ck = Checkpoint::new();
            for g in 0..2 {
                let vals: Vec<f32> = (0..16 * 8).map(|_| (rng.next_f32() - 0.5) * 2.0).collect();
                ck.insert(
                    format!("g{g}"),
                    Tensor::from_f32(vec![16, 8], vals).unwrap(),
                );
            }
            let deep_opts = CleanOptions {
                snapshot_depth: None,
                threads: 2,
                cache: false,
                ..Default::default()
            };
            let snap_opts = CleanOptions {
                snapshot_depth: Some(DEFAULT_SNAPSHOT_DEPTH),
                threads: 2,
                ..Default::default()
            };
            let e = |e: anyhow::Error| format!("{e:#}");
            let mut deep =
                clean_checkpoint_opts(&acc, &ck, "native", None, &deep_opts).map_err(e)?;
            let mut snap =
                clean_checkpoint_opts(&acc, &ck, "native", None, &snap_opts).map_err(e)?;
            for _v in 1..32 {
                // Mostly sparse with occasional trims and rare dense
                // re-writes, so deep chains actually form.
                let op = match rng.below(8) {
                    0 => Op::Trim,
                    1 => Op::Dense,
                    _ => Op::Sparse,
                };
                apply_op(&mut ck, &mut rng, op);
                deep = clean_checkpoint_opts(&acc, &ck, "native", Some(&deep), &deep_opts)
                    .map_err(e)?;
                snap = clean_checkpoint_opts(&acc, &ck, "native", Some(&snap), &snap_opts)
                    .map_err(e)?;
            }
            for g in snap.groups.values() {
                if g.chain_depth() > DEFAULT_SNAPSHOT_DEPTH {
                    return Err(format!(
                        "snapshotted chain depth {} exceeds threshold",
                        g.chain_depth()
                    ));
                }
            }
            // All four (history, cache) combinations agree with the
            // reference checkpoint.
            for meta in [&deep, &snap] {
                for cache in [false, true] {
                    let back = smudge_metadata_opts(&acc, meta, 2, cache).map_err(e)?;
                    if back != ck {
                        return Err(format!(
                            "smudge mismatch (snapshotted={}, cache={cache})",
                            std::ptr::eq(meta, &snap)
                        ));
                    }
                }
            }
            Ok(())
        },
    );
}

#[test]
fn unsnapshotted_sparse_chain_reaches_depth_32() {
    // Sanity for the property above: with snapshotting off and only
    // sparse ops, depth really does hit 32 (the pathology the engine
    // bounds).
    let _guard = lock();
    let td = TempDir::new("deep-32").unwrap();
    let acc = access(&td);
    let mut rng = Pcg64::new(7);
    let mut ck = Checkpoint::new();
    let vals: Vec<f32> = (0..16 * 8).map(|_| rng.next_f32()).collect();
    ck.insert("w", Tensor::from_f32(vec![16, 8], vals).unwrap());
    let opts = CleanOptions {
        snapshot_depth: None,
        threads: 1,
        ..Default::default()
    };
    let mut meta = clean_checkpoint_opts(&acc, &ck, "native", None, &opts).unwrap();
    for _ in 1..32 {
        apply_op(&mut ck, &mut rng, Op::Sparse);
        meta = clean_checkpoint_opts(&acc, &ck, "native", Some(&meta), &opts).unwrap();
    }
    assert_eq!(meta.groups["w"].chain_depth(), 32);
    assert_eq!(smudge_metadata(&acc, &meta, 1).unwrap(), ck);
}

// ----------------------------------------------------------------------
// the `git-theta snapshot` command
// ----------------------------------------------------------------------

fn in_dir<F: FnOnce() -> anyhow::Result<()>>(dir: &Path, f: F) {
    let _guard = lock();
    let old = std::env::current_dir().unwrap();
    std::env::set_current_dir(dir).unwrap();
    let result = f();
    std::env::set_current_dir(old).unwrap();
    result.unwrap();
}

fn sv(args: &[&str]) -> Vec<String> {
    args.iter().map(|s| s.to_string()).collect()
}

fn staged_meta(repo: &Repository, path: &str) -> ModelMetadata {
    ModelMetadata::from_bytes(&repo.prior_staged(path).unwrap().unwrap()).unwrap()
}

#[test]
fn snapshot_command_reanchors_byte_for_byte() {
    let td = TempDir::new("cli-snapshot").unwrap();
    in_dir(td.path(), || {
        git_theta::init();
        dispatch(&sv(&["init"]))?;
        // Let the chain grow unbounded so the command has work to do.
        dispatch(&sv(&["config", "theta.snapshot-depth", "off"]))?;
        dispatch(&sv(&["track", "model.safetensors"]))?;

        let mut rng = Pcg64::new(11);
        let mut ck = Checkpoint::new();
        let vals: Vec<f32> = (0..512).map(|_| rng.next_f32()).collect();
        ck.insert("w", Tensor::from_f32(vec![32, 16], vals).unwrap());
        let fmt = SafetensorsFormat;
        std::fs::write("model.safetensors", fmt.save_bytes(&ck)?)?;
        dispatch(&sv(&["add", "model.safetensors", ".thetaattributes"]))?;
        dispatch(&sv(&["commit", "-m", "base"]))?;
        for i in 0..6 {
            let mut vals = ck.get("w").unwrap().to_f32_vec()?;
            vals[i * 3] += 1.0;
            ck.insert("w", Tensor::from_f32(vec![32, 16], vals).unwrap());
            std::fs::write("model.safetensors", fmt.save_bytes(&ck)?)?;
            dispatch(&sv(&["add", "model.safetensors"]))?;
            let msg = format!("step {i}");
            dispatch(&sv(&["commit", "-m", msg.as_str()]))?;
        }

        let repo = Repository::open(Path::new("."))?;
        let acc = ObjectAccess::for_repo(&repo)?;
        let before = staged_meta(&repo, "model.safetensors");
        assert_eq!(before.groups["w"].chain_depth(), 7);
        let bytes_before = fmt.save_bytes(&smudge_metadata(&acc, &before, 1)?)?;

        dispatch(&sv(&["snapshot", "model.safetensors"]))?;

        let after = staged_meta(&repo, "model.safetensors");
        assert_eq!(after.groups["w"].chain_depth(), 1);
        assert_eq!(after.groups["w"].update.kind, "dense");
        // Smudge output is byte-for-byte identical.
        let bytes_after = fmt.save_bytes(&smudge_metadata(&acc, &after, 1)?)?;
        assert_eq!(bytes_before, bytes_after);
        // Snapshotting again is a no-op on the metadata.
        dispatch(&sv(&["snapshot", "model.safetensors"]))?;
        assert_eq!(staged_meta(&repo, "model.safetensors"), after);

        // The re-anchor commits and checks out cleanly.
        dispatch(&sv(&["commit", "-m", "snapshot"]))?;
        dispatch(&sv(&["checkout", "main"]))?;
        assert_eq!(std::fs::read("model.safetensors")?, bytes_after);
        Ok(())
    });
}

#[test]
fn snapshot_depth_config_bounds_cli_chains() {
    let td = TempDir::new("cli-depth").unwrap();
    in_dir(td.path(), || {
        git_theta::init();
        dispatch(&sv(&["init"]))?;
        dispatch(&sv(&["config", "theta.snapshot-depth", "2"]))?;
        dispatch(&sv(&["track", "m.safetensors"]))?;
        let fmt = SafetensorsFormat;
        let mut rng = Pcg64::new(13);
        let mut ck = Checkpoint::new();
        let vals: Vec<f32> = (0..128).map(|_| rng.next_f32()).collect();
        ck.insert("w", Tensor::from_f32(vec![128], vals).unwrap());
        std::fs::write("m.safetensors", fmt.save_bytes(&ck)?)?;
        dispatch(&sv(&["add", "m.safetensors", ".thetaattributes"]))?;
        dispatch(&sv(&["commit", "-m", "base"]))?;

        let repo = Repository::open(Path::new("."))?;
        for i in 0..5 {
            let mut vals = ck.get("w").unwrap().to_f32_vec()?;
            vals[i] -= 0.5;
            ck.insert("w", Tensor::from_f32(vec![128], vals).unwrap());
            std::fs::write("m.safetensors", fmt.save_bytes(&ck)?)?;
            dispatch(&sv(&["add", "m.safetensors"]))?;
            let msg = format!("step {i}");
            dispatch(&sv(&["commit", "-m", msg.as_str()]))?;
            let depth = staged_meta(&repo, "m.safetensors").groups["w"].chain_depth();
            assert!(depth <= 2, "step {i}: depth {depth} exceeds configured bound");
        }
        Ok(())
    });
}

#[test]
fn snapshot_command_rejects_untracked_paths() {
    let td = TempDir::new("cli-snap-err").unwrap();
    in_dir(td.path(), || {
        git_theta::init();
        dispatch(&sv(&["init"]))?;
        assert!(dispatch(&sv(&["snapshot"])).is_err());
        assert!(dispatch(&sv(&["snapshot", "nope.safetensors"])).is_err());
        std::fs::write("notes.txt", "plain text")?;
        dispatch(&sv(&["add", "notes.txt"]))?;
        assert!(dispatch(&sv(&["snapshot", "notes.txt"])).is_err());
        Ok(())
    });
}
