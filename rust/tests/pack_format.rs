//! Property tests for the packfile format (`lfs/pack.rs`): round-trips
//! at every size from the empty pack to 100 objects, and detection of
//! every corruption class (bit flips anywhere, truncation, foreign
//! index entries).

use git_theta::gitcore::object::Oid;
use git_theta::lfs::{build_pack, pack_index, unpack_into, LfsStore};
use git_theta::util::prop::{check, gens};
use git_theta::util::rng::Pcg64;
use git_theta::util::tmp::TempDir;

fn random_payload(rng: &mut Pcg64, len: usize) -> Vec<u8> {
    (0..len).map(|_| rng.below(256) as u8).collect()
}

/// Build a store holding `sizes.len()` random objects; returns the oids.
fn seeded_store(td: &TempDir, rng: &mut Pcg64, sizes: &[usize]) -> (LfsStore, Vec<Oid>) {
    let store = LfsStore::open(td.path());
    let oids = sizes
        .iter()
        .map(|&n| store.put(&random_payload(rng, n)).unwrap().0)
        .collect();
    (store, oids)
}

#[test]
fn empty_pack_roundtrips() {
    let td = TempDir::new("pf-empty").unwrap();
    let store = LfsStore::open(td.path());
    let pack = build_pack(&store, &[], 4).unwrap();
    assert!(pack_index(&pack).unwrap().is_empty());
    let stats = unpack_into(&store, &pack, 4).unwrap();
    assert_eq!((stats.objects, stats.raw_bytes), (0, 0));
}

#[test]
fn single_object_roundtrips() {
    let td_a = TempDir::new("pf-one-a").unwrap();
    let td_b = TempDir::new("pf-one-b").unwrap();
    let mut rng = Pcg64::new(7);
    let (a, oids) = seeded_store(&td_a, &mut rng, &[1234]);
    let b = LfsStore::open(td_b.path());
    let pack = build_pack(&a, &oids, 1).unwrap();
    assert_eq!(pack_index(&pack).unwrap(), vec![(oids[0], 1234)]);
    unpack_into(&b, &pack, 1).unwrap();
    assert_eq!(b.get(&oids[0]).unwrap(), a.get(&oids[0]).unwrap());
}

#[test]
fn hundred_objects_roundtrip() {
    let td_a = TempDir::new("pf-100-a").unwrap();
    let td_b = TempDir::new("pf-100-b").unwrap();
    let mut rng = Pcg64::new(8);
    let sizes: Vec<usize> = (0..100).map(|i| i * 37 % 5000).collect(); // incl. size 0
    let (a, oids) = seeded_store(&td_a, &mut rng, &sizes);
    let b = LfsStore::open(td_b.path());
    let pack = build_pack(&a, &oids, 8).unwrap();
    let stats = unpack_into(&b, &pack, 8).unwrap();
    assert_eq!(stats.objects, oids.len());
    for oid in &oids {
        assert_eq!(b.get(oid).unwrap(), a.get(oid).unwrap());
    }
}

#[test]
fn roundtrip_property_random_shapes() {
    check(
        "pack roundtrip",
        |rng| {
            let n = gens::usize_in(rng, 0, 12);
            (0..n).map(|_| gens::usize_in(rng, 0, 3000)).collect::<Vec<usize>>()
        },
        |sizes| {
            let td_a = TempDir::new("pf-prop-a").map_err(|e| e.to_string())?;
            let td_b = TempDir::new("pf-prop-b").map_err(|e| e.to_string())?;
            let mut rng = Pcg64::new(sizes.iter().sum::<usize>() as u64 + 1);
            let (a, oids) = seeded_store(&td_a, &mut rng, sizes);
            let b = LfsStore::open(td_b.path());
            let pack = build_pack(&a, &oids, 4).map_err(|e| format!("{e:#}"))?;
            unpack_into(&b, &pack, 4).map_err(|e| format!("{e:#}"))?;
            for oid in &oids {
                if b.get(oid).map_err(|e| format!("{e:#}"))?
                    != a.get(oid).map_err(|e| format!("{e:#}"))?
                {
                    return Err(format!("object {} did not roundtrip", oid.short()));
                }
            }
            Ok(())
        },
    );
}

#[test]
fn corrupted_trailer_is_detected() {
    let td = TempDir::new("pf-corrupt").unwrap();
    let mut rng = Pcg64::new(9);
    let (store, oids) = seeded_store(&td, &mut rng, &[500, 900]);
    let pack = build_pack(&store, &oids, 1).unwrap();
    let dst_td = TempDir::new("pf-corrupt-dst").unwrap();
    let dst = LfsStore::open(dst_td.path());

    // The trailing 40 bytes are index offset + sha256: every flip there
    // must be rejected, as must a flip in the index region before it.
    for back in 1..=48 {
        let mut bad = pack.clone();
        let at = pack.len() - back;
        bad[at] ^= 0x01;
        assert!(
            unpack_into(&dst, &bad, 1).is_err(),
            "flip {back} bytes from the end went undetected"
        );
    }
}

#[test]
fn any_bit_flip_is_detected() {
    check(
        "pack bit-flip detection",
        |rng| gens::usize_in(rng, 0, 1_000_000),
        |&pos_seed| {
            let td = TempDir::new("pf-flip").map_err(|e| e.to_string())?;
            let mut rng = Pcg64::new(11);
            let (store, oids) = seeded_store(&td, &mut rng, &[64, 256]);
            let pack = build_pack(&store, &oids, 1).map_err(|e| format!("{e:#}"))?;
            let at = pos_seed % pack.len();
            let mut bad = pack.clone();
            bad[at] ^= 0x80;
            let dst_td = TempDir::new("pf-flip-dst").map_err(|e| e.to_string())?;
            let dst = LfsStore::open(dst_td.path());
            match unpack_into(&dst, &bad, 1) {
                Err(_) => Ok(()),
                Ok(_) => Err(format!("bit flip at byte {at} of {} accepted", pack.len())),
            }
        },
    );
}

#[test]
fn truncation_is_detected() {
    let td = TempDir::new("pf-trunc").unwrap();
    let mut rng = Pcg64::new(12);
    let (store, oids) = seeded_store(&td, &mut rng, &[2000]);
    let pack = build_pack(&store, &oids, 1).unwrap();
    let dst_td = TempDir::new("pf-trunc-dst").unwrap();
    let dst = LfsStore::open(dst_td.path());
    for keep in [0, 3, 15, 56, pack.len() / 2, pack.len() - 1] {
        assert!(
            unpack_into(&dst, &pack[..keep], 1).is_err(),
            "truncation to {keep} bytes went undetected"
        );
    }
}
