//! Property tests for the packfile format (`lfs/pack.rs`): round-trips
//! at every size from the empty pack to 100 objects, and detection of
//! every corruption class (bit flips anywhere, truncation, foreign
//! index entries).

use git_theta::gitcore::object::Oid;
use git_theta::lfs::pack::KIND_STORE;
use git_theta::lfs::{
    build_pack, full_record_cost, pack_index, plan_deltas, unpack_into, unpack_verified,
    write_delta_pack_file, LfsStore, PackCheck,
};
use git_theta::util::prop::{check, gens};
use git_theta::util::rng::Pcg64;
use git_theta::util::tmp::TempDir;

fn random_payload(rng: &mut Pcg64, len: usize) -> Vec<u8> {
    (0..len).map(|_| rng.below(256) as u8).collect()
}

/// Build a store holding `sizes.len()` random objects; returns the oids.
fn seeded_store(td: &TempDir, rng: &mut Pcg64, sizes: &[usize]) -> (LfsStore, Vec<Oid>) {
    let store = LfsStore::open(td.path());
    let oids = sizes
        .iter()
        .map(|&n| store.put(&random_payload(rng, n)).unwrap().0)
        .collect();
    (store, oids)
}

#[test]
fn empty_pack_roundtrips() {
    let td = TempDir::new("pf-empty").unwrap();
    let store = LfsStore::open(td.path());
    let pack = build_pack(&store, &[], 4).unwrap();
    assert!(pack_index(&pack).unwrap().is_empty());
    let stats = unpack_into(&store, &pack, 4).unwrap();
    assert_eq!((stats.objects, stats.raw_bytes), (0, 0));
}

#[test]
fn single_object_roundtrips() {
    let td_a = TempDir::new("pf-one-a").unwrap();
    let td_b = TempDir::new("pf-one-b").unwrap();
    let mut rng = Pcg64::new(7);
    let (a, oids) = seeded_store(&td_a, &mut rng, &[1234]);
    let b = LfsStore::open(td_b.path());
    let pack = build_pack(&a, &oids, 1).unwrap();
    assert_eq!(pack_index(&pack).unwrap(), vec![(oids[0], 1234)]);
    unpack_into(&b, &pack, 1).unwrap();
    assert_eq!(b.get(&oids[0]).unwrap(), a.get(&oids[0]).unwrap());
}

#[test]
fn hundred_objects_roundtrip() {
    let td_a = TempDir::new("pf-100-a").unwrap();
    let td_b = TempDir::new("pf-100-b").unwrap();
    let mut rng = Pcg64::new(8);
    let sizes: Vec<usize> = (0..100).map(|i| i * 37 % 5000).collect(); // incl. size 0
    let (a, oids) = seeded_store(&td_a, &mut rng, &sizes);
    let b = LfsStore::open(td_b.path());
    let pack = build_pack(&a, &oids, 8).unwrap();
    let stats = unpack_into(&b, &pack, 8).unwrap();
    assert_eq!(stats.objects, oids.len());
    for oid in &oids {
        assert_eq!(b.get(oid).unwrap(), a.get(oid).unwrap());
    }
}

#[test]
fn roundtrip_property_random_shapes() {
    check(
        "pack roundtrip",
        |rng| {
            let n = gens::usize_in(rng, 0, 12);
            (0..n).map(|_| gens::usize_in(rng, 0, 3000)).collect::<Vec<usize>>()
        },
        |sizes| {
            let td_a = TempDir::new("pf-prop-a").map_err(|e| e.to_string())?;
            let td_b = TempDir::new("pf-prop-b").map_err(|e| e.to_string())?;
            let mut rng = Pcg64::new(sizes.iter().sum::<usize>() as u64 + 1);
            let (a, oids) = seeded_store(&td_a, &mut rng, sizes);
            let b = LfsStore::open(td_b.path());
            let pack = build_pack(&a, &oids, 4).map_err(|e| format!("{e:#}"))?;
            unpack_into(&b, &pack, 4).map_err(|e| format!("{e:#}"))?;
            for oid in &oids {
                if b.get(oid).map_err(|e| format!("{e:#}"))?
                    != a.get(oid).map_err(|e| format!("{e:#}"))?
                {
                    return Err(format!("object {} did not roundtrip", oid.short()));
                }
            }
            Ok(())
        },
    );
}

/// Audit of the delta planner's worth-it gate over random
/// near-duplicate tensors: every *kept* delta must undercut the
/// **compressed** full-record wire size by the gate's 10% margin
/// (a comparison against the raw object length would keep deltas that
/// inflate the wire), the resulting v2 pack never exceeds the flat
/// pack, and a receiver holding the bases reconstructs byte-identical
/// objects.
#[test]
fn delta_gate_compares_compressed_wire_sizes() {
    check(
        "delta worth-it gate",
        |rng| {
            let groups = gens::usize_in(rng, 1, 5);
            let elems = gens::usize_in(rng, 256, 4096);
            // How much of each base the near-duplicate keeps, in
            // eighths: low values should mostly demote (the delta is
            // not worth it), high values should mostly keep.
            let kept_eighths = gens::usize_in(rng, 1, 7);
            (groups, elems, kept_eighths, rng.next_u64())
        },
        |&(groups, elems, kept_eighths, seed)| {
            let mut rng = Pcg64::new(seed);
            let td_src = TempDir::new("pf-gate-src").map_err(|e| e.to_string())?;
            let src = LfsStore::open(td_src.path());
            let mut base_of = std::collections::HashMap::new();
            let mut bases = Vec::new();
            let mut targets = Vec::new();
            for _ in 0..groups {
                let len = elems * 4;
                let base = random_payload(&mut rng, len);
                let mut target = base.clone();
                for b in &mut target[len * kept_eighths / 8..] {
                    *b = rng.below(256) as u8;
                }
                let (b_oid, _) = src.put(&base).unwrap();
                let (t_oid, _) = src.put(&target).unwrap();
                if b_oid == t_oid {
                    continue; // the mutation happened to be identity
                }
                base_of.insert(t_oid, (b_oid, KIND_STORE));
                bases.push(base);
                targets.push((t_oid, target));
            }
            let want: Vec<Oid> = targets.iter().map(|(o, _)| *o).collect();
            let plan = plan_deltas(&src, &want, &base_of, 2).map_err(|e| format!("{e:#}"))?;

            // The gate's promise, per kept record: delta payload bytes
            // (32-byte base oid + compressed ops) undercut the zstd-
            // compressed full payload by >= 10%.
            for d in &plan.deltas {
                let full_cost = full_record_cost(&src, &d.oid).map_err(|e| format!("{e:#}"))?;
                if d.wire_cost() - 48 >= (full_cost - 48) * 9 / 10 {
                    return Err(format!(
                        "kept delta {} does not undercut the compressed full record: \
                         delta wire {} vs full wire {}",
                        d.oid.short(),
                        d.wire_cost(),
                        full_cost
                    ));
                }
            }

            // Whatever the plan decided, the v2 pack must not exceed
            // the flat pack for the same want set...
            let td_packs = TempDir::new("pf-gate-packs").map_err(|e| e.to_string())?;
            let delta_path = td_packs.join("delta.pack");
            let built =
                write_delta_pack_file(&src, &plan, 2, &delta_path).map_err(|e| format!("{e:#}"))?;
            let flat = build_pack(&src, &want, 2).map_err(|e| format!("{e:#}"))?;
            if built.len > flat.len() as u64 {
                return Err(format!(
                    "delta pack ({} bytes) exceeds the flat pack ({} bytes)",
                    built.len,
                    flat.len()
                ));
            }

            // ...and a receiver holding the bases must reconstruct
            // byte-identical objects.
            let td_dst = TempDir::new("pf-gate-dst").map_err(|e| e.to_string())?;
            let dst = LfsStore::open(td_dst.path());
            for base in &bases {
                dst.put(base).unwrap();
            }
            let pack_check = PackCheck {
                id: built.id,
                len: built.len,
                objects: built.objects as u64,
            };
            unpack_verified(&delta_path, &dst, 2, &pack_check).map_err(|e| format!("{e:#}"))?;
            for (oid, payload) in &targets {
                if &dst.get(oid).map_err(|e| format!("{e:#}"))? != payload {
                    return Err(format!("object {} did not roundtrip", oid.short()));
                }
            }
            Ok(())
        },
    );
}

#[test]
fn corrupted_trailer_is_detected() {
    let td = TempDir::new("pf-corrupt").unwrap();
    let mut rng = Pcg64::new(9);
    let (store, oids) = seeded_store(&td, &mut rng, &[500, 900]);
    let pack = build_pack(&store, &oids, 1).unwrap();
    let dst_td = TempDir::new("pf-corrupt-dst").unwrap();
    let dst = LfsStore::open(dst_td.path());

    // The trailing 40 bytes are index offset + sha256: every flip there
    // must be rejected, as must a flip in the index region before it.
    for back in 1..=48 {
        let mut bad = pack.clone();
        let at = pack.len() - back;
        bad[at] ^= 0x01;
        assert!(
            unpack_into(&dst, &bad, 1).is_err(),
            "flip {back} bytes from the end went undetected"
        );
    }
}

#[test]
fn any_bit_flip_is_detected() {
    check(
        "pack bit-flip detection",
        |rng| gens::usize_in(rng, 0, 1_000_000),
        |&pos_seed| {
            let td = TempDir::new("pf-flip").map_err(|e| e.to_string())?;
            let mut rng = Pcg64::new(11);
            let (store, oids) = seeded_store(&td, &mut rng, &[64, 256]);
            let pack = build_pack(&store, &oids, 1).map_err(|e| format!("{e:#}"))?;
            let at = pos_seed % pack.len();
            let mut bad = pack.clone();
            bad[at] ^= 0x80;
            let dst_td = TempDir::new("pf-flip-dst").map_err(|e| e.to_string())?;
            let dst = LfsStore::open(dst_td.path());
            match unpack_into(&dst, &bad, 1) {
                Err(_) => Ok(()),
                Ok(_) => Err(format!("bit flip at byte {at} of {} accepted", pack.len())),
            }
        },
    );
}

#[test]
fn truncation_is_detected() {
    let td = TempDir::new("pf-trunc").unwrap();
    let mut rng = Pcg64::new(12);
    let (store, oids) = seeded_store(&td, &mut rng, &[2000]);
    let pack = build_pack(&store, &oids, 1).unwrap();
    let dst_td = TempDir::new("pf-trunc-dst").unwrap();
    let dst = LfsStore::open(dst_td.path());
    for keep in [0, 3, 15, 56, pack.len() / 2, pack.len() - 1] {
        assert!(
            unpack_into(&dst, &pack[..keep], 1).is_err(),
            "truncation to {keep} bytes went undetected"
        );
    }
}
