//! Property-based tests over coordinator invariants (proptest
//! substitute: util::prop over a seeded PCG64).

use git_theta::checkpoint::{Checkpoint, CheckpointFormat, NativeFormat, SafetensorsFormat};
use git_theta::lfs::LfsStore;
use git_theta::tensor::{allclose, DType, Tensor};
use git_theta::theta::filter::{clean_checkpoint, smudge_metadata, ObjectAccess};
use git_theta::theta::lsh::LshSignature;
use git_theta::theta::metadata::ModelMetadata;
use git_theta::theta::updates::{infer_best, update_type};
use git_theta::util::json::Json;
use git_theta::util::msgpack::Mp;
use git_theta::util::prop::{check, gens};
use git_theta::util::rng::Pcg64;
use git_theta::util::tmp::TempDir;

fn random_checkpoint(rng: &mut Pcg64) -> Checkpoint {
    let groups = gens::usize_in(rng, 1, 6);
    let mut ck = Checkpoint::new();
    for g in 0..groups {
        let shape = gens::shape(rng, 2, 512);
        let n: usize = shape.iter().product();
        let vals = gens::f32_vec(rng, n, 0.5);
        let dtype = if rng.below(4) == 0 {
            DType::BF16
        } else {
            DType::F32
        };
        let t = Tensor::from_f32(shape, vals).unwrap().cast(dtype).unwrap();
        ck.insert(format!("g{g}"), t);
    }
    ck
}

#[test]
fn prop_clean_smudge_identity() {
    check(
        "clean∘smudge = identity",
        random_checkpoint,
        |ck| {
            let td = TempDir::new("prop").map_err(|e| e.to_string())?;
            let acc = ObjectAccess {
                store: LfsStore::open(td.path()),
                remote: None,
            };
            let meta = clean_checkpoint(&acc, ck, "safetensors", None, None, 2)
                .map_err(|e| format!("{e:#}"))?;
            let back = smudge_metadata(&acc, &meta, 2).map_err(|e| format!("{e:#}"))?;
            if back == *ck {
                Ok(())
            } else {
                Err("smudge(clean(ck)) != ck".into())
            }
        },
    );
}

#[test]
fn prop_incremental_clean_smudge_identity() {
    check(
        "incremental clean∘smudge = identity",
        |rng| {
            let ck = random_checkpoint(rng);
            // Derive a second version with random per-group edit kinds.
            let mut ck2 = ck.clone();
            let names: Vec<String> = ck.names().cloned().collect();
            for name in &names {
                match rng.below(4) {
                    0 => {} // unchanged
                    1 => {
                        // sparse edit
                        let t = ck2.get(name).unwrap().clone();
                        let mut v = t.to_f32_vec().unwrap();
                        let k = gens::usize_in(rng, 1, v.len().min(5));
                        for i in rng.choose_indices(v.len(), k) {
                            v[i] = rng.next_f32();
                        }
                        ck2.insert(
                            name.clone(),
                            Tensor::from_f32_as(t.dtype(), t.shape().to_vec(), &v).unwrap(),
                        );
                    }
                    2 => {
                        // full replace
                        let t = ck2.get(name).unwrap().clone();
                        let v = gens::f32_vec(rng, t.numel(), 0.5);
                        ck2.insert(
                            name.clone(),
                            Tensor::from_f32_as(t.dtype(), t.shape().to_vec(), &v).unwrap(),
                        );
                    }
                    _ => {
                        // trim first axis (when possible)
                        let t = ck2.get(name).unwrap().clone();
                        if t.shape()[0] > 1 {
                            ck2.insert(name.clone(), t.take_rows(t.shape()[0] - 1).unwrap());
                        }
                    }
                }
            }
            (ck, ck2)
        },
        |(ck, ck2)| {
            let td = TempDir::new("prop2").map_err(|e| e.to_string())?;
            let acc = ObjectAccess {
                store: LfsStore::open(td.path()),
                remote: None,
            };
            let v1 = clean_checkpoint(&acc, ck, "safetensors", None, None, 2)
                .map_err(|e| format!("{e:#}"))?;
            let v2 = clean_checkpoint(&acc, ck2, "safetensors", Some(&v1), None, 2)
                .map_err(|e| format!("{e:#}"))?;
            let b2 = smudge_metadata(&acc, &v2, 2).map_err(|e| format!("{e:#}"))?;
            let b1 = smudge_metadata(&acc, &v1, 2).map_err(|e| format!("{e:#}"))?;
            // Exact for v1; v2 must be allclose (low-rank inference may
            // introduce sub-1e-6 noise by design) and usually exact.
            if b1 != *ck {
                return Err("v1 mismatch".into());
            }
            for (name, t) in ck2.iter() {
                let r = b2.get(name).ok_or(format!("missing {name}"))?;
                if r.shape() != t.shape() {
                    return Err(format!("{name} shape mismatch"));
                }
                if !(r == t || allclose(r, t, 1e-5, 1e-6).unwrap_or(false)) {
                    return Err(format!("{name} values mismatch"));
                }
            }
            Ok(())
        },
    );
}

#[test]
fn prop_update_infer_apply_identity() {
    check(
        "infer∘apply = identity (per update type)",
        |rng| {
            let shape = vec![gens::usize_in(rng, 2, 24), gens::usize_in(rng, 2, 24)];
            let n: usize = shape.iter().product();
            let prev = Tensor::from_f32(shape.clone(), gens::f32_vec(rng, n, 0.5)).unwrap();
            let mut v = prev.to_f32_vec().unwrap();
            let k = gens::usize_in(rng, 1, (n / 5).max(1));
            for i in rng.choose_indices(n, k) {
                v[i] = rng.next_f32();
            }
            let new = Tensor::from_f32(shape, v).unwrap();
            (prev, new)
        },
        |(prev, new)| {
            let payload = infer_best(Some(prev), new, None).map_err(|e| format!("{e:#}"))?;
            let u = update_type(&payload.kind).ok_or("unknown type")?;
            let recon = u
                .apply(&payload, Some(prev))
                .map_err(|e| format!("{e:#}"))?;
            if recon == *new || allclose(&recon, new, 1e-5, 1e-6).unwrap_or(false) {
                Ok(())
            } else {
                Err(format!("{} reconstruction mismatch", payload.kind))
            }
        },
    );
}

#[test]
fn prop_lsh_noise_invariance() {
    check(
        "LSH signature invariant under <=1e-9 L2 noise",
        |rng| {
            let n = gens::usize_in(rng, 100, 20_000);
            gens::f32_vec(rng, n, 0.2)
        },
        |v| {
            let mut w = v.clone();
            let per = 1e-9f32 / (w.len() as f32).sqrt();
            for x in w.iter_mut() {
                *x += per;
            }
            let a = LshSignature::of_values(v);
            let b = LshSignature::of_values(&w);
            if a.buckets == b.buckets {
                Ok(())
            } else {
                Err("buckets differ under noise".into())
            }
        },
    );
}

#[test]
fn prop_checkpoint_format_roundtrip() {
    check(
        "checkpoint save/load = identity (both formats)",
        random_checkpoint,
        |ck| {
            for fmt in [
                &SafetensorsFormat as &dyn CheckpointFormat,
                &NativeFormat as &dyn CheckpointFormat,
            ] {
                let bytes = fmt.save_bytes(ck).map_err(|e| format!("{e:#}"))?;
                let back = fmt.load_bytes(&bytes).map_err(|e| format!("{e:#}"))?;
                if back != *ck {
                    return Err(format!("{} roundtrip mismatch", fmt.name()));
                }
            }
            Ok(())
        },
    );
}

#[test]
fn prop_msgpack_json_fuzz_roundtrip() {
    check(
        "msgpack/json value roundtrips",
        |rng| {
            fn gen_value(rng: &mut Pcg64, depth: usize) -> Mp {
                let roll = if depth > 2 { rng.below(6) } else { rng.below(8) };
                match roll {
                    0 => Mp::Nil,
                    1 => Mp::Bool(rng.below(2) == 0),
                    2 => Mp::Int(-(rng.below(1 << 40) as i64) - 1),
                    3 => Mp::UInt(rng.next_u64()),
                    4 => Mp::F64(rng.next_f64()),
                    5 => Mp::Str(gens::ascii_string(rng, 40)),
                    6 => Mp::Arr((0..rng.below(5)).map(|_| gen_value(rng, depth + 1)).collect()),
                    _ => Mp::Map(
                        (0..rng.below(5))
                            .map(|i| (format!("k{i}"), gen_value(rng, depth + 1)))
                            .collect(),
                    ),
                }
            }
            gen_value(rng, 0)
        },
        |v| {
            let enc = v.encode();
            let dec = Mp::decode(&enc).map_err(|e| e.to_string())?;
            if dec != *v {
                return Err("msgpack mismatch".into());
            }
            Ok(())
        },
    );
}

#[test]
fn prop_metadata_roundtrip() {
    check(
        "metadata to_bytes/from_bytes = identity",
        |rng| {
            let ck = random_checkpoint(rng);
            let td = TempDir::new("meta").unwrap();
            let acc = ObjectAccess {
                store: LfsStore::open(td.path()),
                remote: None,
            };
            clean_checkpoint(&acc, &ck, "safetensors", None, None, 1).unwrap()
        },
        |meta| {
            let bytes = meta.to_bytes();
            let back = ModelMetadata::from_bytes(&bytes).map_err(|e| format!("{e:#}"))?;
            if back == *meta {
                Ok(())
            } else {
                Err("metadata roundtrip mismatch".into())
            }
        },
    );
}

#[test]
fn prop_json_number_precision() {
    check(
        "json roundtrips f64 projections",
        |rng| (0..16).map(|_| rng.next_gaussian() * 1e-5).collect::<Vec<f64>>(),
        |vals| {
            let json = Json::Arr(vals.iter().map(|&v| Json::Num(v)).collect());
            let text = json.to_string_compact();
            let back = Json::parse(&text).map_err(|e| e.to_string())?;
            let arr = back.as_arr().ok_or("not arr")?;
            for (a, b) in vals.iter().zip(arr) {
                let b = b.as_f64().ok_or("not num")?;
                if *a != b {
                    return Err(format!("{a} != {b}"));
                }
            }
            Ok(())
        },
    );
}
