//! Kernel-backed numeric hot paths with pure-Rust fallbacks.
//!
//! Each operation here has two implementations: the AOT-compiled
//! JAX/Pallas kernel (loaded through [`crate::runtime`] when the
//! artifact exists) and a pure-Rust reference. The Rust paths are the
//! *defaults* for LSH because signatures stored in metadata must be
//! bit-deterministic across machines regardless of artifact presence;
//! the kernel paths are used by the training/eval driver (Figure 3),
//! the benchmark harness, and integration tests that cross-check the
//! two implementations.

use crate::runtime::Runtime;
use crate::tensor::{weighted_average, Tensor};
use crate::theta::lsh::{self, NUM_HASHES, POOL_SIZE};
use anyhow::{bail, Context, Result};

/// Rows per LSH kernel block: the artifact is lowered for a fixed
/// (LSH_BLOCK_ROWS × POOL_SIZE) input tile.
pub const LSH_BLOCK_ROWS: usize = 64;

/// Pooled LSH projection through the Pallas kernel
/// (`artifacts/lsh_project.hlo.txt`). Input is zero-padded to whole
/// blocks; per-block partial projections are summed in f64 in Rust.
pub fn lsh_project_kernel(values: &[f32]) -> Result<[f64; NUM_HASHES]> {
    let rt = Runtime::global()?;
    if !rt.available("lsh_project") {
        bail!("artifact 'lsh_project' not built (run `make artifacts`)");
    }
    let params = lsh::params();
    let pool = Tensor::from_f32(vec![POOL_SIZE, NUM_HASHES], params.pool.clone())?;

    let block_elems = LSH_BLOCK_ROWS * POOL_SIZE;
    let mut acc = [0f64; NUM_HASHES];
    let mut offset = 0;
    while offset < values.len() {
        let take = (values.len() - offset).min(block_elems);
        let mut block = vec![0f32; block_elems];
        block[..take].copy_from_slice(&values[offset..offset + take]);
        let x = Tensor::from_f32(vec![LSH_BLOCK_ROWS, POOL_SIZE], block)?;
        let out = rt.execute("lsh_project", &[&x, &pool])?;
        let proj = out
            .first()
            .context("lsh_project returned no output")?
            .to_f32_vec()?;
        for j in 0..NUM_HASHES {
            acc[j] += proj[j] as f64;
        }
        offset += take;
    }
    Ok(acc)
}

/// LSH projection: kernel when `THETA_KERNEL_LSH=1` and available,
/// otherwise the deterministic Rust path.
pub fn lsh_project(values: &[f32]) -> [f64; NUM_HASHES] {
    if std::env::var("THETA_KERNEL_LSH").as_deref() == Ok("1") {
        if let Ok(p) = lsh_project_kernel(values) {
            return p;
        }
    }
    lsh::project(values)
}

/// Parameter averaging through the Pallas kernel
/// (`artifacts/param_average.hlo.txt`), block-processed; falls back to
/// the Rust implementation when the artifact is missing.
pub fn average_pair(a: &Tensor, b: &Tensor) -> Result<Tensor> {
    let rt = Runtime::global();
    if let Ok(rt) = rt {
        if rt.available("param_average") && a.dtype() == crate::tensor::DType::F32 {
            return average_pair_kernel(&rt, a, b);
        }
    }
    Ok(weighted_average(&[a, b], &[1.0, 1.0])?)
}

/// Block size the param_average artifact is lowered for.
pub const AVG_BLOCK: usize = 1 << 20;

fn average_pair_kernel(rt: &Runtime, a: &Tensor, b: &Tensor) -> Result<Tensor> {
    if a.shape() != b.shape() {
        bail!("average: shape mismatch {:?} vs {:?}", a.shape(), b.shape());
    }
    let av = a.to_f32_vec()?;
    let bv = b.to_f32_vec()?;
    let mut out = Vec::with_capacity(av.len());
    let mut offset = 0;
    while offset < av.len() {
        let take = (av.len() - offset).min(AVG_BLOCK);
        let mut xa = vec![0f32; AVG_BLOCK];
        let mut xb = vec![0f32; AVG_BLOCK];
        xa[..take].copy_from_slice(&av[offset..offset + take]);
        xb[..take].copy_from_slice(&bv[offset..offset + take]);
        let ta = Tensor::from_f32(vec![AVG_BLOCK], xa)?;
        let tb = Tensor::from_f32(vec![AVG_BLOCK], xb)?;
        let res = rt.execute("param_average", &[&ta, &tb])?;
        let r = res
            .first()
            .context("param_average returned no output")?
            .to_f32_vec()?;
        out.extend_from_slice(&r[..take]);
        offset += take;
    }
    Ok(Tensor::from_f32(a.shape().to_vec(), out)?)
}

/// LoRA application W' = W + (α/r)·A@B through the Pallas kernel when an
/// artifact for this (m, n, r) exists (`lora_apply_{m}x{n}x{r}`);
/// otherwise the exact Rust fallback.
pub fn lora_apply(w: &Tensor, a: &Tensor, b: &Tensor, alpha: f32) -> Result<Tensor> {
    let (m, n) = match w.shape() {
        [m, n] => (*m, *n),
        s => bail!("lora_apply expects a 2-D weight, got {s:?}"),
    };
    let r = a.shape().get(1).copied().unwrap_or(0);
    if a.shape() != [m, r] || b.shape() != [r, n] {
        bail!(
            "lora_apply shape mismatch: w {:?}, a {:?}, b {:?}",
            w.shape(),
            a.shape(),
            b.shape()
        );
    }
    if let Ok(rt) = Runtime::global() {
        let name = format!("lora_apply_{m}x{n}x{r}");
        if rt.available(&name) {
            let alpha_t = Tensor::from_f32(vec![], vec![alpha])?;
            let out = rt.execute(&name, &[w, a, b, &alpha_t])?;
            return out.into_iter().next().context("lora_apply returned no output");
        }
    }
    lora_apply_rust(w, a, b, alpha, m, n, r)
}

/// Pure-Rust LoRA application (also the cross-check oracle).
pub fn lora_apply_rust(
    w: &Tensor,
    a: &Tensor,
    b: &Tensor,
    alpha: f32,
    m: usize,
    n: usize,
    r: usize,
) -> Result<Tensor> {
    let wv = w.to_f32_vec()?;
    let av = a.to_f32_vec()?;
    let bv = b.to_f32_vec()?;
    let scale = if r > 0 { alpha / r as f32 } else { 0.0 };
    let mut out = vec![0f32; m * n];
    for i in 0..m {
        let arow = &av[i * r..(i + 1) * r];
        for j in 0..n {
            let mut acc = 0f32;
            for (k, &ak) in arow.iter().enumerate() {
                acc += ak * bv[k * n + j];
            }
            out[i * n + j] = wv[i * n + j] + scale * acc;
        }
    }
    Ok(Tensor::from_f32_as(w.dtype(), w.shape().to_vec(), &out)?)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Pcg64;

    fn random(seed: u64, shape: Vec<usize>) -> Tensor {
        let mut rng = Pcg64::new(seed);
        let n: usize = shape.iter().product();
        let vals: Vec<f32> = (0..n).map(|_| (rng.next_f32() - 0.5) * 0.2).collect();
        Tensor::from_f32(shape, vals).unwrap()
    }

    #[test]
    fn lsh_project_default_matches_reference() {
        let t = random(1, vec![10_000]);
        let v = t.to_f32_vec().unwrap();
        assert_eq!(lsh_project(&v), lsh::project(&v));
    }

    #[test]
    fn average_pair_fallback_correct() {
        let a = random(2, vec![100]);
        let b = random(3, vec![100]);
        let avg = average_pair(&a, &b).unwrap();
        let av = a.to_f32_vec().unwrap();
        let bv = b.to_f32_vec().unwrap();
        let got = avg.to_f32_vec().unwrap();
        for i in 0..100 {
            assert!((got[i] - (av[i] + bv[i]) / 2.0).abs() < 1e-6);
        }
    }

    #[test]
    fn lora_apply_rust_shapes_and_values() {
        let w = random(4, vec![8, 6]);
        let a = Tensor::from_f32(vec![8, 2], vec![1.0; 16]).unwrap();
        let b = Tensor::from_f32(vec![2, 6], vec![0.5; 12]).unwrap();
        let out = lora_apply(&w, &a, &b, 2.0).unwrap();
        let wv = w.to_f32_vec().unwrap();
        let ov = out.to_f32_vec().unwrap();
        // delta = (2.0/2) * sum_k 1.0*0.5 = 1.0
        for i in 0..48 {
            assert!((ov[i] - (wv[i] + 1.0)).abs() < 1e-6);
        }
        // Shape mismatches rejected.
        assert!(lora_apply(&w, &b, &a, 1.0).is_err());
    }
}
