//! The `git-theta` command-line interface.
//!
//! Hand-rolled subcommand parser (no clap in the offline vendor set).
//! Mirrors the Git workflow from the paper:
//!
//! ```text
//! git-theta init
//! git-theta track model.safetensors      # paper: git theta track
//! git-theta lfs-track '*.bin'            # baseline: whole-blob LFS
//! git-theta add model.safetensors
//! git-theta commit -m "Train on CB with LoRA"
//! git-theta branch rte && git-theta checkout rte
//! git-theta merge rte --strategy average
//! git-theta diff HEAD~ HEAD              # parameter-group diff
//! git-theta push /path/to/remote main
//! ```

use crate::gitcore::drivers::MergeOptions;
use crate::gitcore::remote::RemoteSpec;
use crate::gitcore::repo::Repository;
use crate::util::humansize;
use anyhow::{bail, Context, Result};
use std::path::{Path, PathBuf};

/// Entry point: parse args, dispatch, map errors to exit codes.
pub fn run() -> i32 {
    let args: Vec<String> = std::env::args().skip(1).collect();
    match dispatch(&args) {
        Ok(()) => 0,
        Err(e) => {
            eprintln!("error: {e:#}");
            1
        }
    }
}

/// Dispatch a parsed argument vector (testable without a process).
pub fn dispatch(args: &[String]) -> Result<()> {
    let cmd = args.first().map(|s| s.as_str()).unwrap_or("help");
    let rest = &args[1.min(args.len())..];
    match cmd {
        "init" => cmd_init(rest),
        "track" => cmd_track(rest),
        "lfs-track" => cmd_lfs_track(rest),
        "add" => cmd_add(rest),
        "commit" => cmd_commit(rest),
        "status" => cmd_status(rest),
        "log" => cmd_log(rest),
        "diff" => cmd_diff(rest),
        "checkout" => cmd_checkout(rest),
        "branch" => cmd_branch(rest),
        "merge" => cmd_merge(rest),
        "push" => cmd_push(rest),
        "fetch" => cmd_fetch(rest),
        "pull" => cmd_pull(rest),
        "clone" => cmd_clone(rest),
        "replicate" => cmd_replicate(rest),
        "config" => cmd_config(rest),
        "serve" => cmd_serve(rest),
        "snapshot" => cmd_snapshot(rest),
        "gc" => cmd_gc(rest),
        "fsck" => cmd_fsck(rest),
        "bench" => crate::benchkit::cli_bench(rest),
        "help" | "--help" | "-h" => {
            println!("{}", usage());
            Ok(())
        }
        other => bail!("unknown command '{other}'\n{}", usage()),
    }
}

fn usage() -> &'static str {
    "git-theta — version control for ML models (Git-Theta reproduction)

USAGE:
  git-theta <command> [args]

COMMANDS:
  init [dir]                     create a repository
  track <pattern>                track a checkpoint with Git-Theta
  lfs-track <pattern>            track a file with plain LFS (baseline)
  add <paths...>                 stage files (runs clean filters)
  commit -m <msg> [--author a]   commit the index
  status                         working tree status
  log                            commit history
  diff [--exact] [<rev> [<rev>]] diff (parameter-group aware; --exact
                                 reconstructs changed groups for true L2)
  checkout <rev|branch>          switch revisions (runs smudge filters)
  branch [<name>]                list or create branches
  merge <branch> [--strategy s] [--group glob=s] [--verbose]
                                 merge a branch (s: average|us|them|
                                 ancestor|weighted|fisher); --verbose
                                 prints merge-engine statistics
  push <remote> [branch] [--pack|--per-object]
                                 push commits + LFS objects (packed by default);
                                 <remote> is a directory, http://host:port, or a
                                 comma-separated replica set of mirrors (pushes
                                 fan out and succeed at theta.replica-quorum)
  fetch <remote> [branch]        fetch commits + prefetch model objects as one
                                 pack (interrupted pack transfers resume; a
                                 replica set serves from its healthiest mirror
                                 and fails over mid-pack)
  pull <remote> [branch]         pull commits + metadata
  clone <remote> <dir>           clone a remote (directory, http://, or a
                                 replica set)
  replicate [--repair] [remote] [branch]
                                 show replica-set mirror status; --repair runs
                                 the anti-entropy pass (ships objects mirrors
                                 missed and fast-forwards lagging branch tips)
  serve <root-dir> [--port N] [--bind HOST]
                                 serve a remote root over http (LFS batch
                                 protocol + resumable packs + commit/ref sync;
                                 binds loopback unless --bind says otherwise)
  config <key> [<value>]         get/set repo config (e.g. remote,
                                 theta.snapshot-depth; theta.gc-report
                                 off silences post-snapshot/merge gc
                                 dry-run reports; theta.gc-auto on prunes
                                 those orphans automatically;
                                 theta.replica-quorum N sets the replica
                                 write quorum, default all mirrors)
  snapshot <path...>             re-anchor tracked models as dense entries
                                 (bounds checkout chain depth; then commit)
  gc [--prune]                   report LFS objects no branch, HEAD, or the
                                 index references (--prune deletes them)
  fsck                           verify object stores
  bench <name>                   run paper benchmarks (see `bench help`)"
}

fn open_repo() -> Result<Repository> {
    crate::init();
    Repository::discover(Path::new("."))
}

/// Print a one-line gc dry-run summary after commands that typically
/// orphan store objects (snapshot re-anchoring, merge resolutions).
/// Prints nothing when the store is clean, and never fails the parent
/// command. Silenced by setting the `theta.gc-report` config key to
/// `off`, `false`, or `0`. With `theta.gc-auto` set to `on`, `true`,
/// or `1` the orphans are pruned on the spot instead of just reported
/// — under the same plan-instant safety rule as `gc --prune`: an
/// orphan a concurrent put re-stores after the plan was computed is
/// spared, never deleted.
fn maybe_print_gc_report(repo: &Repository) {
    match repo.config_get("theta.gc-report") {
        Ok(Some(v)) if matches!(v.trim(), "off" | "false" | "0") => return,
        Err(_) => return,
        _ => {}
    }
    let Ok((mut report, started)) = crate::theta::plan_garbage(repo) else {
        return;
    };
    if report.orphaned.is_empty() {
        return;
    }
    if gc_auto_enabled(repo) {
        if auto_prune_planned(repo, &mut report, started).is_err() {
            return;
        }
        println!(
            "gc: auto-pruned {} orphaned object(s), freed {}{} \
             (disable with `git-theta config theta.gc-auto off`)",
            report.orphaned.len(),
            humansize::bytes(report.orphaned_bytes),
            if report.spared > 0 {
                format!("; spared {} concurrently re-stored", report.spared)
            } else {
                String::new()
            }
        );
        return;
    }
    println!(
        "gc: {} orphaned object(s) holding {}; `git-theta gc --prune` reclaims them \
         (silence with `git-theta config theta.gc-report off`)",
        report.orphaned.len(),
        humansize::bytes(report.orphaned_bytes)
    );
}

/// Whether `theta.gc-auto` opts this repo into pruning right after the
/// post-snapshot/merge report.
fn gc_auto_enabled(repo: &Repository) -> bool {
    matches!(
        repo.config_get("theta.gc-auto")
            .ok()
            .flatten()
            .as_deref()
            .map(str::trim),
        Some("on" | "true" | "1")
    )
}

/// Prune a computed gc plan (the `theta.gc-auto` action), preserving
/// the plan-instant spare rule. Split out so tests can interleave a
/// racing `put` between the plan and the prune.
fn auto_prune_planned(
    repo: &Repository,
    report: &mut crate::theta::GcReport,
    started: std::time::SystemTime,
) -> Result<()> {
    let store = crate::lfs::LfsStore::open(repo.theta_dir());
    crate::theta::prune_plan(&store, report, started)
}

fn cmd_init(args: &[String]) -> Result<()> {
    let dir = args.first().map(PathBuf::from).unwrap_or_else(|| PathBuf::from("."));
    std::fs::create_dir_all(&dir)?;
    Repository::init(&dir)?;
    println!(
        "initialized empty theta repository in {}",
        dir.join(".theta").display()
    );
    Ok(())
}

fn cmd_track(args: &[String]) -> Result<()> {
    let repo = open_repo()?;
    let pattern = args.first().context("usage: git-theta track <pattern>")?;
    if crate::theta::track(&repo, pattern)? {
        println!("tracking '{pattern}' with git-theta");
    } else {
        println!("'{pattern}' already tracked");
    }
    Ok(())
}

fn cmd_lfs_track(args: &[String]) -> Result<()> {
    let repo = open_repo()?;
    let pattern = args.first().context("usage: git-theta lfs-track <pattern>")?;
    crate::gitcore::attributes::Attributes::add_line(
        repo.worktree(),
        &format!("{pattern} filter=lfs"),
    )?;
    println!("tracking '{pattern}' with lfs");
    Ok(())
}

fn cmd_add(args: &[String]) -> Result<()> {
    if args.is_empty() {
        bail!("usage: git-theta add <paths...>");
    }
    let repo = open_repo()?;
    let paths: Vec<&str> = args.iter().map(|s| s.as_str()).collect();
    repo.add(&paths)?;
    println!("staged {} file(s)", paths.len());
    Ok(())
}

fn cmd_commit(args: &[String]) -> Result<()> {
    let repo = open_repo()?;
    let mut message = None;
    let mut author = "git-theta <theta@localhost>".to_string();
    let mut i = 0;
    while i < args.len() {
        match args[i].as_str() {
            "-m" | "--message" => {
                message = Some(args.get(i + 1).context("-m needs a value")?.clone());
                i += 2;
            }
            "--author" => {
                author = args.get(i + 1).context("--author needs a value")?.clone();
                i += 2;
            }
            other => bail!("unknown commit flag '{other}'"),
        }
    }
    let message = message.context("usage: git-theta commit -m <message>")?;
    let oid = repo.commit(&message, &author)?;
    println!("[{}] {message}", oid.short());
    Ok(())
}

fn cmd_status(_args: &[String]) -> Result<()> {
    let repo = open_repo()?;
    print!("{}", repo.status()?.render());
    Ok(())
}

fn cmd_log(_args: &[String]) -> Result<()> {
    let repo = open_repo()?;
    for (oid, commit) in repo.log()? {
        let merge = if commit.parents.len() > 1 {
            " (merge)"
        } else {
            ""
        };
        println!("commit {}{merge}", oid.short());
        println!("  author: {}", commit.author);
        println!("  {}", commit.message.lines().next().unwrap_or(""));
    }
    Ok(())
}

fn cmd_diff(args: &[String]) -> Result<()> {
    let repo = open_repo()?;
    let resolve_rev = |rev: &str| -> Result<crate::gitcore::object::Oid> {
        if let Some(stripped) = rev.strip_suffix('~') {
            let base = repo.resolve(if stripped.is_empty() { "HEAD" } else { stripped })?;
            let commit = repo.odb().read_commit(&base)?;
            return commit
                .parents
                .first()
                .copied()
                .context("revision has no parent");
        }
        repo.resolve(rev)
    };
    let mut exact = false;
    let mut revs: Vec<&String> = Vec::new();
    for arg in args {
        match arg.as_str() {
            "--exact" => exact = true,
            other if other.starts_with("--") => bail!("unknown diff flag '{other}'"),
            _ => revs.push(arg),
        }
    }
    let (old, new) = match revs.len() {
        0 => (None, None), // HEAD vs index
        1 => (Some(resolve_rev(revs[0])?), None),
        _ => (Some(resolve_rev(revs[0])?), Some(resolve_rev(revs[1])?)),
    };
    // The exact toggle is process-global (the diff-driver registry has
    // no option channel); scope it to exactly this invocation.
    crate::theta::diff::set_exact_diff(exact);
    let result = repo.diff(old, new);
    crate::theta::diff::set_exact_diff(false);
    print!("{}", result?);
    Ok(())
}

fn cmd_checkout(args: &[String]) -> Result<()> {
    let repo = open_repo()?;
    let target = args.first().context("usage: git-theta checkout <rev>")?;
    repo.checkout(target)?;
    println!("checked out '{target}'");
    Ok(())
}

fn cmd_branch(args: &[String]) -> Result<()> {
    let repo = open_repo()?;
    match args.first() {
        Some(name) => {
            repo.create_branch(name)?;
            println!("created branch '{name}'");
        }
        None => {
            let head = repo.refs().head()?;
            for (name, oid) in repo.refs().branches()? {
                let marker = match &head {
                    crate::gitcore::refs::Head::Branch(b) if *b == name => "*",
                    _ => " ",
                };
                println!("{marker} {name} {}", oid.short());
            }
        }
    }
    Ok(())
}

fn cmd_merge(args: &[String]) -> Result<()> {
    let repo = open_repo()?;
    let branch = args.first().context("usage: git-theta merge <branch>")?;
    let mut opts = MergeOptions::default();
    let mut i = 1;
    while i < args.len() {
        match args[i].as_str() {
            "--strategy" | "-s" => {
                opts.strategy = Some(args.get(i + 1).context("--strategy needs a value")?.clone());
                i += 2;
            }
            "--group" | "-g" => {
                let spec = args.get(i + 1).context("--group needs glob=strategy")?;
                let (glob, strat) = spec
                    .split_once('=')
                    .context("--group format is <glob>=<strategy>")?;
                opts.per_group.push((glob.to_string(), strat.to_string()));
                i += 2;
            }
            "--verbose" | "-v" => {
                opts.verbose = true;
                i += 1;
            }
            other => bail!("unknown merge flag '{other}'"),
        }
    }
    let report = repo.merge(branch, &opts, "git-theta <theta@localhost>")?;
    if report.already_up_to_date {
        println!("already up to date");
    } else if report.fast_forward {
        println!("fast-forward to {}", report.commit.unwrap().short());
    } else {
        println!("merged '{branch}' -> {}", report.commit.unwrap().short());
        for group in &report.driver_resolved {
            println!("  resolved: {group}");
        }
        // Strategy resolutions that lost to the committed result (and
        // abandoned staging runs) are now orphans; surface them.
        maybe_print_gc_report(&repo);
    }
    Ok(())
}

fn cmd_push(args: &[String]) -> Result<()> {
    let repo = open_repo()?;
    let mut remote = None;
    let mut branch = None;
    let mut per_object = None;
    for arg in args {
        match arg.as_str() {
            // Transfer-engine selection for the LFS sync hooks.
            "--pack" => per_object = Some(false),
            "--per-object" => per_object = Some(true),
            other if other.starts_with("--") => bail!("unknown push flag '{other}'"),
            other if remote.is_none() => remote = Some(other),
            other if branch.is_none() => branch = Some(other),
            other => bail!("unexpected push argument '{other}'"),
        }
    }
    let usage = "usage: git-theta push <remote> [branch] [--pack|--per-object]";
    let remote = remote.context(usage)?;
    let branch = branch.unwrap_or("main");
    let spec = RemoteSpec::parse(remote)?;
    // The engine override is process-global; set it only once argument
    // parsing has succeeded, and scope it to exactly this push.
    crate::lfs::batch::set_per_object_mode(per_object);
    let result = repo.push_spec(&spec, branch);
    crate::lfs::batch::set_per_object_mode(None);
    let report = result?;
    println!(
        "pushed {} commit(s), {} object(s), {}",
        report.commits.len(),
        report.objects_sent,
        humansize::bytes(report.bytes_sent)
    );
    Ok(())
}

fn cmd_fetch(args: &[String]) -> Result<()> {
    let repo = open_repo()?;
    let mut remote = None;
    let mut branch = None;
    for arg in args {
        match arg.as_str() {
            other if other.starts_with("--") => bail!("unknown fetch flag '{other}'"),
            other if remote.is_none() => remote = Some(other),
            other if branch.is_none() => branch = Some(other),
            other => bail!("unexpected fetch argument '{other}'"),
        }
    }
    let remote = remote.context("usage: git-theta fetch <remote> [branch]")?;
    let branch = branch.unwrap_or("main");
    let spec = RemoteSpec::parse(remote)?;

    // Fetching into the checked-out branch would move its ref under a
    // stale index/working tree (a later commit would silently revert
    // the fetched changes), so in that case do what pull does and
    // materialize too. Elsewhere a plain ref + object fetch is safe.
    let on_current_branch =
        repo.refs().head()? == crate::gitcore::refs::Head::Branch(branch.to_string());
    let tip = if on_current_branch {
        repo.pull_spec(&spec, branch)?
    } else {
        repo.fetch_spec(&spec, branch)?
    };
    // Remember the remote (as pull does) so later lazy smudges of
    // revisions outside this tip's chains can still download.
    if repo.config_get("remote")?.is_none() {
        repo.config_set("remote", &spec.to_string())?;
    }

    // Prefetch every LFS object the fetched tip references — model
    // metadata chains and plain LFS pointers alike — in one pack, so a
    // later checkout smudges entirely from the local store. The advert
    // carries the tip's update chains, so a chain-aware remote ships
    // only missing suffixes, as deltas against bases this clone
    // already holds. Over an http remote an interrupted pack resumes
    // on the next fetch.
    let tree = repo.odb().read_tree(&repo.odb().read_commit(&tip)?.tree)?;
    let adv = crate::theta::hooks::fetch_advert(&repo, &tree)?;
    let store = crate::lfs::LfsStore::open(repo.theta_dir());
    let remote = crate::lfs::open_transport(&spec, Some(repo.theta_dir()))?;
    let summary = crate::lfs::fetch_pack_chains(remote.as_ref(), &store, &adv)?;
    if summary.unavailable > 0 {
        eprintln!(
            "warning: remote is missing {} referenced object(s); \
             checkout of revisions needing them will fail",
            summary.unavailable
        );
    }
    println!(
        "'{branch}' is at {}; prefetched {} object(s), {} packed ({} raw)",
        tip.short(),
        summary.objects,
        humansize::bytes(summary.packed_bytes),
        humansize::bytes(summary.raw_bytes)
    );
    Ok(())
}

fn cmd_pull(args: &[String]) -> Result<()> {
    let repo = open_repo()?;
    let remote = args
        .first()
        .context("usage: git-theta pull <remote> [branch]")?;
    let branch = args.get(1).map(|s| s.as_str()).unwrap_or("main");
    let tip = repo.pull_spec(&RemoteSpec::parse(remote)?, branch)?;
    println!("'{branch}' is at {}", tip.short());
    Ok(())
}

fn cmd_clone(args: &[String]) -> Result<()> {
    crate::init();
    let remote = args
        .first()
        .context("usage: git-theta clone <remote> <dir>")?;
    let dir = args.get(1).context("usage: git-theta clone <remote> <dir>")?;
    let dir = PathBuf::from(dir);
    std::fs::create_dir_all(&dir)?;
    let repo = Repository::init(&dir)?;
    let spec = RemoteSpec::parse(remote)?;
    repo.config_set("remote", &spec.to_string())?;
    repo.pull_spec(&spec, "main")?;
    println!("cloned into {}", dir.display());
    Ok(())
}

fn cmd_replicate(args: &[String]) -> Result<()> {
    let repo = open_repo()?;
    let mut repair = false;
    let mut remote = None;
    let mut branch = None;
    for arg in args {
        match arg.as_str() {
            "--repair" => repair = true,
            other if other.starts_with("--") => bail!("unknown replicate flag '{other}'"),
            other if remote.is_none() => remote = Some(other.to_string()),
            other if branch.is_none() => branch = Some(other.to_string()),
            other => bail!("unexpected replicate argument '{other}'"),
        }
    }
    let remote = match remote {
        Some(r) => r,
        None => repo.config_get("remote")?.context(
            "usage: git-theta replicate [--repair] <remote> [branch] (or set a `remote` config)",
        )?,
    };
    let branch = branch.as_deref().unwrap_or("main");
    let spec = RemoteSpec::parse(&remote)?;
    let mirrors = spec.mirrors();
    if mirrors.len() < 2 {
        bail!("'{spec}' is not a replica set; give a comma-separated mirror list");
    }
    let replica = crate::lfs::ReplicatedRemote::open(&mirrors, Some(repo.theta_dir()))?;
    println!(
        "replica set: {} mirror(s), write quorum {}",
        replica.mirror_count(),
        replica.quorum()
    );

    if !repair {
        // Status: per-mirror inventory so a lagging mirror is visible
        // before anyone trips over it on fetch.
        for (i, m) in mirrors.iter().enumerate() {
            let transport = crate::lfs::open_transport(m, Some(repo.theta_dir()))?;
            match transport.list_oids() {
                Ok(Some(oids)) => println!("  [{i}] {m}: {} LFS object(s)", oids.len()),
                Ok(None) => println!("  [{i}] {m}: inventory unsupported (old server)"),
                Err(e) => println!("  [{i}] {m}: unreachable ({e:#})"),
            }
        }
        return Ok(());
    }

    // Anti-entropy: converge the LFS stores first so a laggard's branch
    // tip never lands before the objects its commits reference.
    let report = replica.repair(crate::util::par::default_threads())?;
    println!(
        "lfs repair: {} object(s) across {} mirror(s); healed {} laggard(s), \
         shipped {} object(s) ({} on the wire)",
        report.union_objects,
        report.mirrors,
        report.laggards_healed,
        report.objects_shipped,
        humansize::bytes(report.wire_bytes_shipped)
    );
    let refs = repo.repair_replica_refs(&mirrors, branch)?;
    if refs.diverged {
        eprintln!("warning: mirrors hold diverged '{branch}' tips; merge and push to resolve");
    } else if let Some(tip) = refs.tip {
        println!(
            "ref repair: '{branch}' at {} on all mirrors ({} fast-forwarded)",
            tip.short(),
            refs.fast_forwarded
        );
    } else {
        println!("ref repair: no mirror holds branch '{branch}'");
    }
    Ok(())
}

fn cmd_serve(args: &[String]) -> Result<()> {
    let mut root = None;
    let mut port = 0u16;
    let mut host = "127.0.0.1".to_string();
    let mut i = 0;
    while i < args.len() {
        match args[i].as_str() {
            "--port" => {
                port = args
                    .get(i + 1)
                    .and_then(|v| v.parse().ok())
                    .context("--port needs a number")?;
                i += 2;
            }
            // Bind host (default loopback; 0.0.0.0 serves the network —
            // there is no auth story yet, so that is opt-in).
            "--bind" => {
                host = args.get(i + 1).context("--bind needs a host")?.clone();
                i += 2;
            }
            other if other.starts_with("--") => bail!("unknown serve flag '{other}'"),
            other if root.is_none() => {
                root = Some(other.to_string());
                i += 1;
            }
            other => bail!("unexpected serve argument '{other}'"),
        }
    }
    let root = root.context("usage: git-theta serve <root-dir> [--port N] [--bind HOST]")?;
    std::fs::create_dir_all(&root)?;
    let server = crate::lfs::LfsServer::spawn_on(Path::new(&root), &format!("{host}:{port}"))?;
    println!("serving {root} at {}", server.url());
    println!("  push:  git-theta push {} main", server.url());
    println!("  clone: git-theta clone {} <dir>", server.url());
    println!("(ctrl-c to stop)");
    loop {
        std::thread::sleep(std::time::Duration::from_secs(3600));
    }
}

fn cmd_config(args: &[String]) -> Result<()> {
    let repo = open_repo()?;
    match args {
        [key] => match repo.config_get(key)? {
            Some(v) => println!("{v}"),
            None => bail!("config key '{key}' not set"),
        },
        [key, value] => {
            repo.config_set(key, value)?;
        }
        _ => bail!("usage: git-theta config <key> [<value>]"),
    }
    Ok(())
}

fn cmd_snapshot(args: &[String]) -> Result<()> {
    if args.is_empty() {
        bail!("usage: git-theta snapshot <path...>");
    }
    let repo = open_repo()?;
    let access = crate::theta::ObjectAccess::for_repo(&repo)?;
    for path in args {
        let staged = repo
            .prior_staged(path)?
            .with_context(|| format!("'{path}' has no staged or committed version"))?;
        if !crate::theta::ModelMetadata::is_metadata(&staged) {
            bail!("'{path}' is not a git-theta tracked model (no metadata)");
        }
        let meta = crate::theta::ModelMetadata::from_bytes(&staged)
            .with_context(|| format!("parsing metadata of '{path}'"))?;
        let (snap, report) = crate::theta::snapshot_metadata(
            &access,
            &meta,
            crate::util::par::default_threads(),
        )?;
        if report.reanchored == 0 {
            println!("'{path}': all {} group(s) already dense", report.groups);
            continue;
        }
        // The smudged bytes are unchanged by construction, so the
        // index's raw (working tree) hash stays valid. With no index
        // entry (path known only to HEAD), derive the raw hash from
        // the snapshot's own smudge output — never from the working
        // file, whose uncommitted edits must keep showing as Modified
        // in status.
        let index = crate::gitcore::index::Index::load(repo.theta_dir())?;
        let raw = match index.get(path) {
            Some(entry) => entry.raw,
            None => {
                let fmt = crate::checkpoint::format_by_name(&snap.format).with_context(|| {
                    format!("checkpoint format '{}' not registered", snap.format)
                })?;
                let ck = crate::theta::smudge_metadata(
                    &access,
                    &snap,
                    crate::util::par::default_threads(),
                )?;
                crate::gitcore::object::Oid::of_bytes(&fmt.save_bytes(&ck)?)
            }
        };
        repo.add_staged_bytes(path, snap.to_bytes(), raw)?;
        println!(
            "'{path}': re-anchored {}/{} group(s), max chain depth {} -> 1; staged \
             (commit to finish)",
            report.reanchored, report.groups, report.max_depth_before
        );
    }
    // Re-anchoring replaces staged chains with dense entries; any
    // objects that became unreferenced show up in the dry-run report.
    maybe_print_gc_report(&repo);
    Ok(())
}

fn cmd_gc(args: &[String]) -> Result<()> {
    let mut prune = false;
    for arg in args {
        match arg.as_str() {
            "--prune" => prune = true,
            other => bail!("unknown gc flag '{other}' (usage: git-theta gc [--prune])"),
        }
    }
    let repo = open_repo()?;
    let report = crate::theta::collect_garbage(&repo, prune)?;
    if report.orphaned.is_empty() {
        println!(
            "nothing to prune: all {} object(s) referenced by a branch, HEAD, or the index",
            report.total
        );
        return Ok(());
    }
    for oid in &report.orphaned {
        println!("  orphan {}", oid.short());
    }
    if report.pruned {
        println!(
            "pruned {} orphaned object(s), freed {} ({} live object(s) kept)",
            report.orphaned.len(),
            humansize::bytes(report.orphaned_bytes),
            report.live
        );
    } else {
        println!(
            "{} orphaned object(s) holding {} ({} live); re-run with --prune to delete",
            report.orphaned.len(),
            humansize::bytes(report.orphaned_bytes),
            report.live
        );
    }
    Ok(())
}

fn cmd_fsck(_args: &[String]) -> Result<()> {
    let repo = open_repo()?;
    let mut objects = 0usize;
    for oid in repo.odb().list()? {
        repo.odb()
            .read(&oid)
            .with_context(|| format!("object {} corrupt", oid.short()))?;
        objects += 1;
    }
    let store = crate::lfs::LfsStore::open(repo.theta_dir());
    let mut lfs_objects = 0usize;
    for oid in store.list()? {
        store
            .get(&oid)
            .with_context(|| format!("lfs object {} corrupt", oid.short()))?;
        lfs_objects += 1;
    }
    println!(
        "ok: {objects} odb objects, {lfs_objects} lfs objects ({})",
        humansize::bytes(store.disk_usage()?)
    );
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::tmp::TempDir;
    use std::sync::Mutex;

    // CLI tests chdir; serialize them.
    static CWD_LOCK: Mutex<()> = Mutex::new(());

    fn in_dir<F: FnOnce() -> Result<()>>(dir: &Path, f: F) {
        let _guard = CWD_LOCK.lock().unwrap();
        let old = std::env::current_dir().unwrap();
        std::env::set_current_dir(dir).unwrap();
        let result = f();
        std::env::set_current_dir(old).unwrap();
        result.unwrap();
    }

    fn sv(args: &[&str]) -> Vec<String> {
        args.iter().map(|s| s.to_string()).collect()
    }

    #[test]
    fn full_cli_lifecycle() {
        let td = TempDir::new("cli").unwrap();
        in_dir(td.path(), || {
            dispatch(&sv(&["init"]))?;
            std::fs::write("notes.txt", "hello")?;
            dispatch(&sv(&["add", "notes.txt"]))?;
            dispatch(&sv(&["commit", "-m", "first"]))?;
            dispatch(&sv(&["status"]))?;
            dispatch(&sv(&["log"]))?;
            dispatch(&sv(&["branch", "side"]))?;
            dispatch(&sv(&["checkout", "side"]))?;
            std::fs::write("notes.txt", "side")?;
            dispatch(&sv(&["add", "notes.txt"]))?;
            dispatch(&sv(&["commit", "-m", "side edit"]))?;
            dispatch(&sv(&["checkout", "main"]))?;
            dispatch(&sv(&["merge", "side"]))?;
            assert_eq!(std::fs::read_to_string("notes.txt")?, "side");
            Ok(())
        });
    }

    #[test]
    fn fetch_prefetches_lfs_objects() {
        let td_origin = TempDir::new("cli-origin").unwrap();
        let td_remote = TempDir::new("cli-remote").unwrap();
        let td_clone = TempDir::new("cli-clone").unwrap();
        let remote = td_remote.path().to_str().unwrap().to_string();
        in_dir(td_origin.path(), || {
            dispatch(&sv(&["init"]))?;
            dispatch(&sv(&["lfs-track", "*.bin"]))?;
            std::fs::write("w.bin", vec![5u8; 4096])?;
            dispatch(&sv(&["add", "w.bin", ".thetaattributes"]))?;
            dispatch(&sv(&["commit", "-m", "v1"]))?;
            dispatch(&sv(&["push", remote.as_str(), "main", "--pack"]))?;
            Ok(())
        });
        in_dir(td_clone.path(), || {
            dispatch(&sv(&["init"]))?;
            dispatch(&sv(&["fetch", remote.as_str(), "main"]))?;
            Ok(())
        });
        // The object is local before any checkout touches it.
        let store = crate::lfs::LfsStore::open(&td_clone.path().join(".theta"));
        assert_eq!(store.list().unwrap().len(), 1);
    }

    #[test]
    fn gc_report_prints_and_respects_silencer() {
        let td = TempDir::new("cli-gcreport").unwrap();
        in_dir(td.path(), || {
            dispatch(&sv(&["init"]))?;
            let repo = open_repo()?;
            // Orphan an object so the dry-run report has content.
            let store = crate::lfs::LfsStore::open(repo.theta_dir());
            store.put(b"abandoned resolution")?;
            maybe_print_gc_report(&repo);
            dispatch(&sv(&["config", "theta.gc-report", "off"]))?;
            assert_eq!(repo.config_get("theta.gc-report")?.as_deref(), Some("off"));
            maybe_print_gc_report(&repo);
            // The report never deletes: the orphan must still exist.
            assert_eq!(store.list()?.len(), 1);
            Ok(())
        });
    }

    #[test]
    fn gc_auto_prunes_orphans_and_spares_concurrent_restores() {
        let td = TempDir::new("cli-gcauto").unwrap();
        in_dir(td.path(), || {
            dispatch(&sv(&["init"]))?;
            std::fs::write("notes.txt", "keep")?;
            dispatch(&sv(&["add", "notes.txt"]))?;
            dispatch(&sv(&["commit", "-m", "base"]))?;
            let repo = open_repo()?;
            let store = crate::lfs::LfsStore::open(repo.theta_dir());
            // Age an object so only a fresh put (not its original
            // write) can move its mtime past a gc plan instant.
            let age = |oid: &crate::gitcore::object::Oid| {
                let hex = oid.to_hex();
                let f = std::fs::OpenOptions::new()
                    .write(true)
                    .open(
                        repo.theta_dir()
                            .join("lfs/objects")
                            .join(format!("{}/{}", &hex[..2], &hex[2..])),
                    )
                    .unwrap();
                f.set_modified(std::time::SystemTime::now() - std::time::Duration::from_secs(3600))
                    .unwrap();
            };

            // gc-auto off (the default): the report-only path never deletes.
            let (doomed, _) = store.put(b"left behind by an abandoned merge")?;
            age(&doomed);
            maybe_print_gc_report(&repo);
            assert!(store.contains(&doomed));

            // gc-auto on: the same call prunes the orphan on the spot.
            dispatch(&sv(&["config", "theta.gc-auto", "on"]))?;
            maybe_print_gc_report(&repo);
            assert!(!store.contains(&doomed), "gc-auto left the orphan behind");

            // Regression: an orphan re-stored after the plan instant
            // must be spared — auto-prune rides the same safety rule
            // as `gc --prune`.
            let payload = b"resolution re-stored mid-prune";
            let (racy, _) = store.put(payload)?;
            age(&racy);
            let (mut report, started) = crate::theta::plan_garbage(&repo)?;
            assert!(report.orphaned.contains(&racy));
            store.put(payload)?; // the race: mtime freshens past the plan
            auto_prune_planned(&repo, &mut report, started)?;
            assert!(store.contains(&racy), "auto-prune deleted a re-stored object");
            assert_eq!(report.spared, 1);
            Ok(())
        });
    }

    #[test]
    fn replicate_status_and_repair_converge_mirrors() {
        let td = TempDir::new("cli-replicate").unwrap();
        let work = td.join("work");
        std::fs::create_dir_all(&work).unwrap();
        let ma = td.join("mirror-a");
        let mb = td.join("mirror-b");
        let (ma_s, mb_s) = (ma.display().to_string(), mb.display().to_string());
        let set = format!("{ma_s},{mb_s}");
        in_dir(&work, || {
            dispatch(&sv(&["init"]))?;
            dispatch(&sv(&["lfs-track", "*.bin"]))?;
            std::fs::write("w.bin", vec![7u8; 2048])?;
            dispatch(&sv(&["add", "w.bin", ".thetaattributes"]))?;
            dispatch(&sv(&["commit", "-m", "v1"]))?;
            dispatch(&sv(&["push", set.as_str(), "main"]))?;

            // A plain spec is not a replica set; status over the
            // healthy set works.
            assert!(dispatch(&sv(&["replicate", ma_s.as_str()])).is_err());
            dispatch(&sv(&["replicate", set.as_str()]))?;

            // Advance only mirror a: b now lags by one commit and one
            // LFS object (a quorum-shortfall push in miniature).
            std::fs::write("w.bin", vec![9u8; 2048])?;
            dispatch(&sv(&["add", "w.bin"]))?;
            dispatch(&sv(&["commit", "-m", "v2"]))?;
            dispatch(&sv(&["push", ma_s.as_str(), "main"]))?;

            use crate::gitcore::remote::open_endpoint;
            let ea = open_endpoint(&RemoteSpec::parse(&ma_s)?)?;
            let eb = open_endpoint(&RemoteSpec::parse(&mb_s)?)?;
            assert_ne!(ea.branch("main")?, eb.branch("main")?);

            dispatch(&sv(&["replicate", "--repair", set.as_str(), "main"]))?;

            let tip = ea.branch("main")?;
            assert!(tip.is_some());
            assert_eq!(tip, eb.branch("main")?, "branch tips did not converge");
            let sa = crate::lfs::LfsStore::at(&ma.join("lfs/objects"));
            let sb = crate::lfs::LfsStore::at(&mb.join("lfs/objects"));
            let (mut la, mut lb) = (sa.list()?, sb.list()?);
            la.sort();
            lb.sort();
            assert_eq!(la, lb, "LFS stores did not converge");
            assert_eq!(la.len(), 2);
            for oid in &la {
                assert_eq!(sa.get(oid)?, sb.get(oid)?, "object bytes differ across mirrors");
            }
            // Idempotent: a second repair finds nothing to ship.
            dispatch(&sv(&["replicate", "--repair", set.as_str(), "main"]))?;
            Ok(())
        });
    }

    #[test]
    fn unknown_command_errors() {
        assert!(dispatch(&sv(&["frobnicate"])).is_err());
        assert!(dispatch(&sv(&["help"])).is_ok());
    }
}
