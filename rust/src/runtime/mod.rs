//! PJRT runtime: load and execute AOT-compiled JAX/Pallas artifacts.
//!
//! `make artifacts` runs `python/compile/aot.py` once, lowering the L2
//! JAX computations (which call the L1 Pallas kernels) to **HLO text**
//! under `artifacts/`. This module is the only bridge between the Rust
//! request path and those artifacts: it compiles each HLO module on the
//! PJRT CPU client at first use, caches the executable, and marshals
//! [`Tensor`]s to/from XLA literals. Python never runs at this layer.
//!
//! HLO *text* (not serialized protos) is the interchange format: jax ≥
//! 0.5 emits 64-bit instruction ids that xla_extension 0.5.1 rejects;
//! the text parser reassigns ids (see /opt/xla-example/README.md).

use crate::tensor::{DType, Tensor};
use anyhow::{anyhow, bail, Context, Result};
use once_cell::sync::Lazy;
use std::collections::HashMap;
use std::path::{Path, PathBuf};
use std::sync::{Arc, Mutex};

/// Location of compiled artifacts, overridable via `THETA_ARTIFACTS`.
pub fn default_artifacts_dir() -> PathBuf {
    if let Ok(dir) = std::env::var("THETA_ARTIFACTS") {
        return PathBuf::from(dir);
    }
    // Walk up from cwd looking for an artifacts/ directory.
    let mut dir = std::env::current_dir().unwrap_or_else(|_| PathBuf::from("."));
    loop {
        let cand = dir.join("artifacts");
        if cand.is_dir() {
            return cand;
        }
        if !dir.pop() {
            return PathBuf::from("artifacts");
        }
    }
}

/// A PJRT runtime bound to an artifacts directory.
pub struct Runtime {
    client: xla::PjRtClient,
    artifacts: PathBuf,
    cache: Mutex<HashMap<String, Arc<xla::PjRtLoadedExecutable>>>,
}

// The PJRT client handle is used behind a global mutex-protected cache;
// the underlying CPU client is thread-safe for compile/execute.
unsafe impl Send for Runtime {}
unsafe impl Sync for Runtime {}

static GLOBAL: Lazy<Mutex<Option<Arc<Runtime>>>> = Lazy::new(|| Mutex::new(None));

impl Runtime {
    /// Create a runtime over `artifacts/` with a fresh PJRT CPU client.
    pub fn new(artifacts: PathBuf) -> Result<Runtime> {
        let client = xla::PjRtClient::cpu().map_err(|e| anyhow!("pjrt cpu client: {e:?}"))?;
        Ok(Runtime {
            client,
            artifacts,
            cache: Mutex::new(HashMap::new()),
        })
    }

    /// Process-wide shared runtime (created on first use).
    pub fn global() -> Result<Arc<Runtime>> {
        let mut guard = GLOBAL.lock().unwrap();
        if let Some(rt) = guard.as_ref() {
            return Ok(rt.clone());
        }
        let rt = Arc::new(Runtime::new(default_artifacts_dir())?);
        *guard = Some(rt.clone());
        Ok(rt)
    }

    pub fn artifacts_dir(&self) -> &Path {
        &self.artifacts
    }

    fn artifact_path(&self, name: &str) -> PathBuf {
        self.artifacts.join(format!("{name}.hlo.txt"))
    }

    /// Is this artifact present on disk?
    pub fn available(&self, name: &str) -> bool {
        self.artifact_path(name).exists()
    }

    /// Load (compile + cache) an artifact by name.
    pub fn load(&self, name: &str) -> Result<Arc<xla::PjRtLoadedExecutable>> {
        if let Some(exe) = self.cache.lock().unwrap().get(name) {
            return Ok(exe.clone());
        }
        let path = self.artifact_path(name);
        let proto = xla::HloModuleProto::from_text_file(&path)
            .map_err(|e| anyhow!("loading HLO {}: {e:?}", path.display()))?;
        let comp = xla::XlaComputation::from_proto(&proto);
        let exe = Arc::new(
            self.client
                .compile(&comp)
                .map_err(|e| anyhow!("compiling {name}: {e:?}"))?,
        );
        self.cache.lock().unwrap().insert(name.to_string(), exe.clone());
        Ok(exe)
    }

    /// Execute an artifact on tensors; returns the tuple elements.
    ///
    /// Artifacts are lowered with `return_tuple=True`, so the single
    /// output literal is always a tuple.
    pub fn execute(&self, name: &str, inputs: &[&Tensor]) -> Result<Vec<Tensor>> {
        let exe = self.load(name)?;
        let literals: Vec<xla::Literal> = inputs
            .iter()
            .map(|t| tensor_to_literal(t))
            .collect::<Result<_>>()?;
        let result = exe
            .execute::<xla::Literal>(&literals)
            .map_err(|e| anyhow!("executing {name}: {e:?}"))?;
        let out = result
            .first()
            .and_then(|replica| replica.first())
            .context("artifact produced no output")?
            .to_literal_sync()
            .map_err(|e| anyhow!("fetching output of {name}: {e:?}"))?;
        let parts = out
            .to_tuple()
            .map_err(|e| anyhow!("untupling output of {name}: {e:?}"))?;
        parts.into_iter().map(|l| literal_to_tensor(&l)).collect()
    }
}

fn dtype_to_element_type(dt: DType) -> Result<xla::ElementType> {
    Ok(match dt {
        DType::F32 => xla::ElementType::F32,
        DType::F64 => xla::ElementType::F64,
        DType::BF16 => xla::ElementType::Bf16,
        DType::F16 => xla::ElementType::F16,
        DType::I32 => xla::ElementType::S32,
        DType::I64 => xla::ElementType::S64,
        DType::U8 => xla::ElementType::U8,
        DType::Bool => xla::ElementType::Pred,
    })
}

fn element_type_to_dtype(et: xla::ElementType) -> Result<DType> {
    Ok(match et {
        xla::ElementType::F32 => DType::F32,
        xla::ElementType::F64 => DType::F64,
        xla::ElementType::Bf16 => DType::BF16,
        xla::ElementType::F16 => DType::F16,
        xla::ElementType::S32 => DType::I32,
        xla::ElementType::S64 => DType::I64,
        xla::ElementType::U8 => DType::U8,
        xla::ElementType::Pred => DType::Bool,
        other => bail!("unsupported XLA element type {other:?}"),
    })
}

/// Tensor → XLA literal (zero conversion: raw little-endian bytes).
///
/// Half-precision tensors are promoted to f32 first: the artifacts in
/// this repo take f32/i32 inputs, and the xla crate's half-precision
/// literal paths are unreliable (segfault in literal_copy_to).
pub fn tensor_to_literal(t: &Tensor) -> Result<xla::Literal> {
    if matches!(t.dtype(), DType::BF16 | DType::F16) {
        let promoted = t.cast(DType::F32)?;
        return tensor_to_literal(&promoted);
    }
    let et = dtype_to_element_type(t.dtype())?;
    xla::Literal::create_from_shape_and_untyped_data(et, t.shape(), t.bytes())
        .map_err(|e| anyhow!("creating literal: {e:?}"))
}

/// XLA literal → Tensor.
///
/// `copy_raw_to` is typed, so we dispatch per element type and re-encode
/// as little-endian bytes (a no-op copy on this platform).
pub fn literal_to_tensor(l: &xla::Literal) -> Result<Tensor> {
    let shape = l.shape().map_err(|e| anyhow!("literal shape: {e:?}"))?;
    let shape = match shape {
        xla::Shape::Array(a) => a,
        other => bail!("expected array literal, got {other:?}"),
    };
    let dims: Vec<usize> = shape.dims().iter().map(|&d| d as usize).collect();
    let dtype = element_type_to_dtype(shape.ty())?;
    let n: usize = dims.iter().product();

    fn bytes_of<T: Copy>(v: &[T]) -> Vec<u8> {
        let mut out = Vec::with_capacity(std::mem::size_of_val(v));
        unsafe {
            out.extend_from_slice(std::slice::from_raw_parts(
                v.as_ptr() as *const u8,
                std::mem::size_of_val(v),
            ));
        }
        out
    }

    let bytes = match dtype {
        DType::F32 => bytes_of(
            &l.to_vec::<f32>()
                .map_err(|e| anyhow!("literal data: {e:?}"))?,
        ),
        DType::F64 => bytes_of(
            &l.to_vec::<f64>()
                .map_err(|e| anyhow!("literal data: {e:?}"))?,
        ),
        DType::I32 => bytes_of(
            &l.to_vec::<i32>()
                .map_err(|e| anyhow!("literal data: {e:?}"))?,
        ),
        DType::I64 => bytes_of(
            &l.to_vec::<i64>()
                .map_err(|e| anyhow!("literal data: {e:?}"))?,
        ),
        DType::U8 => bytes_of(
            &l.to_vec::<u8>()
                .map_err(|e| anyhow!("literal data: {e:?}"))?,
        ),
        DType::BF16 | DType::F16 | DType::Bool => {
            bail!("{dtype} literals unsupported on the output path (use f32 outputs)")
        }
    };
    let _ = n;
    Ok(Tensor::from_bytes(dtype, dims, bytes)?)
}

#[cfg(test)]
mod tests {
    use super::*;

    // These tests need compiled artifacts; they are exercised further by
    // integration tests once `make artifacts` has run. Here we test the
    // marshalling layer and graceful failure without artifacts.

    #[test]
    fn artifact_discovery_missing_is_graceful() {
        let rt = Runtime::new(PathBuf::from("/nonexistent/artifacts")).unwrap();
        assert!(!rt.available("model"));
        assert!(rt.load("model").is_err());
    }

    #[test]
    fn literal_roundtrip_f32() {
        let t = Tensor::from_f32(vec![2, 3], vec![1., 2., 3., 4., 5., 6.]).unwrap();
        let l = tensor_to_literal(&t).unwrap();
        let back = literal_to_tensor(&l).unwrap();
        assert_eq!(back, t);
    }

    #[test]
    fn literal_roundtrip_i64_and_bf16_promotion() {
        let t = Tensor::from_i64(vec![4], vec![1, -2, 3, -4]).unwrap();
        assert_eq!(literal_to_tensor(&tensor_to_literal(&t).unwrap()).unwrap(), t);
        // bf16 inputs are promoted to f32 on the way in.
        let b = Tensor::from_f32(vec![2], vec![1.5, -0.25])
            .unwrap()
            .cast(DType::BF16)
            .unwrap();
        let back = literal_to_tensor(&tensor_to_literal(&b).unwrap()).unwrap();
        assert_eq!(back.dtype(), DType::F32);
        assert_eq!(back.to_f32_vec().unwrap(), vec![1.5, -0.25]);
    }
}
