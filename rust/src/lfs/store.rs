//! The local large-object store: `.theta/lfs/objects/<aa>/<rest>`.
//!
//! Objects are stored raw (compression is the serializer's job — see
//! `theta/serialize/`), addressed by sha256, written atomically, and
//! deduplicated by content.

use crate::gitcore::object::Oid;
use anyhow::{bail, Context, Result};
use std::path::{Path, PathBuf};
use std::sync::atomic::{AtomicU64, Ordering};

/// Per-process sequence for temp-file names: parallel clean/merge
/// workers can store identical content concurrently, and two writers
/// sharing one temp path could rename a partially written file into
/// place. A unique suffix per put keeps every write-then-rename atomic
/// for its own writer.
static PUT_SEQ: AtomicU64 = AtomicU64::new(0);

thread_local! {
    /// Count of full directory scans performed by
    /// [`LfsStore::contains_all`] on the calling thread. Thread-local —
    /// like `batch::TransferStats` — so concurrently running tests
    /// cannot perturb each other's deltas.
    static DIR_SCANS: std::cell::Cell<u64> = const { std::cell::Cell::new(0) };
}

/// Snapshot the calling thread's directory-scan counter
/// (instrumentation for tests and benchmarks; a whole have/want
/// negotiation must cost one scan, not O(want) probes — see
/// [`LfsStore::contains_all`]).
pub fn dir_scans() -> u64 {
    DIR_SCANS.with(|c| c.get())
}

/// A content-addressed object store on the local filesystem.
#[derive(Debug, Clone)]
pub struct LfsStore {
    root: PathBuf,
}

impl LfsStore {
    /// Open the store under a repository's `.theta` dir (creates lazily).
    pub fn open(theta_dir: &Path) -> LfsStore {
        LfsStore {
            root: theta_dir.join("lfs/objects"),
        }
    }

    /// Open a bare store rooted at an arbitrary directory (remotes).
    pub fn at(root: &Path) -> LfsStore {
        LfsStore {
            root: root.to_path_buf(),
        }
    }

    /// The directory objects live under.
    pub fn root(&self) -> &Path {
        &self.root
    }

    fn path_for(&self, oid: &Oid) -> PathBuf {
        let hex = oid.to_hex();
        self.root.join(&hex[..2]).join(&hex[2..])
    }

    /// Whether an object is present locally.
    pub fn contains(&self, oid: &Oid) -> bool {
        self.path_for(oid).exists()
    }

    /// Bulk presence check: one answer per oid, aligned with `oids`.
    ///
    /// A have/want negotiation used to probe `contains` once per wanted
    /// oid — O(want) filesystem stats. For large want-sets this walks
    /// the store's shard directories **once**, builds the full resident
    /// set, and answers every probe from memory; small want-sets keep
    /// the direct-stat path, which is cheaper than scanning a store
    /// that may hold the history of many models. IO errors read as
    /// "absent", matching [`LfsStore::contains`].
    pub fn contains_all(&self, oids: &[Oid]) -> Vec<bool> {
        if oids.len() <= 16 {
            return oids.iter().map(|o| self.contains(o)).collect();
        }
        DIR_SCANS.with(|c| c.set(c.get() + 1));
        let resident: std::collections::HashSet<Oid> =
            self.list().unwrap_or_default().into_iter().collect();
        oids.iter().map(|o| resident.contains(o)).collect()
    }

    /// Size in bytes of a stored object, without reading it
    /// (`None` if absent). Used to shard packs by payload size.
    pub fn size_of(&self, oid: &Oid) -> Option<u64> {
        std::fs::metadata(self.path_for(oid)).ok().map(|m| m.len())
    }

    /// Store a blob; returns (oid, size). Idempotent by content.
    pub fn put(&self, bytes: &[u8]) -> Result<(Oid, u64)> {
        let oid = Oid::of_bytes(bytes);
        let path = self.path_for(&oid);
        if path.exists() {
            return Ok((oid, bytes.len() as u64));
        }
        std::fs::create_dir_all(path.parent().unwrap())?;
        let tmp = path.with_extension(format!(
            "tmp{}-{}",
            std::process::id(),
            PUT_SEQ.fetch_add(1, Ordering::Relaxed)
        ));
        std::fs::write(&tmp, bytes)?;
        std::fs::rename(&tmp, &path)?;
        Ok((oid, bytes.len() as u64))
    }

    /// Remove an object from the store (no-op if absent). Returns
    /// whether something was actually deleted. Used by `git-theta gc`
    /// to drop orphaned objects; callers are responsible for proving
    /// the object unreferenced first.
    pub fn delete(&self, oid: &Oid) -> Result<bool> {
        let path = self.path_for(oid);
        if !path.exists() {
            return Ok(false);
        }
        std::fs::remove_file(&path)
            .with_context(|| format!("deleting lfs object {}", oid.short()))?;
        Ok(true)
    }

    /// Retrieve a blob, verifying its hash.
    pub fn get(&self, oid: &Oid) -> Result<Vec<u8>> {
        let mut bytes = Vec::new();
        self.get_to(oid, &mut bytes)?;
        Ok(bytes)
    }

    /// Retrieve a blob into a caller-provided buffer (cleared first),
    /// verifying its hash.
    ///
    /// Reuses the buffer's capacity, so bulk readers — the pack
    /// assembler fanning hundreds of update objects into one pack —
    /// avoid a heap allocation and its copy per object by recycling one
    /// scratch buffer per worker.
    pub fn get_to(&self, oid: &Oid, out: &mut Vec<u8>) -> Result<()> {
        use std::io::Read;
        let path = self.path_for(oid);
        out.clear();
        let mut f = std::fs::File::open(&path)
            .with_context(|| format!("lfs object {} not found locally", oid.short()))?;
        if let Ok(meta) = f.metadata() {
            out.reserve(meta.len() as usize);
        }
        f.read_to_end(out)
            .with_context(|| format!("reading lfs object {}", oid.short()))?;
        if Oid::of_bytes(out) != *oid {
            bail!("lfs object {} is corrupt on disk", oid.short());
        }
        Ok(())
    }

    /// Copy an object from another store (no-op if present). Returns
    /// whether a transfer actually happened (dedup metric).
    pub fn fetch_from(&self, other: &LfsStore, oid: &Oid) -> Result<bool> {
        if self.contains(oid) {
            return Ok(false);
        }
        let bytes = other.get(oid)?;
        self.put(&bytes)?;
        Ok(true)
    }

    /// Total bytes stored.
    pub fn disk_usage(&self) -> Result<u64> {
        let mut total = 0;
        if !self.root.exists() {
            return Ok(0);
        }
        for shard in std::fs::read_dir(&self.root)? {
            let shard = shard?;
            if shard.file_type()?.is_dir() {
                for f in std::fs::read_dir(shard.path())? {
                    total += f?.metadata()?.len();
                }
            }
        }
        Ok(total)
    }

    /// All stored oids.
    pub fn list(&self) -> Result<Vec<Oid>> {
        let mut out = Vec::new();
        if !self.root.exists() {
            return Ok(out);
        }
        for shard in std::fs::read_dir(&self.root)? {
            let shard = shard?;
            if !shard.file_type()?.is_dir() {
                continue;
            }
            let prefix = shard.file_name().to_string_lossy().to_string();
            for f in std::fs::read_dir(shard.path())? {
                let name = f?.file_name().to_string_lossy().to_string();
                if let Ok(oid) = Oid::from_hex(&format!("{prefix}{name}")) {
                    out.push(oid);
                }
            }
        }
        Ok(out)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::tmp::TempDir;

    #[test]
    fn put_get_dedup() {
        let td = TempDir::new("lfs").unwrap();
        let store = LfsStore::open(td.path());
        let (oid, size) = store.put(&vec![42u8; 1000]).unwrap();
        assert_eq!(size, 1000);
        assert!(store.contains(&oid));
        assert_eq!(store.get(&oid).unwrap(), vec![42u8; 1000]);
        let before = store.disk_usage().unwrap();
        store.put(&vec![42u8; 1000]).unwrap();
        assert_eq!(store.disk_usage().unwrap(), before);
    }

    #[test]
    fn corruption_detected() {
        let td = TempDir::new("lfs").unwrap();
        let store = LfsStore::open(td.path());
        let (oid, _) = store.put(b"data").unwrap();
        std::fs::write(store.path_for(&oid), b"tampered").unwrap();
        assert!(store.get(&oid).is_err());
        let mut buf = Vec::new();
        assert!(store.get_to(&oid, &mut buf).is_err());
    }

    #[test]
    fn get_to_reuses_buffer() {
        let td = TempDir::new("lfs").unwrap();
        let store = LfsStore::open(td.path());
        let (big, _) = store.put(&vec![7u8; 4096]).unwrap();
        let (small, _) = store.put(b"tiny").unwrap();
        let mut buf = Vec::new();
        store.get_to(&big, &mut buf).unwrap();
        assert_eq!(buf.len(), 4096);
        let cap = buf.capacity();
        store.get_to(&small, &mut buf).unwrap();
        assert_eq!(buf, b"tiny");
        assert_eq!(buf.capacity(), cap, "capacity must be recycled");
        // Missing objects error without clobbering semantics.
        assert!(store.get_to(&Oid::of_bytes(b"ghost"), &mut buf).is_err());
    }

    #[test]
    fn fetch_from_other_store() {
        let td_a = TempDir::new("lfsA").unwrap();
        let td_b = TempDir::new("lfsB").unwrap();
        let a = LfsStore::open(td_a.path());
        let b = LfsStore::open(td_b.path());
        let (oid, _) = a.put(b"shared weights").unwrap();
        assert!(b.fetch_from(&a, &oid).unwrap());
        assert!(!b.fetch_from(&a, &oid).unwrap()); // cached now
        assert_eq!(b.get(&oid).unwrap(), b"shared weights");
    }

    #[test]
    fn delete_removes_only_the_target() {
        let td = TempDir::new("lfs").unwrap();
        let store = LfsStore::open(td.path());
        let (a, _) = store.put(b"keep me").unwrap();
        let (b, _) = store.put(b"drop me").unwrap();
        assert!(store.delete(&b).unwrap());
        assert!(!store.contains(&b));
        assert!(store.contains(&a));
        assert_eq!(store.get(&a).unwrap(), b"keep me");
        // Deleting again (or a ghost) is a clean no-op.
        assert!(!store.delete(&b).unwrap());
        assert!(!store.delete(&Oid::of_bytes(b"ghost")).unwrap());
    }

    #[test]
    fn contains_all_is_one_scan_not_one_probe_per_oid() {
        let td = TempDir::new("lfs").unwrap();
        let store = LfsStore::open(td.path());
        let held: Vec<Oid> = (0..40u8).map(|i| store.put(&[i, i, i]).unwrap().0).collect();
        let mut want = held.clone();
        for i in 0..24u8 {
            want.push(Oid::of_bytes(&[b'g', i]));
        }

        let scans_before = dir_scans();
        let answers = store.contains_all(&want);
        assert_eq!(dir_scans() - scans_before, 1, "one negotiation must cost one scan");
        assert_eq!(answers.len(), want.len());
        for (i, present) in answers.iter().enumerate() {
            assert_eq!(*present, i < held.len(), "oid {i}");
        }

        // Tiny want-sets stat directly — no scan at all.
        let scans_before = dir_scans();
        assert_eq!(store.contains_all(&want[..2]), vec![true, true]);
        assert_eq!(dir_scans(), scans_before);

        // An empty store answers all-absent (still a single scan).
        let td2 = TempDir::new("lfs-empty").unwrap();
        let empty = LfsStore::open(td2.path());
        assert_eq!(empty.contains_all(&want[..5]), vec![false; 5]);
    }

    #[test]
    fn list_and_usage() {
        let td = TempDir::new("lfs").unwrap();
        let store = LfsStore::open(td.path());
        assert_eq!(store.disk_usage().unwrap(), 0);
        store.put(b"one").unwrap();
        store.put(b"two!").unwrap();
        assert_eq!(store.list().unwrap().len(), 2);
        assert_eq!(store.disk_usage().unwrap(), 7);
    }
}
