//! The local large-object store: `.theta/lfs/objects/<aa>/<rest>`.
//!
//! Objects are stored raw (compression is the serializer's job — see
//! `theta/serialize/`), addressed by sha256, written atomically, and
//! deduplicated by content.

use crate::gitcore::object::Oid;
use crate::util::tmp;
use anyhow::{bail, Context, Result};
use std::path::{Path, PathBuf};

thread_local! {
    /// Count of full directory scans performed by
    /// [`LfsStore::contains_all`] on the calling thread. Thread-local —
    /// like `batch::TransferStats` — so concurrently running tests
    /// cannot perturb each other's deltas.
    static DIR_SCANS: std::cell::Cell<u64> = const { std::cell::Cell::new(0) };
}

/// Snapshot the calling thread's directory-scan counter
/// (instrumentation for tests and benchmarks; a whole have/want
/// negotiation must cost one scan, not O(want) probes — see
/// [`LfsStore::contains_all`]).
pub fn dir_scans() -> u64 {
    DIR_SCANS.with(|c| c.get())
}

/// A content-addressed object store on the local filesystem.
#[derive(Debug, Clone)]
pub struct LfsStore {
    root: PathBuf,
}

impl LfsStore {
    /// Open the store under a repository's `.theta` dir (creates lazily).
    pub fn open(theta_dir: &Path) -> LfsStore {
        LfsStore {
            root: theta_dir.join("lfs/objects"),
        }
    }

    /// Open a bare store rooted at an arbitrary directory (remotes).
    pub fn at(root: &Path) -> LfsStore {
        LfsStore {
            root: root.to_path_buf(),
        }
    }

    /// The directory objects live under.
    pub fn root(&self) -> &Path {
        &self.root
    }

    fn path_for(&self, oid: &Oid) -> PathBuf {
        let hex = oid.to_hex();
        self.root.join(&hex[..2]).join(&hex[2..])
    }

    /// Whether an object is present locally.
    pub fn contains(&self, oid: &Oid) -> bool {
        self.path_for(oid).exists()
    }

    /// Bulk presence check: one answer per oid, aligned with `oids`.
    /// Presence-only shorthand for [`LfsStore::stat_all`].
    pub fn contains_all(&self, oids: &[Oid]) -> Vec<bool> {
        self.stat_all(oids).iter().map(|s| s.is_some()).collect()
    }

    /// Bulk presence **and size** check: one `Some(bytes)` / `None` per
    /// oid, aligned with `oids`. One call answers a whole have/want
    /// negotiation, sizes included — no per-present-oid stat follow-up.
    ///
    /// Strategy is store-size-aware. A full shard-directory scan costs
    /// O(store); per-oid metadata stats cost O(want). Small want-sets
    /// always stat directly; larger ones first *estimate* the store's
    /// population from a few shard directories (O(1)-ish: one root
    /// readdir + a handful of shard readdirs) and scan only when the
    /// store is small enough that one scan beats O(want) stats — a
    /// store holding the history of many models no longer gets walked
    /// end to end to answer a 20-oid negotiation. IO errors read as
    /// "absent", matching [`LfsStore::contains`].
    pub fn stat_all(&self, oids: &[Oid]) -> Vec<Option<u64>> {
        const DIRECT_STAT_MAX: usize = 16;
        // A scan enumerates ~`store` dirents; a stat pass costs `want`
        // metadata syscalls. Scan only when the store is within this
        // factor of the want-set (readdir entries are cheaper than
        // individual stats, hence > 1).
        const SCAN_CROSSOVER: u64 = 4;
        if oids.len() <= DIRECT_STAT_MAX {
            return oids.iter().map(|o| self.size_of(o)).collect();
        }
        let estimate = self.estimate_population();
        if estimate > oids.len() as u64 * SCAN_CROSSOVER {
            return oids.iter().map(|o| self.size_of(o)).collect();
        }
        DIR_SCANS.with(|c| c.set(c.get() + 1));
        let resident: std::collections::HashMap<Oid, u64> = self
            .list_with_sizes()
            .unwrap_or_default()
            .into_iter()
            .collect();
        oids.iter().map(|o| resident.get(o).copied()).collect()
    }

    /// Cheap estimate of how many objects the store holds: count the
    /// shard directories, sample a few, extrapolate. Never scans the
    /// whole store (≤ 1 root readdir + a fixed handful of shard
    /// readdirs).
    fn estimate_population(&self) -> u64 {
        const ESTIMATE_SAMPLE: usize = 4;
        let shards = match std::fs::read_dir(&self.root) {
            Ok(iter) => iter
                .filter_map(|e| e.ok())
                .filter(|e| e.file_type().map(|t| t.is_dir()).unwrap_or(false))
                .collect::<Vec<_>>(),
            Err(_) => return 0,
        };
        if shards.is_empty() {
            return 0;
        }
        let mut sampled_entries = 0u64;
        let mut sampled = 0u64;
        for shard in shards.iter().take(ESTIMATE_SAMPLE) {
            if let Ok(iter) = std::fs::read_dir(shard.path()) {
                sampled_entries += iter.count() as u64;
                sampled += 1;
            }
        }
        if sampled == 0 {
            return 0;
        }
        // Extrapolate the sampled mean across all shards; floor at the
        // shard count (every counted shard holds at least one entry).
        (sampled_entries * shards.len() as u64 / sampled).max(shards.len() as u64)
    }

    /// Size in bytes of a stored object, without reading it
    /// (`None` if absent). Used to shard packs by payload size; bulk
    /// callers should prefer [`LfsStore::stat_all`].
    pub fn size_of(&self, oid: &Oid) -> Option<u64> {
        std::fs::metadata(self.path_for(oid)).ok().map(|m| m.len())
    }

    /// Store a blob; returns (oid, size). Idempotent by content.
    /// Parallel clean/merge workers can store identical content
    /// concurrently; [`tmp::write_atomic`]'s unique temp names keep
    /// every write-then-rename atomic for its own writer.
    ///
    /// A dedup hit **freshens the existing file's mtime**. That is the
    /// store's half of the put-vs-gc handshake: `gc --prune` skips
    /// orphans whose mtime is at or after the gc pass started (see
    /// `theta::gc::prune_plan`), so a put racing a prune — re-storing
    /// content the gc already classified as garbage — marks the object
    /// live-again before the delete can land. Without the freshen, the
    /// dedup fast path returns `Ok` while a concurrent prune unlinks
    /// the file, silently dropping a live object.
    pub fn put(&self, bytes: &[u8]) -> Result<(Oid, u64)> {
        let oid = Oid::of_bytes(bytes);
        let path = self.path_for(&oid);
        if let Ok(file) = std::fs::OpenOptions::new().write(true).open(&path) {
            // Best-effort: a failed utimens only narrows the race
            // window back to the pre-freshen behavior; the put itself
            // is still correct.
            let _ = file.set_modified(std::time::SystemTime::now());
            return Ok((oid, bytes.len() as u64));
        }
        tmp::write_atomic(&path, bytes)?;
        Ok((oid, bytes.len() as u64))
    }

    /// Last-modified time of a stored object (`None` if absent).
    /// Fresh mtimes are how racing puts veto a concurrent
    /// `gc --prune` delete — see [`LfsStore::put`].
    pub fn modified_of(&self, oid: &Oid) -> Option<std::time::SystemTime> {
        std::fs::metadata(self.path_for(oid))
            .and_then(|m| m.modified())
            .ok()
    }

    /// Remove an object from the store (no-op if absent). Returns
    /// whether something was actually deleted. Used by `git-theta gc`
    /// to drop orphaned objects; callers are responsible for proving
    /// the object unreferenced first.
    pub fn delete(&self, oid: &Oid) -> Result<bool> {
        let path = self.path_for(oid);
        if !path.exists() {
            return Ok(false);
        }
        std::fs::remove_file(&path)
            .with_context(|| format!("deleting lfs object {}", oid.short()))?;
        Ok(true)
    }

    /// Retrieve a blob, verifying its hash.
    pub fn get(&self, oid: &Oid) -> Result<Vec<u8>> {
        let mut bytes = Vec::new();
        self.get_to(oid, &mut bytes)?;
        Ok(bytes)
    }

    /// Retrieve a blob into a caller-provided buffer (cleared first),
    /// verifying its hash.
    ///
    /// Reuses the buffer's capacity, so bulk readers — the pack
    /// assembler fanning hundreds of update objects into one pack —
    /// avoid a heap allocation and its copy per object by recycling one
    /// scratch buffer per worker.
    pub fn get_to(&self, oid: &Oid, out: &mut Vec<u8>) -> Result<()> {
        use std::io::Read;
        let path = self.path_for(oid);
        out.clear();
        let mut f = std::fs::File::open(&path)
            .with_context(|| format!("lfs object {} not found locally", oid.short()))?;
        if let Ok(meta) = f.metadata() {
            out.reserve(meta.len() as usize);
        }
        f.read_to_end(out)
            .with_context(|| format!("reading lfs object {}", oid.short()))?;
        if Oid::of_bytes(out) != *oid {
            bail!("lfs object {} is corrupt on disk", oid.short());
        }
        Ok(())
    }

    /// Copy an object from another store (no-op if present). Returns
    /// whether a transfer actually happened (dedup metric).
    pub fn fetch_from(&self, other: &LfsStore, oid: &Oid) -> Result<bool> {
        if self.contains(oid) {
            return Ok(false);
        }
        let bytes = other.get(oid)?;
        self.put(&bytes)?;
        Ok(true)
    }

    /// Total bytes stored.
    pub fn disk_usage(&self) -> Result<u64> {
        let mut total = 0;
        if !self.root.exists() {
            return Ok(0);
        }
        for shard in std::fs::read_dir(&self.root)? {
            let shard = shard?;
            if shard.file_type()?.is_dir() {
                for f in std::fs::read_dir(shard.path())? {
                    total += f?.metadata()?.len();
                }
            }
        }
        Ok(total)
    }

    /// All stored oids.
    pub fn list(&self) -> Result<Vec<Oid>> {
        Ok(self.list_with_sizes()?.into_iter().map(|(o, _)| o).collect())
    }

    /// All stored oids with their byte sizes, from one directory walk
    /// (the scan half of [`LfsStore::stat_all`]: dirent metadata rides
    /// along for free, so negotiations that scan never stat again).
    pub fn list_with_sizes(&self) -> Result<Vec<(Oid, u64)>> {
        let mut out = Vec::new();
        if !self.root.exists() {
            return Ok(out);
        }
        for shard in std::fs::read_dir(&self.root)? {
            let shard = shard?;
            if !shard.file_type()?.is_dir() {
                continue;
            }
            let prefix = shard.file_name().to_string_lossy().to_string();
            for f in std::fs::read_dir(shard.path())? {
                let f = f?;
                let name = f.file_name().to_string_lossy().to_string();
                if let Ok(oid) = Oid::from_hex(&format!("{prefix}{name}")) {
                    // An entry whose metadata vanished mid-scan (a
                    // concurrent `gc --prune` won the race) reads as
                    // absent — one deleted object must not turn the
                    // whole negotiation into "everything is missing".
                    if let Ok(meta) = f.metadata() {
                        out.push((oid, meta.len()));
                    }
                }
            }
        }
        Ok(out)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::tmp::TempDir;

    #[test]
    fn put_get_dedup() {
        let td = TempDir::new("lfs").unwrap();
        let store = LfsStore::open(td.path());
        let (oid, size) = store.put(&vec![42u8; 1000]).unwrap();
        assert_eq!(size, 1000);
        assert!(store.contains(&oid));
        assert_eq!(store.get(&oid).unwrap(), vec![42u8; 1000]);
        let before = store.disk_usage().unwrap();
        store.put(&vec![42u8; 1000]).unwrap();
        assert_eq!(store.disk_usage().unwrap(), before);
    }

    #[test]
    fn dedup_put_freshens_mtime() {
        let td = TempDir::new("lfs-fresh").unwrap();
        let store = LfsStore::open(td.path());
        let (oid, _) = store.put(b"contended content").unwrap();
        // Age the object far into the past, as if it were written long
        // before a gc pass started.
        let old = std::time::SystemTime::now() - std::time::Duration::from_secs(3600);
        let f = std::fs::OpenOptions::new()
            .write(true)
            .open(store.path_for(&oid))
            .unwrap();
        f.set_modified(old).unwrap();
        drop(f);
        let aged = store.modified_of(&oid).unwrap();
        assert!(aged <= old + std::time::Duration::from_secs(1));

        // The dedup fast path must move the mtime forward, so a
        // concurrent prune's grace window sees the object as re-put.
        store.put(b"contended content").unwrap();
        let freshened = store.modified_of(&oid).unwrap();
        assert!(
            freshened > old + std::time::Duration::from_secs(1800),
            "dedup put left a stale mtime"
        );
    }

    #[test]
    fn corruption_detected() {
        let td = TempDir::new("lfs").unwrap();
        let store = LfsStore::open(td.path());
        let (oid, _) = store.put(b"data").unwrap();
        std::fs::write(store.path_for(&oid), b"tampered").unwrap();
        assert!(store.get(&oid).is_err());
        let mut buf = Vec::new();
        assert!(store.get_to(&oid, &mut buf).is_err());
    }

    #[test]
    fn get_to_reuses_buffer() {
        let td = TempDir::new("lfs").unwrap();
        let store = LfsStore::open(td.path());
        let (big, _) = store.put(&vec![7u8; 4096]).unwrap();
        let (small, _) = store.put(b"tiny").unwrap();
        let mut buf = Vec::new();
        store.get_to(&big, &mut buf).unwrap();
        assert_eq!(buf.len(), 4096);
        let cap = buf.capacity();
        store.get_to(&small, &mut buf).unwrap();
        assert_eq!(buf, b"tiny");
        assert_eq!(buf.capacity(), cap, "capacity must be recycled");
        // Missing objects error without clobbering semantics.
        assert!(store.get_to(&Oid::of_bytes(b"ghost"), &mut buf).is_err());
    }

    #[test]
    fn fetch_from_other_store() {
        let td_a = TempDir::new("lfsA").unwrap();
        let td_b = TempDir::new("lfsB").unwrap();
        let a = LfsStore::open(td_a.path());
        let b = LfsStore::open(td_b.path());
        let (oid, _) = a.put(b"shared weights").unwrap();
        assert!(b.fetch_from(&a, &oid).unwrap());
        assert!(!b.fetch_from(&a, &oid).unwrap()); // cached now
        assert_eq!(b.get(&oid).unwrap(), b"shared weights");
    }

    #[test]
    fn delete_removes_only_the_target() {
        let td = TempDir::new("lfs").unwrap();
        let store = LfsStore::open(td.path());
        let (a, _) = store.put(b"keep me").unwrap();
        let (b, _) = store.put(b"drop me").unwrap();
        assert!(store.delete(&b).unwrap());
        assert!(!store.contains(&b));
        assert!(store.contains(&a));
        assert_eq!(store.get(&a).unwrap(), b"keep me");
        // Deleting again (or a ghost) is a clean no-op.
        assert!(!store.delete(&b).unwrap());
        assert!(!store.delete(&Oid::of_bytes(b"ghost")).unwrap());
    }

    #[test]
    fn contains_all_is_one_scan_not_one_probe_per_oid() {
        let td = TempDir::new("lfs").unwrap();
        let store = LfsStore::open(td.path());
        let held: Vec<Oid> = (0..40u8).map(|i| store.put(&[i, i, i]).unwrap().0).collect();
        let mut want = held.clone();
        for i in 0..24u8 {
            want.push(Oid::of_bytes(&[b'g', i]));
        }

        let scans_before = dir_scans();
        let answers = store.contains_all(&want);
        assert_eq!(dir_scans() - scans_before, 1, "one negotiation must cost one scan");
        assert_eq!(answers.len(), want.len());
        for (i, present) in answers.iter().enumerate() {
            assert_eq!(*present, i < held.len(), "oid {i}");
        }

        // Tiny want-sets stat directly — no scan at all.
        let scans_before = dir_scans();
        assert_eq!(store.contains_all(&want[..2]), vec![true, true]);
        assert_eq!(dir_scans(), scans_before);

        // An empty store answers all-absent (still a single scan).
        let td2 = TempDir::new("lfs-empty").unwrap();
        let empty = LfsStore::open(td2.path());
        assert_eq!(empty.contains_all(&want[..5]), vec![false; 5]);
    }

    #[test]
    fn stat_all_reports_sizes_on_both_paths() {
        let td = TempDir::new("lfs-stat").unwrap();
        let store = LfsStore::open(td.path());
        let a = store.put(&[1u8; 10]).unwrap().0;
        let b = store.put(&[2u8; 999]).unwrap().0;
        let ghost = Oid::of_bytes(b"ghost");

        // Small want-set: direct stats, sizes included, no scan.
        let scans = dir_scans();
        assert_eq!(store.stat_all(&[a, ghost, b]), vec![Some(10), None, Some(999)]);
        assert_eq!(dir_scans(), scans);

        // Large want-set over a small store: one scan, same answers.
        let mut want = vec![a, b];
        for i in 0..30u8 {
            want.push(Oid::of_bytes(&[b'g', i]));
        }
        let scans = dir_scans();
        let stats = store.stat_all(&want);
        assert_eq!(dir_scans() - scans, 1);
        assert_eq!(stats[0], Some(10));
        assert_eq!(stats[1], Some(999));
        assert!(stats[2..].iter().all(|s| s.is_none()));
    }

    #[test]
    fn negotiation_against_a_big_store_stats_instead_of_scanning() {
        // A store holding far more objects than the want-set must not
        // be walked end to end: the size estimate flips the crossover
        // to per-oid stats.
        let td = TempDir::new("lfs-big").unwrap();
        let store = LfsStore::open(td.path());
        let held: Vec<Oid> = (0..300u16)
            .map(|i| store.put(&i.to_le_bytes()).unwrap().0)
            .collect();
        let mut want: Vec<Oid> = held[..12].to_vec();
        for i in 0..8u8 {
            want.push(Oid::of_bytes(&[b'x', i]));
        }
        assert!(want.len() > 16, "want-set must be past the direct-stat cutoff");
        let scans = dir_scans();
        let stats = store.stat_all(&want);
        assert_eq!(dir_scans(), scans, "a big store must answer via stats, not a scan");
        assert!(stats[..12].iter().all(|s| s == &Some(2)));
        assert!(stats[12..].iter().all(|s| s.is_none()));
    }

    #[test]
    fn list_and_usage() {
        let td = TempDir::new("lfs").unwrap();
        let store = LfsStore::open(td.path());
        assert_eq!(store.disk_usage().unwrap(), 0);
        store.put(b"one").unwrap();
        store.put(b"two!").unwrap();
        assert_eq!(store.list().unwrap().len(), 2);
        assert_eq!(store.disk_usage().unwrap(), 7);
    }
}
