//! Failure classification and the client retry policy.
//!
//! One question decides everything the resilience layer does: *is this
//! failure worth retrying?* A shed (`503 + Retry-After`), a connection
//! reset, a timeout — yes: the server asked for backoff or the channel
//! hiccuped, and the byte-range resume protocol means a retry never
//! re-sends bytes the server already holds. A `4xx`, a checksum
//! mismatch, a malformed response — no: the same request will fail the
//! same way forever, and retrying converts a crisp error into a slow
//! one.
//!
//! [`classify`] answers the question for any `anyhow::Error` by walking
//! its chain: a typed [`WireError`] (attached by the transports at the
//! point of failure) wins; otherwise `std::io::Error` kinds map to
//! [`FailureClass::Timeout`] / [`FailureClass::Cut`]; anything else is
//! [`FailureClass::Fatal`]. Both transports route their failures
//! through the same mapping, so [`RetryPolicy`] behaves identically
//! over HTTP and a directory remote (`rust/tests/remote_parity.rs`
//! pins this).
//!
//! [`RetryPolicy::run`] drives the loop: capped exponential backoff
//! with deterministic jitter (seeded, so chaos runs replay exactly),
//! honoring the server's `Retry-After` as a floor. Every pause is
//! counted on the thread-local transfer stats (`backoff_retries`,
//! `sheds`), so tests can lock how much retrying a scenario performed.

use anyhow::Result;
use std::sync::atomic::{AtomicU32, Ordering};
use std::time::Duration;

/// What kind of failure a transfer error represents — the whole
/// retryable/fatal split lives here.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum FailureClass {
    /// The server shed load (`503 + Retry-After`): back off and retry.
    Shed,
    /// An I/O deadline expired (socket timeout, request budget).
    Timeout,
    /// The connection was cut mid-conversation (reset, EOF, refused).
    Cut,
    /// Retrying cannot help: `4xx`, checksum mismatch, malformed data.
    Fatal,
}

impl FailureClass {
    /// Whether a retry has any chance of succeeding.
    pub fn retryable(self) -> bool {
        !matches!(self, FailureClass::Fatal)
    }
}

/// A typed transfer failure: the class that drives the retry decision,
/// the server's `Retry-After` hint when one was sent, and a
/// human-readable message. Transports attach this at the point of
/// failure so [`classify`] never has to parse error strings.
#[derive(Debug)]
pub struct WireError {
    class: FailureClass,
    retry_after: Option<u64>,
    message: String,
}

impl WireError {
    /// A `503 + Retry-After` shed from the server.
    pub fn shed(retry_after: Option<u64>, message: impl Into<String>) -> WireError {
        WireError {
            class: FailureClass::Shed,
            retry_after,
            message: message.into(),
        }
    }

    /// A deadline expiry (socket timeout or request budget).
    pub fn timeout(message: impl Into<String>) -> WireError {
        WireError {
            class: FailureClass::Timeout,
            retry_after: None,
            message: message.into(),
        }
    }

    /// A connection cut mid-conversation.
    pub fn cut(message: impl Into<String>) -> WireError {
        WireError {
            class: FailureClass::Cut,
            retry_after: None,
            message: message.into(),
        }
    }

    /// A failure retrying cannot fix.
    pub fn fatal(message: impl Into<String>) -> WireError {
        WireError {
            class: FailureClass::Fatal,
            retry_after: None,
            message: message.into(),
        }
    }

    /// The failure class.
    pub fn class(&self) -> FailureClass {
        self.class
    }

    /// The server's `Retry-After` hint in seconds, if any.
    pub fn retry_after(&self) -> Option<u64> {
        self.retry_after
    }
}

impl std::fmt::Display for WireError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.write_str(&self.message)
    }
}

impl std::error::Error for WireError {}

/// Classify any transfer error by walking its chain: a typed
/// [`WireError`] wins; otherwise `std::io::Error` kinds map timeouts
/// and cuts; anything unrecognized is [`FailureClass::Fatal`] —
/// unknown failures must not loop.
pub fn classify(err: &anyhow::Error) -> FailureClass {
    for cause in err.chain() {
        if let Some(wire) = cause.downcast_ref::<WireError>() {
            return wire.class;
        }
        if let Some(io) = cause.downcast_ref::<std::io::Error>() {
            use std::io::ErrorKind as K;
            return match io.kind() {
                K::TimedOut | K::WouldBlock => FailureClass::Timeout,
                K::ConnectionReset
                | K::ConnectionAborted
                | K::BrokenPipe
                | K::UnexpectedEof
                | K::ConnectionRefused => FailureClass::Cut,
                _ => FailureClass::Fatal,
            };
        }
    }
    FailureClass::Fatal
}

/// The `Retry-After` hint carried by the error chain's [`WireError`],
/// if any.
pub fn retry_after_of(err: &anyhow::Error) -> Option<u64> {
    err.chain()
        .find_map(|c| c.downcast_ref::<WireError>())
        .and_then(|w| w.retry_after())
}

/// Parse an HTTP `Retry-After` header value into delay seconds.
///
/// Only the delta-seconds form is honored; RFC 9110 also allows an
/// HTTP-date, which this client deliberately does not interpret —
/// clock skew between peers makes an absolute date a worse hint than
/// the local backoff schedule. An HTTP-date or garbage value returns
/// `None` so the caller falls back to the default jittered backoff; it
/// must never surface as an error or (worse) parse as a zero-second
/// pause that turns a shed into a tight retry loop. Absurdly large
/// delta values parse fine here and are clamped to the policy's `cap`
/// by [`RetryPolicy::pause`].
pub fn parse_retry_after(value: &str) -> Option<u64> {
    let v = value.trim();
    if v.is_empty() || !v.bytes().all(|b| b.is_ascii_digit()) {
        return None;
    }
    // Saturate rather than fail on overflow-length digit strings: the
    // server said "a very long time", and the cap clamps it anyway.
    Some(v.parse::<u64>().unwrap_or(u64::MAX))
}

/// Capped exponential backoff with deterministic jitter.
///
/// `pause(retry, ..)` for retry `r` (0-based) draws from
/// `[base·2^r / 2, base·2^r)` — a half-window floor keeps pauses from
/// collapsing to zero, the jitter de-synchronizes a fleet — capped at
/// `cap`, with the server's `Retry-After` as a floor. The jitter is a
/// pure function of `(seed, retry)`, so a seeded chaos run replays the
/// exact same schedule.
#[derive(Debug, Clone, Copy)]
pub struct RetryPolicy {
    /// Total attempts (the first try included). `1` disables retrying.
    pub max_attempts: u32,
    /// Backoff window for the first retry; doubles per retry.
    pub base: Duration,
    /// Upper bound on any single pause.
    pub cap: Duration,
    /// Jitter seed: same seed, same pause schedule.
    pub seed: u64,
}

impl Default for RetryPolicy {
    fn default() -> RetryPolicy {
        RetryPolicy {
            max_attempts: 4,
            base: Duration::from_millis(50),
            cap: Duration::from_secs(2),
            seed: 0x5EED,
        }
    }
}

impl RetryPolicy {
    /// A policy that never retries: one attempt, errors surface
    /// immediately. This is the [`Prefetcher`](super::Prefetcher)
    /// default — opting *into* backoff is an explicit decision, and
    /// fault-injection tests depend on first failures being visible.
    pub fn none() -> RetryPolicy {
        RetryPolicy {
            max_attempts: 1,
            ..RetryPolicy::default()
        }
    }

    /// The pause before retry `retry` (0-based), honoring a
    /// `Retry-After` hint as a floor — but never past `cap`: the cap
    /// must bound *every* pause, or one absurd (or hostile) header
    /// value stalls a transfer for hours. Applying the floor before
    /// the cap keeps `cap` the final word.
    pub fn pause(&self, retry: u32, retry_after: Option<u64>) -> Duration {
        let window = self
            .base
            .saturating_mul(1u32 << retry.min(16))
            .min(self.cap);
        let half = window / 2;
        // Deterministic per-(seed, retry) jitter in [half, window).
        let mut rng = crate::util::rng::Pcg64::new(
            self.seed ^ ((retry as u64 + 1).wrapping_mul(0x9E37_79B9_7F4A_7C15)),
        );
        let span = window.saturating_sub(half).as_millis().max(1) as u64;
        let jittered = half + Duration::from_millis(rng.next_u64() % span);
        let floor = Duration::from_secs(retry_after.unwrap_or(0));
        jittered.max(floor).min(self.cap)
    }

    /// Run `op` until it succeeds, fails fatally, or attempts run out.
    /// Retryable failures short of the last attempt sleep the jittered
    /// pause and count onto the thread-local transfer stats
    /// (`backoff_retries`; `sheds` additionally for 503s).
    pub fn run<T>(&self, mut op: impl FnMut() -> Result<T>) -> Result<T> {
        let attempts = self.max_attempts.max(1);
        let mut retry = 0u32;
        loop {
            match op() {
                Ok(v) => return Ok(v),
                Err(err) => {
                    let class = classify(&err);
                    if !class.retryable() || retry + 1 >= attempts {
                        return Err(err);
                    }
                    let pause = self.pause(retry, retry_after_of(&err));
                    super::batch::record(|t| {
                        t.backoff_retries += 1;
                        if class == FailureClass::Shed {
                            t.sheds += 1;
                        }
                    });
                    std::thread::sleep(pause);
                    retry += 1;
                }
            }
        }
    }
}

/// A retry allowance shared across every mirror of one logical
/// operation.
///
/// A replicated remote multiplies retry surfaces: N mirrors, each
/// wrapped in its own [`RetryPolicy`], would spend up to
/// `N × max_attempts` tries (and `N ×` the backoff cap in wall time)
/// on a single fetch. The budget makes the allowance *per operation*
/// instead of per mirror: every attempt — first try or failover —
/// spends from one shared pool, so adding mirrors adds failover
/// choices, not wall-clock.
///
/// Atomic so concurrently fanned-out pushes can draw from one pool;
/// exhaustion is not an error by itself — callers surface the last
/// mirror failure once `spend` declines.
#[derive(Debug)]
pub struct RetryBudget {
    remaining: AtomicU32,
}

impl RetryBudget {
    /// A budget of `attempts` total tries across all mirrors.
    pub fn new(attempts: u32) -> RetryBudget {
        RetryBudget {
            remaining: AtomicU32::new(attempts),
        }
    }

    /// Size a budget for `mirrors` endpoints under `policy`: every
    /// mirror is guaranteed one try, plus the policy's retry allowance
    /// (`max_attempts − 1`) shared across the whole set — *not*
    /// multiplied by it.
    pub fn for_mirrors(mirrors: usize, policy: &RetryPolicy) -> RetryBudget {
        let mirrors = mirrors.min(u32::MAX as usize) as u32;
        RetryBudget::new(mirrors.max(1) + policy.max_attempts.max(1) - 1)
    }

    /// Spend one attempt. Returns `false` when the pool is empty — the
    /// caller must stop failing over and surface its best error.
    pub fn spend(&self) -> bool {
        self.remaining
            .fetch_update(Ordering::Relaxed, Ordering::Relaxed, |v| v.checked_sub(1))
            .is_ok()
    }

    /// Attempts left in the pool.
    pub fn remaining(&self) -> u32 {
        self.remaining.load(Ordering::Relaxed)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::lfs::batch;
    use anyhow::{anyhow, Context};

    #[test]
    fn classification_walks_the_error_chain() {
        let shed = anyhow::Error::new(WireError::shed(Some(3), "server shed"))
            .context("pushing pack");
        assert_eq!(classify(&shed), FailureClass::Shed);
        assert_eq!(retry_after_of(&shed), Some(3));

        let cut = anyhow::Error::new(std::io::Error::new(
            std::io::ErrorKind::ConnectionReset,
            "reset by peer",
        ))
        .context("reading response");
        assert_eq!(classify(&cut), FailureClass::Cut);

        let timeout = anyhow::Error::new(std::io::Error::new(
            std::io::ErrorKind::TimedOut,
            "read timed out",
        ));
        assert_eq!(classify(&timeout), FailureClass::Timeout);

        // Unknown errors and explicit protocol rejections never loop.
        assert_eq!(classify(&anyhow!("some parse error")), FailureClass::Fatal);
        let fatal = anyhow::Error::new(WireError::fatal("422: bad pack"));
        assert_eq!(classify(&fatal), FailureClass::Fatal);
        assert!(!FailureClass::Fatal.retryable());
        assert!(FailureClass::Shed.retryable());
    }

    #[test]
    fn pauses_are_deterministic_capped_and_floor_on_retry_after() {
        let p = RetryPolicy {
            seed: 42,
            ..RetryPolicy::default()
        };
        // Same seed, same schedule; different seed, different jitter.
        assert_eq!(p.pause(0, None), p.pause(0, None));
        let other = RetryPolicy {
            seed: 43,
            ..RetryPolicy::default()
        };
        assert_ne!(
            (p.pause(0, None), p.pause(1, None), p.pause(2, None)),
            (other.pause(0, None), other.pause(1, None), other.pause(2, None)),
            "jitter ignored its seed"
        );
        // Half-window floor and window ceiling.
        for retry in 0..6 {
            let window = p.base.saturating_mul(1 << retry).min(p.cap);
            let pause = p.pause(retry, None);
            assert!(pause >= window / 2, "pause collapsed below the half-window");
            assert!(pause <= p.cap, "pause escaped the cap");
        }
        // Retry-After outranks the backoff schedule up to the cap
        // (default cap 2s): a modest hint floors the pause, an absurd
        // one clamps to the cap instead of stalling the transfer.
        assert_eq!(p.pause(0, Some(1)), Duration::from_secs(1));
        assert_eq!(p.pause(0, Some(5)), p.cap);
        assert_eq!(p.pause(0, Some(u64::MAX)), p.cap);
    }

    #[test]
    fn retry_after_parses_seconds_and_degrades_on_dates_and_garbage() {
        // Integer delta-seconds: honored verbatim.
        assert_eq!(parse_retry_after("3"), Some(3));
        assert_eq!(parse_retry_after(" 120 "), Some(120));
        assert_eq!(parse_retry_after("0"), Some(0));
        // HTTP-date: deliberately not interpreted — must fall back to
        // the default backoff, not error and not parse as 0.
        assert_eq!(parse_retry_after("Fri, 07 Aug 2026 09:00:00 GMT"), None);
        // Garbage: same degradation.
        assert_eq!(parse_retry_after(""), None);
        assert_eq!(parse_retry_after("soon"), None);
        assert_eq!(parse_retry_after("-5"), None);
        assert_eq!(parse_retry_after("1.5"), None);
        // Overflow-length digit strings saturate (and the pause cap
        // clamps them) rather than failing back to None.
        assert_eq!(
            parse_retry_after("99999999999999999999999999"),
            Some(u64::MAX)
        );
        let p = RetryPolicy::default();
        assert_eq!(p.pause(0, parse_retry_after("not-a-date")), p.pause(0, None));
    }

    #[test]
    fn run_retries_transient_failures_and_counts_them() {
        batch::reset_stats();
        let p = RetryPolicy {
            base: Duration::from_millis(1),
            cap: Duration::from_millis(4),
            ..RetryPolicy::default()
        };
        let mut calls = 0u32;
        let out: Result<u32> = p.run(|| {
            calls += 1;
            if calls < 3 {
                Err(anyhow::Error::new(WireError::shed(None, "busy")))
            } else {
                Ok(calls)
            }
        });
        assert_eq!(out.unwrap(), 3);
        let t = batch::stats();
        assert_eq!(t.backoff_retries, 2);
        assert_eq!(t.sheds, 2);
    }

    #[test]
    fn run_surfaces_fatal_failures_immediately() {
        batch::reset_stats();
        let p = RetryPolicy::default();
        let mut calls = 0u32;
        let out: Result<()> = p.run(|| {
            calls += 1;
            Err(anyhow!("schema violation"))
        });
        assert!(out.is_err());
        assert_eq!(calls, 1, "a fatal error must not be retried");
        assert_eq!(batch::stats(), batch::TransferStats::default());
    }

    #[test]
    fn run_exhausts_attempts_on_persistent_transient_failures() {
        batch::reset_stats();
        let p = RetryPolicy {
            max_attempts: 3,
            base: Duration::from_millis(1),
            cap: Duration::from_millis(2),
            ..RetryPolicy::default()
        };
        let mut calls = 0u32;
        let out: Result<()> = p.run(|| {
            calls += 1;
            Err(anyhow::Error::new(WireError::cut("flaky network")))
        });
        assert!(out.is_err());
        assert_eq!(calls, 3);
        assert_eq!(batch::stats().backoff_retries, 2);
        assert_eq!(batch::stats().sheds, 0);
    }

    #[test]
    fn budget_is_shared_not_multiplied() {
        // 3 mirrors under the default 4-attempt policy: 3 guaranteed
        // first tries + 3 shared retries — not 3 × 4 = 12.
        let b = RetryBudget::for_mirrors(3, &RetryPolicy::default());
        assert_eq!(b.remaining(), 6);
        for _ in 0..6 {
            assert!(b.spend());
        }
        assert!(!b.spend(), "an exhausted budget must decline");
        assert!(!b.spend(), "and stay exhausted (no underflow wrap)");
        assert_eq!(b.remaining(), 0);
    }

    #[test]
    fn budget_guarantees_one_try_per_mirror_even_without_retries() {
        let b = RetryBudget::for_mirrors(5, &RetryPolicy::none());
        assert_eq!(b.remaining(), 5);
    }
}
