//! LFS remote transfer: batched have/want negotiation + packed movement.
//!
//! A remote is a directory acting as an LFS server (`<remote>/lfs/objects`).
//! The negotiation API mirrors Git LFS's batch endpoint: the client
//! announces every oid it wants to send or receive in one [`LfsRemote::batch`]
//! call and only missing objects move, so re-pushing a model where most
//! parameter groups are unchanged transfers almost nothing — the
//! network-efficiency property the paper leans on.
//!
//! Movement itself goes through the [`pack`](super::pack) engine by
//! default (one negotiation + one pack for N objects); set
//! `THETA_TRANSFER=object` — or call the `*_per_object` variants — for
//! the legacy engine that copies each object with its own request,
//! kept as the benchmark baseline (`benches/ablation_transfer.rs`).

use super::batch::{self, BatchResponse};
use super::store::LfsStore;
use crate::gitcore::object::Oid;
use anyhow::{bail, Result};
use std::path::Path;

/// Handle to a directory-backed LFS remote.
#[derive(Debug, Clone)]
pub struct LfsRemote {
    store: LfsStore,
}

impl LfsRemote {
    /// Open the LFS area of a directory remote (created lazily on write).
    pub fn open(remote_root: &Path) -> LfsRemote {
        LfsRemote {
            store: LfsStore::at(&remote_root.join("lfs/objects")),
        }
    }

    /// The remote's backing object store.
    pub fn store(&self) -> &LfsStore {
        &self.store
    }

    /// Have/want negotiation: partition `want` into the oids the remote
    /// holds and the oids it lacks, in a single round trip.
    pub fn batch(&self, want: &[Oid]) -> BatchResponse {
        batch::record(|s| s.negotiations += 1);
        let mut resp = BatchResponse::default();
        for oid in want {
            if self.store.contains(oid) {
                resp.present.push(*oid);
            } else {
                resp.missing.push(*oid);
            }
        }
        resp
    }

    /// Which of these oids is the remote missing? (One negotiation.)
    pub fn missing(&self, oids: &[Oid]) -> Vec<Oid> {
        self.batch(oids).missing
    }

    /// Upload objects the remote is missing. Returns (sent, raw bytes).
    ///
    /// Packed by default: one negotiation, then every missing object in
    /// a single integrity-checked pack. Errors (like the per-object
    /// engine) if a wanted object is absent from the local store too.
    pub fn upload(&self, local: &LfsStore, oids: &[Oid]) -> Result<(usize, u64)> {
        if batch::per_object_mode() {
            return self.upload_per_object(local, oids);
        }
        let s = batch::push_pack(local, self, oids)?;
        if s.unavailable > 0 {
            bail!(
                "cannot upload: {} wanted object(s) missing from the local store",
                s.unavailable
            );
        }
        Ok((s.objects, s.raw_bytes))
    }

    /// Legacy upload engine (the seed's behavior): one negotiation for
    /// the whole set, then one copy request per missing object.
    pub fn upload_per_object(&self, local: &LfsStore, oids: &[Oid]) -> Result<(usize, u64)> {
        let mut sent = 0;
        let mut bytes = 0;
        for oid in self.missing(oids) {
            let data = local.get(&oid)?;
            bytes += data.len() as u64;
            self.store.put(&data)?;
            batch::record(|s| {
                s.objects += 1;
                s.object_transfers += 1;
                s.raw_bytes += data.len() as u64;
                s.packed_bytes += data.len() as u64;
            });
            sent += 1;
        }
        Ok((sent, bytes))
    }

    /// Download objects the local store is missing. Returns
    /// (fetched, raw bytes). Packed by default, like [`LfsRemote::upload`];
    /// errors if the remote lacks a requested object.
    pub fn download(&self, local: &LfsStore, oids: &[Oid]) -> Result<(usize, u64)> {
        if batch::per_object_mode() {
            return self.download_per_object(local, oids);
        }
        let s = batch::fetch_pack(self, local, oids)?;
        if s.unavailable > 0 {
            bail!("remote is missing {} requested object(s)", s.unavailable);
        }
        Ok((s.objects, s.raw_bytes))
    }

    /// Legacy download engine (the seed's behavior): one fetch request
    /// per locally missing object.
    pub fn download_per_object(&self, local: &LfsStore, oids: &[Oid]) -> Result<(usize, u64)> {
        let mut fetched = 0;
        let mut bytes = 0;
        for oid in oids {
            if !local.contains(oid) {
                let data = self.store.get(oid)?;
                bytes += data.len() as u64;
                local.put(&data)?;
                batch::record(|s| {
                    s.objects += 1;
                    s.object_transfers += 1;
                    s.raw_bytes += data.len() as u64;
                    s.packed_bytes += data.len() as u64;
                });
                fetched += 1;
            }
        }
        Ok((fetched, bytes))
    }
}

/// Convenience: sync a set of oids from a repo-local store to a remote.
pub fn sync_to_remote(local: &LfsStore, remote_root: &Path, oids: &[Oid]) -> Result<(usize, u64)> {
    LfsRemote::open(remote_root).upload(local, oids)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::tmp::TempDir;

    #[test]
    fn upload_download_dedup() {
        let td_local = TempDir::new("lfs-local").unwrap();
        let td_remote = TempDir::new("lfs-remote").unwrap();
        let local = LfsStore::open(td_local.path());
        let remote = LfsRemote::open(td_remote.path());

        let (a, _) = local.put(b"group-a").unwrap();
        let (b, _) = local.put(b"group-b").unwrap();
        let (sent, bytes) = remote.upload(&local, &[a, b]).unwrap();
        assert_eq!(sent, 2);
        assert_eq!(bytes, 14);

        // Second upload of the same content is free (dedup).
        let (sent2, bytes2) = remote.upload(&local, &[a, b]).unwrap();
        assert_eq!((sent2, bytes2), (0, 0));

        // Fresh clone only downloads what it lacks.
        let td_clone = TempDir::new("lfs-clone").unwrap();
        let clone_store = LfsStore::open(td_clone.path());
        clone_store.put(b"group-a").unwrap(); // already has a
        let (fetched, _) = remote.download(&clone_store, &[a, b]).unwrap();
        assert_eq!(fetched, 1);
        assert_eq!(clone_store.get(&b).unwrap(), b"group-b");
    }

    #[test]
    fn missing_reports_correctly() {
        let td_remote = TempDir::new("lfs-remote").unwrap();
        let td_local = TempDir::new("lfs-local").unwrap();
        let local = LfsStore::open(td_local.path());
        let remote = LfsRemote::open(td_remote.path());
        let (a, _) = local.put(b"x").unwrap();
        let (b, _) = local.put(b"y").unwrap();
        remote.upload(&local, &[a]).unwrap();
        assert_eq!(remote.missing(&[a, b]), vec![b]);
    }

    #[test]
    fn batch_partitions_in_one_round_trip() {
        let td_remote = TempDir::new("lfs-remote").unwrap();
        let remote = LfsRemote::open(td_remote.path());
        let (held, _) = remote.store().put(b"held").unwrap();
        let absent = Oid::of_bytes(b"absent");

        batch::reset_stats();
        let resp = remote.batch(&[held, absent]);
        assert_eq!(resp.present, vec![held]);
        assert_eq!(resp.missing, vec![absent]);
        assert_eq!(batch::stats().negotiations, 1);
    }

    #[test]
    fn packed_and_per_object_engines_agree() {
        let td_local = TempDir::new("lfs-local").unwrap();
        let local = LfsStore::open(td_local.path());
        let oids: Vec<Oid> = (0..10usize)
            .map(|i| local.put(&vec![i as u8; 100 + i]).unwrap().0)
            .collect();

        let td_a = TempDir::new("lfs-packed").unwrap();
        let td_b = TempDir::new("lfs-perobj").unwrap();
        let packed = LfsRemote::open(td_a.path());
        let perobj = LfsRemote::open(td_b.path());
        // Call the engines directly so an ambient THETA_TRANSFER can't
        // change which one each side of the comparison exercises.
        let s = batch::push_pack(&local, &packed, &oids).unwrap();
        let (sent_o, bytes_o) = perobj.upload_per_object(&local, &oids).unwrap();
        assert_eq!((s.objects, s.raw_bytes), (sent_o, bytes_o));
        for oid in &oids {
            assert_eq!(
                packed.store().get(oid).unwrap(),
                perobj.store().get(oid).unwrap()
            );
        }

        // Both download engines restore identical stores.
        let td_c = TempDir::new("lfs-dl-p").unwrap();
        let td_d = TempDir::new("lfs-dl-o").unwrap();
        let c = LfsStore::open(td_c.path());
        let d = LfsStore::open(td_d.path());
        batch::fetch_pack(&packed, &c, &oids).unwrap();
        packed.download_per_object(&d, &oids).unwrap();
        for oid in &oids {
            assert_eq!(c.get(oid).unwrap(), d.get(oid).unwrap());
        }
    }

    #[test]
    fn fewer_round_trips_than_per_object() {
        let td_local = TempDir::new("lfs-local").unwrap();
        let local = LfsStore::open(td_local.path());
        let oids: Vec<Oid> = (0..50)
            .map(|i| local.put(format!("g{i}").as_bytes()).unwrap().0)
            .collect();

        let td_a = TempDir::new("lfs-a").unwrap();
        batch::reset_stats();
        batch::push_pack(&local, &LfsRemote::open(td_a.path()), &oids).unwrap();
        let packed = batch::stats();

        let td_b = TempDir::new("lfs-b").unwrap();
        batch::reset_stats();
        LfsRemote::open(td_b.path())
            .upload_per_object(&local, &oids)
            .unwrap();
        let per_object = batch::stats();

        // Packed: 1 negotiation + 1 pack. Per-object (seed behavior):
        // 1 negotiation + 50 individual copies.
        assert_eq!(packed.round_trips(), 2);
        assert_eq!(per_object.round_trips(), 51);
        assert_eq!(packed.objects, per_object.objects);
    }
}
