//! LFS remote transfer: batch upload/download with content dedup.
//!
//! A remote is a directory acting as an LFS server (`<remote>/lfs/objects`).
//! The batch API mirrors Git LFS's: the client announces the oids it
//! wants to send/receive and only missing objects move, so re-pushing a
//! model where most parameter groups are unchanged transfers almost
//! nothing — the network-efficiency property the paper leans on.

use super::store::LfsStore;
use crate::gitcore::object::Oid;
use anyhow::Result;
use std::path::Path;

/// Handle to a directory-backed LFS remote.
#[derive(Debug, Clone)]
pub struct LfsRemote {
    store: LfsStore,
}

impl LfsRemote {
    pub fn open(remote_root: &Path) -> LfsRemote {
        LfsRemote {
            store: LfsStore::at(&remote_root.join("lfs/objects")),
        }
    }

    pub fn store(&self) -> &LfsStore {
        &self.store
    }

    /// Which of these oids is the remote missing? (Batch API check.)
    pub fn missing(&self, oids: &[Oid]) -> Vec<Oid> {
        oids.iter()
            .filter(|oid| !self.store.contains(oid))
            .copied()
            .collect()
    }

    /// Upload objects the remote is missing. Returns (sent, bytes).
    pub fn upload(&self, local: &LfsStore, oids: &[Oid]) -> Result<(usize, u64)> {
        let mut sent = 0;
        let mut bytes = 0;
        for oid in self.missing(oids) {
            let data = local.get(&oid)?;
            bytes += data.len() as u64;
            self.store.put(&data)?;
            sent += 1;
        }
        Ok((sent, bytes))
    }

    /// Download objects the local store is missing. Returns (fetched, bytes).
    pub fn download(&self, local: &LfsStore, oids: &[Oid]) -> Result<(usize, u64)> {
        let mut fetched = 0;
        let mut bytes = 0;
        for oid in oids {
            if !local.contains(oid) {
                let data = self.store.get(oid)?;
                bytes += data.len() as u64;
                local.put(&data)?;
                fetched += 1;
            }
        }
        Ok((fetched, bytes))
    }
}

/// Convenience: sync a set of oids from a repo-local store to a remote.
pub fn sync_to_remote(local: &LfsStore, remote_root: &Path, oids: &[Oid]) -> Result<(usize, u64)> {
    LfsRemote::open(remote_root).upload(local, oids)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::tmp::TempDir;

    #[test]
    fn upload_download_dedup() {
        let td_local = TempDir::new("lfs-local").unwrap();
        let td_remote = TempDir::new("lfs-remote").unwrap();
        let local = LfsStore::open(td_local.path());
        let remote = LfsRemote::open(td_remote.path());

        let (a, _) = local.put(b"group-a").unwrap();
        let (b, _) = local.put(b"group-b").unwrap();
        let (sent, bytes) = remote.upload(&local, &[a, b]).unwrap();
        assert_eq!(sent, 2);
        assert_eq!(bytes, 14);

        // Second upload of the same content is free (dedup).
        let (sent2, bytes2) = remote.upload(&local, &[a, b]).unwrap();
        assert_eq!((sent2, bytes2), (0, 0));

        // Fresh clone only downloads what it lacks.
        let td_clone = TempDir::new("lfs-clone").unwrap();
        let clone_store = LfsStore::open(td_clone.path());
        clone_store.put(b"group-a").unwrap(); // already has a
        let (fetched, _) = remote.download(&clone_store, &[a, b]).unwrap();
        assert_eq!(fetched, 1);
        assert_eq!(clone_store.get(&b).unwrap(), b"group-b");
    }

    #[test]
    fn missing_reports_correctly() {
        let td_remote = TempDir::new("lfs-remote").unwrap();
        let td_local = TempDir::new("lfs-local").unwrap();
        let local = LfsStore::open(td_local.path());
        let remote = LfsRemote::open(td_remote.path());
        let (a, _) = local.put(b"x").unwrap();
        let (b, _) = local.put(b"y").unwrap();
        remote.upload(&local, &[a]).unwrap();
        assert_eq!(remote.missing(&[a, b]), vec![b]);
    }
}
