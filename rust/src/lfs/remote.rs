//! The directory-backed LFS remote (`<remote>/lfs/objects`).
//!
//! The negotiation API mirrors Git LFS's batch endpoint: the client
//! announces every oid it wants to send or receive in one
//! [`DirRemote::batch`] call and only missing objects move, so
//! re-pushing a model where most parameter groups are unchanged
//! transfers almost nothing — the network-efficiency property the
//! paper leans on.
//!
//! `DirRemote` is one of two [`RemoteTransport`] implementations (the
//! other is [`HttpRemote`](super::http::HttpRemote)); movement goes
//! through the [`pack`](super::pack) engine by default (one
//! negotiation + one pack for N objects). Set `THETA_TRANSFER=object`
//! — or call the `*_per_object` variants — for the legacy engine that
//! copies each object with its own request, kept as the benchmark
//! baseline (`benches/ablation_transfer.rs`).
//!
//! **Failure classification parity.** Directory-remote failures keep
//! their source `std::io::Error` in the error chain (nothing is
//! flattened to a string), so [`retry::classify`](super::retry::classify)
//! applies the same retryable/fatal split here as over HTTP: a missing
//! object or permission problem is fatal on both transports, and
//! [`RetryPolicy`](super::retry::RetryPolicy) makes the same number of
//! attempts whichever transport is underneath
//! (`rust/tests/remote_parity.rs` pins this). A local filesystem never
//! legitimately sheds or times out, so no directory-remote error ever
//! classifies as retryable — retrying a disk error would just repeat
//! it.

use super::batch::{self, BatchResponse};
use super::pack::{self, DeltaPlan, PackStats, PlanCache};
use super::store::LfsStore;
use super::transport::{self, ChainAdvert, ChainNegotiation, RemoteTransport, WireReport};
use crate::gitcore::object::Oid;
use anyhow::Result;
use std::path::Path;
use std::sync::Arc;

/// Handle to a directory-backed LFS remote.
#[derive(Debug, Clone)]
pub struct DirRemote {
    store: LfsStore,
    /// Memoized delta encodings for the responder side of chain-aware
    /// fetches (shared across clones of this handle, like a server
    /// process would share it across requests).
    plan_cache: Arc<PlanCache>,
}

/// Compatibility alias: the seed named the (then only) remote kind
/// `LfsRemote`. New code should name the transport it means.
pub type LfsRemote = DirRemote;

impl DirRemote {
    /// Open the LFS area of a directory remote (created lazily on write).
    pub fn open(remote_root: &Path) -> DirRemote {
        DirRemote {
            store: LfsStore::at(&remote_root.join("lfs/objects")),
            plan_cache: Arc::new(PlanCache::new()),
        }
    }

    /// The remote's backing object store.
    pub fn store(&self) -> &LfsStore {
        &self.store
    }

    /// The responder-side delta plan cache (hit/miss counters included),
    /// for tests and metrics parity with the HTTP server.
    pub fn plan_cache(&self) -> &PlanCache {
        &self.plan_cache
    }

    /// Have/want negotiation: partition `want` into the oids the remote
    /// holds and the oids it lacks, in a single round trip (and at most
    /// one directory scan, sizes included — see [`LfsStore::stat_all`]).
    pub fn batch(&self, want: &[Oid]) -> BatchResponse {
        batch::record(|s| s.negotiations += 1);
        let mut resp = BatchResponse::default();
        for (oid, stat) in want.iter().zip(self.store.stat_all(want)) {
            match stat {
                Some(size) => {
                    resp.present.push(*oid);
                    resp.present_sizes.push(size);
                }
                None => resp.missing.push(*oid),
            }
        }
        resp
    }

    /// Which of these oids is the remote missing? (One negotiation.)
    pub fn missing(&self, oids: &[Oid]) -> Vec<Oid> {
        self.batch(oids).missing
    }

    /// Upload objects the remote is missing. Returns (sent, raw bytes).
    ///
    /// Packed by default: one negotiation, then every missing object in
    /// a single integrity-checked pack. Errors (like the per-object
    /// engine) if a wanted object is absent from the local store too.
    pub fn upload(&self, local: &LfsStore, oids: &[Oid]) -> Result<(usize, u64)> {
        transport::upload(local, self, oids)
    }

    /// Legacy upload engine (the seed's behavior): one negotiation for
    /// the whole set, then one copy request per missing object.
    pub fn upload_per_object(&self, local: &LfsStore, oids: &[Oid]) -> Result<(usize, u64)> {
        transport::upload_per_object(local, self, oids)
    }

    /// Download objects the local store is missing. Returns
    /// (fetched, raw bytes). Packed by default, like
    /// [`DirRemote::upload`]; errors if the remote lacks a requested
    /// object.
    pub fn download(&self, local: &LfsStore, oids: &[Oid]) -> Result<(usize, u64)> {
        transport::download(self, local, oids)
    }

    /// Legacy download engine (the seed's behavior): one fetch request
    /// per locally missing object.
    pub fn download_per_object(&self, local: &LfsStore, oids: &[Oid]) -> Result<(usize, u64)> {
        transport::download_per_object(self, local, oids)
    }
}

impl RemoteTransport for DirRemote {
    fn describe(&self) -> String {
        format!("dir:{}", self.store.root().display())
    }

    fn batch(&self, want: &[Oid]) -> Result<BatchResponse> {
        Ok(DirRemote::batch(self, want))
    }

    fn list_oids(&self) -> Result<Option<Vec<Oid>>> {
        let mut oids = self.store.list()?;
        oids.sort();
        Ok(Some(oids))
    }

    fn negotiate_chains(&self, adv: &ChainAdvert) -> Result<ChainNegotiation> {
        batch::record(|s| s.negotiations += 1);
        Ok(transport::answer_chains(&self.store, adv))
    }

    fn send_pack_with_bases(
        &self,
        src: &LfsStore,
        plan: &DeltaPlan,
        threads: usize,
    ) -> Result<(PackStats, WireReport)> {
        let spill = crate::util::tmp::TempDir::new("dirpack")?;
        let path = spill.join("pack");
        let built = pack::write_delta_pack_file(src, plan, threads, &path)?;
        let check = pack::PackCheck {
            id: built.id,
            len: built.len,
            objects: built.objects as u64,
        };
        let stats = pack::unpack_verified(&path, &self.store, threads, &check)?;
        let report = WireReport {
            wire_bytes: built.len,
            resumed_bytes: 0,
        };
        Ok((stats, report))
    }

    fn fetch_pack_into(
        &self,
        oids: &[Oid],
        dest: &LfsStore,
        threads: usize,
    ) -> Result<(PackStats, WireReport)> {
        stream_between(&self.store, dest, oids, threads)
    }

    fn fetch_pack_with_chains(
        &self,
        adv: &ChainAdvert,
        dest: &LfsStore,
        threads: usize,
    ) -> Result<(PackStats, WireReport)> {
        let plan = transport::plan_fetch_deltas(&self.store, adv, threads, Some(&self.plan_cache))?;
        if plan.deltas.is_empty() {
            // Nothing worth encoding — ship the byte-identical flat pack.
            return self.fetch_pack_into(&adv.want, dest, threads);
        }
        let spill = crate::util::tmp::TempDir::new("dirpack")?;
        let path = spill.join("pack");
        let built = pack::write_delta_pack_file(&self.store, &plan, threads, &path)?;
        let check = pack::PackCheck {
            id: built.id,
            len: built.len,
            objects: built.objects as u64,
        };
        let stats = pack::unpack_verified(&path, dest, threads, &check)?;
        let report = WireReport {
            wire_bytes: built.len,
            resumed_bytes: 0,
        };
        Ok((stats, report))
    }

    fn send_pack_from(
        &self,
        src: &LfsStore,
        oids: &[Oid],
        threads: usize,
    ) -> Result<(PackStats, WireReport)> {
        stream_between(src, &self.store, oids, threads)
    }

    fn get_object(&self, oid: &Oid) -> Result<Vec<u8>> {
        self.store.get(oid)
    }

    fn put_object(&self, bytes: &[u8]) -> Result<()> {
        self.store.put(bytes).map(|_| ())
    }
}

/// Move `oids` between two local stores as a pack, streaming through a
/// spill file: the "wire" of a directory remote is the filesystem, and
/// the pack is never RAM-resident — same bounded-memory profile (and
/// byte-identical pack accounting) as the HTTP transport.
fn stream_between(
    src: &LfsStore,
    dest: &LfsStore,
    oids: &[Oid],
    threads: usize,
) -> Result<(PackStats, WireReport)> {
    let spill = crate::util::tmp::TempDir::new("dirpack")?;
    let path = spill.join("pack");
    let built = pack::write_pack_file(src, oids, threads, &path)?;
    // The writer just produced (and hashed) this file, so its summary
    // doubles as the verification certificate — no second full-file
    // checksum pass; per-record oid re-hashing still gates admission.
    let check = pack::PackCheck {
        id: built.id,
        len: built.len,
        objects: built.objects as u64,
    };
    let stats = pack::unpack_verified(&path, dest, threads, &check)?;
    let report = WireReport {
        wire_bytes: built.len,
        resumed_bytes: 0,
    };
    Ok((stats, report))
}

/// Convenience: sync a set of oids from a repo-local store to a remote.
pub fn sync_to_remote(local: &LfsStore, remote_root: &Path, oids: &[Oid]) -> Result<(usize, u64)> {
    DirRemote::open(remote_root).upload(local, oids)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::lfs::store;
    use crate::util::tmp::TempDir;

    #[test]
    fn upload_download_dedup() {
        let td_local = TempDir::new("lfs-local").unwrap();
        let td_remote = TempDir::new("lfs-remote").unwrap();
        let local = LfsStore::open(td_local.path());
        let remote = LfsRemote::open(td_remote.path());

        let (a, _) = local.put(b"group-a").unwrap();
        let (b, _) = local.put(b"group-b").unwrap();
        let (sent, bytes) = remote.upload(&local, &[a, b]).unwrap();
        assert_eq!(sent, 2);
        assert_eq!(bytes, 14);

        // Second upload of the same content is free (dedup).
        let (sent2, bytes2) = remote.upload(&local, &[a, b]).unwrap();
        assert_eq!((sent2, bytes2), (0, 0));

        // Fresh clone only downloads what it lacks.
        let td_clone = TempDir::new("lfs-clone").unwrap();
        let clone_store = LfsStore::open(td_clone.path());
        clone_store.put(b"group-a").unwrap(); // already has a
        let (fetched, _) = remote.download(&clone_store, &[a, b]).unwrap();
        assert_eq!(fetched, 1);
        assert_eq!(clone_store.get(&b).unwrap(), b"group-b");
    }

    #[test]
    fn missing_reports_correctly() {
        let td_remote = TempDir::new("lfs-remote").unwrap();
        let td_local = TempDir::new("lfs-local").unwrap();
        let local = LfsStore::open(td_local.path());
        let remote = LfsRemote::open(td_remote.path());
        let (a, _) = local.put(b"x").unwrap();
        let (b, _) = local.put(b"y").unwrap();
        remote.upload(&local, &[a]).unwrap();
        assert_eq!(remote.missing(&[a, b]), vec![b]);
    }

    #[test]
    fn batch_partitions_in_one_round_trip() {
        let td_remote = TempDir::new("lfs-remote").unwrap();
        let remote = LfsRemote::open(td_remote.path());
        let (held, _) = remote.store().put(b"held").unwrap();
        let absent = Oid::of_bytes(b"absent");

        batch::reset_stats();
        let resp = remote.batch(&[held, absent]);
        assert_eq!(resp.present, vec![held]);
        assert_eq!(resp.present_sizes, vec![4]);
        assert_eq!(resp.missing, vec![absent]);
        assert_eq!(batch::stats().negotiations, 1);
    }

    #[test]
    fn negotiation_of_many_oids_is_one_directory_scan() {
        let td_remote = TempDir::new("lfs-remote").unwrap();
        let remote = LfsRemote::open(td_remote.path());
        let mut want: Vec<Oid> = (0..32u8)
            .map(|i| remote.store().put(&[i; 8]).unwrap().0)
            .collect();
        want.push(Oid::of_bytes(b"ghost-1"));
        want.push(Oid::of_bytes(b"ghost-2"));

        batch::reset_stats();
        let scans_before = store::dir_scans();
        let resp = remote.batch(&want);
        assert_eq!(batch::stats().negotiations, 1);
        assert_eq!(
            store::dir_scans() - scans_before,
            1,
            "one negotiation must cost one store scan, not O(want)"
        );
        assert_eq!(resp.present.len(), 32);
        assert_eq!(resp.missing.len(), 2);
    }

    #[test]
    fn chain_negotiation_reports_held_prefix_depth() {
        use crate::lfs::transport::ChainEntryAdvert;
        let td_remote = TempDir::new("lfs-remote").unwrap();
        let remote = LfsRemote::open(td_remote.path());
        let (a, _) = remote.store().put(b"depth-0").unwrap();
        let (b, _) = remote.store().put(b"depth-1").unwrap();
        let c = Oid::of_bytes(b"depth-2-missing");

        let chain = vec![
            ChainEntryAdvert {
                key: Oid::of_bytes(b"k0"),
                oids: vec![a],
            },
            ChainEntryAdvert {
                key: Oid::of_bytes(b"k1"),
                oids: vec![b],
            },
            ChainEntryAdvert {
                key: Oid::of_bytes(b"k2"),
                oids: vec![c],
            },
        ];
        let adv = ChainAdvert {
            chains: vec![chain],
            want: vec![c],
        };
        batch::reset_stats();
        let scans_before = store::dir_scans();
        let neg = remote.negotiate_chains(&adv).unwrap();
        assert!(neg.chain_aware);
        assert_eq!(neg.have_depths, vec![2]);
        assert_eq!(neg.batch.missing, vec![c]);
        assert_eq!(batch::stats().negotiations, 1);
        assert_eq!(
            store::dir_scans() - scans_before,
            1,
            "chain negotiation must stay one store scan, not O(oids)"
        );
    }

    #[test]
    fn packed_and_per_object_engines_agree() {
        let td_local = TempDir::new("lfs-local").unwrap();
        let local = LfsStore::open(td_local.path());
        let oids: Vec<Oid> = (0..10usize)
            .map(|i| local.put(&vec![i as u8; 100 + i]).unwrap().0)
            .collect();

        let td_a = TempDir::new("lfs-packed").unwrap();
        let td_b = TempDir::new("lfs-perobj").unwrap();
        let packed = LfsRemote::open(td_a.path());
        let perobj = LfsRemote::open(td_b.path());
        // Call the engines directly so an ambient THETA_TRANSFER can't
        // change which one each side of the comparison exercises.
        let s = batch::push_pack(&local, &packed, &oids).unwrap();
        let (sent_o, bytes_o) = perobj.upload_per_object(&local, &oids).unwrap();
        assert_eq!((s.objects, s.raw_bytes), (sent_o, bytes_o));
        for oid in &oids {
            assert_eq!(
                packed.store().get(oid).unwrap(),
                perobj.store().get(oid).unwrap()
            );
        }

        // Both download engines restore identical stores.
        let td_c = TempDir::new("lfs-dl-p").unwrap();
        let td_d = TempDir::new("lfs-dl-o").unwrap();
        let c = LfsStore::open(td_c.path());
        let d = LfsStore::open(td_d.path());
        batch::fetch_pack(&packed, &c, &oids).unwrap();
        packed.download_per_object(&d, &oids).unwrap();
        for oid in &oids {
            assert_eq!(c.get(oid).unwrap(), d.get(oid).unwrap());
        }
    }

    #[test]
    fn fewer_round_trips_than_per_object() {
        let td_local = TempDir::new("lfs-local").unwrap();
        let local = LfsStore::open(td_local.path());
        let oids: Vec<Oid> = (0..50)
            .map(|i| local.put(format!("g{i}").as_bytes()).unwrap().0)
            .collect();

        let td_a = TempDir::new("lfs-a").unwrap();
        batch::reset_stats();
        batch::push_pack(&local, &LfsRemote::open(td_a.path()), &oids).unwrap();
        let packed = batch::stats();

        let td_b = TempDir::new("lfs-b").unwrap();
        batch::reset_stats();
        LfsRemote::open(td_b.path())
            .upload_per_object(&local, &oids)
            .unwrap();
        let per_object = batch::stats();

        // Packed: 1 negotiation + 1 pack. Per-object (seed behavior):
        // 1 negotiation + 50 individual copies.
        assert_eq!(packed.round_trips(), 2);
        assert_eq!(per_object.round_trips(), 51);
        assert_eq!(packed.objects, per_object.objects);
    }
}
