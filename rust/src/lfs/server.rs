//! `git-theta serve`: a dependency-free HTTP remote server.
//!
//! Serves a remote root over `std::net::TcpListener` so pushes and
//! fetches can cross a real network channel. The root uses the same
//! layout as a directory remote — `objects/` (odb), `refs/heads/` +
//! `HEAD`, `lfs/objects/` — so a directory remote can be promoted to
//! an HTTP remote by pointing `git-theta serve` at it.
//!
//! Endpoints (client halves: [`HttpRemote`](super::http::HttpRemote),
//! `gitcore::remote::HttpEndpoint`):
//!
//! ```text
//! POST   /objects/batch   have/want negotiation  -> present/sizes/missing
//! POST   /packs           build+cache a pack for a want set -> {id,size}
//! GET    /packs/<id>      download (Range: bytes=k- resumes)
//! HEAD   /packs/<id>      upload-resume probe -> X-Received: <bytes>
//! PUT    /packs/<id>      upload (Content-Range); partial bodies persist
//! DELETE /packs/<id>      drop cached/partial pack state
//! GET/PUT /objects/<oid>  per-object fallback
//! GET/HEAD/PUT /odb/<oid>, POST /odb/batch, GET/PUT /refs/<name>,
//! GET /history/<tip>?exclude=..   commit/ref sync
//! ```
//!
//! Durability and dedup: an interrupted `PUT /packs/<id>` leaves its
//! received prefix in `lfs/partial/<id>` — the retry HEAD-probes and
//! sends only the tail. A completed pack is admitted object-by-object
//! through [`LfsStore::put`], which is content-addressed on sha256, so
//! re-uploads (and objects shared between packs) deduplicate
//! server-side; a pack that fails its checksum or id is discarded
//! whole and poisons nothing.

use super::pack;
use super::store::LfsStore;
use crate::gitcore::mergebase::commits_between;
use crate::gitcore::object::{Object, Oid};
use crate::gitcore::odb::Odb;
use crate::gitcore::refs::Refs;
use crate::util::http::{self, Request, Response};
use crate::util::json::{Json, JsonObj};
use anyhow::{Context, Result};
use std::net::{SocketAddr, TcpListener, TcpStream};
use std::path::{Path, PathBuf};
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::{Arc, Mutex};

/// Worker threads used for server-side pack assembly/fan-in. Kept
/// small: each connection already runs on its own thread.
const PACK_THREADS: usize = 2;

/// Unique suffix for write-then-rename temp files: two connections can
/// build the same pack concurrently, and a shared temp path would let
/// one writer rename the other's half-written file into place.
static TMP_SEQ: std::sync::atomic::AtomicU64 = std::sync::atomic::AtomicU64::new(0);

fn unique_tmp(path: &Path) -> PathBuf {
    let seq = TMP_SEQ.fetch_add(1, Ordering::Relaxed);
    path.with_extension(format!("tmp{}-{seq}", std::process::id()))
}

struct ServerState {
    root: PathBuf,
    store: LfsStore,
    odb: Odb,
    refs: Refs,
    /// Serializes ref compare-and-set.
    refs_lock: Mutex<()>,
    /// Serializes partial-pack append/finalize per server.
    partial_lock: Mutex<()>,
}

/// A running LFS + commit/ref server. Shuts down on drop.
pub struct LfsServer {
    addr: SocketAddr,
    stop: Arc<AtomicBool>,
    accept_thread: Option<std::thread::JoinHandle<()>>,
}

impl LfsServer {
    /// Serve `root` on an ephemeral localhost port.
    pub fn spawn(root: &Path) -> Result<LfsServer> {
        LfsServer::spawn_on(root, "127.0.0.1:0")
    }

    /// Serve `root` on an explicit `host:port` bind address.
    pub fn spawn_on(root: &Path, bind: &str) -> Result<LfsServer> {
        std::fs::create_dir_all(root.join("refs/heads"))?;
        let odb = Odb::init(root)?;
        if !root.join("HEAD").exists() {
            Refs::init(root, "main")?;
        }
        let state = Arc::new(ServerState {
            root: root.to_path_buf(),
            store: LfsStore::at(&root.join("lfs/objects")),
            odb,
            refs: Refs::open(root),
            refs_lock: Mutex::new(()),
            partial_lock: Mutex::new(()),
        });
        let listener = TcpListener::bind(bind)
            .with_context(|| format!("binding lfs server to {bind}"))?;
        let addr = listener.local_addr()?;
        let stop = Arc::new(AtomicBool::new(false));
        let stop2 = stop.clone();
        let accept_thread = std::thread::spawn(move || {
            for conn in listener.incoming() {
                if stop2.load(Ordering::SeqCst) {
                    break;
                }
                if let Ok(stream) = conn {
                    let state = state.clone();
                    std::thread::spawn(move || handle_connection(stream, &state));
                }
            }
        });
        Ok(LfsServer {
            addr,
            stop,
            accept_thread: Some(accept_thread),
        })
    }

    /// The bound socket address.
    pub fn addr(&self) -> SocketAddr {
        self.addr
    }

    /// The `http://` URL clients should use as their remote.
    pub fn url(&self) -> String {
        format!("http://{}", self.addr)
    }
}

impl Drop for LfsServer {
    fn drop(&mut self) {
        self.stop.store(true, Ordering::SeqCst);
        // Unblock the accept loop with a throwaway connection.
        let _ = TcpStream::connect(self.addr);
        if let Some(handle) = self.accept_thread.take() {
            let _ = handle.join();
        }
    }
}

fn handle_connection(mut stream: TcpStream, state: &ServerState) {
    stream.set_read_timeout(Some(http::IO_TIMEOUT)).ok();
    stream.set_write_timeout(Some(http::IO_TIMEOUT)).ok();
    stream.set_nodelay(true).ok();
    let (req, complete) = match http::read_request(&mut stream) {
        Ok(v) => v,
        Err(_) => return, // head never completed; nothing to answer
    };
    if let Some(resp) = route(state, &req, complete) {
        let _ = http::write_response(&mut stream, &resp);
    }
}

fn text(status: u16, body: impl Into<String>) -> Response {
    Response::new(status).body(body.into().into_bytes())
}

fn json_response(obj: JsonObj) -> Response {
    Response::new(200)
        .header("content-type", "application/json")
        .body(Json::Obj(obj).to_string_compact().into_bytes())
}

fn parse_want(req: &Request) -> Result<Vec<Oid>> {
    let json = Json::parse(&String::from_utf8_lossy(&req.body)).context("parsing request json")?;
    json.get("want")
        .and_then(|v| v.as_arr())
        .context("request missing 'want'")?
        .iter()
        .map(|v| Oid::from_hex(v.as_str().context("non-string oid")?))
        .collect()
}

fn oid_arr(oids: &[Oid]) -> Json {
    Json::Arr(oids.iter().map(|o| Json::from(o.to_hex())).collect())
}

fn is_hex_id(s: &str) -> bool {
    s.len() == 64 && s.bytes().all(|b| b.is_ascii_hexdigit())
}

/// Dispatch one request. `None` means "no response" — the connection
/// died mid-upload and the received prefix was persisted for resume.
fn route(state: &ServerState, req: &Request, complete: bool) -> Option<Response> {
    let path = req.path();
    let method = req.method.as_str();

    if method == "PUT" {
        if let Some(id) = path.strip_prefix("/packs/") {
            return pack_put(state, id, req, complete);
        }
    }
    if !complete {
        // Every other endpoint needs its full body; the peer is gone
        // anyway, so drop the connection without a response.
        return None;
    }

    let result = dispatch(state, method, path, req);
    Some(result.unwrap_or_else(|e| text(500, format!("{e:#}"))))
}

fn dispatch(state: &ServerState, method: &str, path: &str, req: &Request) -> Result<Response> {
    Ok(match (method, path) {
        ("POST", "/objects/batch") => objects_batch(state, req)?,
        ("POST", "/packs") => pack_create(state, req)?,
        ("POST", "/odb/batch") => odb_batch(state, req)?,
        _ => {
            if let Some(id) = path.strip_prefix("/packs/") {
                pack_misc(state, method, id, req)?
            } else if let Some(hex) = path.strip_prefix("/objects/") {
                object_endpoint(state, method, hex, req)?
            } else if let Some(hex) = path.strip_prefix("/odb/") {
                odb_endpoint(state, method, hex, req)?
            } else if let Some(name) = path.strip_prefix("/refs/") {
                refs_endpoint(state, method, name, req)?
            } else if let Some(hex) = path.strip_prefix("/history/") {
                history_endpoint(state, hex, req)?
            } else {
                text(404, format!("no route for {method} {path}"))
            }
        }
    })
}

fn objects_batch(state: &ServerState, req: &Request) -> Result<Response> {
    let want = match parse_want(req) {
        Ok(w) => w,
        Err(e) => return Ok(text(400, format!("{e:#}"))),
    };
    let mut present = Vec::new();
    let mut sizes = Vec::new();
    let mut missing = Vec::new();
    for (oid, held) in want.iter().zip(state.store.contains_all(&want)) {
        if held {
            present.push(*oid);
            sizes.push(state.store.size_of(oid).unwrap_or(0));
        } else {
            missing.push(*oid);
        }
    }
    let mut obj = JsonObj::new();
    obj.insert("present", oid_arr(&present));
    obj.insert("sizes", Json::Arr(sizes.into_iter().map(Json::from).collect()));
    obj.insert("missing", oid_arr(&missing));
    Ok(json_response(obj))
}

fn outgoing_path(state: &ServerState, id: &str) -> PathBuf {
    state.root.join("lfs/outgoing").join(id)
}

fn partial_path(state: &ServerState, id: &str) -> PathBuf {
    state.root.join("lfs/partial").join(id)
}

/// Memo path for a want set: `lfs/outgoing/bywant/<sha256 of the
/// sorted want hexes>`, holding `"<pack id> <size>"`. Pack contents
/// are a pure function of the wanted oids (content-addressed), so a
/// memo hit can never serve stale bytes — at worst the cached pack
/// file was reaped, which falls back to a rebuild.
fn want_memo_path(state: &ServerState, want: &[Oid]) -> PathBuf {
    use sha2::{Digest, Sha256};
    let mut sorted: Vec<Oid> = want.to_vec();
    sorted.sort();
    sorted.dedup();
    let mut h = Sha256::new();
    for oid in &sorted {
        h.update(oid.0);
    }
    let digest: [u8; 32] = h.finalize().into();
    state
        .root
        .join("lfs/outgoing/bywant")
        .join(crate::util::hex::encode(&digest))
}

fn pack_create(state: &ServerState, req: &Request) -> Result<Response> {
    let want = match parse_want(req) {
        Ok(w) => w,
        Err(e) => return Ok(text(400, format!("{e:#}"))),
    };
    // A retry of an interrupted download re-POSTs the same want set;
    // answer from the memo instead of recompressing the whole pack.
    let memo = want_memo_path(state, &want);
    if let Ok(entry) = std::fs::read_to_string(&memo) {
        if let Some((id, size)) = entry.trim().split_once(' ') {
            if is_hex_id(id) && outgoing_path(state, id).exists() {
                let mut obj = JsonObj::new();
                obj.insert("id", id);
                obj.insert("size", size.parse::<u64>().unwrap_or(0));
                return Ok(json_response(obj));
            }
        }
    }
    let blob = match pack::build_pack(&state.store, &want, PACK_THREADS) {
        Ok(b) => b,
        Err(e) => return Ok(text(422, format!("cannot assemble pack: {e:#}"))),
    };
    let id = pack::pack_id(&blob);
    let path = outgoing_path(state, &id);
    if !path.exists() {
        std::fs::create_dir_all(path.parent().unwrap())?;
        let tmp = unique_tmp(&path);
        std::fs::write(&tmp, &blob)?;
        std::fs::rename(&tmp, &path)?;
    }
    std::fs::create_dir_all(memo.parent().unwrap())?;
    let tmp = unique_tmp(&memo);
    std::fs::write(&tmp, format!("{id} {}", blob.len()))?;
    std::fs::rename(&tmp, &memo)?;
    let mut obj = JsonObj::new();
    obj.insert("id", id);
    obj.insert("size", blob.len() as u64);
    Ok(json_response(obj))
}

fn parse_range(header: Option<&str>) -> Option<u64> {
    header?
        .strip_prefix("bytes=")?
        .strip_suffix('-')?
        .parse::<u64>()
        .ok()
}

/// GET (download, with Range resume), HEAD (upload-resume probe), and
/// DELETE for `/packs/<id>`.
fn pack_misc(state: &ServerState, method: &str, id: &str, req: &Request) -> Result<Response> {
    if !is_hex_id(id) {
        return Ok(text(400, "pack ids are 64 hex chars"));
    }
    match method {
        "GET" => {
            let bytes = match std::fs::read(outgoing_path(state, id)) {
                Ok(b) => b,
                Err(_) => return Ok(text(404, "unknown pack")),
            };
            let total = bytes.len() as u64;
            match parse_range(req.get_header("range")) {
                None => Ok(Response::new(200).body(bytes)),
                Some(k) if k < total => Ok(Response::new(206)
                    .header("content-range", &format!("bytes {k}-{}/{total}", total - 1))
                    .body(bytes[k as usize..].to_vec())),
                Some(_) => Ok(text(416, "range starts at or past the end of the pack")),
            }
        }
        "HEAD" => {
            let have = std::fs::metadata(partial_path(state, id))
                .map(|m| m.len())
                .unwrap_or(0);
            Ok(Response::new(200).header("x-received", &have.to_string()))
        }
        "DELETE" => {
            let _ = std::fs::remove_file(outgoing_path(state, id));
            let _ = std::fs::remove_file(partial_path(state, id));
            Ok(text(200, "gone"))
        }
        _ => Ok(text(404, "unsupported pack method")),
    }
}

/// `Content-Range: bytes a-b/t` -> (a, t); `bytes */t` -> (None, t).
fn parse_content_range(header: Option<&str>) -> Option<(Option<u64>, u64)> {
    let rest = header?.strip_prefix("bytes ")?;
    let (range, total) = rest.split_once('/')?;
    let total = total.parse::<u64>().ok()?;
    if range == "*" {
        return Some((None, total));
    }
    let (start, _end) = range.split_once('-')?;
    Some((Some(start.parse::<u64>().ok()?), total))
}

/// Resumable pack upload: append-at-offset with partial persistence.
///
/// This is the *server half* of push resume. The body may be
/// incomplete (`complete == false`): whatever prefix arrived is
/// appended and persisted, no response is written (the peer is gone),
/// and the client's retry HEAD-probes `X-Received` to send only the
/// tail. On completion the pack is id- and checksum-verified, then
/// fanned into the store (sha256 dedup per object).
fn pack_put(state: &ServerState, id: &str, req: &Request, complete: bool) -> Option<Response> {
    if !is_hex_id(id) {
        return Some(text(400, "pack ids are 64 hex chars"));
    }
    let (offset, total) = match parse_content_range(req.get_header("content-range")) {
        Some(v) => v,
        None => return Some(text(400, "PUT /packs needs a content-range header")),
    };
    let path = partial_path(state, id);
    let _guard = state.partial_lock.lock().unwrap();
    let have = std::fs::metadata(&path).map(|m| m.len()).unwrap_or(0);
    let offset = offset.unwrap_or(have);
    if offset != have {
        return Some(
            text(409, "resume offset does not match the persisted partial")
                .header("x-received", &have.to_string()),
        );
    }
    if !req.body.is_empty() {
        use std::io::Write;
        let append = || -> Result<()> {
            std::fs::create_dir_all(path.parent().unwrap())?;
            let mut f = std::fs::OpenOptions::new().create(true).append(true).open(&path)?;
            f.write_all(&req.body)?;
            Ok(())
        };
        if let Err(e) = append() {
            return Some(text(500, format!("persisting pack body: {e:#}")));
        }
    }
    let now = have + req.body.len() as u64;
    if !complete {
        // Connection died mid-body. The prefix is on disk; the retry
        // resumes from it. Nobody is listening for a response.
        return None;
    }
    if now < total {
        return Some(text(202, "partial accepted").header("x-received", &now.to_string()));
    }
    // Complete: move the body out from under the lock, so the verify +
    // store fan-in (the expensive part) doesn't serialize unrelated
    // concurrent pack uploads on the one partial_lock.
    let fin = unique_tmp(&path);
    if let Err(e) = std::fs::rename(&path, &fin) {
        return Some(text(500, format!("finalizing pack body: {e:#}")));
    }
    drop(_guard);
    let finalize = || -> Result<Response> {
        let blob = std::fs::read(&fin)?;
        if now > total || pack::pack_id(&blob) != id {
            let _ = std::fs::remove_file(&fin);
            return Ok(text(422, "pack does not match its declared id"));
        }
        match pack::unpack_into(&state.store, &blob, PACK_THREADS) {
            Ok(stats) => {
                let _ = std::fs::remove_file(&fin);
                let mut obj = JsonObj::new();
                obj.insert("objects", stats.objects);
                obj.insert("raw_bytes", stats.raw_bytes);
                Ok(json_response(obj))
            }
            Err(e) => {
                let _ = std::fs::remove_file(&fin);
                Ok(text(422, format!("pack verification failed: {e:#}")))
            }
        }
    };
    Some(finalize().unwrap_or_else(|e| text(500, format!("{e:#}"))))
}

fn object_endpoint(
    state: &ServerState,
    method: &str,
    hex: &str,
    req: &Request,
) -> Result<Response> {
    let oid = match Oid::from_hex(hex) {
        Ok(o) => o,
        Err(_) => return Ok(text(400, "bad object id")),
    };
    match method {
        "GET" => match state.store.get(&oid) {
            Ok(bytes) => Ok(Response::new(200).body(bytes)),
            Err(_) => Ok(text(404, "object not found")),
        },
        "PUT" => {
            if Oid::of_bytes(&req.body) != oid {
                return Ok(text(422, "object body does not hash to its id"));
            }
            state.store.put(&req.body)?;
            Ok(text(200, "stored"))
        }
        _ => Ok(text(404, "unsupported object method")),
    }
}

fn odb_batch(state: &ServerState, req: &Request) -> Result<Response> {
    let want = match parse_want(req) {
        Ok(w) => w,
        Err(e) => return Ok(text(400, format!("{e:#}"))),
    };
    let mut present = Vec::new();
    let mut missing = Vec::new();
    for oid in want {
        if state.odb.contains(&oid) {
            present.push(oid);
        } else {
            missing.push(oid);
        }
    }
    let mut obj = JsonObj::new();
    obj.insert("present", oid_arr(&present));
    obj.insert("missing", oid_arr(&missing));
    Ok(json_response(obj))
}

fn odb_endpoint(state: &ServerState, method: &str, hex: &str, req: &Request) -> Result<Response> {
    let oid = match Oid::from_hex(hex) {
        Ok(o) => o,
        Err(_) => return Ok(text(400, "bad object id")),
    };
    match method {
        "GET" => match state.odb.read(&oid) {
            Ok(obj) => Ok(Response::new(200).body(obj.encode())),
            Err(_) => Ok(text(404, "object not found")),
        },
        "HEAD" => {
            if state.odb.contains(&oid) {
                Ok(Response::new(200))
            } else {
                Ok(text(404, ""))
            }
        }
        "PUT" => {
            if Oid::of_bytes(&req.body) != oid {
                return Ok(text(422, "object body does not hash to its id"));
            }
            let obj = match Object::decode(&req.body) {
                Ok(o) => o,
                Err(e) => return Ok(text(422, format!("undecodable object: {e:#}"))),
            };
            state.odb.write(&obj)?;
            Ok(text(200, "stored"))
        }
        _ => Ok(text(404, "unsupported odb method")),
    }
}

fn refs_endpoint(state: &ServerState, method: &str, name: &str, req: &Request) -> Result<Response> {
    match method {
        "GET" => match state.refs.branch(name) {
            Ok(Some(oid)) => Ok(text(200, oid.to_hex())),
            Ok(None) => Ok(text(404, "no such branch")),
            Err(e) => Ok(text(400, format!("{e:#}"))),
        },
        "PUT" => {
            let body = String::from_utf8_lossy(&req.body).to_string();
            let (old, new) = match body.trim().split_once(' ') {
                Some(v) => v,
                None => return Ok(text(400, "ref update body is '<old|none> <new>'")),
            };
            let expected = if old == "none" {
                None
            } else {
                match Oid::from_hex(old) {
                    Ok(o) => Some(o),
                    Err(_) => return Ok(text(400, "bad old oid")),
                }
            };
            let new = match Oid::from_hex(new) {
                Ok(o) => o,
                Err(_) => return Ok(text(400, "bad new oid")),
            };
            let _guard = state.refs_lock.lock().unwrap();
            let current = match state.refs.branch(name) {
                Ok(c) => c,
                Err(e) => return Ok(text(400, format!("{e:#}"))),
            };
            if current != expected {
                let held = match current {
                    Some(oid) => oid.to_hex(),
                    None => "none".to_string(),
                };
                return Ok(text(409, held));
            }
            state.refs.set_branch(name, &new)?;
            Ok(text(200, "updated"))
        }
        _ => Ok(text(404, "unsupported refs method")),
    }
}

fn history_endpoint(state: &ServerState, hex: &str, req: &Request) -> Result<Response> {
    let tip = match Oid::from_hex(hex) {
        Ok(o) => o,
        Err(_) => return Ok(text(400, "bad tip oid")),
    };
    let mut exclude = Vec::new();
    if let Some(query) = req.query() {
        for pair in query.split('&') {
            if let Some(csv) = pair.strip_prefix("exclude=") {
                for part in csv.split(',').filter(|p| !p.is_empty()) {
                    match Oid::from_hex(part) {
                        Ok(o) => exclude.push(o),
                        Err(_) => return Ok(text(400, "bad exclude oid")),
                    }
                }
            }
        }
    }
    match commits_between(&state.odb, tip, &exclude) {
        Ok(commits) => {
            let mut obj = JsonObj::new();
            obj.insert("commits", oid_arr(&commits));
            Ok(json_response(obj))
        }
        Err(e) => Ok(text(404, format!("history walk failed: {e:#}"))),
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::lfs::http::HttpRemote;
    use crate::lfs::transport::RemoteTransport;
    use crate::util::tmp::TempDir;

    #[test]
    fn negotiation_pack_and_object_roundtrip() {
        let td_root = TempDir::new("srv-root").unwrap();
        let td_staging = TempDir::new("srv-staging").unwrap();
        let server = LfsServer::spawn(td_root.path()).unwrap();
        let remote = HttpRemote::open(&server.url(), Some(td_staging.path())).unwrap();

        // Seed the server store directly (what an earlier push did).
        let server_store = LfsStore::at(&td_root.path().join("lfs/objects"));
        let a = server_store.put(b"held-object").unwrap().0;
        let ghost = Oid::of_bytes(b"nobody");

        let resp = RemoteTransport::batch(&remote, &[a, ghost]).unwrap();
        assert_eq!(resp.present, vec![a]);
        assert_eq!(resp.present_sizes, vec![11]);
        assert_eq!(resp.missing, vec![ghost]);

        // Pack download.
        let (blob, wire) = remote.fetch_pack_blob(&[a], 1).unwrap();
        assert_eq!(wire.resumed_bytes, 0);
        assert_eq!(wire.wire_bytes, blob.len() as u64);
        let td_local = TempDir::new("srv-local").unwrap();
        let local = LfsStore::open(td_local.path());
        pack::unpack_into(&local, &blob, 1).unwrap();
        assert_eq!(local.get(&a).unwrap(), b"held-object");

        // Per-object fallback + server-side dedup.
        assert_eq!(remote.get_object(&a).unwrap(), b"held-object");
        remote.put_object(b"fresh-object").unwrap();
        remote.put_object(b"fresh-object").unwrap();
        let fresh = Oid::of_bytes(b"fresh-object");
        assert_eq!(server_store.get(&fresh).unwrap(), b"fresh-object");

        // Pack upload (fresh content), then re-upload dedups.
        let b = local.put(b"uploaded-via-pack").unwrap().0;
        let up = pack::build_pack(&local, &[b], 1).unwrap();
        let id = pack::pack_id(&up);
        let (stats, wire) = remote.send_pack_blob(&id, &up, 1).unwrap();
        assert_eq!(stats.objects, 1);
        assert_eq!(wire.wire_bytes, up.len() as u64);
        assert_eq!(server_store.get(&b).unwrap(), b"uploaded-via-pack");
    }

    #[test]
    fn unknown_routes_and_bad_ids_are_clean_errors() {
        let td_root = TempDir::new("srv-root").unwrap();
        let server = LfsServer::spawn(td_root.path()).unwrap();
        let authority = server.addr().to_string();

        let resp = http::roundtrip(&authority, &http::Request::new("GET", "/nope")).unwrap();
        assert_eq!(resp.status, 404);
        let resp = http::roundtrip(&authority, &http::Request::new("GET", "/packs/zzz")).unwrap();
        assert_eq!(resp.status, 400);
        let resp = http::roundtrip(&authority, &http::Request::new("GET", "/objects/abc")).unwrap();
        assert_eq!(resp.status, 400);
        // A corrupt per-object upload is rejected, not stored.
        let bogus = "0".repeat(64);
        let req = http::Request::new("PUT", &format!("/objects/{bogus}")).body(b"x".to_vec());
        assert_eq!(http::roundtrip(&authority, &req).unwrap().status, 422);
    }

    #[test]
    fn refs_cas_over_http() {
        let td_root = TempDir::new("srv-refs").unwrap();
        let server = LfsServer::spawn(td_root.path()).unwrap();
        let authority = server.addr().to_string();
        let a = Oid::of_bytes(b"ca");
        let b = Oid::of_bytes(b"cb");

        let get = |name: &str| {
            http::roundtrip(&authority, &http::Request::new("GET", &format!("/refs/{name}")))
                .unwrap()
        };
        assert_eq!(get("main").status, 404);

        let put = |body: String| {
            let req = http::Request::new("PUT", "/refs/main").body(body.into_bytes());
            http::roundtrip(&authority, &req).unwrap()
        };
        assert_eq!(put(format!("none {}", a.to_hex())).status, 200);
        assert_eq!(String::from_utf8_lossy(&get("main").body), a.to_hex());
        // Stale expectation loses the race.
        assert_eq!(put(format!("none {}", b.to_hex())).status, 409);
        assert_eq!(put(format!("{} {}", a.to_hex(), b.to_hex())).status, 200);
        assert_eq!(String::from_utf8_lossy(&get("main").body), b.to_hex());
    }
}
