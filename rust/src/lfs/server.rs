//! `git-theta serve`: a dependency-free HTTP remote server.
//!
//! Serves a remote root over `std::net::TcpListener` so pushes and
//! fetches can cross a real network channel. The root uses the same
//! layout as a directory remote — `objects/` (odb), `refs/heads/` +
//! `HEAD`, `lfs/objects/` — so a directory remote can be promoted to
//! an HTTP remote by pointing `git-theta serve` at it.
//!
//! Endpoints (client halves: [`HttpRemote`](super::http::HttpRemote),
//! `gitcore::remote::HttpEndpoint`):
//!
//! ```text
//! POST   /objects/batch   have/want negotiation  -> present/sizes/missing
//!                         (protocol-2 bodies also carry chain adverts
//!                          and the response adds per-chain have_depth)
//! POST   /packs           build+cache a pack for a want set -> {id,size}
//! GET    /packs/<id>      download (Range: bytes=k- resumes; streamed)
//! HEAD   /packs/<id>      upload-resume probe -> X-Received: <bytes>
//! PUT    /packs/<id>      upload (Content-Range; body streams to disk)
//! DELETE /packs/<id>      drop cached/partial pack state
//! GET/PUT /objects/<oid>  per-object fallback
//! GET/HEAD/PUT /odb/<oid>, POST /odb/batch, GET/PUT /refs/<name>,
//! GET /history/<tip>?exclude=..   commit/ref sync
//! ```
//!
//! **Streaming + keep-alive.** Each accepted connection runs a request
//! loop (HTTP/1.1 persistent connections), so a client pays one TCP
//! connect for a whole push or fetch. Pack bodies never materialize in
//! server RAM: `PUT /packs` streams the body straight into the
//! `lfs/partial/<id>` file, `GET /packs` streams the cached file onto
//! the socket in fixed chunks, and `POST /packs` builds its pack with
//! the streaming [`pack::PackWriter`] directly into the cache file —
//! peak heap per connection is O(largest object + window), not O(pack).
//!
//! Durability and dedup: an interrupted `PUT /packs/<id>` leaves its
//! received prefix in `lfs/partial/<id>` — the retry HEAD-probes and
//! sends only the tail. Partial state is guarded by a **per-pack-id
//! lock** (unrelated uploads never serialize on each other). A
//! completed pack is verified end to end ([`pack::verify_pack_file`])
//! and admitted object-by-object through [`LfsStore::put`], which is
//! content-addressed on sha256, so re-uploads (and objects shared
//! between packs) deduplicate server-side; a pack that fails its
//! checksum or id is discarded whole and poisons nothing. Stale cache
//! entries (`lfs/outgoing/`, `lfs/partial/`) are reaped by the
//! age-based [`gc_stale_packs`], run once at spawn.
//!
//! **Overload safety.** Connections are served by a fixed worker pool
//! fed by a bounded accept queue ([`ServeOptions`]); when the queue is
//! full the accept loop sheds the connection with `503 + Retry-After`
//! instead of stalling or spawning without bound. Every request runs
//! under a wall-clock [`Deadline`](crate::util::http::Deadline)
//! layered on the socket `IO_TIMEOUT`, so a slow-loris head or stalled
//! body cannot pin a worker past the budget. Degradation shows up in
//! numbers: per-request counters ([`MetricsSnapshot`]) are exposed
//! over `GET /metrics`. Shutdown drains: accepting stops, in-flight
//! requests get a grace period, stragglers are cut (their partial
//! bodies are already on disk — resume covers a restart), and every
//! worker is joined, so no thread outlives the server.

use super::pack;
use super::store::LfsStore;
use super::transport;
use crate::gitcore::mergebase::commits_between;
use crate::gitcore::object::{Object, Oid};
use crate::gitcore::odb::Odb;
use crate::gitcore::refs::Refs;
use crate::util::http::{self, Request, Response};
use crate::util::json::{Json, JsonObj};
use crate::util::tmp;
use anyhow::{Context, Result};
use std::collections::{HashMap, VecDeque};
use std::io::{Read, Seek, SeekFrom, Write};
use std::net::{SocketAddr, TcpListener, TcpStream};
use std::path::{Path, PathBuf};
use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};
use std::sync::{Arc, Condvar, Mutex};
use std::time::{Duration, Instant};

/// Worker threads used for server-side pack assembly/fan-in. Kept
/// small: each connection already runs on its own thread.
const PACK_THREADS: usize = 2;

/// Age past which cached (`lfs/outgoing/`) and partial
/// (`lfs/partial/`) packs are reaped by [`gc_stale_packs`]. Long
/// enough that any in-flight resume (client retries span seconds to
/// minutes) survives; short enough that abandoned transfers do not
/// accumulate forever.
pub const STALE_PACK_TTL: Duration = Duration::from_secs(24 * 60 * 60);

/// Tuning for the serving core: worker pool size, admission control,
/// per-request budget, and drain behavior. The [`Default`] is sized
/// for test fleets and small teams; `git-theta serve` and the chaos
/// harness pass explicit values.
#[derive(Debug, Clone, Copy)]
pub struct ServeOptions {
    /// Fixed worker threads serving accepted connections. A keep-alive
    /// connection holds its worker between requests (up to the request
    /// budget when idle), so size this above the expected number of
    /// concurrent clients.
    pub workers: usize,
    /// Bounded accept queue ahead of the workers. When it is full, new
    /// connections are shed with `503 + Retry-After` instead of
    /// stalling the accept loop or spawning without bound.
    pub queue: usize,
    /// Wall-clock budget per request (head + body + response), layered
    /// on the socket `IO_TIMEOUT` so a slow-loris or stalled body
    /// cannot pin a worker forever. Also bounds how long an idle
    /// keep-alive connection may hold a worker.
    pub request_budget: Duration,
    /// How long shutdown waits for in-flight requests before cutting
    /// their sockets (partial bodies are on disk either way; resume
    /// covers a restart).
    pub drain_deadline: Duration,
    /// Seconds advertised in the `Retry-After` header of a shed.
    pub retry_after_secs: u64,
}

impl Default for ServeOptions {
    fn default() -> ServeOptions {
        ServeOptions {
            workers: 32,
            queue: 256,
            request_budget: Duration::from_secs(120),
            drain_deadline: Duration::from_secs(5),
            retry_after_secs: 1,
        }
    }
}

/// Monotonic serving counters (`GET /metrics`): degradation under load
/// must show up in numbers, not anecdotes.
#[derive(Debug, Default)]
struct ServeMetrics {
    accepted: AtomicU64,
    rejected: AtomicU64,
    timed_out: AtomicU64,
    requests: AtomicU64,
    in_flight: AtomicU64,
    bytes_in: AtomicU64,
    bytes_out: AtomicU64,
}

impl ServeMetrics {
    fn snapshot(&self) -> MetricsSnapshot {
        MetricsSnapshot {
            accepted: self.accepted.load(Ordering::Relaxed),
            rejected: self.rejected.load(Ordering::Relaxed),
            timed_out: self.timed_out.load(Ordering::Relaxed),
            requests: self.requests.load(Ordering::Relaxed),
            in_flight: self.in_flight.load(Ordering::Relaxed),
            bytes_in: self.bytes_in.load(Ordering::Relaxed),
            bytes_out: self.bytes_out.load(Ordering::Relaxed),
            // Filled in by ServerState::metrics_snapshot, which also
            // sees the plan cache.
            plan_cache_hits: 0,
            plan_cache_misses: 0,
        }
    }
}

/// Point-in-time copy of the serving counters (the in-process view of
/// `GET /metrics`).
#[derive(Debug, Clone, Copy, Default, PartialEq, Eq)]
pub struct MetricsSnapshot {
    /// Connections admitted to the worker queue.
    pub accepted: u64,
    /// Connections shed with `503 + Retry-After` (queue full).
    pub rejected: u64,
    /// Requests cut by the per-request deadline.
    pub timed_out: u64,
    /// Requests served to completion.
    pub requests: u64,
    /// Requests currently being served.
    pub in_flight: u64,
    /// Request body bytes received.
    pub bytes_in: u64,
    /// Response body bytes sent.
    pub bytes_out: u64,
    /// Chain-aware `POST /packs` delta encodings answered from the
    /// (base, target) plan cache — repeated fine-tune fetches of one
    /// base amortize their CDC chunking here.
    pub plan_cache_hits: u64,
    /// Delta encodings that had to be computed (and were then cached).
    pub plan_cache_misses: u64,
}

/// Bounded handoff between the accept loop and the worker pool.
struct AcceptQueue {
    /// Queued connections, plus whether the server is draining.
    slots: Mutex<(VecDeque<TcpStream>, bool)>,
    ready: Condvar,
    cap: usize,
}

impl AcceptQueue {
    fn new(cap: usize) -> AcceptQueue {
        AcceptQueue {
            slots: Mutex::new((VecDeque::new(), false)),
            ready: Condvar::new(),
            cap: cap.max(1),
        }
    }

    /// Admit a connection, or hand it back when the queue is full (the
    /// caller sheds it) or the server is draining.
    fn try_push(&self, stream: TcpStream) -> std::result::Result<(), TcpStream> {
        let mut slots = self.slots.lock().unwrap();
        if slots.1 || slots.0.len() >= self.cap {
            return Err(stream);
        }
        slots.0.push_back(stream);
        drop(slots);
        self.ready.notify_one();
        Ok(())
    }

    /// Block until a connection is available (`Some`) or the queue has
    /// closed (`None`: the worker exits).
    fn pop(&self) -> Option<TcpStream> {
        let mut slots = self.slots.lock().unwrap();
        loop {
            if let Some(stream) = slots.0.pop_front() {
                return Some(stream);
            }
            if slots.1 {
                return None;
            }
            slots = self.ready.wait(slots).unwrap();
        }
    }

    /// Stop admitting work and wake every idle worker. Queued
    /// connections not yet claimed are dropped — their clients observe
    /// a cut, which the retry layer classifies as retryable.
    fn close(&self) {
        let mut slots = self.slots.lock().unwrap();
        slots.1 = true;
        slots.0.clear();
        drop(slots);
        self.ready.notify_all();
    }
}

struct ServerState {
    root: PathBuf,
    store: LfsStore,
    odb: Odb,
    refs: Refs,
    /// Serializes ref compare-and-set.
    refs_lock: Mutex<()>,
    /// Per-pack-id partial-upload locks: concurrent uploads of
    /// *different* packs proceed in parallel; writers of the *same*
    /// pack serialize on its entry. Entries are never removed — minting
    /// a fresh mutex while an old holder is mid-append would let two
    /// writers share one partial file — so the map grows with the
    /// number of distinct pack ids seen, which is tiny.
    partial_locks: Mutex<HashMap<String, Arc<Mutex<()>>>>,
    /// Serving knobs this server was spawned with.
    options: ServeOptions,
    /// Serving counters (`GET /metrics`).
    metrics: ServeMetrics,
    /// (base, target) delta-encoding memo for chain-aware fetches:
    /// repeated `POST /packs` for fine-tunes of one base skip the CDC
    /// chunking. Content-addressed keys mean entries are never stale;
    /// eviction is capacity-only (see [`pack::PlanCache`]).
    plan_cache: pack::PlanCache,
    /// Clones of every connection currently held by a worker, so
    /// drain/kill can unblock workers via `TcpStream::shutdown`.
    conns: Mutex<HashMap<u64, TcpStream>>,
    next_conn: AtomicU64,
}

impl ServerState {
    /// The serving counters plus the plan-cache counters, as one
    /// consistent-enough point-in-time copy.
    fn metrics_snapshot(&self) -> MetricsSnapshot {
        let mut snap = self.metrics.snapshot();
        snap.plan_cache_hits = self.plan_cache.hits();
        snap.plan_cache_misses = self.plan_cache.misses();
        snap
    }
}

/// Track a worker's connection so drain/kill can unblock it; `None`
/// when the clone fails (the connection is then served untracked).
fn register_conn(state: &ServerState, stream: &TcpStream) -> Option<u64> {
    let clone = stream.try_clone().ok()?;
    let id = state.next_conn.fetch_add(1, Ordering::Relaxed);
    state.conns.lock().unwrap().insert(id, clone);
    Some(id)
}

/// Turn away a connection with `503 + Retry-After`, written blind —
/// the request is never read, so a slow or hostile peer costs the
/// accept path nothing. Best-effort: the peer may already be gone.
fn shed(mut stream: TcpStream, retry_after_secs: u64) {
    let _ = stream.set_write_timeout(Some(Duration::from_secs(1)));
    let head = format!(
        "HTTP/1.1 503 Service Unavailable\r\nretry-after: {retry_after_secs}\r\ncontent-length: 0\r\n\r\n"
    );
    let _ = stream.write_all(head.as_bytes());
    let _ = stream.flush();
}

fn id_lock(state: &ServerState, id: &str) -> Arc<Mutex<()>> {
    state
        .partial_locks
        .lock()
        .unwrap()
        .entry(id.to_string())
        .or_default()
        .clone()
}

/// A running LFS + commit/ref server. Drains and shuts down on drop.
pub struct LfsServer {
    addr: SocketAddr,
    stop: Arc<AtomicBool>,
    accept_thread: Option<std::thread::JoinHandle<()>>,
    workers: Vec<std::thread::JoinHandle<()>>,
    queue: Arc<AcceptQueue>,
    state: Arc<ServerState>,
}

impl LfsServer {
    /// Serve `root` on an ephemeral localhost port.
    pub fn spawn(root: &Path) -> Result<LfsServer> {
        LfsServer::spawn_on(root, "127.0.0.1:0")
    }

    /// Serve `root` on an explicit `host:port` bind address.
    pub fn spawn_on(root: &Path, bind: &str) -> Result<LfsServer> {
        LfsServer::spawn_with(root, bind, ServeOptions::default())
    }

    /// Serve `root` with explicit [`ServeOptions`] (worker pool size,
    /// admission control, request budget, drain deadline).
    pub fn spawn_with(root: &Path, bind: &str, options: ServeOptions) -> Result<LfsServer> {
        std::fs::create_dir_all(root.join("refs/heads"))?;
        let odb = Odb::init(root)?;
        if !root.join("HEAD").exists() {
            Refs::init(root, "main")?;
        }
        // Reap pack-cache entries abandoned by long-dead transfers.
        let _ = gc_stale_packs(root, STALE_PACK_TTL);
        let state = Arc::new(ServerState {
            root: root.to_path_buf(),
            store: LfsStore::at(&root.join("lfs/objects")),
            odb,
            refs: Refs::open(root),
            refs_lock: Mutex::new(()),
            partial_locks: Mutex::new(HashMap::new()),
            options,
            metrics: ServeMetrics::default(),
            plan_cache: pack::PlanCache::new(),
            conns: Mutex::new(HashMap::new()),
            next_conn: AtomicU64::new(0),
        });
        let listener = TcpListener::bind(bind)
            .with_context(|| format!("binding lfs server to {bind}"))?;
        let addr = listener.local_addr()?;
        let stop = Arc::new(AtomicBool::new(false));
        let queue = Arc::new(AcceptQueue::new(options.queue));
        let mut workers = Vec::with_capacity(options.workers.max(1));
        for _ in 0..options.workers.max(1) {
            let queue = queue.clone();
            let state = state.clone();
            workers.push(std::thread::spawn(move || {
                while let Some(stream) = queue.pop() {
                    let conn_id = register_conn(&state, &stream);
                    handle_connection(stream, &state);
                    if let Some(id) = conn_id {
                        state.conns.lock().unwrap().remove(&id);
                    }
                }
            }));
        }
        let stop2 = stop.clone();
        let accept_state = state.clone();
        let accept_queue = queue.clone();
        let accept_thread = std::thread::spawn(move || {
            for conn in listener.incoming() {
                if stop2.load(Ordering::SeqCst) {
                    break;
                }
                let Ok(stream) = conn else { continue };
                match accept_queue.try_push(stream) {
                    Ok(()) => {
                        accept_state.metrics.accepted.fetch_add(1, Ordering::Relaxed);
                    }
                    Err(stream) => {
                        accept_state.metrics.rejected.fetch_add(1, Ordering::Relaxed);
                        shed(stream, accept_state.options.retry_after_secs);
                    }
                }
            }
        });
        Ok(LfsServer {
            addr,
            stop,
            accept_thread: Some(accept_thread),
            workers,
            queue,
            state,
        })
    }

    /// The bound socket address.
    pub fn addr(&self) -> SocketAddr {
        self.addr
    }

    /// Point-in-time serving counters (the in-process version of
    /// `GET /metrics`).
    pub fn metrics(&self) -> MetricsSnapshot {
        self.state.metrics_snapshot()
    }

    /// Forcibly shut down every connection currently held by a worker;
    /// in-flight requests observe a cut. The listener keeps accepting,
    /// so to clients this is indistinguishable from a server restart
    /// that kept its disk state — which is what the keep-alive
    /// recovery tests simulate (a literal restart cannot reliably
    /// rebind the same port: std's `TcpListener` takes no
    /// `SO_REUSEADDR`). Returns how many connections were cut.
    pub fn kill_connections(&self) -> usize {
        let conns = self.state.conns.lock().unwrap();
        for stream in conns.values() {
            let _ = stream.shutdown(std::net::Shutdown::Both);
        }
        conns.len()
    }

    /// Graceful shutdown: stop accepting, give in-flight requests the
    /// drain deadline to finish, cut stragglers (their partial bodies
    /// are already on disk; resume covers a restart), and join every
    /// worker — zero threads survive. Returns the final counters.
    /// Dropping the server runs the same drain.
    pub fn shutdown(mut self) -> MetricsSnapshot {
        self.drain();
        self.state.metrics_snapshot()
    }

    fn drain(&mut self) {
        if self.stop.swap(true, Ordering::SeqCst) && self.accept_thread.is_none() {
            return; // already drained
        }
        // Unblock the accept loop with a throwaway connection.
        let _ = TcpStream::connect(self.addr);
        if let Some(handle) = self.accept_thread.take() {
            let _ = handle.join();
        }
        // Stop admitting queued work and wake idle workers.
        self.queue.close();
        // Grace period for whatever is mid-request.
        let deadline = Instant::now() + self.state.options.drain_deadline;
        while self.state.metrics.in_flight.load(Ordering::Relaxed) > 0
            && Instant::now() < deadline
        {
            std::thread::sleep(Duration::from_millis(5));
        }
        // Cut whatever is left (idle keep-alive connections included;
        // nothing in flight loses data — partial bodies are on disk)
        // so blocked workers unblock and exit.
        self.kill_connections();
        for handle in self.workers.drain(..) {
            let _ = handle.join();
        }
    }

    /// The `http://` URL clients should use as their remote.
    pub fn url(&self) -> String {
        format!("http://{}", self.addr)
    }

    /// Run a *claim-aware* stale-pack reap against this server's root:
    /// like [`gc_stale_packs`], but a partial pack whose per-pack-id
    /// lock is currently held by an in-flight `PUT /packs/<id>` is
    /// never reaped, however old its file looks. Mtime age alone is not
    /// proof of abandonment — a slow upload can legitimately straddle
    /// the TTL (last append long ago, writer still alive) — so the
    /// live lock is the authority. Returns how many files were removed.
    pub fn reap_stale(&self, max_age: Duration) -> usize {
        let state = &self.state;
        gc_stale_packs_filtered(&state.root, max_age, |id| {
            let entry = state
                .partial_locks
                .lock()
                .unwrap()
                .get(id)
                .cloned();
            match entry {
                // WouldBlock: a writer holds the claim right now.
                // Poisoned: a writer died holding it; the next PUT of
                // this id still recovers the partial, so keep it too.
                Some(lock) => lock.try_lock().is_err(),
                None => false,
            }
        })
    }
}

impl Drop for LfsServer {
    fn drop(&mut self) {
        self.drain();
    }
}

/// Remove cached (`lfs/outgoing/`, including the by-want memo files)
/// and partial (`lfs/partial/`) pack entries whose last modification
/// is older than `max_age`. Returns how many files were removed.
///
/// Content-addressing makes this always safe: a reaped outgoing pack
/// is rebuilt from the store on the next `POST /packs`, and a reaped
/// partial merely restarts its upload from byte 0.
pub fn gc_stale_packs(root: &Path, max_age: Duration) -> Result<usize> {
    // No claim oracle here (nothing can be in flight when this runs at
    // spawn, before the listener exists), so nothing is exempt.
    Ok(gc_stale_packs_filtered(root, max_age, |_| false))
}

/// Core of the stale-pack reap. `claimed` is consulted for entries in
/// `lfs/partial/` only (keyed by file name, which is the pack id for
/// resumable uploads): a claimed partial belongs to an in-flight PUT
/// and must survive regardless of age. Outgoing packs and memos are
/// pure caches and reap on age alone.
fn gc_stale_packs_filtered(
    root: &Path,
    max_age: Duration,
    claimed: impl Fn(&str) -> bool,
) -> usize {
    let mut removed = 0;
    for dir in [root.join("lfs/outgoing"), root.join("lfs/outgoing/bywant")] {
        removed += tmp::reap_older_than(&dir, max_age, |_| true);
    }
    removed += tmp::reap_older_than(&root.join("lfs/partial"), max_age, |name| !claimed(name));
    removed
}

/// Per-connection request loop (HTTP/1.1 keep-alive): serve requests
/// until the peer closes, asks to close, errors, a mid-body cut leaves
/// the stream unframed, or the per-request [`http::Deadline`] expires.
fn handle_connection(mut stream: TcpStream, state: &ServerState) {
    if let Err(e) = http::prepare_stream(&stream) {
        // A socket that cannot be given I/O deadlines must not be
        // served unbounded: fail closed. Log the condition once — it
        // is an environment problem, not a per-connection one.
        static WARNED: std::sync::Once = std::sync::Once::new();
        WARNED.call_once(|| {
            eprintln!(
                "git-theta serve: closing connection that cannot be given socket deadlines: {e:#}"
            );
        });
        return;
    }
    loop {
        // Arm the budget before the head read: an idle keep-alive
        // connection holds its worker for at most
        // min(IO_TIMEOUT, request_budget) before being reclaimed.
        let deadline = http::Deadline::after(state.options.request_budget);
        let (req, leftover) =
            match http::read_request_head_within(&mut stream, Some(&deadline)) {
                Ok(v) => v,
                // Clean close between requests, or a broken head:
                // either way there is nothing left to answer.
                Err(_) => return,
            };
        state.metrics.in_flight.fetch_add(1, Ordering::Relaxed);
        let served = serve_one(state, &mut stream, req, leftover, &deadline);
        state.metrics.in_flight.fetch_sub(1, Ordering::Relaxed);
        if deadline.expired() {
            // The budget was exhausted mid-request (stalled body or
            // slow drain). Whatever prefix arrived is on disk for
            // resumable routes; the connection itself is unframed.
            state.metrics.timed_out.fetch_add(1, Ordering::Relaxed);
            return;
        }
        match served {
            Ok(true) => {
                state.metrics.requests.fetch_add(1, Ordering::Relaxed);
                continue;
            }
            Ok(false) => {
                state.metrics.requests.fetch_add(1, Ordering::Relaxed);
                return;
            }
            Err(_) => return,
        }
    }
}

/// Serve one request. `Ok(true)` keeps the connection for the next
/// request; `Ok(false)` closes it (peer gone, close requested, or the
/// body stream is no longer cleanly framed).
fn serve_one(
    state: &ServerState,
    stream: &mut TcpStream,
    req: Request,
    leftover: Vec<u8>,
    deadline: &http::Deadline,
) -> Result<bool> {
    let wants_close = req.wants_close();
    let path = req.path().to_string();

    // Streaming routes first: pack bodies never enter RAM.
    if let Some(id) = path.strip_prefix("/packs/") {
        let keep = match req.method.as_str() {
            "PUT" => pack_put_streaming(state, stream, &req, leftover, id, deadline)?,
            method => {
                // GET/HEAD/DELETE carry no meaningful body, but a
                // declared one must still be drained (to nowhere — a
                // hostile Content-Length must not buy a buffer) or its
                // bytes would desync the keep-alive framing.
                let len = req.declared_len()?;
                let (drained, complete) = http::read_body_to_within(
                    stream,
                    &leftover,
                    len,
                    &mut std::io::sink(),
                    Some(deadline),
                )?;
                state.metrics.bytes_in.fetch_add(drained, Ordering::Relaxed);
                if !complete {
                    return Ok(false);
                }
                if method == "GET" {
                    pack_get_streaming(state, stream, &req, id, deadline)?
                } else {
                    let resp = pack_misc(state, method, id)
                        .unwrap_or_else(|e| text(500, format!("{e:#}")));
                    state
                        .metrics
                        .bytes_out
                        .fetch_add(resp.body.len() as u64, Ordering::Relaxed);
                    http::write_response(stream, &resp)?;
                    true
                }
            }
        };
        return Ok(keep && !wants_close);
    }

    // Buffered routes: negotiation, odb/refs sync, per-object ops —
    // all small bodies.
    let len = req.declared_len()?;
    let mut body = Vec::new();
    let (read, complete) =
        http::read_body_to_within(stream, &leftover, len, &mut body, Some(deadline))?;
    state.metrics.bytes_in.fetch_add(read, Ordering::Relaxed);
    if !complete {
        // The peer died mid-body; nobody is listening for a response.
        return Ok(false);
    }
    let mut full = req;
    full.body = body;
    let resp = dispatch(state, &full.method, &path, &full)
        .unwrap_or_else(|e| text(500, format!("{e:#}")));
    state
        .metrics
        .bytes_out
        .fetch_add(resp.body.len() as u64, Ordering::Relaxed);
    http::write_response(stream, &resp)?;
    Ok(!wants_close)
}

fn text(status: u16, body: impl Into<String>) -> Response {
    Response::new(status).body(body.into().into_bytes())
}

fn json_response(obj: JsonObj) -> Response {
    Response::new(200)
        .header("content-type", "application/json")
        .body(Json::Obj(obj).to_string_compact().into_bytes())
}

fn parse_want(req: &Request) -> Result<Vec<Oid>> {
    let json = Json::parse(&String::from_utf8_lossy(&req.body)).context("parsing request json")?;
    json.get("want")
        .and_then(|v| v.as_arr())
        .context("request missing 'want'")?
        .iter()
        .map(|v| Oid::from_hex(v.as_str().context("non-string oid")?))
        .collect()
}

fn oid_arr(oids: &[Oid]) -> Json {
    Json::Arr(oids.iter().map(|o| Json::from(o.to_hex())).collect())
}

fn is_hex_id(s: &str) -> bool {
    s.len() == 64 && s.bytes().all(|b| b.is_ascii_hexdigit())
}

fn dispatch(state: &ServerState, method: &str, path: &str, req: &Request) -> Result<Response> {
    Ok(match (method, path) {
        ("POST", "/objects/batch") => objects_batch(state, req)?,
        ("POST", "/packs") => pack_create(state, req)?,
        ("POST", "/odb/batch") => odb_batch(state, req)?,
        ("GET", "/metrics") => metrics_response(state),
        ("GET", "/objects") => objects_inventory(state)?,
        _ => {
            if let Some(hex) = path.strip_prefix("/objects/") {
                object_endpoint(state, method, hex, req)?
            } else if let Some(hex) = path.strip_prefix("/odb/") {
                odb_endpoint(state, method, hex, req)?
            } else if let Some(name) = path.strip_prefix("/refs/") {
                refs_endpoint(state, method, name, req)?
            } else if let Some(hex) = path.strip_prefix("/history/") {
                history_endpoint(state, hex, req)?
            } else {
                text(404, format!("no route for {method} {path}"))
            }
        }
    })
}

/// `GET /metrics`: the serving counters plus the pool geometry, as
/// JSON — degradation under load must be observable remotely, not
/// just from inside the process.
fn metrics_response(state: &ServerState) -> Response {
    let snap = state.metrics_snapshot();
    let mut obj = JsonObj::new();
    obj.insert("accepted", snap.accepted);
    obj.insert("rejected", snap.rejected);
    obj.insert("timed_out", snap.timed_out);
    obj.insert("requests", snap.requests);
    obj.insert("in_flight", snap.in_flight);
    obj.insert("bytes_in", snap.bytes_in);
    obj.insert("bytes_out", snap.bytes_out);
    obj.insert("plan_cache_hits", snap.plan_cache_hits);
    obj.insert("plan_cache_misses", snap.plan_cache_misses);
    obj.insert("workers", state.options.workers as u64);
    obj.insert("queue", state.options.queue as u64);
    json_response(obj)
}

/// `GET /objects`: the store's full oid inventory, sorted. This is the
/// wire half of [`RemoteTransport::list_oids`](super::transport::RemoteTransport::list_oids);
/// anti-entropy repair unions these lists across mirrors to find what
/// each one is missing.
fn objects_inventory(state: &ServerState) -> Result<Response> {
    let mut oids = state.store.list()?;
    oids.sort();
    let mut obj = JsonObj::new();
    obj.insert("oids", oid_arr(&oids));
    Ok(json_response(obj))
}

fn objects_batch(state: &ServerState, req: &Request) -> Result<Response> {
    let json = match Json::parse(&String::from_utf8_lossy(&req.body)).context("parsing request json")
    {
        Ok(j) => j,
        Err(e) => return Ok(text(400, format!("{e:#}"))),
    };
    // A protocol-2 client advertises chain prefixes alongside its want
    // set; answer with per-chain held depths so it can plan delta
    // records. A plain `{"want":[..]}` body (older clients) gets the
    // byte-identical flat response it always has.
    if json.get("chains").is_some() {
        let adv = match transport::parse_chain_advert(&json) {
            Ok(a) => a,
            Err(e) => return Ok(text(400, format!("{e:#}"))),
        };
        let neg = transport::answer_chains(&state.store, &adv);
        let mut obj = JsonObj::new();
        obj.insert("protocol", 2u32);
        obj.insert("present", oid_arr(&neg.batch.present));
        obj.insert(
            "sizes",
            Json::Arr(
                neg.batch
                    .present_sizes
                    .iter()
                    .map(|&s| Json::from(s))
                    .collect(),
            ),
        );
        obj.insert("missing", oid_arr(&neg.batch.missing));
        let chains: Vec<Json> = neg
            .have_depths
            .iter()
            .map(|&d| {
                let mut c = JsonObj::new();
                c.insert("have_depth", d);
                Json::Obj(c)
            })
            .collect();
        obj.insert("chains", chains);
        return Ok(json_response(obj));
    }
    let want = match parse_want(req) {
        Ok(w) => w,
        Err(e) => return Ok(text(400, format!("{e:#}"))),
    };
    let mut present = Vec::new();
    let mut sizes = Vec::new();
    let mut missing = Vec::new();
    // One stat_all call answers presence *and* sizes — at most one
    // store scan, no per-present-oid stat follow-up.
    for (oid, stat) in want.iter().zip(state.store.stat_all(&want)) {
        match stat {
            Some(size) => {
                present.push(*oid);
                sizes.push(size);
            }
            None => missing.push(*oid),
        }
    }
    let mut obj = JsonObj::new();
    obj.insert("present", oid_arr(&present));
    obj.insert("sizes", Json::Arr(sizes.into_iter().map(Json::from).collect()));
    obj.insert("missing", oid_arr(&missing));
    Ok(json_response(obj))
}

fn outgoing_path(state: &ServerState, id: &str) -> PathBuf {
    state.root.join("lfs/outgoing").join(id)
}

fn partial_path(state: &ServerState, id: &str) -> PathBuf {
    state.root.join("lfs/partial").join(id)
}

/// Memo path for a want set: `lfs/outgoing/bywant/<sha256 of the
/// sorted want hexes>`, holding `"<pack id> <size>"`. Pack contents
/// are a pure function of the wanted oids (content-addressed), so a
/// memo hit can never serve stale bytes — at worst the cached pack
/// file was reaped, which falls back to a rebuild.
fn want_memo_path(state: &ServerState, want: &[Oid]) -> PathBuf {
    use sha2::{Digest, Sha256};
    let mut sorted: Vec<Oid> = want.to_vec();
    sorted.sort();
    sorted.dedup();
    let mut h = Sha256::new();
    for oid in &sorted {
        h.update(oid.0);
    }
    let digest: [u8; 32] = h.finalize().into();
    state
        .root
        .join("lfs/outgoing/bywant")
        .join(crate::util::hex::encode(&digest))
}

/// Memo path for a chain advert: like [`want_memo_path`], but the
/// digest also covers the advertised chains — the delta pack a
/// protocol-2 `POST /packs` builds depends on which bases the *client*
/// holds, so two adverts with equal want sets but different held
/// prefixes must never share a memo entry. Still safe to reuse: pack
/// contents are a pure function of (want, chains, store contents), the
/// store is append-only content-addressed, and a server that has since
/// *gained* a base would at worst serve the older, equally valid pack.
fn advert_memo_path(state: &ServerState, adv: &transport::ChainAdvert) -> PathBuf {
    use sha2::{Digest, Sha256};
    let mut sorted: Vec<Oid> = adv.want.to_vec();
    sorted.sort();
    sorted.dedup();
    let mut h = Sha256::new();
    h.update(b"advert-v2\n");
    for oid in &sorted {
        h.update(oid.0);
    }
    for chain in &adv.chains {
        // Length-framed so (chains, entries, oids) nesting can never
        // collide across different shapes.
        h.update((chain.len() as u64).to_le_bytes());
        for entry in chain {
            h.update(entry.key.0);
            h.update((entry.oids.len() as u64).to_le_bytes());
            for oid in &entry.oids {
                h.update(oid.0);
            }
        }
    }
    let digest: [u8; 32] = h.finalize().into();
    state
        .root
        .join("lfs/outgoing/bywant")
        .join(crate::util::hex::encode(&digest))
}

/// Answer a `POST /packs` from a memo file, if it points at a pack
/// that is still in the outgoing cache.
fn memo_answer(state: &ServerState, memo: &Path) -> Option<Response> {
    let entry = std::fs::read_to_string(memo).ok()?;
    let (id, size) = entry.trim().split_once(' ')?;
    if !is_hex_id(id) || !outgoing_path(state, id).exists() {
        return None;
    }
    let mut obj = JsonObj::new();
    obj.insert("id", id);
    obj.insert("size", size.parse::<u64>().unwrap_or(0));
    Some(json_response(obj))
}

/// Install a freshly built pack into the outgoing cache under its
/// content-hashed id, record the memo, and answer `{id, size}`.
fn install_built(
    state: &ServerState,
    build_tmp: &Path,
    built: &pack::BuiltPack,
    memo: &Path,
) -> Result<Response> {
    let path = outgoing_path(state, &built.id);
    if path.exists() {
        let _ = std::fs::remove_file(build_tmp);
    } else if let Err(e) = std::fs::rename(build_tmp, &path) {
        let _ = std::fs::remove_file(build_tmp);
        return Err(e).context("installing outgoing pack");
    }
    tmp::write_atomic(memo, format!("{} {}", built.id, built.len).as_bytes())?;
    let mut obj = JsonObj::new();
    obj.insert("id", built.id.as_str());
    obj.insert("size", built.len);
    Ok(json_response(obj))
}

/// Build (or reuse) a pack for a want set. The pack is assembled by
/// the streaming writer directly into the outgoing cache file — it is
/// never RAM-resident. A protocol-2 body (chain advert alongside the
/// want set) gets a v2 delta pack planned against the bases the client
/// holds; a plain `{"want":[..]}` body (older clients) gets the flat
/// v1 pack it always has.
fn pack_create(state: &ServerState, req: &Request) -> Result<Response> {
    let json = match Json::parse(&String::from_utf8_lossy(&req.body)).context("parsing request json")
    {
        Ok(j) => j,
        Err(e) => return Ok(text(400, format!("{e:#}"))),
    };
    if json.get("chains").is_some() {
        let adv = match transport::parse_chain_advert(&json) {
            Ok(a) => a,
            Err(e) => return Ok(text(400, format!("{e:#}"))),
        };
        return pack_create_chains(state, &adv);
    }
    let want = match parse_want(req) {
        Ok(w) => w,
        Err(e) => return Ok(text(400, format!("{e:#}"))),
    };
    pack_create_flat(state, &want)
}

/// The flat (protocol-1) half of `POST /packs`.
fn pack_create_flat(state: &ServerState, want: &[Oid]) -> Result<Response> {
    // A retry of an interrupted download re-POSTs the same want set;
    // answer from the memo instead of recompressing the whole pack.
    let memo = want_memo_path(state, want);
    if let Some(resp) = memo_answer(state, &memo) {
        return Ok(resp);
    }
    let build_tmp = tmp::unique_sibling(&state.root.join("lfs/outgoing/build"));
    let built = match pack::write_pack_file(&state.store, want, PACK_THREADS, &build_tmp) {
        Ok(b) => b,
        Err(e) => return Ok(text(422, format!("cannot assemble pack: {e:#}"))),
    };
    install_built(state, &build_tmp, &built, &memo)
}

/// The chain-aware (protocol-2) half of `POST /packs`: plan suffix
/// deltas against bases the advert proves the client holds, consulting
/// the (base, target) plan cache so repeated fine-tune fetches of one
/// base skip the CDC chunking.
fn pack_create_chains(state: &ServerState, adv: &transport::ChainAdvert) -> Result<Response> {
    let memo = advert_memo_path(state, adv);
    if let Some(resp) = memo_answer(state, &memo) {
        return Ok(resp);
    }
    let plan = match transport::plan_fetch_deltas(
        &state.store,
        adv,
        PACK_THREADS,
        Some(&state.plan_cache),
    ) {
        Ok(p) => p,
        Err(e) => return Ok(text(422, format!("cannot assemble pack: {e:#}"))),
    };
    if plan.deltas.is_empty() {
        // Nothing worth delta-encoding; the flat path serves (and
        // memoizes) the byte-identical v1 pack.
        return pack_create_flat(state, &adv.want);
    }
    let build_tmp = tmp::unique_sibling(&state.root.join("lfs/outgoing/build"));
    let built = match pack::write_delta_pack_file(&state.store, &plan, PACK_THREADS, &build_tmp) {
        Ok(b) => b,
        Err(e) => return Ok(text(422, format!("cannot assemble pack: {e:#}"))),
    };
    install_built(state, &build_tmp, &built, &memo)
}

fn parse_range(header: Option<&str>) -> Option<u64> {
    header?
        .strip_prefix("bytes=")?
        .strip_suffix('-')?
        .parse::<u64>()
        .ok()
}

/// HEAD (upload-resume probe) and DELETE for `/packs/<id>` (GET and
/// PUT take the streaming paths).
fn pack_misc(state: &ServerState, method: &str, id: &str) -> Result<Response> {
    if !is_hex_id(id) {
        return Ok(text(400, "pack ids are 64 hex chars"));
    }
    match method {
        "HEAD" => {
            let have = std::fs::metadata(partial_path(state, id))
                .map(|m| m.len())
                .unwrap_or(0);
            Ok(Response::new(200).header("x-received", &have.to_string()))
        }
        "DELETE" => {
            let lock = id_lock(state, id);
            let _guard = lock.lock().unwrap();
            let _ = std::fs::remove_file(outgoing_path(state, id));
            let _ = std::fs::remove_file(partial_path(state, id));
            Ok(text(200, "gone"))
        }
        _ => Ok(text(404, "unsupported pack method")),
    }
}

/// `GET /packs/<id>`: stream the cached pack file (from a byte offset
/// when a `Range` header resumes) onto the socket in fixed chunks.
/// Returns whether the connection is still cleanly framed.
fn pack_get_streaming(
    state: &ServerState,
    stream: &mut TcpStream,
    req: &Request,
    id: &str,
    deadline: &http::Deadline,
) -> Result<bool> {
    if !is_hex_id(id) {
        http::write_response(stream, &text(400, "pack ids are 64 hex chars"))?;
        return Ok(true);
    }
    let path = outgoing_path(state, id);
    let total = match std::fs::metadata(&path) {
        Ok(m) => m.len(),
        Err(_) => {
            http::write_response(stream, &text(404, "unknown pack"))?;
            return Ok(true);
        }
    };
    let (status, start, headers) = match parse_range(req.get_header("range")) {
        None => (200, 0, Vec::new()),
        Some(k) if k < total => (
            206,
            k,
            vec![(
                "content-range".to_string(),
                format!("bytes {k}-{}/{total}", total - 1),
            )],
        ),
        Some(_) => {
            http::write_response(stream, &text(416, "range starts at or past the end of the pack"))?;
            return Ok(true);
        }
    };
    let mut file = std::fs::File::open(&path).context("opening outgoing pack")?;
    file.seek(SeekFrom::Start(start)).context("seeking outgoing pack")?;
    let body_len = total - start;
    http::write_response_head(stream, status, &headers, body_len)?;
    // Chunked copy so the request budget is re-checked per chunk: a
    // peer that stalls its receive window cannot pin this worker past
    // the deadline.
    let mut chunk = vec![0u8; http::COPY_CHUNK];
    let mut copied = 0u64;
    while copied < body_len {
        deadline
            .arm(stream)
            .with_context(|| format!("request budget exhausted streaming pack {id}"))?;
        let want = ((body_len - copied) as usize).min(chunk.len());
        // The cache file shrinking under us (gc raced a download)
        // surfaces here: the declared length is now wrong, so the
        // connection is poisoned either way.
        file.read_exact(&mut chunk[..want])
            .with_context(|| format!("outgoing pack {id} truncated mid-stream"))?;
        stream
            .write_all(&chunk[..want])
            .context("streaming pack body")?;
        copied += want as u64;
    }
    state.metrics.bytes_out.fetch_add(copied, Ordering::Relaxed);
    stream.flush().context("flushing pack body")?;
    Ok(true)
}

/// `Content-Range: bytes a-b/t` -> (a, t); `bytes */t` -> (None, t).
fn parse_content_range(header: Option<&str>) -> Option<(Option<u64>, u64)> {
    let rest = header?.strip_prefix("bytes ")?;
    let (range, total) = rest.split_once('/')?;
    let total = total.parse::<u64>().ok()?;
    if range == "*" {
        return Some((None, total));
    }
    let (start, _end) = range.split_once('-')?;
    Some((Some(start.parse::<u64>().ok()?), total))
}

/// Resumable pack upload: the body streams straight into the
/// `lfs/partial/<id>` file (append-at-offset), so an upload of any
/// size costs O(chunk) server memory.
///
/// This is the *server half* of push resume. The body may stop short
/// (connection died): whatever prefix arrived is already on disk, no
/// response is written (the peer is gone), and the client's retry
/// HEAD-probes `X-Received` to send only the tail. On completion the
/// pack file is id- and checksum-verified, then fanned into the store
/// (sha256 dedup per object) by the streaming reader.
///
/// Returns whether the connection is still cleanly framed (an error
/// response sent before draining the body closes the connection).
fn pack_put_streaming(
    state: &ServerState,
    stream: &mut TcpStream,
    req: &Request,
    leftover: Vec<u8>,
    id: &str,
    deadline: &http::Deadline,
) -> Result<bool> {
    if !is_hex_id(id) {
        http::write_response(stream, &text(400, "pack ids are 64 hex chars"))?;
        return Ok(false);
    }
    let declared = req.declared_len()?;
    let (offset, total) = match parse_content_range(req.get_header("content-range")) {
        Some(v) => v,
        None => {
            http::write_response(stream, &text(400, "PUT /packs needs a content-range header"))?;
            return Ok(false);
        }
    };
    let path = partial_path(state, id);
    let lock = id_lock(state, id);
    let guard = lock.lock().unwrap();
    let have = std::fs::metadata(&path).map(|m| m.len()).unwrap_or(0);
    let offset = offset.unwrap_or(have);
    if offset != have {
        // Drain the in-flight body to nowhere (O(chunk) memory) so the
        // connection stays cleanly framed, then report the real
        // offset: the client's in-protocol 409 retry depends on
        // *receiving* this response, not a reset mid-upload.
        drop(guard);
        let (drained, complete) = http::read_body_to_within(
            stream,
            &leftover,
            declared,
            &mut std::io::sink(),
            Some(deadline),
        )?;
        state.metrics.bytes_in.fetch_add(drained, Ordering::Relaxed);
        if !complete {
            return Ok(false); // peer died mid-body anyway
        }
        let resp = text(409, "resume offset does not match the persisted partial")
            .header("x-received", &have.to_string());
        http::write_response(stream, &resp)?;
        return Ok(true);
    }
    if let Some(parent) = path.parent() {
        std::fs::create_dir_all(parent)?;
    }
    let file = std::fs::OpenOptions::new()
        .create(true)
        .append(true)
        .open(&path)
        .context("opening partial pack file")?;
    let mut sink = std::io::BufWriter::new(file);
    let (written, complete) =
        http::read_body_to_within(stream, &leftover, declared, &mut sink, Some(deadline))?;
    sink.flush().context("flushing partial pack file")?;
    drop(sink);
    state.metrics.bytes_in.fetch_add(written, Ordering::Relaxed);
    let now = have + written;
    if !complete {
        // Connection died mid-body. The prefix is on disk; the retry
        // resumes from it. Nobody is listening for a response.
        return Ok(false);
    }
    if now < total {
        http::write_response(
            stream,
            &text(202, "partial accepted").header("x-received", &now.to_string()),
        )?;
        return Ok(true);
    }
    // Complete: move the body out from under the lock, so the verify +
    // store fan-in (the expensive part) doesn't serialize concurrent
    // uploads of the same id behind it.
    let fin = tmp::unique_sibling(&path);
    if let Err(e) = std::fs::rename(&path, &fin) {
        http::write_response(stream, &text(500, format!("finalizing pack body: {e:#}")))?;
        return Ok(true);
    }
    drop(guard);
    let resp = finalize_pack(state, id, &fin, now, total);
    http::write_response(stream, &resp)?;
    Ok(true)
}

/// Verify a completed upload end to end (streaming, admits nothing on
/// failure) and fan it into the store.
fn finalize_pack(state: &ServerState, id: &str, fin: &Path, now: u64, total: u64) -> Response {
    let result = (|| -> Result<Response> {
        if now > total {
            return Ok(text(422, "pack does not match its declared id"));
        }
        let check = match pack::verify_pack_file(fin) {
            Ok(check) if check.id == id && check.len == total => check,
            Ok(_) => return Ok(text(422, "pack does not match its declared id")),
            Err(e) => return Ok(text(422, format!("pack verification failed: {e:#}"))),
        };
        match pack::unpack_verified(fin, &state.store, PACK_THREADS, &check) {
            Ok(stats) => {
                let mut obj = JsonObj::new();
                obj.insert("objects", stats.objects);
                obj.insert("raw_bytes", stats.raw_bytes);
                Ok(json_response(obj))
            }
            Err(e) => Ok(text(422, format!("pack verification failed: {e:#}"))),
        }
    })();
    let _ = std::fs::remove_file(fin);
    result.unwrap_or_else(|e| text(500, format!("{e:#}")))
}

fn object_endpoint(
    state: &ServerState,
    method: &str,
    hex: &str,
    req: &Request,
) -> Result<Response> {
    let oid = match Oid::from_hex(hex) {
        Ok(o) => o,
        Err(_) => return Ok(text(400, "bad object id")),
    };
    match method {
        "GET" => match state.store.get(&oid) {
            Ok(bytes) => Ok(Response::new(200).body(bytes)),
            Err(_) => Ok(text(404, "object not found")),
        },
        "PUT" => {
            if Oid::of_bytes(&req.body) != oid {
                return Ok(text(422, "object body does not hash to its id"));
            }
            state.store.put(&req.body)?;
            Ok(text(200, "stored"))
        }
        _ => Ok(text(404, "unsupported object method")),
    }
}

fn odb_batch(state: &ServerState, req: &Request) -> Result<Response> {
    let want = match parse_want(req) {
        Ok(w) => w,
        Err(e) => return Ok(text(400, format!("{e:#}"))),
    };
    let mut present = Vec::new();
    let mut missing = Vec::new();
    for oid in want {
        if state.odb.contains(&oid) {
            present.push(oid);
        } else {
            missing.push(oid);
        }
    }
    let mut obj = JsonObj::new();
    obj.insert("present", oid_arr(&present));
    obj.insert("missing", oid_arr(&missing));
    Ok(json_response(obj))
}

fn odb_endpoint(state: &ServerState, method: &str, hex: &str, req: &Request) -> Result<Response> {
    let oid = match Oid::from_hex(hex) {
        Ok(o) => o,
        Err(_) => return Ok(text(400, "bad object id")),
    };
    match method {
        "GET" => match state.odb.read(&oid) {
            Ok(obj) => Ok(Response::new(200).body(obj.encode())),
            Err(_) => Ok(text(404, "object not found")),
        },
        "HEAD" => {
            if state.odb.contains(&oid) {
                Ok(Response::new(200))
            } else {
                Ok(text(404, ""))
            }
        }
        "PUT" => {
            if Oid::of_bytes(&req.body) != oid {
                return Ok(text(422, "object body does not hash to its id"));
            }
            let obj = match Object::decode(&req.body) {
                Ok(o) => o,
                Err(e) => return Ok(text(422, format!("undecodable object: {e:#}"))),
            };
            state.odb.write(&obj)?;
            Ok(text(200, "stored"))
        }
        _ => Ok(text(404, "unsupported odb method")),
    }
}

fn refs_endpoint(state: &ServerState, method: &str, name: &str, req: &Request) -> Result<Response> {
    match method {
        "GET" => match state.refs.branch(name) {
            Ok(Some(oid)) => Ok(text(200, oid.to_hex())),
            Ok(None) => Ok(text(404, "no such branch")),
            Err(e) => Ok(text(400, format!("{e:#}"))),
        },
        "PUT" => {
            let body = String::from_utf8_lossy(&req.body).to_string();
            let (old, new) = match body.trim().split_once(' ') {
                Some(v) => v,
                None => return Ok(text(400, "ref update body is '<old|none> <new>'")),
            };
            let expected = if old == "none" {
                None
            } else {
                match Oid::from_hex(old) {
                    Ok(o) => Some(o),
                    Err(_) => return Ok(text(400, "bad old oid")),
                }
            };
            let new = match Oid::from_hex(new) {
                Ok(o) => o,
                Err(_) => return Ok(text(400, "bad new oid")),
            };
            let _guard = state.refs_lock.lock().unwrap();
            let current = match state.refs.branch(name) {
                Ok(c) => c,
                Err(e) => return Ok(text(400, format!("{e:#}"))),
            };
            if current != expected {
                let held = match current {
                    Some(oid) => oid.to_hex(),
                    None => "none".to_string(),
                };
                return Ok(text(409, held));
            }
            state.refs.set_branch(name, &new)?;
            Ok(text(200, "updated"))
        }
        _ => Ok(text(404, "unsupported refs method")),
    }
}

fn history_endpoint(state: &ServerState, hex: &str, req: &Request) -> Result<Response> {
    let tip = match Oid::from_hex(hex) {
        Ok(o) => o,
        Err(_) => return Ok(text(400, "bad tip oid")),
    };
    let mut exclude = Vec::new();
    if let Some(query) = req.query() {
        for pair in query.split('&') {
            if let Some(csv) = pair.strip_prefix("exclude=") {
                for part in csv.split(',').filter(|p| !p.is_empty()) {
                    match Oid::from_hex(part) {
                        Ok(o) => exclude.push(o),
                        Err(_) => return Ok(text(400, "bad exclude oid")),
                    }
                }
            }
        }
    }
    match commits_between(&state.odb, tip, &exclude) {
        Ok(commits) => {
            let mut obj = JsonObj::new();
            obj.insert("commits", oid_arr(&commits));
            Ok(json_response(obj))
        }
        Err(e) => Ok(text(404, format!("history walk failed: {e:#}"))),
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::lfs::http::HttpRemote;
    use crate::lfs::transport::RemoteTransport;
    use crate::util::tmp::TempDir;

    #[test]
    fn negotiation_pack_and_object_roundtrip() {
        let td_root = TempDir::new("srv-root").unwrap();
        let td_staging = TempDir::new("srv-staging").unwrap();
        let server = LfsServer::spawn(td_root.path()).unwrap();
        let remote = HttpRemote::open(&server.url(), Some(td_staging.path())).unwrap();

        // Seed the server store directly (what an earlier push did).
        let server_store = LfsStore::at(&td_root.path().join("lfs/objects"));
        let a = server_store.put(b"held-object").unwrap().0;
        let ghost = Oid::of_bytes(b"nobody");

        let resp = RemoteTransport::batch(&remote, &[a, ghost]).unwrap();
        assert_eq!(resp.present, vec![a]);
        assert_eq!(resp.present_sizes, vec![11]);
        assert_eq!(resp.missing, vec![ghost]);

        // Streamed pack download straight into a local store.
        let td_local = TempDir::new("srv-local").unwrap();
        let local = LfsStore::open(td_local.path());
        let (stats, wire) = remote.fetch_pack_into(&[a], &local, 1).unwrap();
        assert_eq!(stats.objects, 1);
        assert_eq!(wire.resumed_bytes, 0);
        assert_eq!(wire.wire_bytes, stats.packed_bytes);
        assert_eq!(local.get(&a).unwrap(), b"held-object");

        // Per-object fallback + server-side dedup.
        assert_eq!(remote.get_object(&a).unwrap(), b"held-object");
        remote.put_object(b"fresh-object").unwrap();
        remote.put_object(b"fresh-object").unwrap();
        let fresh = Oid::of_bytes(b"fresh-object");
        assert_eq!(server_store.get(&fresh).unwrap(), b"fresh-object");

        // Streamed pack upload (fresh content), then re-upload dedups.
        let b = local.put(b"uploaded-via-pack").unwrap().0;
        let (stats, wire) = remote.send_pack_from(&local, &[b], 1).unwrap();
        assert_eq!(stats.objects, 1);
        assert_eq!(wire.wire_bytes, stats.packed_bytes);
        assert_eq!(server_store.get(&b).unwrap(), b"uploaded-via-pack");

        // The whole conversation (negotiation, pack each way, object
        // fallbacks) ran over a handful of reused connections, not one
        // per request.
        assert!(
            remote.connections_opened() <= 2,
            "{} connects for ~8 requests — keep-alive broken",
            remote.connections_opened()
        );
    }

    #[test]
    fn unknown_routes_and_bad_ids_are_clean_errors() {
        let td_root = TempDir::new("srv-root").unwrap();
        let server = LfsServer::spawn(td_root.path()).unwrap();
        let authority = server.addr().to_string();

        let resp = http::roundtrip(&authority, &http::Request::new("GET", "/nope")).unwrap();
        assert_eq!(resp.status, 404);
        let resp = http::roundtrip(&authority, &http::Request::new("GET", "/packs/zzz")).unwrap();
        assert_eq!(resp.status, 400);
        let resp = http::roundtrip(&authority, &http::Request::new("GET", "/objects/abc")).unwrap();
        assert_eq!(resp.status, 400);
        // A corrupt per-object upload is rejected, not stored.
        let bogus = "0".repeat(64);
        let req = http::Request::new("PUT", &format!("/objects/{bogus}")).body(b"x".to_vec());
        assert_eq!(http::roundtrip(&authority, &req).unwrap().status, 422);
    }

    #[test]
    fn refs_cas_over_http() {
        let td_root = TempDir::new("srv-refs").unwrap();
        let server = LfsServer::spawn(td_root.path()).unwrap();
        let authority = server.addr().to_string();
        let a = Oid::of_bytes(b"ca");
        let b = Oid::of_bytes(b"cb");

        let get = |name: &str| {
            http::roundtrip(&authority, &http::Request::new("GET", &format!("/refs/{name}")))
                .unwrap()
        };
        assert_eq!(get("main").status, 404);

        let put = |body: String| {
            let req = http::Request::new("PUT", "/refs/main").body(body.into_bytes());
            http::roundtrip(&authority, &req).unwrap()
        };
        assert_eq!(put(format!("none {}", a.to_hex())).status, 200);
        assert_eq!(String::from_utf8_lossy(&get("main").body), a.to_hex());
        // Stale expectation loses the race.
        assert_eq!(put(format!("none {}", b.to_hex())).status, 409);
        assert_eq!(put(format!("{} {}", a.to_hex(), b.to_hex())).status, 200);
        assert_eq!(String::from_utf8_lossy(&get("main").body), b.to_hex());
    }

    #[test]
    fn stale_pack_caches_are_reaped_by_age() {
        let td_root = TempDir::new("srv-gc").unwrap();
        let server = LfsServer::spawn(td_root.path()).unwrap();
        let td_staging = TempDir::new("srv-gc-staging").unwrap();
        let remote = HttpRemote::open(&server.url(), Some(td_staging.path())).unwrap();

        // Create an outgoing pack + memo via a real fetch, and a fake
        // partial upload.
        let server_store = LfsStore::at(&td_root.path().join("lfs/objects"));
        let a = server_store.put(b"gc-object").unwrap().0;
        let td_local = TempDir::new("srv-gc-local").unwrap();
        let local = LfsStore::open(td_local.path());
        remote.fetch_pack_into(&[a], &local, 1).unwrap();
        let outgoing = td_root.path().join("lfs/outgoing");
        let n_cached = std::fs::read_dir(&outgoing)
            .unwrap()
            .flatten()
            .filter(|e| e.metadata().map(|m| m.is_file()).unwrap_or(false))
            .count();
        assert!(n_cached >= 1, "fetch must leave an outgoing pack cached");
        std::fs::create_dir_all(td_root.path().join("lfs/partial")).unwrap();
        std::fs::write(td_root.path().join("lfs/partial").join("0".repeat(64)), b"junk").unwrap();

        // Young entries survive an aged gc.
        let removed = gc_stale_packs(td_root.path(), Duration::from_secs(3600)).unwrap();
        assert_eq!(removed, 0, "fresh cache entries must survive");

        // A zero-age gc reaps everything: outgoing pack, bywant memo,
        // partial.
        let removed = gc_stale_packs(td_root.path(), Duration::ZERO).unwrap();
        assert!(removed >= 3, "expected pack + memo + partial reaped, got {removed}");

        // A reaped pack is simply rebuilt on the next request.
        let td_local2 = TempDir::new("srv-gc-local2").unwrap();
        let local2 = LfsStore::open(td_local2.path());
        remote.fetch_pack_into(&[a], &local2, 1).unwrap();
        assert_eq!(local2.get(&a).unwrap(), b"gc-object");
    }

    #[test]
    fn claimed_partials_survive_the_stale_reap() {
        let td_root = TempDir::new("srv-claim").unwrap();
        let server = LfsServer::spawn(td_root.path()).unwrap();

        // A partial upload whose file looks long-abandoned (mtime two
        // TTLs in the past) but whose per-pack-id lock is held by an
        // in-flight PUT.
        let id = "7".repeat(64);
        let partial_dir = td_root.path().join("lfs/partial");
        std::fs::create_dir_all(&partial_dir).unwrap();
        let path = partial_dir.join(&id);
        std::fs::write(&path, b"slow upload prefix").unwrap();
        let f = std::fs::OpenOptions::new().write(true).open(&path).unwrap();
        f.set_modified(std::time::SystemTime::now() - 2 * STALE_PACK_TTL)
            .unwrap();
        drop(f);

        let lock = id_lock(&server.state, &id);
        let guard = lock.lock().unwrap();

        // While the claim is held, even a zero-TTL reap must spare the
        // partial (age says stale, the live lock says otherwise).
        let removed = server.reap_stale(Duration::ZERO);
        assert_eq!(removed, 0, "reap deleted a partial with a live claim");
        assert!(path.exists(), "claimed partial was reaped out from under its PUT");

        // Once the upload releases its claim, age wins again.
        drop(guard);
        let removed = server.reap_stale(STALE_PACK_TTL);
        assert_eq!(removed, 1);
        assert!(!path.exists());
    }

    #[test]
    fn concurrent_uploads_of_different_packs_do_not_serialize() {
        // Two clients push different packs at the same time; per-id
        // locking must let both complete (the old global partial_lock
        // merely serialized them — this asserts correctness, the lock
        // split is about latency).
        let td_root = TempDir::new("srv-par").unwrap();
        let server = LfsServer::spawn(td_root.path()).unwrap();
        let url = server.url();
        let mut handles = Vec::new();
        for i in 0..2u8 {
            let url = url.clone();
            handles.push(std::thread::spawn(move || {
                let td_local = TempDir::new("srv-par-local").unwrap();
                let local = LfsStore::open(td_local.path());
                let oid = local.put(&vec![i; 5000]).unwrap().0;
                let remote = HttpRemote::open(&url, None).unwrap();
                let (stats, _) = remote.send_pack_from(&local, &[oid], 1).unwrap();
                assert_eq!(stats.objects, 1);
                oid
            }));
        }
        let server_store = LfsStore::at(&td_root.path().join("lfs/objects"));
        for h in handles {
            let oid = h.join().unwrap();
            assert!(server_store.contains(&oid));
        }
    }

    #[test]
    fn overload_sheds_with_retry_after_and_recovers() {
        let td_root = TempDir::new("srv-shed").unwrap();
        let server = LfsServer::spawn_with(
            td_root.path(),
            "127.0.0.1:0",
            ServeOptions {
                workers: 1,
                queue: 1,
                request_budget: Duration::from_secs(1),
                retry_after_secs: 7,
                ..ServeOptions::default()
            },
        )
        .unwrap();
        let authority = server.addr().to_string();

        // One idle connection pins the only worker, a second fills the
        // only queue slot.
        let hog_a = TcpStream::connect(server.addr()).unwrap();
        std::thread::sleep(Duration::from_millis(50));
        let hog_b = TcpStream::connect(server.addr()).unwrap();
        std::thread::sleep(Duration::from_millis(100));

        // The next connection must be shed immediately — 503 with a
        // Retry-After hint, not a stall behind the hogs.
        let resp =
            http::roundtrip(&authority, &http::Request::new("GET", "/metrics")).unwrap();
        assert_eq!(resp.status, 503);
        assert_eq!(resp.get_header("retry-after"), Some("7"));
        assert!(server.metrics().rejected >= 1);

        // Capacity returns once the hogs go away (dropped here; the
        // request budget would have reclaimed them within 1s anyway).
        drop(hog_a);
        drop(hog_b);
        let start = Instant::now();
        loop {
            let resp =
                http::roundtrip(&authority, &http::Request::new("GET", "/metrics")).unwrap();
            if resp.status == 200 {
                break;
            }
            assert!(
                start.elapsed() < Duration::from_secs(10),
                "server never recovered from overload"
            );
            std::thread::sleep(Duration::from_millis(50));
        }
    }

    #[test]
    fn stalled_upload_is_cut_by_the_request_budget_and_resumes() {
        let td_root = TempDir::new("srv-stall").unwrap();
        let server = LfsServer::spawn_with(
            td_root.path(),
            "127.0.0.1:0",
            ServeOptions {
                workers: 2,
                queue: 4,
                request_budget: Duration::from_millis(400),
                ..ServeOptions::default()
            },
        )
        .unwrap();
        let id = "5".repeat(64);

        // A client starts a 10_000-byte pack upload, sends 4_000
        // bytes, then stalls while holding the socket open.
        let mut stalled = TcpStream::connect(server.addr()).unwrap();
        let head = format!(
            "PUT /packs/{id} HTTP/1.1\r\nhost: x\r\ncontent-length: 10000\r\ncontent-range: bytes 0-9999/10000\r\n\r\n"
        );
        stalled.write_all(head.as_bytes()).unwrap();
        stalled.write_all(&[7u8; 4000]).unwrap();
        stalled.flush().unwrap();

        // The 400ms request budget — not the 30s IO_TIMEOUT — must cut
        // the stall, and the cut must be counted.
        let start = Instant::now();
        while server.metrics().timed_out < 1 {
            assert!(
                start.elapsed() < Duration::from_secs(5),
                "stalled upload was never cut by the request budget"
            );
            std::thread::sleep(Duration::from_millis(25));
        }

        // The received prefix survived on disk: the retry can resume.
        let authority = server.addr().to_string();
        let probe = http::Request::new("HEAD", &format!("/packs/{id}"));
        let resp = http::roundtrip(&authority, &probe).unwrap();
        assert_eq!(resp.get_header("x-received"), Some("4000"));
        drop(stalled);
    }

    #[test]
    fn restart_mid_session_reconnects_transparently_for_reads() {
        let td_root = TempDir::new("srv-restart").unwrap();
        let server = LfsServer::spawn(td_root.path()).unwrap();
        let server_store = LfsStore::at(&td_root.path().join("lfs/objects"));
        let a = server_store.put(b"survives-restart").unwrap().0;

        let remote = HttpRemote::open(&server.url(), None).unwrap();
        RemoteTransport::batch(&remote, &[a]).unwrap();
        assert_eq!(remote.connections_opened(), 1);

        // "Restart": every live connection is cut; disk state persists.
        assert!(server.kill_connections() >= 1);
        std::thread::sleep(Duration::from_millis(50));

        // The next negotiation rides the stale pooled connection, sees
        // the cut, and transparently reconnects (POST is
        // stale-retryable; see `may_retry_stale`).
        let resp = RemoteTransport::batch(&remote, &[a]).unwrap();
        assert_eq!(resp.present, vec![a]);
        assert_eq!(remote.connections_opened(), 2);

        // A full fetch works end to end on the new connection.
        let td_local = TempDir::new("srv-restart-local").unwrap();
        let local = LfsStore::open(td_local.path());
        remote.fetch_pack_into(&[a], &local, 1).unwrap();
        assert_eq!(local.get(&a).unwrap(), b"survives-restart");
    }

    #[test]
    fn shutdown_drains_in_flight_and_joins_every_worker() {
        let td_root = TempDir::new("srv-drain").unwrap();
        let server = LfsServer::spawn(td_root.path()).unwrap();
        let authority = server.addr().to_string();
        let resp =
            http::roundtrip(&authority, &http::Request::new("GET", "/metrics")).unwrap();
        assert_eq!(resp.status, 200);

        // Park an idle keep-alive connection on a worker.
        let idle = TcpStream::connect(server.addr()).unwrap();
        std::thread::sleep(Duration::from_millis(50));

        // Drain: the idle straggler is cut and every worker joined —
        // shutdown() returning at all proves zero leaked threads.
        let finals = server.shutdown();
        assert_eq!(finals.in_flight, 0);
        assert!(finals.requests >= 1);
        assert!(finals.accepted >= 2);
        drop(idle);
    }

    #[test]
    fn metrics_endpoint_reports_counters_as_json() {
        let td_root = TempDir::new("srv-metrics").unwrap();
        let server = LfsServer::spawn(td_root.path()).unwrap();
        let authority = server.addr().to_string();
        http::roundtrip(&authority, &http::Request::new("GET", "/nope")).unwrap();
        let resp =
            http::roundtrip(&authority, &http::Request::new("GET", "/metrics")).unwrap();
        assert_eq!(resp.status, 200);
        let body = String::from_utf8_lossy(&resp.body).to_string();
        let json = Json::parse(&body).unwrap();
        assert!(json.get("accepted").and_then(|v| v.as_u64()).unwrap() >= 1);
        assert_eq!(
            json.get("workers").and_then(|v| v.as_u64()),
            Some(ServeOptions::default().workers as u64)
        );
        // The metrics request itself is observably in flight.
        assert!(json.get("in_flight").and_then(|v| v.as_u64()).unwrap() >= 1);
        assert!(server.metrics().requests >= 1);
    }
}
