//! The remote transport abstraction: how packs cross a channel.
//!
//! PRs 1–3 built the pack engine against one "channel": a directory on
//! the same filesystem. [`RemoteTransport`] abstracts the channel into
//! the three operations the `Prefetcher` actually needs — one
//! have/want negotiation, pack receive, pack send — plus a per-object
//! fallback, so the orchestration in [`batch`](super::batch) is
//! transport-agnostic. Two implementations ship:
//!
//! * [`DirRemote`](super::remote::DirRemote) — the original directory
//!   remote (pack "transfer" is a local build/unpack pair).
//! * [`HttpRemote`](super::http::HttpRemote) — a client for the
//!   `git-theta serve` wire protocol with **byte-range resume**: an
//!   interrupted pack transfer persists its partial bytes (client side
//!   on fetch, server side on push) and a retry moves only the missing
//!   tail.
//!
//! [`WireReport`] is how a transport tells the orchestrator what
//! actually crossed the wire, so resume savings are measurable
//! (`TransferSummary::wire_bytes` / `resumed_bytes`).

use super::batch::{self, BatchResponse};
use super::pack::PackStats;
use super::store::LfsStore;
use crate::gitcore::object::Oid;
use crate::gitcore::remote::RemoteSpec;
use anyhow::{bail, Result};
use std::path::Path;

/// What one pack transfer moved over the wire.
#[derive(Debug, Clone, Copy, Default, PartialEq, Eq)]
pub struct WireReport {
    /// Pack bytes that crossed the wire in this call.
    pub wire_bytes: u64,
    /// Pack bytes *not* re-sent because a persisted partial transfer
    /// was resumed with a byte range. Always 0 for local transports.
    pub resumed_bytes: u64,
}

/// A channel that can negotiate and move packs with a remote store.
///
/// The pack operations are **streaming end to end**: a transport moves
/// packs between stores and spill files (client staging dirs, server
/// caches) in bounded chunks, so peak memory scales with the largest
/// object plus a small window — never with pack size. That is why the
/// trait deals in *stores* rather than pack blobs: handing a
/// `Vec<u8>` across the trait boundary would force the whole pack into
/// RAM on both sides.
///
/// Implementations must be cheap to call concurrently: the
/// `Prefetcher` fans sharded packs across worker threads, each calling
/// [`RemoteTransport::fetch_pack_into`] / `send_pack_from` with its
/// own shard. Negotiation counters are recorded by the transport (one
/// per [`RemoteTransport::batch`] call); pack/object/byte counters are
/// recorded by the orchestrator.
pub trait RemoteTransport: Send + Sync {
    /// Human-readable endpoint description for error messages.
    fn describe(&self) -> String;

    /// One have/want negotiation round trip: partition `want` into
    /// present (with sizes, for shard planning) and missing.
    fn batch(&self, want: &[Oid]) -> Result<BatchResponse>;

    /// Obtain a pack holding `oids` from the remote side and admit its
    /// objects into `dest`, streaming (the pack is checksum-verified
    /// before anything is admitted, and never fully RAM-resident).
    ///
    /// Resumable: if a previous call was interrupted, implementations
    /// may re-request only the missing tail of the persisted partial.
    fn fetch_pack_into(
        &self,
        oids: &[Oid],
        dest: &LfsStore,
        threads: usize,
    ) -> Result<(PackStats, WireReport)>;

    /// Assemble a pack of `oids` from `src` and deliver it to the
    /// remote side, which verifies and fans it into its store. The
    /// pack spills to a file and streams out in bounded chunks.
    ///
    /// Resumable: if the remote persisted a partial body from an
    /// interrupted attempt, only the tail is re-sent.
    fn send_pack_from(
        &self,
        src: &LfsStore,
        oids: &[Oid],
        threads: usize,
    ) -> Result<(PackStats, WireReport)>;

    /// Per-object fallback: read one object (hash-verified).
    fn get_object(&self, oid: &Oid) -> Result<Vec<u8>>;

    /// Per-object fallback: store one object (content-addressed, so
    /// re-sending existing content deduplicates remotely).
    fn put_object(&self, bytes: &[u8]) -> Result<()>;
}

/// Open the transport a [`RemoteSpec`] addresses.
///
/// `staging` is a repository `.theta` dir (or any directory) where an
/// HTTP transport persists partial pack downloads so an interrupted
/// fetch resumes across process restarts; `None` disables persistence
/// (transfers still work, they just restart from zero).
pub fn open_transport(
    spec: &RemoteSpec,
    staging: Option<&Path>,
) -> Result<Box<dyn RemoteTransport>> {
    Ok(match spec {
        RemoteSpec::Dir(path) => Box::new(super::remote::DirRemote::open(path)),
        RemoteSpec::Http(url) => Box::new(super::http::HttpRemote::open(url, staging)?),
    })
}

/// Upload objects the remote is missing. Returns (sent, raw bytes).
///
/// Packed by default: one negotiation, then every missing object in
/// integrity-checked packs. Errors if a wanted object is absent from
/// the local store too. `THETA_TRANSFER=object` (or the CLI override)
/// selects the legacy per-object engine.
pub fn upload(
    local: &LfsStore,
    remote: &dyn RemoteTransport,
    oids: &[Oid],
) -> Result<(usize, u64)> {
    if batch::per_object_mode() {
        return upload_per_object(local, remote, oids);
    }
    let s = batch::push_pack(local, remote, oids)?;
    if s.unavailable > 0 {
        bail!(
            "cannot upload: {} wanted object(s) missing from the local store",
            s.unavailable
        );
    }
    Ok((s.objects, s.raw_bytes))
}

/// Download objects the local store is missing. Returns
/// (fetched, raw bytes). Packed by default, like [`upload`]; errors if
/// the remote lacks a requested object.
pub fn download(
    remote: &dyn RemoteTransport,
    local: &LfsStore,
    oids: &[Oid],
) -> Result<(usize, u64)> {
    if batch::per_object_mode() {
        return download_per_object(remote, local, oids);
    }
    let s = batch::fetch_pack(remote, local, oids)?;
    if s.unavailable > 0 {
        bail!("remote is missing {} requested object(s)", s.unavailable);
    }
    Ok((s.objects, s.raw_bytes))
}

/// Legacy upload engine (the seed's behavior): one negotiation for the
/// whole set, then one store request per missing object.
pub fn upload_per_object(
    local: &LfsStore,
    remote: &dyn RemoteTransport,
    oids: &[Oid],
) -> Result<(usize, u64)> {
    let mut sent = 0;
    let mut bytes = 0;
    for oid in remote.batch(oids)?.missing {
        let data = local.get(&oid)?;
        bytes += data.len() as u64;
        remote.put_object(&data)?;
        batch::record(|s| {
            s.objects += 1;
            s.object_transfers += 1;
            s.raw_bytes += data.len() as u64;
            s.packed_bytes += data.len() as u64;
            s.wire_bytes += data.len() as u64;
        });
        sent += 1;
    }
    Ok((sent, bytes))
}

/// Legacy download engine (the seed's behavior): one fetch request per
/// locally missing object.
pub fn download_per_object(
    remote: &dyn RemoteTransport,
    local: &LfsStore,
    oids: &[Oid],
) -> Result<(usize, u64)> {
    let mut fetched = 0;
    let mut bytes = 0;
    for oid in oids {
        if !local.contains(oid) {
            let data = remote.get_object(oid)?;
            bytes += data.len() as u64;
            local.put(&data)?;
            batch::record(|s| {
                s.objects += 1;
                s.object_transfers += 1;
                s.raw_bytes += data.len() as u64;
                s.packed_bytes += data.len() as u64;
                s.wire_bytes += data.len() as u64;
            });
            fetched += 1;
        }
    }
    Ok((fetched, bytes))
}
