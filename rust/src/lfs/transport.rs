//! The remote transport abstraction: how packs cross a channel.
//!
//! PRs 1–3 built the pack engine against one "channel": a directory on
//! the same filesystem. [`RemoteTransport`] abstracts the channel into
//! the three operations the `Prefetcher` actually needs — one
//! have/want negotiation, pack receive, pack send — plus a per-object
//! fallback, so the orchestration in [`batch`](super::batch) is
//! transport-agnostic. Two implementations ship:
//!
//! * [`DirRemote`](super::remote::DirRemote) — the original directory
//!   remote (pack "transfer" is a local build/unpack pair).
//! * [`HttpRemote`](super::http::HttpRemote) — a client for the
//!   `git-theta serve` wire protocol with **byte-range resume**: an
//!   interrupted pack transfer persists its partial bytes (client side
//!   on fetch, server side on push) and a retry moves only the missing
//!   tail.
//!
//! [`WireReport`] is how a transport tells the orchestrator what
//! actually crossed the wire, so resume savings are measurable
//! (`TransferSummary::wire_bytes` / `resumed_bytes`).

use super::batch::{self, BatchResponse};
use super::pack::{self, DeltaPlan, PackStats, PlanCache};
use super::store::LfsStore;
use crate::gitcore::object::Oid;
use crate::gitcore::remote::RemoteSpec;
use crate::util::json::{Json, JsonObj};
use anyhow::{bail, Context, Result};
use std::collections::HashMap;
use std::path::Path;

/// What one pack transfer moved over the wire.
#[derive(Debug, Clone, Copy, Default, PartialEq, Eq)]
pub struct WireReport {
    /// Pack bytes that crossed the wire in this call.
    pub wire_bytes: u64,
    /// Pack bytes *not* re-sent because a persisted partial transfer
    /// was resumed with a byte range. Always 0 for local transports.
    pub resumed_bytes: u64,
}

/// One entry of a chain advertisement: the chain key identifying the
/// metadata prefix ending at this entry, plus the LFS oids that entry
/// references.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct ChainEntryAdvert {
    /// `GroupMetadata::chain_key` of the prefix ending here — the
    /// identity a responder *could* match on; presence is actually
    /// decided from the oids, so keys never have to exist remotely.
    pub key: Oid,
    /// LFS oids this chain entry references.
    pub oids: Vec<Oid>,
}

/// What a chain-aware client advertises in one negotiation: the chains
/// it is about to push (base → tip, one `Vec<ChainEntryAdvert>` per
/// group chain) plus the flat want set. The want set is authoritative —
/// chains only *annotate* it with structure a responder can use to
/// nominate delta bases.
#[derive(Debug, Clone, Default, PartialEq, Eq)]
pub struct ChainAdvert {
    /// Group chains, each base → tip.
    pub chains: Vec<Vec<ChainEntryAdvert>>,
    /// Flat want set (exactly what [`RemoteTransport::batch`] would be
    /// asked), so a chain-oblivious responder loses nothing.
    pub want: Vec<Oid>,
}

/// A responder's answer to a [`ChainAdvert`]: the flat have/want split
/// plus, per advertised chain, how deep a prefix the responder already
/// holds (entries `0..have_depth` fully present).
#[derive(Debug, Clone, Default, PartialEq, Eq)]
pub struct ChainNegotiation {
    /// Flat negotiation result over the want set (identical shape to
    /// [`RemoteTransport::batch`]).
    pub batch: BatchResponse,
    /// Per advertised chain: the deepest k such that entries `0..k`
    /// are fully present on the responder. Suffix entries `k..` are
    /// what the client must ship.
    pub have_depths: Vec<usize>,
    /// Whether the responder actually understood the chain protocol.
    /// `false` means the depths are all zero because the peer only
    /// speaks the flat protocol (version skew) — callers must not plan
    /// store-based deltas in that case.
    pub chain_aware: bool,
}

/// Answer a [`ChainAdvert`] against a store: one bulk [`LfsStore::stat_all`]
/// over the union of the want set and every advertised chain oid (no
/// per-oid stats), split into the flat response plus per-chain have
/// depths. Shared by the directory transport and the HTTP server so
/// both ends of the wire agree by construction.
pub fn answer_chains(store: &LfsStore, adv: &ChainAdvert) -> ChainNegotiation {
    let mut all: Vec<Oid> = adv.want.clone();
    for chain in &adv.chains {
        for entry in chain {
            all.extend_from_slice(&entry.oids);
        }
    }
    all.sort();
    all.dedup();
    let sizes = store.stat_all(&all);
    let present: HashMap<Oid, Option<u64>> = all.iter().copied().zip(sizes).collect();

    let mut batch = BatchResponse::default();
    for oid in &adv.want {
        match present.get(oid).copied().flatten() {
            Some(size) => {
                batch.present.push(*oid);
                batch.present_sizes.push(size);
            }
            None => batch.missing.push(*oid),
        }
    }
    let have_depths = adv
        .chains
        .iter()
        .map(|chain| {
            chain
                .iter()
                .take_while(|entry| {
                    !entry.oids.is_empty()
                        && entry
                            .oids
                            .iter()
                            .all(|o| present.get(o).copied().flatten().is_some())
                })
                .count()
        })
        .collect();
    ChainNegotiation {
        batch,
        have_depths,
        chain_aware: true,
    }
}

/// Per advertised chain, how deep a prefix the *advertising client*
/// holds, derived purely from the advert itself: the want set is
/// exactly what the client lacks, so an entry whose oids are all
/// outside `want` is provably client-held. This is the fetch-direction
/// mirror of [`answer_chains`] — there the responder's store decides
/// the depth, here the client's own want set does, and no extra round
/// trip is spent asking.
pub(crate) fn client_held_depths(adv: &ChainAdvert) -> Vec<usize> {
    let want: std::collections::HashSet<Oid> = adv.want.iter().copied().collect();
    adv.chains
        .iter()
        .map(|chain| {
            chain
                .iter()
                .take_while(|entry| {
                    !entry.oids.is_empty() && entry.oids.iter().all(|o| !want.contains(o))
                })
                .count()
        })
        .collect()
}

/// Responder half of a chain-aware **fetch**: plan the delta pack a
/// client's [`ChainAdvert`] earns, against `store` (the responder's
/// objects).
///
/// The client's held depth per chain comes from [`client_held_depths`];
/// [`batch::chain_bases`] then nominates the deepest client-held entry
/// as a [`pack::KIND_STORE`] base (resolvable by the receiver by
/// construction) — or, for chains the client holds nothing of, the
/// in-flight base as [`pack::KIND_REF`]. Bases the *responder* cannot
/// read are demoted to full records inside [`pack::plan_deltas_cached`],
/// so the effective depth is min(client-held, responder-held) without a
/// second store scan. Shared by the directory transport and the HTTP
/// server so both responders plan identically; `cache` memoizes the CDC
/// encodes across repeated fetches of the same chain.
pub(crate) fn plan_fetch_deltas(
    store: &LfsStore,
    adv: &ChainAdvert,
    threads: usize,
    cache: Option<&PlanCache>,
) -> Result<DeltaPlan> {
    let mut want = adv.want.clone();
    want.sort();
    want.dedup();
    let neg = ChainNegotiation {
        batch: BatchResponse::default(),
        have_depths: client_held_depths(adv),
        chain_aware: true,
    };
    let base_of = batch::chain_bases(adv, &neg, &want);
    pack::plan_deltas_cached(store, &want, &base_of, threads, cache)
}

/// Encode a [`ChainAdvert`] as the `POST /objects/batch` request body
/// of protocol 2. The `want` field is byte-compatible with the flat
/// protocol, so an old server simply ignores the extra keys.
pub(crate) fn chain_advert_body(adv: &ChainAdvert) -> Vec<u8> {
    let mut obj = JsonObj::new();
    obj.insert("protocol", 2u32);
    obj.insert(
        "want",
        Json::Arr(adv.want.iter().map(|o| Json::from(o.to_hex())).collect()),
    );
    let chains: Vec<Json> = adv
        .chains
        .iter()
        .map(|chain| {
            let entries: Vec<Json> = chain
                .iter()
                .map(|entry| {
                    let mut e = JsonObj::new();
                    e.insert("key", entry.key.to_hex());
                    e.insert(
                        "oids",
                        Json::Arr(entry.oids.iter().map(|o| Json::from(o.to_hex())).collect()),
                    );
                    Json::Obj(e)
                })
                .collect();
            let mut c = JsonObj::new();
            c.insert("entries", Json::Arr(entries));
            Json::Obj(c)
        })
        .collect();
    obj.insert("chains", Json::Arr(chains));
    Json::Obj(obj).to_string_compact().into_bytes()
}

/// Decode the chain portion of a protocol-2 `POST /objects/batch`
/// request (the server side of [`chain_advert_body`]).
pub(crate) fn parse_chain_advert(json: &Json) -> Result<ChainAdvert> {
    let want = crate::gitcore::remote::parse_oid_arr(json, "want")?;
    let mut chains = Vec::new();
    for chain in json
        .get("chains")
        .and_then(|v| v.as_arr())
        .context("chain negotiation request missing 'chains'")?
    {
        let entries = chain
            .get("entries")
            .and_then(|v| v.as_arr())
            .context("chain advertisement missing 'entries'")?;
        let mut parsed = Vec::with_capacity(entries.len());
        for entry in entries {
            let key = Oid::from_hex(
                entry
                    .get("key")
                    .and_then(|v| v.as_str())
                    .context("chain entry missing 'key'")?,
            )?;
            let oids = crate::gitcore::remote::parse_oid_arr(entry, "oids")?;
            parsed.push(ChainEntryAdvert { key, oids });
        }
        chains.push(parsed);
    }
    Ok(ChainAdvert { chains, want })
}

/// A channel that can negotiate and move packs with a remote store.
///
/// The pack operations are **streaming end to end**: a transport moves
/// packs between stores and spill files (client staging dirs, server
/// caches) in bounded chunks, so peak memory scales with the largest
/// object plus a small window — never with pack size. That is why the
/// trait deals in *stores* rather than pack blobs: handing a
/// `Vec<u8>` across the trait boundary would force the whole pack into
/// RAM on both sides.
///
/// Implementations must be cheap to call concurrently: the
/// `Prefetcher` fans sharded packs across worker threads, each calling
/// [`RemoteTransport::fetch_pack_into`] / `send_pack_from` with its
/// own shard. Negotiation counters are recorded by the transport (one
/// per [`RemoteTransport::batch`] call); pack/object/byte counters are
/// recorded by the orchestrator.
pub trait RemoteTransport: Send + Sync {
    /// Human-readable endpoint description for error messages.
    fn describe(&self) -> String;

    /// One have/want negotiation round trip: partition `want` into
    /// present (with sizes, for shard planning) and missing.
    fn batch(&self, want: &[Oid]) -> Result<BatchResponse>;

    /// Obtain a pack holding `oids` from the remote side and admit its
    /// objects into `dest`, streaming (the pack is checksum-verified
    /// before anything is admitted, and never fully RAM-resident).
    ///
    /// Resumable: if a previous call was interrupted, implementations
    /// may re-request only the missing tail of the persisted partial.
    fn fetch_pack_into(
        &self,
        oids: &[Oid],
        dest: &LfsStore,
        threads: usize,
    ) -> Result<(PackStats, WireReport)>;

    /// Assemble a pack of `oids` from `src` and deliver it to the
    /// remote side, which verifies and fans it into its store. The
    /// pack spills to a file and streams out in bounded chunks.
    ///
    /// Resumable: if the remote persisted a partial body from an
    /// interrupted attempt, only the tail is re-sent.
    fn send_pack_from(
        &self,
        src: &LfsStore,
        oids: &[Oid],
        threads: usize,
    ) -> Result<(PackStats, WireReport)>;

    /// Per-object fallback: read one object (hash-verified).
    fn get_object(&self, oid: &Oid) -> Result<Vec<u8>>;

    /// Per-object fallback: store one object (content-addressed, so
    /// re-sending existing content deduplicates remotely).
    fn put_object(&self, bytes: &[u8]) -> Result<()>;

    /// Chain-aware negotiation: one round trip answering the flat
    /// have/want split *and* how deep a prefix of each advertised
    /// chain the remote already holds.
    ///
    /// The default degrades to the flat protocol — [`RemoteTransport::batch`]
    /// over the want set with all depths zero and `chain_aware: false` —
    /// which is exactly the version-skew fallback: a transport that
    /// predates chains still negotiates correctly, it just never earns
    /// deltas.
    fn negotiate_chains(&self, adv: &ChainAdvert) -> Result<ChainNegotiation> {
        Ok(ChainNegotiation {
            batch: self.batch(&adv.want)?,
            have_depths: vec![0; adv.chains.len()],
            chain_aware: false,
        })
    }

    /// Deliver a delta-planned pack. The default ignores the plan's
    /// delta pairings and ships every object whole via
    /// [`RemoteTransport::send_pack_from`] — correct for any receiver,
    /// since a delta pack is an optimization, never a requirement.
    fn send_pack_with_bases(
        &self,
        src: &LfsStore,
        plan: &DeltaPlan,
        threads: usize,
    ) -> Result<(PackStats, WireReport)> {
        self.send_pack_from(src, &plan.all_oids(), threads)
    }

    /// Fetch the advert's want set, letting the responder ship suffix
    /// objects as delta records against bases the advert proves the
    /// *client* holds (the fetch-direction mirror of
    /// [`RemoteTransport::send_pack_with_bases`]).
    ///
    /// The default ignores the chains and fetches a flat pack of the
    /// want set via [`RemoteTransport::fetch_pack_into`] — exactly the
    /// version-skew fallback: a transport (or the server behind it)
    /// that predates fetch deltas still converges byte-identically, it
    /// just never earns them.
    fn fetch_pack_with_chains(
        &self,
        adv: &ChainAdvert,
        dest: &LfsStore,
        threads: usize,
    ) -> Result<(PackStats, WireReport)> {
        self.fetch_pack_into(&adv.want, dest, threads)
    }

    /// The remote store's full oid inventory, if this transport can
    /// enumerate it (`GET /objects` over HTTP, a directory scan for a
    /// dir remote). Anti-entropy repair unions inventories across
    /// mirrors to compute what each one is missing. The default
    /// returns `Ok(None)`: a transport that cannot enumerate (a
    /// pre-inventory server) degrades to "cannot be repaired", never
    /// to a wrong answer.
    fn list_oids(&self) -> Result<Option<Vec<Oid>>> {
        Ok(None)
    }
}

/// Open the transport a [`RemoteSpec`] addresses.
///
/// `staging` is a repository `.theta` dir (or any directory) where an
/// HTTP transport persists partial pack downloads so an interrupted
/// fetch resumes across process restarts; `None` disables persistence
/// (transfers still work, they just restart from zero). For a replica
/// set the same staging dir is shared by every mirror — partials are
/// content-addressed, not mirror-addressed, which is what lets a
/// failover resume another mirror's interrupted download. The replica
/// write quorum is read from `theta.replica-quorum` in
/// `<staging>/config` when present (the staging dir *is* the repo's
/// `.theta` dir at every repository call site).
pub fn open_transport(
    spec: &RemoteSpec,
    staging: Option<&Path>,
) -> Result<Box<dyn RemoteTransport>> {
    Ok(match spec {
        RemoteSpec::Dir(path) => Box::new(super::remote::DirRemote::open(path)),
        RemoteSpec::Http(url) => Box::new(super::http::HttpRemote::open(url, staging)?),
        RemoteSpec::Replica(set) => {
            Box::new(super::replicate::ReplicatedRemote::open(set, staging)?)
        }
    })
}

/// Upload objects the remote is missing. Returns (sent, raw bytes).
///
/// Packed by default: one negotiation, then every missing object in
/// integrity-checked packs. Errors if a wanted object is absent from
/// the local store too. `THETA_TRANSFER=object` (or the CLI override)
/// selects the legacy per-object engine.
pub fn upload(
    local: &LfsStore,
    remote: &dyn RemoteTransport,
    oids: &[Oid],
) -> Result<(usize, u64)> {
    if batch::per_object_mode() {
        return upload_per_object(local, remote, oids);
    }
    let s = batch::push_pack(local, remote, oids)?;
    if s.unavailable > 0 {
        bail!(
            "cannot upload: {} wanted object(s) missing from the local store",
            s.unavailable
        );
    }
    Ok((s.objects, s.raw_bytes))
}

/// Upload with chain advertisements: like [`upload`], but the remote
/// may answer with chain depths that let the pack ship suffix objects
/// as deltas against bases it already holds (or against a shared base
/// travelling in the same pack).
///
/// Falls back to the plain packed [`upload`] whenever chains are
/// empty, the per-object engine is selected, or flat negotiation is
/// forced (`THETA_NEGOTIATE=flat` / [`batch::set_flat_negotiation`]) —
/// in all of those cases the wire traffic is byte-identical to the
/// flat protocol.
pub fn upload_with_chains(
    local: &LfsStore,
    remote: &dyn RemoteTransport,
    adv: &ChainAdvert,
) -> Result<(usize, u64)> {
    if batch::per_object_mode() || adv.chains.is_empty() || batch::flat_negotiation() {
        return upload(local, remote, &adv.want);
    }
    let s = batch::Prefetcher::default().push_with_chains(local, remote, adv)?;
    if s.unavailable > 0 {
        bail!(
            "cannot upload: {} wanted object(s) missing from the local store",
            s.unavailable
        );
    }
    Ok((s.objects, s.raw_bytes))
}

/// Download objects the local store is missing. Returns
/// (fetched, raw bytes). Packed by default, like [`upload`]; errors if
/// the remote lacks a requested object.
pub fn download(
    remote: &dyn RemoteTransport,
    local: &LfsStore,
    oids: &[Oid],
) -> Result<(usize, u64)> {
    if batch::per_object_mode() {
        return download_per_object(remote, local, oids);
    }
    let s = batch::fetch_pack(remote, local, oids)?;
    if s.unavailable > 0 {
        bail!("remote is missing {} requested object(s)", s.unavailable);
    }
    Ok((s.objects, s.raw_bytes))
}

/// Download with chain advertisements: like [`download`], but the
/// responder may answer the advert with delta records against bases
/// the advert proves this client already holds, so fetching a
/// fine-tune over a held base ships a fraction of the flat wire bytes.
///
/// Falls back to the plain packed [`download`] whenever chains are
/// empty, the per-object engine is selected, or flat negotiation is
/// forced — mirroring [`upload_with_chains`]'s fallback ladder, with
/// wire traffic byte-identical to the flat protocol in each case.
pub fn download_with_chains(
    remote: &dyn RemoteTransport,
    local: &LfsStore,
    adv: &ChainAdvert,
) -> Result<(usize, u64)> {
    if batch::per_object_mode() || adv.chains.is_empty() || batch::flat_negotiation() {
        return download(remote, local, &adv.want);
    }
    let s = batch::Prefetcher::default().fetch_with_chains(remote, local, adv)?;
    if s.unavailable > 0 {
        bail!("remote is missing {} requested object(s)", s.unavailable);
    }
    Ok((s.objects, s.raw_bytes))
}

/// Legacy upload engine (the seed's behavior): one negotiation for the
/// whole set, then one store request per missing object.
pub fn upload_per_object(
    local: &LfsStore,
    remote: &dyn RemoteTransport,
    oids: &[Oid],
) -> Result<(usize, u64)> {
    let mut sent = 0;
    let mut bytes = 0;
    for oid in remote.batch(oids)?.missing {
        let data = local.get(&oid)?;
        bytes += data.len() as u64;
        remote.put_object(&data)?;
        batch::record(|s| {
            s.objects += 1;
            s.object_transfers += 1;
            s.raw_bytes += data.len() as u64;
            s.packed_bytes += data.len() as u64;
            s.wire_bytes += data.len() as u64;
        });
        sent += 1;
    }
    Ok((sent, bytes))
}

/// Legacy download engine (the seed's behavior): one fetch request per
/// locally missing object.
pub fn download_per_object(
    remote: &dyn RemoteTransport,
    local: &LfsStore,
    oids: &[Oid],
) -> Result<(usize, u64)> {
    let mut fetched = 0;
    let mut bytes = 0;
    for oid in oids {
        if !local.contains(oid) {
            let data = remote.get_object(oid)?;
            bytes += data.len() as u64;
            local.put(&data)?;
            batch::record(|s| {
                s.objects += 1;
                s.object_transfers += 1;
                s.raw_bytes += data.len() as u64;
                s.packed_bytes += data.len() as u64;
                s.wire_bytes += data.len() as u64;
            });
            fetched += 1;
        }
    }
    Ok((fetched, bytes))
}
