//! Replicated multi-mirror remotes: quorum pushes, failover fetch
//! with cross-mirror resume, and anti-entropy repair.
//!
//! A shared model artifact at hub scale cannot depend on one remote
//! staying up: a mirror dying mid-transfer must neither lose a push
//! nor restart a multi-gigabyte fetch from byte zero.
//! [`ReplicatedRemote`] implements [`RemoteTransport`] over N inner
//! transports (Dir or HTTP, mixed) so the whole transfer stack —
//! `Prefetcher`, chain negotiation, the CLI — drives a replica set
//! exactly as it drives one remote:
//!
//! - **Pushes fan out** to every mirror and succeed at a configurable
//!   write quorum (`theta.replica-quorum`, default all). A push that
//!   meets quorum with some mirror down succeeds and counts a
//!   `quorum_shortfalls` on the transfer stats; the laggard converges
//!   later via [`ReplicatedRemote::repair`]. A sub-quorum outcome is
//!   an error — retryable (a [`WireError::cut`]) when enough of the
//!   per-mirror failures were themselves retryable under
//!   [`classify`] to make quorum reachable, fatal otherwise.
//! - **Fetches pick the healthiest mirror** via a per-mirror
//!   [`MirrorHealth`] circuit breaker: consecutive shed/timeout/cut
//!   failures open it, bypasses eventually admit a half-open probe,
//!   and a success closes it again. Among equally healthy mirrors the
//!   lowest latency EWMA serves first.
//! - **A mid-pack mirror death fails over, resuming mid-byte.**
//!   Partial downloads in `lfs/incoming/` are content-addressed (the
//!   pack id is a hash of the pack's object set), *not*
//!   mirror-addressed — so when mirror A dies at byte `k`, the next
//!   mirror's transport claims the same persisted partial and range-
//!   requests bytes `k..` instead of starting over. Each switch
//!   counts one `mirror_failovers`.
//! - **Retry cost does not multiply with mirrors.** Every attempt —
//!   first try or failover — spends from one per-operation
//!   [`RetryBudget`], so N mirrors share the policy's retry
//!   allowance instead of each claiming its own.
//!
//! Negotiation merges are deliberately asymmetric: `batch` reports an
//! object *present* when any reachable mirror holds it (so fetches
//! can fail over to the holder) and *missing* only when no mirror
//! does. A push therefore ships exactly the objects new to the whole
//! set; objects that some-but-not-all mirrors hold (the residue of a
//! past quorum shortfall) are not re-fanned by pushes — that is
//! [`ReplicatedRemote::repair`]'s job: union the mirror inventories
//! ([`RemoteTransport::list_oids`]), run a have/want negotiation per
//! mirror over the union, fetch each missing object from a mirror
//! that holds it, and ship it to each mirror that lacks it. Repair
//! moves whole objects (delta records need chain metadata that lives
//! above this layer) and is idempotent: a second pass ships nothing.
//!
//! A replica set of one mirror delegates every call straight through,
//! byte- and stat-identically to the bare transport
//! (`rust/tests/remote_parity.rs` pins this).
//!
//! [`WireError::cut`]: super::retry::WireError::cut
//! [`classify`]: super::retry::classify

use super::batch::{self, BatchResponse};
use super::pack::{DeltaPlan, PackStats};
use super::retry::{classify, retry_after_of, FailureClass, RetryBudget, RetryPolicy};
use super::store::LfsStore;
use super::transport::{
    open_transport, ChainAdvert, ChainNegotiation, RemoteTransport, WireReport,
};
use crate::gitcore::object::Oid;
use crate::gitcore::remote::RemoteSpec;
use anyhow::{anyhow, bail, Context, Result};
use std::collections::{BTreeMap, BTreeSet};
use std::path::Path;
use std::sync::atomic::{AtomicU32, AtomicU64, Ordering};
use std::time::Instant;

/// Consecutive retryable failures that open a mirror's circuit.
const OPEN_AFTER: u32 = 3;
/// Times an open mirror is bypassed before it earns a half-open probe.
const PROBE_AFTER: u32 = 4;

/// Circuit-breaker position for one mirror.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum HealthState {
    /// Healthy: serves requests normally.
    Closed,
    /// Tripped: bypassed while better mirrors are available.
    Open,
    /// Tripped but due a probe: the next request may test it.
    HalfOpen,
}

/// Per-mirror health: a deterministic circuit breaker plus a latency
/// EWMA for fastest-first selection.
///
/// The breaker counts *consecutive retryable* failures (shed, timeout,
/// cut — the classes [`classify`] deems transient); [`OPEN_AFTER`] of
/// them open it. An open mirror is not gone forever: each time
/// selection bypasses it a counter ticks, and after [`PROBE_AFTER`]
/// bypasses the mirror reports [`HealthState::HalfOpen`] — the next
/// operation tries it as a probe. Success closes the breaker (and
/// zeroes the failure run); a failed probe re-opens it and the
/// bypass count starts over. Counting bypasses instead of wall-clock
/// keeps seeded chaos runs replayable.
#[derive(Debug, Default)]
pub struct MirrorHealth {
    consecutive_failures: AtomicU32,
    bypasses: AtomicU32,
    /// Latency EWMA in microseconds; 0 = no sample yet.
    ewma_micros: AtomicU64,
}

impl MirrorHealth {
    /// Current breaker position.
    pub fn state(&self) -> HealthState {
        if self.consecutive_failures.load(Ordering::Relaxed) < OPEN_AFTER {
            HealthState::Closed
        } else if self.bypasses.load(Ordering::Relaxed) >= PROBE_AFTER {
            HealthState::HalfOpen
        } else {
            HealthState::Open
        }
    }

    /// Record a successful operation and its latency: closes the
    /// breaker and folds the sample into the EWMA (¼ new, ¾ old).
    pub fn record_success(&self, elapsed_micros: u64) {
        self.consecutive_failures.store(0, Ordering::Relaxed);
        self.bypasses.store(0, Ordering::Relaxed);
        let old = self.ewma_micros.load(Ordering::Relaxed);
        let new = if old == 0 {
            elapsed_micros.max(1)
        } else {
            (3 * old + elapsed_micros.max(1)) / 4
        };
        self.ewma_micros.store(new, Ordering::Relaxed);
    }

    /// Record a failed operation. Only retryable classes feed the
    /// breaker — a fatal answer (`4xx`, checksum mismatch) proves the
    /// mirror is *reachable*, just unwilling, and tripping on it would
    /// mask a real error behind "mirror unhealthy".
    pub fn record_failure(&self, class: FailureClass) {
        if class.retryable() {
            self.consecutive_failures.fetch_add(1, Ordering::Relaxed);
            self.bypasses.store(0, Ordering::Relaxed);
        }
    }

    /// Note that selection bypassed this (open) mirror; enough of
    /// these earn a half-open probe.
    pub fn note_bypass(&self) {
        self.bypasses.fetch_add(1, Ordering::Relaxed);
    }

    /// The latency EWMA in microseconds (0 until the first success).
    pub fn latency_micros(&self) -> u64 {
        self.ewma_micros.load(Ordering::Relaxed)
    }
}

struct Mirror {
    transport: Box<dyn RemoteTransport>,
    health: MirrorHealth,
}

/// N inner transports behind one [`RemoteTransport`] face: quorum
/// writes, health-ordered failover reads, cross-mirror resume, and an
/// anti-entropy [`ReplicatedRemote::repair`] pass. See the module
/// docs for the full semantics.
pub struct ReplicatedRemote {
    mirrors: Vec<Mirror>,
    quorum: usize,
    policy: RetryPolicy,
}

impl ReplicatedRemote {
    /// Open every mirror of `set` (sharing `staging`, so partial
    /// downloads are resumable across mirrors) and read the write
    /// quorum from `theta.replica-quorum` in `<staging>/config` when
    /// present (at repository call sites `staging` *is* the repo's
    /// `.theta` dir). Default quorum: all mirrors.
    pub fn open(set: &[RemoteSpec], staging: Option<&Path>) -> Result<ReplicatedRemote> {
        let mut transports = Vec::with_capacity(set.len());
        for spec in set {
            if matches!(spec, RemoteSpec::Replica(_)) {
                bail!("replica sets do not nest");
            }
            transports.push(open_transport(spec, staging)?);
        }
        let quorum = staging.and_then(configured_quorum);
        Ok(ReplicatedRemote::new(transports, quorum))
    }

    /// Wrap `transports` with an explicit write quorum (`None` = all
    /// mirrors; clamped to `1..=N`).
    pub fn new(
        transports: Vec<Box<dyn RemoteTransport>>,
        quorum: Option<usize>,
    ) -> ReplicatedRemote {
        let n = transports.len().max(1);
        ReplicatedRemote {
            mirrors: transports
                .into_iter()
                .map(|transport| Mirror {
                    transport,
                    health: MirrorHealth::default(),
                })
                .collect(),
            quorum: quorum.unwrap_or(n).clamp(1, n),
            policy: RetryPolicy::default(),
        }
    }

    /// Number of mirrors in the set.
    pub fn mirror_count(&self) -> usize {
        self.mirrors.len()
    }

    /// The effective write quorum.
    pub fn quorum(&self) -> usize {
        self.quorum
    }

    /// Each mirror's current breaker position (for `replicate status`).
    pub fn health_states(&self) -> Vec<HealthState> {
        self.mirrors.iter().map(|m| m.health.state()).collect()
    }

    fn single(&self) -> Option<&dyn RemoteTransport> {
        if self.mirrors.len() == 1 {
            Some(self.mirrors[0].transport.as_ref())
        } else {
            None
        }
    }

    /// Mirror indices in serving order: closed breakers first, then
    /// half-open probes, open ones last (still tried — a fully tripped
    /// set must degrade to "try everything", not to certain failure);
    /// ties break on latency EWMA then index. Bypassed open mirrors
    /// tick toward their probe.
    fn fetch_order(&self) -> Vec<usize> {
        let mut order: Vec<usize> = (0..self.mirrors.len()).collect();
        let rank = |s: HealthState| match s {
            HealthState::Closed => 0u8,
            HealthState::HalfOpen => 1,
            HealthState::Open => 2,
        };
        order.sort_by_key(|&i| {
            let h = &self.mirrors[i].health;
            (rank(h.state()), h.latency_micros(), i)
        });
        for &i in order.iter().skip(1) {
            if self.mirrors[i].health.state() == HealthState::Open {
                self.mirrors[i].health.note_bypass();
            }
        }
        order
    }

    /// Run `op` against mirrors in health order, failing over on
    /// retryable errors under one shared [`RetryBudget`]. Each switch
    /// to another mirror counts one `mirror_failovers`; a fatal
    /// classification surfaces immediately (no mirror will answer a
    /// checksum mismatch differently).
    fn fail_over<T>(
        &self,
        what: &str,
        op: impl Fn(&dyn RemoteTransport) -> Result<T>,
    ) -> Result<T> {
        let order = self.fetch_order();
        let n = order.len();
        let budget = RetryBudget::for_mirrors(n, &self.policy);
        let mut last: Option<anyhow::Error> = None;
        let mut tries = 0u32;
        while budget.spend() {
            let mirror = &self.mirrors[order[tries as usize % n]];
            let t0 = Instant::now();
            match op(mirror.transport.as_ref()) {
                Ok(v) => {
                    mirror
                        .health
                        .record_success(t0.elapsed().as_micros() as u64);
                    return Ok(v);
                }
                Err(e) => {
                    let class = classify(&e);
                    mirror.health.record_failure(class);
                    if class == FailureClass::Fatal {
                        return Err(e);
                    }
                    let retry_after = retry_after_of(&e);
                    last = Some(e);
                    batch::record(|s| s.mirror_failovers += 1);
                    tries += 1;
                    // Moving to a *different* mirror needs no pause —
                    // its channel is independent. Only wrapping back to
                    // an already-tried mirror backs off.
                    if tries as usize % n == 0 && budget.remaining() > 0 {
                        std::thread::sleep(self.policy.pause(tries / n as u32 - 1, retry_after));
                    }
                }
            }
        }
        Err(last
            .unwrap_or_else(|| anyhow!("replica set has no mirrors"))
            .context(format!("{what}: every mirror of the replica set failed")))
    }

    /// Fan a write out to every mirror in parallel and demand the
    /// quorum. On success with stragglers, counts one
    /// `quorum_shortfalls`; sub-quorum outcomes error — retryable iff
    /// successes plus retryable failures could still reach quorum.
    fn quorum_push(
        &self,
        what: &str,
        op: impl Fn(&dyn RemoteTransport) -> Result<(PackStats, WireReport)> + Sync,
    ) -> Result<(PackStats, WireReport)> {
        if let Some(t) = self.single() {
            return op(t);
        }
        let budget = RetryBudget::for_mirrors(self.mirrors.len(), &self.policy);
        let indices: Vec<usize> = (0..self.mirrors.len()).collect();
        // Pack sends record nothing on thread-local transfer stats, so
        // fanning them across threads loses no counters; every stat
        // below is recorded back on the calling thread.
        let results: Vec<Result<(PackStats, WireReport)>> = crate::util::par::par_map(
            &indices,
            self.mirrors.len(),
            |_, &i| -> Result<(PackStats, WireReport)> {
                if !budget.spend() {
                    bail!("retry budget exhausted before mirror {i} was attempted");
                }
                let mirror = &self.mirrors[i];
                let t0 = Instant::now();
                let r = op(mirror.transport.as_ref());
                match &r {
                    Ok(_) => mirror
                        .health
                        .record_success(t0.elapsed().as_micros() as u64),
                    Err(e) => mirror.health.record_failure(classify(e)),
                }
                r
            },
        );
        self.settle_quorum(what, results)
    }

    fn settle_quorum(
        &self,
        what: &str,
        results: Vec<Result<(PackStats, WireReport)>>,
    ) -> Result<(PackStats, WireReport)> {
        let mut first_ok: Option<PackStats> = None;
        let mut wire = WireReport::default();
        let mut successes = 0usize;
        let mut retryable = 0usize;
        let mut failures: Vec<String> = Vec::new();
        for (i, r) in results.into_iter().enumerate() {
            match r {
                Ok((stats, report)) => {
                    successes += 1;
                    wire.wire_bytes += report.wire_bytes;
                    wire.resumed_bytes += report.resumed_bytes;
                    first_ok.get_or_insert(stats);
                }
                Err(e) => {
                    let class = classify(&e);
                    if class.retryable() {
                        retryable += 1;
                    }
                    failures.push(format!("mirror {i} ({class:?}): {e:#}"));
                }
            }
        }
        if successes >= self.quorum {
            if !failures.is_empty() {
                batch::record(|s| s.quorum_shortfalls += 1);
                eprintln!(
                    "warning: {what} met quorum {}/{} but left mirrors behind \
                     (run `git-theta replicate --repair`): {}",
                    successes,
                    self.mirrors.len(),
                    failures.join("; ")
                );
            }
            return Ok((first_ok.expect("quorum >= 1 implies a success"), wire));
        }
        let msg = format!(
            "{what}: write quorum not met ({successes}/{} mirrors succeeded, quorum {}): {}",
            self.mirrors.len(),
            self.quorum,
            failures.join("; ")
        );
        if successes + retryable >= self.quorum {
            // Enough of the failures were transient that a retry can
            // still reach quorum: surface as a retryable cut.
            Err(anyhow::Error::new(super::retry::WireError::cut(msg)))
        } else {
            Err(anyhow!(msg))
        }
    }

    /// One anti-entropy pass: converge every mirror's store onto the
    /// union of all mirrors' objects. See the module docs for the
    /// protocol; `threads` bounds pack streaming parallelism.
    ///
    /// Idempotent — a converged set reports zero shipped objects.
    pub fn repair(&self, threads: usize) -> Result<RepairReport> {
        let mut report = RepairReport {
            mirrors: self.mirrors.len(),
            ..RepairReport::default()
        };
        // 1. Inventories. A mirror that cannot enumerate cannot be
        //    diffed against the union; refusing beats guessing.
        let mut inventories: Vec<BTreeSet<Oid>> = Vec::with_capacity(self.mirrors.len());
        for (i, mirror) in self.mirrors.iter().enumerate() {
            let oids = mirror
                .transport
                .list_oids()
                .with_context(|| format!("listing mirror {i} ({})", mirror.transport.describe()))?
                .with_context(|| {
                    format!(
                        "mirror {i} ({}) cannot enumerate its store; \
                         anti-entropy repair needs an inventory-capable remote",
                        mirror.transport.describe()
                    )
                })?;
            inventories.push(oids.into_iter().collect());
        }
        let union: Vec<Oid> = inventories
            .iter()
            .flat_map(|inv| inv.iter().copied())
            .collect::<BTreeSet<Oid>>()
            .into_iter()
            .collect();
        report.union_objects = union.len() as u64;
        if union.is_empty() {
            return Ok(report);
        }

        // 2. Have/want negotiation per mirror over the union — the
        //    existing batch protocol decides what each mirror lacks
        //    (the inventory alone could be stale by now).
        let mut missing_per: Vec<Vec<Oid>> = Vec::with_capacity(self.mirrors.len());
        for mirror in &self.mirrors {
            missing_per.push(mirror.transport.batch(&union)?.missing);
        }
        if missing_per.iter().all(|m| m.is_empty()) {
            return Ok(report);
        }

        // 3. Stage every missing-anywhere object into a local buffer
        //    store, fetching each from the first mirror that holds it.
        let spill = crate::util::tmp::TempDir::new("replica-repair")?;
        let buffer = LfsStore::at(&spill.join("objects"));
        let all_missing: BTreeSet<Oid> = missing_per.iter().flatten().copied().collect();
        let mut by_donor: BTreeMap<usize, Vec<Oid>> = BTreeMap::new();
        for oid in &all_missing {
            let donor = inventories
                .iter()
                .position(|inv| inv.contains(oid))
                .with_context(|| format!("object {} held by no mirror", oid.short()))?;
            by_donor.entry(donor).or_default().push(*oid);
        }
        for (donor, oids) in &by_donor {
            self.mirrors[*donor]
                .transport
                .fetch_pack_into(oids, &buffer, threads)
                .with_context(|| format!("staging repair objects from mirror {donor}"))?;
        }

        // 4. Ship each laggard exactly its missing set.
        for (i, missing) in missing_per.iter().enumerate() {
            if missing.is_empty() {
                continue;
            }
            let (stats, wire) = self.mirrors[i]
                .transport
                .send_pack_from(&buffer, missing, threads)
                .with_context(|| format!("repairing mirror {i}"))?;
            report.laggards_healed += 1;
            report.objects_shipped += missing.len() as u64;
            report.raw_bytes_shipped += stats.raw_bytes;
            report.wire_bytes_shipped += wire.wire_bytes;
        }
        Ok(report)
    }
}

/// What one [`ReplicatedRemote::repair`] pass moved.
#[derive(Debug, Clone, Copy, Default, PartialEq, Eq)]
pub struct RepairReport {
    /// Mirrors in the set.
    pub mirrors: usize,
    /// Distinct objects across all mirrors after the union.
    pub union_objects: u64,
    /// Mirrors that were missing at least one object and got healed.
    pub laggards_healed: usize,
    /// Object copies delivered to laggards (one object shipped to two
    /// mirrors counts twice).
    pub objects_shipped: u64,
    /// Raw payload bytes of the shipped copies.
    pub raw_bytes_shipped: u64,
    /// Pack bytes that crossed the wire to laggards.
    pub wire_bytes_shipped: u64,
}

/// Read `theta.replica-quorum` from `<staging>/config`; unreadable or
/// non-positive values mean "unset" (= all mirrors), never a weaker
/// quorum than the user configured.
fn configured_quorum(staging: &Path) -> Option<usize> {
    let text = std::fs::read_to_string(staging.join("config")).ok()?;
    let json = crate::util::json::Json::parse(&text).ok()?;
    json.get("theta.replica-quorum")
        .and_then(|v| v.as_str())
        .and_then(|s| s.trim().parse::<usize>().ok())
        .filter(|q| *q > 0)
}

impl RemoteTransport for ReplicatedRemote {
    fn describe(&self) -> String {
        let names: Vec<String> = self
            .mirrors
            .iter()
            .map(|m| m.transport.describe())
            .collect();
        format!(
            "replica[{}; quorum {}/{}]",
            names.join(","),
            self.quorum,
            self.mirrors.len()
        )
    }

    fn batch(&self, want: &[Oid]) -> Result<BatchResponse> {
        if let Some(t) = self.single() {
            return t.batch(want);
        }
        // Merge per-mirror answers: present on any reachable mirror =
        // present (fetches fail over to the holder); missing only when
        // no mirror holds it. Sizes come from the first holder. Dead
        // mirrors are skipped, but at least one must answer — an
        // all-dead set has nothing truthful to report.
        let mut held: BTreeMap<Oid, u64> = BTreeMap::new();
        let mut answered = false;
        let mut last: Option<anyhow::Error> = None;
        for mirror in &self.mirrors {
            match mirror.transport.batch(want) {
                Ok(resp) => {
                    answered = true;
                    for (i, oid) in resp.present.iter().enumerate() {
                        let size = resp.present_sizes.get(i).copied().unwrap_or(0);
                        held.entry(*oid).or_insert(size);
                    }
                }
                Err(e) => {
                    mirror.health.record_failure(classify(&e));
                    last = Some(e);
                }
            }
        }
        if !answered {
            return Err(last
                .unwrap_or_else(|| anyhow!("replica set has no mirrors"))
                .context("negotiation failed on every mirror of the replica set"));
        }
        let mut resp = BatchResponse::default();
        for oid in want {
            match held.get(oid) {
                Some(size) => {
                    resp.present.push(*oid);
                    resp.present_sizes.push(*size);
                }
                None => resp.missing.push(*oid),
            }
        }
        Ok(resp)
    }

    fn negotiate_chains(&self, adv: &ChainAdvert) -> Result<ChainNegotiation> {
        if let Some(t) = self.single() {
            return t.negotiate_chains(adv);
        }
        // Chain-aware only when *every* mirror answers chain-aware:
        // depths merge to the element-wise minimum so a planned delta
        // resolves on every receiver, and one unreachable (or
        // pre-chains) mirror degrades the whole round to flat packs —
        // it could not resolve a delta pack it never negotiated.
        let mut merged: Option<ChainNegotiation> = None;
        for mirror in &self.mirrors {
            let neg = match mirror.transport.negotiate_chains(adv) {
                Ok(n) => n,
                Err(e) => {
                    mirror.health.record_failure(classify(&e));
                    return Ok(ChainNegotiation {
                        batch: self.batch(&adv.want)?,
                        have_depths: vec![0; adv.chains.len()],
                        chain_aware: false,
                    });
                }
            };
            merged = Some(match merged.take() {
                None => neg,
                Some(mut acc) => {
                    acc.chain_aware &= neg.chain_aware;
                    for (a, b) in acc.have_depths.iter_mut().zip(&neg.have_depths) {
                        *a = (*a).min(*b);
                    }
                    acc
                }
            });
        }
        let mut merged = merged.expect("non-empty replica set");
        // The flat split must still follow the any-present merge rule,
        // not the last mirror's view.
        merged.batch = self.batch(&adv.want)?;
        Ok(merged)
    }

    fn fetch_pack_into(
        &self,
        oids: &[Oid],
        dest: &LfsStore,
        threads: usize,
    ) -> Result<(PackStats, WireReport)> {
        if let Some(t) = self.single() {
            return t.fetch_pack_into(oids, dest, threads);
        }
        self.fail_over("fetch", |t| t.fetch_pack_into(oids, dest, threads))
    }

    fn fetch_pack_with_chains(
        &self,
        adv: &ChainAdvert,
        dest: &LfsStore,
        threads: usize,
    ) -> Result<(PackStats, WireReport)> {
        if let Some(t) = self.single() {
            return t.fetch_pack_with_chains(adv, dest, threads);
        }
        self.fail_over("fetch", |t| t.fetch_pack_with_chains(adv, dest, threads))
    }

    fn send_pack_from(
        &self,
        src: &LfsStore,
        oids: &[Oid],
        threads: usize,
    ) -> Result<(PackStats, WireReport)> {
        self.quorum_push("push", |t| t.send_pack_from(src, oids, threads))
    }

    fn send_pack_with_bases(
        &self,
        src: &LfsStore,
        plan: &DeltaPlan,
        threads: usize,
    ) -> Result<(PackStats, WireReport)> {
        self.quorum_push("push", |t| t.send_pack_with_bases(src, plan, threads))
    }

    fn get_object(&self, oid: &Oid) -> Result<Vec<u8>> {
        if let Some(t) = self.single() {
            return t.get_object(oid);
        }
        self.fail_over("object fetch", |t| t.get_object(oid))
    }

    fn put_object(&self, bytes: &[u8]) -> Result<()> {
        if let Some(t) = self.single() {
            return t.put_object(bytes);
        }
        // Same quorum discipline as packs, minus the wire accounting.
        let results: Vec<Result<(PackStats, WireReport)>> = self
            .mirrors
            .iter()
            .map(|m| {
                m.transport
                    .put_object(bytes)
                    .map(|()| (PackStats::default(), WireReport::default()))
            })
            .collect();
        self.settle_quorum("object push", results).map(|_| ())
    }

    fn list_oids(&self) -> Result<Option<Vec<Oid>>> {
        // The set's inventory is the union of its mirrors'; if any
        // mirror cannot enumerate, neither can the set.
        let mut union: BTreeSet<Oid> = BTreeSet::new();
        for mirror in &self.mirrors {
            match mirror.transport.list_oids()? {
                Some(oids) => union.extend(oids),
                None => return Ok(None),
            }
        }
        Ok(Some(union.into_iter().collect()))
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::lfs::remote::DirRemote;
    use crate::util::tmp::TempDir;

    fn seeded_remote(td: &TempDir, name: &str, payloads: &[&[u8]]) -> (Box<DirRemote>, Vec<Oid>) {
        let remote = DirRemote::open(&td.join(name));
        let oids = payloads
            .iter()
            .map(|p| remote.store().put(p).unwrap().0)
            .collect();
        (Box::new(remote), oids)
    }

    #[test]
    fn health_breaker_opens_probes_and_closes() {
        let h = MirrorHealth::default();
        assert_eq!(h.state(), HealthState::Closed);
        for _ in 0..OPEN_AFTER {
            h.record_failure(FailureClass::Cut);
        }
        assert_eq!(h.state(), HealthState::Open);
        // Fatal answers never feed the breaker.
        let h2 = MirrorHealth::default();
        for _ in 0..10 {
            h2.record_failure(FailureClass::Fatal);
        }
        assert_eq!(h2.state(), HealthState::Closed);
        // Enough bypasses earn a half-open probe…
        for _ in 0..PROBE_AFTER {
            h.note_bypass();
        }
        assert_eq!(h.state(), HealthState::HalfOpen);
        // …a failed probe re-opens, a success closes.
        h.record_failure(FailureClass::Timeout);
        assert_eq!(h.state(), HealthState::Open);
        h.record_success(100);
        assert_eq!(h.state(), HealthState::Closed);
        assert_eq!(h.latency_micros(), 100);
    }

    #[test]
    fn batch_merges_any_present_and_quorum_push_fans_out() {
        crate::init();
        let td = TempDir::new("replica").unwrap();
        let (a, oids_a) = seeded_remote(&td, "a", &[b"alpha", b"shared"]);
        let (b, oids_b) = seeded_remote(&td, "b", &[b"beta", b"shared"]);
        let replica = ReplicatedRemote::new(vec![a, b], None);

        let ghost = Oid::of_bytes(b"nowhere");
        let want = vec![oids_a[0], oids_b[0], oids_a[1], ghost];
        let resp = replica.batch(&want).unwrap();
        // alpha (only on a), beta (only on b), shared: all present;
        // only the ghost is missing from the whole set.
        assert_eq!(resp.present, vec![oids_a[0], oids_b[0], oids_a[1]]);
        assert_eq!(resp.missing, vec![ghost]);

        // A push fans out to both mirrors.
        let local_td = TempDir::new("replica-local").unwrap();
        let local = LfsStore::at(&local_td.join("objects"));
        let (oid, _) = local.put(b"fresh payload").unwrap();
        replica.send_pack_from(&local, &[oid], 2).unwrap();
        let a_store = LfsStore::at(&td.join("a").join("lfs/objects"));
        let b_store = LfsStore::at(&td.join("b").join("lfs/objects"));
        assert!(a_store.contains(&oid) && b_store.contains(&oid));
    }

    #[test]
    fn repair_converges_divergent_mirrors_and_is_idempotent() {
        crate::init();
        let td = TempDir::new("replica-repair").unwrap();
        let (a, _) = seeded_remote(&td, "a", &[b"only-on-a", b"both"]);
        let (b, _) = seeded_remote(&td, "b", &[b"only-on-b", b"both"]);
        let replica = ReplicatedRemote::new(vec![a, b], None);

        let report = replica.repair(2).unwrap();
        assert_eq!(report.union_objects, 3);
        assert_eq!(report.laggards_healed, 2);
        assert_eq!(report.objects_shipped, 2);

        let a_store = LfsStore::at(&td.join("a").join("lfs/objects"));
        let b_store = LfsStore::at(&td.join("b").join("lfs/objects"));
        let mut a_list = a_store.list().unwrap();
        let mut b_list = b_store.list().unwrap();
        a_list.sort();
        b_list.sort();
        assert_eq!(a_list, b_list, "repair must converge the stores");
        for oid in &a_list {
            assert_eq!(a_store.get(oid).unwrap(), b_store.get(oid).unwrap());
        }

        // Second pass: nothing left to ship.
        let again = replica.repair(2).unwrap();
        assert_eq!(again.objects_shipped, 0);
        assert_eq!(again.laggards_healed, 0);
    }

    #[test]
    fn sub_quorum_push_is_retryable_only_if_quorum_reachable() {
        crate::init();
        // A fatal per-mirror failure (object missing from the local
        // store) against quorum=all must not surface as retryable.
        let td = TempDir::new("replica-q").unwrap();
        let (a, _) = seeded_remote(&td, "a", &[]);
        let (b, _) = seeded_remote(&td, "b", &[]);
        let replica = ReplicatedRemote::new(vec![a, b], None);
        let local_td = TempDir::new("replica-q-local").unwrap();
        let local = LfsStore::at(&local_td.join("objects"));
        let ghost = Oid::of_bytes(b"never stored");
        let err = replica.send_pack_from(&local, &[ghost], 1).unwrap_err();
        assert_eq!(classify(&err), FailureClass::Fatal);
    }
}
