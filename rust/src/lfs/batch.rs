//! Have/want negotiation and the packed transfer orchestrator.
//!
//! The paper's communication-efficiency story (§3.2, §4) is about *what*
//! moves: only changed parameter-group objects. This module is about
//! *how* they move: instead of one negotiation and one copy per object,
//! a client announces its full want/have set in one
//! [`RemoteTransport::batch`] call, the sender assembles every missing
//! object into a single [`pack`](super::pack), and the receiver fans
//! the pack back into its store — one round trip and one transfer for
//! N objects, over whatever channel the
//! [`transport`](super::transport) implements (directory or HTTP with
//! byte-range resume).
//!
//! [`Prefetcher`] is the orchestrator: it drops already-present oids,
//! negotiates once, then pipelines pack assembly → transfer → store
//! fan-in on [`par`] workers. Very large want-sets are sharded into
//! several packs processed concurrently (bounded memory, overlapping
//! compression with fan-in).
//!
//! Chain-aware transfers extend the single negotiation with chain
//! advertisements derived from group metadata, in **both directions**:
//! on push ([`Prefetcher::push_with_chains`]) the remote answers how
//! deep a prefix of each chain it already holds and the planner ships
//! suffix objects as content-defined deltas against those proven bases
//! (or against a shared base travelling in the same pack); on fetch
//! ([`Prefetcher::fetch_with_chains`]) the client advertises the
//! chains it holds prefixes of and the *responder* plans the deltas,
//! shipping the wanted suffix against bases the advert proves the
//! client can resolve. Every fallback — no chains,
//! `THETA_NEGOTIATE=flat`, a chain-oblivious peer on either side —
//! degrades to wire traffic byte-identical to the flat protocol.
//!
//! Every operation updates **thread-local** [`TransferStats`] counters,
//! so tests and benchmarks can assert on round trips and wire bytes
//! without interference from concurrently running tests.

use super::pack;
use super::retry::RetryPolicy;
use super::store::LfsStore;
use super::transport::{ChainAdvert, ChainNegotiation, RemoteTransport, WireReport};
use crate::gitcore::object::Oid;
use crate::util::par;
use anyhow::Result;
use std::cell::Cell;
use std::collections::{HashMap, HashSet};
use std::sync::atomic::{AtomicU8, Ordering};

/// Result of one have/want negotiation against a remote.
#[derive(Debug, Clone, Default, PartialEq, Eq)]
pub struct BatchResponse {
    /// Wanted oids the remote holds.
    pub present: Vec<Oid>,
    /// Raw byte size of each present oid (aligned with `present`; 0
    /// when unknown). The fetch planner shards packs on these without
    /// touching the remote again.
    pub present_sizes: Vec<u64>,
    /// Wanted oids the remote does not hold.
    pub missing: Vec<Oid>,
}

/// What one packed transfer actually moved.
#[derive(Debug, Clone, Copy, Default, PartialEq, Eq)]
pub struct TransferSummary {
    /// Objects that crossed the wire.
    pub objects: usize,
    /// Uncompressed payload bytes of those objects.
    pub raw_bytes: u64,
    /// Pack bytes of the packs moved (full pack size).
    pub packed_bytes: u64,
    /// Pack bytes that actually crossed the wire in this call. Equal to
    /// `packed_bytes` unless a byte-range resume skipped a prefix.
    pub wire_bytes: u64,
    /// Pack bytes *not* re-sent because an interrupted transfer was
    /// resumed from its persisted partial.
    pub resumed_bytes: u64,
    /// Wanted objects the sender could not provide.
    pub unavailable: usize,
}

/// Point-in-time snapshot of the calling thread's transfer counters.
#[derive(Debug, Clone, Copy, Default, PartialEq, Eq)]
pub struct TransferStats {
    /// Have/want negotiations performed.
    pub negotiations: u64,
    /// Packs assembled and applied.
    pub packs: u64,
    /// Objects moved in either direction.
    pub objects: u64,
    /// Objects moved by individual request (legacy per-object engine).
    pub object_transfers: u64,
    /// Uncompressed bytes moved.
    pub raw_bytes: u64,
    /// Wire bytes moved (pack size; per-object transfers count raw size).
    pub packed_bytes: u64,
    /// Bytes that actually crossed the wire (≤ `packed_bytes` when a
    /// resume skipped a persisted prefix).
    pub wire_bytes: u64,
    /// Bytes saved by byte-range resume of interrupted transfers.
    pub resumed_bytes: u64,
    /// Objects that crossed the wire as delta records (chain-aware
    /// pushes) instead of whole payloads.
    pub delta_objects: u64,
    /// Transfer attempts repeated after a retryable failure
    /// ([`RetryPolicy::run`](super::retry::RetryPolicy::run) pauses).
    pub backoff_retries: u64,
    /// Retries caused specifically by a server shed (`503 +
    /// Retry-After`) — a subset of `backoff_retries`.
    pub sheds: u64,
    /// Fetches that abandoned a dying mirror and completed against the
    /// next one in a [replica set](super::replicate::ReplicatedRemote).
    pub mirror_failovers: u64,
    /// Replicated pushes that met their write quorum but left at least
    /// one mirror behind (healed later by `replicate --repair`).
    pub quorum_shortfalls: u64,
}

impl TransferStats {
    /// Total round trips: each negotiation, each pack, and each
    /// individually requested object is one wire exchange.
    pub fn round_trips(&self) -> u64 {
        self.negotiations + self.packs + self.object_transfers
    }
}

thread_local! {
    static STATS: Cell<TransferStats> = Cell::new(TransferStats::default());
}

/// Snapshot the calling thread's transfer counters.
pub fn stats() -> TransferStats {
    STATS.with(|s| s.get())
}

/// Zero the calling thread's transfer counters (tests and benchmarks).
pub fn reset_stats() {
    STATS.with(|s| s.set(TransferStats::default()))
}

/// Apply an update to the calling thread's counters.
pub(crate) fn record(f: impl FnOnce(&mut TransferStats)) {
    STATS.with(|s| {
        let mut v = s.get();
        f(&mut v);
        s.set(v);
    })
}

/// Process-wide engine override set by CLI flags: 0 = defer to the
/// environment, 1 = packed, 2 = per-object. An atomic (not an env
/// write) because concurrent `setenv`/`getenv` is undefined behavior.
static MODE_OVERRIDE: AtomicU8 = AtomicU8::new(0);

/// Force the transfer engine for this process: `Some(true)` = legacy
/// per-object, `Some(false)` = packed, `None` = defer to the
/// `THETA_TRANSFER` environment variable.
pub fn set_per_object_mode(mode: Option<bool>) {
    let v = match mode {
        None => 0,
        Some(false) => 1,
        Some(true) => 2,
    };
    MODE_OVERRIDE.store(v, Ordering::Relaxed);
}

/// Whether the legacy per-object engine is selected — by
/// [`set_per_object_mode`], else `THETA_TRANSFER=object` (the default
/// is packed transfer).
pub fn per_object_mode() -> bool {
    match MODE_OVERRIDE.load(Ordering::Relaxed) {
        1 => false,
        2 => true,
        _ => matches!(
            std::env::var("THETA_TRANSFER").as_deref(),
            Ok("object") | Ok("per-object")
        ),
    }
}

/// Process-wide negotiation override, same shape as [`set_per_object_mode`]:
/// 0 = defer to the environment, 1 = chain-aware, 2 = flat.
static NEGOTIATE_OVERRIDE: AtomicU8 = AtomicU8::new(0);

/// Force the negotiation protocol for this process: `Some(true)` =
/// flat (chain advertisements are ignored and pushes take the plain
/// packed path), `Some(false)` = chain-aware, `None` = defer to the
/// `THETA_NEGOTIATE` environment variable.
pub fn set_flat_negotiation(mode: Option<bool>) {
    let v = match mode {
        None => 0,
        Some(false) => 1,
        Some(true) => 2,
    };
    NEGOTIATE_OVERRIDE.store(v, Ordering::Relaxed);
}

/// Whether chain advertisements should be ignored — by
/// [`set_flat_negotiation`], else `THETA_NEGOTIATE=flat` (the default
/// is chain-aware negotiation whenever chains are advertised).
pub fn flat_negotiation() -> bool {
    match NEGOTIATE_OVERRIDE.load(Ordering::Relaxed) {
        1 => false,
        2 => true,
        _ => matches!(std::env::var("THETA_NEGOTIATE").as_deref(), Ok("flat")),
    }
}

/// Concurrent prefetcher: one negotiation, then pack assembly →
/// transfer → store fan-in, parallelized on [`par`] workers.
#[derive(Debug, Clone)]
pub struct Prefetcher {
    /// Maximum objects per pack. Want-sets larger than this are sharded
    /// into several packs processed concurrently.
    pub max_pack_objects: usize,
    /// Maximum cumulative *raw* payload bytes per pack. With the
    /// streaming pipeline a pack is never RAM-resident (it spills to a
    /// file and moves in bounded chunks), so this now bounds *disk*
    /// staging per shard and keeps shards small enough to overlap
    /// transfer with compression/fan-in across workers.
    pub max_pack_bytes: u64,
    /// Worker threads for compression and store fan-in.
    pub threads: usize,
    /// Retry policy wrapped around every wire exchange (negotiation
    /// and per-shard pack transfer). Defaults to
    /// [`RetryPolicy::none`]: backoff is an explicit opt-in, so a
    /// first failure stays visible to callers (and to the
    /// fault-injection suites) unless a caller asks for resilience.
    pub retry: RetryPolicy,
}

impl Default for Prefetcher {
    fn default() -> Prefetcher {
        Prefetcher {
            max_pack_objects: 4096,
            max_pack_bytes: 256 << 20,
            threads: par::default_threads(),
            retry: RetryPolicy::none(),
        }
    }
}

impl Prefetcher {
    /// Download `want` from `remote` into `local`.
    ///
    /// Drops oids already in `local`, negotiates the remainder in one
    /// [`RemoteTransport::batch`] call, and moves everything the remote
    /// holds as packs. Oids the remote lacks are reported as
    /// `unavailable` rather than failing the whole transfer — the
    /// caller decides whether an absent object is fatal.
    pub fn fetch(
        &self,
        remote: &dyn RemoteTransport,
        local: &LfsStore,
        want: &[Oid],
    ) -> Result<TransferSummary> {
        let mut need: Vec<Oid> = want.iter().filter(|o| !local.contains(o)).copied().collect();
        need.sort();
        need.dedup();
        if need.is_empty() {
            return Ok(TransferSummary::default());
        }
        let resp = self.retry.run(|| remote.batch(&need))?;
        let shards = self.shard_sized(&resp.present, &resp.present_sizes);
        let inner = if shards.len() > 1 { 1 } else { self.threads };
        let per_shard = par::try_par_map(
            &shards,
            self.threads.min(shards.len().max(1)),
            |_, shard| -> Result<(pack::PackStats, WireReport)> {
                // A retried shard rides byte-range resume: bytes the
                // local partial already holds are never re-fetched.
                self.retry.run(|| remote.fetch_pack_into(shard, local, inner))
            },
        )?;
        Ok(accumulate(resp.missing.len(), &per_shard))
    }

    /// Upload `oids` from `local` to `remote`.
    ///
    /// Negotiates once; only objects the remote is missing *and* the
    /// local store holds are packed and sent.
    pub fn push(
        &self,
        local: &LfsStore,
        remote: &dyn RemoteTransport,
        oids: &[Oid],
    ) -> Result<TransferSummary> {
        let mut want = oids.to_vec();
        want.sort();
        want.dedup();
        if want.is_empty() {
            return Ok(TransferSummary::default());
        }
        let resp = self.retry.run(|| remote.batch(&want))?;
        let held = local.contains_all(&resp.missing);
        let send: Vec<Oid> = resp
            .missing
            .iter()
            .zip(&held)
            .filter(|(_, h)| **h)
            .map(|(o, _)| *o)
            .collect();
        let unavailable = resp.missing.len() - send.len();
        let shards = self.shard(local, &send);
        let inner = if shards.len() > 1 { 1 } else { self.threads };
        let per_shard = par::try_par_map(
            &shards,
            self.threads.min(shards.len().max(1)),
            |_, shard| -> Result<(pack::PackStats, WireReport)> {
                // A retried upload HEAD-probes the server's partial
                // and sends only the tail the server lacks.
                self.retry.run(|| remote.send_pack_from(local, shard, inner))
            },
        )?;
        Ok(accumulate(unavailable, &per_shard))
    }

    /// Chain-aware upload: negotiate once with chain advertisements,
    /// then ship each shard as a delta-planned pack wherever the
    /// negotiation proved a usable base.
    ///
    /// Degrades gracefully at every step: empty chains or a forced
    /// flat negotiation take [`Prefetcher::push`] verbatim; a
    /// chain-oblivious remote (version skew) answers `chain_aware:
    /// false` and every object ships whole through the same shard
    /// loop; and any candidate that fails the delta planner's worth-it
    /// gate falls back to a full record. A push that plans no deltas
    /// produces wire traffic byte-identical to the flat protocol.
    pub fn push_with_chains(
        &self,
        local: &LfsStore,
        remote: &dyn RemoteTransport,
        adv: &ChainAdvert,
    ) -> Result<TransferSummary> {
        if adv.chains.is_empty() || flat_negotiation() {
            return self.push(local, remote, &adv.want);
        }
        let mut adv = adv.clone();
        adv.want.sort();
        adv.want.dedup();
        if adv.want.is_empty() {
            return Ok(TransferSummary::default());
        }
        let neg = self.retry.run(|| remote.negotiate_chains(&adv))?;
        let held = local.contains_all(&neg.batch.missing);
        let send: Vec<Oid> = neg
            .batch
            .missing
            .iter()
            .zip(&held)
            .filter(|(_, h)| **h)
            .map(|(o, _)| *o)
            .collect();
        let unavailable = neg.batch.missing.len() - send.len();
        let base_of = chain_bases(&adv, &neg, &send);
        let shards = self.shard(local, &send);
        let inner = if shards.len() > 1 { 1 } else { self.threads };
        let per_shard = par::try_par_map(
            &shards,
            self.threads.min(shards.len().max(1)),
            |_, shard| -> Result<((pack::PackStats, WireReport), u64)> {
                let plan = pack::plan_deltas(local, shard, &base_of, inner)?;
                let deltas = plan.deltas.len() as u64;
                let moved = if deltas == 0 {
                    self.retry.run(|| remote.send_pack_from(local, shard, inner))?
                } else {
                    self.retry.run(|| remote.send_pack_with_bases(local, &plan, inner))?
                };
                Ok((moved, deltas))
            },
        )?;
        let delta_objects: u64 = per_shard.iter().map(|&(_, d)| d).sum();
        record(|t| t.delta_objects += delta_objects);
        let moved: Vec<(pack::PackStats, WireReport)> =
            per_shard.into_iter().map(|(m, _)| m).collect();
        Ok(accumulate(unavailable, &moved))
    }

    /// Chain-aware download: negotiate once with chain advertisements,
    /// then fetch each shard through
    /// [`RemoteTransport::fetch_pack_with_chains`] so the responder can
    /// ship suffix objects as deltas against bases this client holds.
    ///
    /// The fallback ladder mirrors [`Prefetcher::push_with_chains`]:
    /// empty chains or a forced flat negotiation take
    /// [`Prefetcher::fetch`] verbatim; a chain-oblivious remote
    /// (version skew) answers `chain_aware: false` and every shard
    /// moves as a flat pack; and a responder that plans no worthwhile
    /// deltas ships a byte-identical version-1 pack. Like `fetch`, the
    /// want set is trimmed to locally missing oids first — which is
    /// also what lets the responder derive this client's held chain
    /// depths from the advert alone.
    pub fn fetch_with_chains(
        &self,
        remote: &dyn RemoteTransport,
        local: &LfsStore,
        adv: &ChainAdvert,
    ) -> Result<TransferSummary> {
        if adv.chains.is_empty() || flat_negotiation() {
            return self.fetch(remote, local, &adv.want);
        }
        let mut need: Vec<Oid> = adv
            .want
            .iter()
            .filter(|o| !local.contains(o))
            .copied()
            .collect();
        need.sort();
        need.dedup();
        if need.is_empty() {
            return Ok(TransferSummary::default());
        }
        let mut adv = adv.clone();
        adv.want = need;
        let neg = self.retry.run(|| remote.negotiate_chains(&adv))?;
        let shards = self.shard_sized(&neg.batch.present, &neg.batch.present_sizes);
        let inner = if shards.len() > 1 { 1 } else { self.threads };
        if !neg.chain_aware {
            let per_shard = par::try_par_map(
                &shards,
                self.threads.min(shards.len().max(1)),
                |_, shard| -> Result<(pack::PackStats, WireReport)> {
                    self.retry.run(|| remote.fetch_pack_into(shard, local, inner))
                },
            )?;
            return Ok(accumulate(neg.batch.missing.len(), &per_shard));
        }
        let per_shard = par::try_par_map(
            &shards,
            self.threads.min(shards.len().max(1)),
            |_, shard| -> Result<(pack::PackStats, WireReport)> {
                // Chains travel whole with every shard (they are cheap
                // annotations); only the want set is shard-scoped.
                let shard_adv = ChainAdvert {
                    chains: adv.chains.clone(),
                    want: shard.clone(),
                };
                // A retried shard re-addresses the same deterministic
                // pack and rides byte-range resume.
                self.retry
                    .run(|| remote.fetch_pack_with_chains(&shard_adv, local, inner))
            },
        )?;
        // The apply side counted every delta record it resolved; fold
        // that onto the thread's counters (the push path counts from
        // its plan instead — both land in the same field).
        let delta_objects: u64 = per_shard.iter().map(|(s, _)| s.delta_objects as u64).sum();
        record(|t| t.delta_objects += delta_objects);
        Ok(accumulate(neg.batch.missing.len(), &per_shard))
    }

    /// Greedily split `oids` into shards respecting both the object and
    /// the raw-byte cap, with sizes supplied per oid.
    fn shard_pairs(&self, oids: &[Oid], size_of: impl Fn(usize, &Oid) -> u64) -> Vec<Vec<Oid>> {
        let max_objects = self.max_pack_objects.max(1);
        let mut shards = Vec::new();
        let mut cur: Vec<Oid> = Vec::new();
        let mut cur_bytes = 0u64;
        for (i, &oid) in oids.iter().enumerate() {
            let size = size_of(i, &oid);
            if !cur.is_empty()
                && (cur.len() >= max_objects
                    || cur_bytes.saturating_add(size) > self.max_pack_bytes)
            {
                shards.push(std::mem::take(&mut cur));
                cur_bytes = 0;
            }
            cur.push(oid);
            cur_bytes += size;
        }
        if !cur.is_empty() {
            shards.push(cur);
        }
        shards
    }

    /// Shard with sizes probed from a local source store's metadata (an
    /// oid the source lacks counts as zero and fails later in
    /// `build_pack` with a precise error).
    fn shard(&self, src: &LfsStore, oids: &[Oid]) -> Vec<Vec<Oid>> {
        self.shard_pairs(oids, |_, oid| src.size_of(oid).unwrap_or(0))
    }

    /// Shard with sizes reported by the remote's negotiation response.
    fn shard_sized(&self, oids: &[Oid], sizes: &[u64]) -> Vec<Vec<Oid>> {
        self.shard_pairs(oids, |i, _| sizes.get(i).copied().unwrap_or(0))
    }
}

/// Pair each to-be-sent object with the delta base the chain
/// negotiation nominated. A chain the remote holds a prefix of pairs
/// its suffix objects against the deepest held entry's first oid
/// ([`pack::KIND_STORE`] — proven present remotely); a chain being
/// pushed whole pairs entries past the base against the chain's first
/// object travelling in the same push ([`pack::KIND_REF`]; the planner
/// demotes the pair to a full record if base and target land in
/// different shards). A chain-oblivious peer gets no pairings at all,
/// so version skew can never produce a pack the receiver cannot read.
pub(crate) fn chain_bases(
    adv: &ChainAdvert,
    neg: &ChainNegotiation,
    send: &[Oid],
) -> HashMap<Oid, (Oid, u8)> {
    let mut base_of: HashMap<Oid, (Oid, u8)> = HashMap::new();
    if !neg.chain_aware {
        return base_of;
    }
    let send_set: HashSet<Oid> = send.iter().copied().collect();
    for (chain, &depth) in adv.chains.iter().zip(&neg.have_depths) {
        if chain.is_empty() {
            continue;
        }
        if depth >= 1 {
            let Some(&base) = chain.get(depth - 1).and_then(|e| e.oids.first()) else {
                continue;
            };
            for entry in &chain[depth.min(chain.len())..] {
                for oid in &entry.oids {
                    if send_set.contains(oid) && *oid != base {
                        base_of.entry(*oid).or_insert((base, pack::KIND_STORE));
                    }
                }
            }
        } else {
            let Some(&base) = chain[0].oids.first() else {
                continue;
            };
            if !send_set.contains(&base) {
                continue;
            }
            for entry in &chain[1..] {
                for oid in &entry.oids {
                    if send_set.contains(oid) && *oid != base {
                        base_of.entry(*oid).or_insert((base, pack::KIND_REF));
                    }
                }
            }
        }
    }
    base_of
}

/// Fold per-shard pack stats + wire reports into one summary and record
/// it on the calling thread's counters.
fn accumulate(unavailable: usize, per_shard: &[(pack::PackStats, WireReport)]) -> TransferSummary {
    let mut total = TransferSummary {
        unavailable,
        ..Default::default()
    };
    for (s, w) in per_shard {
        total.objects += s.objects;
        total.raw_bytes += s.raw_bytes;
        total.packed_bytes += s.packed_bytes;
        total.wire_bytes += w.wire_bytes;
        total.resumed_bytes += w.resumed_bytes;
    }
    record(|t| {
        t.packs += per_shard.len() as u64;
        t.objects += total.objects as u64;
        t.raw_bytes += total.raw_bytes;
        t.packed_bytes += total.packed_bytes;
        t.wire_bytes += total.wire_bytes;
        t.resumed_bytes += total.resumed_bytes;
    });
    total
}

/// Fetch `want` into `local` with the default [`Prefetcher`].
pub fn fetch_pack(
    remote: &dyn RemoteTransport,
    local: &LfsStore,
    want: &[Oid],
) -> Result<TransferSummary> {
    Prefetcher::default().fetch(remote, local, want)
}

/// Fetch an advert's want set with the default [`Prefetcher`],
/// advertising the client's held chains so a chain-aware remote ships
/// missing suffixes as deltas against bases already in `local`.
pub fn fetch_pack_chains(
    remote: &dyn RemoteTransport,
    local: &LfsStore,
    adv: &ChainAdvert,
) -> Result<TransferSummary> {
    Prefetcher::default().fetch_with_chains(remote, local, adv)
}

/// Push `oids` to `remote` with the default [`Prefetcher`].
pub fn push_pack(
    local: &LfsStore,
    remote: &dyn RemoteTransport,
    oids: &[Oid],
) -> Result<TransferSummary> {
    Prefetcher::default().push(local, remote, oids)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::lfs::remote::LfsRemote;
    use crate::util::tmp::TempDir;

    fn seeded(td: &TempDir, n: usize) -> (LfsStore, Vec<Oid>) {
        let store = LfsStore::open(td.path());
        let oids = (0..n)
            .map(|i| store.put(format!("object-{i}").as_bytes()).unwrap().0)
            .collect();
        (store, oids)
    }

    #[test]
    fn fetch_is_one_negotiation_one_pack() {
        let td_r = TempDir::new("batch-remote").unwrap();
        let td_l = TempDir::new("batch-local").unwrap();
        let remote = LfsRemote::open(td_r.path());
        let oids: Vec<Oid> = (0..20)
            .map(|i| remote.store().put(format!("object-{i}").as_bytes()).unwrap().0)
            .collect();
        let local = LfsStore::open(td_l.path());

        reset_stats();
        let s = fetch_pack(&remote, &local, &oids).unwrap();
        assert_eq!(s.objects, 20);
        assert_eq!(s.unavailable, 0);
        assert_eq!(s.wire_bytes, s.packed_bytes);
        assert_eq!(s.resumed_bytes, 0);
        let t = stats();
        assert_eq!(t.negotiations, 1);
        assert_eq!(t.packs, 1);
        assert_eq!(t.objects, 20);

        // Second fetch: everything local, zero round trips.
        reset_stats();
        let s2 = fetch_pack(&remote, &local, &oids).unwrap();
        assert_eq!(s2.objects, 0);
        assert_eq!(stats(), TransferStats::default());
    }

    #[test]
    fn push_skips_objects_the_remote_has() {
        let td_l = TempDir::new("batch-l").unwrap();
        let td_r = TempDir::new("batch-r").unwrap();
        let (local, oids) = seeded(&td_l, 8);
        let remote = LfsRemote::open(td_r.path());

        reset_stats();
        let s1 = push_pack(&local, &remote, &oids).unwrap();
        assert_eq!(s1.objects, 8);
        let s2 = push_pack(&local, &remote, &oids).unwrap();
        assert_eq!(s2.objects, 0);
        // Two negotiations (one per push), but only one pack moved.
        let t = stats();
        assert_eq!(t.negotiations, 2);
        assert_eq!(t.packs, 1);
    }

    #[test]
    fn unavailable_objects_are_reported_not_fatal() {
        let td_l = TempDir::new("batch-l").unwrap();
        let td_r = TempDir::new("batch-r").unwrap();
        let (_, mut oids) = seeded(&td_l, 2);
        let remote = LfsRemote::open(td_r.path());
        let local = LfsStore::open(td_l.path());
        oids.push(Oid::of_bytes(b"nobody has this"));

        let s = fetch_pack(&remote, &local, &[oids[2]]).unwrap();
        assert_eq!((s.objects, s.unavailable), (0, 1));
        let s = push_pack(&local, &remote, &oids).unwrap();
        assert_eq!((s.objects, s.unavailable), (2, 1));
    }

    #[test]
    fn large_want_sets_shard_into_multiple_packs() {
        let td_l = TempDir::new("batch-shard-l").unwrap();
        let td_r = TempDir::new("batch-shard-r").unwrap();
        let (local, oids) = seeded(&td_l, 25);
        let remote = LfsRemote::open(td_r.path());

        reset_stats();
        let p = Prefetcher {
            max_pack_objects: 10,
            threads: 4,
            ..Prefetcher::default()
        };
        let s = p.push(&local, &remote, &oids).unwrap();
        assert_eq!(s.objects, 25);
        let t = stats();
        assert_eq!(t.negotiations, 1);
        assert_eq!(t.packs, 3); // 10 + 10 + 5
        for oid in &oids {
            assert!(remote.store().contains(oid));
        }
    }

    #[test]
    fn byte_cap_shards_large_payloads() {
        let td_l = TempDir::new("batch-bytes-l").unwrap();
        let td_r = TempDir::new("batch-bytes-r").unwrap();
        let local = LfsStore::open(td_l.path());
        let oids: Vec<Oid> = (0..6u8)
            .map(|i| local.put(&vec![i; 1000]).unwrap().0)
            .collect();
        let remote = LfsRemote::open(td_r.path());

        reset_stats();
        let p = Prefetcher {
            max_pack_bytes: 2500, // fits two 1000-byte objects per pack
            threads: 2,
            ..Prefetcher::default()
        };
        p.push(&local, &remote, &oids).unwrap();
        let t = stats();
        assert_eq!(t.negotiations, 1);
        assert_eq!(t.packs, 3);
        assert_eq!(t.objects, 6);
    }

    #[test]
    fn fetch_shards_on_negotiated_sizes() {
        // The download planner never probes the remote store directly:
        // shard decisions come from the negotiation's size report.
        let td_r = TempDir::new("batch-dlshard-r").unwrap();
        let td_l = TempDir::new("batch-dlshard-l").unwrap();
        let remote = LfsRemote::open(td_r.path());
        let oids: Vec<Oid> = (0..6u8)
            .map(|i| remote.store().put(&vec![i; 1000]).unwrap().0)
            .collect();
        let local = LfsStore::open(td_l.path());

        reset_stats();
        let p = Prefetcher {
            max_pack_bytes: 2500,
            threads: 2,
            ..Prefetcher::default()
        };
        let s = p.fetch(&remote, &local, &oids).unwrap();
        assert_eq!(s.objects, 6);
        assert_eq!(stats().packs, 3);
        for oid in &oids {
            assert_eq!(local.get(oid).unwrap(), remote.store().get(oid).unwrap());
        }
    }

    /// Incompressible base + fine-tune differing only in the tail
    /// quarter — the delta planner's ideal customer.
    fn near_pair(seed: u64, len: usize) -> (Vec<u8>, Vec<u8>) {
        let mut rng = crate::util::rng::Pcg64::new(seed);
        let base: Vec<u8> = (0..len).map(|_| rng.next_u64() as u8).collect();
        let mut tuned = base.clone();
        for b in &mut tuned[len - len / 4..] {
            *b = rng.next_u64() as u8;
        }
        (base, tuned)
    }

    #[test]
    fn chain_push_ships_deltas_against_remote_bases() {
        use crate::lfs::transport::ChainEntryAdvert;
        let td_l = TempDir::new("batch-chain-l").unwrap();
        let td_r = TempDir::new("batch-chain-r").unwrap();
        let local = LfsStore::open(td_l.path());
        let (base, tuned) = near_pair(31, 64 * 1024);
        let (base_oid, _) = local.put(&base).unwrap();
        let (tuned_oid, _) = local.put(&tuned).unwrap();
        let remote = LfsRemote::open(td_r.path());
        remote.store().put(&base).unwrap();

        let adv = ChainAdvert {
            chains: vec![vec![
                ChainEntryAdvert { key: base_oid, oids: vec![base_oid] },
                ChainEntryAdvert { key: tuned_oid, oids: vec![tuned_oid] },
            ]],
            want: vec![tuned_oid],
        };
        reset_stats();
        let s = Prefetcher::default()
            .push_with_chains(&local, &remote, &adv)
            .unwrap();
        assert_eq!((s.objects, s.unavailable), (1, 0));
        let t = stats();
        assert_eq!(t.negotiations, 1);
        assert_eq!(t.packs, 1);
        assert_eq!(t.delta_objects, 1);
        assert_eq!(remote.store().get(&tuned_oid).unwrap(), tuned);

        // Same object pushed flat to a second remote costs far more wire.
        let td_flat = TempDir::new("batch-chain-flat").unwrap();
        let flat = LfsRemote::open(td_flat.path());
        flat.store().put(&base).unwrap();
        reset_stats();
        let sf = push_pack(&local, &flat, &[tuned_oid]).unwrap();
        assert!(
            s.wire_bytes < sf.wire_bytes / 2,
            "delta push ({}) should undercut flat push ({})",
            s.wire_bytes,
            sf.wire_bytes
        );
        assert_eq!(stats().delta_objects, 0);
    }

    #[test]
    fn whole_chain_push_dedups_against_its_own_base() {
        use crate::lfs::transport::ChainEntryAdvert;
        let td_l = TempDir::new("batch-wchain-l").unwrap();
        let td_r = TempDir::new("batch-wchain-r").unwrap();
        let local = LfsStore::open(td_l.path());
        let (base, tuned) = near_pair(32, 64 * 1024);
        let (base_oid, _) = local.put(&base).unwrap();
        let (tuned_oid, _) = local.put(&tuned).unwrap();
        // The remote holds nothing: the whole chain ships, with the
        // suffix entry referencing the base record in the same pack.
        let remote = LfsRemote::open(td_r.path());
        let adv = ChainAdvert {
            chains: vec![vec![
                ChainEntryAdvert { key: base_oid, oids: vec![base_oid] },
                ChainEntryAdvert { key: tuned_oid, oids: vec![tuned_oid] },
            ]],
            want: vec![base_oid, tuned_oid],
        };
        reset_stats();
        let s = Prefetcher::default()
            .push_with_chains(&local, &remote, &adv)
            .unwrap();
        assert_eq!(s.objects, 2);
        assert_eq!(stats().delta_objects, 1);
        assert_eq!(remote.store().get(&base_oid).unwrap(), base);
        assert_eq!(remote.store().get(&tuned_oid).unwrap(), tuned);

        let td_flat = TempDir::new("batch-wchain-flat").unwrap();
        let flat = LfsRemote::open(td_flat.path());
        reset_stats();
        let sf = push_pack(&local, &flat, &[base_oid, tuned_oid]).unwrap();
        assert!(
            s.wire_bytes < sf.wire_bytes * 3 / 4,
            "in-pack dedup ({}) should undercut the flat push ({})",
            s.wire_bytes,
            sf.wire_bytes
        );
    }
}
