//! The LFS clean/smudge filter and hooks (paper §2.4).
//!
//! clean: working-tree bytes → store in `.theta/lfs/objects/` → pointer.
//! smudge: pointer → local store (or lazily from the configured remote).
//! pre-push hook: scan pushed commits for pointer files, sync those
//! objects to the remote's LFS store.

use super::pointer::Pointer;
use super::store::LfsStore;
use super::transport;
use crate::gitcore::drivers::{DriverRegistry, FilterDriver, Hooks};
use crate::gitcore::object::Oid;
use crate::gitcore::remote::RemoteSpec;
use crate::gitcore::repo::Repository;
use anyhow::{Context, Result};
use std::sync::Arc;

/// The `filter=lfs` driver.
pub struct LfsFilter;

impl FilterDriver for LfsFilter {
    fn clean(&self, repo: &Repository, _path: &str, working: &[u8]) -> Result<Vec<u8>> {
        let store = LfsStore::open(repo.theta_dir());
        let (oid, size) = store.put(working)?;
        Ok(Pointer::new(oid, size).to_text().into_bytes())
    }

    fn smudge(&self, repo: &Repository, path: &str, staged: &[u8]) -> Result<Vec<u8>> {
        let text = std::str::from_utf8(staged)
            .with_context(|| format!("lfs smudge: staged '{path}' is not a pointer"))?;
        let pointer = Pointer::parse(text)?;
        let store = LfsStore::open(repo.theta_dir());
        if !store.contains(&pointer.oid) {
            // Lazy download from the configured remote (paper: "the smudge
            // filter first retrieves the file from the LFS remote server").
            // The remote may be a directory or an http:// endpoint.
            if let Some(spec) = repo.config_get("remote")? {
                let remote =
                    transport::open_transport(&RemoteSpec::parse(&spec)?, Some(repo.theta_dir()))?;
                transport::download(remote.as_ref(), &store, &[pointer.oid])?;
            }
        }
        store.get(&pointer.oid)
    }
}

/// LFS repository hooks: pre-push object sync.
pub struct LfsHooks;

impl Hooks for LfsHooks {
    fn pre_push(&self, repo: &Repository, remote: &RemoteSpec, commits: &[Oid]) -> Result<()> {
        let store = LfsStore::open(repo.theta_dir());
        let mut oids = Vec::new();
        for commit_oid in commits {
            let commit = repo.odb().read_commit(commit_oid)?;
            let tree = repo.odb().read_tree(&commit.tree)?;
            for entry in &tree.entries {
                let blob = repo.odb().read_blob(&entry.oid)?;
                oids.extend(Pointer::oid_of_blob(&blob));
            }
        }
        oids.sort();
        oids.dedup();
        // Only sync oids we actually have locally (theta-managed pointers
        // inside metadata files are synced by theta's own hook).
        let have: Vec<Oid> = oids.into_iter().filter(|o| store.contains(o)).collect();
        let remote = transport::open_transport(remote, Some(repo.theta_dir()))?;
        transport::upload(&store, remote.as_ref(), &have)?;
        Ok(())
    }
}

/// Register the LFS filter and hooks under the name "lfs".
pub fn register_lfs() {
    DriverRegistry::register_filter("lfs", Arc::new(LfsFilter));
    DriverRegistry::register_hooks(Arc::new(LfsHooks));
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::gitcore::attributes::Attributes;
    use crate::util::tmp::TempDir;

    fn setup() -> (TempDir, Repository) {
        let td = TempDir::new("lfsfilter").unwrap();
        let repo = Repository::init(td.path()).unwrap();
        register_lfs();
        (td, repo)
    }

    #[test]
    fn clean_produces_pointer_smudge_restores() {
        let (_td, repo) = setup();
        let payload = vec![7u8; 50_000];
        let filter = LfsFilter;
        let pointer_bytes = filter.clean(&repo, "big.bin", &payload).unwrap();
        assert!(Pointer::is_pointer(&pointer_bytes));
        let restored = filter.smudge(&repo, "big.bin", &pointer_bytes).unwrap();
        assert_eq!(restored, payload);
    }

    #[test]
    fn end_to_end_through_repo_add_checkout() {
        let (td, repo) = setup();
        Attributes::add_line(repo.worktree(), "*.bin filter=lfs").unwrap();
        let payload: Vec<u8> = (0..10_000u32).flat_map(|x| x.to_le_bytes()).collect();
        std::fs::write(td.join("weights.bin"), &payload).unwrap();
        repo.add(&["weights.bin", ".thetaattributes"]).unwrap();
        let c1 = repo.commit("add weights", "t").unwrap();

        // The staged object is a small pointer, not the 40 KB payload.
        let staged = repo.read_path_at(c1, "weights.bin").unwrap().unwrap();
        assert!(staged.len() < 200);

        // Modify and commit again; checkout v1 restores exact bytes.
        std::fs::write(td.join("weights.bin"), vec![1u8; 1000]).unwrap();
        repo.add(&["weights.bin"]).unwrap();
        repo.commit("overwrite", "t").unwrap();
        repo.checkout(&c1.to_hex()).unwrap();
        assert_eq!(std::fs::read(td.join("weights.bin")).unwrap(), payload);
    }

    #[test]
    fn push_syncs_objects_and_clone_lazy_fetches() {
        let (td, repo) = setup();
        let td_remote = TempDir::new("remote").unwrap();
        Attributes::add_line(repo.worktree(), "*.bin filter=lfs").unwrap();
        std::fs::write(td.join("w.bin"), vec![9u8; 5000]).unwrap();
        repo.add(&["w.bin", ".thetaattributes"]).unwrap();
        repo.commit("c", "t").unwrap();
        repo.push(td_remote.path(), "main").unwrap();

        // Remote LFS store received the object.
        let remote_store = LfsStore::at(&td_remote.path().join("lfs/objects"));
        assert_eq!(remote_store.list().unwrap().len(), 1);

        // Fresh clone: pull + configure remote; smudge fetches lazily.
        let td_clone = TempDir::new("clone").unwrap();
        let clone = Repository::init(td_clone.path()).unwrap();
        clone.config_set("remote", td_remote.path().to_str().unwrap()).unwrap();
        clone.pull(td_remote.path(), "main").unwrap();
        assert_eq!(std::fs::read(td_clone.join("w.bin")).unwrap(), vec![9u8; 5000]);
    }
}
