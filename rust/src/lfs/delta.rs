//! Content-defined-chunking binary deltas for near-identical objects.
//!
//! Two models fine-tuned from one base share most of every dense
//! parameter group byte-for-byte, yet their group objects hash to
//! different oids, so oid-level dedup alone re-ships the whole group.
//! [`encode_delta`] closes that gap: it splits the *base* object into
//! content-defined chunks (a gear rolling hash picks the boundaries,
//! so an insertion shifts chunk edges locally instead of invalidating
//! every later block), indexes them by content hash, and walks the
//! *target* emitting copy ops for chunks the base already holds and
//! literal ops for genuinely new bytes. [`apply_delta`] replays the
//! ops against the base with full bounds checking — a corrupt or
//! hostile ops stream yields an error, never a panic or an oversized
//! allocation.
//!
//! The ops stream is a flat tag-length encoding (integers
//! little-endian):
//!
//! ```text
//! 0x00 | len u32 | bytes        literal: append `len` raw bytes
//! 0x01 | off u64 | len u32      copy: append base[off .. off+len]
//! ```
//!
//! Adjacent ops coalesce (contiguous copies merge into one, literal
//! runs merge into one), so identical inputs encode to a single
//! whole-object copy. Chunk-hash matches are confirmed with a byte
//! compare — the hash is only a filter — so an encoded delta can never
//! describe a wrong copy. Chunking parameters and the gear table are
//! fixed constants, making encoding fully deterministic: the same
//! (base, target) pair always yields the same ops bytes, which is what
//! keeps delta packs content-addressed and resumable.

use anyhow::{bail, Result};
use std::collections::HashMap;
use std::sync::OnceLock;

/// Minimum chunk length: the boundary test is suppressed below this,
/// keeping pathological inputs from degenerating into tiny chunks.
const MIN_CHUNK: usize = 512;
/// Hard maximum chunk length: a boundary is forced at this size even
/// if the rolling hash never fires (e.g. on constant data).
const MAX_CHUNK: usize = 4096;
/// Boundary mask: a chunk ends where `hash & MASK == 0`, giving ~1 KiB
/// average chunks between the min/max clamps.
const BOUNDARY_MASK: u64 = (1 << 10) - 1;

/// Ops-stream tag: literal bytes follow.
const OP_LITERAL: u8 = 0x00;
/// Ops-stream tag: copy a base range.
const OP_COPY: u8 = 0x01;

fn splitmix64(x: u64) -> u64 {
    let mut z = x.wrapping_add(0x9e37_79b9_7f4a_7c15);
    z = (z ^ (z >> 30)).wrapping_mul(0xbf58_476d_1ce4_e5b9);
    z = (z ^ (z >> 27)).wrapping_mul(0x94d0_49bb_1331_11eb);
    z ^ (z >> 31)
}

/// The 256-entry gear table, derived deterministically (splitmix64 of
/// the byte value) so chunk boundaries — and therefore encoded deltas
/// and the packs that carry them — are stable across processes.
fn gear() -> &'static [u64; 256] {
    static GEAR: OnceLock<[u64; 256]> = OnceLock::new();
    GEAR.get_or_init(|| {
        let mut table = [0u64; 256];
        for (i, slot) in table.iter_mut().enumerate() {
            *slot = splitmix64(i as u64);
        }
        table
    })
}

/// Split `data` into content-defined chunks, returned as (offset, len)
/// spans covering the input exactly.
fn chunk_spans(data: &[u8]) -> Vec<(usize, usize)> {
    let gear = gear();
    let mut spans = Vec::with_capacity(data.len() / 1024 + 1);
    let mut start = 0usize;
    let mut hash = 0u64;
    let mut len = 0usize;
    for (i, &b) in data.iter().enumerate() {
        hash = (hash << 1).wrapping_add(gear[b as usize]);
        len += 1;
        if (len >= MIN_CHUNK && (hash & BOUNDARY_MASK) == 0) || len >= MAX_CHUNK {
            spans.push((start, len));
            start = i + 1;
            hash = 0;
            len = 0;
        }
    }
    if len > 0 {
        spans.push((start, len));
    }
    spans
}

/// FNV-1a over a chunk: the index filter. Matches are re-verified with
/// a byte compare before any copy op is emitted.
fn chunk_hash(bytes: &[u8]) -> u64 {
    let mut h = 0xcbf2_9ce4_8422_2325u64;
    for &b in bytes {
        h ^= b as u64;
        h = h.wrapping_mul(0x0000_0100_0000_01b3);
    }
    h
}

/// Incremental ops encoder that coalesces adjacent literals and
/// base-contiguous copies.
struct OpsBuilder {
    ops: Vec<u8>,
    lit: Vec<u8>,
    copy: Option<(u64, u64)>,
}

impl OpsBuilder {
    fn new() -> OpsBuilder {
        OpsBuilder {
            ops: Vec::new(),
            lit: Vec::new(),
            copy: None,
        }
    }

    fn flush_lit(&mut self) {
        // u32 op lengths: a >4 GiB literal run (at the pack format's
        // object limit) splits into several ops.
        for piece in self.lit.chunks(u32::MAX as usize) {
            self.ops.push(OP_LITERAL);
            self.ops.extend_from_slice(&(piece.len() as u32).to_le_bytes());
            self.ops.extend_from_slice(piece);
        }
        self.lit.clear();
    }

    fn flush_copy(&mut self) {
        if let Some((mut off, mut len)) = self.copy.take() {
            while len > 0 {
                let piece = len.min(u32::MAX as u64);
                self.ops.push(OP_COPY);
                self.ops.extend_from_slice(&off.to_le_bytes());
                self.ops.extend_from_slice(&(piece as u32).to_le_bytes());
                off += piece;
                len -= piece;
            }
        }
    }

    fn literal(&mut self, bytes: &[u8]) {
        self.flush_copy();
        self.lit.extend_from_slice(bytes);
    }

    fn copy(&mut self, off: u64, len: u64) {
        self.flush_lit();
        match self.copy {
            Some((o, l)) if o + l == off => self.copy = Some((o, l + len)),
            Some(_) => {
                self.flush_copy();
                self.copy = Some((off, len));
            }
            None => self.copy = Some((off, len)),
        }
    }

    fn finish(mut self) -> Vec<u8> {
        self.flush_lit();
        self.flush_copy();
        self.ops
    }
}

/// Encode `target` as an ops stream against `base`.
///
/// Deterministic, and always correct for *any* pair of inputs — in the
/// worst case (nothing shared) the ops are one literal holding the
/// whole target plus 5 bytes of framing. Whether the delta is *worth
/// shipping* is the caller's decision (the pack planner compares the
/// compressed ops against the compressed full object).
pub fn encode_delta(base: &[u8], target: &[u8]) -> Vec<u8> {
    let mut index: HashMap<u64, Vec<(usize, usize)>> = HashMap::new();
    for (off, len) in chunk_spans(base) {
        index
            .entry(chunk_hash(&base[off..off + len]))
            .or_default()
            .push((off, len));
    }
    let mut b = OpsBuilder::new();
    for (off, len) in chunk_spans(target) {
        let piece = &target[off..off + len];
        let hit = index.get(&chunk_hash(piece)).and_then(|cands| {
            cands
                .iter()
                .find(|&&(boff, blen)| blen == len && &base[boff..boff + blen] == piece)
        });
        match hit {
            Some(&(boff, _)) => b.copy(boff as u64, len as u64),
            None => b.literal(piece),
        }
    }
    b.finish()
}

fn take<'a>(ops: &'a [u8], at: &mut usize, n: usize) -> Result<&'a [u8]> {
    if ops.len() - *at < n {
        bail!("delta ops stream truncated");
    }
    let s = &ops[*at..*at + n];
    *at += n;
    Ok(s)
}

/// Replay an ops stream against `base`, producing exactly
/// `expected_len` bytes.
///
/// Every read is bounds-checked against the ops stream and the base,
/// and the output is capped at `expected_len` as it grows, so a
/// corrupt or hostile stream fails fast without a panic or an
/// allocation larger than the declared result.
pub fn apply_delta(base: &[u8], ops: &[u8], expected_len: u64) -> Result<Vec<u8>> {
    let mut out: Vec<u8> = Vec::with_capacity((expected_len as usize).min(16 << 20));
    let mut at = 0usize;
    while at < ops.len() {
        let tag = ops[at];
        at += 1;
        match tag {
            OP_LITERAL => {
                let len = u32::from_le_bytes(take(ops, &mut at, 4)?.try_into().unwrap()) as usize;
                let bytes = take(ops, &mut at, len)?;
                if out.len() as u64 + len as u64 > expected_len {
                    bail!("delta output exceeds its declared length");
                }
                out.extend_from_slice(bytes);
            }
            OP_COPY => {
                let off = u64::from_le_bytes(take(ops, &mut at, 8)?.try_into().unwrap());
                let len =
                    u32::from_le_bytes(take(ops, &mut at, 4)?.try_into().unwrap()) as u64;
                let end = off
                    .checked_add(len)
                    .filter(|&e| e <= base.len() as u64)
                    .ok_or_else(|| anyhow::anyhow!("delta copy overruns its base"))?;
                if out.len() as u64 + len > expected_len {
                    bail!("delta output exceeds its declared length");
                }
                out.extend_from_slice(&base[off as usize..end as usize]);
            }
            t => bail!("delta ops stream has unknown tag {t:#04x}"),
        }
    }
    if out.len() as u64 != expected_len {
        bail!(
            "delta output has wrong length ({} declared, {} produced)",
            expected_len,
            out.len()
        );
    }
    Ok(out)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::prop::{self, gens};
    use crate::util::rng::Pcg64;

    fn roundtrip(base: &[u8], target: &[u8]) -> Vec<u8> {
        let ops = encode_delta(base, target);
        let back = apply_delta(base, &ops, target.len() as u64).unwrap();
        assert_eq!(back, target, "delta roundtrip changed the content");
        ops
    }

    #[test]
    fn identical_inputs_encode_one_copy() {
        let data: Vec<u8> = (0..20_000u32).map(|i| (i % 251) as u8).collect();
        let ops = roundtrip(&data, &data);
        // One coalesced copy op: tag + off + len.
        assert_eq!(ops.len(), 13, "identical inputs must coalesce to one copy");
    }

    #[test]
    fn near_identical_is_mostly_copies() {
        let mut rng = Pcg64::new(7);
        let base: Vec<u8> = (0..64 * 1024).map(|_| rng.next_u64() as u8).collect();
        let mut target = base.clone();
        // Overwrite an interior 4 KiB window.
        for b in &mut target[20_000..24_096] {
            *b = rng.next_u64() as u8;
        }
        let ops = roundtrip(&base, &target);
        assert!(
            ops.len() < target.len() / 4,
            "ops ({} bytes) should be far smaller than the target ({} bytes)",
            ops.len(),
            target.len()
        );
    }

    #[test]
    fn disjoint_inputs_still_roundtrip() {
        let mut rng = Pcg64::new(8);
        let base: Vec<u8> = (0..8000).map(|_| rng.next_u64() as u8).collect();
        let target: Vec<u8> = (0..9000).map(|_| rng.next_u64() as u8).collect();
        roundtrip(&base, &target);
        roundtrip(&[], &target);
        roundtrip(&base, &[]);
        assert!(encode_delta(&base, &[]).is_empty());
    }

    #[test]
    fn random_edits_roundtrip_property() {
        prop::check(
            "delta_random_edits",
            |rng| {
                let n = gens::usize_in(rng, 0, 40_000);
                let base: Vec<u8> = (0..n).map(|_| rng.next_u64() as u8).collect();
                let mut target = base.clone();
                // A few random splices: overwrite, insert, or truncate.
                for _ in 0..gens::usize_in(rng, 0, 4) {
                    if target.is_empty() {
                        break;
                    }
                    let at = gens::usize_in(rng, 0, target.len() - 1);
                    let len = gens::usize_in(rng, 1, 2000).min(target.len() - at);
                    match rng.below(3) {
                        0 => {
                            for b in &mut target[at..at + len] {
                                *b = rng.next_u64() as u8;
                            }
                        }
                        1 => {
                            let ins: Vec<u8> =
                                (0..len).map(|_| rng.next_u64() as u8).collect();
                            target.splice(at..at, ins);
                        }
                        _ => {
                            target.drain(at..at + len);
                        }
                    }
                }
                (base, target)
            },
            |(base, target)| {
                let ops = encode_delta(base, target);
                let back = apply_delta(base, &ops, target.len() as u64)
                    .map_err(|e| format!("apply failed: {e:#}"))?;
                if back != *target {
                    return Err("roundtrip mismatch".to_string());
                }
                Ok(())
            },
        );
    }

    #[test]
    fn corrupt_ops_never_panic_and_never_pass() {
        let mut rng = Pcg64::new(9);
        let base: Vec<u8> = (0..10_000).map(|_| rng.next_u64() as u8).collect();
        let mut target = base.clone();
        for b in &mut target[4000..4200] {
            *b = rng.next_u64() as u8;
        }
        let ops = encode_delta(&base, &target);

        // Truncations: must error (or, if the stream stays well formed,
        // fail the final length check) — never produce the target.
        for keep in [0, 1, 5, ops.len() / 2, ops.len() - 1] {
            if let Ok(out) = apply_delta(&base, &ops[..keep], target.len() as u64) {
                assert_ne!(out, target, "truncated ops at {keep} reproduced the target");
            }
        }
        // Byte flips across the stream: same contract.
        for at in (0..ops.len()).step_by(7) {
            let mut bad = ops.clone();
            bad[at] ^= 0xff;
            if let Ok(out) = apply_delta(&base, &bad, target.len() as u64) {
                assert_ne!(out, target, "flipped ops at {at} reproduced the target");
            }
        }
        // A wrong declared length is always rejected.
        assert!(apply_delta(&base, &ops, target.len() as u64 + 1).is_err());
        assert!(apply_delta(&base, &ops, target.len() as u64 - 1).is_err());
    }
}
