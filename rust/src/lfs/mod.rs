//! Git LFS substrate (paper §2.4).
//!
//! Reimplements the slice of Git LFS that Git-Theta builds on: pointer
//! files, a content-addressed large-object store under
//! `.theta/lfs/objects/`, clean/smudge filters that swap file contents
//! for pointers, a pre-push hook that syncs referenced objects to an
//! LFS remote, and smudge-time download from the remote.
//!
//! Transfer is batched, transport-abstracted, and **streaming**:
//! [`batch`] negotiates the full have/want set in one round trip and
//! [`pack`] moves every missing object as a single integrity-checked
//! packfile over a [`transport::RemoteTransport`] — a directory
//! ([`remote`]) or an HTTP server ([`http`] client / [`server`]) with
//! byte-range resume of interrupted transfers. Packs spill to disk and
//! move in bounded chunks over pooled keep-alive connections, so peak
//! memory scales with the largest object, not the pack, and a
//! multi-request push or fetch pays one TCP connect. Transfers that
//! carry model update chains advertise them
//! ([`transport::ChainAdvert`]) in the same negotiation round trip, in
//! both directions: on push the receiver answers its held prefix
//! depths and the sender ships suffix objects as [`delta`] records
//! against bases the receiver holds; on fetch the client advertises
//! the chains it holds and the responder plans the deltas — consulting
//! a (base, target) [`pack::PlanCache`] so repeated fine-tune fetches
//! of one base skip the CDC chunking (pack format v2 — the flat
//! protocol remains the version-skew fallback either way). Failures
//! are typed and classified ([`retry`]): a shed (`503 + Retry-After`),
//! cut, or timeout is retryable under a seeded, capped backoff policy
//! that rides byte-range resume; a `4xx` or checksum mismatch is
//! fatal and surfaces immediately. [`faults`] is the
//! failure-injection proxy that proves the resume semantics (see
//! `docs/ARCHITECTURE.md` "Remotes" for the data flow and wire
//! protocol).
//!
//! It is used two ways in this repo:
//! 1. as Git-Theta's parameter-group storage backend (paper §3.3
//!    "Storage"), and
//! 2. as the **Table 1 baseline**: tracking a whole checkpoint as one
//!    opaque LFS blob (`baseline/`).

pub mod batch;
pub mod delta;
pub mod faults;
pub mod filter;
pub mod http;
pub mod pack;
pub mod pointer;
pub mod remote;
pub mod replicate;
pub mod retry;
pub mod server;
pub mod store;
pub mod transport;

pub use batch::{
    fetch_pack, fetch_pack_chains, push_pack, BatchResponse, Prefetcher, TransferStats,
    TransferSummary,
};
pub use delta::{apply_delta, encode_delta};
pub use filter::{register_lfs, LfsFilter, LfsHooks};
pub use http::HttpRemote;
pub use pack::{
    build_pack, full_record_cost, pack_id, pack_index, plan_deltas, plan_deltas_cached,
    unpack_file, unpack_into, unpack_verified, verify_pack_file, write_delta_pack_file,
    write_pack_file, BuiltPack, DeltaPlan, DeltaRecord, PackCheck, PackStats, PackWriter,
    PlanCache, PACK_VERSION_DELTA,
};
pub use server::gc_stale_packs;
pub use pointer::Pointer;
pub use remote::{sync_to_remote, DirRemote, LfsRemote};
pub use replicate::{HealthState, MirrorHealth, RepairReport, ReplicatedRemote};
pub use retry::{classify, parse_retry_after, FailureClass, RetryBudget, RetryPolicy, WireError};
pub use server::{LfsServer, MetricsSnapshot, ServeOptions};
pub use store::LfsStore;
pub use transport::{
    answer_chains, download_with_chains, open_transport, upload_with_chains, ChainAdvert,
    ChainEntryAdvert, ChainNegotiation, RemoteTransport, WireReport,
};
