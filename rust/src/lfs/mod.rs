//! Git LFS substrate (paper §2.4).
//!
//! Reimplements the slice of Git LFS that Git-Theta builds on: pointer
//! files, a content-addressed large-object store under
//! `.theta/lfs/objects/`, clean/smudge filters that swap file contents
//! for pointers, a pre-push hook that syncs referenced objects to an
//! LFS remote, and smudge-time download from the remote.
//!
//! Transfer is batched: [`batch`] negotiates the full have/want set in
//! one round trip and [`pack`] moves every missing object as a single
//! integrity-checked packfile (see `docs/ARCHITECTURE.md` for the data
//! flow).
//!
//! It is used two ways in this repo:
//! 1. as Git-Theta's parameter-group storage backend (paper §3.3
//!    "Storage"), and
//! 2. as the **Table 1 baseline**: tracking a whole checkpoint as one
//!    opaque LFS blob (`baseline/`).

pub mod batch;
pub mod filter;
pub mod pack;
pub mod pointer;
pub mod remote;
pub mod store;

pub use batch::{fetch_pack, push_pack, BatchResponse, Prefetcher, TransferStats, TransferSummary};
pub use filter::{register_lfs, LfsFilter, LfsHooks};
pub use pack::{build_pack, pack_index, unpack_into, PackStats};
pub use pointer::Pointer;
pub use remote::{sync_to_remote, LfsRemote};
pub use store::LfsStore;
