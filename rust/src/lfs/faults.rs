//! Fault-injection proxy: real-network failure modes for pack streams.
//!
//! The transport's resume story ("an interrupted transfer re-sends
//! only the missing tail") is only provable against a channel that can
//! actually stall, drop, or duplicate. [`FaultProxy`] sits between a
//! client and an [`LfsServer`](super::server::LfsServer), forwards
//! traffic verbatim, and — when armed — injects exactly one fault into
//! the next matching **pack body**:
//!
//! * **truncate** — kill both sockets once `k` pack-body bytes have
//!   been relayed (k is a byte offset *into the pack*, not the
//!   connection: HTTP heads are not counted, so tests can sweep k
//!   across the pack deterministically);
//! * **duplicate** — re-inject a previously relayed body slice in
//!   place of the real tail (stream corruption that preserves
//!   `Content-Length`, so only checksums can catch it);
//! * **delay** — sleep before relaying the pack.
//!
//! Faults are one-shot: after firing, the proxy is transparent again,
//! which is what lets a test assert "attempt 1 dies at byte k, the
//! retry resumes". Non-pack requests (negotiations, ref sync) always
//! pass through untouched.
//!
//! The proxy is a deliverable of the test harness (the
//! `rust/tests/support` module builds on it) but lives in the library
//! so `benchkit`'s transfer ablation can sample an injected-fault
//! resume too.

use crate::util::http::{self, Request};
use anyhow::{Context, Result};
use std::net::{SocketAddr, TcpListener, TcpStream};
use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};
use std::sync::{Arc, Mutex};

/// Which pack streams a fault applies to.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Direction {
    /// Server → client pack bodies (`GET /packs/<id>` responses).
    Download,
    /// Client → server pack bodies (`PUT /packs/<id>` requests).
    Upload,
}

/// One fault to inject into the next matching pack stream.
#[derive(Debug, Clone, Copy)]
pub struct FaultSpec {
    /// Which pack direction to target.
    pub direction: Direction,
    /// Kill the connection after relaying this many pack-body bytes.
    pub kill_after: Option<u64>,
    /// `(offset, len)`: when the body reaches `offset`, re-send the
    /// `len` bytes preceding it instead of the real continuation
    /// (total length preserved; content corrupted from `offset` on).
    pub duplicate_at: Option<(u64, u64)>,
    /// Sleep this long before relaying the pack body.
    pub delay_ms: u64,
}

impl FaultSpec {
    /// A truncation fault: cut the stream after `k` pack-body bytes.
    pub fn kill(direction: Direction, k: u64) -> FaultSpec {
        FaultSpec {
            direction,
            kill_after: Some(k),
            duplicate_at: None,
            delay_ms: 0,
        }
    }

    /// A duplication fault: at body byte `offset`, replay the previous
    /// `len` bytes (corrupting the stream without changing its length).
    pub fn duplicate(direction: Direction, offset: u64, len: u64) -> FaultSpec {
        FaultSpec {
            direction,
            kill_after: None,
            duplicate_at: Some((offset, len)),
            delay_ms: 0,
        }
    }

    /// A delay fault: stall the pack body by `ms` milliseconds.
    pub fn delay(direction: Direction, ms: u64) -> FaultSpec {
        FaultSpec {
            direction,
            kill_after: None,
            duplicate_at: None,
            delay_ms: ms,
        }
    }
}

/// A TCP proxy that can inject one fault into the next pack stream.
pub struct FaultProxy {
    addr: SocketAddr,
    armed: Arc<Mutex<Option<FaultSpec>>>,
    fired: Arc<AtomicU64>,
    stop: Arc<AtomicBool>,
    accept_thread: Option<std::thread::JoinHandle<()>>,
}

impl FaultProxy {
    /// Proxy localhost connections to `upstream` (an `http://` URL or
    /// a bare `host:port` authority).
    pub fn spawn(upstream: &str) -> Result<FaultProxy> {
        let upstream = if upstream.starts_with("http://") {
            http::authority_of(upstream)?
        } else {
            upstream.to_string()
        };
        let listener = TcpListener::bind("127.0.0.1:0").context("binding fault proxy")?;
        let addr = listener.local_addr()?;
        let armed = Arc::new(Mutex::new(None));
        let fired = Arc::new(AtomicU64::new(0));
        let stop = Arc::new(AtomicBool::new(false));
        let (armed2, fired2, stop2) = (armed.clone(), fired.clone(), stop.clone());
        let accept_thread = std::thread::spawn(move || {
            for conn in listener.incoming() {
                if stop2.load(Ordering::SeqCst) {
                    break;
                }
                if let Ok(stream) = conn {
                    let upstream = upstream.clone();
                    let armed = armed2.clone();
                    let fired = fired2.clone();
                    std::thread::spawn(move || {
                        let _ = relay(stream, &upstream, &armed, &fired);
                    });
                }
            }
        });
        Ok(FaultProxy {
            addr,
            armed,
            fired,
            stop,
            accept_thread: Some(accept_thread),
        })
    }

    /// The `http://` URL clients should use instead of the upstream's.
    pub fn url(&self) -> String {
        format!("http://{}", self.addr)
    }

    /// Arm one fault; it fires on the next matching pack stream and
    /// then disarms (replacing any fault still armed).
    pub fn arm(&self, spec: FaultSpec) {
        *self.armed.lock().unwrap() = Some(spec);
    }

    /// Disarm without firing.
    pub fn disarm(&self) {
        *self.armed.lock().unwrap() = None;
    }

    /// How many faults have fired since spawn.
    pub fn fired(&self) -> u64 {
        self.fired.load(Ordering::SeqCst)
    }
}

impl Drop for FaultProxy {
    fn drop(&mut self) {
        self.stop.store(true, Ordering::SeqCst);
        let _ = TcpStream::connect(self.addr);
        if let Some(handle) = self.accept_thread.take() {
            let _ = handle.join();
        }
    }
}

/// Apply a duplication fault to a body: replace the continuation at
/// `offset` with a replay of the `len` bytes before it, preserving
/// total length.
fn duplicate_body(body: &[u8], offset: u64, len: u64) -> Vec<u8> {
    let total = body.len();
    let offset = (offset as usize).min(total);
    let len = (len as usize).min(offset);
    if len == 0 {
        return body.to_vec();
    }
    let mut out = Vec::with_capacity(total);
    out.extend_from_slice(&body[..offset]);
    out.extend_from_slice(&body[offset - len..offset]);
    out.extend_from_slice(&body[offset..]);
    out.truncate(total);
    out
}

fn is_pack_request(req: &Request) -> Option<Direction> {
    if !req.path().starts_with("/packs/") {
        return None;
    }
    match req.method.as_str() {
        "GET" => Some(Direction::Download),
        "PUT" => Some(Direction::Upload),
        _ => None,
    }
}

/// Handle one proxied connection at request granularity, looping while
/// the client keeps the connection alive (so pooled keep-alive clients
/// work through the proxy): read the full request, apply any armed
/// upload fault while forwarding, read the full upstream response,
/// apply any armed download fault while relaying it back. A fired kill
/// fault ends the loop (both sockets drop — that is the fault).
fn relay(
    mut client: TcpStream,
    upstream: &str,
    armed: &Mutex<Option<FaultSpec>>,
    fired: &AtomicU64,
) -> Result<()> {
    client.set_read_timeout(Some(http::IO_TIMEOUT)).ok();
    client.set_write_timeout(Some(http::IO_TIMEOUT)).ok();
    loop {
        relay_one(&mut client, upstream, armed, fired)?;
    }
}

/// Relay a single request/response exchange; `Err` ends the connection
/// (including deliberate kill faults).
fn relay_one(
    client: &mut TcpStream,
    upstream: &str,
    armed: &Mutex<Option<FaultSpec>>,
    fired: &AtomicU64,
) -> Result<()> {
    let (req, _complete) = http::read_request(client)?;

    // Claim the armed fault iff this request is a matching pack stream.
    let fault = match is_pack_request(&req) {
        Some(direction) => {
            let mut guard = armed.lock().unwrap();
            if (*guard).map(|s| s.direction) == Some(direction) {
                guard.take()
            } else {
                None
            }
        }
        None => None,
    };
    if let Some(spec) = &fault {
        fired.fetch_add(1, Ordering::SeqCst);
        if spec.delay_ms > 0 {
            std::thread::sleep(std::time::Duration::from_millis(spec.delay_ms));
        }
    }

    let mut up = TcpStream::connect(upstream).context("fault proxy: connecting upstream")?;
    up.set_read_timeout(Some(http::IO_TIMEOUT)).ok();
    up.set_write_timeout(Some(http::IO_TIMEOUT)).ok();

    // Forward the request, with upload faults applied to the body.
    match fault {
        Some(spec) if spec.direction == Direction::Upload => {
            if let Some(k) = spec.kill_after {
                // Declare the full body but send only k bytes, then cut
                // both sockets: the server sees a short read and
                // persists the prefix; the client sees a dead channel.
                let k = (k as usize).min(req.body.len());
                http::write_request_head(
                    &mut up,
                    &req.method,
                    &req.target,
                    &req.headers,
                    req.body.len() as u64,
                )?;
                use std::io::Write;
                up.write_all(&req.body[..k])?;
                up.flush().ok();
                // Drop both connections (ends the keep-alive loop).
                anyhow::bail!("upload kill fault fired");
            }
            let mut faulted = req.clone();
            if let Some((offset, len)) = spec.duplicate_at {
                faulted.body = duplicate_body(&req.body, offset, len);
            }
            http::write_request(&mut up, &faulted)?;
        }
        _ => http::write_request(&mut up, &req)?,
    }

    // Relay the response, with download faults applied to the body.
    let resp = http::read_response(&mut up, req.method == "HEAD")?;
    match fault {
        Some(spec) if spec.direction == Direction::Download => {
            if let Some(k) = spec.kill_after {
                let k = (k as usize).min(resp.body.len());
                http::write_response_head(
                    client,
                    resp.status,
                    &resp.headers,
                    resp.body.len() as u64,
                )?;
                use std::io::Write;
                client.write_all(&resp.body[..k])?;
                client.flush().ok();
                // Drop both connections (ends the keep-alive loop).
                anyhow::bail!("download kill fault fired");
            }
            let mut faulted = resp.clone();
            if let Some((offset, len)) = spec.duplicate_at {
                faulted.body = duplicate_body(&resp.body, offset, len);
            }
            http::write_response(client, &faulted)?;
        }
        _ => http::write_response(client, &resp)?,
    }
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn duplicate_body_preserves_length_and_corrupts_tail() {
        let body: Vec<u8> = (0..100u8).collect();
        let out = duplicate_body(&body, 40, 10);
        assert_eq!(out.len(), body.len());
        assert_eq!(&out[..40], &body[..40]);
        assert_eq!(&out[40..50], &body[30..40]); // replayed slice
        assert_ne!(out, body);
        // Degenerate parameters are no-ops.
        assert_eq!(duplicate_body(&body, 0, 10), body);
        assert_eq!(duplicate_body(&body, 40, 0), body);
        // Offset past the end clamps to the end: the replayed slice
        // lands entirely in the truncated region, so nothing changes.
        assert_eq!(duplicate_body(&body, 1000, 10), body);
    }

    #[test]
    fn passthrough_when_unarmed() {
        use std::io::{Read, Write};
        use std::net::TcpListener;
        // A tiny upstream echoing a fixed response.
        let listener = TcpListener::bind("127.0.0.1:0").unwrap();
        let upstream_addr = listener.local_addr().unwrap();
        std::thread::spawn(move || {
            for conn in listener.incoming() {
                let mut stream = match conn {
                    Ok(s) => s,
                    Err(_) => break,
                };
                let mut buf = [0u8; 4096];
                let _ = stream.read(&mut buf);
                let _ = stream.write_all(
                    b"HTTP/1.1 200 OK\r\ncontent-length: 5\r\nconnection: close\r\n\r\nhello",
                );
            }
        });
        let proxy = FaultProxy::spawn(&upstream_addr.to_string()).unwrap();
        let authority = http::authority_of(&proxy.url()).unwrap();
        let resp = http::roundtrip(&authority, &Request::new("GET", "/anything")).unwrap();
        assert_eq!(resp.status, 200);
        assert_eq!(resp.body, b"hello");
        assert_eq!(proxy.fired(), 0);
    }
}
