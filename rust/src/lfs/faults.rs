//! Fault-injection proxy: real-network failure modes for pack streams.
//!
//! The transport's resume story ("an interrupted transfer re-sends
//! only the missing tail") is only provable against a channel that can
//! actually stall, drop, or duplicate. [`FaultProxy`] sits between a
//! client and an [`LfsServer`](super::server::LfsServer), forwards
//! traffic verbatim, and — when armed — injects exactly one fault into
//! the next matching **pack body**:
//!
//! * **truncate** — kill both sockets once `k` pack-body bytes have
//!   been relayed (k is a byte offset *into the pack*, not the
//!   connection: HTTP heads are not counted, so tests can sweep k
//!   across the pack deterministically);
//! * **duplicate** — re-inject a previously relayed body slice in
//!   place of the real tail (stream corruption that preserves
//!   `Content-Length`, so only checksums can catch it);
//! * **delay** — sleep before relaying the pack;
//! * **stall** — relay the body up to an offset, then hold the
//!   connection silent for a fixed time before sending the rest (the
//!   slow-loris shape that request budgets must cut);
//! * **slow-drip** — relay the body in tiny chunks with a pause
//!   between each (a peer that is alive but pathologically slow).
//!
//! Faults are one-shot: after firing, the proxy is transparent again,
//! which is what lets a test assert "attempt 1 dies at byte k, the
//! retry resumes". Non-pack requests (negotiations, ref sync) always
//! pass through untouched.
//!
//! Separately from per-pack faults, [`FaultProxy::reject_next`] arms a
//! **multi-shot admission fault**: the next `n` requests (any route)
//! are answered locally with `503 + Retry-After` without touching the
//! upstream — the overload-shedding shape the client's
//! [`RetryPolicy`](super::retry::RetryPolicy) must absorb.
//!
//! The proxy is a deliverable of the test harness (the
//! `rust/tests/support` module builds on it) but lives in the library
//! so `benchkit`'s transfer ablation can sample an injected-fault
//! resume too.

use crate::util::http::{self, Request};
use anyhow::{Context, Result};
use std::net::{SocketAddr, TcpListener, TcpStream};
use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};
use std::sync::{Arc, Mutex};

/// Which pack streams a fault applies to.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Direction {
    /// Server → client pack bodies (`GET /packs/<id>` responses).
    Download,
    /// Client → server pack bodies (`PUT /packs/<id>` requests).
    Upload,
}

/// One fault to inject into the next matching pack stream.
#[derive(Debug, Clone, Copy)]
pub struct FaultSpec {
    /// Which pack direction to target.
    pub direction: Direction,
    /// Kill the connection after relaying this many pack-body bytes.
    pub kill_after: Option<u64>,
    /// `(offset, len)`: when the body reaches `offset`, re-send the
    /// `len` bytes preceding it instead of the real continuation
    /// (total length preserved; content corrupted from `offset` on).
    pub duplicate_at: Option<(u64, u64)>,
    /// Sleep this long before relaying the pack body.
    pub delay_ms: u64,
    /// Relay the body up to this offset, then go silent for
    /// [`stall_ms`](FaultSpec::stall_ms) before sending the rest.
    pub stall_at: Option<u64>,
    /// How long a `stall_at` fault holds the connection silent.
    pub stall_ms: u64,
    /// Relay the body in chunks of this size with a
    /// [`drip_ms`](FaultSpec::drip_ms) pause between each.
    pub drip_chunk: Option<usize>,
    /// The per-chunk pause of a `drip_chunk` fault.
    pub drip_ms: u64,
}

/// A spec with no fault modes set (direction only); constructors start
/// from this and flip on the one mode they model.
fn base_spec(direction: Direction) -> FaultSpec {
    FaultSpec {
        direction,
        kill_after: None,
        duplicate_at: None,
        delay_ms: 0,
        stall_at: None,
        stall_ms: 0,
        drip_chunk: None,
        drip_ms: 0,
    }
}

impl FaultSpec {
    /// A truncation fault: cut the stream after `k` pack-body bytes.
    pub fn kill(direction: Direction, k: u64) -> FaultSpec {
        FaultSpec {
            kill_after: Some(k),
            ..base_spec(direction)
        }
    }

    /// A duplication fault: at body byte `offset`, replay the previous
    /// `len` bytes (corrupting the stream without changing its length).
    pub fn duplicate(direction: Direction, offset: u64, len: u64) -> FaultSpec {
        FaultSpec {
            duplicate_at: Some((offset, len)),
            ..base_spec(direction)
        }
    }

    /// A delay fault: stall the pack body by `ms` milliseconds.
    pub fn delay(direction: Direction, ms: u64) -> FaultSpec {
        FaultSpec {
            delay_ms: ms,
            ..base_spec(direction)
        }
    }

    /// A stall fault: relay `offset` body bytes, hold the connection
    /// silent for `ms` milliseconds, then relay the rest. The socket
    /// stays open the whole time — only a request budget can cut it.
    pub fn stall(direction: Direction, offset: u64, ms: u64) -> FaultSpec {
        FaultSpec {
            stall_at: Some(offset),
            stall_ms: ms,
            ..base_spec(direction)
        }
    }

    /// A slow-drip fault: relay the body `chunk` bytes at a time with
    /// `ms` milliseconds between chunks — alive, but pathologically
    /// slow.
    pub fn drip(direction: Direction, chunk: usize, ms: u64) -> FaultSpec {
        FaultSpec {
            drip_chunk: Some(chunk.max(1)),
            drip_ms: ms,
            ..base_spec(direction)
        }
    }
}

/// State shared between the proxy handle and its relay threads.
struct ProxyShared {
    /// The one-shot pack-stream fault, if armed.
    armed: Mutex<Option<FaultSpec>>,
    /// Total faults fired since spawn (pack faults + rejections).
    fired: AtomicU64,
    /// How many more requests to answer locally with a 503.
    reject_left: AtomicU64,
    /// The `Retry-After` value (seconds) rejection responses carry.
    reject_retry_after: AtomicU64,
}

/// A TCP proxy that can inject one fault into the next pack stream.
pub struct FaultProxy {
    addr: SocketAddr,
    shared: Arc<ProxyShared>,
    stop: Arc<AtomicBool>,
    accept_thread: Option<std::thread::JoinHandle<()>>,
}

impl FaultProxy {
    /// Proxy localhost connections to `upstream` (an `http://` URL or
    /// a bare `host:port` authority).
    pub fn spawn(upstream: &str) -> Result<FaultProxy> {
        let upstream = if upstream.starts_with("http://") {
            http::authority_of(upstream)?
        } else {
            upstream.to_string()
        };
        let listener = TcpListener::bind("127.0.0.1:0").context("binding fault proxy")?;
        let addr = listener.local_addr()?;
        let shared = Arc::new(ProxyShared {
            armed: Mutex::new(None),
            fired: AtomicU64::new(0),
            reject_left: AtomicU64::new(0),
            reject_retry_after: AtomicU64::new(0),
        });
        let stop = Arc::new(AtomicBool::new(false));
        let (shared2, stop2) = (shared.clone(), stop.clone());
        let accept_thread = std::thread::spawn(move || {
            for conn in listener.incoming() {
                if stop2.load(Ordering::SeqCst) {
                    break;
                }
                if let Ok(stream) = conn {
                    let upstream = upstream.clone();
                    let shared = shared2.clone();
                    std::thread::spawn(move || {
                        let _ = relay(stream, &upstream, &shared);
                    });
                }
            }
        });
        Ok(FaultProxy {
            addr,
            shared,
            stop,
            accept_thread: Some(accept_thread),
        })
    }

    /// The `http://` URL clients should use instead of the upstream's.
    pub fn url(&self) -> String {
        format!("http://{}", self.addr)
    }

    /// Arm one fault; it fires on the next matching pack stream and
    /// then disarms (replacing any fault still armed).
    pub fn arm(&self, spec: FaultSpec) {
        *self.shared.armed.lock().unwrap() = Some(spec);
    }

    /// Disarm without firing.
    pub fn disarm(&self) {
        *self.shared.armed.lock().unwrap() = None;
    }

    /// Answer the next `n` requests (any route) locally with
    /// `503 + Retry-After: <retry_after_secs>` without contacting the
    /// upstream — the reject-N-then-accept shape of an overloaded
    /// server. Unlike pack faults this is multi-shot: each rejection
    /// fires (and counts), the connection survives, and request `n+1`
    /// passes through normally.
    pub fn reject_next(&self, n: u64, retry_after_secs: u64) {
        self.shared
            .reject_retry_after
            .store(retry_after_secs, Ordering::SeqCst);
        self.shared.reject_left.store(n, Ordering::SeqCst);
    }

    /// How many faults have fired since spawn.
    pub fn fired(&self) -> u64 {
        self.shared.fired.load(Ordering::SeqCst)
    }
}

impl Drop for FaultProxy {
    fn drop(&mut self) {
        self.stop.store(true, Ordering::SeqCst);
        let _ = TcpStream::connect(self.addr);
        if let Some(handle) = self.accept_thread.take() {
            let _ = handle.join();
        }
    }
}

/// Apply a duplication fault to a body: replace the continuation at
/// `offset` with a replay of the `len` bytes before it, preserving
/// total length.
fn duplicate_body(body: &[u8], offset: u64, len: u64) -> Vec<u8> {
    let total = body.len();
    let offset = (offset as usize).min(total);
    let len = (len as usize).min(offset);
    if len == 0 {
        return body.to_vec();
    }
    let mut out = Vec::with_capacity(total);
    out.extend_from_slice(&body[..offset]);
    out.extend_from_slice(&body[offset - len..offset]);
    out.extend_from_slice(&body[offset..]);
    out.truncate(total);
    out
}

fn is_pack_request(req: &Request) -> Option<Direction> {
    if !req.path().starts_with("/packs/") {
        return None;
    }
    match req.method.as_str() {
        "GET" => Some(Direction::Download),
        "PUT" => Some(Direction::Upload),
        _ => None,
    }
}

/// Handle one proxied connection at request granularity, looping while
/// the client keeps the connection alive (so pooled keep-alive clients
/// work through the proxy): read the full request, apply any armed
/// upload fault while forwarding, read the full upstream response,
/// apply any armed download fault while relaying it back. A fired kill
/// fault ends the loop (both sockets drop — that is the fault).
fn relay(mut client: TcpStream, upstream: &str, shared: &ProxyShared) -> Result<()> {
    client.set_read_timeout(Some(http::IO_TIMEOUT)).ok();
    client.set_write_timeout(Some(http::IO_TIMEOUT)).ok();
    loop {
        relay_one(&mut client, upstream, shared)?;
    }
}

/// Relay a single request/response exchange; `Err` ends the connection
/// (including deliberate kill faults).
fn relay_one(client: &mut TcpStream, upstream: &str, shared: &ProxyShared) -> Result<()> {
    let (req, _complete) = http::read_request(client)?;

    // Admission faults answer locally, before any upstream contact:
    // an overloaded server sheds without doing the request's work.
    let claimed_reject = shared
        .reject_left
        .fetch_update(Ordering::SeqCst, Ordering::SeqCst, |n| n.checked_sub(1))
        .is_ok();
    if claimed_reject {
        shared.fired.fetch_add(1, Ordering::SeqCst);
        let secs = shared.reject_retry_after.load(Ordering::SeqCst);
        let resp = http::Response::new(503).header("retry-after", &secs.to_string());
        http::write_response(client, &resp)?;
        return Ok(()); // keep-alive: the retry rides the same channel
    }

    // Claim the armed fault iff this request is a matching pack stream.
    let fault = match is_pack_request(&req) {
        Some(direction) => {
            let mut guard = shared.armed.lock().unwrap();
            if (*guard).map(|s| s.direction) == Some(direction) {
                guard.take()
            } else {
                None
            }
        }
        None => None,
    };
    if let Some(spec) = &fault {
        shared.fired.fetch_add(1, Ordering::SeqCst);
        if spec.delay_ms > 0 {
            std::thread::sleep(std::time::Duration::from_millis(spec.delay_ms));
        }
    }

    let mut up = TcpStream::connect(upstream).context("fault proxy: connecting upstream")?;
    up.set_read_timeout(Some(http::IO_TIMEOUT)).ok();
    up.set_write_timeout(Some(http::IO_TIMEOUT)).ok();

    // Forward the request, with upload faults applied to the body.
    match fault {
        Some(spec) if spec.direction == Direction::Upload => {
            if let Some(k) = spec.kill_after {
                // Declare the full body but send only k bytes, then cut
                // both sockets: the server sees a short read and
                // persists the prefix; the client sees a dead channel.
                let k = (k as usize).min(req.body.len());
                http::write_request_head(
                    &mut up,
                    &req.method,
                    &req.target,
                    &req.headers,
                    req.body.len() as u64,
                )?;
                use std::io::Write;
                up.write_all(&req.body[..k])?;
                up.flush().ok();
                // Drop both connections (ends the keep-alive loop).
                anyhow::bail!("upload kill fault fired");
            }
            let mut body = req.body.clone();
            if let Some((offset, len)) = spec.duplicate_at {
                body = duplicate_body(&req.body, offset, len);
            }
            http::write_request_head(
                &mut up,
                &req.method,
                &req.target,
                &req.headers,
                body.len() as u64,
            )?;
            write_body_faulted(&mut up, &body, &spec)?;
        }
        _ => http::write_request(&mut up, &req)?,
    }

    // Relay the response, with download faults applied to the body.
    let resp = http::read_response(&mut up, req.method == "HEAD")?;
    match fault {
        Some(spec) if spec.direction == Direction::Download => {
            if let Some(k) = spec.kill_after {
                let k = (k as usize).min(resp.body.len());
                http::write_response_head(
                    client,
                    resp.status,
                    &resp.headers,
                    resp.body.len() as u64,
                )?;
                use std::io::Write;
                client.write_all(&resp.body[..k])?;
                client.flush().ok();
                // Drop both connections (ends the keep-alive loop).
                anyhow::bail!("download kill fault fired");
            }
            let mut body = resp.body.clone();
            if let Some((offset, len)) = spec.duplicate_at {
                body = duplicate_body(&resp.body, offset, len);
            }
            http::write_response_head(client, resp.status, &resp.headers, body.len() as u64)?;
            write_body_faulted(client, &body, &spec)?;
        }
        _ => http::write_response(client, &resp)?,
    }
    Ok(())
}

/// Write a (possibly duplicated) body with any stall or drip fault
/// applied; the head — with the body's true length — is already on the
/// wire, so the peer's `Content-Length` accounting stays honest while
/// the *pacing* misbehaves.
fn write_body_faulted(stream: &mut TcpStream, body: &[u8], spec: &FaultSpec) -> Result<()> {
    use std::io::Write;
    if let Some(offset) = spec.stall_at {
        let offset = (offset as usize).min(body.len());
        stream.write_all(&body[..offset])?;
        stream.flush().ok();
        std::thread::sleep(std::time::Duration::from_millis(spec.stall_ms));
        stream.write_all(&body[offset..])?;
    } else if let Some(chunk) = spec.drip_chunk {
        for piece in body.chunks(chunk.max(1)) {
            stream.write_all(piece)?;
            stream.flush().ok();
            std::thread::sleep(std::time::Duration::from_millis(spec.drip_ms));
        }
    } else {
        stream.write_all(body)?;
    }
    stream.flush().context("flushing faulted body")
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn duplicate_body_preserves_length_and_corrupts_tail() {
        let body: Vec<u8> = (0..100u8).collect();
        let out = duplicate_body(&body, 40, 10);
        assert_eq!(out.len(), body.len());
        assert_eq!(&out[..40], &body[..40]);
        assert_eq!(&out[40..50], &body[30..40]); // replayed slice
        assert_ne!(out, body);
        // Degenerate parameters are no-ops.
        assert_eq!(duplicate_body(&body, 0, 10), body);
        assert_eq!(duplicate_body(&body, 40, 0), body);
        // Offset past the end clamps to the end: the replayed slice
        // lands entirely in the truncated region, so nothing changes.
        assert_eq!(duplicate_body(&body, 1000, 10), body);
    }

    /// A tiny single-purpose upstream answering every request with
    /// `200 hello`, for tests that only exercise the proxy itself.
    fn tiny_upstream() -> std::net::SocketAddr {
        use std::io::{Read, Write};
        use std::net::TcpListener;
        let listener = TcpListener::bind("127.0.0.1:0").unwrap();
        let upstream_addr = listener.local_addr().unwrap();
        std::thread::spawn(move || {
            for conn in listener.incoming() {
                let mut stream = match conn {
                    Ok(s) => s,
                    Err(_) => break,
                };
                let mut buf = [0u8; 4096];
                let _ = stream.read(&mut buf);
                let _ = stream.write_all(
                    b"HTTP/1.1 200 OK\r\ncontent-length: 5\r\nconnection: close\r\n\r\nhello",
                );
            }
        });
        upstream_addr
    }

    #[test]
    fn passthrough_when_unarmed() {
        let upstream_addr = tiny_upstream();
        let proxy = FaultProxy::spawn(&upstream_addr.to_string()).unwrap();
        let authority = http::authority_of(&proxy.url()).unwrap();
        let resp = http::roundtrip(&authority, &Request::new("GET", "/anything")).unwrap();
        assert_eq!(resp.status, 200);
        assert_eq!(resp.body, b"hello");
        assert_eq!(proxy.fired(), 0);
    }

    #[test]
    fn reject_next_sheds_locally_then_passes_through() {
        let upstream_addr = tiny_upstream();
        let proxy = FaultProxy::spawn(&upstream_addr.to_string()).unwrap();
        let authority = http::authority_of(&proxy.url()).unwrap();
        proxy.reject_next(2, 9);
        for _ in 0..2 {
            let resp = http::roundtrip(&authority, &Request::new("GET", "/anything")).unwrap();
            assert_eq!(resp.status, 503);
            assert_eq!(resp.get_header("retry-after"), Some("9"));
        }
        // Request n+1 reaches the upstream untouched.
        let resp = http::roundtrip(&authority, &Request::new("GET", "/anything")).unwrap();
        assert_eq!(resp.status, 200);
        assert_eq!(resp.body, b"hello");
        assert_eq!(proxy.fired(), 2);
    }

    #[test]
    fn stall_and_drip_deliver_the_full_body_late() {
        let upstream_addr = tiny_upstream();
        let proxy = FaultProxy::spawn(&upstream_addr.to_string()).unwrap();
        let authority = http::authority_of(&proxy.url()).unwrap();
        // Pack-shaped target so the armed download faults match.
        let target = format!("/packs/{}", "0".repeat(64));
        for spec in [
            FaultSpec::stall(Direction::Download, 2, 120),
            FaultSpec::drip(Direction::Download, 1, 15),
        ] {
            proxy.arm(spec);
            let started = std::time::Instant::now();
            let resp = http::roundtrip(&authority, &Request::new("GET", &target)).unwrap();
            assert_eq!(resp.status, 200);
            assert_eq!(resp.body, b"hello"); // late, but intact
            assert!(resp.complete);
            assert!(started.elapsed() >= std::time::Duration::from_millis(50));
        }
        assert_eq!(proxy.fired(), 2);
    }
}
