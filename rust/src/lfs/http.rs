//! HTTP client half of the remote transport (`git-theta serve` peer).
//!
//! Speaks a small LFS-batch-style protocol against
//! [`LfsServer`](super::server::LfsServer):
//!
//! * `POST /objects/batch` — one have/want negotiation round trip.
//!   With a protocol-2 body the same request also advertises chain
//!   prefixes and the server reports per-chain held depths, enabling
//!   delta packs ([`RemoteTransport::negotiate_chains`]); servers that
//!   ignore the extra fields degrade the push to flat records.
//! * `POST /packs` + `GET /packs/<id>` — the server assembles (and
//!   caches) a pack for a want set; the client **streams** the body
//!   straight into a partial file under the staging directory, so an
//!   interrupted download resumes with `Range: bytes=<k>-` and a pack
//!   is never RAM-resident on the receive path.
//! * `HEAD`/`PUT /packs/<id>` — upload with `Content-Range` resume:
//!   the client spills the pack to a file and streams it out in fixed
//!   chunks; the server persists whatever body prefix arrives before a
//!   connection dies, `HEAD` reports how much it holds, and the retry
//!   sends only the tail.
//! * `GET`/`PUT /objects/<oid>` — per-object fallback.
//!
//! All requests ride one pooled keep-alive connection per endpoint
//! (see [`HttpClient`]): a push or fetch that negotiates, probes, and
//! moves a pack pays a single TCP connect, observable via
//! [`HttpRemote::connections_opened`].
//!
//! Every pack is verified before anything is admitted: the streamed
//! file must pass [`pack::verify_pack_file`] (structure + trailing
//! sha256) and match the id the server advertised, and `unpack_file`
//! re-hashes every object. A resumed splice that mixes a stale prefix
//! with a rebuilt tail therefore cannot corrupt a store — it fails
//! verification and the client falls back to one clean full download.

use super::batch::{self, BatchResponse};
use super::pack::{self, DeltaPlan, PackStats};
use super::retry::WireError;
use super::store::LfsStore;
use super::transport::{self, ChainAdvert, ChainNegotiation, RemoteTransport, WireReport};
use crate::gitcore::object::Oid;
use crate::gitcore::remote::{parse_json, parse_oid_arr, want_body};
use crate::util::http::{HttpClient, Request};
use crate::util::tmp::{self, TempDir};
use anyhow::{bail, Context, Result};
use std::io::{Seek, SeekFrom, Write};
use std::path::{Path, PathBuf};
use std::sync::Arc;
use std::time::Duration;

/// Age past which orphaned `.tmp*` files (claims and spills left by
/// crashed transfers — their names are unique per process+call, so no
/// retry ever reuses them) are reaped from a staging directory. Live
/// transfers are safe: their claims are far younger than this.
const STAGING_TMP_TTL: Duration = Duration::from_secs(60 * 60);

/// Admit a verified claim file into `dest`, removing it on success
/// **and** on failure. A claim that passed `verify_pack_file` but then
/// fails admission (a record failing its oid re-hash, a delta record
/// whose store base this client lacks, disk full) must not be handed
/// back to the shared resume slot: a full-length partial there is
/// re-verified and re-admitted on the next fetch of the same pack id,
/// so a deterministically bad pack would fail the same way forever —
/// the poisoned-resume loop. Deleting it costs one clean re-download
/// and lets the retry start from offset 0 (and, for a delta pack, lets
/// the caller renegotiate for a flat one).
fn admit_and_consume(
    claim: &Path,
    dest: &LfsStore,
    threads: usize,
    check: &pack::PackCheck,
) -> Result<PackStats> {
    let result = pack::unpack_verified(claim, dest, threads, check);
    let _ = std::fs::remove_file(claim);
    result
}

/// Drop the first `n` bytes of a file in place (rewrite via a unique
/// temp + rename). Used when a server ignored our byte-range request
/// and sent the whole body after a stale prefix.
fn strip_file_prefix(path: &Path, n: u64) -> Result<()> {
    let mut src = std::fs::File::open(path).context("reopening partial pack")?;
    src.seek(SeekFrom::Start(n)).context("seeking partial pack")?;
    let tmp_path = tmp::unique_sibling(path);
    let mut dst = std::fs::File::create(&tmp_path).context("rewriting partial pack")?;
    std::io::copy(&mut src, &mut dst).context("rewriting partial pack")?;
    dst.flush().context("rewriting partial pack")?;
    drop(dst);
    std::fs::rename(&tmp_path, path).context("installing rewritten partial pack")?;
    Ok(())
}

/// Type an unexpected response status for the retry layer: a `503` is
/// a shed (its `Retry-After` hint travels with the error), anything
/// else is fatal — the server answered, it just said no. Header
/// parsing is delegated to [`retry::parse_retry_after`], which maps
/// HTTP-date and garbage values to `None` (→ default backoff) instead
/// of a zero-length pause.
fn status_error(status: u16, retry_after: Option<&str>, what: String) -> anyhow::Error {
    if status == 503 {
        let after = retry_after.and_then(super::retry::parse_retry_after);
        anyhow::Error::new(WireError::shed(after, what))
    } else {
        anyhow::Error::new(WireError::fatal(what))
    }
}

/// Client handle for an `http://` LFS remote.
#[derive(Debug, Clone)]
pub struct HttpRemote {
    client: Arc<HttpClient>,
    /// Staging root (usually a repository's `.theta` dir): partial
    /// downloads persist under `lfs/incoming/`, outgoing pack spills
    /// under `lfs/outgoing/`. `None` stages in throwaway temp dirs
    /// (transfers still stream and resume within a call, but nothing
    /// survives the process).
    staging: Option<PathBuf>,
}

impl HttpRemote {
    /// Parse the URL; `staging` (usually a repository's `.theta` dir)
    /// hosts partial pack downloads so an interrupted fetch resumes
    /// even across process restarts. URLs with a path component are
    /// rejected (the wire protocol is rooted at `/`).
    pub fn open(url: &str, staging: Option<&Path>) -> Result<HttpRemote> {
        Ok(HttpRemote {
            client: Arc::new(HttpClient::open(url)?),
            staging: staging.map(Path::to_path_buf),
        })
    }

    /// The endpoint URL this remote talks to.
    pub fn url(&self) -> &str {
        self.client.url()
    }

    /// TCP connections opened so far (all clones of this remote share
    /// one pool). With keep-alive this stays far below the request
    /// count — the transfer ablation locks it.
    pub fn connections_opened(&self) -> u64 {
        self.client.connections_opened()
    }

    /// Resolve a staging file path (`<staging>/<subdir>/<name>`,
    /// directory created) or — with no staging configured — a path in
    /// a throwaway temp dir whose guard the caller must keep alive.
    /// Shared by the download partials (`lfs/incoming`) and the upload
    /// spills (`lfs/outgoing`).
    fn staging_path(&self, subdir: &str, name: &str) -> Result<(PathBuf, Option<TempDir>)> {
        match &self.staging {
            Some(base) => {
                let dir = base.join(subdir);
                std::fs::create_dir_all(&dir)?;
                // Opportunistically reap claim/spill litter from
                // crashed transfers (unique names: no retry reuses it).
                tmp::reap_older_than(&dir, STAGING_TMP_TTL, |n| n.contains(".tmp"));
                Ok((dir.join(name), None))
            }
            None => {
                let td = TempDir::new("http-staging")?;
                Ok((td.join(name), Some(td)))
            }
        }
    }

    /// Stream one download attempt into `partial` (append mode) and
    /// return the server status plus (streamed bytes, complete).
    fn stream_pack_body(
        &self,
        id: &str,
        offset: u64,
        partial: &Path,
    ) -> Result<(u16, u64, bool)> {
        let mut req = Request::new("GET", &format!("/packs/{id}"));
        if offset > 0 {
            req = req.header("range", &format!("bytes={offset}-"));
        }
        let mut file = std::fs::OpenOptions::new()
            .create(true)
            .append(true)
            .open(partial)
            .context("opening partial pack file")?;
        let resp = self.client.fetch_to_sink(&req, &[200, 206], &mut file)?;
        file.flush().context("flushing partial pack file")?;
        match resp.status {
            200 | 206 => Ok((resp.status, resp.streamed, resp.complete)),
            404 => bail!("{} no longer has pack {id}", self.url()),
            s => Err(status_error(
                s,
                resp.get_header("retry-after"),
                format!("{}: GET /packs/{id} -> {s}", self.url()),
            )),
        }
    }

    /// Upload a spilled pack file with `Content-Range` resume.
    fn send_spilled(&self, built: &pack::BuiltPack, spill: &Path) -> Result<(PackStats, WireReport)> {
        let total = built.len;
        let id = &built.id;
        // How much of this pack did an earlier, interrupted attempt
        // already deliver? The server persists partial bodies.
        let head = self.client.send(&Request::new("HEAD", &format!("/packs/{id}")))?;
        let mut offset = head
            .get_header("x-received")
            .and_then(|v| v.parse::<u64>().ok())
            .unwrap_or(0);
        if offset > total {
            // A foreign partial under our id (should be impossible —
            // ids are content hashes); clear it and start over.
            let _ = self
                .client
                .roundtrip(&Request::new("DELETE", &format!("/packs/{id}")));
            offset = 0;
        }
        let mut file = std::fs::File::open(spill).context("opening spilled pack")?;
        for _attempt in 0..3 {
            let range = if offset == total {
                format!("bytes */{total}")
            } else {
                format!("bytes {offset}-{}/{total}", total - 1)
            };
            let wire = total - offset;
            let headers = vec![("content-range".to_string(), range)];
            let resp = self
                .client
                .send_file("PUT", &format!("/packs/{id}"), &headers, &mut file, offset, wire)
                .with_context(|| {
                    format!(
                        "pack upload to {} interrupted ({} keeps the partial; a retry resumes)",
                        self.url(),
                        self.url()
                    )
                })?;
            if !resp.complete {
                // Typed as a cut so the retry layer backs off and
                // resumes instead of giving up.
                return Err(anyhow::Error::new(WireError::cut(format!(
                    "pack upload to {} interrupted mid-response; a retry resumes from the \
                     server-side partial",
                    self.url()
                ))));
            }
            match resp.status {
                200 => {
                    let json = parse_json(&resp)?;
                    let stats = PackStats {
                        objects: json.get("objects").and_then(|v| v.as_usize()).unwrap_or(0),
                        raw_bytes: json.get("raw_bytes").and_then(|v| v.as_u64()).unwrap_or(0),
                        packed_bytes: total,
                        // Push-side delta counting comes from the plan
                        // (the receiver's count stays server-side).
                        delta_objects: 0,
                    };
                    let report = WireReport {
                        wire_bytes: wire,
                        resumed_bytes: offset,
                    };
                    return Ok((stats, report));
                }
                409 => {
                    // Our offset raced another writer (or a stale HEAD);
                    // the server tells us what it actually holds.
                    offset = resp
                        .get_header("x-received")
                        .and_then(|v| v.parse::<u64>().ok())
                        .unwrap_or(0)
                        .min(total);
                }
                422 => {
                    // The server answered: the pack itself is bad.
                    // Retrying would re-send the same rejected bytes.
                    return Err(anyhow::Error::new(WireError::fatal(format!(
                        "{} rejected pack {id}: {}",
                        self.url(),
                        String::from_utf8_lossy(&resp.body)
                    ))));
                }
                s => {
                    return Err(status_error(
                        s,
                        resp.get_header("retry-after"),
                        format!("{}: PUT /packs/{id} -> {s}", self.url()),
                    ))
                }
            }
        }
        bail!(
            "pack upload to {} kept conflicting on its resume offset",
            self.url()
        )
    }

    /// POST `/packs` with an arbitrary request body (flat want list or
    /// protocol-2 chain advert), then stream the advertised pack down
    /// with byte-range resume, verify it, and admit it into `dest`.
    /// The server assembles (or reuses) the pack and reports its
    /// identity + size; identical requests yield identical ids, so a
    /// retry after an interruption re-addresses the same pack.
    fn fetch_pack_request(
        &self,
        body: Vec<u8>,
        dest: &LfsStore,
        threads: usize,
    ) -> Result<(PackStats, WireReport)> {
        let resp = self
            .client
            .send(&Request::new("POST", "/packs").body(body))?;
        if resp.status != 200 {
            return Err(status_error(
                resp.status,
                resp.get_header("retry-after"),
                format!(
                    "{}: POST /packs -> {}: {}",
                    self.url(),
                    resp.status,
                    String::from_utf8_lossy(&resp.body)
                ),
            ));
        }
        let json = parse_json(&resp)?;
        let id = json
            .get("id")
            .and_then(|v| v.as_str())
            .context("/packs response missing id")?
            .to_string();
        let total = json
            .get("size")
            .and_then(|v| v.as_u64())
            .context("/packs response missing size")?;

        // Claim any persisted resume state by *renaming* the shared
        // `lfs/incoming/<id>` file to a path unique to this call:
        // concurrent fetches of the same pack id must never
        // append-interleave into one file. Exactly one claimant wins
        // the rename; losers simply start from byte zero.
        let (shared, _tmp_guard) = self.staging_path("lfs/incoming", &id)?;
        let claim = tmp::unique_sibling(&shared);
        let _ = std::fs::rename(&shared, &claim);
        let mut attempt_full = false;
        loop {
            if attempt_full {
                let _ = std::fs::remove_file(&claim);
            }
            let mut offset = std::fs::metadata(&claim).map(|m| m.len()).unwrap_or(0);
            if offset > total {
                let _ = std::fs::remove_file(&claim);
                offset = 0;
            }
            if offset == total {
                // A previous run persisted the complete pack just
                // before dying; verify and use it without touching the
                // wire. A full-length partial that fails verification
                // is dropped — resuming from it would just ask the
                // server for an empty tail.
                match pack::verify_pack_file(&claim) {
                    Ok(check) if check.id == id => {
                        let stats = admit_and_consume(&claim, dest, threads, &check)?;
                        let report = WireReport {
                            wire_bytes: 0,
                            resumed_bytes: total,
                        };
                        return Ok((stats, report));
                    }
                    _ => {}
                }
                let _ = std::fs::remove_file(&claim);
                offset = 0;
            }

            let (status, streamed, complete) = self.stream_pack_body(&id, offset, &claim)?;
            if status == 200 && offset > 0 {
                // The server ignored our byte range and sent the pack
                // from the top; drop our stale prefix so the file is a
                // clean prefix of the full body (resume math included),
                // and stop claiming resume savings we didn't get.
                strip_file_prefix(&claim, offset)?;
                offset = 0;
            }
            if !complete {
                // Mid-flight cut: every byte that made it across is in
                // the claim file; hand it back to the shared resume
                // slot so a retry — this process or the next — asks
                // only for the missing tail. (Without a staging dir
                // the slot dies with its temp dir.)
                let _ = std::fs::rename(&claim, &shared);
                // Typed as a cut: the retry layer resumes from the
                // persisted partial instead of treating this as final.
                return Err(anyhow::Error::new(WireError::cut(format!(
                    "pack download from {} interrupted after {} of {total} bytes{}",
                    self.url(),
                    offset + streamed,
                    if self.staging.is_some() {
                        " (partial persisted; a retry resumes from it)"
                    } else {
                        ""
                    }
                ))));
            }
            let have = std::fs::metadata(&claim).map(|m| m.len()).unwrap_or(0);
            if have == total {
                if let Ok(check) = pack::verify_pack_file(&claim) {
                    if check.id == id {
                        let stats = admit_and_consume(&claim, dest, threads, &check)?;
                        // The server-side pack cache is deliberately left in
                        // place: a concurrent clone of the same tip addresses
                        // the same content-hashed id, and deleting it here
                        // would 404 that transfer mid-flight. Stale outgoing
                        // packs are reaped by the server's age-based gc.
                        let report = WireReport {
                            wire_bytes: streamed,
                            resumed_bytes: offset,
                        };
                        return Ok((stats, report));
                    }
                }
            }
            // Verification failed: a stale partial spliced onto a
            // rebuilt pack, or in-flight corruption. Drop local state
            // and retry exactly once from scratch.
            let _ = std::fs::remove_file(&claim);
            if attempt_full || offset == 0 {
                bail!("pack {id} from {} failed integrity verification", self.url());
            }
            attempt_full = true;
        }
    }
}

impl RemoteTransport for HttpRemote {
    fn describe(&self) -> String {
        self.url().to_string()
    }

    fn batch(&self, want: &[Oid]) -> Result<BatchResponse> {
        batch::record(|s| s.negotiations += 1);
        let req = Request::new("POST", "/objects/batch").body(want_body(want));
        let resp = self.client.send(&req)?;
        if resp.status != 200 {
            return Err(status_error(
                resp.status,
                resp.get_header("retry-after"),
                format!("{}: POST /objects/batch -> {}", self.url(), resp.status),
            ));
        }
        let json = parse_json(&resp)?;
        let present = parse_oid_arr(&json, "present")?;
        let missing = parse_oid_arr(&json, "missing")?;
        let present_sizes: Vec<u64> = json
            .get("sizes")
            .and_then(|v| v.as_arr())
            .map(|a| a.iter().map(|v| v.as_u64().unwrap_or(0)).collect())
            .unwrap_or_default();
        Ok(BatchResponse {
            present,
            present_sizes,
            missing,
        })
    }

    fn list_oids(&self) -> Result<Option<Vec<Oid>>> {
        let resp = self.client.send(&Request::new("GET", "/objects"))?;
        match resp.status {
            200 => Ok(Some(parse_oid_arr(&parse_json(&resp)?, "oids")?)),
            // A pre-inventory server has no /objects route: report
            // "cannot enumerate", not an error (version skew rule).
            404 => Ok(None),
            s => Err(status_error(
                s,
                resp.get_header("retry-after"),
                format!("{}: GET /objects -> {s}", self.url()),
            )),
        }
    }

    fn negotiate_chains(&self, adv: &ChainAdvert) -> Result<ChainNegotiation> {
        batch::record(|s| s.negotiations += 1);
        let req =
            Request::new("POST", "/objects/batch").body(transport::chain_advert_body(adv));
        let resp = self.client.send(&req)?;
        if resp.status != 200 {
            return Err(status_error(
                resp.status,
                resp.get_header("retry-after"),
                format!("{}: POST /objects/batch -> {}", self.url(), resp.status),
            ));
        }
        let json = parse_json(&resp)?;
        let present = parse_oid_arr(&json, "present")?;
        let missing = parse_oid_arr(&json, "missing")?;
        let present_sizes: Vec<u64> = json
            .get("sizes")
            .and_then(|v| v.as_arr())
            .map(|a| a.iter().map(|v| v.as_u64().unwrap_or(0)).collect())
            .unwrap_or_default();
        let batch = BatchResponse {
            present,
            present_sizes,
            missing,
        };
        // A chain-aware server echoes protocol 2 and a per-chain depth
        // array; an older server answers the flat fields only, and the
        // push degrades to whole-object records (version skew rule).
        let chain_aware = json.get("protocol").and_then(|v| v.as_u64()) == Some(2);
        let have_depths = if chain_aware {
            json.get("chains")
                .and_then(|v| v.as_arr())
                .map(|a| {
                    a.iter()
                        .map(|c| {
                            c.get("have_depth").and_then(|v| v.as_usize()).unwrap_or(0)
                        })
                        .collect()
                })
                .unwrap_or_else(|| vec![0; adv.chains.len()])
        } else {
            vec![0; adv.chains.len()]
        };
        Ok(ChainNegotiation {
            batch,
            have_depths,
            chain_aware,
        })
    }

    fn send_pack_with_bases(
        &self,
        src: &LfsStore,
        plan: &DeltaPlan,
        threads: usize,
    ) -> Result<(PackStats, WireReport)> {
        let (spill_base, _tmp_guard) = self.staging_path("lfs/outgoing", "pack")?;
        let spill = tmp::unique_sibling(&spill_base);
        let built = pack::write_delta_pack_file(src, plan, threads, &spill)?;
        let result = self.send_spilled(&built, &spill);
        let _ = std::fs::remove_file(&spill);
        result
    }

    fn fetch_pack_into(
        &self,
        oids: &[Oid],
        dest: &LfsStore,
        threads: usize,
    ) -> Result<(PackStats, WireReport)> {
        self.fetch_pack_request(want_body(oids), dest, threads)
    }

    fn fetch_pack_with_chains(
        &self,
        adv: &ChainAdvert,
        dest: &LfsStore,
        threads: usize,
    ) -> Result<(PackStats, WireReport)> {
        // Same endpoint, protocol-2 body: the advert carries both the
        // want set and the chains this client holds prefixes of. A
        // chain-aware server plans deltas against those bases; an older
        // server reads only `want` and builds a flat v1 pack — the
        // claim/resume/verify loop below is identical either way.
        self.fetch_pack_request(transport::chain_advert_body(adv), dest, threads)
    }

    fn send_pack_from(
        &self,
        src: &LfsStore,
        oids: &[Oid],
        threads: usize,
    ) -> Result<(PackStats, WireReport)> {
        // Spill the pack to disk (streaming build), then stream the
        // file out; the pack bytes are never RAM-resident.
        let (spill_base, _tmp_guard) = self.staging_path("lfs/outgoing", "pack")?;
        let spill = tmp::unique_sibling(&spill_base);
        let built = pack::write_pack_file(src, oids, threads, &spill)?;
        let result = self.send_spilled(&built, &spill);
        let _ = std::fs::remove_file(&spill);
        result
    }

    fn get_object(&self, oid: &Oid) -> Result<Vec<u8>> {
        let resp = self
            .client
            .send(&Request::new("GET", &format!("/objects/{}", oid.to_hex())))?;
        if resp.status == 404 {
            bail!("lfs object {} not found on {}", oid.short(), self.url());
        }
        if resp.status != 200 {
            return Err(status_error(
                resp.status,
                resp.get_header("retry-after"),
                format!("{}: GET /objects/{} -> {}", self.url(), oid.short(), resp.status),
            ));
        }
        if Oid::of_bytes(&resp.body) != *oid {
            bail!("lfs object {} from {} failed its content hash", oid.short(), self.url());
        }
        Ok(resp.body)
    }

    fn put_object(&self, bytes: &[u8]) -> Result<()> {
        let oid = Oid::of_bytes(bytes);
        let req = Request::new("PUT", &format!("/objects/{}", oid.to_hex())).body(bytes.to_vec());
        let resp = self.client.send(&req)?;
        if resp.status != 200 {
            return Err(status_error(
                resp.status,
                resp.get_header("retry-after"),
                format!("{}: PUT /objects/{} -> {}", self.url(), oid.short(), resp.status),
            ));
        }
        Ok(())
    }
}
