//! HTTP client half of the remote transport (`git-theta serve` peer).
//!
//! Speaks a small LFS-batch-style protocol against
//! [`LfsServer`](super::server::LfsServer):
//!
//! * `POST /objects/batch` — one have/want negotiation round trip.
//! * `POST /packs` + `GET /packs/<id>` — the server assembles (and
//!   caches) a pack for a want set; the client downloads it, resuming
//!   an interrupted body with `Range: bytes=<k>-` from a partial file
//!   persisted under the staging directory.
//! * `HEAD`/`PUT /packs/<id>` — upload with `Content-Range` resume:
//!   the server persists whatever body prefix arrives before a
//!   connection dies, `HEAD` reports how much it holds, and the retry
//!   sends only the tail.
//! * `GET`/`PUT /objects/<oid>` — per-object fallback.
//!
//! Every pack is verified twice before anything is trusted: its id
//! must equal its trailing sha256, and `unpack_into` re-hashes every
//! object. A resumed splice that mixes a stale prefix with a rebuilt
//! tail therefore cannot corrupt a store — it fails verification and
//! the client falls back to one clean full download.

use super::batch::{self, BatchResponse};
use super::pack::{self, PackStats};
use super::transport::{RemoteTransport, WireReport};
use crate::gitcore::object::Oid;
use crate::gitcore::remote::{parse_json, parse_oid_arr, want_body};
use crate::util::http;
use anyhow::{bail, Context, Result};
use std::path::{Path, PathBuf};

/// Client handle for an `http://` LFS remote.
#[derive(Debug, Clone)]
pub struct HttpRemote {
    authority: String,
    url: String,
    /// Partial-download staging dir (resume persistence); `None`
    /// disables persistence but not transfers.
    staging: Option<PathBuf>,
}

impl HttpRemote {
    /// Parse the URL; `staging` (usually a repository's `.theta` dir)
    /// hosts partial pack downloads so an interrupted fetch resumes
    /// even across process restarts. URLs with a path component are
    /// rejected (the wire protocol is rooted at `/`).
    pub fn open(url: &str, staging: Option<&Path>) -> Result<HttpRemote> {
        http::require_rootless(url)?;
        Ok(HttpRemote {
            authority: http::authority_of(url)?,
            url: url.trim_end_matches('/').to_string(),
            staging: staging.map(|p| p.join("lfs/incoming")),
        })
    }

    /// The endpoint URL this remote talks to.
    pub fn url(&self) -> &str {
        &self.url
    }

    /// Send a request and require a complete response body.
    fn send(&self, req: http::Request) -> Result<http::Response> {
        let resp = http::roundtrip(&self.authority, &req)?;
        if !resp.complete {
            bail!("connection to {} interrupted mid-response", self.url);
        }
        Ok(resp)
    }

    fn partial_path(&self, id: &str) -> Option<PathBuf> {
        self.staging.as_ref().map(|d| d.join(id))
    }

    /// Persist a partial pack body for a later byte-range resume
    /// (write-then-rename with a unique temp name, so a crash never
    /// leaves a torn file and concurrent writers never share a path).
    fn persist_partial(&self, id: &str, bytes: &[u8]) -> Result<()> {
        static TMP_SEQ: std::sync::atomic::AtomicU64 = std::sync::atomic::AtomicU64::new(0);
        let path = match self.partial_path(id) {
            Some(p) => p,
            None => return Ok(()),
        };
        std::fs::create_dir_all(path.parent().unwrap())?;
        let seq = TMP_SEQ.fetch_add(1, std::sync::atomic::Ordering::Relaxed);
        let tmp = path.with_extension(format!("tmp{}-{seq}", std::process::id()));
        std::fs::write(&tmp, bytes)?;
        std::fs::rename(&tmp, &path).context("persisting partial pack")
    }

    fn drop_partial(&self, id: &str) {
        if let Some(path) = self.partial_path(id) {
            let _ = std::fs::remove_file(path);
        }
    }
}

impl RemoteTransport for HttpRemote {
    fn describe(&self) -> String {
        self.url.clone()
    }

    fn batch(&self, want: &[Oid]) -> Result<BatchResponse> {
        batch::record(|s| s.negotiations += 1);
        let req = http::Request::new("POST", "/objects/batch").body(want_body(want));
        let resp = self.send(req)?;
        if resp.status != 200 {
            bail!("{}: POST /objects/batch -> {}", self.url, resp.status);
        }
        let json = parse_json(&resp)?;
        let present = parse_oid_arr(&json, "present")?;
        let missing = parse_oid_arr(&json, "missing")?;
        let present_sizes: Vec<u64> = json
            .get("sizes")
            .and_then(|v| v.as_arr())
            .map(|a| a.iter().map(|v| v.as_u64().unwrap_or(0)).collect())
            .unwrap_or_default();
        Ok(BatchResponse {
            present,
            present_sizes,
            missing,
        })
    }

    fn fetch_pack_blob(&self, oids: &[Oid], _threads: usize) -> Result<(Vec<u8>, WireReport)> {
        // The server assembles (or reuses) the pack and reports its
        // identity + size; identical want sets yield identical ids, so
        // a retry after an interruption re-addresses the same pack.
        let resp = self.send(http::Request::new("POST", "/packs").body(want_body(oids)))?;
        if resp.status != 200 {
            bail!(
                "{}: POST /packs -> {}: {}",
                self.url,
                resp.status,
                String::from_utf8_lossy(&resp.body)
            );
        }
        let json = parse_json(&resp)?;
        let id = json
            .get("id")
            .and_then(|v| v.as_str())
            .context("/packs response missing id")?
            .to_string();
        let total = json
            .get("size")
            .and_then(|v| v.as_u64())
            .context("/packs response missing size")?;

        let mut prefix: Vec<u8> = Vec::new();
        if let Some(path) = self.partial_path(&id) {
            if let Ok(bytes) = std::fs::read(&path) {
                if bytes.len() as u64 <= total {
                    prefix = bytes;
                } else {
                    self.drop_partial(&id);
                }
            }
        }
        // A previous run may have persisted the complete pack just
        // before dying; verify and use it without touching the wire. A
        // full-length partial that fails verification is dropped here —
        // resuming from it would just ask the server for an empty tail.
        if prefix.len() as u64 == total {
            if pack::pack_id(&prefix) == id {
                self.drop_partial(&id);
                let report = WireReport {
                    wire_bytes: 0,
                    resumed_bytes: total,
                };
                return Ok((prefix, report));
            }
            self.drop_partial(&id);
            prefix.clear();
        }

        let mut attempt_full = false;
        loop {
            let offset = if attempt_full { 0 } else { prefix.len() as u64 };
            let mut req = http::Request::new("GET", &format!("/packs/{id}"));
            if offset > 0 {
                req = req.header("range", &format!("bytes={offset}-"));
            }
            let resp = http::roundtrip(&self.authority, &req)?;
            match resp.status {
                200 | 206 => {}
                404 => bail!("{} no longer has pack {id}", self.url),
                s => bail!("{}: GET /packs/{id} -> {s}", self.url),
            }
            let mut blob = if offset > 0 { prefix.clone() } else { Vec::new() };
            blob.extend_from_slice(&resp.body);
            if !resp.complete {
                // Mid-flight cut: keep every byte that made it across,
                // so the retry re-requests only the missing tail.
                self.persist_partial(&id, &blob)?;
                bail!(
                    "pack download from {} interrupted after {} of {total} bytes{}",
                    self.url,
                    blob.len(),
                    if self.staging.is_some() {
                        " (partial persisted; a retry resumes from it)"
                    } else {
                        ""
                    }
                );
            }
            if blob.len() as u64 == total && pack::pack_id(&blob) == id {
                self.drop_partial(&id);
                // The server-side pack cache is deliberately left in
                // place: a concurrent clone of the same tip addresses
                // the same content-hashed id, and deleting it here
                // would 404 that transfer mid-flight. Stale outgoing
                // packs are the server's to reap (ROADMAP).
                let report = WireReport {
                    wire_bytes: resp.body.len() as u64,
                    resumed_bytes: offset,
                };
                return Ok((blob, report));
            }
            // Verification failed: a stale partial spliced onto a
            // rebuilt pack, or in-flight corruption. Drop local state
            // and retry exactly once from scratch.
            self.drop_partial(&id);
            if attempt_full || offset == 0 {
                bail!("pack {id} from {} failed integrity verification", self.url);
            }
            attempt_full = true;
        }
    }

    fn send_pack_blob(
        &self,
        pack_id: &str,
        pack: &[u8],
        _threads: usize,
    ) -> Result<(PackStats, WireReport)> {
        let total = pack.len() as u64;
        // How much of this pack did an earlier, interrupted attempt
        // already deliver? The server persists partial bodies.
        let head = self.send(http::Request::new("HEAD", &format!("/packs/{pack_id}")))?;
        let mut offset = head
            .get_header("x-received")
            .and_then(|v| v.parse::<u64>().ok())
            .unwrap_or(0);
        if offset > total {
            // A foreign partial under our id (should be impossible —
            // ids are content hashes); clear it and start over.
            let _ = http::roundtrip(
                &self.authority,
                &http::Request::new("DELETE", &format!("/packs/{pack_id}")),
            );
            offset = 0;
        }
        for _attempt in 0..3 {
            let range = if offset == total {
                format!("bytes */{total}")
            } else {
                format!("bytes {offset}-{}/{total}", total - 1)
            };
            let wire = total - offset;
            let req = http::Request::new("PUT", &format!("/packs/{pack_id}"))
                .header("content-range", &range)
                .body(pack[offset as usize..].to_vec());
            let resp = http::roundtrip(&self.authority, &req).with_context(|| {
                format!(
                    "pack upload to {} interrupted ({} keeps the partial; a retry resumes)",
                    self.url, self.url
                )
            })?;
            if !resp.complete {
                bail!(
                    "pack upload to {} interrupted mid-response; a retry resumes from the \
                     server-side partial",
                    self.url
                );
            }
            match resp.status {
                200 => {
                    let json = parse_json(&resp)?;
                    let stats = PackStats {
                        objects: json.get("objects").and_then(|v| v.as_usize()).unwrap_or(0),
                        raw_bytes: json.get("raw_bytes").and_then(|v| v.as_u64()).unwrap_or(0),
                        packed_bytes: total,
                    };
                    let report = WireReport {
                        wire_bytes: wire,
                        resumed_bytes: offset,
                    };
                    return Ok((stats, report));
                }
                409 => {
                    // Our offset raced another writer (or a stale HEAD);
                    // the server tells us what it actually holds.
                    offset = resp
                        .get_header("x-received")
                        .and_then(|v| v.parse::<u64>().ok())
                        .unwrap_or(0)
                        .min(total);
                }
                422 => bail!(
                    "{} rejected pack {pack_id}: {}",
                    self.url,
                    String::from_utf8_lossy(&resp.body)
                ),
                s => bail!("{}: PUT /packs/{pack_id} -> {s}", self.url),
            }
        }
        bail!("pack upload to {} kept conflicting on its resume offset", self.url)
    }

    fn get_object(&self, oid: &Oid) -> Result<Vec<u8>> {
        let resp = self.send(http::Request::new("GET", &format!("/objects/{}", oid.to_hex())))?;
        if resp.status == 404 {
            bail!("lfs object {} not found on {}", oid.short(), self.url);
        }
        if resp.status != 200 {
            bail!("{}: GET /objects/{} -> {}", self.url, oid.short(), resp.status);
        }
        if Oid::of_bytes(&resp.body) != *oid {
            bail!("lfs object {} from {} failed its content hash", oid.short(), self.url);
        }
        Ok(resp.body)
    }

    fn put_object(&self, bytes: &[u8]) -> Result<()> {
        let oid = Oid::of_bytes(bytes);
        let req =
            http::Request::new("PUT", &format!("/objects/{}", oid.to_hex())).body(bytes.to_vec());
        let resp = self.send(req)?;
        if resp.status != 200 {
            bail!("{}: PUT /objects/{} -> {}", self.url, oid.short(), resp.status);
        }
        Ok(())
    }
}
