//! LFS pointer files.
//!
//! A pointer file replaces a large binary in version control (paper
//! §2.4): it records the spec version, the object's sha256, and its
//! size. Format mirrors Git LFS:
//!
//! ```text
//! version https://git-lfs.github.com/spec/v1
//! oid sha256:4d7a214614ab2935c943f9e0ff69d22eadbb8f32b1258daaa5e2ca24d17e2393
//! size 12345
//! ```

use crate::gitcore::object::Oid;
use anyhow::{bail, Context, Result};

/// The Git LFS pointer spec this implementation emits and accepts.
pub const SPEC_VERSION: &str = "https://git-lfs.github.com/spec/v1";

/// A parsed LFS pointer.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct Pointer {
    /// sha256 of the object the pointer stands in for.
    pub oid: Oid,
    /// Size of the object in bytes.
    pub size: u64,
}

impl Pointer {
    /// Build a pointer for an object of known oid and size.
    pub fn new(oid: Oid, size: u64) -> Pointer {
        Pointer { oid, size }
    }

    /// Serialize to pointer-file text.
    pub fn to_text(&self) -> String {
        format!(
            "version {SPEC_VERSION}\noid sha256:{}\nsize {}\n",
            self.oid, self.size
        )
    }

    /// Parse pointer-file text.
    pub fn parse(text: &str) -> Result<Pointer> {
        let mut version = None;
        let mut oid = None;
        let mut size = None;
        for line in text.lines() {
            let (key, val) = line
                .split_once(' ')
                .with_context(|| format!("malformed pointer line '{line}'"))?;
            match key {
                "version" => version = Some(val.to_string()),
                "oid" => {
                    let hex = val
                        .strip_prefix("sha256:")
                        .context("pointer oid must be sha256")?;
                    oid = Some(Oid::from_hex(hex)?);
                }
                "size" => size = Some(val.parse::<u64>().context("bad pointer size")?),
                _ => {} // forward-compatible
            }
        }
        let version = version.context("pointer missing version")?;
        if version != SPEC_VERSION {
            bail!("unsupported pointer spec '{version}'");
        }
        Ok(Pointer {
            oid: oid.context("pointer missing oid")?,
            size: size.context("pointer missing size")?,
        })
    }

    /// Heuristic: does this staged blob look like a pointer file?
    pub fn is_pointer(bytes: &[u8]) -> bool {
        bytes.len() < 400 && bytes.starts_with(b"version https://git-lfs")
    }

    /// The object oid of a blob, if the blob is a parseable pointer
    /// file. The one place pointer sniffing + parsing is combined, so
    /// hooks and prefetchers cannot drift apart.
    pub fn oid_of_blob(bytes: &[u8]) -> Option<Oid> {
        if !Self::is_pointer(bytes) {
            return None;
        }
        Pointer::parse(&String::from_utf8_lossy(bytes)).ok().map(|p| p.oid)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn roundtrip() {
        let p = Pointer::new(Oid::of_bytes(b"big model"), 123456789);
        let text = p.to_text();
        assert!(Pointer::is_pointer(text.as_bytes()));
        assert_eq!(Pointer::parse(&text).unwrap(), p);
    }

    #[test]
    fn rejects_malformed() {
        assert!(Pointer::parse("").is_err());
        assert!(Pointer::parse("version wrong\noid sha256:00\nsize 1\n").is_err());
        assert!(Pointer::parse(&format!(
            "version {SPEC_VERSION}\noid md5:abc\nsize 1\n"
        ))
        .is_err());
        assert!(Pointer::parse(&format!("version {SPEC_VERSION}\nsize 1\n")).is_err());
    }

    #[test]
    fn is_pointer_rejects_binaries() {
        assert!(!Pointer::is_pointer(&vec![0u8; 100]));
        assert!(!Pointer::is_pointer(&vec![b'v'; 1000]));
    }
}
