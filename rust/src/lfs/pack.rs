//! The Git-Theta packfile: many LFS objects in one integrity-checked blob.
//!
//! The per-object transfer loop in the seed negotiated and moved one
//! object per round trip, which collapses under the many-small-objects
//! workload the clean filter produces (one update object per changed
//! parameter group). A pack amortizes that: the sender assembles every
//! wanted object into a single blob, the receiver fans it back into its
//! store, and both halves parallelize per object via [`par`].
//!
//! Layout (all integers little-endian):
//!
//! ```text
//! header   "THP1" (4) | version u32 (4) | object count u64 (8)
//! records  count × { oid (32) | raw_len u64 | comp_len u64 | zstd bytes }
//! index    count × { oid (32) | record offset u64 }
//! trailer  index offset u64 | sha256 of everything above (32)
//! ```
//!
//! The trailing index lets a reader locate records without scanning, and
//! the trailing sha256 makes truncation or bit-rot anywhere in the pack
//! detectable before any object is admitted to a store. Each object is
//! additionally verified against its oid (sha256 of the raw bytes) on
//! unpack, so a pack can never silently install wrong content.

//! **Streaming:** packs are *pipelines*, not blobs. [`PackWriter`]
//! encodes objects incrementally into any `io::Write` (compress → hash
//! → index as it goes), so a pack spills to a file or straight into a
//! socket without ever being RAM-materialized; [`verify_pack_file`] +
//! [`unpack_file`] check and admit a pack from disk reading one record
//! window at a time. Peak heap is O(largest object + window), not
//! O(pack) — the property the transfer ablation's `TrackingAlloc`
//! counter locks. The buffered [`build_pack`] / [`unpack_into`] remain
//! as conveniences over the same code paths and produce byte-identical
//! packs.

use super::store::LfsStore;
use crate::gitcore::object::Oid;
use crate::util::par;
use anyhow::{bail, Context, Result};
use sha2::{Digest, Sha256};
use std::cell::RefCell;
use std::io::{BufReader, Read, Write};
use std::path::Path;

/// First four bytes of every pack.
pub const PACK_MAGIC: &[u8; 4] = b"THP1";
/// Current pack format version.
pub const PACK_VERSION: u32 = 1;

const HEADER_LEN: usize = 16; // magic + version + count
const TRAILER_LEN: usize = 40; // index offset + sha256
const INDEX_ENTRY_LEN: usize = 40; // oid + record offset
const RECORD_HEADER_LEN: usize = 48; // oid + raw_len + comp_len

/// zstd level for object payloads (matches the serializer default).
const PACK_ZSTD_LEVEL: i32 = 3;

/// Format limit on a single object's uncompressed size (4 GiB). Keeps a
/// crafted record's declared `raw_len` from driving a giant allocation
/// before decompression can fail.
pub const MAX_OBJECT_BYTES: u64 = 1 << 32;

/// Size summary of a pack build or apply.
#[derive(Debug, Clone, Copy, Default, PartialEq, Eq)]
pub struct PackStats {
    /// Objects carried by the pack.
    pub objects: usize,
    /// Total uncompressed payload bytes.
    pub raw_bytes: u64,
    /// Bytes of the pack blob itself (what moves over the wire).
    pub packed_bytes: u64,
}

/// Raw-byte window for the streaming encode/decode batches: how many
/// cumulative payload bytes may be in flight between the sequential
/// framing and the parallel compress/admit workers. Bounds peak heap
/// together with the largest single object.
const STREAM_WINDOW_BYTES: u64 = 32 << 20;

/// Streaming pack encoder: objects in, framed pack bytes out, with the
/// trailing index and checksum accumulated on the fly.
///
/// The writer never holds more than the object currently being framed:
/// the pack itself flows straight into `out` (a spill file, a socket,
/// or a `Vec` for the buffered [`build_pack`] path). The object count
/// is declared up front because the header carries it; [`PackWriter::finish`]
/// fails if the promise is broken.
pub struct PackWriter<W: Write> {
    out: W,
    hasher: Sha256,
    pos: u64,
    index: Vec<(Oid, u64)>,
    declared: u64,
    raw_bytes: u64,
}

impl<W: Write> PackWriter<W> {
    /// Start a pack that will carry exactly `objects` records.
    pub fn new(out: W, objects: u64) -> Result<PackWriter<W>> {
        let mut w = PackWriter {
            out,
            hasher: Sha256::new(),
            pos: 0,
            index: Vec::with_capacity(objects.min(1 << 20) as usize),
            declared: objects,
            raw_bytes: 0,
        };
        let mut header = [0u8; HEADER_LEN];
        header[..4].copy_from_slice(PACK_MAGIC);
        header[4..8].copy_from_slice(&PACK_VERSION.to_le_bytes());
        header[8..16].copy_from_slice(&objects.to_le_bytes());
        w.emit(&header)?;
        Ok(w)
    }

    /// Write bytes through the running checksum.
    fn emit(&mut self, bytes: &[u8]) -> Result<()> {
        self.hasher.update(bytes);
        self.out.write_all(bytes).context("writing pack stream")?;
        self.pos += bytes.len() as u64;
        Ok(())
    }

    /// Append one record whose payload the caller already compressed
    /// (the parallel-compression fan-in path).
    pub fn add_compressed(&mut self, oid: Oid, raw_len: u64, comp: &[u8]) -> Result<()> {
        if self.index.len() as u64 >= self.declared {
            bail!("pack writer: more objects added than declared");
        }
        if raw_len > MAX_OBJECT_BYTES {
            bail!("object {} exceeds the pack format's size limit", oid.short());
        }
        self.index.push((oid, self.pos));
        self.emit(&oid.0)?;
        self.emit(&raw_len.to_le_bytes())?;
        self.emit(&(comp.len() as u64).to_le_bytes())?;
        self.emit(comp)?;
        self.raw_bytes += raw_len;
        Ok(())
    }

    /// Compress and append one record.
    pub fn add_object(&mut self, oid: Oid, raw: &[u8]) -> Result<()> {
        let comp = zstd::bulk::compress(raw, PACK_ZSTD_LEVEL).context("pack compress")?;
        self.add_compressed(oid, raw.len() as u64, &comp)
    }

    /// Write the index + trailer and flush. Returns the finished
    /// pack's summary (its id is the trailing sha256, as always).
    pub fn finish(mut self) -> Result<BuiltPack> {
        if self.index.len() as u64 != self.declared {
            bail!(
                "pack writer: {} objects declared but {} added",
                self.declared,
                self.index.len()
            );
        }
        let index_offset = self.pos;
        // Move the index out so emit (&mut self) can run inside the loop.
        let index = std::mem::take(&mut self.index);
        for (oid, off) in &index {
            self.emit(&oid.0)?;
            self.emit(&off.to_le_bytes())?;
        }
        self.emit(&index_offset.to_le_bytes())?;
        let digest: [u8; 32] = self.hasher.finalize().into();
        self.out.write_all(&digest).context("writing pack trailer")?;
        self.out.flush().context("flushing pack stream")?;
        Ok(BuiltPack {
            id: crate::util::hex::encode(&digest),
            len: self.pos + 32,
            objects: index.len(),
            raw_bytes: self.raw_bytes,
        })
    }
}

/// Summary of a streamed pack build.
#[derive(Debug, Clone)]
pub struct BuiltPack {
    /// The pack's identity (hex of the trailing sha256).
    pub id: String,
    /// Total pack bytes written.
    pub len: u64,
    /// Records carried.
    pub objects: usize,
    /// Total uncompressed payload bytes.
    pub raw_bytes: u64,
}

/// Stream a pack holding `oids` (read from `store`) into `out`.
///
/// Duplicate oids are packed once. Object payloads are compressed in
/// parallel across `threads` workers in bounded windows; the framing
/// is written sequentially so the pack is deterministic (and therefore
/// byte-identical to [`build_pack`] of the same want set). Peak heap
/// is O(window), independent of the pack size.
pub fn write_pack_to<W: Write>(
    store: &LfsStore,
    oids: &[Oid],
    threads: usize,
    out: W,
) -> Result<BuiltPack> {
    let mut unique = oids.to_vec();
    unique.sort();
    unique.dedup();

    thread_local! {
        // Per-worker read buffer recycled across objects: with
        // `LfsStore::get_to` this drops one allocation + full copy per
        // object from the pack-assembly fan-in.
        static READ_SCRATCH: RefCell<Vec<u8>> = RefCell::new(Vec::new());
    }
    let mut writer = PackWriter::new(out, unique.len() as u64)?;
    // Window the compression fan-out: enough objects to keep `threads`
    // workers busy, but bounded so a huge want set never materializes
    // in RAM between compression and framing.
    let window_objects = threads.max(1) * 4;
    let mut start = 0usize;
    while start < unique.len() {
        let mut end = start;
        let mut window_bytes = 0u64;
        while end < unique.len()
            && (end - start) < window_objects
            && (end == start || window_bytes < STREAM_WINDOW_BYTES)
        {
            window_bytes += store.size_of(&unique[end]).unwrap_or(0);
            end += 1;
        }
        let batch = &unique[start..end];
        let blobs = par::try_par_map(batch, threads, |_, oid| -> Result<(u64, Vec<u8>)> {
            READ_SCRATCH.with(|buf| {
                let mut raw = buf.borrow_mut();
                store
                    .get_to(oid, &mut raw)
                    .with_context(|| format!("packing object {}", oid.short()))?;
                if raw.len() as u64 > MAX_OBJECT_BYTES {
                    bail!("object {} exceeds the pack format's size limit", oid.short());
                }
                let comp = zstd::bulk::compress(&raw, PACK_ZSTD_LEVEL).context("pack compress")?;
                Ok((raw.len() as u64, comp))
            })
        })?;
        for (oid, (raw_len, comp)) in batch.iter().zip(&blobs) {
            writer.add_compressed(*oid, *raw_len, comp)?;
        }
        start = end;
    }
    writer.finish()
}

/// Stream a pack for `oids` into a fresh file at `path` (parent
/// directories created). Returns the build summary; on error the
/// partial file is removed.
pub fn write_pack_file(
    store: &LfsStore,
    oids: &[Oid],
    threads: usize,
    path: &Path,
) -> Result<BuiltPack> {
    if let Some(parent) = path.parent() {
        std::fs::create_dir_all(parent)?;
    }
    let file = std::fs::File::create(path).context("creating pack spill file")?;
    match write_pack_to(store, oids, threads, std::io::BufWriter::new(file)) {
        Ok(built) => Ok(built),
        Err(e) => {
            let _ = std::fs::remove_file(path);
            Err(e)
        }
    }
}

/// Assemble a pack holding `oids` in memory (buffered convenience over
/// [`write_pack_to`]; byte-identical output).
pub fn build_pack(store: &LfsStore, oids: &[Oid], threads: usize) -> Result<Vec<u8>> {
    let mut out = Vec::new();
    write_pack_to(store, oids, threads, &mut out)?;
    Ok(out)
}

/// A validated view of a pack: the trailer checksum has been verified
/// and the index parsed, but records are not yet decompressed.
struct PackView {
    index: Vec<(Oid, usize)>,
    /// Where the index begins == where record data ends.
    records_end: usize,
}

fn parse(pack: &[u8]) -> Result<PackView> {
    if pack.len() < HEADER_LEN + TRAILER_LEN {
        bail!("pack truncated ({} bytes)", pack.len());
    }
    if &pack[..4] != PACK_MAGIC {
        bail!("pack: bad magic");
    }
    let version = u32::from_le_bytes(pack[4..8].try_into().unwrap());
    if version != PACK_VERSION {
        bail!("pack: unsupported version {version}");
    }
    let checksum_at = pack.len() - 32;
    let actual: [u8; 32] = Sha256::digest(&pack[..checksum_at]).into();
    if actual[..] != pack[checksum_at..] {
        bail!("pack checksum mismatch (corrupt trailer or content)");
    }
    // All length/offset fields come from the (checksummed) pack, but a
    // checksum only proves the sender wrote what we read — a malicious
    // sender can still write absurd values. Validate with overflow-safe
    // comparisons so a crafted pack yields Err, never a panic.
    let index_end = checksum_at - 8;
    let count = u64::from_le_bytes(pack[8..16].try_into().unwrap());
    if count > (index_end / INDEX_ENTRY_LEN) as u64 {
        bail!("pack declares more objects than it can hold");
    }
    let count = count as usize;
    let index_offset = u64::from_le_bytes(pack[checksum_at - 8..checksum_at].try_into().unwrap());
    if index_offset > index_end as u64 {
        bail!("pack index out of bounds");
    }
    let index_offset = index_offset as usize;
    if index_offset < HEADER_LEN || index_end - index_offset != count * INDEX_ENTRY_LEN {
        bail!("pack index out of bounds");
    }
    let mut index = Vec::with_capacity(count);
    for i in 0..count {
        let at = index_offset + i * INDEX_ENTRY_LEN;
        let oid = Oid(pack[at..at + 32].try_into().unwrap());
        let off = u64::from_le_bytes(pack[at + 32..at + 40].try_into().unwrap());
        let record_end = off.checked_add(RECORD_HEADER_LEN as u64);
        if off < HEADER_LEN as u64 || record_end.map_or(true, |e| e > index_offset as u64) {
            bail!("pack record offset out of bounds for {}", oid.short());
        }
        index.push((oid, off as usize));
    }
    Ok(PackView {
        index,
        records_end: index_offset,
    })
}

/// Slice the record at `off`, returning (oid, raw_len, compressed bytes).
fn record_at(pack: &[u8], off: usize, records_end: usize) -> Result<(Oid, u64, &[u8])> {
    let oid = Oid(pack[off..off + 32].try_into().unwrap());
    let raw_len = u64::from_le_bytes(pack[off + 32..off + 40].try_into().unwrap());
    let comp_len = u64::from_le_bytes(pack[off + 40..off + 48].try_into().unwrap());
    let start = off + RECORD_HEADER_LEN;
    // Overflow-safe: compare in u64 before narrowing.
    if comp_len > (records_end - start) as u64 {
        bail!("pack record for {} overruns the index", oid.short());
    }
    let comp_len = comp_len as usize;
    Ok((oid, raw_len, &pack[start..start + comp_len]))
}

/// The pack's identity: the hex of its trailing sha256.
///
/// Stable across rebuilds of the same content (pack assembly is
/// deterministic: sorted unique oids, fixed zstd level), which is what
/// lets an interrupted transfer re-address the *same* pack on retry
/// and resume from a byte offset. Anything too short to carry a
/// trailer ids as `"invalid"`; a corrupt-but-long-enough blob simply
/// won't match its re-computed checksum downstream.
pub fn pack_id(pack: &[u8]) -> String {
    if pack.len() < HEADER_LEN + TRAILER_LEN {
        return String::from("invalid");
    }
    crate::util::hex::encode(&pack[pack.len() - 32..])
}

/// List the (oid, raw size) of every object in a pack without
/// decompressing any payload. Verifies the trailer checksum.
pub fn pack_index(pack: &[u8]) -> Result<Vec<(Oid, u64)>> {
    let view = parse(pack)?;
    view.index
        .iter()
        .map(|&(oid, off)| {
            let (record_oid, raw_len, _) = record_at(pack, off, view.records_end)?;
            if record_oid != oid {
                bail!("pack index entry for {} points at a foreign record", oid.short());
            }
            Ok((oid, raw_len))
        })
        .collect()
}

/// Decompress, hash-verify, and store one record's payload. Shared by
/// the buffered and the streaming admit paths so the safety argument
/// (bomb guard, content-hash gate) lives in one place.
fn admit_record(store: &LfsStore, oid: Oid, raw_len: u64, comp: &[u8]) -> Result<u64> {
    if raw_len > MAX_OBJECT_BYTES {
        bail!("pack object {} declares an implausible size", oid.short());
    }
    // Stream-decompress with a hard read limit: the output buffer
    // grows with actual data (a crafted `raw_len` cannot force a
    // giant up-front allocation) and a decompression bomb stops one
    // byte past the declared size.
    let mut raw = Vec::with_capacity((raw_len as usize).min(16 << 20));
    let decoder = zstd::stream::Decoder::new(comp)
        .with_context(|| format!("pack decompress of {}", oid.short()))?;
    decoder
        .take(raw_len + 1)
        .read_to_end(&mut raw)
        .with_context(|| format!("pack decompress of {}", oid.short()))?;
    if raw.len() as u64 != raw_len {
        bail!("pack object {} has wrong length", oid.short());
    }
    if Oid::of_bytes(&raw) != oid {
        bail!("pack object {} failed its content hash", oid.short());
    }
    store.put(&raw)?;
    Ok(raw_len)
}

/// Verify, decompress, and store every object in `pack` (store fan-in).
///
/// Objects are admitted only after their raw bytes re-hash to the oid
/// the pack claims, so a damaged pack can never poison a store. Workers
/// fan objects in concurrently; [`LfsStore::put`] is atomic.
pub fn unpack_into(store: &LfsStore, pack: &[u8], threads: usize) -> Result<PackStats> {
    let view = parse(pack)?;
    let sizes = par::try_par_map(&view.index, threads, |_, &(oid, off)| -> Result<u64> {
        let (record_oid, raw_len, comp) = record_at(pack, off, view.records_end)?;
        if record_oid != oid {
            bail!("pack index entry for {} points at a foreign record", oid.short());
        }
        admit_record(store, oid, raw_len, comp)
    })?;
    Ok(PackStats {
        objects: sizes.len(),
        raw_bytes: sizes.iter().sum(),
        packed_bytes: pack.len() as u64,
    })
}

/// A reader wrapper that feeds everything it reads (up to a hashing
/// limit — the trailer digest must not hash itself) through a running
/// sha256 while tracking the stream position.
struct HashScan<R: Read> {
    r: R,
    hasher: Sha256,
    pos: u64,
    hash_limit: u64,
}

impl<R: Read> HashScan<R> {
    fn read_exact(&mut self, buf: &mut [u8]) -> Result<()> {
        self.r.read_exact(buf).context("pack file truncated")?;
        let remain = self.hash_limit.saturating_sub(self.pos);
        let h = (remain.min(buf.len() as u64)) as usize;
        self.hasher.update(&buf[..h]);
        self.pos += buf.len() as u64;
        Ok(())
    }

    /// Read-and-discard `n` bytes (they still feed the checksum).
    fn skip(&mut self, mut n: u64) -> Result<()> {
        let mut chunk = [0u8; 64 * 1024];
        while n > 0 {
            let want = n.min(chunk.len() as u64) as usize;
            self.read_exact(&mut chunk[..want])?;
            n -= want as u64;
        }
        Ok(())
    }
}

/// Outcome of a streaming pack-file verification.
#[derive(Debug, Clone)]
pub struct PackCheck {
    /// The pack's identity (hex of the trailing sha256).
    pub id: String,
    /// File length in bytes.
    pub len: u64,
    /// Records the pack carries.
    pub objects: u64,
}

/// Verify a pack **file** end to end — structure, index, and trailing
/// checksum — in one streaming pass with O(1) memory (payloads are
/// hashed and discarded, never decompressed). Nothing is admitted to
/// any store; this is the gate the streaming receive path runs before
/// [`unpack_file`] touches a store, so a corrupt pack admits nothing.
pub fn verify_pack_file(path: &Path) -> Result<PackCheck> {
    let len = std::fs::metadata(path).context("pack file missing")?.len();
    if len < (HEADER_LEN + TRAILER_LEN) as u64 {
        bail!("pack truncated ({len} bytes)");
    }
    let file = std::fs::File::open(path).context("opening pack file")?;
    let mut scan = HashScan {
        r: BufReader::with_capacity(64 * 1024, file),
        hasher: Sha256::new(),
        pos: 0,
        hash_limit: len - 32,
    };

    let mut header = [0u8; HEADER_LEN];
    scan.read_exact(&mut header)?;
    if &header[..4] != PACK_MAGIC {
        bail!("pack: bad magic");
    }
    let version = u32::from_le_bytes(header[4..8].try_into().unwrap());
    if version != PACK_VERSION {
        bail!("pack: unsupported version {version}");
    }
    let count = u64::from_le_bytes(header[8..16].try_into().unwrap());
    let index_bytes = count
        .checked_mul(INDEX_ENTRY_LEN as u64)
        .filter(|&b| b <= len - (HEADER_LEN + TRAILER_LEN) as u64)
        .with_context(|| "pack declares more objects than it can hold".to_string())?;
    let index_offset = len - TRAILER_LEN as u64 - index_bytes;

    // Walk the records region, hashing payloads without decompressing.
    let mut records: Vec<(Oid, u64)> = Vec::with_capacity(count.min(1 << 20) as usize);
    let mut rec_header = [0u8; RECORD_HEADER_LEN];
    while scan.pos < index_offset {
        if index_offset - scan.pos < RECORD_HEADER_LEN as u64 {
            bail!("pack records overrun the index");
        }
        let off = scan.pos;
        scan.read_exact(&mut rec_header)?;
        let oid = Oid(rec_header[..32].try_into().unwrap());
        let raw_len = u64::from_le_bytes(rec_header[32..40].try_into().unwrap());
        let comp_len = u64::from_le_bytes(rec_header[40..48].try_into().unwrap());
        if raw_len > MAX_OBJECT_BYTES {
            bail!("pack object {} declares an implausible size", oid.short());
        }
        if comp_len > index_offset - scan.pos {
            bail!("pack record for {} overruns the index", oid.short());
        }
        scan.skip(comp_len)?;
        records.push((oid, off));
    }
    if records.len() as u64 != count {
        bail!(
            "pack declares {count} objects but carries {}",
            records.len()
        );
    }

    // The index must mirror the records we just walked, in order.
    let mut entry = [0u8; INDEX_ENTRY_LEN];
    for (oid, off) in &records {
        scan.read_exact(&mut entry)?;
        let idx_oid = Oid(entry[..32].try_into().unwrap());
        let idx_off = u64::from_le_bytes(entry[32..40].try_into().unwrap());
        if idx_oid != *oid || idx_off != *off {
            bail!("pack index entry for {} points at a foreign record", idx_oid.short());
        }
    }
    let mut tail = [0u8; 8];
    scan.read_exact(&mut tail)?;
    if u64::from_le_bytes(tail) != index_offset {
        bail!("pack index out of bounds");
    }

    let digest: [u8; 32] = scan.hasher.finalize().into();
    let mut trailer = [0u8; 32];
    scan.r
        .read_exact(&mut trailer)
        .context("pack file truncated")?;
    if digest != trailer {
        bail!("pack checksum mismatch (corrupt trailer or content)");
    }
    Ok(PackCheck {
        id: crate::util::hex::encode(&trailer),
        len,
        objects: count,
    })
}

/// Verify a pack file, then decompress + admit its objects reading one
/// bounded window of records at a time (streaming fan-in).
///
/// The checksum pass runs first and touches no store, so a corrupt
/// pack admits nothing — same guarantee as the buffered
/// [`unpack_into`], with peak heap O(largest object + window) instead
/// of O(pack). Callers that already ran [`verify_pack_file`] (the
/// transfer paths, which also need the id) should pass its result to
/// [`unpack_verified`] instead of paying a second full-file hash pass.
pub fn unpack_file(path: &Path, store: &LfsStore, threads: usize) -> Result<PackStats> {
    let check = verify_pack_file(path)?;
    unpack_verified(path, store, threads, &check)
}

/// Decompress + admit a pack file that [`verify_pack_file`] has
/// already vouched for; `check` must come from that verification of
/// this same file. Each record still re-hashes to its oid before
/// admission, so even a file swapped between the passes cannot poison
/// the store — it just fails here.
pub fn unpack_verified(
    path: &Path,
    store: &LfsStore,
    threads: usize,
    check: &PackCheck,
) -> Result<PackStats> {
    let file = std::fs::File::open(path).context("opening pack file")?;
    let mut r = BufReader::with_capacity(64 * 1024, file);

    let mut header = [0u8; HEADER_LEN];
    r.read_exact(&mut header).context("pack file truncated")?;

    let window_objects = threads.max(1) * 4;
    let mut window: Vec<(Oid, u64, Vec<u8>)> = Vec::with_capacity(window_objects);
    let mut window_bytes = 0u64;
    let mut raw_total = 0u64;
    let mut rec_header = [0u8; RECORD_HEADER_LEN];
    let flush = |window: &mut Vec<(Oid, u64, Vec<u8>)>, raw_total: &mut u64| -> Result<()> {
        let sizes = par::try_par_map(window.as_slice(), threads, |_, (oid, raw_len, comp)| {
            admit_record(store, *oid, *raw_len, comp)
        })?;
        *raw_total += sizes.iter().sum::<u64>();
        window.clear();
        Ok(())
    };
    for _ in 0..check.objects {
        r.read_exact(&mut rec_header).context("pack file truncated")?;
        let oid = Oid(rec_header[..32].try_into().unwrap());
        let raw_len = u64::from_le_bytes(rec_header[32..40].try_into().unwrap());
        let comp_len = u64::from_le_bytes(rec_header[40..48].try_into().unwrap());
        // verify_pack_file bounded these already; re-clamp defensively
        // in case the file changed between the two passes.
        if comp_len > check.len || raw_len > MAX_OBJECT_BYTES {
            bail!("pack record for {} changed between passes", oid.short());
        }
        let mut comp = vec![0u8; comp_len as usize];
        r.read_exact(&mut comp).context("pack file truncated")?;
        window_bytes += comp_len + raw_len;
        window.push((oid, raw_len, comp));
        if window.len() >= window_objects || window_bytes >= STREAM_WINDOW_BYTES {
            flush(&mut window, &mut raw_total)?;
            window_bytes = 0;
        }
    }
    flush(&mut window, &mut raw_total)?;
    Ok(PackStats {
        objects: check.objects as usize,
        raw_bytes: raw_total,
        packed_bytes: check.len,
    })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::tmp::TempDir;

    fn store_with(td: &TempDir, payloads: &[&[u8]]) -> (LfsStore, Vec<Oid>) {
        let store = LfsStore::open(td.path());
        let oids = payloads
            .iter()
            .map(|p| store.put(p).unwrap().0)
            .collect();
        (store, oids)
    }

    #[test]
    fn roundtrip_and_dedup() {
        let td_a = TempDir::new("pack-a").unwrap();
        let td_b = TempDir::new("pack-b").unwrap();
        let (a, oids) = store_with(&td_a, &[b"alpha", b"beta", &[0u8; 10_000]]);
        let b = LfsStore::open(td_b.path());

        // Duplicates in the want list pack once.
        let doubled: Vec<Oid> = oids.iter().chain(oids.iter()).copied().collect();
        let pack = build_pack(&a, &doubled, 2).unwrap();
        assert_eq!(pack_index(&pack).unwrap().len(), 3);

        let stats = unpack_into(&b, &pack, 2).unwrap();
        assert_eq!(stats.objects, 3);
        assert_eq!(stats.raw_bytes, 5 + 4 + 10_000);
        assert_eq!(stats.packed_bytes, pack.len() as u64);
        for oid in &oids {
            assert_eq!(b.get(oid).unwrap(), a.get(oid).unwrap());
        }
    }

    #[test]
    fn empty_pack_is_valid() {
        let td = TempDir::new("pack-empty").unwrap();
        let (store, _) = store_with(&td, &[]);
        let pack = build_pack(&store, &[], 4).unwrap();
        assert_eq!(pack.len(), HEADER_LEN + TRAILER_LEN);
        assert!(pack_index(&pack).unwrap().is_empty());
        assert_eq!(unpack_into(&store, &pack, 4).unwrap().objects, 0);
    }

    #[test]
    fn every_flipped_byte_is_detected() {
        let td = TempDir::new("pack-flip").unwrap();
        let (store, oids) = store_with(&td, &[b"some weights", b"more weights"]);
        let pack = build_pack(&store, &oids, 1).unwrap();
        let td2 = TempDir::new("pack-flip2").unwrap();
        let dst = LfsStore::open(td2.path());
        // Flip a byte in each region: header, record payload, index, trailer.
        for at in [2usize, HEADER_LEN + 40, pack.len() - 50, pack.len() - 1] {
            let mut bad = pack.clone();
            bad[at] ^= 0xff;
            assert!(unpack_into(&dst, &bad, 1).is_err(), "flip at {at} undetected");
        }
        // Truncation anywhere is detected too.
        assert!(unpack_into(&dst, &pack[..pack.len() - 7], 1).is_err());
        assert!(unpack_into(&dst, &pack[..10], 1).is_err());
    }

    #[test]
    fn pack_id_is_deterministic_and_content_bound() {
        let td = TempDir::new("pack-id").unwrap();
        let (store, oids) = store_with(&td, &[b"w1", b"w2"]);
        let a = build_pack(&store, &oids, 1).unwrap();
        let b = build_pack(&store, &oids, 2).unwrap();
        assert_eq!(a, b, "pack assembly must be deterministic");
        assert_eq!(pack_id(&a), pack_id(&b));
        assert_eq!(pack_id(&a).len(), 64);
        let (_, more) = store_with(&td, &[b"w3"]);
        let c = build_pack(&store, &more, 1).unwrap();
        assert_ne!(pack_id(&a), pack_id(&c));
        assert_eq!(pack_id(&a[..10]), "invalid");
    }

    #[test]
    fn missing_source_object_fails_build() {
        let td = TempDir::new("pack-miss").unwrap();
        let (store, _) = store_with(&td, &[b"x"]);
        let ghost = Oid::of_bytes(b"never stored");
        assert!(build_pack(&store, &[ghost], 1).is_err());
    }

    #[test]
    fn streamed_pack_is_byte_identical_to_buffered() {
        let td = TempDir::new("pack-stream").unwrap();
        let (store, oids) =
            store_with(&td, &[b"alpha", b"beta", &[5u8; 20_000], b"delta", &[9u8; 3]]);
        let buffered = build_pack(&store, &oids, 1).unwrap();

        let td2 = TempDir::new("pack-stream2").unwrap();
        let path = td2.join("spill.pack");
        let built = write_pack_file(&store, &oids, 2, &path).unwrap();
        let from_file = std::fs::read(&path).unwrap();
        assert_eq!(from_file, buffered, "stream and buffer paths must agree byte-for-byte");
        assert_eq!(built.len, buffered.len() as u64);
        assert_eq!(built.id, pack_id(&buffered));
        assert_eq!(built.objects, 5);
        assert_eq!(built.raw_bytes, 5 + 4 + 20_000 + 5 + 3);
    }

    #[test]
    fn verify_and_unpack_file_roundtrip() {
        let td = TempDir::new("pack-vf").unwrap();
        let (store, oids) = store_with(&td, &[b"one", b"two", &[3u8; 5000]]);
        let td_spill = TempDir::new("pack-vf-spill").unwrap();
        let path = td_spill.join("p.pack");
        let built = write_pack_file(&store, &oids, 2, &path).unwrap();

        let check = verify_pack_file(&path).unwrap();
        assert_eq!(check.id, built.id);
        assert_eq!(check.len, built.len);
        assert_eq!(check.objects, 3);

        let td_dst = TempDir::new("pack-vf-dst").unwrap();
        let dst = LfsStore::open(td_dst.path());
        let stats = unpack_file(&path, &dst, 2).unwrap();
        assert_eq!(stats.objects, 3);
        assert_eq!(stats.raw_bytes, 3 + 3 + 5000);
        assert_eq!(stats.packed_bytes, built.len);
        for oid in &oids {
            assert_eq!(dst.get(oid).unwrap(), store.get(oid).unwrap());
        }
    }

    #[test]
    fn corrupt_or_truncated_file_admits_nothing() {
        let td = TempDir::new("pack-corrupt").unwrap();
        let (store, oids) = store_with(&td, &[b"weights-a", b"weights-b", &[7u8; 4000]]);
        let td_spill = TempDir::new("pack-corrupt-spill").unwrap();
        let good = td_spill.join("good.pack");
        write_pack_file(&store, &oids, 1, &good).unwrap();
        let bytes = std::fs::read(&good).unwrap();

        let td_dst = TempDir::new("pack-corrupt-dst").unwrap();
        let dst = LfsStore::open(td_dst.path());
        // Flip a byte in each region, truncate at several points: every
        // damage mode must fail verification and admit nothing.
        for at in [2usize, HEADER_LEN + 40, bytes.len() - 50, bytes.len() - 1] {
            let mut bad = bytes.clone();
            bad[at] ^= 0xff;
            let path = td_spill.join("bad.pack");
            std::fs::write(&path, &bad).unwrap();
            assert!(unpack_file(&path, &dst, 2).is_err(), "flip at {at} undetected");
            assert!(dst.list().unwrap().is_empty(), "flip at {at} admitted objects");
        }
        for keep in [10usize, bytes.len() - 7, bytes.len() - 33] {
            let path = td_spill.join("short.pack");
            std::fs::write(&path, &bytes[..keep]).unwrap();
            assert!(unpack_file(&path, &dst, 1).is_err(), "truncation at {keep} undetected");
            assert!(dst.list().unwrap().is_empty());
        }
    }

    #[test]
    fn writer_enforces_declared_count() {
        let td = TempDir::new("pack-count").unwrap();
        let (_store, _) = store_with(&td, &[]);
        // Fewer objects than declared → finish fails.
        let mut out = Vec::new();
        let w = PackWriter::new(&mut out, 2).unwrap();
        assert!(w.finish().is_err());
        // More than declared → add fails.
        let mut out = Vec::new();
        let mut w = PackWriter::new(&mut out, 0).unwrap();
        assert!(w.add_object(Oid::of_bytes(b"x"), b"x").is_err());
    }
}
