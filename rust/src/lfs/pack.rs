//! The Git-Theta packfile: many LFS objects in one integrity-checked blob.
//!
//! The per-object transfer loop in the seed negotiated and moved one
//! object per round trip, which collapses under the many-small-objects
//! workload the clean filter produces (one update object per changed
//! parameter group). A pack amortizes that: the sender assembles every
//! wanted object into a single blob, the receiver fans it back into its
//! store, and both halves parallelize per object via [`par`].
//!
//! Layout (all integers little-endian):
//!
//! ```text
//! header   "THP1" (4) | version u32 (4) | object count u64 (8)
//! records  count × { oid (32) | raw_len u64 | comp_len u64 | zstd bytes }
//! index    count × { oid (32) | record offset u64 }
//! trailer  index offset u64 | sha256 of everything above (32)
//! ```
//!
//! The trailing index lets a reader locate records without scanning, and
//! the trailing sha256 makes truncation or bit-rot anywhere in the pack
//! detectable before any object is admitted to a store. Each object is
//! additionally verified against its oid (sha256 of the raw bytes) on
//! unpack, so a pack can never silently install wrong content.

//! **Streaming:** packs are *pipelines*, not blobs. [`PackWriter`]
//! encodes objects incrementally into any `io::Write` (compress → hash
//! → index as it goes), so a pack spills to a file or straight into a
//! socket without ever being RAM-materialized; [`verify_pack_file`] +
//! [`unpack_file`] check and admit a pack from disk reading one record
//! window at a time. Peak heap is O(largest object + window), not
//! O(pack) — the property the transfer ablation's `TrackingAlloc`
//! counter locks. The buffered [`build_pack`] / [`unpack_into`] remain
//! as conveniences over the same code paths and produce byte-identical
//! packs.

//! **Deltas (format v2):** a [`PACK_VERSION_DELTA`] pack may carry two
//! extra record kinds alongside full objects — [`KIND_REF`], a
//! content-defined-chunking delta against a full record travelling
//! earlier in the *same* pack (a shared base is emitted once and later
//! records reference it by oid), and [`KIND_STORE`], a delta against a
//! base the *receiver* already holds (proven present during chain
//! negotiation). The record kind rides the high byte of the on-disk
//! `raw_len` field (real lengths are capped at 2³² by
//! [`MAX_OBJECT_BYTES`]), so v1 packs are bit-for-bit unchanged and a
//! plan with no deltas still writes a v1 pack. A delta payload is the
//! 32-byte base oid followed by the zstd-compressed [`delta`] ops
//! stream; resolution on unpack is O(1) memory over the
//! already-admitted records and the receiving store, and every
//! reconstructed object still re-hashes to its oid before admission.
//!
//! [`delta`]: super::delta

use super::store::LfsStore;
use crate::gitcore::object::Oid;
use crate::util::par;
use anyhow::{bail, Context, Result};
use sha2::{Digest, Sha256};
use std::cell::RefCell;
use std::collections::{HashMap, HashSet};
use std::io::{BufReader, Read, Write};
use std::path::Path;
use std::sync::Arc;

/// First four bytes of every pack.
pub const PACK_MAGIC: &[u8; 4] = b"THP1";
/// Current pack format version.
pub const PACK_VERSION: u32 = 1;
/// Pack format version that may carry delta records. Writers only use
/// it when a plan actually holds deltas, so flat transfers keep
/// producing version-1 packs older peers can read.
pub const PACK_VERSION_DELTA: u32 = 2;

/// Record kind: a whole zstd-compressed object (the only kind in v1).
pub const KIND_FULL: u8 = 0;
/// Record kind: delta whose base is a full record earlier in the same
/// pack (shared-base reference).
pub const KIND_REF: u8 = 1;
/// Record kind: delta whose base lives in the receiver's store,
/// negotiated present before the pack was built.
pub const KIND_STORE: u8 = 2;

/// Pack the record kind into the high byte of the on-disk `raw_len`
/// field. Safe because [`MAX_OBJECT_BYTES`] caps true lengths at 2³²,
/// and kind 0 leaves v1 records byte-identical.
fn encode_len(kind: u8, raw_len: u64) -> u64 {
    ((kind as u64) << 56) | raw_len
}

/// Split an on-disk length field into (kind, raw_len).
fn decode_len(field: u64) -> (u8, u64) {
    ((field >> 56) as u8, field & ((1u64 << 56) - 1))
}

const HEADER_LEN: usize = 16; // magic + version + count
const TRAILER_LEN: usize = 40; // index offset + sha256
const INDEX_ENTRY_LEN: usize = 40; // oid + record offset
const RECORD_HEADER_LEN: usize = 48; // oid + raw_len + comp_len

/// zstd level for object payloads (matches the serializer default).
const PACK_ZSTD_LEVEL: i32 = 3;

/// Format limit on a single object's uncompressed size (4 GiB). Keeps a
/// crafted record's declared `raw_len` from driving a giant allocation
/// before decompression can fail.
pub const MAX_OBJECT_BYTES: u64 = 1 << 32;

/// Size summary of a pack build or apply.
#[derive(Debug, Clone, Copy, Default, PartialEq, Eq)]
pub struct PackStats {
    /// Objects carried by the pack.
    pub objects: usize,
    /// Total uncompressed payload bytes.
    pub raw_bytes: u64,
    /// Bytes of the pack blob itself (what moves over the wire).
    pub packed_bytes: u64,
    /// Objects that arrived as delta records ([`KIND_REF`] /
    /// [`KIND_STORE`]) rather than whole payloads. Counted on the
    /// *apply* side, so a receiver can report delta savings without
    /// trusting the sender's plan.
    pub delta_objects: usize,
}

/// Raw-byte window for the streaming encode/decode batches: how many
/// cumulative payload bytes may be in flight between the sequential
/// framing and the parallel compress/admit workers. Bounds peak heap
/// together with the largest single object.
const STREAM_WINDOW_BYTES: u64 = 32 << 20;

/// Streaming pack encoder: objects in, framed pack bytes out, with the
/// trailing index and checksum accumulated on the fly.
///
/// The writer never holds more than the object currently being framed:
/// the pack itself flows straight into `out` (a spill file, a socket,
/// or a `Vec` for the buffered [`build_pack`] path). The object count
/// is declared up front because the header carries it; [`PackWriter::finish`]
/// fails if the promise is broken.
pub struct PackWriter<W: Write> {
    out: W,
    hasher: Sha256,
    pos: u64,
    index: Vec<(Oid, u64)>,
    declared: u64,
    raw_bytes: u64,
    version: u32,
}

impl<W: Write> PackWriter<W> {
    /// Start a pack that will carry exactly `objects` records.
    pub fn new(out: W, objects: u64) -> Result<PackWriter<W>> {
        PackWriter::new_versioned(out, objects, PACK_VERSION)
    }

    /// Start a pack in an explicit format version: [`PACK_VERSION`] for
    /// flat packs, [`PACK_VERSION_DELTA`] when delta records follow.
    pub fn new_versioned(out: W, objects: u64, version: u32) -> Result<PackWriter<W>> {
        if version != PACK_VERSION && version != PACK_VERSION_DELTA {
            bail!("pack: unsupported version {version}");
        }
        let mut w = PackWriter {
            out,
            hasher: Sha256::new(),
            pos: 0,
            index: Vec::with_capacity(objects.min(1 << 20) as usize),
            declared: objects,
            raw_bytes: 0,
            version,
        };
        let mut header = [0u8; HEADER_LEN];
        header[..4].copy_from_slice(PACK_MAGIC);
        header[4..8].copy_from_slice(&version.to_le_bytes());
        header[8..16].copy_from_slice(&objects.to_le_bytes());
        w.emit(&header)?;
        Ok(w)
    }

    /// Write bytes through the running checksum.
    fn emit(&mut self, bytes: &[u8]) -> Result<()> {
        self.hasher.update(bytes);
        self.out.write_all(bytes).context("writing pack stream")?;
        self.pos += bytes.len() as u64;
        Ok(())
    }

    /// Append one record whose payload the caller already compressed
    /// (the parallel-compression fan-in path).
    pub fn add_compressed(&mut self, oid: Oid, raw_len: u64, comp: &[u8]) -> Result<()> {
        if self.index.len() as u64 >= self.declared {
            bail!("pack writer: more objects added than declared");
        }
        if raw_len > MAX_OBJECT_BYTES {
            bail!("object {} exceeds the pack format's size limit", oid.short());
        }
        self.index.push((oid, self.pos));
        self.emit(&oid.0)?;
        self.emit(&raw_len.to_le_bytes())?;
        self.emit(&(comp.len() as u64).to_le_bytes())?;
        self.emit(comp)?;
        self.raw_bytes += raw_len;
        Ok(())
    }

    /// Compress and append one record.
    pub fn add_object(&mut self, oid: Oid, raw: &[u8]) -> Result<()> {
        let comp = zstd::bulk::compress(raw, PACK_ZSTD_LEVEL).context("pack compress")?;
        self.add_compressed(oid, raw.len() as u64, &comp)
    }

    /// Append one delta record: `oid` reconstructs to `raw_len` bytes
    /// by replaying the zstd-compressed ops in `ops_comp` against
    /// `base`. Only valid in a [`PACK_VERSION_DELTA`] pack; `kind` must
    /// be [`KIND_REF`] or [`KIND_STORE`].
    pub fn add_delta(
        &mut self,
        oid: Oid,
        kind: u8,
        raw_len: u64,
        base: &Oid,
        ops_comp: &[u8],
    ) -> Result<()> {
        if self.version < PACK_VERSION_DELTA {
            bail!("pack writer: delta records need a version-{PACK_VERSION_DELTA} pack");
        }
        if kind != KIND_REF && kind != KIND_STORE {
            bail!("pack writer: invalid delta kind {kind}");
        }
        if self.index.len() as u64 >= self.declared {
            bail!("pack writer: more objects added than declared");
        }
        if raw_len > MAX_OBJECT_BYTES {
            bail!("object {} exceeds the pack format's size limit", oid.short());
        }
        self.index.push((oid, self.pos));
        self.emit(&oid.0)?;
        self.emit(&encode_len(kind, raw_len).to_le_bytes())?;
        self.emit(&((32 + ops_comp.len()) as u64).to_le_bytes())?;
        self.emit(&base.0)?;
        self.emit(ops_comp)?;
        self.raw_bytes += raw_len;
        Ok(())
    }

    /// Write the index + trailer and flush. Returns the finished
    /// pack's summary (its id is the trailing sha256, as always).
    pub fn finish(mut self) -> Result<BuiltPack> {
        if self.index.len() as u64 != self.declared {
            bail!(
                "pack writer: {} objects declared but {} added",
                self.declared,
                self.index.len()
            );
        }
        let index_offset = self.pos;
        // Move the index out so emit (&mut self) can run inside the loop.
        let index = std::mem::take(&mut self.index);
        for (oid, off) in &index {
            self.emit(&oid.0)?;
            self.emit(&off.to_le_bytes())?;
        }
        self.emit(&index_offset.to_le_bytes())?;
        let digest: [u8; 32] = self.hasher.finalize().into();
        self.out.write_all(&digest).context("writing pack trailer")?;
        self.out.flush().context("flushing pack stream")?;
        Ok(BuiltPack {
            id: crate::util::hex::encode(&digest),
            len: self.pos + 32,
            objects: index.len(),
            raw_bytes: self.raw_bytes,
        })
    }
}

/// Summary of a streamed pack build.
#[derive(Debug, Clone)]
pub struct BuiltPack {
    /// The pack's identity (hex of the trailing sha256).
    pub id: String,
    /// Total pack bytes written.
    pub len: u64,
    /// Records carried.
    pub objects: usize,
    /// Total uncompressed payload bytes.
    pub raw_bytes: u64,
}

/// One planned delta record: `oid` ships as CDC ops against `base`.
#[derive(Debug, Clone)]
pub struct DeltaRecord {
    /// Object being shipped.
    pub oid: Oid,
    /// Base object the ops replay against.
    pub base: Oid,
    /// [`KIND_REF`] (base travels as a full record in the same pack)
    /// or [`KIND_STORE`] (base already held by the receiver).
    pub kind: u8,
    /// Reconstructed length in bytes.
    pub raw_len: u64,
    /// zstd-compressed [`super::delta`] ops stream.
    pub ops_comp: Vec<u8>,
}

impl DeltaRecord {
    /// Wire bytes this record occupies in a v2 pack: the 48-byte
    /// record header, the 32-byte base oid, and the compressed ops.
    pub fn wire_cost(&self) -> u64 {
        48 + 32 + self.ops_comp.len() as u64
    }
}

/// Wire bytes `oid` would occupy as a full record: the 48-byte header
/// plus its payload compressed at the pack's zstd level. The delta
/// planner's worth-it gate promises every kept delta undercuts this
/// *compressed* cost by at least 10% — never a comparison against the
/// raw object length; `tests/pack_format.rs` audits that promise.
pub fn full_record_cost(store: &LfsStore, oid: &Oid) -> Result<u64> {
    let raw = store.get(oid)?;
    let comp = zstd::bulk::compress(&raw, PACK_ZSTD_LEVEL).context("pack compress")?;
    Ok(48 + comp.len() as u64)
}

/// A pack plan: which objects ship whole and which ship as deltas.
#[derive(Debug, Clone, Default)]
pub struct DeltaPlan {
    /// Objects shipped as ordinary full records.
    pub full: Vec<Oid>,
    /// Objects shipped as delta records.
    pub deltas: Vec<DeltaRecord>,
}

impl DeltaPlan {
    /// Every object the pack will carry (full + delta).
    pub fn all_oids(&self) -> Vec<Oid> {
        self.full
            .iter()
            .copied()
            .chain(self.deltas.iter().map(|d| d.oid))
            .collect()
    }
}

/// Build a [`DeltaPlan`] for `oids`: each object with a candidate base
/// in `base_of` (oid → (base, kind)) is CDC-encoded against it and
/// kept as a delta only when the compressed ops beat the compressed
/// full object by a clear margin; everything else ships whole.
///
/// Demotions to full records keep the pack self-consistent:
/// [`KIND_REF`] candidates whose base is not itself in `oids` (the
/// base must travel in the same pack), objects that *serve* as a base
/// for another candidate (a base is never itself a delta), and
/// candidates whose base the source store cannot produce. Encoding is
/// parallel across `threads` and fully deterministic for a given store
/// state, so retried packs keep their id and stay resumable.
pub fn plan_deltas(
    store: &LfsStore,
    oids: &[Oid],
    base_of: &HashMap<Oid, (Oid, u8)>,
    threads: usize,
) -> Result<DeltaPlan> {
    plan_deltas_cached(store, oids, base_of, threads, None)
}

/// Outcome of one content-addressed `(base, target)` delta encode,
/// memoized by [`PlanCache`]. A demotion (the gate said "ship whole")
/// is cached too — re-running CDC just to re-reject is the expensive
/// half of repeated fine-tune fetches.
#[derive(Debug, Clone)]
enum CachedEncode {
    /// The worth-it gate demoted this pairing to a full record.
    Demoted,
    /// The compressed ops stream and the target's raw length.
    Delta { raw_len: u64, ops_comp: Arc<Vec<u8>> },
}

/// Cap on memoized encodes. Entries are tiny relative to the tensors
/// they describe (just the compressed ops), but a long-lived server
/// must still bound them; past the cap new encodes simply aren't
/// cached. 1024 entries comfortably covers the chains of the hottest
/// bases a hub serves between restarts.
const PLAN_CACHE_MAX_ENTRIES: usize = 1024;

/// Server-side delta-base plan cache, keyed by `(base oid, target oid)`.
///
/// The CDC encode + worth-it gate in [`plan_deltas`] depend only on the
/// *contents* of the base and target objects, and oids are content
/// hashes — so a memoized outcome can never go stale; eviction is
/// purely a capacity concern (entries past [`PLAN_CACHE_MAX_ENTRIES`]
/// are not retained). Context-dependent demotions (base missing from
/// the pack, an object serving as another's base) are decided *before*
/// the cache is consulted and are never memoized.
///
/// Hit/miss counters feed `GET /metrics` on the HTTP server, so the
/// amortization claim (repeated fine-tune fetches of one base don't
/// re-run chunking) is observable, not assumed.
#[derive(Debug, Default)]
pub struct PlanCache {
    entries: std::sync::Mutex<HashMap<(Oid, Oid), CachedEncode>>,
    hits: std::sync::atomic::AtomicU64,
    misses: std::sync::atomic::AtomicU64,
}

impl PlanCache {
    /// A fresh, empty cache.
    pub fn new() -> PlanCache {
        PlanCache::default()
    }

    /// Encodes served from memory instead of re-running CDC chunking.
    pub fn hits(&self) -> u64 {
        self.hits.load(std::sync::atomic::Ordering::Relaxed)
    }

    /// Encodes that had to run (and were then memoized, capacity
    /// permitting).
    pub fn misses(&self) -> u64 {
        self.misses.load(std::sync::atomic::Ordering::Relaxed)
    }

    fn get(&self, key: &(Oid, Oid)) -> Option<CachedEncode> {
        let found = self.entries.lock().unwrap().get(key).cloned();
        let counter = if found.is_some() { &self.hits } else { &self.misses };
        counter.fetch_add(1, std::sync::atomic::Ordering::Relaxed);
        found
    }

    fn put(&self, key: (Oid, Oid), value: CachedEncode) {
        let mut entries = self.entries.lock().unwrap();
        if entries.len() < PLAN_CACHE_MAX_ENTRIES {
            entries.insert(key, value);
        }
    }
}

/// [`plan_deltas`] with an optional [`PlanCache`]: per `(base, target)`
/// pair the CDC encode (or its demotion) is served from the cache when
/// present, so a responder replanning the same fine-tune suffix for
/// every fresh clone pays the chunking cost once.
pub fn plan_deltas_cached(
    store: &LfsStore,
    oids: &[Oid],
    base_of: &HashMap<Oid, (Oid, u8)>,
    threads: usize,
    cache: Option<&PlanCache>,
) -> Result<DeltaPlan> {
    let mut unique = oids.to_vec();
    unique.sort();
    unique.dedup();
    let in_pack: HashSet<Oid> = unique.iter().copied().collect();
    let bases_used: HashSet<Oid> = unique
        .iter()
        .filter_map(|o| base_of.get(o).map(|&(b, _)| b))
        .collect();
    let encoded = par::try_par_map(&unique, threads, |_, oid| -> Result<Option<DeltaRecord>> {
        let Some(&(base, kind)) = base_of.get(oid) else {
            return Ok(None);
        };
        if base == *oid
            || bases_used.contains(oid)
            || (kind == KIND_REF && !in_pack.contains(&base))
        {
            return Ok(None);
        }
        // Past the context-dependent demotions above, the encode is a
        // pure function of the two objects' contents — exactly what
        // the cache memoizes.
        if let Some(hit) = cache.and_then(|c| c.get(&(base, *oid))) {
            return Ok(match hit {
                CachedEncode::Demoted => None,
                CachedEncode::Delta { raw_len, ops_comp } => Some(DeltaRecord {
                    oid: *oid,
                    base,
                    kind,
                    raw_len,
                    ops_comp: ops_comp.as_ref().clone(),
                }),
            });
        }
        let Ok(base_bytes) = store.get(&base) else {
            return Ok(None);
        };
        let target = store
            .get(oid)
            .with_context(|| format!("packing object {}", oid.short()))?;
        let ops = super::delta::encode_delta(&base_bytes, &target);
        let ops_comp = zstd::bulk::compress(&ops, PACK_ZSTD_LEVEL).context("pack compress")?;
        let full_comp = zstd::bulk::compress(&target, PACK_ZSTD_LEVEL).context("pack compress")?;
        // Worth-it gate, compressed-vs-compressed by design: a delta
        // record's wire cost is its 48-byte header + 32-byte base oid +
        // compressed ops; the full record it would replace costs the
        // same header + the *zstd-compressed* payload (`full_comp`),
        // never the raw object length. Requiring `32 + ops_comp` to
        // undercut `full_comp` by ≥10% therefore guarantees a kept
        // delta ships strictly fewer wire bytes than the full record —
        // the invariant `tests/pack_format.rs` pins with random
        // near-duplicate tensors.
        if 32 + ops_comp.len() >= full_comp.len() * 9 / 10 {
            if let Some(c) = cache {
                c.put((base, *oid), CachedEncode::Demoted);
            }
            return Ok(None);
        }
        if let Some(c) = cache {
            c.put(
                (base, *oid),
                CachedEncode::Delta {
                    raw_len: target.len() as u64,
                    ops_comp: Arc::new(ops_comp.clone()),
                },
            );
        }
        Ok(Some(DeltaRecord {
            oid: *oid,
            base,
            kind,
            raw_len: target.len() as u64,
            ops_comp,
        }))
    })?;
    let mut plan = DeltaPlan::default();
    for (oid, rec) in unique.iter().zip(encoded) {
        match rec {
            Some(d) => plan.deltas.push(d),
            None => plan.full.push(*oid),
        }
    }
    Ok(plan)
}

/// Stream full records for `unique` (pre-sorted, deduped) through
/// `writer`: windowed parallel compression, sequential framing.
fn stream_full_records<W: Write>(
    store: &LfsStore,
    writer: &mut PackWriter<W>,
    unique: &[Oid],
    threads: usize,
) -> Result<()> {
    thread_local! {
        // Per-worker read buffer recycled across objects: with
        // `LfsStore::get_to` this drops one allocation + full copy per
        // object from the pack-assembly fan-in.
        static READ_SCRATCH: RefCell<Vec<u8>> = RefCell::new(Vec::new());
    }
    // Window the compression fan-out: enough objects to keep `threads`
    // workers busy, but bounded so a huge want set never materializes
    // in RAM between compression and framing.
    let window_objects = threads.max(1) * 4;
    let mut start = 0usize;
    while start < unique.len() {
        let mut end = start;
        let mut window_bytes = 0u64;
        while end < unique.len()
            && (end - start) < window_objects
            && (end == start || window_bytes < STREAM_WINDOW_BYTES)
        {
            window_bytes += store.size_of(&unique[end]).unwrap_or(0);
            end += 1;
        }
        let batch = &unique[start..end];
        let blobs = par::try_par_map(batch, threads, |_, oid| -> Result<(u64, Vec<u8>)> {
            READ_SCRATCH.with(|buf| {
                let mut raw = buf.borrow_mut();
                store
                    .get_to(oid, &mut raw)
                    .with_context(|| format!("packing object {}", oid.short()))?;
                if raw.len() as u64 > MAX_OBJECT_BYTES {
                    bail!("object {} exceeds the pack format's size limit", oid.short());
                }
                let comp = zstd::bulk::compress(&raw, PACK_ZSTD_LEVEL).context("pack compress")?;
                Ok((raw.len() as u64, comp))
            })
        })?;
        for (oid, (raw_len, comp)) in batch.iter().zip(&blobs) {
            writer.add_compressed(*oid, *raw_len, comp)?;
        }
        start = end;
    }
    Ok(())
}

/// Stream a pack holding `oids` (read from `store`) into `out`.
///
/// Duplicate oids are packed once. Object payloads are compressed in
/// parallel across `threads` workers in bounded windows; the framing
/// is written sequentially so the pack is deterministic (and therefore
/// byte-identical to [`build_pack`] of the same want set). Peak heap
/// is O(window), independent of the pack size.
pub fn write_pack_to<W: Write>(
    store: &LfsStore,
    oids: &[Oid],
    threads: usize,
    out: W,
) -> Result<BuiltPack> {
    let mut unique = oids.to_vec();
    unique.sort();
    unique.dedup();
    let mut writer = PackWriter::new(out, unique.len() as u64)?;
    stream_full_records(store, &mut writer, &unique, threads)?;
    writer.finish()
}

/// Stream a delta-planned pack into `out`: full records first (the
/// exact [`write_pack_to`] streaming path, so in-pack bases are always
/// admitted before anything references them), then the plan's delta
/// records sorted by oid. A plan with no deltas degrades to a
/// byte-identical version-1 pack, keeping flat pushes wire-compatible
/// with older receivers.
pub fn write_delta_pack_to<W: Write>(
    store: &LfsStore,
    plan: &DeltaPlan,
    threads: usize,
    out: W,
) -> Result<BuiltPack> {
    if plan.deltas.is_empty() {
        return write_pack_to(store, &plan.full, threads, out);
    }
    let mut full = plan.full.clone();
    full.sort();
    full.dedup();
    let mut deltas: Vec<&DeltaRecord> = plan.deltas.iter().collect();
    deltas.sort_by_key(|d| d.oid);
    let total = (full.len() + deltas.len()) as u64;
    let mut writer = PackWriter::new_versioned(out, total, PACK_VERSION_DELTA)?;
    stream_full_records(store, &mut writer, &full, threads)?;
    for d in deltas {
        writer.add_delta(d.oid, d.kind, d.raw_len, &d.base, &d.ops_comp)?;
    }
    writer.finish()
}

/// Stream a delta-planned pack into a fresh file at `path` (parent
/// directories created; partial file removed on error).
pub fn write_delta_pack_file(
    store: &LfsStore,
    plan: &DeltaPlan,
    threads: usize,
    path: &Path,
) -> Result<BuiltPack> {
    if let Some(parent) = path.parent() {
        std::fs::create_dir_all(parent)?;
    }
    let file = std::fs::File::create(path).context("creating pack spill file")?;
    match write_delta_pack_to(store, plan, threads, std::io::BufWriter::new(file)) {
        Ok(built) => Ok(built),
        Err(e) => {
            let _ = std::fs::remove_file(path);
            Err(e)
        }
    }
}

/// Stream a pack for `oids` into a fresh file at `path` (parent
/// directories created). Returns the build summary; on error the
/// partial file is removed.
pub fn write_pack_file(
    store: &LfsStore,
    oids: &[Oid],
    threads: usize,
    path: &Path,
) -> Result<BuiltPack> {
    if let Some(parent) = path.parent() {
        std::fs::create_dir_all(parent)?;
    }
    let file = std::fs::File::create(path).context("creating pack spill file")?;
    match write_pack_to(store, oids, threads, std::io::BufWriter::new(file)) {
        Ok(built) => Ok(built),
        Err(e) => {
            let _ = std::fs::remove_file(path);
            Err(e)
        }
    }
}

/// Assemble a pack holding `oids` in memory (buffered convenience over
/// [`write_pack_to`]; byte-identical output).
pub fn build_pack(store: &LfsStore, oids: &[Oid], threads: usize) -> Result<Vec<u8>> {
    let mut out = Vec::new();
    write_pack_to(store, oids, threads, &mut out)?;
    Ok(out)
}

/// A validated view of a pack: the trailer checksum has been verified
/// and the index parsed, but records are not yet decompressed.
struct PackView {
    index: Vec<(Oid, usize)>,
    /// Where the index begins == where record data ends.
    records_end: usize,
    /// Format version (bounds which record kinds are legal).
    version: u32,
}

fn parse(pack: &[u8]) -> Result<PackView> {
    if pack.len() < HEADER_LEN + TRAILER_LEN {
        bail!("pack truncated ({} bytes)", pack.len());
    }
    if &pack[..4] != PACK_MAGIC {
        bail!("pack: bad magic");
    }
    let version = u32::from_le_bytes(pack[4..8].try_into().unwrap());
    if version != PACK_VERSION && version != PACK_VERSION_DELTA {
        bail!("pack: unsupported version {version}");
    }
    let checksum_at = pack.len() - 32;
    let actual: [u8; 32] = Sha256::digest(&pack[..checksum_at]).into();
    if actual[..] != pack[checksum_at..] {
        bail!("pack checksum mismatch (corrupt trailer or content)");
    }
    // All length/offset fields come from the (checksummed) pack, but a
    // checksum only proves the sender wrote what we read — a malicious
    // sender can still write absurd values. Validate with overflow-safe
    // comparisons so a crafted pack yields Err, never a panic.
    let index_end = checksum_at - 8;
    let count = u64::from_le_bytes(pack[8..16].try_into().unwrap());
    if count > (index_end / INDEX_ENTRY_LEN) as u64 {
        bail!("pack declares more objects than it can hold");
    }
    let count = count as usize;
    let index_offset = u64::from_le_bytes(pack[checksum_at - 8..checksum_at].try_into().unwrap());
    if index_offset > index_end as u64 {
        bail!("pack index out of bounds");
    }
    let index_offset = index_offset as usize;
    if index_offset < HEADER_LEN || index_end - index_offset != count * INDEX_ENTRY_LEN {
        bail!("pack index out of bounds");
    }
    let mut index = Vec::with_capacity(count);
    for i in 0..count {
        let at = index_offset + i * INDEX_ENTRY_LEN;
        let oid = Oid(pack[at..at + 32].try_into().unwrap());
        let off = u64::from_le_bytes(pack[at + 32..at + 40].try_into().unwrap());
        let record_end = off.checked_add(RECORD_HEADER_LEN as u64);
        if off < HEADER_LEN as u64 || record_end.map_or(true, |e| e > index_offset as u64) {
            bail!("pack record offset out of bounds for {}", oid.short());
        }
        index.push((oid, off as usize));
    }
    Ok(PackView {
        index,
        records_end: index_offset,
        version,
    })
}

/// Slice the record at `off`, returning (oid, kind, raw_len, payload).
fn record_at(pack: &[u8], off: usize, records_end: usize) -> Result<(Oid, u8, u64, &[u8])> {
    let oid = Oid(pack[off..off + 32].try_into().unwrap());
    let (kind, raw_len) = decode_len(u64::from_le_bytes(pack[off + 32..off + 40].try_into().unwrap()));
    let comp_len = u64::from_le_bytes(pack[off + 40..off + 48].try_into().unwrap());
    let start = off + RECORD_HEADER_LEN;
    // Overflow-safe: compare in u64 before narrowing.
    if comp_len > (records_end - start) as u64 {
        bail!("pack record for {} overruns the index", oid.short());
    }
    let comp_len = comp_len as usize;
    Ok((oid, kind, raw_len, &pack[start..start + comp_len]))
}

/// Validate a record's kind against the pack version it appeared in.
fn check_kind(version: u32, kind: u8, payload_len: u64, oid: &Oid) -> Result<()> {
    match kind {
        KIND_FULL => Ok(()),
        KIND_REF | KIND_STORE if version >= PACK_VERSION_DELTA => {
            if payload_len < 32 {
                bail!(
                    "pack delta record for {} is too short to name a base",
                    oid.short()
                );
            }
            Ok(())
        }
        _ => bail!("pack record for {} has invalid kind {kind}", oid.short()),
    }
}

/// The pack's identity: the hex of its trailing sha256.
///
/// Stable across rebuilds of the same content (pack assembly is
/// deterministic: sorted unique oids, fixed zstd level), which is what
/// lets an interrupted transfer re-address the *same* pack on retry
/// and resume from a byte offset. Anything too short to carry a
/// trailer ids as `"invalid"`; a corrupt-but-long-enough blob simply
/// won't match its re-computed checksum downstream.
pub fn pack_id(pack: &[u8]) -> String {
    if pack.len() < HEADER_LEN + TRAILER_LEN {
        return String::from("invalid");
    }
    crate::util::hex::encode(&pack[pack.len() - 32..])
}

/// List the (oid, raw size) of every object in a pack without
/// decompressing any payload. Verifies the trailer checksum.
pub fn pack_index(pack: &[u8]) -> Result<Vec<(Oid, u64)>> {
    let view = parse(pack)?;
    view.index
        .iter()
        .map(|&(oid, off)| {
            let (record_oid, kind, raw_len, payload) = record_at(pack, off, view.records_end)?;
            if record_oid != oid {
                bail!("pack index entry for {} points at a foreign record", oid.short());
            }
            check_kind(view.version, kind, payload.len() as u64, &oid)?;
            Ok((oid, raw_len))
        })
        .collect()
}

/// Decompress, hash-verify, and store one record's payload. Shared by
/// the buffered and the streaming admit paths so the safety argument
/// (bomb guard, content-hash gate) lives in one place.
fn admit_record(store: &LfsStore, oid: Oid, raw_len: u64, comp: &[u8]) -> Result<u64> {
    if raw_len > MAX_OBJECT_BYTES {
        bail!("pack object {} declares an implausible size", oid.short());
    }
    // Stream-decompress with a hard read limit: the output buffer
    // grows with actual data (a crafted `raw_len` cannot force a
    // giant up-front allocation) and a decompression bomb stops one
    // byte past the declared size.
    let mut raw = Vec::with_capacity((raw_len as usize).min(16 << 20));
    let decoder = zstd::stream::Decoder::new(comp)
        .with_context(|| format!("pack decompress of {}", oid.short()))?;
    decoder
        .take(raw_len + 1)
        .read_to_end(&mut raw)
        .with_context(|| format!("pack decompress of {}", oid.short()))?;
    if raw.len() as u64 != raw_len {
        bail!("pack object {} has wrong length", oid.short());
    }
    if Oid::of_bytes(&raw) != oid {
        bail!("pack object {} failed its content hash", oid.short());
    }
    store.put(&raw)?;
    Ok(raw_len)
}

/// Resolve and admit one delta record: fetch the base from the
/// receiving store (full records of the same pack were admitted first,
/// so [`KIND_REF`] bases resolve the same way [`KIND_STORE`] ones do),
/// bomb-guard decompress the ops, replay them, and gate admission on
/// the content hash — the same safety contract as [`admit_record`],
/// with O(1) extra memory beyond the base and the result.
fn admit_delta_record(store: &LfsStore, oid: Oid, raw_len: u64, payload: &[u8]) -> Result<u64> {
    if raw_len > MAX_OBJECT_BYTES {
        bail!("pack object {} declares an implausible size", oid.short());
    }
    if payload.len() < 32 {
        bail!(
            "pack delta record for {} is too short to name a base",
            oid.short()
        );
    }
    let base_oid = Oid(payload[..32].try_into().unwrap());
    let base = store.get(&base_oid).with_context(|| {
        format!(
            "delta base {} for {} is missing from the receiving store",
            base_oid.short(),
            oid.short()
        )
    })?;
    // The ops stream frames the literal content, so its size is
    // bounded a little above the declared output; cap decompression
    // there so a bomb fails fast.
    let ops_limit = raw_len + raw_len / 16 + 4096;
    let mut ops = Vec::with_capacity(((raw_len / 4) as usize).min(16 << 20));
    let decoder = zstd::stream::Decoder::new(&payload[32..])
        .with_context(|| format!("pack decompress of {}", oid.short()))?;
    decoder
        .take(ops_limit + 1)
        .read_to_end(&mut ops)
        .with_context(|| format!("pack decompress of {}", oid.short()))?;
    if ops.len() as u64 > ops_limit {
        bail!(
            "pack delta record for {} has implausibly large ops",
            oid.short()
        );
    }
    let raw = super::delta::apply_delta(&base, &ops, raw_len)
        .with_context(|| format!("replaying delta for {}", oid.short()))?;
    if Oid::of_bytes(&raw) != oid {
        bail!("pack object {} failed its content hash", oid.short());
    }
    store.put(&raw)?;
    Ok(raw_len)
}

/// Verify, decompress, and store every object in `pack` (store fan-in).
///
/// Objects are admitted only after their raw bytes re-hash to the oid
/// the pack claims, so a damaged pack can never poison a store. Full
/// records fan in concurrently ([`LfsStore::put`] is atomic); delta
/// records resolve afterwards, so in-pack bases are always admitted
/// before anything references them.
pub fn unpack_into(store: &LfsStore, pack: &[u8], threads: usize) -> Result<PackStats> {
    let view = parse(pack)?;
    let mut full: Vec<(Oid, u64, &[u8])> = Vec::with_capacity(view.index.len());
    let mut deltas: Vec<(Oid, u64, &[u8])> = Vec::new();
    for &(oid, off) in &view.index {
        let (record_oid, kind, raw_len, payload) = record_at(pack, off, view.records_end)?;
        if record_oid != oid {
            bail!("pack index entry for {} points at a foreign record", oid.short());
        }
        check_kind(view.version, kind, payload.len() as u64, &oid)?;
        if kind == KIND_FULL {
            full.push((oid, raw_len, payload));
        } else {
            deltas.push((oid, raw_len, payload));
        }
    }
    let sizes = par::try_par_map(&full, threads, |_, &(oid, raw_len, comp)| {
        admit_record(store, oid, raw_len, comp)
    })?;
    let mut raw_total: u64 = sizes.iter().sum();
    let delta_objects = deltas.len();
    for (oid, raw_len, payload) in deltas {
        raw_total += admit_delta_record(store, oid, raw_len, payload)?;
    }
    Ok(PackStats {
        objects: view.index.len(),
        raw_bytes: raw_total,
        packed_bytes: pack.len() as u64,
        delta_objects,
    })
}

/// A reader wrapper that feeds everything it reads (up to a hashing
/// limit — the trailer digest must not hash itself) through a running
/// sha256 while tracking the stream position.
struct HashScan<R: Read> {
    r: R,
    hasher: Sha256,
    pos: u64,
    hash_limit: u64,
}

impl<R: Read> HashScan<R> {
    fn read_exact(&mut self, buf: &mut [u8]) -> Result<()> {
        self.r.read_exact(buf).context("pack file truncated")?;
        let remain = self.hash_limit.saturating_sub(self.pos);
        let h = (remain.min(buf.len() as u64)) as usize;
        self.hasher.update(&buf[..h]);
        self.pos += buf.len() as u64;
        Ok(())
    }

    /// Read-and-discard `n` bytes (they still feed the checksum).
    fn skip(&mut self, mut n: u64) -> Result<()> {
        let mut chunk = [0u8; 64 * 1024];
        while n > 0 {
            let want = n.min(chunk.len() as u64) as usize;
            self.read_exact(&mut chunk[..want])?;
            n -= want as u64;
        }
        Ok(())
    }
}

/// Outcome of a streaming pack-file verification.
#[derive(Debug, Clone)]
pub struct PackCheck {
    /// The pack's identity (hex of the trailing sha256).
    pub id: String,
    /// File length in bytes.
    pub len: u64,
    /// Records the pack carries.
    pub objects: u64,
}

/// Verify a pack **file** end to end — structure, index, and trailing
/// checksum — in one streaming pass with O(1) memory (payloads are
/// hashed and discarded, never decompressed). Nothing is admitted to
/// any store; this is the gate the streaming receive path runs before
/// [`unpack_file`] touches a store, so a corrupt pack admits nothing.
pub fn verify_pack_file(path: &Path) -> Result<PackCheck> {
    let len = std::fs::metadata(path).context("pack file missing")?.len();
    if len < (HEADER_LEN + TRAILER_LEN) as u64 {
        bail!("pack truncated ({len} bytes)");
    }
    let file = std::fs::File::open(path).context("opening pack file")?;
    let mut scan = HashScan {
        r: BufReader::with_capacity(64 * 1024, file),
        hasher: Sha256::new(),
        pos: 0,
        hash_limit: len - 32,
    };

    let mut header = [0u8; HEADER_LEN];
    scan.read_exact(&mut header)?;
    if &header[..4] != PACK_MAGIC {
        bail!("pack: bad magic");
    }
    let version = u32::from_le_bytes(header[4..8].try_into().unwrap());
    if version != PACK_VERSION && version != PACK_VERSION_DELTA {
        bail!("pack: unsupported version {version}");
    }
    let count = u64::from_le_bytes(header[8..16].try_into().unwrap());
    let index_bytes = count
        .checked_mul(INDEX_ENTRY_LEN as u64)
        .filter(|&b| b <= len - (HEADER_LEN + TRAILER_LEN) as u64)
        .with_context(|| "pack declares more objects than it can hold".to_string())?;
    let index_offset = len - TRAILER_LEN as u64 - index_bytes;

    // Walk the records region, hashing payloads without decompressing.
    let mut records: Vec<(Oid, u64)> = Vec::with_capacity(count.min(1 << 20) as usize);
    let mut rec_header = [0u8; RECORD_HEADER_LEN];
    while scan.pos < index_offset {
        if index_offset - scan.pos < RECORD_HEADER_LEN as u64 {
            bail!("pack records overrun the index");
        }
        let off = scan.pos;
        scan.read_exact(&mut rec_header)?;
        let oid = Oid(rec_header[..32].try_into().unwrap());
        let (kind, raw_len) =
            decode_len(u64::from_le_bytes(rec_header[32..40].try_into().unwrap()));
        let comp_len = u64::from_le_bytes(rec_header[40..48].try_into().unwrap());
        if raw_len > MAX_OBJECT_BYTES {
            bail!("pack object {} declares an implausible size", oid.short());
        }
        if comp_len > index_offset - scan.pos {
            bail!("pack record for {} overruns the index", oid.short());
        }
        check_kind(version, kind, comp_len, &oid)?;
        scan.skip(comp_len)?;
        records.push((oid, off));
    }
    if records.len() as u64 != count {
        bail!(
            "pack declares {count} objects but carries {}",
            records.len()
        );
    }

    // The index must mirror the records we just walked, in order.
    let mut entry = [0u8; INDEX_ENTRY_LEN];
    for (oid, off) in &records {
        scan.read_exact(&mut entry)?;
        let idx_oid = Oid(entry[..32].try_into().unwrap());
        let idx_off = u64::from_le_bytes(entry[32..40].try_into().unwrap());
        if idx_oid != *oid || idx_off != *off {
            bail!("pack index entry for {} points at a foreign record", idx_oid.short());
        }
    }
    let mut tail = [0u8; 8];
    scan.read_exact(&mut tail)?;
    if u64::from_le_bytes(tail) != index_offset {
        bail!("pack index out of bounds");
    }

    let digest: [u8; 32] = scan.hasher.finalize().into();
    let mut trailer = [0u8; 32];
    scan.r
        .read_exact(&mut trailer)
        .context("pack file truncated")?;
    if digest != trailer {
        bail!("pack checksum mismatch (corrupt trailer or content)");
    }
    Ok(PackCheck {
        id: crate::util::hex::encode(&trailer),
        len,
        objects: count,
    })
}

/// Verify a pack file, then decompress + admit its objects reading one
/// bounded window of records at a time (streaming fan-in).
///
/// The checksum pass runs first and touches no store, so a corrupt
/// pack admits nothing — same guarantee as the buffered
/// [`unpack_into`], with peak heap O(largest object + window) instead
/// of O(pack). Callers that already ran [`verify_pack_file`] (the
/// transfer paths, which also need the id) should pass its result to
/// [`unpack_verified`] instead of paying a second full-file hash pass.
pub fn unpack_file(path: &Path, store: &LfsStore, threads: usize) -> Result<PackStats> {
    let check = verify_pack_file(path)?;
    unpack_verified(path, store, threads, &check)
}

/// Decompress + admit a pack file that [`verify_pack_file`] has
/// already vouched for; `check` must come from that verification of
/// this same file. Each record still re-hashes to its oid before
/// admission, so even a file swapped between the passes cannot poison
/// the store — it just fails here.
pub fn unpack_verified(
    path: &Path,
    store: &LfsStore,
    threads: usize,
    check: &PackCheck,
) -> Result<PackStats> {
    let file = std::fs::File::open(path).context("opening pack file")?;
    let mut r = BufReader::with_capacity(64 * 1024, file);

    let mut header = [0u8; HEADER_LEN];
    r.read_exact(&mut header).context("pack file truncated")?;

    let window_objects = threads.max(1) * 4;
    let mut window: Vec<(Oid, u64, Vec<u8>)> = Vec::with_capacity(window_objects);
    let mut window_bytes = 0u64;
    let mut raw_total = 0u64;
    let mut delta_objects = 0usize;
    let mut rec_header = [0u8; RECORD_HEADER_LEN];
    let flush = |window: &mut Vec<(Oid, u64, Vec<u8>)>, raw_total: &mut u64| -> Result<()> {
        let sizes = par::try_par_map(window.as_slice(), threads, |_, (oid, raw_len, comp)| {
            admit_record(store, *oid, *raw_len, comp)
        })?;
        *raw_total += sizes.iter().sum::<u64>();
        window.clear();
        Ok(())
    };
    for _ in 0..check.objects {
        r.read_exact(&mut rec_header).context("pack file truncated")?;
        let oid = Oid(rec_header[..32].try_into().unwrap());
        let (kind, raw_len) =
            decode_len(u64::from_le_bytes(rec_header[32..40].try_into().unwrap()));
        let comp_len = u64::from_le_bytes(rec_header[40..48].try_into().unwrap());
        // verify_pack_file bounded these already; re-clamp defensively
        // in case the file changed between the two passes.
        if comp_len > check.len || raw_len > MAX_OBJECT_BYTES || kind > KIND_STORE {
            bail!("pack record for {} changed between passes", oid.short());
        }
        let mut comp = vec![0u8; comp_len as usize];
        r.read_exact(&mut comp).context("pack file truncated")?;
        if kind == KIND_FULL {
            window_bytes += comp_len + raw_len;
            window.push((oid, raw_len, comp));
            if window.len() >= window_objects || window_bytes >= STREAM_WINDOW_BYTES {
                flush(&mut window, &mut raw_total)?;
                window_bytes = 0;
            }
        } else {
            // A delta may reference a full record travelling earlier in
            // this same pack: drain the pending window so every in-pack
            // base is admitted, then resolve serially against the store.
            flush(&mut window, &mut raw_total)?;
            window_bytes = 0;
            raw_total += admit_delta_record(store, oid, raw_len, &comp)?;
            delta_objects += 1;
        }
    }
    flush(&mut window, &mut raw_total)?;
    Ok(PackStats {
        objects: check.objects as usize,
        raw_bytes: raw_total,
        packed_bytes: check.len,
        delta_objects,
    })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::tmp::TempDir;

    fn store_with(td: &TempDir, payloads: &[&[u8]]) -> (LfsStore, Vec<Oid>) {
        let store = LfsStore::open(td.path());
        let oids = payloads
            .iter()
            .map(|p| store.put(p).unwrap().0)
            .collect();
        (store, oids)
    }

    #[test]
    fn roundtrip_and_dedup() {
        let td_a = TempDir::new("pack-a").unwrap();
        let td_b = TempDir::new("pack-b").unwrap();
        let (a, oids) = store_with(&td_a, &[b"alpha", b"beta", &[0u8; 10_000]]);
        let b = LfsStore::open(td_b.path());

        // Duplicates in the want list pack once.
        let doubled: Vec<Oid> = oids.iter().chain(oids.iter()).copied().collect();
        let pack = build_pack(&a, &doubled, 2).unwrap();
        assert_eq!(pack_index(&pack).unwrap().len(), 3);

        let stats = unpack_into(&b, &pack, 2).unwrap();
        assert_eq!(stats.objects, 3);
        assert_eq!(stats.raw_bytes, 5 + 4 + 10_000);
        assert_eq!(stats.packed_bytes, pack.len() as u64);
        for oid in &oids {
            assert_eq!(b.get(oid).unwrap(), a.get(oid).unwrap());
        }
    }

    #[test]
    fn empty_pack_is_valid() {
        let td = TempDir::new("pack-empty").unwrap();
        let (store, _) = store_with(&td, &[]);
        let pack = build_pack(&store, &[], 4).unwrap();
        assert_eq!(pack.len(), HEADER_LEN + TRAILER_LEN);
        assert!(pack_index(&pack).unwrap().is_empty());
        assert_eq!(unpack_into(&store, &pack, 4).unwrap().objects, 0);
    }

    #[test]
    fn every_flipped_byte_is_detected() {
        let td = TempDir::new("pack-flip").unwrap();
        let (store, oids) = store_with(&td, &[b"some weights", b"more weights"]);
        let pack = build_pack(&store, &oids, 1).unwrap();
        let td2 = TempDir::new("pack-flip2").unwrap();
        let dst = LfsStore::open(td2.path());
        // Flip a byte in each region: header, record payload, index, trailer.
        for at in [2usize, HEADER_LEN + 40, pack.len() - 50, pack.len() - 1] {
            let mut bad = pack.clone();
            bad[at] ^= 0xff;
            assert!(unpack_into(&dst, &bad, 1).is_err(), "flip at {at} undetected");
        }
        // Truncation anywhere is detected too.
        assert!(unpack_into(&dst, &pack[..pack.len() - 7], 1).is_err());
        assert!(unpack_into(&dst, &pack[..10], 1).is_err());
    }

    #[test]
    fn pack_id_is_deterministic_and_content_bound() {
        let td = TempDir::new("pack-id").unwrap();
        let (store, oids) = store_with(&td, &[b"w1", b"w2"]);
        let a = build_pack(&store, &oids, 1).unwrap();
        let b = build_pack(&store, &oids, 2).unwrap();
        assert_eq!(a, b, "pack assembly must be deterministic");
        assert_eq!(pack_id(&a), pack_id(&b));
        assert_eq!(pack_id(&a).len(), 64);
        let (_, more) = store_with(&td, &[b"w3"]);
        let c = build_pack(&store, &more, 1).unwrap();
        assert_ne!(pack_id(&a), pack_id(&c));
        assert_eq!(pack_id(&a[..10]), "invalid");
    }

    #[test]
    fn missing_source_object_fails_build() {
        let td = TempDir::new("pack-miss").unwrap();
        let (store, _) = store_with(&td, &[b"x"]);
        let ghost = Oid::of_bytes(b"never stored");
        assert!(build_pack(&store, &[ghost], 1).is_err());
    }

    #[test]
    fn streamed_pack_is_byte_identical_to_buffered() {
        let td = TempDir::new("pack-stream").unwrap();
        let (store, oids) =
            store_with(&td, &[b"alpha", b"beta", &[5u8; 20_000], b"delta", &[9u8; 3]]);
        let buffered = build_pack(&store, &oids, 1).unwrap();

        let td2 = TempDir::new("pack-stream2").unwrap();
        let path = td2.join("spill.pack");
        let built = write_pack_file(&store, &oids, 2, &path).unwrap();
        let from_file = std::fs::read(&path).unwrap();
        assert_eq!(from_file, buffered, "stream and buffer paths must agree byte-for-byte");
        assert_eq!(built.len, buffered.len() as u64);
        assert_eq!(built.id, pack_id(&buffered));
        assert_eq!(built.objects, 5);
        assert_eq!(built.raw_bytes, 5 + 4 + 20_000 + 5 + 3);
    }

    #[test]
    fn verify_and_unpack_file_roundtrip() {
        let td = TempDir::new("pack-vf").unwrap();
        let (store, oids) = store_with(&td, &[b"one", b"two", &[3u8; 5000]]);
        let td_spill = TempDir::new("pack-vf-spill").unwrap();
        let path = td_spill.join("p.pack");
        let built = write_pack_file(&store, &oids, 2, &path).unwrap();

        let check = verify_pack_file(&path).unwrap();
        assert_eq!(check.id, built.id);
        assert_eq!(check.len, built.len);
        assert_eq!(check.objects, 3);

        let td_dst = TempDir::new("pack-vf-dst").unwrap();
        let dst = LfsStore::open(td_dst.path());
        let stats = unpack_file(&path, &dst, 2).unwrap();
        assert_eq!(stats.objects, 3);
        assert_eq!(stats.raw_bytes, 3 + 3 + 5000);
        assert_eq!(stats.packed_bytes, built.len);
        for oid in &oids {
            assert_eq!(dst.get(oid).unwrap(), store.get(oid).unwrap());
        }
    }

    #[test]
    fn corrupt_or_truncated_file_admits_nothing() {
        let td = TempDir::new("pack-corrupt").unwrap();
        let (store, oids) = store_with(&td, &[b"weights-a", b"weights-b", &[7u8; 4000]]);
        let td_spill = TempDir::new("pack-corrupt-spill").unwrap();
        let good = td_spill.join("good.pack");
        write_pack_file(&store, &oids, 1, &good).unwrap();
        let bytes = std::fs::read(&good).unwrap();

        let td_dst = TempDir::new("pack-corrupt-dst").unwrap();
        let dst = LfsStore::open(td_dst.path());
        // Flip a byte in each region, truncate at several points: every
        // damage mode must fail verification and admit nothing.
        for at in [2usize, HEADER_LEN + 40, bytes.len() - 50, bytes.len() - 1] {
            let mut bad = bytes.clone();
            bad[at] ^= 0xff;
            let path = td_spill.join("bad.pack");
            std::fs::write(&path, &bad).unwrap();
            assert!(unpack_file(&path, &dst, 2).is_err(), "flip at {at} undetected");
            assert!(dst.list().unwrap().is_empty(), "flip at {at} admitted objects");
        }
        for keep in [10usize, bytes.len() - 7, bytes.len() - 33] {
            let path = td_spill.join("short.pack");
            std::fs::write(&path, &bytes[..keep]).unwrap();
            assert!(unpack_file(&path, &dst, 1).is_err(), "truncation at {keep} undetected");
            assert!(dst.list().unwrap().is_empty());
        }
    }

    #[test]
    fn writer_enforces_declared_count() {
        let td = TempDir::new("pack-count").unwrap();
        let (_store, _) = store_with(&td, &[]);
        // Fewer objects than declared → finish fails.
        let mut out = Vec::new();
        let w = PackWriter::new(&mut out, 2).unwrap();
        assert!(w.finish().is_err());
        // More than declared → add fails.
        let mut out = Vec::new();
        let mut w = PackWriter::new(&mut out, 0).unwrap();
        assert!(w.add_object(Oid::of_bytes(b"x"), b"x").is_err());
    }

    /// A ~repeating base and a near-identical fine-tune of it (tail
    /// rewritten), both compressible but clearly delta-friendly.
    fn near_identical_pair(seed: u64, len: usize) -> (Vec<u8>, Vec<u8>) {
        let mut rng = crate::util::rng::Pcg64::new(seed);
        let base: Vec<u8> = (0..len).map(|_| rng.next_u64() as u8).collect();
        let mut tuned = base.clone();
        let tail = len / 4;
        for b in &mut tuned[len - tail..] {
            *b = rng.next_u64() as u8;
        }
        (base, tuned)
    }

    #[test]
    fn delta_pack_roundtrips_and_shrinks() {
        let td = TempDir::new("pack-delta").unwrap();
        let (base, tuned) = near_identical_pair(21, 64 * 1024);
        let (store, oids) = store_with(&td, &[base.as_slice(), tuned.as_slice()]);
        let (base_oid, tuned_oid) = (oids[0], oids[1]);

        let mut base_of = HashMap::new();
        base_of.insert(tuned_oid, (base_oid, KIND_REF));
        let plan = plan_deltas(&store, &oids, &base_of, 2).unwrap();
        assert_eq!(plan.deltas.len(), 1, "near-identical pair must delta");
        assert_eq!(plan.full, vec![base_oid]);

        let td_spill = TempDir::new("pack-delta-spill").unwrap();
        let path = td_spill.join("d.pack");
        let built = write_delta_pack_file(&store, &plan, 2, &path).unwrap();
        let flat = build_pack(&store, &oids, 1).unwrap();
        assert!(
            built.len < flat.len() as u64 * 3 / 4,
            "delta pack ({}) should clearly undercut the flat pack ({})",
            built.len,
            flat.len()
        );
        assert_eq!(built.raw_bytes, (base.len() + tuned.len()) as u64);

        // Streamed and buffered v2 writers agree byte for byte.
        let mut buffered = Vec::new();
        let rebuilt = write_delta_pack_to(&store, &plan, 1, &mut buffered).unwrap();
        assert_eq!(std::fs::read(&path).unwrap(), buffered);
        assert_eq!(rebuilt.id, built.id, "delta packs must be deterministic");

        // File path admits both objects byte-identically.
        let td_dst = TempDir::new("pack-delta-dst").unwrap();
        let dst = LfsStore::open(td_dst.path());
        let stats = unpack_file(&path, &dst, 2).unwrap();
        assert_eq!(stats.objects, 2);
        assert_eq!(stats.raw_bytes, built.raw_bytes);
        assert_eq!(dst.get(&base_oid).unwrap(), base);
        assert_eq!(dst.get(&tuned_oid).unwrap(), tuned);

        // Buffered path agrees.
        let td_dst2 = TempDir::new("pack-delta-dst2").unwrap();
        let dst2 = LfsStore::open(td_dst2.path());
        let stats2 = unpack_into(&dst2, &buffered, 2).unwrap();
        assert_eq!(stats2.objects, 2);
        assert_eq!(dst2.get(&tuned_oid).unwrap(), tuned);
        assert_eq!(pack_index(&buffered).unwrap().len(), 2);
    }

    #[test]
    fn store_based_delta_resolves_against_receiver() {
        let td = TempDir::new("pack-sdelta").unwrap();
        let (base, tuned) = near_identical_pair(22, 48 * 1024);
        let (store, oids) = store_with(&td, &[base.as_slice(), tuned.as_slice()]);
        let (base_oid, tuned_oid) = (oids[0], oids[1]);

        let mut base_of = HashMap::new();
        base_of.insert(tuned_oid, (base_oid, KIND_STORE));
        // Only the tuned object ships; the base is "already remote".
        let plan = plan_deltas(&store, &[tuned_oid], &base_of, 1).unwrap();
        assert_eq!(plan.deltas.len(), 1);
        assert!(plan.full.is_empty());

        let td_spill = TempDir::new("pack-sdelta-spill").unwrap();
        let path = td_spill.join("s.pack");
        write_delta_pack_file(&store, &plan, 1, &path).unwrap();

        // A receiver holding the base reconstructs the tuned object.
        let td_dst = TempDir::new("pack-sdelta-dst").unwrap();
        let dst = LfsStore::open(td_dst.path());
        dst.put(&base).unwrap();
        let stats = unpack_file(&path, &dst, 1).unwrap();
        assert_eq!(stats.objects, 1);
        assert_eq!(dst.get(&tuned_oid).unwrap(), tuned);

        // A receiver without the base fails cleanly and admits nothing.
        let td_bare = TempDir::new("pack-sdelta-bare").unwrap();
        let bare = LfsStore::open(td_bare.path());
        let err = unpack_file(&path, &bare, 1).unwrap_err();
        assert!(
            format!("{err:#}").contains("missing from the receiving store"),
            "unexpected error: {err:#}"
        );
        assert!(bare.list().unwrap().is_empty());
    }

    #[test]
    fn empty_delta_plan_writes_a_byte_identical_v1_pack() {
        let td = TempDir::new("pack-flatplan").unwrap();
        let (store, oids) = store_with(&td, &[b"alpha", b"beta", &[3u8; 9000]]);
        let plan = DeltaPlan {
            full: oids.clone(),
            deltas: Vec::new(),
        };
        let mut out = Vec::new();
        write_delta_pack_to(&store, &plan, 2, &mut out).unwrap();
        assert_eq!(out, build_pack(&store, &oids, 1).unwrap());
    }

    #[test]
    fn unworthy_deltas_ship_full() {
        let td = TempDir::new("pack-unworthy").unwrap();
        let mut rng = crate::util::rng::Pcg64::new(23);
        let a: Vec<u8> = (0..20_000).map(|_| rng.next_u64() as u8).collect();
        let b: Vec<u8> = (0..20_000).map(|_| rng.next_u64() as u8).collect();
        let (store, oids) = store_with(&td, &[a.as_slice(), b.as_slice()]);
        let mut base_of = HashMap::new();
        base_of.insert(oids[1], (oids[0], KIND_REF));
        let plan = plan_deltas(&store, &oids, &base_of, 1).unwrap();
        assert!(plan.deltas.is_empty(), "unrelated objects must ship whole");
        assert_eq!(plan.full.len(), 2);
    }

    #[test]
    fn corrupt_delta_pack_admits_nothing() {
        let td = TempDir::new("pack-dcorrupt").unwrap();
        let (base, tuned) = near_identical_pair(24, 32 * 1024);
        let (store, oids) = store_with(&td, &[base.as_slice(), tuned.as_slice()]);
        let mut base_of = HashMap::new();
        base_of.insert(oids[1], (oids[0], KIND_REF));
        let plan = plan_deltas(&store, &oids, &base_of, 1).unwrap();
        assert_eq!(plan.deltas.len(), 1);
        let td_spill = TempDir::new("pack-dcorrupt-spill").unwrap();
        let good = td_spill.join("good.pack");
        write_delta_pack_file(&store, &plan, 1, &good).unwrap();
        let bytes = std::fs::read(&good).unwrap();

        let td_dst = TempDir::new("pack-dcorrupt-dst").unwrap();
        let dst = LfsStore::open(td_dst.path());
        for at in [5usize, HEADER_LEN + 40, bytes.len() / 2, bytes.len() - 1] {
            let mut bad = bytes.clone();
            bad[at] ^= 0xff;
            let path = td_spill.join("bad.pack");
            std::fs::write(&path, &bad).unwrap();
            assert!(unpack_file(&path, &dst, 1).is_err(), "flip at {at} undetected");
            assert!(dst.list().unwrap().is_empty(), "flip at {at} admitted objects");
        }
        for keep in [20usize, bytes.len() - 5, bytes.len() - 40] {
            let path = td_spill.join("short.pack");
            std::fs::write(&path, &bytes[..keep]).unwrap();
            assert!(unpack_file(&path, &dst, 1).is_err());
            assert!(dst.list().unwrap().is_empty());
        }
    }

    #[test]
    fn writer_rejects_misplaced_delta_records() {
        // Delta records are illegal in a v1 pack.
        let mut out = Vec::new();
        let mut w = PackWriter::new(&mut out, 1).unwrap();
        let oid = Oid::of_bytes(b"t");
        let base = Oid::of_bytes(b"b");
        assert!(w.add_delta(oid, KIND_REF, 1, &base, &[]).is_err());
        // And only the two delta kinds are accepted in a v2 pack.
        let mut out = Vec::new();
        let mut w = PackWriter::new_versioned(&mut out, 1, PACK_VERSION_DELTA).unwrap();
        assert!(w.add_delta(oid, KIND_FULL, 1, &base, &[]).is_err());
        assert!(w.add_delta(oid, 7, 1, &base, &[]).is_err());
    }
}
