//! The Git-Theta packfile: many LFS objects in one integrity-checked blob.
//!
//! The per-object transfer loop in the seed negotiated and moved one
//! object per round trip, which collapses under the many-small-objects
//! workload the clean filter produces (one update object per changed
//! parameter group). A pack amortizes that: the sender assembles every
//! wanted object into a single blob, the receiver fans it back into its
//! store, and both halves parallelize per object via [`par`].
//!
//! Layout (all integers little-endian):
//!
//! ```text
//! header   "THP1" (4) | version u32 (4) | object count u64 (8)
//! records  count × { oid (32) | raw_len u64 | comp_len u64 | zstd bytes }
//! index    count × { oid (32) | record offset u64 }
//! trailer  index offset u64 | sha256 of everything above (32)
//! ```
//!
//! The trailing index lets a reader locate records without scanning, and
//! the trailing sha256 makes truncation or bit-rot anywhere in the pack
//! detectable before any object is admitted to a store. Each object is
//! additionally verified against its oid (sha256 of the raw bytes) on
//! unpack, so a pack can never silently install wrong content.

use super::store::LfsStore;
use crate::gitcore::object::Oid;
use crate::util::par;
use anyhow::{bail, Context, Result};
use sha2::{Digest, Sha256};
use std::cell::RefCell;
use std::io::Read;

/// First four bytes of every pack.
pub const PACK_MAGIC: &[u8; 4] = b"THP1";
/// Current pack format version.
pub const PACK_VERSION: u32 = 1;

const HEADER_LEN: usize = 16; // magic + version + count
const TRAILER_LEN: usize = 40; // index offset + sha256
const INDEX_ENTRY_LEN: usize = 40; // oid + record offset
const RECORD_HEADER_LEN: usize = 48; // oid + raw_len + comp_len

/// zstd level for object payloads (matches the serializer default).
const PACK_ZSTD_LEVEL: i32 = 3;

/// Format limit on a single object's uncompressed size (4 GiB). Keeps a
/// crafted record's declared `raw_len` from driving a giant allocation
/// before decompression can fail.
pub const MAX_OBJECT_BYTES: u64 = 1 << 32;

/// Size summary of a pack build or apply.
#[derive(Debug, Clone, Copy, Default, PartialEq, Eq)]
pub struct PackStats {
    /// Objects carried by the pack.
    pub objects: usize,
    /// Total uncompressed payload bytes.
    pub raw_bytes: u64,
    /// Bytes of the pack blob itself (what moves over the wire).
    pub packed_bytes: u64,
}

/// Assemble a pack holding `oids`, read from `store`.
///
/// Duplicate oids are packed once. Object payloads are compressed in
/// parallel across `threads` workers; the surrounding framing is
/// written sequentially so offsets stay deterministic.
pub fn build_pack(store: &LfsStore, oids: &[Oid], threads: usize) -> Result<Vec<u8>> {
    let mut unique = oids.to_vec();
    unique.sort();
    unique.dedup();

    thread_local! {
        // Per-worker read buffer recycled across objects: with
        // `LfsStore::get_to` this drops one allocation + full copy per
        // object from the pack-assembly fan-in.
        static READ_SCRATCH: RefCell<Vec<u8>> = RefCell::new(Vec::new());
    }
    let blobs = par::try_par_map(&unique, threads, |_, oid| -> Result<(u64, Vec<u8>)> {
        READ_SCRATCH.with(|buf| {
            let mut raw = buf.borrow_mut();
            store
                .get_to(oid, &mut raw)
                .with_context(|| format!("packing object {}", oid.short()))?;
            if raw.len() as u64 > MAX_OBJECT_BYTES {
                bail!("object {} exceeds the pack format's size limit", oid.short());
            }
            let comp = zstd::bulk::compress(&raw, PACK_ZSTD_LEVEL).context("pack compress")?;
            Ok((raw.len() as u64, comp))
        })
    })?;

    let body: usize = blobs
        .iter()
        .map(|(_, c)| RECORD_HEADER_LEN + c.len())
        .sum();
    let mut out =
        Vec::with_capacity(HEADER_LEN + body + unique.len() * INDEX_ENTRY_LEN + TRAILER_LEN);
    out.extend_from_slice(PACK_MAGIC);
    out.extend_from_slice(&PACK_VERSION.to_le_bytes());
    out.extend_from_slice(&(unique.len() as u64).to_le_bytes());

    let mut offsets = Vec::with_capacity(unique.len());
    for (oid, (raw_len, comp)) in unique.iter().zip(&blobs) {
        offsets.push(out.len() as u64);
        out.extend_from_slice(&oid.0);
        out.extend_from_slice(&raw_len.to_le_bytes());
        out.extend_from_slice(&(comp.len() as u64).to_le_bytes());
        out.extend_from_slice(comp);
    }

    let index_offset = out.len() as u64;
    for (oid, off) in unique.iter().zip(&offsets) {
        out.extend_from_slice(&oid.0);
        out.extend_from_slice(&off.to_le_bytes());
    }
    out.extend_from_slice(&index_offset.to_le_bytes());
    let digest: [u8; 32] = Sha256::digest(&out).into();
    out.extend_from_slice(&digest);
    Ok(out)
}

/// A validated view of a pack: the trailer checksum has been verified
/// and the index parsed, but records are not yet decompressed.
struct PackView {
    index: Vec<(Oid, usize)>,
    /// Where the index begins == where record data ends.
    records_end: usize,
}

fn parse(pack: &[u8]) -> Result<PackView> {
    if pack.len() < HEADER_LEN + TRAILER_LEN {
        bail!("pack truncated ({} bytes)", pack.len());
    }
    if &pack[..4] != PACK_MAGIC {
        bail!("pack: bad magic");
    }
    let version = u32::from_le_bytes(pack[4..8].try_into().unwrap());
    if version != PACK_VERSION {
        bail!("pack: unsupported version {version}");
    }
    let checksum_at = pack.len() - 32;
    let actual: [u8; 32] = Sha256::digest(&pack[..checksum_at]).into();
    if actual[..] != pack[checksum_at..] {
        bail!("pack checksum mismatch (corrupt trailer or content)");
    }
    // All length/offset fields come from the (checksummed) pack, but a
    // checksum only proves the sender wrote what we read — a malicious
    // sender can still write absurd values. Validate with overflow-safe
    // comparisons so a crafted pack yields Err, never a panic.
    let index_end = checksum_at - 8;
    let count = u64::from_le_bytes(pack[8..16].try_into().unwrap());
    if count > (index_end / INDEX_ENTRY_LEN) as u64 {
        bail!("pack declares more objects than it can hold");
    }
    let count = count as usize;
    let index_offset = u64::from_le_bytes(pack[checksum_at - 8..checksum_at].try_into().unwrap());
    if index_offset > index_end as u64 {
        bail!("pack index out of bounds");
    }
    let index_offset = index_offset as usize;
    if index_offset < HEADER_LEN || index_end - index_offset != count * INDEX_ENTRY_LEN {
        bail!("pack index out of bounds");
    }
    let mut index = Vec::with_capacity(count);
    for i in 0..count {
        let at = index_offset + i * INDEX_ENTRY_LEN;
        let oid = Oid(pack[at..at + 32].try_into().unwrap());
        let off = u64::from_le_bytes(pack[at + 32..at + 40].try_into().unwrap());
        let record_end = off.checked_add(RECORD_HEADER_LEN as u64);
        if off < HEADER_LEN as u64 || record_end.map_or(true, |e| e > index_offset as u64) {
            bail!("pack record offset out of bounds for {}", oid.short());
        }
        index.push((oid, off as usize));
    }
    Ok(PackView {
        index,
        records_end: index_offset,
    })
}

/// Slice the record at `off`, returning (oid, raw_len, compressed bytes).
fn record_at(pack: &[u8], off: usize, records_end: usize) -> Result<(Oid, u64, &[u8])> {
    let oid = Oid(pack[off..off + 32].try_into().unwrap());
    let raw_len = u64::from_le_bytes(pack[off + 32..off + 40].try_into().unwrap());
    let comp_len = u64::from_le_bytes(pack[off + 40..off + 48].try_into().unwrap());
    let start = off + RECORD_HEADER_LEN;
    // Overflow-safe: compare in u64 before narrowing.
    if comp_len > (records_end - start) as u64 {
        bail!("pack record for {} overruns the index", oid.short());
    }
    let comp_len = comp_len as usize;
    Ok((oid, raw_len, &pack[start..start + comp_len]))
}

/// The pack's identity: the hex of its trailing sha256.
///
/// Stable across rebuilds of the same content (pack assembly is
/// deterministic: sorted unique oids, fixed zstd level), which is what
/// lets an interrupted transfer re-address the *same* pack on retry
/// and resume from a byte offset. Anything too short to carry a
/// trailer ids as `"invalid"`; a corrupt-but-long-enough blob simply
/// won't match its re-computed checksum downstream.
pub fn pack_id(pack: &[u8]) -> String {
    if pack.len() < HEADER_LEN + TRAILER_LEN {
        return String::from("invalid");
    }
    crate::util::hex::encode(&pack[pack.len() - 32..])
}

/// List the (oid, raw size) of every object in a pack without
/// decompressing any payload. Verifies the trailer checksum.
pub fn pack_index(pack: &[u8]) -> Result<Vec<(Oid, u64)>> {
    let view = parse(pack)?;
    view.index
        .iter()
        .map(|&(oid, off)| {
            let (record_oid, raw_len, _) = record_at(pack, off, view.records_end)?;
            if record_oid != oid {
                bail!("pack index entry for {} points at a foreign record", oid.short());
            }
            Ok((oid, raw_len))
        })
        .collect()
}

/// Verify, decompress, and store every object in `pack` (store fan-in).
///
/// Objects are admitted only after their raw bytes re-hash to the oid
/// the pack claims, so a damaged pack can never poison a store. Workers
/// fan objects in concurrently; [`LfsStore::put`] is atomic.
pub fn unpack_into(store: &LfsStore, pack: &[u8], threads: usize) -> Result<PackStats> {
    let view = parse(pack)?;
    let sizes = par::try_par_map(&view.index, threads, |_, &(oid, off)| -> Result<u64> {
        let (record_oid, raw_len, comp) = record_at(pack, off, view.records_end)?;
        if record_oid != oid {
            bail!("pack index entry for {} points at a foreign record", oid.short());
        }
        if raw_len > MAX_OBJECT_BYTES {
            bail!("pack object {} declares an implausible size", oid.short());
        }
        // Stream-decompress with a hard read limit: the output buffer
        // grows with actual data (a crafted `raw_len` cannot force a
        // giant up-front allocation) and a decompression bomb stops one
        // byte past the declared size.
        let mut raw = Vec::with_capacity((raw_len as usize).min(16 << 20));
        let decoder = zstd::stream::Decoder::new(comp)
            .with_context(|| format!("pack decompress of {}", oid.short()))?;
        decoder
            .take(raw_len + 1)
            .read_to_end(&mut raw)
            .with_context(|| format!("pack decompress of {}", oid.short()))?;
        if raw.len() as u64 != raw_len {
            bail!("pack object {} has wrong length", oid.short());
        }
        if Oid::of_bytes(&raw) != oid {
            bail!("pack object {} failed its content hash", oid.short());
        }
        store.put(&raw)?;
        Ok(raw_len)
    })?;
    Ok(PackStats {
        objects: sizes.len(),
        raw_bytes: sizes.iter().sum(),
        packed_bytes: pack.len() as u64,
    })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::tmp::TempDir;

    fn store_with(td: &TempDir, payloads: &[&[u8]]) -> (LfsStore, Vec<Oid>) {
        let store = LfsStore::open(td.path());
        let oids = payloads
            .iter()
            .map(|p| store.put(p).unwrap().0)
            .collect();
        (store, oids)
    }

    #[test]
    fn roundtrip_and_dedup() {
        let td_a = TempDir::new("pack-a").unwrap();
        let td_b = TempDir::new("pack-b").unwrap();
        let (a, oids) = store_with(&td_a, &[b"alpha", b"beta", &[0u8; 10_000]]);
        let b = LfsStore::open(td_b.path());

        // Duplicates in the want list pack once.
        let doubled: Vec<Oid> = oids.iter().chain(oids.iter()).copied().collect();
        let pack = build_pack(&a, &doubled, 2).unwrap();
        assert_eq!(pack_index(&pack).unwrap().len(), 3);

        let stats = unpack_into(&b, &pack, 2).unwrap();
        assert_eq!(stats.objects, 3);
        assert_eq!(stats.raw_bytes, 5 + 4 + 10_000);
        assert_eq!(stats.packed_bytes, pack.len() as u64);
        for oid in &oids {
            assert_eq!(b.get(oid).unwrap(), a.get(oid).unwrap());
        }
    }

    #[test]
    fn empty_pack_is_valid() {
        let td = TempDir::new("pack-empty").unwrap();
        let (store, _) = store_with(&td, &[]);
        let pack = build_pack(&store, &[], 4).unwrap();
        assert_eq!(pack.len(), HEADER_LEN + TRAILER_LEN);
        assert!(pack_index(&pack).unwrap().is_empty());
        assert_eq!(unpack_into(&store, &pack, 4).unwrap().objects, 0);
    }

    #[test]
    fn every_flipped_byte_is_detected() {
        let td = TempDir::new("pack-flip").unwrap();
        let (store, oids) = store_with(&td, &[b"some weights", b"more weights"]);
        let pack = build_pack(&store, &oids, 1).unwrap();
        let td2 = TempDir::new("pack-flip2").unwrap();
        let dst = LfsStore::open(td2.path());
        // Flip a byte in each region: header, record payload, index, trailer.
        for at in [2usize, HEADER_LEN + 40, pack.len() - 50, pack.len() - 1] {
            let mut bad = pack.clone();
            bad[at] ^= 0xff;
            assert!(unpack_into(&dst, &bad, 1).is_err(), "flip at {at} undetected");
        }
        // Truncation anywhere is detected too.
        assert!(unpack_into(&dst, &pack[..pack.len() - 7], 1).is_err());
        assert!(unpack_into(&dst, &pack[..10], 1).is_err());
    }

    #[test]
    fn pack_id_is_deterministic_and_content_bound() {
        let td = TempDir::new("pack-id").unwrap();
        let (store, oids) = store_with(&td, &[b"w1", b"w2"]);
        let a = build_pack(&store, &oids, 1).unwrap();
        let b = build_pack(&store, &oids, 2).unwrap();
        assert_eq!(a, b, "pack assembly must be deterministic");
        assert_eq!(pack_id(&a), pack_id(&b));
        assert_eq!(pack_id(&a).len(), 64);
        let (_, more) = store_with(&td, &[b"w3"]);
        let c = build_pack(&store, &more, 1).unwrap();
        assert_ne!(pack_id(&a), pack_id(&c));
        assert_eq!(pack_id(&a[..10]), "invalid");
    }

    #[test]
    fn missing_source_object_fails_build() {
        let td = TempDir::new("pack-miss").unwrap();
        let (store, _) = store_with(&td, &[b"x"]);
        let ghost = Oid::of_bytes(b"never stored");
        assert!(build_pack(&store, &[ghost], 1).is_err());
    }
}
