// Heap high-water-mark tracking (two relaxed atomics per allocation):
// lets `git-theta bench checkout` report real peak-allocation numbers.
#[global_allocator]
static ALLOC: git_theta::util::alloc::TrackingAlloc = git_theta::util::alloc::TrackingAlloc;

fn main() {
    git_theta::init();
    std::process::exit(git_theta::cli::run());
}
