fn main() {
    git_theta::init();
    std::process::exit(git_theta::cli::run());
}
