//! Checkpoint abstraction: framework-native file ⇄ named parameter groups.
//!
//! Mirrors the paper's `Checkpoint` plug-in type: "Checkpoints are
//! responsible for loading a framework-native checkpoint file into a
//! standardized format in memory, identifying parameter groups, and
//! saving in-memory models back onto disk in the same framework-native
//! format." Two formats ship built-in — a safetensors-compatible format
//! and a msgpack-framed native format — and new ones register through
//! [`registry`].

mod native;
mod npz;
mod registry;
mod safetensors;

pub use native::NativeFormat;
pub use npz::NpzFormat;
pub use registry::{
    detect_format, format_by_name, register_format, registered_formats, CheckpointFormat,
};
pub use safetensors::SafetensorsFormat;

use crate::tensor::Tensor;
use std::collections::BTreeMap;

/// An in-memory model: ordered map of parameter-group name → tensor.
///
/// Names are flattened with `/` separators (e.g. `block_0/attn/q_proj`),
/// matching how the paper's Checkpoint plug-ins flatten PyTorch state
/// dicts and Flax pytrees.
#[derive(Debug, Clone, Default, PartialEq)]
pub struct Checkpoint {
    groups: BTreeMap<String, Tensor>,
}

impl Checkpoint {
    pub fn new() -> Checkpoint {
        Checkpoint::default()
    }

    pub fn insert(&mut self, name: impl Into<String>, tensor: Tensor) {
        self.groups.insert(name.into(), tensor);
    }

    pub fn get(&self, name: &str) -> Option<&Tensor> {
        self.groups.get(name)
    }

    pub fn remove(&mut self, name: &str) -> Option<Tensor> {
        self.groups.remove(name)
    }

    pub fn contains(&self, name: &str) -> bool {
        self.groups.contains_key(name)
    }

    pub fn len(&self) -> usize {
        self.groups.len()
    }

    pub fn is_empty(&self) -> bool {
        self.groups.is_empty()
    }

    pub fn names(&self) -> impl Iterator<Item = &String> {
        self.groups.keys()
    }

    pub fn iter(&self) -> impl Iterator<Item = (&String, &Tensor)> {
        self.groups.iter()
    }

    pub fn into_iter_groups(self) -> impl Iterator<Item = (String, Tensor)> {
        self.groups.into_iter()
    }

    /// Total parameter count across groups.
    pub fn total_params(&self) -> usize {
        self.groups.values().map(|t| t.numel()).sum()
    }

    /// Total in-memory byte size across groups.
    pub fn total_bytes(&self) -> usize {
        self.groups.values().map(|t| t.nbytes()).sum()
    }
}

impl FromIterator<(String, Tensor)> for Checkpoint {
    fn from_iter<T: IntoIterator<Item = (String, Tensor)>>(iter: T) -> Self {
        Checkpoint {
            groups: iter.into_iter().collect(),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::tensor::Tensor;

    #[test]
    fn basic_map_ops() {
        let mut ck = Checkpoint::new();
        ck.insert("layer0/w", Tensor::from_f32(vec![2, 2], vec![1., 2., 3., 4.]).unwrap());
        ck.insert("layer0/b", Tensor::from_f32(vec![2], vec![0., 0.]).unwrap());
        assert_eq!(ck.len(), 2);
        assert_eq!(ck.total_params(), 6);
        assert_eq!(ck.total_bytes(), 24);
        assert!(ck.contains("layer0/w"));
        let names: Vec<_> = ck.names().cloned().collect();
        assert_eq!(names, vec!["layer0/b", "layer0/w"]); // sorted
    }
}
