//! Native msgpack-framed checkpoint format (`.theta` extension).
//!
//! A compact format for tests and tooling: a msgpack map
//! `{"version": 1, "tensors": {name: {"dtype", "shape", "data"}}}`.

use super::registry::CheckpointFormat;
use super::Checkpoint;
use crate::tensor::{DType, Tensor};
use crate::util::msgpack::Mp;
use anyhow::{bail, Context, Result};

const MAGIC: &[u8; 6] = b"THETA\x01";

/// The native format plug-in.
#[derive(Debug, Default)]
pub struct NativeFormat;

impl CheckpointFormat for NativeFormat {
    fn name(&self) -> &'static str {
        "theta-native"
    }

    fn extensions(&self) -> &'static [&'static str] {
        &["theta"]
    }

    fn sniff(&self, prefix: &[u8]) -> bool {
        prefix.starts_with(MAGIC)
    }

    fn load_bytes(&self, bytes: &[u8]) -> Result<Checkpoint> {
        if !bytes.starts_with(MAGIC) {
            bail!("native: missing THETA magic");
        }
        let root = Mp::decode(&bytes[MAGIC.len()..]).context("native: bad msgpack")?;
        let version = root
            .get("version")
            .and_then(|v| v.as_u64())
            .context("native: missing version")?;
        if version != 1 {
            bail!("native: unsupported version {version}");
        }
        let tensors = match root.get("tensors") {
            Some(Mp::Map(entries)) => entries,
            _ => bail!("native: missing tensors map"),
        };
        let mut ck = Checkpoint::new();
        for (name, entry) in tensors {
            let dtype_name = entry
                .get("dtype")
                .and_then(|v| v.as_str())
                .with_context(|| format!("native: tensor '{name}' missing dtype"))?;
            let dtype = DType::parse(dtype_name)
                .with_context(|| format!("native: bad dtype '{dtype_name}'"))?;
            let shape: Vec<usize> = entry
                .get("shape")
                .and_then(|v| v.as_arr())
                .with_context(|| format!("native: tensor '{name}' missing shape"))?
                .iter()
                .map(|d| d.as_u64().map(|v| v as usize).context("bad dim"))
                .collect::<Result<_>>()?;
            let data = entry
                .get("data")
                .and_then(|v| v.as_bin())
                .with_context(|| format!("native: tensor '{name}' missing data"))?;
            ck.insert(
                name.clone(),
                Tensor::from_bytes(dtype, shape, data.to_vec())
                    .with_context(|| format!("native: tensor '{name}'"))?,
            );
        }
        Ok(ck)
    }

    fn save_bytes(&self, ck: &Checkpoint) -> Result<Vec<u8>> {
        let tensors: Vec<(String, Mp)> = ck
            .iter()
            .map(|(name, t)| {
                (
                    name.clone(),
                    Mp::map_from(vec![
                        ("dtype", Mp::Str(t.dtype().name().to_string())),
                        (
                            "shape",
                            Mp::Arr(t.shape().iter().map(|&d| Mp::UInt(d as u64)).collect()),
                        ),
                        ("data", Mp::Bin(t.bytes().to_vec())),
                    ]),
                )
            })
            .collect();
        let root = Mp::map_from(vec![
            ("version", Mp::UInt(1)),
            ("tensors", Mp::Map(tensors)),
        ]);
        let mut out = MAGIC.to_vec();
        out.extend_from_slice(&root.encode());
        Ok(out)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn roundtrip() {
        let mut ck = Checkpoint::new();
        ck.insert("w", Tensor::from_f32(vec![2, 2], vec![1., 2., 3., 4.]).unwrap());
        ck.insert("idx", Tensor::from_i64(vec![2], vec![5, -7]).unwrap());
        let fmt = NativeFormat;
        let bytes = fmt.save_bytes(&ck).unwrap();
        assert!(fmt.sniff(&bytes));
        assert_eq!(fmt.load_bytes(&bytes).unwrap(), ck);
    }

    #[test]
    fn rejects_wrong_magic() {
        assert!(NativeFormat.load_bytes(b"NOTTHETA").is_err());
    }
}
