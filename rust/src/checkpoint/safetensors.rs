//! Safetensors-compatible checkpoint format.
//!
//! Layout: `u64 le` header length, JSON header mapping tensor name →
//! {dtype, shape, data_offsets}, then the raw little-endian tensor data.
//! Interoperates with files produced by the `safetensors` Python package
//! (and by `python/compile/` in this repo), which is how checkpoints move
//! between the JAX build path and the Rust VCS.

use super::registry::CheckpointFormat;
use super::Checkpoint;
use crate::tensor::{DType, Tensor};
use crate::util::json::{Json, JsonObj};
use anyhow::{bail, Context, Result};

/// The safetensors format plug-in.
#[derive(Debug, Default)]
pub struct SafetensorsFormat;

impl CheckpointFormat for SafetensorsFormat {
    fn name(&self) -> &'static str {
        "safetensors"
    }

    fn extensions(&self) -> &'static [&'static str] {
        &["safetensors"]
    }

    fn sniff(&self, prefix: &[u8]) -> bool {
        // Header length (u64) followed by '{' is a strong signal.
        if prefix.len() < 9 {
            return false;
        }
        let len = u64::from_le_bytes(prefix[..8].try_into().unwrap());
        len > 0 && len < (1 << 33) && prefix[8] == b'{'
    }

    fn load_bytes(&self, bytes: &[u8]) -> Result<Checkpoint> {
        if bytes.len() < 8 {
            bail!("safetensors: file shorter than header length field");
        }
        let header_len = u64::from_le_bytes(bytes[..8].try_into().unwrap()) as usize;
        let header_end = 8usize
            .checked_add(header_len)
            .context("safetensors: header length overflow")?;
        if bytes.len() < header_end {
            bail!("safetensors: truncated header");
        }
        let header_text = std::str::from_utf8(&bytes[8..header_end])
            .context("safetensors: header is not utf-8")?;
        let header = Json::parse(header_text).context("safetensors: bad header json")?;
        let obj = header
            .as_obj()
            .context("safetensors: header is not an object")?;
        let data = &bytes[header_end..];

        let mut ck = Checkpoint::new();
        for (name, entry) in obj.iter() {
            if name == "__metadata__" {
                continue;
            }
            let dtype_name = entry
                .get("dtype")
                .and_then(|v| v.as_str())
                .with_context(|| format!("safetensors: tensor '{name}' missing dtype"))?;
            let dtype = DType::parse(dtype_name)
                .with_context(|| format!("safetensors: unsupported dtype '{dtype_name}'"))?;
            let shape: Vec<usize> = entry
                .get("shape")
                .and_then(|v| v.as_arr())
                .with_context(|| format!("safetensors: tensor '{name}' missing shape"))?
                .iter()
                .map(|d| d.as_usize().context("bad dim"))
                .collect::<Result<_>>()?;
            let offsets = entry
                .get("data_offsets")
                .and_then(|v| v.as_arr())
                .with_context(|| format!("safetensors: tensor '{name}' missing data_offsets"))?;
            if offsets.len() != 2 {
                bail!("safetensors: tensor '{name}' has malformed data_offsets");
            }
            let begin = offsets[0].as_usize().context("bad offset")?;
            let end = offsets[1].as_usize().context("bad offset")?;
            if end < begin || end > data.len() {
                bail!("safetensors: tensor '{name}' offsets out of range");
            }
            let tensor = Tensor::from_bytes(dtype, shape, data[begin..end].to_vec())
                .with_context(|| format!("safetensors: tensor '{name}'"))?;
            ck.insert(name.clone(), tensor);
        }
        Ok(ck)
    }

    fn save_bytes(&self, ck: &Checkpoint) -> Result<Vec<u8>> {
        let mut header = JsonObj::new();
        let mut offset = 0usize;
        for (name, t) in ck.iter() {
            let mut entry = JsonObj::new();
            entry.insert("dtype", t.dtype().safetensors_name());
            entry.insert(
                "shape",
                Json::Arr(t.shape().iter().map(|&d| Json::from(d)).collect()),
            );
            entry.insert(
                "data_offsets",
                Json::Arr(vec![Json::from(offset), Json::from(offset + t.nbytes())]),
            );
            header.insert(name.clone(), entry);
            offset += t.nbytes();
        }
        let mut header_text = Json::Obj(header).to_string_compact();
        // Pad header to 8-byte alignment with spaces (spec allows this and
        // it aligns tensor data for zero-copy readers).
        while (8 + header_text.len()) % 8 != 0 {
            header_text.push(' ');
        }

        let mut out = Vec::with_capacity(8 + header_text.len() + offset);
        out.extend_from_slice(&(header_text.len() as u64).to_le_bytes());
        out.extend_from_slice(header_text.as_bytes());
        for (_, t) in ck.iter() {
            out.extend_from_slice(t.bytes());
        }
        Ok(out)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn sample() -> Checkpoint {
        let mut ck = Checkpoint::new();
        ck.insert("a/w", Tensor::from_f32(vec![2, 3], vec![1., 2., 3., 4., 5., 6.]).unwrap());
        ck.insert("a/b", Tensor::from_f32(vec![3], vec![-1., 0., 1.]).unwrap());
        ck.insert(
            "emb",
            Tensor::from_f32(vec![4, 2], (0..8).map(|x| x as f32).collect())
                .unwrap()
                .cast(DType::BF16)
                .unwrap(),
        );
        ck
    }

    #[test]
    fn roundtrip() {
        let fmt = SafetensorsFormat;
        let bytes = fmt.save_bytes(&sample()).unwrap();
        assert!(fmt.sniff(&bytes[..16]));
        let back = fmt.load_bytes(&bytes).unwrap();
        assert_eq!(back, sample());
    }

    #[test]
    fn header_is_aligned() {
        let bytes = SafetensorsFormat.save_bytes(&sample()).unwrap();
        let hlen = u64::from_le_bytes(bytes[..8].try_into().unwrap()) as usize;
        assert_eq!((8 + hlen) % 8, 0);
    }

    #[test]
    fn rejects_corrupt() {
        let fmt = SafetensorsFormat;
        assert!(fmt.load_bytes(b"short").is_err());
        let mut bytes = fmt.save_bytes(&sample()).unwrap();
        bytes.truncate(bytes.len() - 4); // chop tensor data
        assert!(fmt.load_bytes(&bytes).is_err());
    }

    #[test]
    fn skips_metadata_key() {
        let header = r#"{"__metadata__":{"format":"pt"},"x":{"dtype":"F32","shape":[1],"data_offsets":[0,4]}}"#;
        let mut bytes = Vec::new();
        bytes.extend_from_slice(&(header.len() as u64).to_le_bytes());
        bytes.extend_from_slice(header.as_bytes());
        bytes.extend_from_slice(&1.5f32.to_le_bytes());
        let ck = SafetensorsFormat.load_bytes(&bytes).unwrap();
        assert_eq!(ck.len(), 1);
        assert_eq!(ck.get("x").unwrap().to_f32_vec().unwrap(), vec![1.5]);
    }
}
