//! NumPy `.npz` checkpoint format plug-in (paper §6 future work:
//! "supporting more Checkpoint types").
//!
//! An `.npz` is a ZIP archive of `.npy` members; flax/optax users
//! commonly ship weights this way. Supports the dtypes in
//! [`crate::tensor::DType`], little-endian, C-order; members may be
//! stored (method 0) or deflated (method 8).

use super::registry::CheckpointFormat;
use super::Checkpoint;
use crate::tensor::{DType, Tensor};
use anyhow::{bail, Context, Result};
use std::io::Read;

/// The npz format plug-in.
#[derive(Debug, Default)]
pub struct NpzFormat;

fn dtype_to_descr(dt: DType) -> &'static str {
    match dt {
        DType::F64 => "<f8",
        DType::F32 => "<f4",
        DType::F16 => "<f2",
        // NumPy has no native bf16; we borrow ml_dtypes' "bfloat16"
        // spelling on write and accept <V2 on read is NOT safe, so bf16
        // round-trips through our own descr tag.
        DType::BF16 => "bfloat16",
        DType::I64 => "<i8",
        DType::I32 => "<i4",
        DType::U8 => "|u1",
        DType::Bool => "|b1",
    }
}

fn descr_to_dtype(descr: &str) -> Option<DType> {
    Some(match descr {
        "<f8" | "f8" => DType::F64,
        "<f4" | "f4" => DType::F32,
        "<f2" | "f2" => DType::F16,
        "bfloat16" => DType::BF16,
        "<i8" | "i8" => DType::I64,
        "<i4" | "i4" => DType::I32,
        "|u1" | "u1" => DType::U8,
        "|b1" | "b1" => DType::Bool,
        _ => return None,
    })
}

fn npy_bytes(t: &Tensor) -> Vec<u8> {
    let shape = t
        .shape()
        .iter()
        .map(|d| d.to_string())
        .collect::<Vec<_>>()
        .join(", ");
    let shape = if t.shape().len() == 1 {
        format!("({shape},)")
    } else {
        format!("({shape})")
    };
    let mut header = format!(
        "{{'descr': '{}', 'fortran_order': False, 'shape': {shape}, }}",
        dtype_to_descr(t.dtype())
    );
    // Pad so magic(6)+ver(2)+len(2)+header is a multiple of 64.
    while (10 + header.len() + 1) % 64 != 0 {
        header.push(' ');
    }
    header.push('\n');
    let mut out = Vec::with_capacity(10 + header.len() + t.nbytes());
    out.extend_from_slice(b"\x93NUMPY\x01\x00");
    out.extend_from_slice(&(header.len() as u16).to_le_bytes());
    out.extend_from_slice(header.as_bytes());
    out.extend_from_slice(t.bytes());
    out
}

fn parse_npy(bytes: &[u8]) -> Result<Tensor> {
    if bytes.len() < 10 || &bytes[..6] != b"\x93NUMPY" {
        bail!("npy: bad magic");
    }
    let (hlen, body_at) = match bytes[6] {
        1 => (
            u16::from_le_bytes(bytes[8..10].try_into().unwrap()) as usize,
            10usize,
        ),
        2 | 3 => (
            u32::from_le_bytes(bytes[8..12].try_into().unwrap()) as usize,
            12usize,
        ),
        v => bail!("npy: unsupported version {v}"),
    };
    let header = std::str::from_utf8(&bytes[body_at..body_at + hlen])
        .context("npy: header not utf-8")?;

    let grab = |key: &str| -> Option<&str> {
        let at = header.find(key)?;
        let rest = &header[at + key.len()..];
        let rest = rest.trim_start_matches([':', ' ']);
        Some(rest)
    };
    let descr_raw = grab("'descr'").context("npy: missing descr")?;
    let descr = descr_raw
        .trim_start_matches('\'')
        .split('\'')
        .next()
        .unwrap_or("");
    let dtype = descr_to_dtype(descr)
        .with_context(|| format!("npy: unsupported descr '{descr}'"))?;
    if grab("'fortran_order'")
        .map(|v| v.starts_with("True"))
        .unwrap_or(false)
    {
        bail!("npy: fortran order unsupported");
    }
    let shape_raw = grab("'shape'").context("npy: missing shape")?;
    let inside = shape_raw
        .trim_start_matches('(')
        .split(')')
        .next()
        .unwrap_or("");
    let shape: Vec<usize> = inside
        .split(',')
        .map(|s| s.trim())
        .filter(|s| !s.is_empty())
        .map(|s| s.parse::<usize>().context("npy: bad dim"))
        .collect::<Result<_>>()?;
    let data = &bytes[body_at + hlen..];
    let want = shape.iter().product::<usize>() * dtype.size();
    if data.len() < want {
        bail!("npy: truncated data");
    }
    Ok(Tensor::from_bytes(dtype, shape, data[..want].to_vec())?)
}

// --- minimal ZIP (store + deflate) ---------------------------------------

struct ZipMember {
    name: String,
    data: Vec<u8>,
}

fn crc32(data: &[u8]) -> u32 {
    let mut h = flate2::Crc::new();
    h.update(data);
    h.sum()
}

fn write_zip(members: &[ZipMember]) -> Vec<u8> {
    let mut out = Vec::new();
    let mut central = Vec::new();
    for m in members {
        let offset = out.len() as u32;
        let crc = crc32(&m.data);
        let name = m.name.as_bytes();
        // Local file header, method 0 (stored).
        out.extend_from_slice(&0x04034b50u32.to_le_bytes());
        out.extend_from_slice(&20u16.to_le_bytes()); // version
        out.extend_from_slice(&0u16.to_le_bytes()); // flags
        out.extend_from_slice(&0u16.to_le_bytes()); // method: store
        out.extend_from_slice(&0u32.to_le_bytes()); // dos time/date
        out.extend_from_slice(&crc.to_le_bytes());
        out.extend_from_slice(&(m.data.len() as u32).to_le_bytes());
        out.extend_from_slice(&(m.data.len() as u32).to_le_bytes());
        out.extend_from_slice(&(name.len() as u16).to_le_bytes());
        out.extend_from_slice(&0u16.to_le_bytes()); // extra len
        out.extend_from_slice(name);
        out.extend_from_slice(&m.data);

        central.extend_from_slice(&0x02014b50u32.to_le_bytes());
        central.extend_from_slice(&20u16.to_le_bytes()); // version made by
        central.extend_from_slice(&20u16.to_le_bytes()); // version needed
        central.extend_from_slice(&0u16.to_le_bytes());
        central.extend_from_slice(&0u16.to_le_bytes());
        central.extend_from_slice(&0u32.to_le_bytes());
        central.extend_from_slice(&crc.to_le_bytes());
        central.extend_from_slice(&(m.data.len() as u32).to_le_bytes());
        central.extend_from_slice(&(m.data.len() as u32).to_le_bytes());
        central.extend_from_slice(&(name.len() as u16).to_le_bytes());
        central.extend_from_slice(&0u16.to_le_bytes());
        central.extend_from_slice(&0u16.to_le_bytes());
        central.extend_from_slice(&0u16.to_le_bytes());
        central.extend_from_slice(&0u16.to_le_bytes());
        central.extend_from_slice(&0u32.to_le_bytes());
        central.extend_from_slice(&offset.to_le_bytes());
        central.extend_from_slice(name);
    }
    let central_offset = out.len() as u32;
    out.extend_from_slice(&central);
    // End of central directory.
    out.extend_from_slice(&0x06054b50u32.to_le_bytes());
    out.extend_from_slice(&0u16.to_le_bytes());
    out.extend_from_slice(&0u16.to_le_bytes());
    out.extend_from_slice(&(members.len() as u16).to_le_bytes());
    out.extend_from_slice(&(members.len() as u16).to_le_bytes());
    out.extend_from_slice(&(central.len() as u32).to_le_bytes());
    out.extend_from_slice(&central_offset.to_le_bytes());
    out.extend_from_slice(&0u16.to_le_bytes());
    out
}

fn read_zip(bytes: &[u8]) -> Result<Vec<ZipMember>> {
    // Find end-of-central-directory (scan back; no zip comments expected).
    let eocd = bytes
        .windows(4)
        .rposition(|w| w == 0x06054b50u32.to_le_bytes())
        .context("zip: no end-of-central-directory")?;
    if bytes.len() < eocd + 22 {
        bail!("zip: truncated EOCD");
    }
    let count = u16::from_le_bytes(bytes[eocd + 10..eocd + 12].try_into().unwrap()) as usize;
    let cd_offset = u32::from_le_bytes(bytes[eocd + 16..eocd + 20].try_into().unwrap()) as usize;

    let mut members = Vec::with_capacity(count);
    let mut pos = cd_offset;
    for _ in 0..count {
        if &bytes[pos..pos + 4] != 0x02014b50u32.to_le_bytes().as_slice() {
            bail!("zip: bad central directory entry");
        }
        let method = u16::from_le_bytes(bytes[pos + 10..pos + 12].try_into().unwrap());
        let csize = u32::from_le_bytes(bytes[pos + 20..pos + 24].try_into().unwrap()) as usize;
        let usize_ = u32::from_le_bytes(bytes[pos + 24..pos + 28].try_into().unwrap()) as usize;
        let nlen = u16::from_le_bytes(bytes[pos + 28..pos + 30].try_into().unwrap()) as usize;
        let elen = u16::from_le_bytes(bytes[pos + 30..pos + 32].try_into().unwrap()) as usize;
        let clen = u16::from_le_bytes(bytes[pos + 32..pos + 34].try_into().unwrap()) as usize;
        let lho = u32::from_le_bytes(bytes[pos + 42..pos + 46].try_into().unwrap()) as usize;
        let name = String::from_utf8(bytes[pos + 46..pos + 46 + nlen].to_vec())
            .context("zip: member name not utf-8")?;
        pos += 46 + nlen + elen + clen;

        // Local header: re-read name/extra lengths (can differ from CD).
        let lnlen = u16::from_le_bytes(bytes[lho + 26..lho + 28].try_into().unwrap()) as usize;
        let lelen = u16::from_le_bytes(bytes[lho + 28..lho + 30].try_into().unwrap()) as usize;
        let data_at = lho + 30 + lnlen + lelen;
        let raw = &bytes[data_at..data_at + csize];
        let data = match method {
            0 => raw.to_vec(),
            8 => {
                let mut out = Vec::with_capacity(usize_);
                flate2::read::DeflateDecoder::new(raw)
                    .read_to_end(&mut out)
                    .context("zip: inflate")?;
                out
            }
            m => bail!("zip: unsupported compression method {m}"),
        };
        members.push(ZipMember { name, data });
    }
    Ok(members)
}

impl CheckpointFormat for NpzFormat {
    fn name(&self) -> &'static str {
        "npz"
    }

    fn extensions(&self) -> &'static [&'static str] {
        &["npz"]
    }

    fn sniff(&self, prefix: &[u8]) -> bool {
        prefix.starts_with(b"PK\x03\x04")
    }

    fn load_bytes(&self, bytes: &[u8]) -> Result<Checkpoint> {
        let mut ck = Checkpoint::new();
        for m in read_zip(bytes)? {
            let name = m.name.strip_suffix(".npy").unwrap_or(&m.name);
            ck.insert(
                name.to_string(),
                parse_npy(&m.data).with_context(|| format!("npz member '{}'", m.name))?,
            );
        }
        Ok(ck)
    }

    fn save_bytes(&self, ck: &Checkpoint) -> Result<Vec<u8>> {
        let members: Vec<ZipMember> = ck
            .iter()
            .map(|(name, t)| ZipMember {
                name: format!("{name}.npy"),
                data: npy_bytes(t),
            })
            .collect();
        Ok(write_zip(&members))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn sample() -> Checkpoint {
        let mut ck = Checkpoint::new();
        ck.insert(
            "layer/w",
            Tensor::from_f32(vec![2, 3], vec![1., 2., 3., 4., 5., 6.]).unwrap(),
        );
        ck.insert("idx", Tensor::from_i64(vec![2], vec![-1, 99]).unwrap());
        ck.insert(
            "half",
            Tensor::from_f32(vec![4], vec![0.5, 1.0, -2.0, 0.0])
                .unwrap()
                .cast(DType::F16)
                .unwrap(),
        );
        ck
    }

    #[test]
    fn roundtrip() {
        let fmt = NpzFormat;
        let bytes = fmt.save_bytes(&sample()).unwrap();
        assert!(fmt.sniff(&bytes));
        assert_eq!(fmt.load_bytes(&bytes).unwrap(), sample());
    }

    #[test]
    fn numpy_compatible_npy_header() {
        let t = Tensor::from_f32(vec![3], vec![1., 2., 3.]).unwrap();
        let npy = npy_bytes(&t);
        assert!(npy.starts_with(b"\x93NUMPY\x01\x00"));
        let text = String::from_utf8_lossy(&npy[10..80]);
        assert!(text.contains("'descr': '<f4'"), "{text}");
        assert!(text.contains("'shape': (3,)"), "{text}");
        assert_eq!(parse_npy(&npy).unwrap(), t);
    }

    #[test]
    fn rejects_corrupt() {
        let fmt = NpzFormat;
        assert!(fmt.load_bytes(b"not a zip").is_err());
        let mut bytes = fmt.save_bytes(&sample()).unwrap();
        bytes.truncate(bytes.len() / 2);
        assert!(fmt.load_bytes(&bytes).is_err());
    }

    #[test]
    fn registered_in_registry() {
        crate::init();
        assert!(crate::checkpoint::format_by_name("npz").is_some());
    }
}
