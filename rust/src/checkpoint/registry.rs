//! Checkpoint format plug-in registry.
//!
//! Reproduces the paper's entry-point-based plug-in system: core code
//! looks formats up by name or by file sniffing; users register
//! additional formats at startup with [`register_format`].

use super::{Checkpoint, NativeFormat, NpzFormat, SafetensorsFormat};
use anyhow::{Context, Result};
use once_cell::sync::Lazy;
use std::path::Path;
use std::sync::RwLock;

/// A checkpoint format plug-in ("Checkpoint" in the paper's taxonomy).
pub trait CheckpointFormat: Send + Sync {
    /// Registry key (e.g. "safetensors").
    fn name(&self) -> &'static str;

    /// File extensions this format claims (without dots).
    fn extensions(&self) -> &'static [&'static str];

    /// Cheap content-based detection from the first bytes of a file.
    fn sniff(&self, prefix: &[u8]) -> bool;

    /// Parse a framework-native checkpoint into parameter groups.
    fn load_bytes(&self, bytes: &[u8]) -> Result<Checkpoint>;

    /// Serialize parameter groups back into the framework-native format.
    fn save_bytes(&self, ck: &Checkpoint) -> Result<Vec<u8>>;

    /// Load from a path (default: whole-file read).
    fn load_file(&self, path: &Path) -> Result<Checkpoint> {
        let bytes = std::fs::read(path)
            .with_context(|| format!("reading checkpoint {}", path.display()))?;
        self.load_bytes(&bytes)
    }

    /// Save to a path (default: whole-file write).
    fn save_file(&self, ck: &Checkpoint, path: &Path) -> Result<()> {
        let bytes = self.save_bytes(ck)?;
        std::fs::write(path, bytes)
            .with_context(|| format!("writing checkpoint {}", path.display()))
    }
}

static REGISTRY: Lazy<RwLock<Vec<&'static dyn CheckpointFormat>>> = Lazy::new(|| {
    RwLock::new(vec![
        &SafetensorsFormat as &'static dyn CheckpointFormat,
        &NativeFormat as &'static dyn CheckpointFormat,
        &NpzFormat as &'static dyn CheckpointFormat,
    ])
});

/// Register a user-defined format plug-in (leaked to get 'static).
pub fn register_format(fmt: Box<dyn CheckpointFormat>) {
    REGISTRY.write().unwrap().push(Box::leak(fmt));
}

/// Look up a format by registry name.
pub fn format_by_name(name: &str) -> Option<&'static dyn CheckpointFormat> {
    REGISTRY.read().unwrap().iter().copied().find(|f| f.name() == name)
}

/// Names of all registered formats, in registration order.
pub fn registered_formats() -> Vec<&'static str> {
    REGISTRY.read().unwrap().iter().map(|f| f.name()).collect()
}

/// Pick a format for a file: extension first, then content sniffing.
pub fn detect_format(path: &Path, prefix: &[u8]) -> Option<&'static dyn CheckpointFormat> {
    let ext = path
        .extension()
        .and_then(|e| e.to_str())
        .map(|e| e.to_ascii_lowercase());
    let reg = REGISTRY.read().unwrap();
    if let Some(ext) = &ext {
        if let Some(f) = reg.iter().copied().find(|f| f.extensions().contains(&ext.as_str())) {
            return Some(f);
        }
    }
    reg.iter().copied().find(|f| f.sniff(prefix))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::tensor::Tensor;

    #[test]
    fn builtin_formats_registered() {
        assert!(format_by_name("safetensors").is_some());
        assert!(format_by_name("theta-native").is_some());
        assert!(format_by_name("nope").is_none());
    }

    #[test]
    fn detect_by_extension_and_content() {
        let fmt = detect_format(Path::new("m.safetensors"), b"").unwrap();
        assert_eq!(fmt.name(), "safetensors");
        let fmt = detect_format(Path::new("m.theta"), b"").unwrap();
        assert_eq!(fmt.name(), "theta-native");
        // Unknown extension falls back to sniffing.
        let mut ck = Checkpoint::new();
        ck.insert("x", Tensor::from_f32(vec![1], vec![1.0]).unwrap());
        let bytes = SafetensorsFormat.save_bytes(&ck).unwrap();
        let fmt = detect_format(Path::new("m.bin"), &bytes[..16]).unwrap();
        assert_eq!(fmt.name(), "safetensors");
    }

    #[test]
    fn user_plugin_registration() {
        #[derive(Debug)]
        struct Dummy;
        impl CheckpointFormat for Dummy {
            fn name(&self) -> &'static str {
                "dummy-fmt"
            }
            fn extensions(&self) -> &'static [&'static str] {
                &["dummy"]
            }
            fn sniff(&self, _p: &[u8]) -> bool {
                false
            }
            fn load_bytes(&self, _b: &[u8]) -> Result<Checkpoint> {
                Ok(Checkpoint::new())
            }
            fn save_bytes(&self, _c: &Checkpoint) -> Result<Vec<u8>> {
                Ok(vec![])
            }
        }
        register_format(Box::new(Dummy));
        assert!(format_by_name("dummy-fmt").is_some());
        assert_eq!(
            detect_format(Path::new("x.dummy"), b"").unwrap().name(),
            "dummy-fmt"
        );
    }
}
