//! The Git LFS baseline (paper §4).
//!
//! The paper compares Git-Theta against Git LFS, where each checkpoint
//! version is one opaque blob: "any change to a model file results in a
//! new copy of the entire model being stored". This module packages
//! that workflow so the benchmark harness can run the two systems over
//! identical commit sequences and measure add/checkout wall-clock and
//! on-disk size (Table 1, Figure 2).

use crate::checkpoint::{Checkpoint, CheckpointFormat, SafetensorsFormat};
use crate::gitcore::attributes::Attributes;
use crate::gitcore::object::Oid;
use crate::gitcore::repo::Repository;
use crate::lfs::LfsStore;
use anyhow::Result;
use std::path::Path;

/// A repository that tracks a single checkpoint file as an LFS blob.
pub struct LfsBaselineRepo {
    pub repo: Repository,
    pub model_path: String,
}

impl LfsBaselineRepo {
    pub fn init(dir: &Path, model_path: &str) -> Result<LfsBaselineRepo> {
        crate::init();
        let repo = Repository::init(dir)?;
        Attributes::add_line(repo.worktree(), &format!("{model_path} filter=lfs"))?;
        Ok(LfsBaselineRepo {
            repo,
            model_path: model_path.to_string(),
        })
    }

    /// Write the checkpoint into the working tree (not timed).
    pub fn write_model(&self, ck: &Checkpoint) -> Result<()> {
        SafetensorsFormat.save_file(ck, &self.repo.worktree().join(&self.model_path))
    }

    /// `git add` the model (the timed clean-filter path).
    pub fn add(&self) -> Result<()> {
        self.repo.add(&[self.model_path.as_str()])
    }

    pub fn commit(&self, message: &str) -> Result<Oid> {
        self.repo.commit(message, "bench <bench@localhost>")
    }

    /// `git checkout <rev>` (the timed smudge-filter path).
    pub fn checkout(&self, rev: &str) -> Result<()> {
        self.repo.checkout(rev)
    }

    /// Read the checked-out model back.
    pub fn read_model(&self) -> Result<Checkpoint> {
        SafetensorsFormat.load_file(&self.repo.worktree().join(&self.model_path))
    }

    /// Bytes in the LFS object store (the paper's per-commit "Size").
    pub fn storage_bytes(&self) -> Result<u64> {
        LfsStore::open(self.repo.theta_dir()).disk_usage()
    }
}

/// Same workflow driven through Git-Theta.
pub struct ThetaRepo {
    pub repo: Repository,
    pub model_path: String,
}

impl ThetaRepo {
    pub fn init(dir: &Path, model_path: &str) -> Result<ThetaRepo> {
        crate::init();
        let repo = Repository::init(dir)?;
        crate::theta::track(&repo, model_path)?;
        Ok(ThetaRepo {
            repo,
            model_path: model_path.to_string(),
        })
    }

    pub fn write_model(&self, ck: &Checkpoint) -> Result<()> {
        SafetensorsFormat.save_file(ck, &self.repo.worktree().join(&self.model_path))
    }

    pub fn add(&self) -> Result<()> {
        self.repo.add(&[self.model_path.as_str()])
    }

    pub fn commit(&self, message: &str) -> Result<Oid> {
        self.repo.commit(message, "bench <bench@localhost>")
    }

    pub fn checkout(&self, rev: &str) -> Result<()> {
        self.repo.checkout(rev)
    }

    pub fn read_model(&self) -> Result<Checkpoint> {
        SafetensorsFormat.load_file(&self.repo.worktree().join(&self.model_path))
    }

    pub fn storage_bytes(&self) -> Result<u64> {
        LfsStore::open(self.repo.theta_dir()).disk_usage()
    }

    /// Merge another branch with a strategy (paper: automatic merge).
    pub fn merge_with_strategy(&self, branch: &str, strategy: &str) -> Result<Oid> {
        let opts = crate::gitcore::drivers::MergeOptions {
            strategy: Some(strategy.to_string()),
            ..Default::default()
        };
        let report = self.repo.merge(branch, &opts, "bench <bench@localhost>")?;
        report.commit.ok_or_else(|| anyhow::anyhow!("merge produced no commit"))
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::tensor::Tensor;
    use crate::util::tmp::TempDir;

    fn ck(v: f32) -> Checkpoint {
        let mut c = Checkpoint::new();
        c.insert("w", Tensor::from_f32(vec![100], vec![v; 100]).unwrap());
        c
    }

    #[test]
    fn lfs_baseline_stores_full_copy_per_version() {
        let td = TempDir::new("base").unwrap();
        let b = LfsBaselineRepo::init(td.path(), "m.safetensors").unwrap();
        b.write_model(&ck(1.0)).unwrap();
        b.add().unwrap();
        b.commit("v1").unwrap();
        let s1 = b.storage_bytes().unwrap();
        b.write_model(&ck(2.0)).unwrap();
        b.add().unwrap();
        b.commit("v2").unwrap();
        let s2 = b.storage_bytes().unwrap();
        // Storage doubles: each version is a whole blob.
        assert!(s2 >= 2 * s1 - 16, "s1={s1} s2={s2}");
        assert_eq!(b.read_model().unwrap(), ck(2.0));
    }

    #[test]
    fn theta_repo_shares_unchanged_groups() {
        let td = TempDir::new("theta").unwrap();
        let t = ThetaRepo::init(td.path(), "m.safetensors").unwrap();
        t.write_model(&ck(1.0)).unwrap();
        t.add().unwrap();
        let c1 = t.commit("v1").unwrap();
        let s1 = t.storage_bytes().unwrap();
        // Identical re-add: no new storage.
        t.write_model(&ck(1.0)).unwrap();
        t.add().unwrap();
        let c2 = t.commit("v2 (noop)").unwrap();
        assert_eq!(c1, c2); // empty commit skipped
        assert_eq!(t.storage_bytes().unwrap(), s1);
    }
}
