//! # git-theta-rs
//!
//! A full-system reproduction of **"Git-Theta: A Git Extension for
//! Collaborative Development of Machine Learning Models"** (Kandpal*,
//! Lester*, et al., ICML 2023) as a three-layer Rust + JAX + Pallas
//! stack.
//!
//! Layer 3 (this crate) is the entire request path: a from-scratch
//! content-addressed VCS ([`gitcore`]), an LFS substrate ([`lfs`]),
//! and Git-Theta itself ([`theta`]) — parameter-group-level tracking,
//! communication-efficient updates, LSH change detection, automatic
//! model merging, and meaningful diffs. Layers 2/1 (JAX model + Pallas
//! kernels under `python/compile/`) are AOT-lowered to HLO once and
//! executed from Rust via PJRT ([`runtime`]); Python never runs on the
//! request path.
//!
//! See docs/ARCHITECTURE.md for the per-module map and data flow, and
//! docs/merge-strategies.md for the merge plug-in guide.
#![warn(missing_docs)]

// rustdoc burn-down: fully documented modules participate in
// `missing_docs`; the rest are allowed until their documentation pass
// lands (tracked in ROADMAP.md). New public items in `lfs/` and
// `theta/metadata.rs` must carry docs.
#[allow(missing_docs)]
pub mod baseline;
pub mod benchkit;
#[allow(missing_docs)]
pub mod checkpoint;
#[allow(missing_docs)]
pub mod cli;
pub mod gitcore;
pub mod lfs;
#[allow(missing_docs)]
pub mod mlops;
#[allow(missing_docs)]
pub mod runtime;
#[allow(missing_docs)]
pub mod tensor;
pub mod theta;
#[allow(missing_docs)]
pub mod train;
pub mod util;

/// Register every built-in driver/plug-in (idempotent). Call once at
/// startup before using repositories with filtered files.
pub fn init() {
    use std::sync::Once;
    static ONCE: Once = Once::new();
    ONCE.call_once(|| {
        lfs::register_lfs();
        theta::register_theta();
    });
}
