//! # git-theta-rs
//!
//! A full-system reproduction of **"Git-Theta: A Git Extension for
//! Collaborative Development of Machine Learning Models"** (Kandpal*,
//! Lester*, et al., ICML 2023) as a three-layer Rust + JAX + Pallas
//! stack.
//!
//! Layer 3 (this crate) is the entire request path: a from-scratch
//! content-addressed VCS ([`gitcore`]), an LFS substrate ([`lfs`]),
//! and Git-Theta itself ([`theta`]) — parameter-group-level tracking,
//! communication-efficient updates, LSH change detection, automatic
//! model merging, and meaningful diffs. Layers 2/1 (JAX model + Pallas
//! kernels under `python/compile/`) are AOT-lowered to HLO once and
//! executed from Rust via PJRT ([`runtime`]); Python never runs on the
//! request path.
//!
//! See DESIGN.md for the architecture and EXPERIMENTS.md for the
//! paper-reproduction results (Table 1, Figures 2–3).

pub mod baseline;
pub mod benchkit;
pub mod checkpoint;
pub mod cli;
pub mod gitcore;
pub mod lfs;
pub mod mlops;
pub mod runtime;
pub mod tensor;
pub mod theta;
pub mod train;
pub mod util;

/// Register every built-in driver/plug-in (idempotent). Call once at
/// startup before using repositories with filtered files.
pub fn init() {
    use std::sync::Once;
    static ONCE: Once = Once::new();
    ONCE.call_once(|| {
        lfs::register_lfs();
        theta::register_theta();
    });
}
