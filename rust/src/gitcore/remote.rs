//! Remote endpoints for commit/ref sync: directory- and HTTP-backed.
//!
//! `Repository::push/fetch/pull` used to be hard-wired to a directory
//! on the same filesystem. This module abstracts the endpoint behind
//! [`GitEndpoint`]: [`DirEndpoint`] keeps the original semantics (a
//! bare odb + refs directory), while [`HttpEndpoint`] speaks the
//! `git-theta serve` wire protocol (`/refs`, `/odb`, `/history` — see
//! `lfs/server.rs` for the server half and `docs/ARCHITECTURE.md`
//! "Remotes" for the full protocol). Large-object movement is *not*
//! handled here; the pre-push hooks route it through
//! `lfs::transport`, which shares the same [`RemoteSpec`].

use super::mergebase::commits_between;
use super::object::{Object, Oid};
use super::odb::Odb;
use super::refs::Refs;
use crate::util::http;
use crate::util::json::{Json, JsonObj};
use anyhow::{bail, Context, Result};
use std::fmt;
use std::path::{Path, PathBuf};

/// Where a remote lives: a directory on this filesystem or an HTTP
/// server speaking the `git-theta serve` protocol.
#[derive(Debug, Clone, PartialEq, Eq)]
pub enum RemoteSpec {
    /// A bare directory remote (the seed's only kind).
    Dir(PathBuf),
    /// An `http://host:port` endpoint.
    Http(String),
}

impl RemoteSpec {
    /// Classify a user-supplied remote string: `http://` URLs become
    /// [`RemoteSpec::Http`], plain strings are directory paths, and
    /// any *other* `<scheme>://` is rejected — silently treating
    /// `https://host` as a local directory would fabricate a directory
    /// literally named `https:/host` and report a successful push that
    /// never left the machine.
    pub fn parse(s: &str) -> Result<RemoteSpec> {
        if s.starts_with("http://") {
            return Ok(RemoteSpec::Http(s.trim_end_matches('/').to_string()));
        }
        if let Some((scheme, _)) = s.split_once("://") {
            bail!(
                "unsupported remote scheme '{scheme}://' — git-theta remotes are a \
                 directory path or http://host:port"
            );
        }
        Ok(RemoteSpec::Dir(PathBuf::from(s)))
    }

    /// Classify a path-typed remote (legacy call sites); a path whose
    /// text is an `http://` URL is routed to the HTTP endpoint.
    pub fn from_path(p: &Path) -> RemoteSpec {
        match p.to_str() {
            Some(s) if s.starts_with("http://") => {
                RemoteSpec::Http(s.trim_end_matches('/').to_string())
            }
            _ => RemoteSpec::Dir(p.to_path_buf()),
        }
    }

    /// Whether this spec addresses an HTTP remote.
    pub fn is_http(&self) -> bool {
        matches!(self, RemoteSpec::Http(_))
    }
}

impl fmt::Display for RemoteSpec {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            RemoteSpec::Dir(p) => write!(f, "{}", p.display()),
            RemoteSpec::Http(url) => f.write_str(url),
        }
    }
}

/// Commit/ref operations a push or fetch needs from the remote side.
///
/// Every method is one logical round trip over HTTP; the directory
/// implementation touches the filesystem directly.
pub trait GitEndpoint {
    /// The remote's tip for a branch (`None` if absent).
    fn branch(&self, name: &str) -> Result<Option<Oid>>;

    /// Compare-and-set a branch tip: fails if the remote's current tip
    /// no longer equals `expected` (a concurrent push won the race).
    fn set_branch(&self, name: &str, expected: Option<Oid>, new: &Oid) -> Result<()>;

    /// Whether the remote's odb holds an object.
    fn contains(&self, oid: &Oid) -> Result<bool>;

    /// Read and verify an object from the remote's odb.
    fn read(&self, oid: &Oid) -> Result<Object>;

    /// Write an object into the remote's odb (idempotent).
    fn write(&self, obj: &Object) -> Result<()>;

    /// Of `oids`, the ones the remote's odb lacks — one round trip,
    /// whatever the set size (the odb analogue of the LFS batch call).
    fn missing(&self, oids: &[Oid]) -> Result<Vec<Oid>>;

    /// Commits reachable from `tip` but not from `exclude`, in the
    /// remote history's delivery order (the server walks its own DAG).
    fn commits_between(&self, tip: Oid, exclude: &[Oid]) -> Result<Vec<Oid>>;
}

/// Open the endpoint a spec addresses (directories are created lazily).
pub fn open_endpoint(spec: &RemoteSpec) -> Result<Box<dyn GitEndpoint>> {
    Ok(match spec {
        RemoteSpec::Dir(path) => Box::new(DirEndpoint::open_or_init(path)?),
        RemoteSpec::Http(url) => Box::new(HttpEndpoint::open(url)?),
    })
}

/// A bare directory remote: just an odb and refs (the seed's
/// `RemoteDir`, now behind the endpoint trait).
pub struct DirEndpoint {
    odb: Odb,
    refs: Refs,
}

impl DirEndpoint {
    /// Open a directory remote, initializing its layout if absent.
    pub fn open_or_init(path: &Path) -> Result<DirEndpoint> {
        std::fs::create_dir_all(path.join("refs/heads"))?;
        let odb = Odb::init(path)?;
        let refs = Refs::open(path);
        if !path.join("HEAD").exists() {
            Refs::init(path, "main")?;
        }
        Ok(DirEndpoint { odb, refs })
    }
}

impl GitEndpoint for DirEndpoint {
    fn branch(&self, name: &str) -> Result<Option<Oid>> {
        self.refs.branch(name)
    }

    fn set_branch(&self, name: &str, expected: Option<Oid>, new: &Oid) -> Result<()> {
        let current = self.refs.branch(name)?;
        if current != expected {
            bail!("remote branch '{name}' moved during the push (fetch and retry)");
        }
        self.refs.set_branch(name, new)
    }

    fn contains(&self, oid: &Oid) -> Result<bool> {
        Ok(self.odb.contains(oid))
    }

    fn read(&self, oid: &Oid) -> Result<Object> {
        self.odb.read(oid)
    }

    fn write(&self, obj: &Object) -> Result<()> {
        self.odb.write(obj).map(|_| ())
    }

    fn missing(&self, oids: &[Oid]) -> Result<Vec<Oid>> {
        Ok(oids.iter().filter(|o| !self.odb.contains(o)).copied().collect())
    }

    fn commits_between(&self, tip: Oid, exclude: &[Oid]) -> Result<Vec<Oid>> {
        commits_between(&self.odb, tip, exclude)
    }
}

/// Client half of the HTTP commit/ref protocol.
///
/// Built on the shared [`http::HttpClient`] scaffold, so a whole
/// commit walk (dozens of `/odb` round trips) reuses one keep-alive
/// connection instead of opening one per object.
pub struct HttpEndpoint {
    client: http::HttpClient,
}

impl HttpEndpoint {
    /// Parse the URL; no connection is made until the first call.
    /// URLs with a path component are rejected (the protocol is rooted
    /// at `/`, so a path would be silently ignored).
    pub fn open(url: &str) -> Result<HttpEndpoint> {
        Ok(HttpEndpoint {
            client: http::HttpClient::open(url)?,
        })
    }

    fn url(&self) -> &str {
        self.client.url()
    }

    fn send(&self, req: http::Request) -> Result<http::Response> {
        self.client.send(&req)
    }
}

/// Encode a `{"want": [oid..]}` request body (shared by the odb and
/// LFS halves of the wire protocol).
pub(crate) fn want_body(oids: &[Oid]) -> Vec<u8> {
    let mut obj = JsonObj::new();
    obj.insert(
        "want",
        Json::Arr(oids.iter().map(|o| Json::from(o.to_hex())).collect()),
    );
    Json::Obj(obj).to_string_compact().into_bytes()
}

/// Decode an oid array field from a wire response.
pub(crate) fn parse_oid_arr(json: &Json, key: &str) -> Result<Vec<Oid>> {
    json.get(key)
        .and_then(|v| v.as_arr())
        .with_context(|| format!("remote response missing '{key}'"))?
        .iter()
        .map(|v| Oid::from_hex(v.as_str().context("non-string oid in remote response")?))
        .collect()
}

/// Parse a wire response body as JSON.
pub(crate) fn parse_json(resp: &http::Response) -> Result<Json> {
    Json::parse(&String::from_utf8_lossy(&resp.body)).context("parsing remote json response")
}

impl GitEndpoint for HttpEndpoint {
    fn branch(&self, name: &str) -> Result<Option<Oid>> {
        let resp = self.send(http::Request::new("GET", &format!("/refs/{name}")))?;
        match resp.status {
            200 => Ok(Some(Oid::from_hex(String::from_utf8_lossy(&resp.body).trim())?)),
            404 => Ok(None),
            s => bail!("{}: GET /refs/{name} -> {s}", self.url()),
        }
    }

    fn set_branch(&self, name: &str, expected: Option<Oid>, new: &Oid) -> Result<()> {
        let old = match expected {
            Some(oid) => oid.to_hex(),
            None => "none".to_string(),
        };
        let body = format!("{old} {}", new.to_hex()).into_bytes();
        let resp = self.send(http::Request::new("PUT", &format!("/refs/{name}")).body(body))?;
        match resp.status {
            200 => Ok(()),
            409 => bail!("remote branch '{name}' moved during the push (fetch and retry)"),
            s => bail!("{}: PUT /refs/{name} -> {s}", self.url()),
        }
    }

    fn contains(&self, oid: &Oid) -> Result<bool> {
        let resp = self.send(http::Request::new("HEAD", &format!("/odb/{}", oid.to_hex())))?;
        match resp.status {
            200 => Ok(true),
            404 => Ok(false),
            s => bail!("{}: HEAD /odb/{} -> {s}", self.url(), oid.short()),
        }
    }

    fn read(&self, oid: &Oid) -> Result<Object> {
        let resp = self.send(http::Request::new("GET", &format!("/odb/{}", oid.to_hex())))?;
        if resp.status == 404 {
            bail!("object {} not found on {}", oid.short(), self.url());
        }
        if resp.status != 200 {
            bail!("{}: GET /odb/{} -> {}", self.url(), oid.short(), resp.status);
        }
        if Oid::of_bytes(&resp.body) != *oid {
            bail!("object {} from {} failed its content hash", oid.short(), self.url());
        }
        Object::decode(&resp.body)
    }

    fn write(&self, obj: &Object) -> Result<()> {
        let encoded = obj.encode();
        let oid = Oid::of_bytes(&encoded);
        let req = http::Request::new("PUT", &format!("/odb/{}", oid.to_hex())).body(encoded);
        let resp = self.send(req)?;
        if resp.status != 200 {
            bail!("{}: PUT /odb/{} -> {}", self.url(), oid.short(), resp.status);
        }
        Ok(())
    }

    fn missing(&self, oids: &[Oid]) -> Result<Vec<Oid>> {
        if oids.is_empty() {
            return Ok(Vec::new());
        }
        let req = http::Request::new("POST", "/odb/batch").body(want_body(oids));
        let resp = self.send(req)?;
        if resp.status != 200 {
            bail!("{}: POST /odb/batch -> {}", self.url(), resp.status);
        }
        parse_oid_arr(&parse_json(&resp)?, "missing")
    }

    fn commits_between(&self, tip: Oid, exclude: &[Oid]) -> Result<Vec<Oid>> {
        let exclude_csv: Vec<String> = exclude.iter().map(|o| o.to_hex()).collect();
        let target = if exclude_csv.is_empty() {
            format!("/history/{}", tip.to_hex())
        } else {
            format!("/history/{}?exclude={}", tip.to_hex(), exclude_csv.join(","))
        };
        let resp = self.send(http::Request::new("GET", &target))?;
        if resp.status != 200 {
            bail!(
                "{}: history walk from {} failed ({}): {}",
                self.url(),
                tip.short(),
                resp.status,
                String::from_utf8_lossy(&resp.body)
            );
        }
        parse_oid_arr(&parse_json(&resp)?, "commits")
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn spec_parsing_and_display() {
        assert_eq!(
            RemoteSpec::parse("/srv/models").unwrap(),
            RemoteSpec::Dir(PathBuf::from("/srv/models"))
        );
        assert_eq!(
            RemoteSpec::parse("http://127.0.0.1:8123/").unwrap(),
            RemoteSpec::Http("http://127.0.0.1:8123".into())
        );
        assert!(RemoteSpec::parse("http://h:1").unwrap().is_http());
        assert!(!RemoteSpec::parse("relative/dir").unwrap().is_http());
        assert_eq!(
            RemoteSpec::parse("http://h:1").unwrap().to_string(),
            "http://h:1"
        );
        assert_eq!(
            RemoteSpec::from_path(Path::new("http://h:2")),
            RemoteSpec::Http("http://h:2".into())
        );
        // Unsupported schemes fail fast instead of minting a local
        // directory named after the URL.
        assert!(RemoteSpec::parse("https://models.lab:8417").is_err());
        assert!(RemoteSpec::parse("ssh://host/repo").is_err());
    }

    #[test]
    fn dir_endpoint_cas_rejects_moved_branch() {
        let td = crate::util::tmp::TempDir::new("gitremote").unwrap();
        let ep = DirEndpoint::open_or_init(td.path()).unwrap();
        let a = Oid::of_bytes(b"a");
        let b = Oid::of_bytes(b"b");
        ep.set_branch("main", None, &a).unwrap();
        assert_eq!(ep.branch("main").unwrap(), Some(a));
        // Stale expectation: someone else moved the branch.
        assert!(ep.set_branch("main", None, &b).is_err());
        ep.set_branch("main", Some(a), &b).unwrap();
        assert_eq!(ep.branch("main").unwrap(), Some(b));
    }
}
