//! Remote endpoints for commit/ref sync: directory- and HTTP-backed.
//!
//! `Repository::push/fetch/pull` used to be hard-wired to a directory
//! on the same filesystem. This module abstracts the endpoint behind
//! [`GitEndpoint`]: [`DirEndpoint`] keeps the original semantics (a
//! bare odb + refs directory), while [`HttpEndpoint`] speaks the
//! `git-theta serve` wire protocol (`/refs`, `/odb`, `/history` — see
//! `lfs/server.rs` for the server half and `docs/ARCHITECTURE.md`
//! "Remotes" for the full protocol). Large-object movement is *not*
//! handled here; the pre-push hooks route it through
//! `lfs::transport`, which shares the same [`RemoteSpec`].

use super::mergebase::commits_between;
use super::object::{Object, Oid};
use super::odb::Odb;
use super::refs::Refs;
use crate::util::http;
use crate::util::json::{Json, JsonObj};
use anyhow::{bail, Context, Result};
use std::fmt;
use std::path::{Path, PathBuf};

/// Where a remote lives: a directory on this filesystem, an HTTP
/// server speaking the `git-theta serve` protocol, or a replica set of
/// several such mirrors addressed as one logical remote.
#[derive(Debug, Clone, PartialEq, Eq)]
pub enum RemoteSpec {
    /// A bare directory remote (the seed's only kind).
    Dir(PathBuf),
    /// An `http://host:port` endpoint.
    Http(String),
    /// A comma-separated replica set of two or more mirrors (Dir or
    /// Http, mixed). Pushes fan out to every mirror and succeed at a
    /// write quorum; fetches fail over between them.
    Replica(Vec<RemoteSpec>),
}

impl RemoteSpec {
    /// Classify a user-supplied remote string: `http://` URLs become
    /// [`RemoteSpec::Http`], plain strings are directory paths, and
    /// any *other* `<scheme>://` is rejected — silently treating
    /// `https://host` as a local directory would fabricate a directory
    /// literally named `https:/host` and report a successful push that
    /// never left the machine. A comma-separated list of endpoints
    /// parses as a [`RemoteSpec::Replica`] set; duplicate entries are
    /// dropped with a warning (a duplicated mirror would silently
    /// double-push), and a list whose entries are *all* the same
    /// endpoint is rejected outright — it is one remote wearing a
    /// replica costume, and accepting it would report N-way redundancy
    /// that does not exist.
    pub fn parse(s: &str) -> Result<RemoteSpec> {
        if s.contains(',') {
            return RemoteSpec::parse_replica(s);
        }
        RemoteSpec::parse_single(s)
    }

    fn parse_single(s: &str) -> Result<RemoteSpec> {
        if s.starts_with("http://") {
            return Ok(RemoteSpec::Http(s.trim_end_matches('/').to_string()));
        }
        if let Some((scheme, _)) = s.split_once("://") {
            bail!(
                "unsupported remote scheme '{scheme}://' — git-theta remotes are a \
                 directory path or http://host:port"
            );
        }
        Ok(RemoteSpec::Dir(PathBuf::from(s)))
    }

    fn parse_replica(s: &str) -> Result<RemoteSpec> {
        let entries: Vec<&str> = s.split(',').map(str::trim).filter(|e| !e.is_empty()).collect();
        if entries.is_empty() {
            bail!("empty replica set '{s}' — list at least one endpoint");
        }
        let mut mirrors: Vec<RemoteSpec> = Vec::new();
        let mut dropped = 0usize;
        for entry in &entries {
            let spec = RemoteSpec::parse_single(entry)?;
            if mirrors.contains(&spec) {
                eprintln!(
                    "warning: duplicate mirror '{spec}' in replica set dropped \
                     (it would be pushed twice)"
                );
                dropped += 1;
            } else {
                mirrors.push(spec);
            }
        }
        if mirrors.len() == 1 {
            if dropped > 0 {
                // Fail closed: every entry named the same endpoint, so
                // the promised redundancy is fictional.
                bail!(
                    "replica set '{s}' lists the same endpoint {} times — \
                     a replica set needs at least two distinct mirrors",
                    dropped + 1
                );
            }
            // A single-entry "list" (e.g. a trailing comma) is just
            // that endpoint; no replica wrapper.
            return Ok(mirrors.remove(0));
        }
        Ok(RemoteSpec::Replica(mirrors))
    }

    /// The individual mirrors this spec addresses: the set's members
    /// for a replica, otherwise the spec itself.
    pub fn mirrors(&self) -> Vec<RemoteSpec> {
        match self {
            RemoteSpec::Replica(set) => set.clone(),
            other => vec![other.clone()],
        }
    }

    /// Classify a path-typed remote (legacy call sites); a path whose
    /// text is an `http://` URL is routed to the HTTP endpoint.
    pub fn from_path(p: &Path) -> RemoteSpec {
        match p.to_str() {
            Some(s) if s.starts_with("http://") => {
                RemoteSpec::Http(s.trim_end_matches('/').to_string())
            }
            _ => RemoteSpec::Dir(p.to_path_buf()),
        }
    }

    /// Whether this spec addresses an HTTP remote (for a replica set:
    /// whether any mirror does).
    pub fn is_http(&self) -> bool {
        match self {
            RemoteSpec::Http(_) => true,
            RemoteSpec::Replica(set) => set.iter().any(RemoteSpec::is_http),
            RemoteSpec::Dir(_) => false,
        }
    }
}

impl fmt::Display for RemoteSpec {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            RemoteSpec::Dir(p) => write!(f, "{}", p.display()),
            RemoteSpec::Http(url) => f.write_str(url),
            RemoteSpec::Replica(set) => {
                for (i, spec) in set.iter().enumerate() {
                    if i > 0 {
                        f.write_str(",")?;
                    }
                    write!(f, "{spec}")?;
                }
                Ok(())
            }
        }
    }
}

/// Commit/ref operations a push or fetch needs from the remote side.
///
/// Every method is one logical round trip over HTTP; the directory
/// implementation touches the filesystem directly.
pub trait GitEndpoint {
    /// The remote's tip for a branch (`None` if absent).
    fn branch(&self, name: &str) -> Result<Option<Oid>>;

    /// Compare-and-set a branch tip: fails if the remote's current tip
    /// no longer equals `expected` (a concurrent push won the race).
    fn set_branch(&self, name: &str, expected: Option<Oid>, new: &Oid) -> Result<()>;

    /// Whether the remote's odb holds an object.
    fn contains(&self, oid: &Oid) -> Result<bool>;

    /// Read and verify an object from the remote's odb.
    fn read(&self, oid: &Oid) -> Result<Object>;

    /// Write an object into the remote's odb (idempotent).
    fn write(&self, obj: &Object) -> Result<()>;

    /// Of `oids`, the ones the remote's odb lacks — one round trip,
    /// whatever the set size (the odb analogue of the LFS batch call).
    fn missing(&self, oids: &[Oid]) -> Result<Vec<Oid>>;

    /// Commits reachable from `tip` but not from `exclude`, in the
    /// remote history's delivery order (the server walks its own DAG).
    fn commits_between(&self, tip: Oid, exclude: &[Oid]) -> Result<Vec<Oid>>;
}

/// Open the endpoint a spec addresses (directories are created lazily).
/// A replica set opens as a [`ReplicatedEndpoint`] requiring every
/// mirror for writes; use [`open_endpoint_with_quorum`] to relax that.
pub fn open_endpoint(spec: &RemoteSpec) -> Result<Box<dyn GitEndpoint>> {
    open_endpoint_with_quorum(spec, None)
}

/// Open the endpoint a spec addresses with an explicit write quorum
/// for replica sets (`None` = all mirrors; clamped to `1..=N`).
/// Non-replica specs ignore `quorum`.
pub fn open_endpoint_with_quorum(
    spec: &RemoteSpec,
    quorum: Option<usize>,
) -> Result<Box<dyn GitEndpoint>> {
    Ok(match spec {
        RemoteSpec::Dir(path) => Box::new(DirEndpoint::open_or_init(path)?),
        RemoteSpec::Http(url) => Box::new(HttpEndpoint::open(url)?),
        RemoteSpec::Replica(set) => {
            let mirrors = set
                .iter()
                .map(open_endpoint)
                .collect::<Result<Vec<_>>>()?;
            Box::new(ReplicatedEndpoint::new(mirrors, quorum))
        }
    })
}

/// Commit/ref replication over N mirrors: reads come from the first
/// mirror that answers (falling through dead or lacking ones), writes
/// fan out to every mirror and succeed once `quorum` of them do.
///
/// A mirror that missed an earlier quorum write fails its CAS on the
/// next push (its tip is behind the expectation read from a fresh
/// mirror) and simply stays behind, still internally consistent at its
/// old tip — `git-theta replicate --repair` fast-forwards it. This is
/// the odb/ref twin of the LFS-side
/// [`ReplicatedRemote`](crate::lfs::replicate::ReplicatedRemote).
pub struct ReplicatedEndpoint {
    mirrors: Vec<Box<dyn GitEndpoint>>,
    quorum: usize,
}

impl ReplicatedEndpoint {
    /// Wrap `mirrors` with a write quorum (`None` = all, clamped to
    /// `1..=N`).
    pub fn new(mirrors: Vec<Box<dyn GitEndpoint>>, quorum: Option<usize>) -> ReplicatedEndpoint {
        let n = mirrors.len().max(1);
        let quorum = quorum.unwrap_or(n).clamp(1, n);
        ReplicatedEndpoint { mirrors, quorum }
    }

    /// Run `op` against mirrors in order, returning the first success;
    /// if every mirror fails, the last error (with fall-through
    /// context) surfaces.
    fn first_ok<T>(
        &self,
        what: &str,
        op: impl Fn(&dyn GitEndpoint) -> Result<T>,
    ) -> Result<T> {
        let mut last: Option<anyhow::Error> = None;
        for mirror in &self.mirrors {
            match op(mirror.as_ref()) {
                Ok(v) => return Ok(v),
                Err(e) => last = Some(e),
            }
        }
        Err(last
            .unwrap_or_else(|| anyhow::anyhow!("replica set has no mirrors"))
            .context(format!("{what} failed on every mirror of the replica set")))
    }

    /// Fan `op` out to every mirror; succeed once `quorum` do,
    /// otherwise surface an error naming each mirror failure.
    fn quorum_write(&self, what: &str, op: impl Fn(&dyn GitEndpoint) -> Result<()>) -> Result<()> {
        let mut successes = 0usize;
        let mut failures: Vec<String> = Vec::new();
        for (i, mirror) in self.mirrors.iter().enumerate() {
            match op(mirror.as_ref()) {
                Ok(()) => successes += 1,
                Err(e) => failures.push(format!("mirror {i}: {e:#}")),
            }
        }
        if successes >= self.quorum {
            return Ok(());
        }
        bail!(
            "{what}: write quorum not met ({successes}/{} mirrors succeeded, quorum {}): {}",
            self.mirrors.len(),
            self.quorum,
            failures.join("; ")
        );
    }
}

impl GitEndpoint for ReplicatedEndpoint {
    fn branch(&self, name: &str) -> Result<Option<Oid>> {
        self.first_ok("reading branch tip", |m| m.branch(name))
    }

    fn set_branch(&self, name: &str, expected: Option<Oid>, new: &Oid) -> Result<()> {
        self.quorum_write("updating branch tip", |m| m.set_branch(name, expected, new))
    }

    fn contains(&self, oid: &Oid) -> Result<bool> {
        self.first_ok("odb membership check", |m| m.contains(oid))
    }

    fn read(&self, oid: &Oid) -> Result<Object> {
        // Fall through mirrors that lack the object (a laggard replica)
        // as well as dead ones — any holder serves the read.
        self.first_ok("odb read", |m| m.read(oid))
    }

    fn write(&self, obj: &Object) -> Result<()> {
        self.quorum_write("odb write", |m| m.write(obj))
    }

    fn missing(&self, oids: &[Oid]) -> Result<Vec<Oid>> {
        // Union across reachable mirrors: an object any mirror lacks
        // must be pushed (writes are idempotent, so mirrors that
        // already hold it dedup on arrival). At least one mirror must
        // answer, or the push has nothing truthful to go on.
        let mut missing: Vec<Oid> = Vec::new();
        let mut answered = false;
        let mut last: Option<anyhow::Error> = None;
        for mirror in &self.mirrors {
            match mirror.missing(oids) {
                Ok(m) => {
                    answered = true;
                    for oid in m {
                        if !missing.contains(&oid) {
                            missing.push(oid);
                        }
                    }
                }
                Err(e) => last = Some(e),
            }
        }
        if !answered {
            return Err(last
                .unwrap_or_else(|| anyhow::anyhow!("replica set has no mirrors"))
                .context("odb negotiation failed on every mirror of the replica set"));
        }
        Ok(missing)
    }

    fn commits_between(&self, tip: Oid, exclude: &[Oid]) -> Result<Vec<Oid>> {
        self.first_ok("history walk", |m| m.commits_between(tip, exclude))
    }
}

/// A bare directory remote: just an odb and refs (the seed's
/// `RemoteDir`, now behind the endpoint trait).
pub struct DirEndpoint {
    odb: Odb,
    refs: Refs,
}

impl DirEndpoint {
    /// Open a directory remote, initializing its layout if absent.
    pub fn open_or_init(path: &Path) -> Result<DirEndpoint> {
        std::fs::create_dir_all(path.join("refs/heads"))?;
        let odb = Odb::init(path)?;
        let refs = Refs::open(path);
        if !path.join("HEAD").exists() {
            Refs::init(path, "main")?;
        }
        Ok(DirEndpoint { odb, refs })
    }
}

impl GitEndpoint for DirEndpoint {
    fn branch(&self, name: &str) -> Result<Option<Oid>> {
        self.refs.branch(name)
    }

    fn set_branch(&self, name: &str, expected: Option<Oid>, new: &Oid) -> Result<()> {
        let current = self.refs.branch(name)?;
        if current != expected {
            bail!("remote branch '{name}' moved during the push (fetch and retry)");
        }
        self.refs.set_branch(name, new)
    }

    fn contains(&self, oid: &Oid) -> Result<bool> {
        Ok(self.odb.contains(oid))
    }

    fn read(&self, oid: &Oid) -> Result<Object> {
        self.odb.read(oid)
    }

    fn write(&self, obj: &Object) -> Result<()> {
        self.odb.write(obj).map(|_| ())
    }

    fn missing(&self, oids: &[Oid]) -> Result<Vec<Oid>> {
        Ok(oids.iter().filter(|o| !self.odb.contains(o)).copied().collect())
    }

    fn commits_between(&self, tip: Oid, exclude: &[Oid]) -> Result<Vec<Oid>> {
        commits_between(&self.odb, tip, exclude)
    }
}

/// Client half of the HTTP commit/ref protocol.
///
/// Built on the shared [`http::HttpClient`] scaffold, so a whole
/// commit walk (dozens of `/odb` round trips) reuses one keep-alive
/// connection instead of opening one per object.
pub struct HttpEndpoint {
    client: http::HttpClient,
}

impl HttpEndpoint {
    /// Parse the URL; no connection is made until the first call.
    /// URLs with a path component are rejected (the protocol is rooted
    /// at `/`, so a path would be silently ignored).
    pub fn open(url: &str) -> Result<HttpEndpoint> {
        Ok(HttpEndpoint {
            client: http::HttpClient::open(url)?,
        })
    }

    fn url(&self) -> &str {
        self.client.url()
    }

    fn send(&self, req: http::Request) -> Result<http::Response> {
        self.client.send(&req)
    }
}

/// Encode a `{"want": [oid..]}` request body (shared by the odb and
/// LFS halves of the wire protocol).
pub(crate) fn want_body(oids: &[Oid]) -> Vec<u8> {
    let mut obj = JsonObj::new();
    obj.insert(
        "want",
        Json::Arr(oids.iter().map(|o| Json::from(o.to_hex())).collect()),
    );
    Json::Obj(obj).to_string_compact().into_bytes()
}

/// Decode an oid array field from a wire response.
pub(crate) fn parse_oid_arr(json: &Json, key: &str) -> Result<Vec<Oid>> {
    json.get(key)
        .and_then(|v| v.as_arr())
        .with_context(|| format!("remote response missing '{key}'"))?
        .iter()
        .map(|v| Oid::from_hex(v.as_str().context("non-string oid in remote response")?))
        .collect()
}

/// Parse a wire response body as JSON.
pub(crate) fn parse_json(resp: &http::Response) -> Result<Json> {
    Json::parse(&String::from_utf8_lossy(&resp.body)).context("parsing remote json response")
}

impl GitEndpoint for HttpEndpoint {
    fn branch(&self, name: &str) -> Result<Option<Oid>> {
        let resp = self.send(http::Request::new("GET", &format!("/refs/{name}")))?;
        match resp.status {
            200 => Ok(Some(Oid::from_hex(String::from_utf8_lossy(&resp.body).trim())?)),
            404 => Ok(None),
            s => bail!("{}: GET /refs/{name} -> {s}", self.url()),
        }
    }

    fn set_branch(&self, name: &str, expected: Option<Oid>, new: &Oid) -> Result<()> {
        let old = match expected {
            Some(oid) => oid.to_hex(),
            None => "none".to_string(),
        };
        let body = format!("{old} {}", new.to_hex()).into_bytes();
        let resp = self.send(http::Request::new("PUT", &format!("/refs/{name}")).body(body))?;
        match resp.status {
            200 => Ok(()),
            409 => bail!("remote branch '{name}' moved during the push (fetch and retry)"),
            s => bail!("{}: PUT /refs/{name} -> {s}", self.url()),
        }
    }

    fn contains(&self, oid: &Oid) -> Result<bool> {
        let resp = self.send(http::Request::new("HEAD", &format!("/odb/{}", oid.to_hex())))?;
        match resp.status {
            200 => Ok(true),
            404 => Ok(false),
            s => bail!("{}: HEAD /odb/{} -> {s}", self.url(), oid.short()),
        }
    }

    fn read(&self, oid: &Oid) -> Result<Object> {
        let resp = self.send(http::Request::new("GET", &format!("/odb/{}", oid.to_hex())))?;
        if resp.status == 404 {
            bail!("object {} not found on {}", oid.short(), self.url());
        }
        if resp.status != 200 {
            bail!("{}: GET /odb/{} -> {}", self.url(), oid.short(), resp.status);
        }
        if Oid::of_bytes(&resp.body) != *oid {
            bail!("object {} from {} failed its content hash", oid.short(), self.url());
        }
        Object::decode(&resp.body)
    }

    fn write(&self, obj: &Object) -> Result<()> {
        let encoded = obj.encode();
        let oid = Oid::of_bytes(&encoded);
        let req = http::Request::new("PUT", &format!("/odb/{}", oid.to_hex())).body(encoded);
        let resp = self.send(req)?;
        if resp.status != 200 {
            bail!("{}: PUT /odb/{} -> {}", self.url(), oid.short(), resp.status);
        }
        Ok(())
    }

    fn missing(&self, oids: &[Oid]) -> Result<Vec<Oid>> {
        if oids.is_empty() {
            return Ok(Vec::new());
        }
        let req = http::Request::new("POST", "/odb/batch").body(want_body(oids));
        let resp = self.send(req)?;
        if resp.status != 200 {
            bail!("{}: POST /odb/batch -> {}", self.url(), resp.status);
        }
        parse_oid_arr(&parse_json(&resp)?, "missing")
    }

    fn commits_between(&self, tip: Oid, exclude: &[Oid]) -> Result<Vec<Oid>> {
        let exclude_csv: Vec<String> = exclude.iter().map(|o| o.to_hex()).collect();
        let target = if exclude_csv.is_empty() {
            format!("/history/{}", tip.to_hex())
        } else {
            format!("/history/{}?exclude={}", tip.to_hex(), exclude_csv.join(","))
        };
        let resp = self.send(http::Request::new("GET", &target))?;
        if resp.status != 200 {
            bail!(
                "{}: history walk from {} failed ({}): {}",
                self.url(),
                tip.short(),
                resp.status,
                String::from_utf8_lossy(&resp.body)
            );
        }
        parse_oid_arr(&parse_json(&resp)?, "commits")
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn spec_parsing_and_display() {
        assert_eq!(
            RemoteSpec::parse("/srv/models").unwrap(),
            RemoteSpec::Dir(PathBuf::from("/srv/models"))
        );
        assert_eq!(
            RemoteSpec::parse("http://127.0.0.1:8123/").unwrap(),
            RemoteSpec::Http("http://127.0.0.1:8123".into())
        );
        assert!(RemoteSpec::parse("http://h:1").unwrap().is_http());
        assert!(!RemoteSpec::parse("relative/dir").unwrap().is_http());
        assert_eq!(
            RemoteSpec::parse("http://h:1").unwrap().to_string(),
            "http://h:1"
        );
        assert_eq!(
            RemoteSpec::from_path(Path::new("http://h:2")),
            RemoteSpec::Http("http://h:2".into())
        );
        // Unsupported schemes fail fast instead of minting a local
        // directory named after the URL.
        assert!(RemoteSpec::parse("https://models.lab:8417").is_err());
        assert!(RemoteSpec::parse("ssh://host/repo").is_err());
    }

    #[test]
    fn replica_spec_parses_dedups_and_fails_closed() {
        // Mixed-kind list parses, preserves order, and round-trips
        // through Display.
        let spec = RemoteSpec::parse("/srv/a,http://h:1,/srv/b").unwrap();
        assert_eq!(
            spec,
            RemoteSpec::Replica(vec![
                RemoteSpec::Dir(PathBuf::from("/srv/a")),
                RemoteSpec::Http("http://h:1".into()),
                RemoteSpec::Dir(PathBuf::from("/srv/b")),
            ])
        );
        assert_eq!(spec.to_string(), "/srv/a,http://h:1,/srv/b");
        assert_eq!(RemoteSpec::parse(&spec.to_string()).unwrap(), spec);
        assert!(spec.is_http());
        assert_eq!(spec.mirrors().len(), 3);

        // Duplicates are dropped (with a warning), not double-pushed.
        assert_eq!(
            RemoteSpec::parse("/srv/a,/srv/b,/srv/a").unwrap(),
            RemoteSpec::Replica(vec![
                RemoteSpec::Dir(PathBuf::from("/srv/a")),
                RemoteSpec::Dir(PathBuf::from("/srv/b")),
            ])
        );

        // A fully-duplicate list is one remote in a replica costume:
        // fail closed rather than promise redundancy that isn't there.
        assert!(RemoteSpec::parse("/srv/a,/srv/a").is_err());
        assert!(RemoteSpec::parse("http://h:1,http://h:1/").is_err());

        // A trailing comma is a single endpoint, not a replica set.
        assert_eq!(
            RemoteSpec::parse("/srv/a,").unwrap(),
            RemoteSpec::Dir(PathBuf::from("/srv/a"))
        );
        assert!(RemoteSpec::parse(",,").is_err());
        // One bad scheme poisons the whole list.
        assert!(RemoteSpec::parse("/srv/a,ssh://host/repo").is_err());
    }

    #[test]
    fn replicated_endpoint_quorum_and_fallthrough() {
        let td = crate::util::tmp::TempDir::new("gitreplica").unwrap();
        let a_dir = td.path().join("a");
        let b_dir = td.path().join("b");
        let a = Oid::of_bytes(b"commit-a");
        let b = Oid::of_bytes(b"commit-b");

        // Quorum 2/2 (default): a write lands on both mirrors.
        let ep = ReplicatedEndpoint::new(
            vec![
                Box::new(DirEndpoint::open_or_init(&a_dir).unwrap()),
                Box::new(DirEndpoint::open_or_init(&b_dir).unwrap()),
            ],
            None,
        );
        ep.set_branch("main", None, &a).unwrap();
        assert_eq!(
            DirEndpoint::open_or_init(&a_dir).unwrap().branch("main").unwrap(),
            Some(a)
        );
        assert_eq!(
            DirEndpoint::open_or_init(&b_dir).unwrap().branch("main").unwrap(),
            Some(a)
        );

        // Desynchronize mirror b (simulates a missed quorum write).
        DirEndpoint::open_or_init(&b_dir)
            .unwrap()
            .set_branch("main", Some(a), &b)
            .unwrap();

        // Quorum 2/2: the divergent CAS fails the whole write.
        assert!(ep.set_branch("main", Some(a), &b).is_err());

        // Quorum 1/2: the same write succeeds on the mirror whose tip
        // still matches, and the laggard is left to repair.
        let ep1 = ReplicatedEndpoint::new(
            vec![
                Box::new(DirEndpoint::open_or_init(&a_dir).unwrap()),
                Box::new(DirEndpoint::open_or_init(&b_dir).unwrap()),
            ],
            Some(1),
        );
        ep1.set_branch("main", Some(a), &b).unwrap();
        assert_eq!(ep1.branch("main").unwrap(), Some(b));

        // missing() is the union across mirrors: an object held by only
        // one mirror still counts as missing (the push must fan it out).
        let obj = Object::Blob(b"payload".to_vec());
        DirEndpoint::open_or_init(&a_dir).unwrap().write(&obj).unwrap();
        let oid = Oid::of_bytes(&obj.encode());
        assert_eq!(ep1.missing(&[oid]).unwrap(), vec![oid]);
        ep1.write(&obj).unwrap();
        assert!(ep1.missing(&[oid]).unwrap().is_empty());
    }

    #[test]
    fn dir_endpoint_cas_rejects_moved_branch() {
        let td = crate::util::tmp::TempDir::new("gitremote").unwrap();
        let ep = DirEndpoint::open_or_init(td.path()).unwrap();
        let a = Oid::of_bytes(b"a");
        let b = Oid::of_bytes(b"b");
        ep.set_branch("main", None, &a).unwrap();
        assert_eq!(ep.branch("main").unwrap(), Some(a));
        // Stale expectation: someone else moved the branch.
        assert!(ep.set_branch("main", None, &b).is_err());
        ep.set_branch("main", Some(a), &b).unwrap();
        assert_eq!(ep.branch("main").unwrap(), Some(b));
    }
}
