//! The staging area (`.theta/index`).
//!
//! Maps repository-relative paths to staged blob oids. What lands here
//! for filtered files is the *clean-filter output* (for Git-Theta: the
//! model metadata file), exactly as in Git.

use super::object::Oid;
use crate::util::json::{Json, JsonObj};
use anyhow::{Context, Result};
use std::collections::BTreeMap;
use std::path::{Path, PathBuf};

/// One staged file.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct IndexEntry {
    /// Oid of the staged blob (the clean-filter output for filtered
    /// files).
    pub oid: Oid,
    /// Size of the staged blob in bytes.
    pub size: u64,
    /// Hash of the *raw working-tree* content at staging time (before the
    /// clean filter ran). Lets `status` detect modifications without
    /// re-running expensive filters.
    pub raw: Oid,
}

/// The staging index.
#[derive(Debug, Clone, Default, PartialEq)]
pub struct Index {
    entries: BTreeMap<String, IndexEntry>,
}

impl Index {
    /// An empty index.
    pub fn new() -> Index {
        Index::default()
    }

    /// Load the index from `.theta/index` (empty if absent).
    pub fn load(theta_dir: &Path) -> Result<Index> {
        let path = index_path(theta_dir);
        if !path.exists() {
            return Ok(Index::new());
        }
        let text = std::fs::read_to_string(&path).context("reading index")?;
        let json = Json::parse(&text).context("parsing index")?;
        let obj = json
            .get("entries")
            .and_then(|v| v.as_obj())
            .context("index missing entries")?;
        let mut entries = BTreeMap::new();
        for (path, entry) in obj.iter() {
            let oid = Oid::from_hex(
                entry
                    .get("oid")
                    .and_then(|v| v.as_str())
                    .context("index entry missing oid")?,
            )?;
            let size = entry
                .get("size")
                .and_then(|v| v.as_u64())
                .context("index entry missing size")?;
            let raw = Oid::from_hex(
                entry
                    .get("raw")
                    .and_then(|v| v.as_str())
                    .context("index entry missing raw hash")?,
            )?;
            entries.insert(path.clone(), IndexEntry { oid, size, raw });
        }
        Ok(Index { entries })
    }

    /// Persist the index to `.theta/index`.
    pub fn save(&self, theta_dir: &Path) -> Result<()> {
        let mut obj = JsonObj::new();
        for (path, e) in &self.entries {
            let mut entry = JsonObj::new();
            entry.insert("oid", e.oid.to_hex());
            entry.insert("size", e.size);
            entry.insert("raw", e.raw.to_hex());
            obj.insert(path.clone(), entry);
        }
        let mut root = JsonObj::new();
        root.insert("version", 1u64);
        root.insert("entries", obj);
        std::fs::write(index_path(theta_dir), Json::Obj(root).to_string_pretty())
            .context("writing index")
    }

    /// Stage `path` at `oid` (replacing any previous entry).
    pub fn stage(&mut self, path: impl Into<String>, oid: Oid, size: u64, raw: Oid) {
        self.entries.insert(path.into(), IndexEntry { oid, size, raw });
    }

    /// Remove `path` from the index, returning its entry if staged.
    pub fn unstage(&mut self, path: &str) -> Option<IndexEntry> {
        self.entries.remove(path)
    }

    /// The staged entry for `path`, if any.
    pub fn get(&self, path: &str) -> Option<&IndexEntry> {
        self.entries.get(path)
    }

    /// Iterate staged `(path, entry)` pairs in path order.
    pub fn iter(&self) -> impl Iterator<Item = (&String, &IndexEntry)> {
        self.entries.iter()
    }

    /// Number of staged entries.
    pub fn len(&self) -> usize {
        self.entries.len()
    }

    /// Whether nothing is staged.
    pub fn is_empty(&self) -> bool {
        self.entries.is_empty()
    }

    /// Replace the whole index with a tree's contents (used on checkout).
    pub fn reset_to(&mut self, entries: impl Iterator<Item = (String, Oid, u64, Oid)>) {
        self.entries.clear();
        for (path, oid, size, raw) in entries {
            self.entries.insert(path, IndexEntry { oid, size, raw });
        }
    }
}

fn index_path(theta_dir: &Path) -> PathBuf {
    theta_dir.join("index")
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::tmp::TempDir;

    #[test]
    fn stage_save_load() {
        let td = TempDir::new("index").unwrap();
        let mut idx = Index::new();
        idx.stage("model.safetensors", Oid::of_bytes(b"meta"), 1234, Oid::of_bytes(b"rawm"));
        idx.stage("train.py", Oid::of_bytes(b"code"), 99, Oid::of_bytes(b"rawc"));
        idx.save(td.path()).unwrap();
        let loaded = Index::load(td.path()).unwrap();
        assert_eq!(loaded, idx);
        assert_eq!(loaded.get("train.py").unwrap().size, 99);
    }

    #[test]
    fn missing_index_is_empty() {
        let td = TempDir::new("index").unwrap();
        assert!(Index::load(td.path()).unwrap().is_empty());
    }

    #[test]
    fn unstage_and_reset() {
        let mut idx = Index::new();
        idx.stage("a", Oid::of_bytes(b"1"), 1, Oid::of_bytes(b"1"));
        idx.stage("b", Oid::of_bytes(b"2"), 2, Oid::of_bytes(b"2"));
        assert!(idx.unstage("a").is_some());
        assert!(idx.get("a").is_none());
        idx.reset_to(
            vec![("c".to_string(), Oid::of_bytes(b"3"), 3u64, Oid::of_bytes(b"3"))].into_iter(),
        );
        assert_eq!(idx.len(), 1);
        assert!(idx.get("c").is_some());
    }
}
