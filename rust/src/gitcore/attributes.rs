//! `.thetaattributes` — per-file customization, mirroring `.gitattributes`.
//!
//! Each line: `<glob-pattern> key=value key2=value2 ...`. Git-Theta's
//! `track` command writes lines like:
//!
//! ```text
//! model.safetensors filter=theta diff=theta merge=theta
//! ```
//!
//! Later lines override earlier ones for the same key (Git semantics).

use crate::util::glob::Glob;
use anyhow::Result;
use std::collections::BTreeMap;
use std::path::Path;

/// File name the attribute rules are read from at the worktree root
/// (this repo's analogue of `.gitattributes`).
pub const ATTRIBUTES_FILE: &str = ".thetaattributes";

/// Value of one attribute for one file.
#[derive(Debug, Clone, PartialEq, Eq)]
pub enum AttrValue {
    /// The attribute is present with no value (`pattern attr`).
    Set,
    /// The attribute is explicitly removed (`pattern -attr`).
    Unset,
    /// The attribute carries a value (`pattern attr=value`).
    Value(String),
}

#[derive(Debug, Clone)]
struct Rule {
    glob: Glob,
    attrs: Vec<(String, AttrValue)>,
}

/// A parsed attributes file.
#[derive(Debug, Clone, Default)]
pub struct Attributes {
    rules: Vec<Rule>,
}

impl Attributes {
    /// Parse attributes-file text into an ordered rule list (later
    /// lines override earlier ones, as in Git).
    pub fn parse(text: &str) -> Attributes {
        let mut rules = Vec::new();
        for line in text.lines() {
            let line = line.trim();
            if line.is_empty() || line.starts_with('#') {
                continue;
            }
            let mut parts = line.split_whitespace();
            let pattern = match parts.next() {
                Some(p) => p,
                None => continue,
            };
            let mut attrs = Vec::new();
            for tok in parts {
                if let Some((k, v)) = tok.split_once('=') {
                    attrs.push((k.to_string(), AttrValue::Value(v.to_string())));
                } else if let Some(k) = tok.strip_prefix('-') {
                    attrs.push((k.to_string(), AttrValue::Unset));
                } else {
                    attrs.push((tok.to_string(), AttrValue::Set));
                }
            }
            rules.push(Rule {
                glob: Glob::new(pattern),
                attrs,
            });
        }
        Attributes { rules }
    }

    /// Load from a working tree root (missing file = empty).
    pub fn load(worktree: &Path) -> Result<Attributes> {
        let path = worktree.join(ATTRIBUTES_FILE);
        if !path.exists() {
            return Ok(Attributes::default());
        }
        Ok(Attributes::parse(&std::fs::read_to_string(path)?))
    }

    /// All attributes that apply to `path`, with later rules overriding.
    pub fn lookup(&self, path: &str) -> BTreeMap<String, AttrValue> {
        let mut out = BTreeMap::new();
        for rule in &self.rules {
            if rule.glob.matches(path) {
                for (k, v) in &rule.attrs {
                    out.insert(k.clone(), v.clone());
                }
            }
        }
        out
    }

    /// The value of a single attribute for `path`, if it's `key=value`.
    pub fn value_of(&self, path: &str, key: &str) -> Option<String> {
        match self.lookup(path).remove(key) {
            Some(AttrValue::Value(v)) => Some(v),
            _ => None,
        }
    }

    /// Append a tracking line (used by `git theta track`); dedupes exact lines.
    pub fn add_line(worktree: &Path, line: &str) -> Result<bool> {
        let path = worktree.join(ATTRIBUTES_FILE);
        let existing = if path.exists() {
            std::fs::read_to_string(&path)?
        } else {
            String::new()
        };
        if existing.lines().any(|l| l.trim() == line.trim()) {
            return Ok(false);
        }
        let mut out = existing;
        if !out.is_empty() && !out.ends_with('\n') {
            out.push('\n');
        }
        out.push_str(line);
        out.push('\n');
        std::fs::write(&path, out)?;
        Ok(true)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::tmp::TempDir;

    #[test]
    fn parse_and_lookup() {
        let attrs = Attributes::parse(
            "# comment\n\
             *.safetensors filter=theta diff=theta merge=theta\n\
             *.bin filter=lfs\n\
             legacy.bin -filter\n\
             special.bin binary\n",
        );
        assert_eq!(
            attrs.value_of("model.safetensors", "filter"),
            Some("theta".into())
        );
        assert_eq!(attrs.value_of("sub/dir/model.safetensors", "merge"), Some("theta".into()));
        assert_eq!(attrs.value_of("weights.bin", "filter"), Some("lfs".into()));
        // Later rule unsets filter for legacy.bin.
        assert_eq!(attrs.value_of("legacy.bin", "filter"), None);
        assert_eq!(
            attrs.lookup("legacy.bin").get("filter"),
            Some(&AttrValue::Unset)
        );
        assert_eq!(
            attrs.lookup("special.bin").get("binary"),
            Some(&AttrValue::Set)
        );
        assert!(attrs.lookup("unrelated.txt").is_empty());
    }

    #[test]
    fn add_line_dedupes() {
        let td = TempDir::new("attrs").unwrap();
        assert!(Attributes::add_line(td.path(), "m.safetensors filter=theta").unwrap());
        assert!(!Attributes::add_line(td.path(), "m.safetensors filter=theta").unwrap());
        assert!(Attributes::add_line(td.path(), "n.safetensors filter=theta").unwrap());
        let text = std::fs::read_to_string(td.join(ATTRIBUTES_FILE)).unwrap();
        assert_eq!(text.lines().count(), 2);
    }
}
