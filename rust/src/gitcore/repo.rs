//! The repository facade: init/open, add, commit, checkout, branch,
//! merge, diff, log, status, push/pull.
//!
//! This is where gitcore's inversion of control happens (paper §3.3):
//! `add` runs the clean filter selected by `.thetaattributes`, `checkout`
//! runs the smudge filter, `merge`/`diff` dispatch registered drivers,
//! and `commit`/`push` fire hooks.

use super::attributes::Attributes;
use super::drivers::{DriverRegistry, MergeOptions, MergeOutcome};
use super::index::Index;
use super::mergebase::{commits_between, is_ancestor, merge_base};
use super::object::{Commit, Object, Oid, Tree, TreeEntry};
use super::odb::Odb;
use super::refs::{Head, Refs};
use super::remote::{open_endpoint, open_endpoint_with_quorum, GitEndpoint, RemoteSpec};
use super::status::{FileStatus, Status};
use anyhow::{bail, Context, Result};
use std::collections::{BTreeMap, HashSet};
use std::path::{Path, PathBuf};

/// Name of the repository metadata directory (Git's `.git`).
pub const THETA_DIR: &str = ".theta";

/// An opened repository.
#[derive(Debug, Clone)]
pub struct Repository {
    worktree: PathBuf,
    theta_dir: PathBuf,
    odb: Odb,
    refs: Refs,
}

/// Result of a merge.
#[derive(Debug, Clone)]
pub struct MergeReport {
    /// The merge commit created (None for fast-forward / no-op merges).
    pub commit: Option<Oid>,
    /// True when the merge was a plain fast-forward.
    pub fast_forward: bool,
    /// True when there was nothing to merge.
    pub already_up_to_date: bool,
    /// Paths whose conflicts were resolved by a merge driver.
    pub driver_resolved: Vec<String>,
}

/// Result of a push.
#[derive(Debug, Clone)]
pub struct PushReport {
    /// New commits delivered to the remote, oldest first.
    pub commits: Vec<Oid>,
    /// Odb objects the remote was missing and received.
    pub objects_sent: usize,
    /// Raw blob bytes among the objects sent.
    pub bytes_sent: u64,
}

/// What [`Repository::repair_replica_refs`] did (or refused to do).
#[derive(Debug, Clone, Copy, Default, PartialEq, Eq)]
pub struct RefRepair {
    /// Distinct branch tips observed across the mirrors.
    pub tips: usize,
    /// The winning tip every mirror now points at (`None` when no
    /// mirror held the branch, or the tips diverged).
    pub tip: Option<Oid>,
    /// Mirrors whose branch ref was fast-forwarded to the winner.
    pub fast_forwarded: usize,
    /// True when no tip dominated the others; refs were left alone.
    pub diverged: bool,
}

impl Repository {
    /// Create a new repository in `worktree`.
    pub fn init(worktree: &Path) -> Result<Repository> {
        let theta_dir = worktree.join(THETA_DIR);
        if theta_dir.exists() {
            bail!("repository already exists at {}", worktree.display());
        }
        std::fs::create_dir_all(&theta_dir)?;
        let odb = Odb::init(&theta_dir)?;
        let refs = Refs::init(&theta_dir, "main")?;
        Ok(Repository {
            worktree: worktree.to_path_buf(),
            theta_dir,
            odb,
            refs,
        })
    }

    /// Open an existing repository rooted exactly at `worktree`.
    pub fn open(worktree: &Path) -> Result<Repository> {
        let theta_dir = worktree.join(THETA_DIR);
        if !theta_dir.exists() {
            bail!("not a theta repository: {}", worktree.display());
        }
        Ok(Repository {
            worktree: worktree.to_path_buf(),
            theta_dir: theta_dir.clone(),
            odb: Odb::open(&theta_dir),
            refs: Refs::open(&theta_dir),
        })
    }

    /// Walk up from `start` to find a repository (like `git` does).
    pub fn discover(start: &Path) -> Result<Repository> {
        let mut dir = start.canonicalize().unwrap_or_else(|_| start.to_path_buf());
        loop {
            if dir.join(THETA_DIR).exists() {
                return Repository::open(&dir);
            }
            if !dir.pop() {
                bail!("no theta repository found above {}", start.display());
            }
        }
    }

    /// The working-tree root.
    pub fn worktree(&self) -> &Path {
        &self.worktree
    }

    /// The `.theta` metadata directory.
    pub fn theta_dir(&self) -> &Path {
        &self.theta_dir
    }

    /// The object database.
    pub fn odb(&self) -> &Odb {
        &self.odb
    }

    /// The ref store.
    pub fn refs(&self) -> &Refs {
        &self.refs
    }

    /// Parse `.thetaattributes` from the worktree (empty if absent).
    pub fn attributes(&self) -> Result<Attributes> {
        Attributes::load(&self.worktree)
    }

    /// The commit HEAD resolves to (None on an unborn branch).
    pub fn head_commit(&self) -> Result<Option<Oid>> {
        self.refs.head_commit()
    }

    fn abs(&self, rel: &str) -> PathBuf {
        self.worktree.join(rel)
    }

    /// Normalize a user-supplied path to repo-relative forward-slash form.
    pub fn rel_path(&self, path: &Path) -> Result<String> {
        let abs = if path.is_absolute() {
            path.to_path_buf()
        } else {
            self.worktree.join(path)
        };
        let rel = abs
            .strip_prefix(&self.worktree)
            .map_err(|_| anyhow::anyhow!("path {} is outside the repository", path.display()))?;
        Ok(rel.to_string_lossy().replace('\\', "/"))
    }

    // ------------------------------------------------------------------
    // add / commit
    // ------------------------------------------------------------------

    /// Stage files: run the clean filter (if any) and record the result.
    pub fn add(&self, paths: &[&str]) -> Result<()> {
        let attrs = self.attributes()?;
        let mut index = Index::load(&self.theta_dir)?;
        for path in paths {
            let abs = self.abs(path);
            let working = std::fs::read(&abs)
                .with_context(|| format!("reading {} for staging", abs.display()))?;
            let raw = Oid::of_bytes(&working);
            let staged = match attrs.value_of(path, "filter") {
                Some(name) => {
                    let driver = DriverRegistry::filter(&name)
                        .with_context(|| format!("no filter driver '{name}' registered"))?;
                    driver.clean(self, path, &working)?
                }
                None => working,
            };
            let size = staged.len() as u64;
            let oid = self.odb.write_blob(staged)?;
            index.stage(path.to_string(), oid, size, raw);
        }
        index.save(&self.theta_dir)
    }

    /// Stage a file whose staged content is provided directly (used by
    /// tooling that already produced clean-filter output).
    pub fn add_staged_bytes(&self, path: &str, staged: Vec<u8>, raw: Oid) -> Result<Oid> {
        let mut index = Index::load(&self.theta_dir)?;
        let size = staged.len() as u64;
        let oid = self.odb.write_blob(staged)?;
        index.stage(path.to_string(), oid, size, raw);
        index.save(&self.theta_dir)?;
        Ok(oid)
    }

    /// The staged (clean-filtered) content HEAD/index currently has for a
    /// path. Clean filters use this to compare against the prior version.
    pub fn prior_staged(&self, path: &str) -> Result<Option<Vec<u8>>> {
        let index = Index::load(&self.theta_dir)?;
        if let Some(entry) = index.get(path) {
            return Ok(Some(self.odb.read_blob(&entry.oid)?));
        }
        if let Some(head) = self.head_commit()? {
            let tree = self.odb.read_tree(&self.odb.read_commit(&head)?.tree)?;
            if let Some(oid) = tree.get(path) {
                return Ok(Some(self.odb.read_blob(&oid)?));
            }
        }
        Ok(None)
    }

    /// Commit the index. Returns the new commit oid.
    pub fn commit(&self, message: &str, author: &str) -> Result<Oid> {
        let parents = match self.head_commit()? {
            Some(head) => vec![head],
            None => vec![],
        };
        self.commit_with_parents(message, author, parents)
    }

    fn now() -> u64 {
        std::time::SystemTime::now()
            .duration_since(std::time::UNIX_EPOCH)
            .map(|d| d.as_secs())
            .unwrap_or(0)
    }

    /// Commit the index with an explicit parent list (merge commits).
    pub fn commit_with_parents(
        &self,
        message: &str,
        author: &str,
        parents: Vec<Oid>,
    ) -> Result<Oid> {
        let index = Index::load(&self.theta_dir)?;
        if index.is_empty() {
            bail!("nothing staged to commit");
        }
        let entries: Vec<TreeEntry> = index
            .iter()
            .map(|(path, e)| TreeEntry {
                path: path.clone(),
                oid: e.oid,
            })
            .collect();
        let tree = self.odb.write(&Object::Tree(Tree::from_entries(entries)))?;
        // Skip empty commits (same tree as sole parent).
        if let [parent] = parents.as_slice() {
            if self.odb.read_commit(parent)?.tree == tree {
                return Ok(*parent);
            }
        }
        let commit_oid = self.odb.write(&Object::Commit(Commit {
            tree,
            parents,
            author: author.to_string(),
            timestamp: Self::now(),
            message: message.to_string(),
        }))?;
        match self.refs.head()? {
            Head::Branch(name) => self.refs.set_branch(&name, &commit_oid)?,
            Head::Detached(_) => self.refs.set_head(&Head::Detached(commit_oid))?,
        }
        for hooks in DriverRegistry::all_hooks() {
            hooks.post_commit(self, &commit_oid)?;
        }
        Ok(commit_oid)
    }

    // ------------------------------------------------------------------
    // checkout / branch
    // ------------------------------------------------------------------

    /// Resolve a revision string: branch name, full/short hex oid, or "HEAD".
    pub fn resolve(&self, rev: &str) -> Result<Oid> {
        if rev == "HEAD" {
            return self
                .head_commit()?
                .context("HEAD does not point at a commit yet");
        }
        if let Some(oid) = self.refs.branch(rev)? {
            return Ok(oid);
        }
        if rev.len() == 64 {
            if let Ok(oid) = Oid::from_hex(rev) {
                if self.odb.contains(&oid) {
                    return Ok(oid);
                }
            }
        }
        // Short hex prefix.
        if rev.len() >= 6 && rev.chars().all(|c| c.is_ascii_hexdigit()) {
            let matches: Vec<Oid> = self
                .odb
                .list()?
                .into_iter()
                .filter(|o| o.to_hex().starts_with(rev))
                .collect();
            match matches.len() {
                1 => return Ok(matches[0]),
                n if n > 1 => bail!("ambiguous revision '{rev}' ({n} matches)"),
                _ => {}
            }
        }
        bail!("unknown revision '{rev}'")
    }

    /// Create a branch at HEAD (does not switch).
    pub fn create_branch(&self, name: &str) -> Result<()> {
        let head = self
            .head_commit()?
            .context("cannot branch from an unborn HEAD")?;
        if self.refs.branch(name)?.is_some() {
            bail!("branch '{name}' already exists");
        }
        self.refs.set_branch(name, &head)
    }

    /// Switch to a branch or commit, materializing its tree (smudge).
    pub fn checkout(&self, target: &str) -> Result<()> {
        let (head, commit_oid) = match self.refs.branch(target)? {
            Some(oid) => (Head::Branch(target.to_string()), oid),
            None => {
                let oid = self.resolve(target)?;
                (Head::Detached(oid), oid)
            }
        };
        let old_tree = match self.head_commit()? {
            Some(h) => Some(self.odb.read_tree(&self.odb.read_commit(&h)?.tree)?),
            None => None,
        };
        // Point HEAD at the target *before* smudging so smudge filters
        // that consult repository state see the checked-out revision.
        self.refs.set_head(&head)?;
        self.materialize(commit_oid, old_tree.as_ref())
    }

    /// Write the tree of `commit_oid` into the working tree, smudging
    /// filtered files, and reset the index to match.
    pub fn materialize(&self, commit_oid: Oid, old_tree: Option<&Tree>) -> Result<()> {
        let commit = self.odb.read_commit(&commit_oid)?;
        let tree = self.odb.read_tree(&commit.tree)?;

        // Attributes of the target revision (so smudge uses the filters
        // that were in effect when the tree was committed).
        let attrs = match tree.get(super::attributes::ATTRIBUTES_FILE) {
            Some(oid) => Attributes::parse(&String::from_utf8_lossy(&self.odb.read_blob(&oid)?)),
            None => self.attributes()?,
        };

        let mut index_entries = Vec::new();
        for entry in &tree.entries {
            let staged = self.odb.read_blob(&entry.oid)?;
            let working = match attrs.value_of(&entry.path, "filter") {
                Some(name) => {
                    let driver = DriverRegistry::filter(&name)
                        .with_context(|| format!("no filter driver '{name}' registered"))?;
                    driver.smudge(self, &entry.path, &staged)?
                }
                None => staged.clone(),
            };
            let abs = self.abs(&entry.path);
            if let Some(parent) = abs.parent() {
                std::fs::create_dir_all(parent)?;
            }
            std::fs::write(&abs, &working)?;
            index_entries.push((
                entry.path.clone(),
                entry.oid,
                staged.len() as u64,
                Oid::of_bytes(&working),
            ));
        }

        // Remove files tracked by the old revision but absent in the new.
        if let Some(old) = old_tree {
            for path in old.paths() {
                if tree.get(path).is_none() {
                    let abs = self.abs(path);
                    if abs.exists() {
                        std::fs::remove_file(&abs)?;
                    }
                }
            }
        }

        let mut index = Index::load(&self.theta_dir)?;
        index.reset_to(index_entries.into_iter());
        index.save(&self.theta_dir)
    }

    // ------------------------------------------------------------------
    // history / inspection
    // ------------------------------------------------------------------

    /// Commits reachable from HEAD, newest-first.
    pub fn log(&self) -> Result<Vec<(Oid, Commit)>> {
        let head = match self.head_commit()? {
            Some(h) => h,
            None => return Ok(vec![]),
        };
        let oids = commits_between(&self.odb, head, &[])?;
        let mut out = Vec::with_capacity(oids.len());
        for oid in oids.into_iter().rev() {
            out.push((oid, self.odb.read_commit(&oid)?));
        }
        Ok(out)
    }

    /// The staged content of `path` at `commit` (None if absent).
    pub fn read_path_at(&self, commit: Oid, path: &str) -> Result<Option<Vec<u8>>> {
        let tree = self.odb.read_tree(&self.odb.read_commit(&commit)?.tree)?;
        match tree.get(path) {
            Some(oid) => Ok(Some(self.odb.read_blob(&oid)?)),
            None => Ok(None),
        }
    }

    /// Render a diff between two revisions (or HEAD and the index when
    /// `old`/`new` are None), dispatching per-path diff drivers.
    pub fn diff(&self, old: Option<Oid>, new: Option<Oid>) -> Result<String> {
        let old_tree = match old {
            Some(oid) => self.odb.read_tree(&self.odb.read_commit(&oid)?.tree)?,
            None => match self.head_commit()? {
                Some(h) => self.odb.read_tree(&self.odb.read_commit(&h)?.tree)?,
                None => Tree::default(),
            },
        };
        let new_tree = match new {
            Some(oid) => self.odb.read_tree(&self.odb.read_commit(&oid)?.tree)?,
            None => {
                // Index as a tree.
                let index = Index::load(&self.theta_dir)?;
                Tree::from_entries(
                    index
                        .iter()
                        .map(|(p, e)| TreeEntry {
                            path: p.clone(),
                            oid: e.oid,
                        })
                        .collect(),
                )
            }
        };
        let attrs = self.attributes()?;
        let mut paths: Vec<&str> = old_tree.paths().chain(new_tree.paths()).collect();
        paths.sort_unstable();
        paths.dedup();

        let mut out = String::new();
        for path in paths {
            let o = old_tree.get(path);
            let n = new_tree.get(path);
            if o == n {
                continue;
            }
            let old_bytes = o.map(|oid| self.odb.read_blob(&oid)).transpose()?;
            let new_bytes = n.map(|oid| self.odb.read_blob(&oid)).transpose()?;
            let rendered = match attrs.value_of(path, "diff") {
                Some(name) => {
                    let driver = DriverRegistry::diff(&name)
                        .with_context(|| format!("no diff driver '{name}' registered"))?;
                    driver.diff(self, path, old_bytes.as_deref(), new_bytes.as_deref())?
                }
                None => default_text_diff(path, old_bytes.as_deref(), new_bytes.as_deref()),
            };
            out.push_str(&rendered);
        }
        Ok(out)
    }

    /// Repository status.
    pub fn status(&self) -> Result<Status> {
        let index = Index::load(&self.theta_dir)?;
        let head = self.head_commit()?;
        let head_tree = match head {
            Some(h) => Some(self.odb.read_tree(&self.odb.read_commit(&h)?.tree)?),
            None => None,
        };
        let mut entries: BTreeMap<String, FileStatus> = BTreeMap::new();

        // Index vs HEAD.
        for (path, e) in index.iter() {
            match head_tree.as_ref().and_then(|t| t.get(path)) {
                None => {
                    entries.insert(path.clone(), FileStatus::Added);
                }
                Some(oid) if oid != e.oid => {
                    entries.insert(path.clone(), FileStatus::Staged);
                }
                _ => {}
            }
        }
        // HEAD vs index: deletions.
        if let Some(tree) = &head_tree {
            for path in tree.paths() {
                if index.get(path).is_none() {
                    entries.insert(path.to_string(), FileStatus::Deleted);
                }
            }
        }
        // Working tree vs index.
        let mut work_files = Vec::new();
        collect_files(&self.worktree, &self.worktree, &mut work_files)?;
        for path in &work_files {
            match index.get(path) {
                Some(e) => {
                    let bytes = std::fs::read(self.abs(path))?;
                    if Oid::of_bytes(&bytes) != e.raw {
                        entries.insert(path.clone(), FileStatus::Modified);
                    }
                }
                None => {
                    entries.insert(path.clone(), FileStatus::Untracked);
                }
            }
        }
        // Index entries whose working file vanished.
        for (path, _) in index.iter() {
            if !self.abs(path).exists() {
                entries.insert(path.clone(), FileStatus::Deleted);
            }
        }

        Ok(Status {
            entries: entries.into_iter().collect(),
            head,
            branch: match self.refs.head()? {
                Head::Branch(b) => Some(b),
                Head::Detached(_) => None,
            },
        })
    }

    // ------------------------------------------------------------------
    // merge
    // ------------------------------------------------------------------

    /// Merge `other` (a branch name or revision) into HEAD.
    pub fn merge(&self, other: &str, opts: &MergeOptions, author: &str) -> Result<MergeReport> {
        let ours = self
            .head_commit()?
            .context("cannot merge into an unborn HEAD")?;
        let theirs = self.resolve(other)?;

        if is_ancestor(&self.odb, theirs, ours)? {
            return Ok(MergeReport {
                commit: None,
                fast_forward: false,
                already_up_to_date: true,
                driver_resolved: vec![],
            });
        }
        if is_ancestor(&self.odb, ours, theirs)? {
            // Fast-forward.
            let old_tree = self.odb.read_tree(&self.odb.read_commit(&ours)?.tree)?;
            match self.refs.head()? {
                Head::Branch(name) => self.refs.set_branch(&name, &theirs)?,
                Head::Detached(_) => self.refs.set_head(&Head::Detached(theirs))?,
            }
            self.materialize(theirs, Some(&old_tree))?;
            return Ok(MergeReport {
                commit: Some(theirs),
                fast_forward: true,
                already_up_to_date: false,
                driver_resolved: vec![],
            });
        }

        let base = merge_base(&self.odb, ours, theirs)?;
        let base_tree = match base {
            Some(b) => self.odb.read_tree(&self.odb.read_commit(&b)?.tree)?,
            None => Tree::default(),
        };
        let our_tree = self.odb.read_tree(&self.odb.read_commit(&ours)?.tree)?;
        let their_tree = self.odb.read_tree(&self.odb.read_commit(&theirs)?.tree)?;
        let attrs = self.attributes()?;

        let mut paths: Vec<&str> = base_tree
            .paths()
            .chain(our_tree.paths())
            .chain(their_tree.paths())
            .collect();
        paths.sort_unstable();
        paths.dedup();

        let mut merged_entries = Vec::new();
        let mut driver_resolved = Vec::new();
        for path in paths {
            let o = base_tree.get(path);
            let a = our_tree.get(path);
            let b = their_tree.get(path);
            let pick = if a == b {
                a // identical (or both deleted)
            } else if a == o {
                b // only theirs changed
            } else if b == o {
                a // only ours changed
            } else {
                // Both sides changed: dispatch the merge driver.
                let name = attrs
                    .value_of(path, "merge")
                    .with_context(|| format!("conflict in '{path}' and no merge driver set"))?;
                let driver = DriverRegistry::merge(&name)
                    .with_context(|| format!("no merge driver '{name}' registered"))?;
                let base_bytes = o.map(|oid| self.odb.read_blob(&oid)).transpose()?;
                let our_bytes = a.map(|oid| self.odb.read_blob(&oid)).transpose()?;
                let their_bytes = b.map(|oid| self.odb.read_blob(&oid)).transpose()?;
                match driver.merge(
                    self,
                    path,
                    base_bytes.as_deref(),
                    our_bytes.as_deref(),
                    their_bytes.as_deref(),
                    opts,
                )? {
                    MergeOutcome::Resolved(bytes) => {
                        driver_resolved.push(path.to_string());
                        Some(self.odb.write_blob(bytes)?)
                    }
                    MergeOutcome::Conflict(msg) => {
                        bail!("merge conflict in '{path}': {msg}")
                    }
                }
            };
            if let Some(oid) = pick {
                merged_entries.push(TreeEntry {
                    path: path.to_string(),
                    oid,
                });
            }
        }

        let merged_tree = self
            .odb
            .write(&Object::Tree(Tree::from_entries(merged_entries)))?;
        let commit_oid = self.odb.write(&Object::Commit(Commit {
            tree: merged_tree,
            parents: vec![ours, theirs],
            author: author.to_string(),
            timestamp: Self::now(),
            message: format!("Merge '{other}'"),
        }))?;
        match self.refs.head()? {
            Head::Branch(name) => self.refs.set_branch(&name, &commit_oid)?,
            Head::Detached(_) => self.refs.set_head(&Head::Detached(commit_oid))?,
        }
        let old_tree = self.odb.read_tree(&self.odb.read_commit(&ours)?.tree)?;
        self.materialize(commit_oid, Some(&old_tree))?;
        for hooks in DriverRegistry::all_hooks() {
            hooks.post_commit(self, &commit_oid)?;
        }
        Ok(MergeReport {
            commit: Some(commit_oid),
            fast_forward: false,
            already_up_to_date: false,
            driver_resolved,
        })
    }

    // ------------------------------------------------------------------
    // config
    // ------------------------------------------------------------------

    /// Read a key from `.theta/config` (flat JSON string map).
    pub fn config_get(&self, key: &str) -> Result<Option<String>> {
        let path = self.theta_dir.join("config");
        if !path.exists() {
            return Ok(None);
        }
        let json = crate::util::json::Json::parse(&std::fs::read_to_string(&path)?)
            .context("parsing .theta/config")?;
        Ok(json.get(key).and_then(|v| v.as_str()).map(|s| s.to_string()))
    }

    /// Write a key to `.theta/config`.
    pub fn config_set(&self, key: &str, value: &str) -> Result<()> {
        use crate::util::json::{Json, JsonObj};
        let path = self.theta_dir.join("config");
        let mut obj = if path.exists() {
            match Json::parse(&std::fs::read_to_string(&path)?) {
                Ok(Json::Obj(o)) => o,
                _ => JsonObj::new(),
            }
        } else {
            JsonObj::new()
        };
        obj.insert(key.to_string(), value);
        std::fs::write(&path, Json::Obj(obj).to_string_pretty()).context("writing config")
    }

    // ------------------------------------------------------------------
    // remote transfer
    // ------------------------------------------------------------------

    /// The configured replica write quorum (`theta.replica-quorum`),
    /// if any. `0`, negative, or unparsable values are treated as
    /// unset (= all mirrors) rather than silently weakening writes.
    pub fn replica_quorum(&self) -> Result<Option<usize>> {
        Ok(self
            .config_get("theta.replica-quorum")?
            .and_then(|v| v.trim().parse::<usize>().ok())
            .filter(|q| *q > 0))
    }

    /// Open `remote`'s endpoint honoring this repo's configured
    /// replica quorum for replica sets.
    fn endpoint_for(&self, remote: &RemoteSpec) -> Result<Box<dyn GitEndpoint>> {
        open_endpoint_with_quorum(remote, self.replica_quorum()?)
    }

    /// Push `branch` to a directory remote (legacy path-typed entry
    /// point; see [`Repository::push_spec`] for http remotes).
    pub fn push(&self, remote: &Path, branch: &str) -> Result<PushReport> {
        self.push_spec(&RemoteSpec::from_path(remote), branch)
    }

    /// Push `branch` to a remote, transferring missing objects.
    ///
    /// Works against any [`RemoteSpec`]: the remote's tip is read, the
    /// fast-forward check runs locally, pre-push hooks sync LFS objects
    /// (through `lfs::transport`), then exactly the odb objects the
    /// remote is missing — negotiated in one round trip — are sent and
    /// the branch tip is compare-and-set.
    pub fn push_spec(&self, remote: &RemoteSpec, branch: &str) -> Result<PushReport> {
        let tip = self
            .refs
            .branch(branch)?
            .with_context(|| format!("no local branch '{branch}'"))?;
        let endpoint = self.endpoint_for(remote)?;
        let remote_tip = endpoint.branch(branch)?;

        if let Some(rt) = remote_tip {
            if rt == tip {
                return Ok(PushReport {
                    commits: vec![],
                    objects_sent: 0,
                    bytes_sent: 0,
                });
            }
            if !self.odb.contains(&rt) || !is_ancestor(&self.odb, rt, tip)? {
                bail!("push rejected: remote '{branch}' is not an ancestor of local (fetch first)");
            }
        }

        let exclude: Vec<Oid> = remote_tip.into_iter().collect();
        let commits = commits_between(&self.odb, tip, &exclude)?;

        // Pre-push hooks run before any object transfer (paper: LFS sync).
        for hooks in DriverRegistry::all_hooks() {
            hooks.pre_push(self, remote, &commits)?;
        }

        // Candidate objects in dependency order (blobs before their
        // tree, tree before its commit), deduplicated, then negotiated
        // in a single round trip so only missing objects move.
        let mut candidates: Vec<Oid> = Vec::new();
        for &commit_oid in &commits {
            let commit = self.odb.read_commit(&commit_oid)?;
            let tree = self.odb.read_tree(&commit.tree)?;
            for entry in &tree.entries {
                candidates.push(entry.oid);
            }
            candidates.push(commit.tree);
            candidates.push(commit_oid);
        }
        let mut seen = HashSet::new();
        candidates.retain(|o| seen.insert(*o));
        let missing: HashSet<Oid> = endpoint.missing(&candidates)?.into_iter().collect();

        let mut objects_sent = 0usize;
        let mut bytes_sent = 0u64;
        for oid in &candidates {
            if !missing.contains(oid) {
                continue;
            }
            let obj = self.odb.read(oid)?;
            bytes_sent += blob_size(&obj);
            endpoint.write(&obj)?;
            objects_sent += 1;
        }
        endpoint.set_branch(branch, remote_tip, &tip)?;
        Ok(PushReport {
            commits,
            objects_sent,
            bytes_sent,
        })
    }

    /// Fetch `branch` from a directory remote (legacy path-typed entry
    /// point; see [`Repository::fetch_spec`] for http remotes).
    pub fn fetch(&self, remote: &Path, branch: &str) -> Result<Oid> {
        self.fetch_spec(&RemoteSpec::from_path(remote), branch)
    }

    /// Fetch `branch` from a remote into the local odb and fast-forward
    /// the local branch. Does not touch the working tree.
    pub fn fetch_spec(&self, remote: &RemoteSpec, branch: &str) -> Result<Oid> {
        let remote_tip = self.fetch_head_spec(remote, branch)?;
        if let Some(lt) = self.refs.branch(branch)? {
            if lt != remote_tip && !is_ancestor(&self.odb, lt, remote_tip)? {
                bail!("fetch: local branch '{branch}' has diverged from remote");
            }
        }
        self.refs.set_branch(branch, &remote_tip)?;
        Ok(remote_tip)
    }

    /// Fetch `branch`'s objects from a remote into the local odb and
    /// return the remote tip **without moving any local ref**. This is
    /// the fetch half a push-retry loop needs: when a push is rejected
    /// because the remote moved, the local branch has diverged by
    /// definition, so [`Repository::fetch_spec`]'s fast-forward would
    /// bail — instead the caller merges the returned tip locally and
    /// pushes again.
    pub fn fetch_head_spec(&self, remote: &RemoteSpec, branch: &str) -> Result<Oid> {
        let endpoint = self.endpoint_for(remote)?;
        let remote_tip = endpoint
            .branch(branch)?
            .with_context(|| format!("remote has no branch '{branch}'"))?;
        let local_tip = self.refs.branch(branch)?;

        let mut exclude: Vec<Oid> = Vec::new();
        if let Some(t) = local_tip {
            if endpoint.contains(&t)? {
                exclude.push(t);
            }
        }
        let commits = endpoint.commits_between(remote_tip, &exclude)?;
        for &commit_oid in &commits {
            let commit = match endpoint.read(&commit_oid)? {
                Object::Commit(c) => c,
                other => bail!("expected commit {}, found {}", commit_oid.short(), other.kind()),
            };
            let tree_obj = endpoint.read(&commit.tree)?;
            let tree = match &tree_obj {
                Object::Tree(t) => t.clone(),
                other => bail!("expected tree {}, found {}", commit.tree.short(), other.kind()),
            };
            for entry in &tree.entries {
                if !self.odb.contains(&entry.oid) {
                    self.odb.write(&endpoint.read(&entry.oid)?)?;
                }
            }
            self.odb.write(&tree_obj)?;
            self.odb.write(&Object::Commit(commit))?;
        }
        Ok(remote_tip)
    }

    /// Converge the `branch` tips of a replica set's mirrors after a
    /// quorum-shortfall write left some of them behind.
    ///
    /// Every mirror's history is fetched into the local odb (no local
    /// ref moves), the winning tip — the one every other observed tip
    /// is an ancestor of — is picked, and each lagging mirror receives
    /// exactly the odb objects it is missing before its branch ref is
    /// compare-and-set forward. True divergence (no tip dominates) is
    /// reported, never resolved: that needs a merge and a fresh push.
    /// All mirrors must be reachable — repairing around a dead mirror
    /// would just mint a new laggard.
    pub fn repair_replica_refs(&self, mirrors: &[RemoteSpec], branch: &str) -> Result<RefRepair> {
        let mut tips: Vec<Option<Oid>> = Vec::with_capacity(mirrors.len());
        for m in mirrors {
            tips.push(open_endpoint(m)?.branch(branch)?);
        }
        let mut distinct: Vec<Oid> = tips.iter().flatten().copied().collect();
        distinct.sort();
        distinct.dedup();
        if distinct.is_empty() {
            return Ok(RefRepair::default());
        }

        // Pull every tip's history into the local odb so the ancestry
        // checks and object shipping below run against local state.
        for (m, tip) in mirrors.iter().zip(&tips) {
            if tip.is_some() {
                self.fetch_head_spec(m, branch)?;
            }
        }

        // The winner is the tip every other tip fast-forwards to.
        let mut best = None;
        'cand: for &cand in &distinct {
            for &other in &distinct {
                if other != cand && !is_ancestor(&self.odb, other, cand)? {
                    continue 'cand;
                }
            }
            best = Some(cand);
            break;
        }
        let Some(best) = best else {
            return Ok(RefRepair {
                tips: distinct.len(),
                diverged: true,
                ..RefRepair::default()
            });
        };

        let mut report = RefRepair {
            tips: distinct.len(),
            tip: Some(best),
            ..RefRepair::default()
        };
        for (m, tip) in mirrors.iter().zip(&tips) {
            if *tip == Some(best) {
                continue;
            }
            let endpoint = open_endpoint(m)?;
            let exclude: Vec<Oid> = tip.iter().copied().collect();
            let commits = commits_between(&self.odb, best, &exclude)?;
            // Dependency order, as in push_spec: blobs before their
            // tree, tree before its commit.
            let mut candidates: Vec<Oid> = Vec::new();
            for &commit_oid in &commits {
                let commit = self.odb.read_commit(&commit_oid)?;
                let tree = self.odb.read_tree(&commit.tree)?;
                for entry in &tree.entries {
                    candidates.push(entry.oid);
                }
                candidates.push(commit.tree);
                candidates.push(commit_oid);
            }
            let mut seen = HashSet::new();
            candidates.retain(|o| seen.insert(*o));
            let missing: HashSet<Oid> = endpoint.missing(&candidates)?.into_iter().collect();
            for oid in &candidates {
                if missing.contains(oid) {
                    endpoint.write(&self.odb.read(oid)?)?;
                }
            }
            endpoint.set_branch(branch, *tip, &best)?;
            report.fast_forwarded += 1;
        }
        Ok(report)
    }

    /// Pull from a directory remote (legacy path-typed entry point; see
    /// [`Repository::pull_spec`] for http remotes).
    pub fn pull(&self, remote: &Path, branch: &str) -> Result<Oid> {
        self.pull_spec(&RemoteSpec::from_path(remote), branch)
    }

    /// Fetch + materialize if HEAD is on that branch (paper's `git pull`).
    pub fn pull_spec(&self, remote: &RemoteSpec, branch: &str) -> Result<Oid> {
        let old_tree = match self.head_commit()? {
            Some(h) => Some(self.odb.read_tree(&self.odb.read_commit(&h)?.tree)?),
            None => None,
        };
        // Remember the remote (like git's `origin`) so smudge filters can
        // lazily download large objects referenced by pulled commits.
        if self.config_get("remote")?.is_none() {
            self.config_set("remote", &remote.to_string())?;
        }
        let tip = self.fetch_spec(remote, branch)?;
        if self.refs.head()? == Head::Branch(branch.to_string()) {
            self.materialize(tip, old_tree.as_ref())?;
        }
        Ok(tip)
    }
}

fn blob_size(obj: &Object) -> u64 {
    match obj {
        Object::Blob(b) => b.len() as u64,
        _ => 0,
    }
}

fn collect_files(root: &Path, dir: &Path, out: &mut Vec<String>) -> Result<()> {
    for entry in std::fs::read_dir(dir)? {
        let entry = entry?;
        let name = entry.file_name().to_string_lossy().to_string();
        if name == THETA_DIR {
            continue;
        }
        let path = entry.path();
        if entry.file_type()?.is_dir() {
            collect_files(root, &path, out)?;
        } else {
            out.push(
                path.strip_prefix(root)
                    .unwrap()
                    .to_string_lossy()
                    .replace('\\', "/"),
            );
        }
    }
    Ok(())
}

/// Minimal line-based unified-ish diff for unfiltered text files.
fn default_text_diff(path: &str, old: Option<&[u8]>, new: Option<&[u8]>) -> String {
    let mut out = format!("--- {path}\n");
    match (old, new) {
        (None, Some(n)) => {
            out.push_str(&format!("new file ({} bytes)\n", n.len()));
        }
        (Some(o), None) => {
            out.push_str(&format!("deleted ({} bytes)\n", o.len()));
        }
        (Some(o), Some(n)) => {
            let (os, ns) = (String::from_utf8_lossy(o), String::from_utf8_lossy(n));
            let old_lines: Vec<&str> = os.lines().collect();
            let new_lines: Vec<&str> = ns.lines().collect();
            for l in &old_lines {
                if !new_lines.contains(l) {
                    out.push_str(&format!("- {l}\n"));
                }
            }
            for l in &new_lines {
                if !old_lines.contains(l) {
                    out.push_str(&format!("+ {l}\n"));
                }
            }
        }
        (None, None) => {}
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::tmp::TempDir;

    fn write(repo: &Repository, rel: &str, content: &str) {
        let abs = repo.worktree().join(rel);
        std::fs::create_dir_all(abs.parent().unwrap()).unwrap();
        std::fs::write(abs, content).unwrap();
    }

    #[test]
    fn init_add_commit_log() {
        let td = TempDir::new("repo").unwrap();
        let repo = Repository::init(td.path()).unwrap();
        write(&repo, "train.py", "print('hi')\n");
        repo.add(&["train.py"]).unwrap();
        let c1 = repo.commit("initial", "tester").unwrap();
        write(&repo, "train.py", "print('v2')\n");
        repo.add(&["train.py"]).unwrap();
        let c2 = repo.commit("update", "tester").unwrap();
        let log = repo.log().unwrap();
        assert_eq!(log.len(), 2);
        assert_eq!(log[0].0, c2);
        assert_eq!(log[1].0, c1);
        assert_eq!(
            repo.read_path_at(c1, "train.py").unwrap().unwrap(),
            b"print('hi')\n"
        );
    }

    #[test]
    fn empty_commit_is_skipped() {
        let td = TempDir::new("repo").unwrap();
        let repo = Repository::init(td.path()).unwrap();
        write(&repo, "a", "1");
        repo.add(&["a"]).unwrap();
        let c1 = repo.commit("c1", "t").unwrap();
        repo.add(&["a"]).unwrap();
        let c2 = repo.commit("c2", "t").unwrap();
        assert_eq!(c1, c2);
    }

    #[test]
    fn branch_checkout_restores_content() {
        let td = TempDir::new("repo").unwrap();
        let repo = Repository::init(td.path()).unwrap();
        write(&repo, "f.txt", "base");
        repo.add(&["f.txt"]).unwrap();
        repo.commit("base", "t").unwrap();

        repo.create_branch("feature").unwrap();
        repo.checkout("feature").unwrap();
        write(&repo, "f.txt", "feature-version");
        repo.add(&["f.txt"]).unwrap();
        repo.commit("feat", "t").unwrap();

        repo.checkout("main").unwrap();
        assert_eq!(
            std::fs::read_to_string(td.join("f.txt")).unwrap(),
            "base"
        );
        repo.checkout("feature").unwrap();
        assert_eq!(
            std::fs::read_to_string(td.join("f.txt")).unwrap(),
            "feature-version"
        );
    }

    #[test]
    fn checkout_removes_files_absent_in_target() {
        let td = TempDir::new("repo").unwrap();
        let repo = Repository::init(td.path()).unwrap();
        write(&repo, "keep.txt", "k");
        repo.add(&["keep.txt"]).unwrap();
        repo.commit("c1", "t").unwrap();
        repo.create_branch("extra").unwrap();
        repo.checkout("extra").unwrap();
        write(&repo, "extra.txt", "e");
        repo.add(&["extra.txt"]).unwrap();
        repo.commit("c2", "t").unwrap();
        repo.checkout("main").unwrap();
        assert!(!td.join("extra.txt").exists());
        assert!(td.join("keep.txt").exists());
    }

    #[test]
    fn merge_non_overlapping_changes() {
        let td = TempDir::new("repo").unwrap();
        let repo = Repository::init(td.path()).unwrap();
        write(&repo, "a.txt", "a");
        write(&repo, "b.txt", "b");
        repo.add(&["a.txt", "b.txt"]).unwrap();
        repo.commit("base", "t").unwrap();

        repo.create_branch("side").unwrap();
        repo.checkout("side").unwrap();
        write(&repo, "a.txt", "a-side");
        repo.add(&["a.txt"]).unwrap();
        repo.commit("side edit", "t").unwrap();

        repo.checkout("main").unwrap();
        write(&repo, "b.txt", "b-main");
        repo.add(&["b.txt"]).unwrap();
        repo.commit("main edit", "t").unwrap();

        let report = repo.merge("side", &MergeOptions::default(), "t").unwrap();
        assert!(!report.fast_forward && !report.already_up_to_date);
        assert_eq!(std::fs::read_to_string(td.join("a.txt")).unwrap(), "a-side");
        assert_eq!(std::fs::read_to_string(td.join("b.txt")).unwrap(), "b-main");
        // Merge commit has two parents.
        let head = repo.head_commit().unwrap().unwrap();
        assert_eq!(repo.odb().read_commit(&head).unwrap().parents.len(), 2);
    }

    #[test]
    fn merge_fast_forward_and_up_to_date() {
        let td = TempDir::new("repo").unwrap();
        let repo = Repository::init(td.path()).unwrap();
        write(&repo, "f", "1");
        repo.add(&["f"]).unwrap();
        repo.commit("c1", "t").unwrap();
        repo.create_branch("ahead").unwrap();
        repo.checkout("ahead").unwrap();
        write(&repo, "f", "2");
        repo.add(&["f"]).unwrap();
        let c2 = repo.commit("c2", "t").unwrap();
        repo.checkout("main").unwrap();
        let report = repo.merge("ahead", &MergeOptions::default(), "t").unwrap();
        assert!(report.fast_forward);
        assert_eq!(report.commit, Some(c2));
        assert_eq!(std::fs::read_to_string(td.join("f")).unwrap(), "2");
        let report2 = repo.merge("ahead", &MergeOptions::default(), "t").unwrap();
        assert!(report2.already_up_to_date);
    }

    #[test]
    fn merge_conflict_without_driver_errors() {
        let td = TempDir::new("repo").unwrap();
        let repo = Repository::init(td.path()).unwrap();
        write(&repo, "f", "base");
        repo.add(&["f"]).unwrap();
        repo.commit("base", "t").unwrap();
        repo.create_branch("side").unwrap();
        repo.checkout("side").unwrap();
        write(&repo, "f", "side");
        repo.add(&["f"]).unwrap();
        repo.commit("side", "t").unwrap();
        repo.checkout("main").unwrap();
        write(&repo, "f", "main");
        repo.add(&["f"]).unwrap();
        repo.commit("main", "t").unwrap();
        assert!(repo.merge("side", &MergeOptions::default(), "t").is_err());
    }

    #[test]
    fn status_lifecycle() {
        let td = TempDir::new("repo").unwrap();
        let repo = Repository::init(td.path()).unwrap();
        write(&repo, "f", "1");
        let st = repo.status().unwrap();
        assert_eq!(st.of("f"), Some(&FileStatus::Untracked));
        repo.add(&["f"]).unwrap();
        assert_eq!(repo.status().unwrap().of("f"), Some(&FileStatus::Added));
        repo.commit("c", "t").unwrap();
        assert!(repo.status().unwrap().is_clean());
        write(&repo, "f", "2");
        assert_eq!(repo.status().unwrap().of("f"), Some(&FileStatus::Modified));
        std::fs::remove_file(td.join("f")).unwrap();
        assert_eq!(repo.status().unwrap().of("f"), Some(&FileStatus::Deleted));
    }

    #[test]
    fn push_pull_roundtrip() {
        let td_a = TempDir::new("repoA").unwrap();
        let td_b = TempDir::new("repoB").unwrap();
        let td_r = TempDir::new("remote").unwrap();
        let a = Repository::init(td_a.path()).unwrap();
        write(&a, "m.txt", "v1");
        a.add(&["m.txt"]).unwrap();
        a.commit("v1", "alice").unwrap();
        let report = a.push(td_r.path(), "main").unwrap();
        assert!(report.objects_sent >= 3);

        let b = Repository::init(td_b.path()).unwrap();
        b.pull(td_r.path(), "main").unwrap();
        assert_eq!(std::fs::read_to_string(td_b.join("m.txt")).unwrap(), "v1");

        // Second push transfers only the delta.
        write(&a, "m.txt", "v2");
        a.add(&["m.txt"]).unwrap();
        a.commit("v2", "alice").unwrap();
        let report2 = a.push(td_r.path(), "main").unwrap();
        assert_eq!(report2.commits.len(), 1);
        b.pull(td_r.path(), "main").unwrap();
        assert_eq!(std::fs::read_to_string(td_b.join("m.txt")).unwrap(), "v2");
    }

    #[test]
    fn push_rejects_non_fast_forward() {
        let td_a = TempDir::new("repoA").unwrap();
        let td_b = TempDir::new("repoB").unwrap();
        let td_r = TempDir::new("remote").unwrap();
        let a = Repository::init(td_a.path()).unwrap();
        write(&a, "f", "1");
        a.add(&["f"]).unwrap();
        a.commit("c1", "alice").unwrap();
        a.push(td_r.path(), "main").unwrap();

        let b = Repository::init(td_b.path()).unwrap();
        b.pull(td_r.path(), "main").unwrap();
        std::fs::write(td_b.join("f"), "b-edit").unwrap();
        b.add(&["f"]).unwrap();
        b.commit("b2", "bob").unwrap();
        b.push(td_r.path(), "main").unwrap();

        // A commits without fetching; push must be rejected.
        write(&a, "f", "a-edit");
        a.add(&["f"]).unwrap();
        a.commit("a2", "alice").unwrap();
        assert!(a.push(td_r.path(), "main").is_err());
    }

    #[test]
    fn resolve_short_hex_and_head() {
        let td = TempDir::new("repo").unwrap();
        let repo = Repository::init(td.path()).unwrap();
        write(&repo, "f", "1");
        repo.add(&["f"]).unwrap();
        let c1 = repo.commit("c1", "t").unwrap();
        assert_eq!(repo.resolve("HEAD").unwrap(), c1);
        assert_eq!(repo.resolve(&c1.to_hex()).unwrap(), c1);
        assert_eq!(repo.resolve(&c1.to_hex()[..12]).unwrap(), c1);
        assert!(repo.resolve("nonexistent").is_err());
    }

    #[test]
    fn diff_default_driver() {
        let td = TempDir::new("repo").unwrap();
        let repo = Repository::init(td.path()).unwrap();
        write(&repo, "f.txt", "alpha\nbeta\n");
        repo.add(&["f.txt"]).unwrap();
        let c1 = repo.commit("c1", "t").unwrap();
        write(&repo, "f.txt", "alpha\ngamma\n");
        repo.add(&["f.txt"]).unwrap();
        let c2 = repo.commit("c2", "t").unwrap();
        let diff = repo.diff(Some(c1), Some(c2)).unwrap();
        assert!(diff.contains("- beta"));
        assert!(diff.contains("+ gamma"));
    }
}
