//! `gitcore` — a from-scratch content-addressed version control substrate.
//!
//! The paper builds Git-Theta as an extension of Git, using a narrow,
//! well-defined slice of Git's machinery: the object database, refs,
//! the staging index, `.gitattributes`-driven clean/smudge filters,
//! custom diff/merge drivers, repository-level hooks, and three-way
//! merges over a commit DAG. This module implements exactly that slice
//! natively in Rust (per DESIGN.md §1 the external `git` binary is
//! substituted, preserving the identical control flow: clean on add,
//! smudge on checkout, driver dispatch on merge/diff, hooks around
//! commit/push).
//!
//! Terminology matches Git: objects are zlib-deflated, sha256-addressed
//! blobs/trees/commits under `.theta/objects/`; branches live under
//! `.theta/refs/heads/`; the staging area is `.theta/index`.

pub mod attributes;
pub mod drivers;
pub mod index;
pub mod mergebase;
pub mod object;
pub mod odb;
pub mod refs;
pub mod remote;
pub mod repo;
pub mod status;

pub use attributes::{AttrValue, Attributes};
pub use drivers::{DiffDriver, DriverRegistry, FilterDriver, MergeDriver, MergeOutcome};
pub use index::Index;
pub use object::{Commit, Object, Oid, Tree, TreeEntry};
pub use odb::Odb;
pub use remote::RemoteSpec;
pub use repo::{MergeReport, Repository, THETA_DIR};
pub use status::{FileStatus, Status};
