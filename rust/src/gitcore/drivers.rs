//! Driver and hook registries — gitcore's inversion-of-control points.
//!
//! Mirrors Git's extension architecture (paper §2.3): the `filter`
//! attribute selects a clean/smudge [`FilterDriver`]; the `diff` and
//! `merge` attributes select [`DiffDriver`] / [`MergeDriver`]; hooks run
//! around commit and push. Git-Theta (`theta/`) and the LFS substrate
//! (`lfs/`) register their drivers here by name at startup.

use super::repo::Repository;
use anyhow::Result;
use once_cell::sync::Lazy;
use std::collections::BTreeMap;
use std::sync::{Arc, RwLock};

/// Clean/smudge filter pair (Git's `filter` attribute).
pub trait FilterDriver: Send + Sync {
    /// Working tree → staging area transformation (runs on `add`).
    fn clean(&self, repo: &Repository, path: &str, working: &[u8]) -> Result<Vec<u8>>;

    /// Staging area → working tree transformation (runs on `checkout`).
    fn smudge(&self, repo: &Repository, path: &str, staged: &[u8]) -> Result<Vec<u8>>;
}

/// Custom diff driver (Git's `diff` attribute).
pub trait DiffDriver: Send + Sync {
    /// Render a human-readable diff between two staged representations.
    fn diff(
        &self,
        repo: &Repository,
        path: &str,
        old: Option<&[u8]>,
        new: Option<&[u8]>,
    ) -> Result<String>;
}

/// Result of a merge-driver invocation.
#[derive(Debug, Clone, PartialEq)]
pub enum MergeOutcome {
    /// Fully resolved staged content for the merged file.
    Resolved(Vec<u8>),
    /// The driver could not resolve; the merge must stop.
    Conflict(String),
}

/// Options threaded into merge drivers from the CLI.
#[derive(Debug, Clone, Default)]
pub struct MergeOptions {
    /// Non-interactive strategy selection (e.g. "average", "ours").
    pub strategy: Option<String>,
    /// Per-parameter-group strategy overrides: (group glob, strategy).
    pub per_group: Vec<(String, String)>,
    /// Surface per-file merge-engine statistics (trivial/skipped group
    /// counts, reconstruction-cache hits and misses, prefetched
    /// objects) on stderr while merging.
    pub verbose: bool,
}

/// Custom merge driver (Git's `merge` attribute).
pub trait MergeDriver: Send + Sync {
    fn merge(
        &self,
        repo: &Repository,
        path: &str,
        ancestor: Option<&[u8]>,
        ours: Option<&[u8]>,
        theirs: Option<&[u8]>,
        opts: &MergeOptions,
    ) -> Result<MergeOutcome>;
}

/// Repository-level hooks (Git's hook scripts).
pub trait Hooks: Send + Sync {
    /// Runs after a commit is created (paper: records new LFS objects
    /// under `.theta/commits/<commit>`).
    fn post_commit(&self, _repo: &Repository, _commit: &super::object::Oid) -> Result<()> {
        Ok(())
    }

    /// Runs before commits are pushed (paper: syncs LFS objects). The
    /// remote may be a directory or an http endpoint; hooks move bytes
    /// through `lfs::transport::open_transport`, never raw paths.
    fn pre_push(
        &self,
        _repo: &Repository,
        _remote: &super::remote::RemoteSpec,
        _commits: &[super::object::Oid],
    ) -> Result<()> {
        Ok(())
    }
}

#[derive(Default)]
struct Registries {
    filters: BTreeMap<String, Arc<dyn FilterDriver>>,
    diffs: BTreeMap<String, Arc<dyn DiffDriver>>,
    merges: BTreeMap<String, Arc<dyn MergeDriver>>,
    hooks: Vec<Arc<dyn Hooks>>,
}

static REGISTRIES: Lazy<RwLock<Registries>> = Lazy::new(|| RwLock::new(Registries::default()));

/// Global driver registry facade.
pub struct DriverRegistry;

impl DriverRegistry {
    /// Register (or replace) the clean/smudge filter driver `name`.
    pub fn register_filter(name: &str, driver: Arc<dyn FilterDriver>) {
        REGISTRIES.write().unwrap().filters.insert(name.to_string(), driver);
    }

    /// Register (or replace) the diff driver `name`.
    pub fn register_diff(name: &str, driver: Arc<dyn DiffDriver>) {
        REGISTRIES.write().unwrap().diffs.insert(name.to_string(), driver);
    }

    /// Register (or replace) the merge driver `name`.
    pub fn register_merge(name: &str, driver: Arc<dyn MergeDriver>) {
        REGISTRIES.write().unwrap().merges.insert(name.to_string(), driver);
    }

    /// Append a hook set; all registered hooks run on push/fetch.
    pub fn register_hooks(hooks: Arc<dyn Hooks>) {
        REGISTRIES.write().unwrap().hooks.push(hooks);
    }

    /// Look up the filter driver registered under `name`.
    pub fn filter(name: &str) -> Option<Arc<dyn FilterDriver>> {
        REGISTRIES.read().unwrap().filters.get(name).cloned()
    }

    /// Look up the diff driver registered under `name`.
    pub fn diff(name: &str) -> Option<Arc<dyn DiffDriver>> {
        REGISTRIES.read().unwrap().diffs.get(name).cloned()
    }

    /// Look up the merge driver registered under `name`.
    pub fn merge(name: &str) -> Option<Arc<dyn MergeDriver>> {
        REGISTRIES.read().unwrap().merges.get(name).cloned()
    }

    /// Every registered hook set, in registration order.
    pub fn all_hooks() -> Vec<Arc<dyn Hooks>> {
        REGISTRIES.read().unwrap().hooks.clone()
    }

    /// Names of all registered filter drivers (sorted).
    pub fn filter_names() -> Vec<String> {
        REGISTRIES.read().unwrap().filters.keys().cloned().collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    struct Upper;
    impl FilterDriver for Upper {
        fn clean(&self, _r: &Repository, _p: &str, w: &[u8]) -> Result<Vec<u8>> {
            Ok(w.to_ascii_uppercase())
        }
        fn smudge(&self, _r: &Repository, _p: &str, s: &[u8]) -> Result<Vec<u8>> {
            Ok(s.to_ascii_lowercase())
        }
    }

    #[test]
    fn register_and_lookup() {
        DriverRegistry::register_filter("upper-test", Arc::new(Upper));
        assert!(DriverRegistry::filter("upper-test").is_some());
        assert!(DriverRegistry::filter("absent").is_none());
        assert!(DriverRegistry::filter_names().contains(&"upper-test".to_string()));
    }
}
