//! Object database: zlib-deflated, sha256-addressed object storage.

use super::object::{Commit, Object, Oid, Tree};
use anyhow::{bail, Context, Result};
use flate2::read::ZlibDecoder;
use flate2::write::ZlibEncoder;
use flate2::Compression;
use std::io::{Read, Write};
use std::path::{Path, PathBuf};

/// An on-disk object store rooted at `<dir>/objects/`.
#[derive(Debug, Clone)]
pub struct Odb {
    root: PathBuf,
}

impl Odb {
    /// Open the store under `<theta_dir>/objects` (need not exist yet).
    pub fn open(theta_dir: &Path) -> Odb {
        Odb {
            root: theta_dir.join("objects"),
        }
    }

    /// Open the store and create its directory on disk.
    pub fn init(theta_dir: &Path) -> Result<Odb> {
        let odb = Odb::open(theta_dir);
        std::fs::create_dir_all(&odb.root).context("creating objects dir")?;
        Ok(odb)
    }

    fn path_for(&self, oid: &Oid) -> PathBuf {
        let hex = oid.to_hex();
        self.root.join(&hex[..2]).join(&hex[2..])
    }

    /// Whether the object is present on disk.
    pub fn contains(&self, oid: &Oid) -> bool {
        self.path_for(oid).exists()
    }

    /// Write an object; returns its oid. Idempotent.
    pub fn write(&self, obj: &Object) -> Result<Oid> {
        let encoded = obj.encode();
        let oid = Oid::of_bytes(&encoded);
        let path = self.path_for(&oid);
        if path.exists() {
            return Ok(oid);
        }
        std::fs::create_dir_all(path.parent().unwrap())?;
        let mut enc = ZlibEncoder::new(Vec::new(), Compression::fast());
        enc.write_all(&encoded)?;
        let compressed = enc.finish()?;
        // Write-then-rename for atomicity under concurrent writers.
        let tmp = path.with_extension(format!("tmp{}", std::process::id()));
        std::fs::write(&tmp, &compressed)?;
        std::fs::rename(&tmp, &path)?;
        Ok(oid)
    }

    /// Read and verify an object.
    pub fn read(&self, oid: &Oid) -> Result<Object> {
        let path = self.path_for(oid);
        let compressed = std::fs::read(&path)
            .with_context(|| format!("object {} not found", oid.short()))?;
        let mut dec = ZlibDecoder::new(&compressed[..]);
        let mut encoded = Vec::new();
        dec.read_to_end(&mut encoded).context("corrupt object (zlib)")?;
        let actual = Oid::of_bytes(&encoded);
        if actual != *oid {
            bail!(
                "object corruption: {} hashes to {}",
                oid.short(),
                actual.short()
            );
        }
        Object::decode(&encoded)
    }

    /// Read an object that must be a blob.
    pub fn read_blob(&self, oid: &Oid) -> Result<Vec<u8>> {
        match self.read(oid)? {
            Object::Blob(data) => Ok(data),
            other => bail!("expected blob {}, found {}", oid.short(), other.kind()),
        }
    }

    /// Read an object that must be a tree.
    pub fn read_tree(&self, oid: &Oid) -> Result<Tree> {
        match self.read(oid)? {
            Object::Tree(t) => Ok(t),
            other => bail!("expected tree {}, found {}", oid.short(), other.kind()),
        }
    }

    /// Read an object that must be a commit.
    pub fn read_commit(&self, oid: &Oid) -> Result<Commit> {
        match self.read(oid)? {
            Object::Commit(c) => Ok(c),
            other => bail!("expected commit {}, found {}", oid.short(), other.kind()),
        }
    }

    /// Store raw bytes as a blob; returns its oid.
    pub fn write_blob(&self, data: Vec<u8>) -> Result<Oid> {
        self.write(&Object::Blob(data))
    }

    /// Total on-disk bytes of all stored objects (for benchmarking).
    pub fn disk_usage(&self) -> Result<u64> {
        let mut total = 0u64;
        if !self.root.exists() {
            return Ok(0);
        }
        for shard in std::fs::read_dir(&self.root)? {
            let shard = shard?;
            if shard.file_type()?.is_dir() {
                for f in std::fs::read_dir(shard.path())? {
                    total += f?.metadata()?.len();
                }
            }
        }
        Ok(total)
    }

    /// All oids in the store (diagnostics / fsck).
    pub fn list(&self) -> Result<Vec<Oid>> {
        let mut out = Vec::new();
        if !self.root.exists() {
            return Ok(out);
        }
        for shard in std::fs::read_dir(&self.root)? {
            let shard = shard?;
            if !shard.file_type()?.is_dir() {
                continue;
            }
            let prefix = shard.file_name().to_string_lossy().to_string();
            for f in std::fs::read_dir(shard.path())? {
                let name = f?.file_name().to_string_lossy().to_string();
                if let Ok(oid) = Oid::from_hex(&format!("{prefix}{name}")) {
                    out.push(oid);
                }
            }
        }
        Ok(out)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::gitcore::object::TreeEntry;
    use crate::util::tmp::TempDir;

    #[test]
    fn write_read_roundtrip() {
        let td = TempDir::new("odb").unwrap();
        let odb = Odb::init(td.path()).unwrap();
        let oid = odb.write_blob(b"parameter data".to_vec()).unwrap();
        assert!(odb.contains(&oid));
        assert_eq!(odb.read_blob(&oid).unwrap(), b"parameter data");
    }

    #[test]
    fn dedup_identical_content() {
        let td = TempDir::new("odb").unwrap();
        let odb = Odb::init(td.path()).unwrap();
        let a = odb.write_blob(vec![7u8; 1000]).unwrap();
        let usage1 = odb.disk_usage().unwrap();
        let b = odb.write_blob(vec![7u8; 1000]).unwrap();
        assert_eq!(a, b);
        assert_eq!(odb.disk_usage().unwrap(), usage1);
    }

    #[test]
    fn detects_corruption() {
        let td = TempDir::new("odb").unwrap();
        let odb = Odb::init(td.path()).unwrap();
        let oid = odb.write_blob(b"data".to_vec()).unwrap();
        // Overwrite the object file with a different valid object's bytes.
        let other = Object::Blob(b"tampered".to_vec());
        let mut enc = ZlibEncoder::new(Vec::new(), Compression::fast());
        enc.write_all(&other.encode()).unwrap();
        let path = odb.path_for(&oid);
        std::fs::write(&path, enc.finish().unwrap()).unwrap();
        assert!(odb.read(&oid).is_err());
    }

    #[test]
    fn typed_readers_enforce_kind() {
        let td = TempDir::new("odb").unwrap();
        let odb = Odb::init(td.path()).unwrap();
        let blob = odb.write_blob(b"x".to_vec()).unwrap();
        assert!(odb.read_tree(&blob).is_err());
        let tree_oid = odb
            .write(&Object::Tree(Tree::from_entries(vec![TreeEntry {
                path: "f".into(),
                oid: blob,
            }])))
            .unwrap();
        assert!(odb.read_tree(&tree_oid).is_ok());
        assert!(odb.read_commit(&tree_oid).is_err());
    }

    #[test]
    fn list_finds_all() {
        let td = TempDir::new("odb").unwrap();
        let odb = Odb::init(td.path()).unwrap();
        let mut oids: Vec<Oid> = (0..10)
            .map(|i| odb.write_blob(vec![i as u8; 10]).unwrap())
            .collect();
        let mut listed = odb.list().unwrap();
        oids.sort();
        listed.sort();
        assert_eq!(oids, listed);
    }
}
