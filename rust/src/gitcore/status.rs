//! Working-tree status: staged / modified / untracked / deleted.

use super::object::Oid;

/// Status of one path.
#[derive(Debug, Clone, PartialEq, Eq)]
pub enum FileStatus {
    /// Staged and new relative to HEAD.
    Added,
    /// Staged with content differing from HEAD.
    Staged,
    /// Working tree differs from the staged version.
    Modified,
    /// In HEAD or index but missing from the working tree.
    Deleted,
    /// Present in the working tree but never staged.
    Untracked,
}

/// Full repository status snapshot.
#[derive(Debug, Clone, Default)]
pub struct Status {
    /// (path, status) pairs sorted by path.
    pub entries: Vec<(String, FileStatus)>,
    /// HEAD commit at the time of the snapshot.
    pub head: Option<Oid>,
    /// Current branch name (None when detached).
    pub branch: Option<String>,
}

impl Status {
    /// True when nothing is staged, modified, or deleted
    /// (untracked files do not count as dirty).
    pub fn is_clean(&self) -> bool {
        self.entries
            .iter()
            .all(|(_, s)| matches!(s, FileStatus::Untracked))
    }

    /// Status of one path, if it appears in the snapshot.
    pub fn of(&self, path: &str) -> Option<&FileStatus> {
        self.entries
            .iter()
            .find(|(p, _)| p == path)
            .map(|(_, s)| s)
    }

    /// Render like `git status --short`.
    pub fn render(&self) -> String {
        let mut out = String::new();
        match (&self.branch, &self.head) {
            (Some(b), Some(h)) => out.push_str(&format!("On branch {b} at {}\n", h.short())),
            (Some(b), None) => out.push_str(&format!("On branch {b} (no commits yet)\n")),
            (None, Some(h)) => out.push_str(&format!("HEAD detached at {}\n", h.short())),
            (None, None) => out.push_str("Empty repository\n"),
        }
        for (path, st) in &self.entries {
            let code = match st {
                FileStatus::Added => "A ",
                FileStatus::Staged => "M ",
                FileStatus::Modified => " M",
                FileStatus::Deleted => " D",
                FileStatus::Untracked => "??",
            };
            out.push_str(&format!("{code} {path}\n"));
        }
        if self.entries.is_empty() {
            out.push_str("nothing to commit, working tree clean\n");
        }
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn render_and_queries() {
        let st = Status {
            entries: vec![
                ("a.txt".into(), FileStatus::Added),
                ("b.txt".into(), FileStatus::Modified),
                ("c.txt".into(), FileStatus::Untracked),
            ],
            head: Some(Oid::of_bytes(b"h")),
            branch: Some("main".into()),
        };
        assert!(!st.is_clean());
        assert_eq!(st.of("b.txt"), Some(&FileStatus::Modified));
        let rendered = st.render();
        assert!(rendered.contains("On branch main"));
        assert!(rendered.contains("A  a.txt"));
        assert!(rendered.contains(" M b.txt"));
        assert!(rendered.contains("?? c.txt"));
    }

    #[test]
    fn untracked_only_is_clean() {
        let st = Status {
            entries: vec![("x".into(), FileStatus::Untracked)],
            head: None,
            branch: Some("main".into()),
        };
        assert!(st.is_clean());
    }
}
