//! Object model: oids, blobs, trees, commits and their wire encodings.

use crate::util::hex;
use anyhow::{bail, Context, Result};
use sha2::{Digest, Sha256};
use std::fmt;

/// A sha256 object id.
#[derive(Clone, Copy, PartialEq, Eq, Hash, PartialOrd, Ord)]
pub struct Oid(pub [u8; 32]);

impl Oid {
    /// Hash raw bytes into an oid (sha256 of the encoded object).
    pub fn of_bytes(bytes: &[u8]) -> Oid {
        let mut h = Sha256::new();
        h.update(bytes);
        Oid(h.finalize().into())
    }

    /// Lowercase 64-char hex form.
    pub fn to_hex(&self) -> String {
        hex::encode(&self.0)
    }

    /// Parse a 64-char hex id (surrounding whitespace tolerated).
    pub fn from_hex(s: &str) -> Result<Oid> {
        let bytes = hex::decode(s.trim()).context("invalid hex oid")?;
        let arr: [u8; 32] = bytes
            .try_into()
            .map_err(|_| anyhow::anyhow!("oid must be 32 bytes"))?;
        Ok(Oid(arr))
    }

    /// Abbreviated id for display.
    pub fn short(&self) -> String {
        self.to_hex()[..10].to_string()
    }
}

impl fmt::Debug for Oid {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "Oid({})", self.short())
    }
}

impl fmt::Display for Oid {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(&self.to_hex())
    }
}

/// A tree entry: one tracked file (flat path) → blob oid.
///
/// Unlike Git's nested trees, `gitcore` stores one flat manifest per
/// commit. Blob-level dedup (what Git-Theta relies on) is identical;
/// only subtree-level dedup of the manifest itself is lost, which is
/// negligible at checkpoint-metadata scale.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct TreeEntry {
    /// Path of the tracked file, relative to the worktree root.
    pub path: String,
    /// Blob oid the path resolves to at this commit.
    pub oid: Oid,
}

/// A flat tree (sorted by path).
#[derive(Debug, Clone, Default, PartialEq, Eq)]
pub struct Tree {
    /// Entries sorted by path (see [`Tree::from_entries`]).
    pub entries: Vec<TreeEntry>,
}

impl Tree {
    /// Build a tree, sorting by path and dropping duplicate paths.
    pub fn from_entries(mut entries: Vec<TreeEntry>) -> Tree {
        entries.sort_by(|a, b| a.path.cmp(&b.path));
        entries.dedup_by(|a, b| a.path == b.path);
        Tree { entries }
    }

    /// Look up the blob oid for a path (binary search).
    pub fn get(&self, path: &str) -> Option<Oid> {
        self.entries
            .binary_search_by(|e| e.path.as_str().cmp(path))
            .ok()
            .map(|i| self.entries[i].oid)
    }

    /// All tracked paths, in sorted order.
    pub fn paths(&self) -> impl Iterator<Item = &str> {
        self.entries.iter().map(|e| e.path.as_str())
    }
}

/// A commit object.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct Commit {
    /// The tree snapshot this commit records.
    pub tree: Oid,
    /// Parent commits (empty for a root, two for a merge).
    pub parents: Vec<Oid>,
    /// Free-form author string.
    pub author: String,
    /// Seconds since the epoch.
    pub timestamp: u64,
    /// Commit message.
    pub message: String,
}

/// Any object in the database.
#[derive(Debug, Clone, PartialEq)]
pub enum Object {
    /// Raw file contents.
    Blob(Vec<u8>),
    /// A flat path manifest.
    Tree(Tree),
    /// A history node.
    Commit(Commit),
}

impl Object {
    /// Object type name: `"blob"`, `"tree"`, or `"commit"`.
    pub fn kind(&self) -> &'static str {
        match self {
            Object::Blob(_) => "blob",
            Object::Tree(_) => "tree",
            Object::Commit(_) => "commit",
        }
    }

    /// Canonical byte encoding: `<kind> <len>\0<body>` (like Git).
    pub fn encode(&self) -> Vec<u8> {
        let body = self.encode_body();
        let mut out = Vec::with_capacity(body.len() + 16);
        out.extend_from_slice(self.kind().as_bytes());
        out.push(b' ');
        out.extend_from_slice(body.len().to_string().as_bytes());
        out.push(0);
        out.extend_from_slice(&body);
        out
    }

    fn encode_body(&self) -> Vec<u8> {
        match self {
            Object::Blob(data) => data.clone(),
            Object::Tree(tree) => {
                let mut out = Vec::new();
                for e in &tree.entries {
                    out.extend_from_slice(e.oid.to_hex().as_bytes());
                    out.push(b' ');
                    out.extend_from_slice(e.path.as_bytes());
                    out.push(b'\n');
                }
                out
            }
            Object::Commit(c) => {
                let mut out = String::new();
                out.push_str(&format!("tree {}\n", c.tree));
                for p in &c.parents {
                    out.push_str(&format!("parent {p}\n"));
                }
                out.push_str(&format!("author {}\n", c.author));
                out.push_str(&format!("timestamp {}\n", c.timestamp));
                out.push('\n');
                out.push_str(&c.message);
                out.into_bytes()
            }
        }
    }

    /// Decode from the canonical encoding.
    pub fn decode(bytes: &[u8]) -> Result<Object> {
        let nul = bytes
            .iter()
            .position(|&b| b == 0)
            .context("object missing header terminator")?;
        let header = std::str::from_utf8(&bytes[..nul]).context("object header not utf-8")?;
        let (kind, len_str) = header
            .split_once(' ')
            .context("object header missing space")?;
        let len: usize = len_str.parse().context("object header bad length")?;
        let body = &bytes[nul + 1..];
        if body.len() != len {
            bail!("object length mismatch: header says {len}, body is {}", body.len());
        }
        match kind {
            "blob" => Ok(Object::Blob(body.to_vec())),
            "tree" => {
                let text = std::str::from_utf8(body).context("tree body not utf-8")?;
                let mut entries = Vec::new();
                for line in text.lines() {
                    let (oid_hex, path) = line.split_once(' ').context("bad tree entry")?;
                    entries.push(TreeEntry {
                        path: path.to_string(),
                        oid: Oid::from_hex(oid_hex)?,
                    });
                }
                Ok(Object::Tree(Tree::from_entries(entries)))
            }
            "commit" => {
                let text = std::str::from_utf8(body).context("commit body not utf-8")?;
                let (headers, message) = text
                    .split_once("\n\n")
                    .unwrap_or((text, ""));
                let mut tree = None;
                let mut parents = Vec::new();
                let mut author = String::new();
                let mut timestamp = 0u64;
                for line in headers.lines() {
                    let (key, val) = line.split_once(' ').context("bad commit header")?;
                    match key {
                        "tree" => tree = Some(Oid::from_hex(val)?),
                        "parent" => parents.push(Oid::from_hex(val)?),
                        "author" => author = val.to_string(),
                        "timestamp" => timestamp = val.parse().context("bad timestamp")?,
                        _ => {} // forward-compatible: ignore unknown headers
                    }
                }
                Ok(Object::Commit(Commit {
                    tree: tree.context("commit missing tree")?,
                    parents,
                    author,
                    timestamp,
                    message: message.to_string(),
                }))
            }
            other => bail!("unknown object kind '{other}'"),
        }
    }

    /// Object id: sha256 of the canonical encoding.
    pub fn oid(&self) -> Oid {
        Oid::of_bytes(&self.encode())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn oid_hex_roundtrip() {
        let oid = Oid::of_bytes(b"hello");
        let hexs = oid.to_hex();
        assert_eq!(hexs.len(), 64);
        assert_eq!(Oid::from_hex(&hexs).unwrap(), oid);
        assert!(Oid::from_hex("xyz").is_err());
    }

    #[test]
    fn blob_roundtrip() {
        let obj = Object::Blob(vec![0, 1, 2, 255]);
        let enc = obj.encode();
        assert!(enc.starts_with(b"blob 4\0"));
        assert_eq!(Object::decode(&enc).unwrap(), obj);
    }

    #[test]
    fn tree_roundtrip_and_sorting() {
        let tree = Tree::from_entries(vec![
            TreeEntry { path: "z.txt".into(), oid: Oid::of_bytes(b"z") },
            TreeEntry { path: "a/b.txt".into(), oid: Oid::of_bytes(b"ab") },
        ]);
        assert_eq!(tree.entries[0].path, "a/b.txt");
        let obj = Object::Tree(tree.clone());
        let back = Object::decode(&obj.encode()).unwrap();
        assert_eq!(back, Object::Tree(tree.clone()));
        assert_eq!(tree.get("z.txt"), Some(Oid::of_bytes(b"z")));
        assert_eq!(tree.get("missing"), None);
    }

    #[test]
    fn commit_roundtrip() {
        let c = Commit {
            tree: Oid::of_bytes(b"tree"),
            parents: vec![Oid::of_bytes(b"p1"), Oid::of_bytes(b"p2")],
            author: "tester <t@example.com>".into(),
            timestamp: 1_700_000_000,
            message: "Merge branch 'rte'\n\nbody".into(),
        };
        let obj = Object::Commit(c.clone());
        assert_eq!(Object::decode(&obj.encode()).unwrap(), Object::Commit(c));
    }

    #[test]
    fn content_addressing_is_stable() {
        let a = Object::Blob(b"same".to_vec());
        let b = Object::Blob(b"same".to_vec());
        assert_eq!(a.oid(), b.oid());
        let c = Object::Blob(b"diff".to_vec());
        assert_ne!(a.oid(), c.oid());
    }

    #[test]
    fn decode_rejects_corrupt() {
        assert!(Object::decode(b"blob 5\0abc").is_err());
        assert!(Object::decode(b"weird 3\0abc").is_err());
        assert!(Object::decode(b"no-nul").is_err());
    }
}
