//! Refs: branches and HEAD.

use super::object::Oid;
use anyhow::{bail, Context, Result};
use std::path::{Path, PathBuf};

/// Where HEAD currently points.
#[derive(Debug, Clone, PartialEq, Eq)]
pub enum Head {
    /// On a branch (which may not exist yet in a fresh repo).
    Branch(String),
    /// Detached at a commit.
    Detached(Oid),
}

#[derive(Debug, Clone)]
/// Loose-file ref storage under `<theta_dir>/refs/heads` plus `HEAD`.
pub struct Refs {
    theta_dir: PathBuf,
}

impl Refs {
    /// Open the ref store rooted at `theta_dir` (need not exist yet).
    pub fn open(theta_dir: &Path) -> Refs {
        Refs {
            theta_dir: theta_dir.to_path_buf(),
        }
    }

    /// Create the ref layout and point HEAD at `default_branch`.
    pub fn init(theta_dir: &Path, default_branch: &str) -> Result<Refs> {
        let refs = Refs::open(theta_dir);
        std::fs::create_dir_all(theta_dir.join("refs/heads"))?;
        refs.set_head(&Head::Branch(default_branch.to_string()))?;
        Ok(refs)
    }

    fn head_path(&self) -> PathBuf {
        self.theta_dir.join("HEAD")
    }

    fn branch_path(&self, name: &str) -> Result<PathBuf> {
        if name.is_empty()
            || name.contains("..")
            || name.starts_with('/')
            || name.chars().any(|c| c.is_whitespace() || c == '\\' || c == ':')
        {
            bail!("invalid branch name '{name}'");
        }
        Ok(self.theta_dir.join("refs/heads").join(name))
    }

    /// Read HEAD: either a branch pointer or a detached commit.
    pub fn head(&self) -> Result<Head> {
        let text = std::fs::read_to_string(self.head_path()).context("reading HEAD")?;
        let text = text.trim();
        if let Some(branch) = text.strip_prefix("ref: refs/heads/") {
            Ok(Head::Branch(branch.to_string()))
        } else {
            Ok(Head::Detached(Oid::from_hex(text)?))
        }
    }

    /// Rewrite HEAD.
    pub fn set_head(&self, head: &Head) -> Result<()> {
        let content = match head {
            Head::Branch(name) => format!("ref: refs/heads/{name}\n"),
            Head::Detached(oid) => format!("{oid}\n"),
        };
        std::fs::write(self.head_path(), content).context("writing HEAD")
    }

    /// The commit HEAD resolves to (None on an unborn branch).
    pub fn head_commit(&self) -> Result<Option<Oid>> {
        match self.head()? {
            Head::Branch(name) => self.branch(&name),
            Head::Detached(oid) => Ok(Some(oid)),
        }
    }

    /// Read a branch tip (None if the branch does not exist).
    pub fn branch(&self, name: &str) -> Result<Option<Oid>> {
        let path = self.branch_path(name)?;
        if !path.exists() {
            return Ok(None);
        }
        let text = std::fs::read_to_string(path)?;
        Ok(Some(Oid::from_hex(text.trim())?))
    }

    /// Point a branch at a commit, creating it if needed.
    pub fn set_branch(&self, name: &str, oid: &Oid) -> Result<()> {
        let path = self.branch_path(name)?;
        if let Some(parent) = path.parent() {
            std::fs::create_dir_all(parent)?;
        }
        std::fs::write(path, format!("{oid}\n")).context("writing branch ref")
    }

    /// Remove a branch ref (no-op if absent).
    pub fn delete_branch(&self, name: &str) -> Result<()> {
        let path = self.branch_path(name)?;
        if path.exists() {
            std::fs::remove_file(path)?;
        }
        Ok(())
    }

    /// All branches as `(name, tip)` pairs, sorted by name.
    pub fn branches(&self) -> Result<Vec<(String, Oid)>> {
        let dir = self.theta_dir.join("refs/heads");
        let mut out = Vec::new();
        if !dir.exists() {
            return Ok(out);
        }
        collect_refs(&dir, String::new(), &mut out)?;
        out.sort_by(|a, b| a.0.cmp(&b.0));
        Ok(out)
    }
}

fn collect_refs(dir: &Path, prefix: String, out: &mut Vec<(String, Oid)>) -> Result<()> {
    for entry in std::fs::read_dir(dir)? {
        let entry = entry?;
        let name = entry.file_name().to_string_lossy().to_string();
        let full = if prefix.is_empty() {
            name.clone()
        } else {
            format!("{prefix}/{name}")
        };
        if entry.file_type()?.is_dir() {
            collect_refs(&entry.path(), full, out)?;
        } else {
            let text = std::fs::read_to_string(entry.path())?;
            out.push((full, Oid::from_hex(text.trim())?));
        }
    }
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::tmp::TempDir;

    #[test]
    fn init_and_head() {
        let td = TempDir::new("refs").unwrap();
        let refs = Refs::init(td.path(), "main").unwrap();
        assert_eq!(refs.head().unwrap(), Head::Branch("main".into()));
        assert_eq!(refs.head_commit().unwrap(), None); // unborn

        let oid = Oid::of_bytes(b"c1");
        refs.set_branch("main", &oid).unwrap();
        assert_eq!(refs.head_commit().unwrap(), Some(oid));
    }

    #[test]
    fn branches_and_detached() {
        let td = TempDir::new("refs").unwrap();
        let refs = Refs::init(td.path(), "main").unwrap();
        let a = Oid::of_bytes(b"a");
        let b = Oid::of_bytes(b"b");
        refs.set_branch("main", &a).unwrap();
        refs.set_branch("feature/rte", &b).unwrap();
        let branches = refs.branches().unwrap();
        assert_eq!(
            branches,
            vec![("feature/rte".to_string(), b), ("main".to_string(), a)]
        );
        refs.set_head(&Head::Detached(a)).unwrap();
        assert_eq!(refs.head_commit().unwrap(), Some(a));
        refs.delete_branch("feature/rte").unwrap();
        assert_eq!(refs.branch("feature/rte").unwrap(), None);
    }

    #[test]
    fn rejects_bad_branch_names() {
        let td = TempDir::new("refs").unwrap();
        let refs = Refs::init(td.path(), "main").unwrap();
        for bad in ["", "../x", "/abs", "has space", "a:b"] {
            assert!(refs.set_branch(bad, &Oid::of_bytes(b"x")).is_err(), "{bad}");
        }
    }
}
