//! Merge-base computation: lowest common ancestor over the commit DAG.

use super::object::Oid;
use super::odb::Odb;
use anyhow::Result;
use std::collections::{HashMap, HashSet, VecDeque};

/// All ancestors of `start` (inclusive).
pub fn ancestors(odb: &Odb, start: Oid) -> Result<HashSet<Oid>> {
    let mut seen = HashSet::new();
    let mut queue = VecDeque::from([start]);
    while let Some(oid) = queue.pop_front() {
        if !seen.insert(oid) {
            continue;
        }
        let commit = odb.read_commit(&oid)?;
        for p in commit.parents {
            queue.push_back(p);
        }
    }
    Ok(seen)
}

/// Best common ancestor of `a` and `b` for three-way merge.
///
/// Returns a common ancestor that is not an ancestor of any other common
/// ancestor (a "maximal" common ancestor). With criss-cross histories
/// several maximal candidates can exist; ties break by highest timestamp
/// then oid for determinism, matching what `git merge-base` would pick as
/// one of its results.
pub fn merge_base(odb: &Odb, a: Oid, b: Oid) -> Result<Option<Oid>> {
    if a == b {
        return Ok(Some(a));
    }
    let anc_a = ancestors(odb, a)?;
    let anc_b = ancestors(odb, b)?;
    let common: HashSet<Oid> = anc_a.intersection(&anc_b).copied().collect();
    if common.is_empty() {
        return Ok(None);
    }

    // Remove every common ancestor reachable from another common ancestor
    // via at least one edge; survivors are maximal.
    let mut reachable_from_common: HashSet<Oid> = HashSet::new();
    for &c in &common {
        let commit = odb.read_commit(&c)?;
        let mut queue: VecDeque<Oid> = commit.parents.into();
        let mut seen = HashSet::new();
        while let Some(p) = queue.pop_front() {
            if !seen.insert(p) {
                continue;
            }
            reachable_from_common.insert(p);
            let pc = odb.read_commit(&p)?;
            for gp in pc.parents {
                queue.push_back(gp);
            }
        }
    }
    let mut maximal: Vec<Oid> = common
        .iter()
        .filter(|c| !reachable_from_common.contains(c))
        .copied()
        .collect();
    if maximal.is_empty() {
        return Ok(None);
    }
    let mut stamped: Vec<(u64, Oid)> = Vec::new();
    for oid in maximal.drain(..) {
        stamped.push((odb.read_commit(&oid)?.timestamp, oid));
    }
    stamped.sort_by(|x, y| y.0.cmp(&x.0).then(y.1.cmp(&x.1)));
    Ok(Some(stamped[0].1))
}

/// Is `anc` an ancestor of (or equal to) `desc`? Used for fast-forward checks.
pub fn is_ancestor(odb: &Odb, anc: Oid, desc: Oid) -> Result<bool> {
    Ok(ancestors(odb, desc)?.contains(&anc))
}

/// Commits reachable from `tip` but not from any commit in `exclude`,
/// oldest-first — the set a push must transfer.
pub fn commits_between(odb: &Odb, tip: Oid, exclude: &[Oid]) -> Result<Vec<Oid>> {
    let mut excluded = HashSet::new();
    for &e in exclude {
        excluded.extend(ancestors(odb, e)?);
    }
    let mut out = Vec::new();
    let mut seen = HashSet::new();
    let mut queue = VecDeque::from([tip]);
    while let Some(oid) = queue.pop_front() {
        if excluded.contains(&oid) || !seen.insert(oid) {
            continue;
        }
        out.push(oid);
        for p in odb.read_commit(&oid)?.parents {
            queue.push_back(p);
        }
    }
    // Topological order (parents before children), timestamp/oid tie-break,
    // so same-second commits still apply oldest-first.
    let set: HashSet<Oid> = out.iter().copied().collect();
    let mut indegree: HashMap<Oid, usize> = HashMap::new();
    let mut children: HashMap<Oid, Vec<Oid>> = HashMap::new();
    let mut stamped: HashMap<Oid, u64> = HashMap::new();
    for &oid in &out {
        let c = odb.read_commit(&oid)?;
        stamped.insert(oid, c.timestamp);
        let in_parents = c.parents.iter().filter(|p| set.contains(p)).count();
        indegree.insert(oid, in_parents);
        for p in c.parents {
            if set.contains(&p) {
                children.entry(p).or_default().push(oid);
            }
        }
    }
    let mut ready: Vec<Oid> = out
        .iter()
        .copied()
        .filter(|o| indegree[o] == 0)
        .collect();
    let mut ordered = Vec::with_capacity(out.len());
    while !ready.is_empty() {
        ready.sort_by_key(|o| (std::cmp::Reverse(stamped[o]), std::cmp::Reverse(*o)));
        let next = ready.pop().unwrap();
        ordered.push(next);
        for &child in children.get(&next).into_iter().flatten() {
            let d = indegree.get_mut(&child).unwrap();
            *d -= 1;
            if *d == 0 {
                ready.push(child);
            }
        }
    }
    Ok(ordered)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::gitcore::object::{Commit, Object, Tree};
    use crate::util::tmp::TempDir;

    fn commit(odb: &Odb, parents: Vec<Oid>, ts: u64, msg: &str) -> Oid {
        let tree = odb.write(&Object::Tree(Tree::default())).unwrap();
        odb.write(&Object::Commit(Commit {
            tree,
            parents,
            author: "t".into(),
            timestamp: ts,
            message: msg.into(),
        }))
        .unwrap()
    }

    #[test]
    fn linear_history() {
        let td = TempDir::new("mb").unwrap();
        let odb = Odb::init(td.path()).unwrap();
        let c1 = commit(&odb, vec![], 1, "c1");
        let c2 = commit(&odb, vec![c1], 2, "c2");
        let c3 = commit(&odb, vec![c2], 3, "c3");
        assert_eq!(merge_base(&odb, c3, c2).unwrap(), Some(c2));
        assert_eq!(merge_base(&odb, c2, c3).unwrap(), Some(c2));
        assert!(is_ancestor(&odb, c1, c3).unwrap());
        assert!(!is_ancestor(&odb, c3, c1).unwrap());
    }

    #[test]
    fn diverged_branches() {
        let td = TempDir::new("mb").unwrap();
        let odb = Odb::init(td.path()).unwrap();
        let base = commit(&odb, vec![], 1, "base");
        let main2 = commit(&odb, vec![base], 2, "anli");
        let feat2 = commit(&odb, vec![base], 3, "rte");
        assert_eq!(merge_base(&odb, main2, feat2).unwrap(), Some(base));
        // After merging, base of merge vs either tip is the tip itself.
        let merged = commit(&odb, vec![main2, feat2], 4, "merge");
        assert_eq!(merge_base(&odb, merged, main2).unwrap(), Some(main2));
    }

    #[test]
    fn unrelated_histories() {
        let td = TempDir::new("mb").unwrap();
        let odb = Odb::init(td.path()).unwrap();
        let a = commit(&odb, vec![], 1, "a");
        let b = commit(&odb, vec![], 1, "b");
        assert_eq!(merge_base(&odb, a, b).unwrap(), None);
    }

    #[test]
    fn commits_between_excludes_remote() {
        let td = TempDir::new("mb").unwrap();
        let odb = Odb::init(td.path()).unwrap();
        let c1 = commit(&odb, vec![], 1, "c1");
        let c2 = commit(&odb, vec![c1], 2, "c2");
        let c3 = commit(&odb, vec![c2], 3, "c3");
        let c4 = commit(&odb, vec![c3], 4, "c4");
        assert_eq!(commits_between(&odb, c4, &[c2]).unwrap(), vec![c3, c4]);
        assert_eq!(commits_between(&odb, c4, &[]).unwrap(), vec![c1, c2, c3, c4]);
        assert!(commits_between(&odb, c2, &[c4]).unwrap().is_empty());
    }
}
