//! Synthetic few-shot entailment-style tasks with controlled transfer.
//!
//! Stand-ins for CB / RTE / ANLI (paper §4): each task labels a token
//! sequence by the sign of Σ_t w_task[x_t], where
//! `w_task = w_shared + γ · w_specific`. The shared component makes the
//! tasks related — training on one moves the others — which is the
//! property Figure 3 depends on (merging RTE- and ANLI-trained models
//! improves RTE over the CB-trained base).

use crate::util::rng::Pcg64;

/// Which paper task this synthetic task stands in for.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum TaskKind {
    Cb,
    Rte,
    Anli,
}

impl TaskKind {
    pub fn name(self) -> &'static str {
        match self {
            TaskKind::Cb => "CB",
            TaskKind::Rte => "RTE",
            TaskKind::Anli => "ANLI R1",
        }
    }

    fn task_seed(self) -> u64 {
        match self {
            TaskKind::Cb => 101,
            TaskKind::Rte => 202,
            TaskKind::Anli => 303,
        }
    }
}

/// A generated binary classification task over token sequences.
pub struct SyntheticTask {
    pub kind: TaskKind,
    pub vocab: usize,
    pub seq_len: usize,
    /// Per-token labeling weights (w_shared + γ·w_specific).
    weights: Vec<f64>,
    rng: Pcg64,
}

/// Relatedness: fraction of the labeling rule shared across tasks.
const SPECIFIC_GAMMA: f64 = 0.55;

impl SyntheticTask {
    pub fn new(kind: TaskKind, vocab: usize, seq_len: usize, shared_seed: u64) -> SyntheticTask {
        let mut shared_rng = Pcg64::new(shared_seed);
        let mut spec_rng = Pcg64::new(shared_seed ^ kind.task_seed());
        let weights: Vec<f64> = (0..vocab)
            .map(|_| shared_rng.next_gaussian() + SPECIFIC_GAMMA * spec_rng.next_gaussian())
            .collect();
        SyntheticTask {
            kind,
            vocab,
            seq_len,
            weights,
            rng: Pcg64::new(shared_seed ^ kind.task_seed() ^ 0xdead),
        }
    }

    /// Sample a batch: (tokens i32[B*S] flattened, labels i32[B]).
    pub fn batch(&mut self, batch: usize) -> (Vec<i32>, Vec<i32>) {
        let mut tokens = Vec::with_capacity(batch * self.seq_len);
        let mut labels = Vec::with_capacity(batch);
        for _ in 0..batch {
            let mut score = 0f64;
            let start = tokens.len();
            for _ in 0..self.seq_len {
                let tok = self.rng.below(self.vocab as u64) as usize;
                score += self.weights[tok];
                tokens.push(tok as i32);
            }
            let _ = start;
            labels.push((score > 0.0) as i32);
        }
        (tokens, labels)
    }

    /// A deterministic held-out eval set (fresh generator, fixed seed).
    pub fn eval_set(&self, batches: usize, batch: usize) -> Vec<(Vec<i32>, Vec<i32>)> {
        let mut task = SyntheticTask {
            kind: self.kind,
            vocab: self.vocab,
            seq_len: self.seq_len,
            weights: self.weights.clone(),
            rng: Pcg64::new(0xe7a1 ^ self.kind.task_seed()),
        };
        (0..batches).map(|_| task.batch(batch)).collect()
    }
}

/// Pearson correlation of two tasks' labeling rules (diagnostic; related
/// tasks should correlate strongly but not perfectly).
pub fn task_correlation(a: &SyntheticTask, b: &SyntheticTask) -> f64 {
    let n = a.weights.len().min(b.weights.len());
    let ma: f64 = a.weights.iter().take(n).sum::<f64>() / n as f64;
    let mb: f64 = b.weights.iter().take(n).sum::<f64>() / n as f64;
    let mut cov = 0.0;
    let mut va = 0.0;
    let mut vb = 0.0;
    for i in 0..n {
        let da = a.weights[i] - ma;
        let db = b.weights[i] - mb;
        cov += da * db;
        va += da * da;
        vb += db * db;
    }
    cov / (va.sqrt() * vb.sqrt())
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn batches_have_valid_tokens_and_balanced_labels() {
        let mut task = SyntheticTask::new(TaskKind::Rte, 256, 32, 7);
        let (tokens, labels) = task.batch(200);
        assert_eq!(tokens.len(), 200 * 32);
        assert!(tokens.iter().all(|&t| (0..256).contains(&t)));
        let pos: usize = labels.iter().map(|&l| l as usize).sum();
        assert!(pos > 40 && pos < 160, "label balance {pos}/200");
    }

    #[test]
    fn tasks_are_related_but_distinct() {
        let cb = SyntheticTask::new(TaskKind::Cb, 256, 32, 7);
        let rte = SyntheticTask::new(TaskKind::Rte, 256, 32, 7);
        let anli = SyntheticTask::new(TaskKind::Anli, 256, 32, 7);
        let c1 = task_correlation(&cb, &rte);
        let c2 = task_correlation(&rte, &anli);
        assert!(c1 > 0.5 && c1 < 0.95, "cb-rte correlation {c1}");
        assert!(c2 > 0.5 && c2 < 0.95, "rte-anli correlation {c2}");
        // Same kind, same seed -> identical rule.
        let rte2 = SyntheticTask::new(TaskKind::Rte, 256, 32, 7);
        assert!((task_correlation(&rte, &rte2) - 1.0).abs() < 1e-12);
    }

    #[test]
    fn eval_set_is_deterministic() {
        let task = SyntheticTask::new(TaskKind::Cb, 128, 16, 9);
        let a = task.eval_set(2, 8);
        let b = task.eval_set(2, 8);
        assert_eq!(a, b);
    }
}
