//! Figure 3 driver: real training through the AOT-compiled train step.
//!
//! The paper fine-tunes T0-3B on CB, RTE, and ANLI and shows task
//! accuracy at each point in commit history (merging the RTE and ANLI
//! branches recovers RTE performance). We reproduce the *shape* of that
//! result with a small transformer classifier (L2, `python/compile/
//! model.py`) trained from Rust by executing the AOT `train_step` /
//! `eval_step` artifacts — Python never runs here.
//!
//! Tasks are synthetic few-shot entailment-style classification problems
//! with controlled transfer: CB/RTE/ANLI-like tasks share a common
//! latent labeling rule plus task-specific components, so training on
//! one task moves performance on the others the way the paper's related
//! NLP tasks do.

pub mod data;
pub mod trainer;

pub use data::{SyntheticTask, TaskKind};
pub use trainer::{ModelParams, TrainConfig, Trainer};
