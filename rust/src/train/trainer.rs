//! Training/eval loops over the AOT-compiled L2 artifacts.
//!
//! `python/compile/aot.py` lowers `train_step` (full fine-tune),
//! `train_step_lora` (LoRA adapters only; base frozen) and `eval_step`
//! to HLO text, writes initial parameters to
//! `artifacts/init_params.safetensors`, and records tensor ordering in
//! `artifacts/manifest.json`. This module drives those artifacts from
//! Rust — the whole Figure 3 experiment runs without Python.

use super::data::SyntheticTask;
use crate::checkpoint::{Checkpoint, CheckpointFormat, SafetensorsFormat};
use crate::runtime::Runtime;
use crate::tensor::{DType, Tensor};
use crate::util::json::Json;
use anyhow::{bail, Context, Result};
use std::path::Path;
use std::sync::Arc;

/// Model/optimizer configuration mirrored from `artifacts/manifest.json`.
#[derive(Debug, Clone)]
pub struct TrainConfig {
    pub vocab: usize,
    pub seq_len: usize,
    pub d_model: usize,
    pub layers: usize,
    pub heads: usize,
    pub classes: usize,
    pub batch: usize,
    pub lora_rank: usize,
    pub param_names: Vec<String>,
    pub lora_param_names: Vec<String>,
}

impl TrainConfig {
    pub fn load(artifacts: &Path) -> Result<TrainConfig> {
        let path = artifacts.join("manifest.json");
        let json = Json::parse(
            &std::fs::read_to_string(&path)
                .with_context(|| format!("reading {} (run `make artifacts`)", path.display()))?,
        )?;
        let model = json.get("model").context("manifest missing model")?;
        let get = |k: &str| -> Result<usize> {
            model
                .get(k)
                .and_then(|v| v.as_usize())
                .with_context(|| format!("manifest missing model.{k}"))
        };
        let names = |k: &str| -> Result<Vec<String>> {
            Ok(model
                .get(k)
                .and_then(|v| v.as_arr())
                .with_context(|| format!("manifest missing model.{k}"))?
                .iter()
                .filter_map(|v| v.as_str().map(|s| s.to_string()))
                .collect())
        };
        Ok(TrainConfig {
            vocab: get("vocab")?,
            seq_len: get("seq_len")?,
            d_model: get("d_model")?,
            layers: get("layers")?,
            heads: get("heads")?,
            classes: get("classes")?,
            batch: get("batch")?,
            lora_rank: get("lora_rank")?,
            param_names: names("param_names")?,
            lora_param_names: names("lora_param_names")?,
        })
    }
}

/// Ordered parameter list (order must match the artifact signature).
#[derive(Debug, Clone)]
pub struct ModelParams {
    pub tensors: Vec<(String, Tensor)>,
}

impl ModelParams {
    pub fn from_checkpoint(ck: &Checkpoint, order: &[String]) -> Result<ModelParams> {
        let mut tensors = Vec::with_capacity(order.len());
        for name in order {
            let t = ck
                .get(name)
                .with_context(|| format!("checkpoint missing parameter '{name}'"))?;
            tensors.push((name.clone(), t.clone()));
        }
        Ok(ModelParams { tensors })
    }

    pub fn to_checkpoint(&self) -> Checkpoint {
        self.tensors.iter().cloned().collect()
    }

    pub fn get(&self, name: &str) -> Option<&Tensor> {
        self.tensors.iter().find(|(n, _)| n == name).map(|(_, t)| t)
    }
}

/// The Figure 3 trainer.
pub struct Trainer {
    rt: Arc<Runtime>,
    pub cfg: TrainConfig,
}

impl Trainer {
    /// Create a trainer if artifacts are built; `None` otherwise (lets
    /// tests/examples skip gracefully).
    pub fn try_new() -> Result<Option<Trainer>> {
        let rt = Runtime::global()?;
        if !rt.available("train_step") || !rt.available("eval_step") {
            return Ok(None);
        }
        let cfg = TrainConfig::load(rt.artifacts_dir())?;
        Ok(Some(Trainer { rt, cfg }))
    }

    /// Initial (pre-trained stand-in) parameters from the artifacts dir.
    pub fn init_params(&self) -> Result<ModelParams> {
        let path = self.rt.artifacts_dir().join("init_params.safetensors");
        let ck = SafetensorsFormat.load_file(&path)?;
        ModelParams::from_checkpoint(&ck, &self.cfg.param_names)
    }

    /// Initial (zero / identity-scaled) LoRA adapters.
    pub fn init_lora(&self) -> Result<ModelParams> {
        let path = self.rt.artifacts_dir().join("init_lora.safetensors");
        let ck = SafetensorsFormat.load_file(&path)?;
        ModelParams::from_checkpoint(&ck, &self.cfg.lora_param_names)
    }

    fn batch_tensors(&self, tokens: &[i32], labels: &[i32]) -> Result<(Tensor, Tensor)> {
        let b = self.cfg.batch;
        if tokens.len() != b * self.cfg.seq_len || labels.len() != b {
            bail!(
                "batch shape mismatch: {} tokens, {} labels (want {}x{})",
                tokens.len(),
                labels.len(),
                b,
                self.cfg.seq_len
            );
        }
        let mut tbytes = Vec::with_capacity(tokens.len() * 4);
        for t in tokens {
            tbytes.extend_from_slice(&t.to_le_bytes());
        }
        let mut lbytes = Vec::with_capacity(labels.len() * 4);
        for l in labels {
            lbytes.extend_from_slice(&l.to_le_bytes());
        }
        Ok((
            Tensor::from_bytes(DType::I32, vec![b, self.cfg.seq_len], tbytes)?,
            Tensor::from_bytes(DType::I32, vec![b], lbytes)?,
        ))
    }

    /// Run `steps` full fine-tuning steps; returns per-step losses.
    pub fn train(
        &self,
        params: &mut ModelParams,
        task: &mut SyntheticTask,
        steps: usize,
        lr: f32,
    ) -> Result<Vec<f32>> {
        let lr_t = Tensor::from_f32(vec![], vec![lr])?;
        let mut losses = Vec::with_capacity(steps);
        for _ in 0..steps {
            let (tokens, labels) = task.batch(self.cfg.batch);
            let (tok_t, lab_t) = self.batch_tensors(&tokens, &labels)?;
            let mut inputs: Vec<&Tensor> = params.tensors.iter().map(|(_, t)| t).collect();
            inputs.push(&tok_t);
            inputs.push(&lab_t);
            inputs.push(&lr_t);
            let mut out = self.rt.execute("train_step", &inputs)?;
            if out.len() != params.tensors.len() + 1 {
                bail!(
                    "train_step returned {} outputs, expected {}",
                    out.len(),
                    params.tensors.len() + 1
                );
            }
            let loss = out.pop().unwrap().to_f32_vec()?[0];
            for ((_, slot), new) in params.tensors.iter_mut().zip(out) {
                *slot = new;
            }
            losses.push(loss);
        }
        Ok(losses)
    }

    /// Run `steps` LoRA-only steps (base params frozen).
    pub fn train_lora(
        &self,
        params: &ModelParams,
        lora: &mut ModelParams,
        task: &mut SyntheticTask,
        steps: usize,
        lr: f32,
    ) -> Result<Vec<f32>> {
        let lr_t = Tensor::from_f32(vec![], vec![lr])?;
        let mut losses = Vec::with_capacity(steps);
        for _ in 0..steps {
            let (tokens, labels) = task.batch(self.cfg.batch);
            let (tok_t, lab_t) = self.batch_tensors(&tokens, &labels)?;
            let mut inputs: Vec<&Tensor> = params.tensors.iter().map(|(_, t)| t).collect();
            inputs.extend(lora.tensors.iter().map(|(_, t)| t));
            inputs.push(&tok_t);
            inputs.push(&lab_t);
            inputs.push(&lr_t);
            let mut out = self.rt.execute("train_step_lora", &inputs)?;
            if out.len() != lora.tensors.len() + 1 {
                bail!(
                    "train_step_lora returned {} outputs, expected {}",
                    out.len(),
                    lora.tensors.len() + 1
                );
            }
            let loss = out.pop().unwrap().to_f32_vec()?[0];
            for ((_, slot), new) in lora.tensors.iter_mut().zip(out) {
                *slot = new;
            }
            losses.push(loss);
        }
        Ok(losses)
    }

    /// Merge LoRA adapters into the base weights (α/r scaling), using
    /// the kernel-backed LoRA application.
    pub fn merge_lora(
        &self,
        params: &ModelParams,
        lora: &ModelParams,
        alpha: f32,
    ) -> Result<ModelParams> {
        let mut merged = params.clone();
        for (name, _) in &lora.tensors {
            // Names are "<target>.lora_a" / "<target>.lora_b".
            if let Some(target) = name.strip_suffix(".lora_a") {
                let a = lora.get(name).unwrap();
                let b = lora
                    .get(&format!("{target}.lora_b"))
                    .with_context(|| format!("missing lora_b for '{target}'"))?;
                let slot = merged
                    .tensors
                    .iter_mut()
                    .find(|(n, _)| n == target)
                    .with_context(|| format!("missing base weight '{target}'"))?;
                slot.1 = crate::mlops::lora_apply(&slot.1, a, b, alpha)?;
            }
        }
        Ok(merged)
    }

    /// Accuracy + mean loss over the task's held-out eval set.
    pub fn eval(
        &self,
        params: &ModelParams,
        task: &SyntheticTask,
        batches: usize,
    ) -> Result<(f64, f64)> {
        let sets = task.eval_set(batches, self.cfg.batch);
        let mut correct = 0f64;
        let mut total = 0f64;
        let mut loss_sum = 0f64;
        for (tokens, labels) in &sets {
            let (tok_t, lab_t) = self.batch_tensors(tokens, labels)?;
            let mut inputs: Vec<&Tensor> = params.tensors.iter().map(|(_, t)| t).collect();
            inputs.push(&tok_t);
            inputs.push(&lab_t);
            let out = self.rt.execute("eval_step", &inputs)?;
            if out.len() != 2 {
                bail!("eval_step returned {} outputs, expected 2", out.len());
            }
            correct += out[0].to_f32_vec()?[0] as f64;
            loss_sum += out[1].to_f32_vec()?[0] as f64;
            total += self.cfg.batch as f64;
        }
        Ok((correct / total, loss_sum / sets.len() as f64))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn model_params_ordering() {
        let mut ck = Checkpoint::new();
        ck.insert("b", Tensor::from_f32(vec![1], vec![2.0]).unwrap());
        ck.insert("a", Tensor::from_f32(vec![1], vec![1.0]).unwrap());
        let order = vec!["b".to_string(), "a".to_string()];
        let p = ModelParams::from_checkpoint(&ck, &order).unwrap();
        assert_eq!(p.tensors[0].0, "b");
        assert_eq!(p.tensors[1].0, "a");
        assert_eq!(p.to_checkpoint(), ck);
        // Missing params error.
        let bad = vec!["missing".to_string()];
        assert!(ModelParams::from_checkpoint(&ck, &bad).is_err());
    }

    #[test]
    fn trainer_absent_without_artifacts() {
        // With THETA_ARTIFACTS pointed at an empty dir, try_new is None.
        // (Runs before artifacts are built in CI ordering too.)
        let td = crate::util::tmp::TempDir::new("noart").unwrap();
        std::env::set_var("THETA_ARTIFACTS", td.path());
        // Note: Runtime::global() may already be bound to a real dir if
        // another test created it first; accept both outcomes but don't
        // crash.
        let _ = Trainer::try_new();
        std::env::remove_var("THETA_ARTIFACTS");
    }
}
