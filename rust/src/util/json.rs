//! Minimal JSON value type, parser, and writer.
//!
//! The offline vendor set has no `serde`/`serde_json`, so Git-Theta's
//! metadata files (which the paper stores as text tracked by Git) are
//! handled by this self-contained implementation. It supports the full
//! JSON grammar plus a `pretty` writer with stable (insertion-ordered)
//! object keys so metadata diffs are meaningful line-by-line — a property
//! the paper relies on for Git to version metadata files efficiently.

use std::collections::BTreeMap;
use std::fmt;

/// A parsed JSON value.
#[derive(Debug, Clone, PartialEq)]
pub enum Json {
    /// The `null` literal.
    Null,
    /// `true` / `false`.
    Bool(bool),
    /// All JSON numbers are kept as f64; integers round-trip exactly up to 2^53.
    Num(f64),
    /// A string value.
    Str(String),
    /// An array of values.
    Arr(Vec<Json>),
    /// Objects preserve insertion order via a parallel key list.
    Obj(JsonObj),
}

/// An insertion-ordered JSON object.
#[derive(Debug, Clone, Default, PartialEq)]
pub struct JsonObj {
    keys: Vec<String>,
    map: BTreeMap<String, Json>,
}

impl JsonObj {
    /// An empty object.
    pub fn new() -> Self {
        Self::default()
    }

    /// Set `key` to `value`; a re-inserted key keeps its original
    /// position in the key order.
    pub fn insert(&mut self, key: impl Into<String>, value: impl Into<Json>) {
        let key = key.into();
        if !self.map.contains_key(&key) {
            self.keys.push(key.clone());
        }
        self.map.insert(key, value.into());
    }

    /// Look a field up by key.
    pub fn get(&self, key: &str) -> Option<&Json> {
        self.map.get(key)
    }

    /// Whether the object has a field named `key`.
    pub fn contains_key(&self, key: &str) -> bool {
        self.map.contains_key(key)
    }

    /// Remove and return a field (its key slot is dropped too).
    pub fn remove(&mut self, key: &str) -> Option<Json> {
        if let Some(v) = self.map.remove(key) {
            self.keys.retain(|k| k != key);
            Some(v)
        } else {
            None
        }
    }

    /// Number of fields.
    pub fn len(&self) -> usize {
        self.keys.len()
    }

    /// Whether the object has no fields.
    pub fn is_empty(&self) -> bool {
        self.keys.is_empty()
    }

    /// Iterate `(key, value)` pairs in insertion order.
    pub fn iter(&self) -> impl Iterator<Item = (&String, &Json)> {
        self.keys.iter().map(move |k| (k, &self.map[k]))
    }

    /// Iterate keys in insertion order.
    pub fn keys(&self) -> impl Iterator<Item = &String> {
        self.keys.iter()
    }
}

impl FromIterator<(String, Json)> for JsonObj {
    fn from_iter<T: IntoIterator<Item = (String, Json)>>(iter: T) -> Self {
        let mut obj = JsonObj::new();
        for (k, v) in iter {
            obj.insert(k, v);
        }
        obj
    }
}

impl Json {
    /// The boolean value, if this is a `Bool`.
    pub fn as_bool(&self) -> Option<bool> {
        match self {
            Json::Bool(b) => Some(*b),
            _ => None,
        }
    }

    /// The numeric value, if this is a `Num`.
    pub fn as_f64(&self) -> Option<f64> {
        match self {
            Json::Num(n) => Some(*n),
            _ => None,
        }
    }

    /// The value as a u64, if it is a non-negative integer ≤ 2^53.
    pub fn as_u64(&self) -> Option<u64> {
        match self {
            Json::Num(n) if *n >= 0.0 && n.fract() == 0.0 && *n <= 2f64.powi(53) => {
                Some(*n as u64)
            }
            _ => None,
        }
    }

    /// The value as an i64, if it is an integer with |n| ≤ 2^53.
    pub fn as_i64(&self) -> Option<i64> {
        match self {
            Json::Num(n) if n.fract() == 0.0 && n.abs() <= 2f64.powi(53) => Some(*n as i64),
            _ => None,
        }
    }

    /// The value as a usize (via [`as_u64`](Json::as_u64)).
    pub fn as_usize(&self) -> Option<usize> {
        self.as_u64().map(|v| v as usize)
    }

    /// The string slice, if this is a `Str`.
    pub fn as_str(&self) -> Option<&str> {
        match self {
            Json::Str(s) => Some(s),
            _ => None,
        }
    }

    /// The element slice, if this is an `Arr`.
    pub fn as_arr(&self) -> Option<&[Json]> {
        match self {
            Json::Arr(a) => Some(a),
            _ => None,
        }
    }

    /// The object, if this is an `Obj`.
    pub fn as_obj(&self) -> Option<&JsonObj> {
        match self {
            Json::Obj(o) => Some(o),
            _ => None,
        }
    }

    /// Object field lookup; `Json::Null` for anything else.
    pub fn get(&self, key: &str) -> Option<&Json> {
        self.as_obj().and_then(|o| o.get(key))
    }

    /// Compact single-line encoding.
    pub fn to_string_compact(&self) -> String {
        let mut out = String::new();
        write_value(self, &mut out, None, 0);
        out
    }

    /// Pretty, 2-space-indented encoding with a trailing newline.
    pub fn to_string_pretty(&self) -> String {
        let mut out = String::new();
        write_value(self, &mut out, Some(2), 0);
        out.push('\n');
        out
    }

    /// Parse a JSON document from text.
    pub fn parse(text: &str) -> Result<Json, JsonError> {
        let mut p = Parser {
            bytes: text.as_bytes(),
            pos: 0,
        };
        p.skip_ws();
        let v = p.parse_value()?;
        p.skip_ws();
        if p.pos != p.bytes.len() {
            return Err(p.err("trailing characters after JSON document"));
        }
        Ok(v)
    }
}

impl From<bool> for Json {
    fn from(v: bool) -> Self {
        Json::Bool(v)
    }
}
impl From<f64> for Json {
    fn from(v: f64) -> Self {
        Json::Num(v)
    }
}
impl From<u64> for Json {
    fn from(v: u64) -> Self {
        Json::Num(v as f64)
    }
}
impl From<usize> for Json {
    fn from(v: usize) -> Self {
        Json::Num(v as f64)
    }
}
impl From<i64> for Json {
    fn from(v: i64) -> Self {
        Json::Num(v as f64)
    }
}
impl From<u32> for Json {
    fn from(v: u32) -> Self {
        Json::Num(v as f64)
    }
}
impl From<&str> for Json {
    fn from(v: &str) -> Self {
        Json::Str(v.to_string())
    }
}
impl From<String> for Json {
    fn from(v: String) -> Self {
        Json::Str(v)
    }
}
impl From<Vec<Json>> for Json {
    fn from(v: Vec<Json>) -> Self {
        Json::Arr(v)
    }
}
impl From<JsonObj> for Json {
    fn from(v: JsonObj) -> Self {
        Json::Obj(v)
    }
}

/// Error produced while parsing JSON, with a byte offset for context.
#[derive(Debug, Clone, PartialEq, Eq, thiserror::Error)]
#[error("json parse error at byte {pos}: {msg}")]
pub struct JsonError {
    /// Byte offset into the source text where parsing failed.
    pub pos: usize,
    /// What the parser expected or found.
    pub msg: String,
}

struct Parser<'a> {
    bytes: &'a [u8],
    pos: usize,
}

impl<'a> Parser<'a> {
    fn err(&self, msg: impl Into<String>) -> JsonError {
        JsonError {
            pos: self.pos,
            msg: msg.into(),
        }
    }

    fn peek(&self) -> Option<u8> {
        self.bytes.get(self.pos).copied()
    }

    fn bump(&mut self) -> Option<u8> {
        let b = self.peek();
        if b.is_some() {
            self.pos += 1;
        }
        b
    }

    fn skip_ws(&mut self) {
        while matches!(self.peek(), Some(b' ' | b'\t' | b'\n' | b'\r')) {
            self.pos += 1;
        }
    }

    fn expect(&mut self, b: u8) -> Result<(), JsonError> {
        if self.peek() == Some(b) {
            self.pos += 1;
            Ok(())
        } else {
            Err(self.err(format!("expected '{}'", b as char)))
        }
    }

    fn parse_value(&mut self) -> Result<Json, JsonError> {
        self.skip_ws();
        match self.peek() {
            Some(b'{') => self.parse_obj(),
            Some(b'[') => self.parse_arr(),
            Some(b'"') => Ok(Json::Str(self.parse_string()?)),
            Some(b't') => self.parse_lit("true", Json::Bool(true)),
            Some(b'f') => self.parse_lit("false", Json::Bool(false)),
            Some(b'n') => self.parse_lit("null", Json::Null),
            Some(c) if c == b'-' || c.is_ascii_digit() => self.parse_num(),
            Some(c) => Err(self.err(format!("unexpected character '{}'", c as char))),
            None => Err(self.err("unexpected end of input")),
        }
    }

    fn parse_lit(&mut self, lit: &str, val: Json) -> Result<Json, JsonError> {
        if self.bytes[self.pos..].starts_with(lit.as_bytes()) {
            self.pos += lit.len();
            Ok(val)
        } else {
            Err(self.err(format!("expected literal '{lit}'")))
        }
    }

    fn parse_num(&mut self) -> Result<Json, JsonError> {
        let start = self.pos;
        if self.peek() == Some(b'-') {
            self.pos += 1;
        }
        while matches!(self.peek(), Some(c) if c.is_ascii_digit()) {
            self.pos += 1;
        }
        if self.peek() == Some(b'.') {
            self.pos += 1;
            while matches!(self.peek(), Some(c) if c.is_ascii_digit()) {
                self.pos += 1;
            }
        }
        if matches!(self.peek(), Some(b'e' | b'E')) {
            self.pos += 1;
            if matches!(self.peek(), Some(b'+' | b'-')) {
                self.pos += 1;
            }
            while matches!(self.peek(), Some(c) if c.is_ascii_digit()) {
                self.pos += 1;
            }
        }
        let s = std::str::from_utf8(&self.bytes[start..self.pos]).unwrap();
        s.parse::<f64>()
            .map(Json::Num)
            .map_err(|_| self.err(format!("invalid number '{s}'")))
    }

    fn parse_string(&mut self) -> Result<String, JsonError> {
        self.expect(b'"')?;
        let mut out = String::new();
        loop {
            match self.bump() {
                None => return Err(self.err("unterminated string")),
                Some(b'"') => return Ok(out),
                Some(b'\\') => match self.bump() {
                    Some(b'"') => out.push('"'),
                    Some(b'\\') => out.push('\\'),
                    Some(b'/') => out.push('/'),
                    Some(b'b') => out.push('\u{0008}'),
                    Some(b'f') => out.push('\u{000C}'),
                    Some(b'n') => out.push('\n'),
                    Some(b'r') => out.push('\r'),
                    Some(b't') => out.push('\t'),
                    Some(b'u') => {
                        let cp = self.parse_hex4()?;
                        // Handle UTF-16 surrogate pairs.
                        if (0xD800..0xDC00).contains(&cp) {
                            if self.bump() != Some(b'\\') || self.bump() != Some(b'u') {
                                return Err(self.err("unpaired surrogate"));
                            }
                            let lo = self.parse_hex4()?;
                            if !(0xDC00..0xE000).contains(&lo) {
                                return Err(self.err("invalid low surrogate"));
                            }
                            let c = 0x10000 + ((cp - 0xD800) << 10) + (lo - 0xDC00);
                            out.push(char::from_u32(c).ok_or_else(|| self.err("bad codepoint"))?);
                        } else if (0xDC00..0xE000).contains(&cp) {
                            return Err(self.err("unpaired low surrogate"));
                        } else {
                            out.push(char::from_u32(cp).ok_or_else(|| self.err("bad codepoint"))?);
                        }
                    }
                    _ => return Err(self.err("invalid escape")),
                },
                Some(c) if c < 0x20 => return Err(self.err("control character in string")),
                Some(c) => {
                    // Re-assemble multi-byte UTF-8 sequences.
                    let len = utf8_len(c);
                    if len == 1 {
                        out.push(c as char);
                    } else {
                        let start = self.pos - 1;
                        for _ in 1..len {
                            self.bump().ok_or_else(|| self.err("truncated utf-8"))?;
                        }
                        let s = std::str::from_utf8(&self.bytes[start..self.pos])
                            .map_err(|_| self.err("invalid utf-8"))?;
                        out.push_str(s);
                    }
                }
            }
        }
    }

    fn parse_hex4(&mut self) -> Result<u32, JsonError> {
        let mut v = 0u32;
        for _ in 0..4 {
            let c = self.bump().ok_or_else(|| self.err("truncated \\u escape"))?;
            let d = (c as char).to_digit(16).ok_or_else(|| self.err("bad hex digit"))?;
            v = v * 16 + d;
        }
        Ok(v)
    }

    fn parse_arr(&mut self) -> Result<Json, JsonError> {
        self.expect(b'[')?;
        let mut items = Vec::new();
        self.skip_ws();
        if self.peek() == Some(b']') {
            self.pos += 1;
            return Ok(Json::Arr(items));
        }
        loop {
            items.push(self.parse_value()?);
            self.skip_ws();
            match self.bump() {
                Some(b',') => continue,
                Some(b']') => return Ok(Json::Arr(items)),
                _ => return Err(self.err("expected ',' or ']'")),
            }
        }
    }

    fn parse_obj(&mut self) -> Result<Json, JsonError> {
        self.expect(b'{')?;
        let mut obj = JsonObj::new();
        self.skip_ws();
        if self.peek() == Some(b'}') {
            self.pos += 1;
            return Ok(Json::Obj(obj));
        }
        loop {
            self.skip_ws();
            let key = self.parse_string()?;
            self.skip_ws();
            self.expect(b':')?;
            let val = self.parse_value()?;
            obj.insert(key, val);
            self.skip_ws();
            match self.bump() {
                Some(b',') => continue,
                Some(b'}') => return Ok(Json::Obj(obj)),
                _ => return Err(self.err("expected ',' or '}'")),
            }
        }
    }
}

fn utf8_len(first: u8) -> usize {
    match first {
        0x00..=0x7F => 1,
        0xC0..=0xDF => 2,
        0xE0..=0xEF => 3,
        _ => 4,
    }
}

fn write_value(v: &Json, out: &mut String, indent: Option<usize>, depth: usize) {
    match v {
        Json::Null => out.push_str("null"),
        Json::Bool(true) => out.push_str("true"),
        Json::Bool(false) => out.push_str("false"),
        Json::Num(n) => write_num(*n, out),
        Json::Str(s) => write_string(s, out),
        Json::Arr(items) => {
            if items.is_empty() {
                out.push_str("[]");
                return;
            }
            out.push('[');
            for (i, item) in items.iter().enumerate() {
                if i > 0 {
                    out.push(',');
                }
                newline_indent(out, indent, depth + 1);
                write_value(item, out, indent, depth + 1);
            }
            newline_indent(out, indent, depth);
            out.push(']');
        }
        Json::Obj(obj) => {
            if obj.is_empty() {
                out.push_str("{}");
                return;
            }
            out.push('{');
            for (i, (k, val)) in obj.iter().enumerate() {
                if i > 0 {
                    out.push(',');
                }
                newline_indent(out, indent, depth + 1);
                write_string(k, out);
                out.push(':');
                if indent.is_some() {
                    out.push(' ');
                }
                write_value(val, out, indent, depth + 1);
            }
            newline_indent(out, indent, depth);
            out.push('}');
        }
    }
}

fn newline_indent(out: &mut String, indent: Option<usize>, depth: usize) {
    if let Some(w) = indent {
        out.push('\n');
        for _ in 0..w * depth {
            out.push(' ');
        }
    }
}

fn write_num(n: f64, out: &mut String) {
    if n.fract() == 0.0 && n.abs() < 2f64.powi(53) {
        let _ = fmt::Write::write_fmt(out, format_args!("{}", n as i64));
    } else {
        // Shortest round-trip representation Rust provides.
        let _ = fmt::Write::write_fmt(out, format_args!("{n}"));
    }
}

fn write_string(s: &str, out: &mut String) {
    out.push('"');
    for c in s.chars() {
        match c {
            '"' => out.push_str("\\\""),
            '\\' => out.push_str("\\\\"),
            '\n' => out.push_str("\\n"),
            '\r' => out.push_str("\\r"),
            '\t' => out.push_str("\\t"),
            c if (c as u32) < 0x20 => {
                let _ = fmt::Write::write_fmt(out, format_args!("\\u{:04x}", c as u32));
            }
            c => out.push(c),
        }
    }
    out.push('"');
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parse_scalars() {
        assert_eq!(Json::parse("null").unwrap(), Json::Null);
        assert_eq!(Json::parse("true").unwrap(), Json::Bool(true));
        assert_eq!(Json::parse("false").unwrap(), Json::Bool(false));
        assert_eq!(Json::parse("42").unwrap(), Json::Num(42.0));
        assert_eq!(Json::parse("-3.5e2").unwrap(), Json::Num(-350.0));
        assert_eq!(Json::parse("\"hi\"").unwrap(), Json::Str("hi".into()));
    }

    #[test]
    fn parse_nested() {
        let v = Json::parse(r#"{"a": [1, 2, {"b": null}], "c": "x"}"#).unwrap();
        assert_eq!(v.get("c").unwrap().as_str(), Some("x"));
        let arr = v.get("a").unwrap().as_arr().unwrap();
        assert_eq!(arr.len(), 3);
        assert_eq!(arr[2].get("b"), Some(&Json::Null));
    }

    #[test]
    fn parse_string_escapes() {
        let v = Json::parse(r#""a\nb\t\"c\" é 😀""#).unwrap();
        assert_eq!(v.as_str(), Some("a\nb\t\"c\" é 😀"));
    }

    #[test]
    fn roundtrip_compact_and_pretty() {
        let src = r#"{"name":"t5.bias","shape":[1024],"dtype":"f32","lsh":[1,-2,3],"nested":{"x":true}}"#;
        let v = Json::parse(src).unwrap();
        assert_eq!(v.to_string_compact(), src.replace(", ", ","));
        let pretty = v.to_string_pretty();
        assert_eq!(Json::parse(&pretty).unwrap(), v);
    }

    #[test]
    fn object_preserves_insertion_order() {
        let mut o = JsonObj::new();
        o.insert("z", 1u64);
        o.insert("a", 2u64);
        o.insert("m", 3u64);
        let keys: Vec<_> = o.keys().cloned().collect();
        assert_eq!(keys, vec!["z", "a", "m"]);
        assert_eq!(Json::Obj(o).to_string_compact(), r#"{"z":1,"a":2,"m":3}"#);
    }

    #[test]
    fn object_insert_overwrites_in_place() {
        let mut o = JsonObj::new();
        o.insert("a", 1u64);
        o.insert("b", 2u64);
        o.insert("a", 9u64);
        assert_eq!(o.len(), 2);
        assert_eq!(o.get("a").unwrap().as_u64(), Some(9));
        assert_eq!(Json::Obj(o).to_string_compact(), r#"{"a":9,"b":2}"#);
    }

    #[test]
    fn rejects_garbage() {
        assert!(Json::parse("{").is_err());
        assert!(Json::parse("[1,]").is_err());
        assert!(Json::parse("nul").is_err());
        assert!(Json::parse("1 2").is_err());
        assert!(Json::parse(r#""\ud800""#).is_err());
    }

    #[test]
    fn big_integers_roundtrip() {
        let n = 9007199254740992u64; // 2^53
        let v = Json::parse(&n.to_string()).unwrap();
        assert_eq!(v.as_f64(), Some(n as f64));
    }
}
