//! Scoped-thread parallel map (rayon substitute).
//!
//! Git-Theta's clean/smudge filters process parameter groups in an
//! embarrassingly parallel fashion (paper §4: "Git-Theta leverages the
//! embarrassingly parallel nature of parameter processing and makes heavy
//! use of asynchronous and multi-core code"). This module provides the
//! primitive: an order-preserving parallel map over a work list using an
//! atomic work-stealing cursor and scoped threads.

use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::Mutex;

/// Number of worker threads to use, overridable via `THETA_THREADS`.
pub fn default_threads() -> usize {
    if let Ok(v) = std::env::var("THETA_THREADS") {
        if let Ok(n) = v.parse::<usize>() {
            return n.max(1);
        }
    }
    std::thread::available_parallelism()
        .map(|n| n.get())
        .unwrap_or(4)
}

/// Map `f` over `items` in parallel, preserving input order in the output.
///
/// Work is distributed dynamically (one atomic fetch per item) so uneven
/// per-item costs — e.g. a 300 MB embedding matrix next to a 4 KB bias —
/// balance across threads.
pub fn par_map<T, U, F>(items: &[T], threads: usize, f: F) -> Vec<U>
where
    T: Sync,
    U: Send,
    F: Fn(usize, &T) -> U + Sync,
{
    let n = items.len();
    if n == 0 {
        return Vec::new();
    }
    let threads = threads.clamp(1, n);
    if threads == 1 {
        return items.iter().enumerate().map(|(i, t)| f(i, t)).collect();
    }

    let cursor = AtomicUsize::new(0);
    let mut out: Vec<Option<U>> = Vec::with_capacity(n);
    out.resize_with(n, || None);
    let out = Mutex::new(&mut out);

    crossbeam_utils::thread::scope(|scope| {
        for _ in 0..threads {
            scope.spawn(|_| {
                // Each worker buffers its results and writes them back in
                // small batches to keep lock traffic low.
                let mut local: Vec<(usize, U)> = Vec::new();
                loop {
                    let i = cursor.fetch_add(1, Ordering::Relaxed);
                    if i >= n {
                        break;
                    }
                    local.push((i, f(i, &items[i])));
                    if local.len() >= 16 {
                        let mut guard = out.lock().unwrap();
                        for (j, v) in local.drain(..) {
                            guard[j] = Some(v);
                        }
                    }
                }
                if !local.is_empty() {
                    let mut guard = out.lock().unwrap();
                    for (j, v) in local.drain(..) {
                        guard[j] = Some(v);
                    }
                }
            });
        }
    })
    .expect("worker thread panicked");

    out.into_inner()
        .unwrap()
        .iter_mut()
        .map(|slot| slot.take().expect("uncomputed slot"))
        .collect()
}

/// Parallel map where `f` may fail; returns the first error by input order.
pub fn try_par_map<T, U, E, F>(items: &[T], threads: usize, f: F) -> Result<Vec<U>, E>
where
    T: Sync,
    U: Send,
    E: Send,
    F: Fn(usize, &T) -> Result<U, E> + Sync,
{
    let results = par_map(items, threads, f);
    let mut out = Vec::with_capacity(results.len());
    for r in results {
        out.push(r?);
    }
    Ok(out)
}

/// Parallel for-each over owned items where `f` may fail.
///
/// Each item is handed to exactly one worker by value, which lets
/// callers move non-`Sync` state (e.g. `&mut` slices into a shared
/// output buffer) across the pool. On failure the error with the
/// lowest input index is returned, matching [`try_par_map`].
pub fn try_par_consume<T, E, F>(items: Vec<T>, threads: usize, f: F) -> Result<(), E>
where
    T: Send,
    E: Send,
    F: Fn(usize, T) -> Result<(), E> + Sync,
{
    let n = items.len();
    if n == 0 {
        return Ok(());
    }
    let threads = threads.clamp(1, n);
    if threads == 1 {
        for (i, t) in items.into_iter().enumerate() {
            f(i, t)?;
        }
        return Ok(());
    }

    let slots: Vec<Mutex<Option<T>>> = items.into_iter().map(|t| Mutex::new(Some(t))).collect();
    let errors: Vec<Mutex<Option<E>>> = (0..n).map(|_| Mutex::new(None)).collect();
    let cursor = AtomicUsize::new(0);
    crossbeam_utils::thread::scope(|scope| {
        for _ in 0..threads {
            scope.spawn(|_| loop {
                let i = cursor.fetch_add(1, Ordering::Relaxed);
                if i >= n {
                    break;
                }
                let t = slots[i].lock().unwrap().take().expect("item already taken");
                if let Err(e) = f(i, t) {
                    *errors[i].lock().unwrap() = Some(e);
                }
            });
        }
    })
    .expect("worker thread panicked");

    for e in errors {
        if let Some(e) = e.into_inner().unwrap() {
            return Err(e);
        }
    }
    Ok(())
}

/// Process disjoint chunks of a mutable byte buffer in parallel.
///
/// Used by the serializer hot path (byte-shuffle + compression) where each
/// chunk is independent.
pub fn par_chunks_mut<F>(data: &mut [u8], chunk: usize, threads: usize, f: F)
where
    F: Fn(usize, &mut [u8]) + Sync,
{
    if data.is_empty() {
        return;
    }
    let threads = threads.max(1);
    if threads == 1 || data.len() <= chunk {
        for (i, c) in data.chunks_mut(chunk).enumerate() {
            f(i, c);
        }
        return;
    }
    let chunks: Vec<&mut [u8]> = data.chunks_mut(chunk).collect();
    let n = chunks.len();
    let slots: Vec<Mutex<Option<&mut [u8]>>> =
        chunks.into_iter().map(|c| Mutex::new(Some(c))).collect();
    let cursor = AtomicUsize::new(0);
    crossbeam_utils::thread::scope(|scope| {
        for _ in 0..threads.min(n) {
            scope.spawn(|_| loop {
                let i = cursor.fetch_add(1, Ordering::Relaxed);
                if i >= n {
                    break;
                }
                let mut guard = slots[i].lock().unwrap();
                let c = guard.take().expect("chunk already taken");
                drop(guard);
                // Safety of mutation: each chunk is moved out exactly once.
                let c: &mut [u8] = c;
                f(i, c);
            });
        }
    })
    .expect("worker thread panicked");
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn preserves_order() {
        let items: Vec<u64> = (0..1000).collect();
        let out = par_map(&items, 8, |_, &x| x * 2);
        assert_eq!(out, (0..1000).map(|x| x * 2).collect::<Vec<_>>());
    }

    #[test]
    fn single_thread_and_empty() {
        let out = par_map(&[1, 2, 3], 1, |i, &x| (i, x));
        assert_eq!(out, vec![(0, 1), (1, 2), (2, 3)]);
        let empty: Vec<i32> = par_map(&Vec::<i32>::new(), 4, |_, &x| x);
        assert!(empty.is_empty());
    }

    #[test]
    fn uneven_work_balances() {
        // Items with wildly different costs still all complete.
        let items: Vec<usize> = (0..64).collect();
        let out = par_map(&items, 8, |_, &x| {
            let mut acc = 0u64;
            for i in 0..(x * 1000) {
                acc = acc.wrapping_add(i as u64);
            }
            acc
        });
        assert_eq!(out.len(), 64);
    }

    #[test]
    fn try_par_map_propagates_error() {
        let items: Vec<u32> = (0..100).collect();
        let r: Result<Vec<u32>, String> = try_par_map(&items, 4, |_, &x| {
            if x == 37 {
                Err("boom".to_string())
            } else {
                Ok(x)
            }
        });
        assert_eq!(r.unwrap_err(), "boom");
    }

    #[test]
    fn try_par_consume_moves_mutable_borrows() {
        let mut data = vec![0u8; 4096];
        let work: Vec<(u8, &mut [u8])> = data
            .chunks_mut(1024)
            .enumerate()
            .map(|(i, c)| (i as u8 + 1, c))
            .collect();
        let r: Result<(), String> = try_par_consume(work, 4, |_, (v, chunk)| {
            for b in chunk.iter_mut() {
                *b = v;
            }
            Ok(())
        });
        r.unwrap();
        for (i, c) in data.chunks(1024).enumerate() {
            assert!(c.iter().all(|&b| b == i as u8 + 1));
        }
    }

    #[test]
    fn try_par_consume_reports_lowest_index_error() {
        let items: Vec<usize> = (0..100).collect();
        let r: Result<(), String> = try_par_consume(items, 4, |_, x| {
            if x == 17 || x == 80 {
                Err(format!("boom {x}"))
            } else {
                Ok(())
            }
        });
        assert_eq!(r.unwrap_err(), "boom 17");
    }

    #[test]
    fn par_chunks_mut_touches_every_byte() {
        let mut data = vec![0u8; 10_000];
        par_chunks_mut(&mut data, 1024, 4, |_, c| {
            for b in c.iter_mut() {
                *b = b.wrapping_add(1);
            }
        });
        assert!(data.iter().all(|&b| b == 1));
    }
}
