//! MessagePack encoder/decoder.
//!
//! The paper combines multi-tensor updates (e.g. a sparse update's indices
//! and values) into one blob "using msgpack"; this module is that
//! serializer. It implements the msgpack wire format for the subset of
//! types Git-Theta needs: nil, bool, ints, f32/f64, str, bin, array, map.

use std::collections::BTreeMap;

/// A decoded MessagePack value.
#[derive(Debug, Clone, PartialEq)]
pub enum Mp {
    /// The nil value.
    Nil,
    /// A boolean.
    Bool(bool),
    /// A signed integer (negative values decode here).
    Int(i64),
    /// An unsigned integer (non-negative values decode here).
    UInt(u64),
    /// A 32-bit float.
    F32(f32),
    /// A 64-bit float.
    F64(f64),
    /// A UTF-8 string.
    Str(String),
    /// A raw binary blob.
    Bin(Vec<u8>),
    /// An array of values.
    Arr(Vec<Mp>),
    /// String-keyed map (sufficient for Git-Theta payloads), ordered.
    Map(Vec<(String, Mp)>),
}

impl Mp {
    /// The value as a u64 (accepts non-negative [`Mp::Int`]s too).
    pub fn as_u64(&self) -> Option<u64> {
        match self {
            Mp::UInt(v) => Some(*v),
            Mp::Int(v) if *v >= 0 => Some(*v as u64),
            _ => None,
        }
    }

    /// The value as an i64 (accepts [`Mp::UInt`]s that fit).
    pub fn as_i64(&self) -> Option<i64> {
        match self {
            Mp::Int(v) => Some(*v),
            Mp::UInt(v) if *v <= i64::MAX as u64 => Some(*v as i64),
            _ => None,
        }
    }

    /// The value as a string slice, if it is a [`Mp::Str`].
    pub fn as_str(&self) -> Option<&str> {
        match self {
            Mp::Str(s) => Some(s),
            _ => None,
        }
    }

    /// The value as a byte slice, if it is a [`Mp::Bin`].
    pub fn as_bin(&self) -> Option<&[u8]> {
        match self {
            Mp::Bin(b) => Some(b),
            _ => None,
        }
    }

    /// The value as a slice of elements, if it is an [`Mp::Arr`].
    pub fn as_arr(&self) -> Option<&[Mp]> {
        match self {
            Mp::Arr(a) => Some(a),
            _ => None,
        }
    }

    /// Look up `key` in a [`Mp::Map`] (first match wins).
    pub fn get(&self, key: &str) -> Option<&Mp> {
        match self {
            Mp::Map(entries) => entries.iter().find(|(k, _)| k == key).map(|(_, v)| v),
            _ => None,
        }
    }

    /// Build a [`Mp::Map`] from `(key, value)` pairs.
    pub fn map_from(entries: Vec<(&str, Mp)>) -> Mp {
        Mp::Map(entries.into_iter().map(|(k, v)| (k.to_string(), v)).collect())
    }

    /// Encode to msgpack bytes.
    pub fn encode(&self) -> Vec<u8> {
        let mut out = Vec::new();
        encode_into(self, &mut out);
        out
    }

    /// Decode a single msgpack value; errors on trailing bytes.
    pub fn decode(bytes: &[u8]) -> Result<Mp, MpError> {
        let mut d = Decoder { bytes, pos: 0 };
        let v = d.decode_value(0)?;
        if d.pos != bytes.len() {
            return Err(MpError::Trailing(d.pos));
        }
        Ok(v)
    }
}

/// Why a msgpack decode failed (byte offsets index the input slice).
#[derive(Debug, thiserror::Error)]
pub enum MpError {
    /// The input ended before the value it declared was complete.
    #[error("msgpack: truncated input at byte {0}")]
    Truncated(usize),
    /// A tag byte outside the supported subset.
    #[error("msgpack: unknown or unsupported tag 0x{0:02x} at byte {1}")]
    BadTag(u8, usize),
    /// A str payload that is not valid UTF-8.
    #[error("msgpack: invalid utf-8 string at byte {0}")]
    BadUtf8(usize),
    /// A map key that is not a string (or a bin-map value that is not
    /// a bin).
    #[error("msgpack: non-string map key at byte {0}")]
    BadKey(usize),
    /// Bytes remained after the first complete value.
    #[error("msgpack: trailing bytes after value at byte {0}")]
    Trailing(usize),
    /// Containers nested beyond the decoder's depth limit.
    #[error("msgpack: nesting too deep")]
    TooDeep,
}

fn encode_into(v: &Mp, out: &mut Vec<u8>) {
    match v {
        Mp::Nil => out.push(0xc0),
        Mp::Bool(false) => out.push(0xc2),
        Mp::Bool(true) => out.push(0xc3),
        Mp::Int(n) => encode_int(*n, out),
        Mp::UInt(n) => encode_uint(*n, out),
        Mp::F32(f) => {
            out.push(0xca);
            out.extend_from_slice(&f.to_be_bytes());
        }
        Mp::F64(f) => {
            out.push(0xcb);
            out.extend_from_slice(&f.to_be_bytes());
        }
        Mp::Str(s) => {
            let b = s.as_bytes();
            match b.len() {
                0..=31 => out.push(0xa0 | b.len() as u8),
                32..=255 => {
                    out.push(0xd9);
                    out.push(b.len() as u8);
                }
                256..=65535 => {
                    out.push(0xda);
                    out.extend_from_slice(&(b.len() as u16).to_be_bytes());
                }
                _ => {
                    out.push(0xdb);
                    out.extend_from_slice(&(b.len() as u32).to_be_bytes());
                }
            }
            out.extend_from_slice(b);
        }
        Mp::Bin(b) => {
            match b.len() {
                0..=255 => {
                    out.push(0xc4);
                    out.push(b.len() as u8);
                }
                256..=65535 => {
                    out.push(0xc5);
                    out.extend_from_slice(&(b.len() as u16).to_be_bytes());
                }
                _ => {
                    out.push(0xc6);
                    out.extend_from_slice(&(b.len() as u32).to_be_bytes());
                }
            }
            out.extend_from_slice(b);
        }
        Mp::Arr(items) => {
            match items.len() {
                0..=15 => out.push(0x90 | items.len() as u8),
                16..=65535 => {
                    out.push(0xdc);
                    out.extend_from_slice(&(items.len() as u16).to_be_bytes());
                }
                _ => {
                    out.push(0xdd);
                    out.extend_from_slice(&(items.len() as u32).to_be_bytes());
                }
            }
            for item in items {
                encode_into(item, out);
            }
        }
        Mp::Map(entries) => {
            match entries.len() {
                0..=15 => out.push(0x80 | entries.len() as u8),
                16..=65535 => {
                    out.push(0xde);
                    out.extend_from_slice(&(entries.len() as u16).to_be_bytes());
                }
                _ => {
                    out.push(0xdf);
                    out.extend_from_slice(&(entries.len() as u32).to_be_bytes());
                }
            }
            for (k, val) in entries {
                encode_into(&Mp::Str(k.clone()), out);
                encode_into(val, out);
            }
        }
    }
}

fn encode_uint(n: u64, out: &mut Vec<u8>) {
    match n {
        0..=0x7f => out.push(n as u8),
        0x80..=0xff => {
            out.push(0xcc);
            out.push(n as u8);
        }
        0x100..=0xffff => {
            out.push(0xcd);
            out.extend_from_slice(&(n as u16).to_be_bytes());
        }
        0x10000..=0xffff_ffff => {
            out.push(0xce);
            out.extend_from_slice(&(n as u32).to_be_bytes());
        }
        _ => {
            out.push(0xcf);
            out.extend_from_slice(&n.to_be_bytes());
        }
    }
}

fn encode_int(n: i64, out: &mut Vec<u8>) {
    if n >= 0 {
        encode_uint(n as u64, out);
        return;
    }
    match n {
        -32..=-1 => out.push(n as u8),
        -128..=-33 => {
            out.push(0xd0);
            out.push(n as u8);
        }
        -32768..=-129 => {
            out.push(0xd1);
            out.extend_from_slice(&(n as i16).to_be_bytes());
        }
        -2147483648..=-32769 => {
            out.push(0xd2);
            out.extend_from_slice(&(n as i32).to_be_bytes());
        }
        _ => {
            out.push(0xd3);
            out.extend_from_slice(&n.to_be_bytes());
        }
    }
}

const MAX_DEPTH: usize = 64;

struct Decoder<'a> {
    bytes: &'a [u8],
    pos: usize,
}

impl<'a> Decoder<'a> {
    fn take(&mut self, n: usize) -> Result<&'a [u8], MpError> {
        if self.pos + n > self.bytes.len() {
            return Err(MpError::Truncated(self.pos));
        }
        let s = &self.bytes[self.pos..self.pos + n];
        self.pos += n;
        Ok(s)
    }

    fn u8(&mut self) -> Result<u8, MpError> {
        Ok(self.take(1)?[0])
    }
    fn u16(&mut self) -> Result<u16, MpError> {
        Ok(u16::from_be_bytes(self.take(2)?.try_into().unwrap()))
    }
    fn u32(&mut self) -> Result<u32, MpError> {
        Ok(u32::from_be_bytes(self.take(4)?.try_into().unwrap()))
    }
    fn u64v(&mut self) -> Result<u64, MpError> {
        Ok(u64::from_be_bytes(self.take(8)?.try_into().unwrap()))
    }

    fn str_of(&mut self, len: usize) -> Result<String, MpError> {
        let at = self.pos;
        let b = self.take(len)?;
        String::from_utf8(b.to_vec()).map_err(|_| MpError::BadUtf8(at))
    }

    fn arr_of(&mut self, len: usize, depth: usize) -> Result<Mp, MpError> {
        let mut items = Vec::with_capacity(len.min(4096));
        for _ in 0..len {
            items.push(self.decode_value(depth + 1)?);
        }
        Ok(Mp::Arr(items))
    }

    fn map_of(&mut self, len: usize, depth: usize) -> Result<Mp, MpError> {
        let mut entries = Vec::with_capacity(len.min(4096));
        for _ in 0..len {
            let at = self.pos;
            let key = match self.decode_value(depth + 1)? {
                Mp::Str(s) => s,
                _ => return Err(MpError::BadKey(at)),
            };
            entries.push((key, self.decode_value(depth + 1)?));
        }
        Ok(Mp::Map(entries))
    }

    fn decode_value(&mut self, depth: usize) -> Result<Mp, MpError> {
        if depth > MAX_DEPTH {
            return Err(MpError::TooDeep);
        }
        let at = self.pos;
        let tag = self.u8()?;
        Ok(match tag {
            0x00..=0x7f => Mp::UInt(tag as u64),
            0xe0..=0xff => Mp::Int(tag as i8 as i64),
            0x80..=0x8f => self.map_of((tag & 0x0f) as usize, depth)?,
            0x90..=0x9f => self.arr_of((tag & 0x0f) as usize, depth)?,
            0xa0..=0xbf => {
                let len = (tag & 0x1f) as usize;
                Mp::Str(self.str_of(len)?)
            }
            0xc0 => Mp::Nil,
            0xc2 => Mp::Bool(false),
            0xc3 => Mp::Bool(true),
            0xc4 => {
                let len = self.u8()? as usize;
                Mp::Bin(self.take(len)?.to_vec())
            }
            0xc5 => {
                let len = self.u16()? as usize;
                Mp::Bin(self.take(len)?.to_vec())
            }
            0xc6 => {
                let len = self.u32()? as usize;
                Mp::Bin(self.take(len)?.to_vec())
            }
            0xca => Mp::F32(f32::from_be_bytes(self.take(4)?.try_into().unwrap())),
            0xcb => Mp::F64(f64::from_be_bytes(self.take(8)?.try_into().unwrap())),
            0xcc => Mp::UInt(self.u8()? as u64),
            0xcd => Mp::UInt(self.u16()? as u64),
            0xce => Mp::UInt(self.u32()? as u64),
            0xcf => Mp::UInt(self.u64v()?),
            0xd0 => Mp::Int(self.u8()? as i8 as i64),
            0xd1 => Mp::Int(self.u16()? as i16 as i64),
            0xd2 => Mp::Int(self.u32()? as i32 as i64),
            0xd3 => Mp::Int(self.u64v()? as i64),
            0xd9 => {
                let len = self.u8()? as usize;
                Mp::Str(self.str_of(len)?)
            }
            0xda => {
                let len = self.u16()? as usize;
                Mp::Str(self.str_of(len)?)
            }
            0xdb => {
                let len = self.u32()? as usize;
                Mp::Str(self.str_of(len)?)
            }
            0xdc => {
                let len = self.u16()? as usize;
                self.arr_of(len, depth)?
            }
            0xdd => {
                let len = self.u32()? as usize;
                self.arr_of(len, depth)?
            }
            0xde => {
                let len = self.u16()? as usize;
                self.map_of(len, depth)?
            }
            0xdf => {
                let len = self.u32()? as usize;
                self.map_of(len, depth)?
            }
            t => return Err(MpError::BadTag(t, at)),
        })
    }
}

/// Map of named binary payloads — the shape Git-Theta's combined
/// serializer stores (e.g. {"indices": ..., "values": ...}).
pub type BinMap = BTreeMap<String, Vec<u8>>;

/// Encode a map of named blobs (the paper's msgpack combiner).
pub fn encode_bin_map(map: &BinMap) -> Vec<u8> {
    Mp::Map(
        map.iter()
            .map(|(k, v)| (k.clone(), Mp::Bin(v.clone())))
            .collect(),
    )
    .encode()
}

/// Decode a map of named blobs.
pub fn decode_bin_map(bytes: &[u8]) -> Result<BinMap, MpError> {
    let v = Mp::decode(bytes)?;
    let entries = match v {
        Mp::Map(e) => e,
        _ => return Err(MpError::BadKey(0)),
    };
    let mut out = BinMap::new();
    for (k, v) in entries {
        match v {
            Mp::Bin(b) => {
                out.insert(k, b);
            }
            _ => return Err(MpError::BadKey(0)),
        }
    }
    Ok(out)
}

#[cfg(test)]
mod tests {
    use super::*;

    fn roundtrip(v: Mp) {
        let enc = v.encode();
        assert_eq!(Mp::decode(&enc).unwrap(), v);
    }

    #[test]
    fn roundtrip_scalars() {
        roundtrip(Mp::Nil);
        roundtrip(Mp::Bool(true));
        roundtrip(Mp::Bool(false));
        for n in [0u64, 1, 127, 128, 255, 256, 65535, 65536, u32::MAX as u64, u64::MAX] {
            roundtrip(Mp::UInt(n));
        }
        for n in [-1i64, -31, -32, -33, -128, -129, -32768, -32769, i32::MIN as i64, i64::MIN] {
            roundtrip(Mp::Int(n));
        }
        roundtrip(Mp::F32(3.25));
        roundtrip(Mp::F64(-1.0e-8));
    }

    #[test]
    fn roundtrip_strings_and_bins() {
        roundtrip(Mp::Str(String::new()));
        roundtrip(Mp::Str("a".repeat(31)));
        roundtrip(Mp::Str("b".repeat(32)));
        roundtrip(Mp::Str("c".repeat(300)));
        roundtrip(Mp::Str("d".repeat(70_000)));
        roundtrip(Mp::Bin(vec![]));
        roundtrip(Mp::Bin(vec![7u8; 255]));
        roundtrip(Mp::Bin(vec![8u8; 70_000]));
    }

    #[test]
    fn roundtrip_containers() {
        roundtrip(Mp::Arr(vec![Mp::UInt(1), Mp::Str("x".into()), Mp::Nil]));
        roundtrip(Mp::Arr((0..20).map(Mp::UInt).collect()));
        roundtrip(Mp::map_from(vec![
            ("shape", Mp::Arr(vec![Mp::UInt(2), Mp::UInt(3)])),
            ("data", Mp::Bin(vec![1, 2, 3])),
        ]));
        // 16+ entry map exercises map16 encoding.
        roundtrip(Mp::Map(
            (0..40).map(|i| (format!("k{i}"), Mp::Int(-(i + 1)))).collect(),
        ));
    }

    #[test]
    fn negative_int_encodings_match_spec() {
        assert_eq!(Mp::Int(-1).encode(), vec![0xff]);
        assert_eq!(Mp::Int(-32).encode(), vec![0xe0]);
        assert_eq!(Mp::Int(-33).encode(), vec![0xd0, 0xdf]);
        assert_eq!(Mp::UInt(5).encode(), vec![0x05]);
        assert_eq!(Mp::UInt(200).encode(), vec![0xcc, 200]);
    }

    #[test]
    fn bin_map_roundtrip() {
        let mut m = BinMap::new();
        m.insert("indices".into(), vec![0, 1, 2, 3]);
        m.insert("values".into(), vec![9; 100]);
        let enc = encode_bin_map(&m);
        assert_eq!(decode_bin_map(&enc).unwrap(), m);
    }

    #[test]
    fn rejects_truncated_and_trailing() {
        let enc = Mp::Str("hello".into()).encode();
        assert!(Mp::decode(&enc[..3]).is_err());
        let mut with_extra = enc.clone();
        with_extra.push(0);
        assert!(matches!(Mp::decode(&with_extra), Err(MpError::Trailing(_))));
    }

    #[test]
    fn rejects_non_string_map_keys() {
        // fixmap with 1 entry whose key is an int.
        let bytes = vec![0x81, 0x01, 0x02];
        assert!(matches!(Mp::decode(&bytes), Err(MpError::BadKey(_))));
    }
}
