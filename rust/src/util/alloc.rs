//! Heap high-water-mark tracking for benchmarks and tests.
//!
//! [`TrackingAlloc`] wraps the system allocator with two relaxed
//! atomic counters: live bytes and the peak live bytes since the last
//! [`reset_peak`]. It is *not* installed by the library — a binary
//! opts in with
//!
//! ```ignore
//! #[global_allocator]
//! static ALLOC: git_theta::util::alloc::TrackingAlloc = TrackingAlloc;
//! ```
//!
//! as the `git-theta` CLI, `benches/ablation_checkout.rs`, and
//! `rust/tests/checkout_engine.rs` do. The checkout ablation uses it
//! to report peak transient allocation of the smudge path; when the
//! running binary has not installed it, [`active`] returns false and
//! consumers print `n/a` instead of zeros.

use std::alloc::{GlobalAlloc, Layout, System};
use std::sync::atomic::{AtomicUsize, Ordering};

/// A [`System`] wrapper that maintains live/peak heap-byte counters.
pub struct TrackingAlloc;

static CURRENT: AtomicUsize = AtomicUsize::new(0);
static PEAK: AtomicUsize = AtomicUsize::new(0);

fn add(n: usize) {
    let cur = CURRENT.fetch_add(n, Ordering::Relaxed) + n;
    PEAK.fetch_max(cur, Ordering::Relaxed);
}

fn sub(n: usize) {
    CURRENT.fetch_sub(n, Ordering::Relaxed);
}

unsafe impl GlobalAlloc for TrackingAlloc {
    unsafe fn alloc(&self, layout: Layout) -> *mut u8 {
        let p = System.alloc(layout);
        if !p.is_null() {
            add(layout.size());
        }
        p
    }

    unsafe fn alloc_zeroed(&self, layout: Layout) -> *mut u8 {
        let p = System.alloc_zeroed(layout);
        if !p.is_null() {
            add(layout.size());
        }
        p
    }

    unsafe fn dealloc(&self, ptr: *mut u8, layout: Layout) {
        System.dealloc(ptr, layout);
        sub(layout.size());
    }

    unsafe fn realloc(&self, ptr: *mut u8, layout: Layout, new_size: usize) -> *mut u8 {
        let p = System.realloc(ptr, layout, new_size);
        if !p.is_null() {
            sub(layout.size());
            add(new_size);
        }
        p
    }
}

/// Whether a [`TrackingAlloc`] is installed in this binary (any heap
/// traffic has been observed). Startup always allocates, so this is
/// reliable by the time user code runs.
pub fn active() -> bool {
    PEAK.load(Ordering::Relaxed) > 0
}

/// Bytes currently live on the heap.
pub fn current_bytes() -> usize {
    CURRENT.load(Ordering::Relaxed)
}

/// Peak live heap bytes since the last [`reset_peak`].
pub fn peak_bytes() -> usize {
    PEAK.load(Ordering::Relaxed)
}

/// Restart peak tracking from the current live-byte level. Returns the
/// level the measurement starts from, so callers can report
/// `peak_bytes() - reset_peak()` as the transient high-water mark of a
/// region.
pub fn reset_peak() -> usize {
    let cur = CURRENT.load(Ordering::Relaxed);
    PEAK.store(cur, Ordering::Relaxed);
    cur
}

#[cfg(test)]
mod tests {
    // The library's own test binary does not install the allocator, so
    // counters stay zero here — behavior is asserted in
    // `rust/tests/checkout_engine.rs`, which does install it. This only
    // checks the API is callable and self-consistent.
    #[test]
    fn counters_are_consistent_without_install() {
        let base = super::reset_peak();
        assert_eq!(base, super::current_bytes());
        assert!(super::peak_bytes() >= base);
    }
}
