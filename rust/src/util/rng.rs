//! Deterministic PRNG (PCG64-DXSM variant) + Gaussian sampling.
//!
//! Used for the LSH random pool (which must be identical across machines
//! and across the Rust/JAX implementations — both seed from the same
//! integer and use the same generator defined here), synthetic workload
//! generation, and the property-test harness.

/// PCG64-DXSM style generator with a 128-bit state.
#[derive(Debug, Clone)]
pub struct Pcg64 {
    state: u128,
    inc: u128,
}

const PCG_MULT: u128 = 0x2360ed051fc65da44385df649fccf645;

impl Pcg64 {
    /// Seed a generator. Equal seeds yield identical streams on every
    /// platform (and match the JAX-side pool generator).
    pub fn new(seed: u64) -> Pcg64 {
        // SplitMix-style seeding to fill 128 bits of state from 64.
        let mut sm = seed.wrapping_add(0x9E3779B97F4A7C15);
        let mut next = || {
            sm = sm.wrapping_add(0x9E3779B97F4A7C15);
            let mut z = sm;
            z = (z ^ (z >> 30)).wrapping_mul(0xBF58476D1CE4E5B9);
            z = (z ^ (z >> 27)).wrapping_mul(0x94D049BB133111EB);
            z ^ (z >> 31)
        };
        let state = ((next() as u128) << 64) | next() as u128;
        let inc = (((next() as u128) << 64) | next() as u128) | 1;
        let mut rng = Pcg64 { state, inc };
        rng.next_u64();
        rng
    }

    /// Next uniformly distributed 64-bit value.
    pub fn next_u64(&mut self) -> u64 {
        self.state = self
            .state
            .wrapping_mul(PCG_MULT)
            .wrapping_add(self.inc);
        // DXSM output permutation.
        let mut hi = (self.state >> 64) as u64;
        let lo = (self.state as u64) | 1;
        hi ^= hi >> 32;
        hi = hi.wrapping_mul(0xda942042e4dd58b5);
        hi ^= hi >> 48;
        hi.wrapping_mul(lo)
    }

    /// Next uniformly distributed 32-bit value (the high word of
    /// [`next_u64`](Pcg64::next_u64)).
    pub fn next_u32(&mut self) -> u32 {
        (self.next_u64() >> 32) as u32
    }

    /// Uniform in [0, 1).
    pub fn next_f64(&mut self) -> f64 {
        (self.next_u64() >> 11) as f64 * (1.0 / (1u64 << 53) as f64)
    }

    /// Uniform in [0, 1) as f32.
    pub fn next_f32(&mut self) -> f32 {
        (self.next_u32() >> 8) as f32 * (1.0 / (1u32 << 24) as f32)
    }

    /// Uniform integer in [0, bound) without modulo bias (Lemire).
    pub fn below(&mut self, bound: u64) -> u64 {
        assert!(bound > 0);
        loop {
            let r = self.next_u64();
            let (hi, lo) = mul128(r, bound);
            if lo >= bound || lo >= r.wrapping_neg() % bound {
                return hi;
            }
        }
    }

    /// Standard normal via Box–Muller (matches python/compile/poolgen).
    pub fn next_gaussian(&mut self) -> f64 {
        loop {
            let u1 = self.next_f64();
            if u1 <= f64::MIN_POSITIVE {
                continue;
            }
            let u2 = self.next_f64();
            return (-2.0 * u1.ln()).sqrt() * (2.0 * std::f64::consts::PI * u2).cos();
        }
    }

    /// Fill a slice with N(0, sigma) f32 values.
    pub fn fill_gaussian_f32(&mut self, out: &mut [f32], sigma: f32) {
        for v in out.iter_mut() {
            *v = self.next_gaussian() as f32 * sigma;
        }
    }

    /// Choose `k` distinct indices in [0, n) (partial Fisher–Yates).
    pub fn choose_indices(&mut self, n: usize, k: usize) -> Vec<usize> {
        assert!(k <= n);
        let mut idx: Vec<usize> = (0..n).collect();
        for i in 0..k {
            let j = i + self.below((n - i) as u64) as usize;
            idx.swap(i, j);
        }
        idx.truncate(k);
        idx
    }

    /// Shuffle a slice in place.
    pub fn shuffle<T>(&mut self, items: &mut [T]) {
        for i in (1..items.len()).rev() {
            let j = self.below((i + 1) as u64) as usize;
            items.swap(i, j);
        }
    }
}

fn mul128(a: u64, b: u64) -> (u64, u64) {
    let wide = (a as u128) * (b as u128);
    ((wide >> 64) as u64, wide as u64)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic_per_seed() {
        let mut a = Pcg64::new(42);
        let mut b = Pcg64::new(42);
        for _ in 0..100 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
        let mut c = Pcg64::new(43);
        assert_ne!(Pcg64::new(42).next_u64(), c.next_u64());
    }

    #[test]
    fn uniform_range() {
        let mut rng = Pcg64::new(7);
        for _ in 0..10_000 {
            let f = rng.next_f64();
            assert!((0.0..1.0).contains(&f));
        }
    }

    #[test]
    fn below_is_in_range_and_covers() {
        let mut rng = Pcg64::new(9);
        let mut seen = [false; 10];
        for _ in 0..1000 {
            let v = rng.below(10) as usize;
            assert!(v < 10);
            seen[v] = true;
        }
        assert!(seen.iter().all(|&s| s));
    }

    #[test]
    fn gaussian_moments() {
        let mut rng = Pcg64::new(1234);
        let n = 200_000;
        let mut sum = 0.0;
        let mut sumsq = 0.0;
        for _ in 0..n {
            let g = rng.next_gaussian();
            sum += g;
            sumsq += g * g;
        }
        let mean = sum / n as f64;
        let var = sumsq / n as f64 - mean * mean;
        assert!(mean.abs() < 0.01, "mean {mean}");
        assert!((var - 1.0).abs() < 0.02, "var {var}");
    }

    #[test]
    fn choose_indices_distinct() {
        let mut rng = Pcg64::new(5);
        let idx = rng.choose_indices(100, 20);
        assert_eq!(idx.len(), 20);
        let mut sorted = idx.clone();
        sorted.sort_unstable();
        sorted.dedup();
        assert_eq!(sorted.len(), 20);
    }
}
