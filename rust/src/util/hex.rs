//! Hex encoding/decoding for object ids (sha256 digests).

/// Encode bytes as lowercase hex.
pub fn encode(bytes: &[u8]) -> String {
    const HEX: &[u8; 16] = b"0123456789abcdef";
    let mut out = String::with_capacity(bytes.len() * 2);
    for &b in bytes {
        out.push(HEX[(b >> 4) as usize] as char);
        out.push(HEX[(b & 0xf) as usize] as char);
    }
    out
}

/// Decode a hex string; `None` on odd length or non-hex characters.
pub fn decode(s: &str) -> Option<Vec<u8>> {
    if s.len() % 2 != 0 {
        return None;
    }
    let mut out = Vec::with_capacity(s.len() / 2);
    let bytes = s.as_bytes();
    for pair in bytes.chunks(2) {
        let hi = (pair[0] as char).to_digit(16)?;
        let lo = (pair[1] as char).to_digit(16)?;
        out.push(((hi << 4) | lo) as u8);
    }
    Some(out)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn roundtrip() {
        let data = [0u8, 1, 0x7f, 0x80, 0xff, 0xab];
        let s = encode(&data);
        assert_eq!(s, "00017f80ffab");
        assert_eq!(decode(&s).unwrap(), data);
    }

    #[test]
    fn rejects_bad_input() {
        assert!(decode("abc").is_none());
        assert!(decode("zz").is_none());
        assert_eq!(decode("").unwrap(), Vec::<u8>::new());
    }
}
