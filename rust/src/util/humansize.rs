//! Human-readable byte sizes and durations for reports and the CLI.

/// Format bytes like the paper's tables: "11.4GB", "0.27GB", "1024kB".
pub fn bytes(n: u64) -> String {
    const KB: f64 = 1000.0;
    let n = n as f64;
    if n >= KB * KB * KB {
        format!("{:.2}GB", n / (KB * KB * KB))
    } else if n >= KB * KB {
        format!("{:.2}MB", n / (KB * KB))
    } else if n >= KB {
        format!("{:.1}kB", n / KB)
    } else {
        format!("{n}B")
    }
}

/// Format a duration like the paper's tables: "2m 24.6s", "35.6s".
pub fn duration(secs: f64) -> String {
    if secs >= 60.0 {
        let m = (secs / 60.0).floor() as u64;
        format!("{m}m {:.1}s", secs - m as f64 * 60.0)
    } else if secs >= 1.0 {
        format!("{secs:.1}s")
    } else {
        format!("{:.1}ms", secs * 1e3)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn formats_bytes() {
        assert_eq!(bytes(512), "512B");
        assert_eq!(bytes(2_048), "2.0kB");
        assert_eq!(bytes(11_400_000_000), "11.40GB");
        assert_eq!(bytes(270_000_000), "270.00MB");
    }

    #[test]
    fn formats_duration() {
        assert_eq!(duration(144.6), "2m 24.6s");
        assert_eq!(duration(35.6), "35.6s");
        assert_eq!(duration(0.0352), "35.2ms");
    }
}
