//! Self-contained support substrates.
//!
//! The offline build environment provides no serde/clap/criterion/rayon,
//! so the small generic pieces Git-Theta needs are implemented here:
//! JSON and MessagePack codecs, hex, glob matching, a PCG64 RNG, a
//! scoped-thread parallel map, human-readable sizes, temp dirs, a
//! tiny property-testing harness, a minimal HTTP/1.1 codec for the
//! remote transport, and an opt-in heap high-water-mark allocator for
//! benchmarks.

pub mod alloc;
pub mod glob;
pub mod hex;
pub mod http;
pub mod humansize;
pub mod json;
pub mod msgpack;
pub mod par;
pub mod prop;
pub mod rng;
pub mod tmp;
