//! Gitattributes-style glob matching.
//!
//! Supports `*` (any run of non-separator chars), `?` (one non-separator
//! char), `**` (any run including separators), and character classes
//! `[abc]` / `[a-z]` / `[!abc]`. Matching semantics follow what
//! `.gitattributes` patterns need: a pattern without a slash matches the
//! basename of a path; a pattern with a slash matches the full path.

/// A compiled glob pattern.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct Glob {
    pattern: String,
    has_slash: bool,
}

impl Glob {
    /// Compile a pattern (a leading `./` is stripped; whether the
    /// pattern contains a `/` decides basename vs full-path matching).
    pub fn new(pattern: &str) -> Glob {
        Glob {
            pattern: pattern.trim_start_matches("./").to_string(),
            has_slash: pattern.contains('/'),
        }
    }

    /// The normalized source pattern this glob was compiled from.
    pub fn pattern(&self) -> &str {
        &self.pattern
    }

    /// Does this glob match the given repository-relative path?
    pub fn matches(&self, path: &str) -> bool {
        let path = path.trim_start_matches("./");
        if self.has_slash {
            glob_match(&self.pattern, path)
        } else {
            let base = path.rsplit('/').next().unwrap_or(path);
            glob_match(&self.pattern, base)
        }
    }
}

/// Core matcher over full strings.
pub fn glob_match(pattern: &str, text: &str) -> bool {
    let p: Vec<char> = pattern.chars().collect();
    let t: Vec<char> = text.chars().collect();
    match_at(&p, 0, &t, 0)
}

fn match_at(p: &[char], mut pi: usize, t: &[char], mut ti: usize) -> bool {
    while pi < p.len() {
        match p[pi] {
            '*' => {
                // Collapse consecutive stars; detect `**`.
                let mut stars = 0;
                while pi < p.len() && p[pi] == '*' {
                    stars += 1;
                    pi += 1;
                }
                let cross_sep = stars >= 2;
                // `**/` can also match zero directories.
                if cross_sep && pi < p.len() && p[pi] == '/' && match_at(p, pi + 1, t, ti) {
                    return true;
                }
                for k in ti..=t.len() {
                    if match_at(p, pi, t, k) {
                        return true;
                    }
                    if k < t.len() && !cross_sep && t[k] == '/' {
                        return false;
                    }
                }
                return false;
            }
            '?' => {
                if ti >= t.len() || t[ti] == '/' {
                    return false;
                }
                pi += 1;
                ti += 1;
            }
            '[' => {
                let (matched, next_pi) = match_class(p, pi, t, ti);
                if !matched {
                    return false;
                }
                pi = next_pi;
                ti += 1;
            }
            c => {
                if ti >= t.len() || t[ti] != c {
                    return false;
                }
                pi += 1;
                ti += 1;
            }
        }
    }
    ti == t.len()
}

fn match_class(p: &[char], pi: usize, t: &[char], ti: usize) -> (bool, usize) {
    // pi points at '['. Find closing ']'.
    let mut end = pi + 1;
    let negated = end < p.len() && (p[end] == '!' || p[end] == '^');
    let start = if negated { pi + 2 } else { pi + 1 };
    end = start;
    // A ']' directly after the opening (or '!') is a literal member.
    if end < p.len() && p[end] == ']' {
        end += 1;
    }
    while end < p.len() && p[end] != ']' {
        end += 1;
    }
    if end >= p.len() {
        // Unterminated class: treat '[' literally.
        return (ti < t.len() && t[ti] == '[', pi + 1);
    }
    if ti >= t.len() || t[ti] == '/' {
        return (false, end + 1);
    }
    let c = t[ti];
    let mut matched = false;
    let mut i = start;
    while i < end {
        if i + 2 < end && p[i + 1] == '-' {
            if p[i] <= c && c <= p[i + 2] {
                matched = true;
            }
            i += 3;
        } else {
            if p[i] == c {
                matched = true;
            }
            i += 1;
        }
    }
    (matched != negated, end + 1)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn literal_and_star() {
        assert!(glob_match("model.pt", "model.pt"));
        assert!(!glob_match("model.pt", "model.pth"));
        assert!(glob_match("*.pt", "model.pt"));
        assert!(!glob_match("*.pt", "dir/model.pt")); // '*' does not cross '/'
        assert!(glob_match("**/*.pt", "dir/sub/model.pt"));
        assert!(glob_match("**/*.pt", "model.pt")); // `**/` matches zero dirs
        assert!(glob_match("dir/**", "dir/a/b/c"));
    }

    #[test]
    fn question_and_class() {
        assert!(glob_match("v?.bin", "v1.bin"));
        assert!(!glob_match("v?.bin", "v12.bin"));
        assert!(glob_match("v[0-9].bin", "v7.bin"));
        assert!(!glob_match("v[0-9].bin", "vx.bin"));
        assert!(glob_match("v[!0-9].bin", "vx.bin"));
    }

    #[test]
    fn gitattributes_basename_semantics() {
        let g = Glob::new("*.ckpt");
        assert!(g.matches("a/b/model.ckpt"));
        assert!(g.matches("model.ckpt"));
        let g2 = Glob::new("models/*.ckpt");
        assert!(g2.matches("models/m.ckpt"));
        assert!(!g2.matches("other/m.ckpt"));
    }
}
