//! Temp directories for tests and benches (tempfile substitute).

use std::path::{Path, PathBuf};
use std::sync::atomic::{AtomicU64, Ordering};

static COUNTER: AtomicU64 = AtomicU64::new(0);

/// A directory deleted on drop.
pub struct TempDir {
    path: PathBuf,
}

impl TempDir {
    /// Create a fresh directory under the system temp dir.
    pub fn new(prefix: &str) -> std::io::Result<TempDir> {
        let n = COUNTER.fetch_add(1, Ordering::Relaxed);
        let pid = std::process::id();
        let nanos = std::time::SystemTime::now()
            .duration_since(std::time::UNIX_EPOCH)
            .map(|d| d.subsec_nanos())
            .unwrap_or(0);
        let path = std::env::temp_dir().join(format!("theta-{prefix}-{pid}-{n}-{nanos}"));
        std::fs::create_dir_all(&path)?;
        Ok(TempDir { path })
    }

    pub fn path(&self) -> &Path {
        &self.path
    }

    pub fn join(&self, rel: &str) -> PathBuf {
        self.path.join(rel)
    }

    /// Release ownership without deleting (for debugging).
    pub fn keep(mut self) -> PathBuf {
        let p = std::mem::take(&mut self.path);
        std::mem::forget(self);
        p
    }
}

impl Drop for TempDir {
    fn drop(&mut self) {
        if !self.path.as_os_str().is_empty() {
            let _ = std::fs::remove_dir_all(&self.path);
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn creates_and_cleans_up() {
        let path;
        {
            let td = TempDir::new("t").unwrap();
            path = td.path().to_path_buf();
            std::fs::write(td.join("x"), b"hello").unwrap();
            assert!(path.exists());
        }
        assert!(!path.exists());
    }

    #[test]
    fn distinct_dirs() {
        let a = TempDir::new("t").unwrap();
        let b = TempDir::new("t").unwrap();
        assert_ne!(a.path(), b.path());
    }
}
